"""Layer-2 JAX model: the chiplet compute graph MCMComm schedules.

MCMComm executes a DNN as a *sequence of GEMMs* spatially partitioned over
a chiplet grid (paper section 4.2.2). The unit of work the Rust coordinator
dispatches to one chiplet is a GEMM *chunk*:

    out[Px rows, Py cols] = epilogue( x_chunk @ w_chunk (+ bias_chunk) )

This module defines that chunk as a jittable JAX function built on the L1
Pallas output-stationary kernel, plus a chained variant used to validate
inter-layer semantics (the pattern on-package redistribution rearranges).

These functions exist only on the *compile path*: `aot.py` lowers them once
per shape bucket to HLO text under `artifacts/`, and the Rust runtime
(rust/src/runtime) loads and executes the artifacts via PJRT. Python never
runs at serving time.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from compile.kernels.matmul_os import matmul_os


def chiplet_gemm(x, w, bias, *, relu: bool):
    """One chiplet's share of a partitioned GEMM, with fused epilogue.

    Returns a 1-tuple so the lowered HLO entry computation is a tuple —
    the calling convention the Rust loader unwraps with `to_tuple1()`.
    """
    return (matmul_os(x, w, bias, relu=relu),)


def chiplet_gemm_fn(relu: bool):
    """The jittable chunk function for a given epilogue configuration."""
    return functools.partial(chiplet_gemm, relu=relu)


def gemm_chain(x, weights_and_biases, relus):
    """Layer-sequential chain of GEMMs — inter-layer validation graph.

    ``weights_and_biases`` is a flat tuple (w0, b0, w1, b1, ...) so the
    function stays lowerable with positional ShapeDtypeStructs.
    """
    out = x
    for idx, relu in enumerate(relus):
        w = weights_and_biases[2 * idx]
        b = weights_and_biases[2 * idx + 1]
        (out,) = chiplet_gemm(out, w, b, relu=relu)
    return (out,)


def lower_chiplet_gemm(m: int, k: int, n: int, relu: bool,
                       dtype=jnp.float32):
    """Lower the chunk function for a concrete (M, K, N) shape bucket."""
    x = jax.ShapeDtypeStruct((m, k), dtype)
    w = jax.ShapeDtypeStruct((k, n), dtype)
    b = jax.ShapeDtypeStruct((n,), dtype)
    return jax.jit(chiplet_gemm_fn(relu)).lower(x, w, b)
