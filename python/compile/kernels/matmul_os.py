"""Layer-1 Pallas kernel: output-stationary tiled GEMM with fused epilogue.

This is the numeric twin of the paper's compute model. MCMComm models every
chiplet as an R x C systolic array running the *output-stationary* dataflow
(eq. 7, SCALE-Sim latency model):

    comp_{x,y} = (2R + C + K - 2) * (Px/R) * (Py/C)

i.e. the PE array holds one (R x C) output tile resident while the K
(contraction) dimension streams through it, then moves to the next output
tile — Px/R * Py/C tile iterations in total. The Pallas kernel below
realizes exactly that schedule:

  * grid = (M/bm, N/bn, K/bk): the two outer grid axes walk output tiles
    (the "stationary" part), the innermost axis streams the contraction;
  * the accumulator lives in a VMEM scratch ref across the K steps of one
    output tile and is written out once per tile, on the last K step,
    together with the fused bias/ReLU epilogue.

TPU adaptation notes (DESIGN.md section Hardware-Adaptation): on a real TPU
the (bm, bk) x (bk, bn) block product maps onto the 128x128 MXU and the
three blocks must co-reside in ~16 MiB VMEM; block choice is therefore
bm = bn = bk = 128 when shapes allow (3 * 128*128 * 4 B = 192 KiB per grid
step, double-buffered ~384 KiB, far inside VMEM; MXU-shaped operands give
the systolic array full occupancy). We *always* lower with interpret=True:
the CPU PJRT plugin cannot execute Mosaic custom-calls, and correctness is
the build-time contract (pytest vs `ref.py`); TPU efficiency is estimated
analytically in EXPERIMENTS.md section Perf.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _pick_block(dim: int, preferred: int = 128, floor: int = 8) -> int:
    """Largest power-of-two block <= `preferred` that divides `dim`.

    Shapes fed by the AOT bucketizer are powers of two >= 16, so this
    normally returns 128 (the MXU-shaped block) or the dimension itself
    for small dims. Falls back to the largest divisor >= floor, or `dim`.
    """
    b = preferred
    while b >= floor:
        if dim % b == 0:
            return b
        b //= 2
    return dim


def _gemm_kernel(x_ref, w_ref, b_ref, o_ref, acc_ref, *, nk: int, relu: bool,
                 has_bias: bool):
    """One grid step: accumulate x_tile @ w_tile into the stationary tile.

    Grid axes: (i, j, k) = (output-row tile, output-col tile, contraction
    step). `acc_ref` is VMEM scratch holding the output-stationary
    accumulator; it is zeroed on k == 0 and flushed (with epilogue) on
    k == nk - 1.
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        x_ref[...].astype(jnp.float32),
        w_ref[...].astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )

    @pl.when(k == nk - 1)
    def _flush():
        acc = acc_ref[...]
        if has_bias:
            acc = acc + b_ref[...].astype(jnp.float32)[None, :]
        if relu:
            acc = jnp.maximum(acc, 0.0)
        o_ref[...] = acc


@functools.partial(
    jax.jit, static_argnames=("relu", "bm", "bn", "bk", "interpret"))
def matmul_os(x, w, bias=None, *, relu: bool = False, bm: int = 0,
              bn: int = 0, bk: int = 0, interpret: bool = True):
    """Output-stationary tiled GEMM: ``epilogue(x @ w + bias)``.

    Args:
      x:    [M, K] activations (f32 or bf16).
      w:    [K, N] weights.
      bias: optional [N] bias fused into the epilogue.
      relu: fuse ``max(0, .)`` into the epilogue.
      bm/bn/bk: block sizes; 0 = auto (MXU-preferred 128, divisor of shape).
      interpret: run the Pallas interpreter (required on CPU PJRT).

    Returns:
      [M, N] float32 output (f32 accumulation regardless of input dtype).
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"contraction mismatch: {x.shape} @ {w.shape}"
    bm = bm or _pick_block(m)
    bn = bn or _pick_block(n)
    bk = bk or _pick_block(k)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (
        f"blocks ({bm},{bn},{bk}) must divide shape ({m},{k},{n}); "
        "the AOT bucketizer guarantees power-of-two dims")
    nk = k // bk

    has_bias = bias is not None
    if not has_bias:
        # Pallas wants a concrete ref; feed a zero vector that the kernel
        # never reads (has_bias is closed over statically).
        bias = jnp.zeros((n,), dtype=x.dtype)

    kernel = functools.partial(
        _gemm_kernel, nk=nk, relu=relu, has_bias=has_bias)

    return pl.pallas_call(
        kernel,
        grid=(m // bm, n // bn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bn,), lambda i, j, kk: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, w, bias)
