"""Pure-jnp correctness oracle for the chiplet GEMM kernel.

This is the *reference semantics* the Pallas output-stationary kernel
(`matmul_os.py`) must match bit-for-bit (up to float tolerance). Every
pytest in `python/tests/` checks kernel-vs-ref; this file must therefore
stay dependency-free and obviously correct.
"""

from __future__ import annotations

import jax.numpy as jnp


def ref_gemm(x, w, bias=None, relu: bool = False):
    """Reference GEMM with optional fused bias-add and ReLU epilogue.

    Args:
      x:    [M, K] activations.
      w:    [K, N] weights.
      bias: optional [N] bias, added to every output row.
      relu: apply max(0, .) after the (optional) bias add.

    Returns:
      [M, N] output in float32 accumulation (matching the kernel, which
      accumulates in f32 regardless of input dtype).
    """
    out = jnp.dot(x.astype(jnp.float32), w.astype(jnp.float32),
                  preferred_element_type=jnp.float32)
    if bias is not None:
        out = out + bias.astype(jnp.float32)[None, :]
    if relu:
        out = jnp.maximum(out, 0.0)
    return out


def ref_gemm_chain(x, weights, biases=None, relus=None):
    """Reference for a sequence of chained GEMMs (layer-sequential model).

    ``out_i = epilogue(out_{i-1} @ W_i)`` — the inter-layer pattern the
    paper's on-package redistribution (Section 5.2) optimizes.
    """
    n = len(weights)
    biases = biases if biases is not None else [None] * n
    relus = relus if relus is not None else [False] * n
    out = x
    for w, b, r in zip(weights, biases, relus):
        out = ref_gemm(out, w, b, r)
    return out
