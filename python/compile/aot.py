"""AOT compile path: lower the L2 chunk function to HLO-text artifacts.

Emits one artifact per (M, K, N, relu) *shape bucket* plus a manifest the
Rust runtime reads. HLO is shape-static, so the runtime pads a chiplet's
chunk up to the nearest bucket and slices the result back (see
rust/src/runtime/artifacts.rs); buckets are powers of two so padding waste
is bounded by 2x per dim.

Interchange format is HLO **text**, not a serialized HloModuleProto: jax
>= 0.5 emits protos with 64-bit instruction ids which xla_extension 0.5.1
(the version behind the `xla` crate) rejects; the text parser reassigns
ids and round-trips cleanly (see /opt/xla-example/README.md).

Usage: cd python && python -m compile.aot --out-dir ../artifacts
"""

from __future__ import annotations

import argparse
import json
import os

from jax._src.lib import xla_client as xc

from compile.model import lower_chiplet_gemm

# Power-of-two bucket dims. 16 = one systolic tile (paper Table 2:
# 16x16 PE array); 256 caps a single chunk at 256^3 = 16.8M MACs so the
# interpret-mode CPU path stays fast in tests and examples; 1024 covers
# the contraction dims of the scaled model zoo (e.g. AlexNet-mini fc6).
BUCKET_DIMS = (16, 64, 256, 1024)


def to_hlo_text(lowered) -> str:
    """StableHLO MLIR -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def bucket_name(m: int, k: int, n: int, relu: bool) -> str:
    return f"gemm_m{m}_k{k}_n{n}_{'relu' if relu else 'id'}"


def emit_all(out_dir: str, dims=BUCKET_DIMS, verbose: bool = True) -> dict:
    """Lower every bucket; write artifacts + manifest. Returns manifest."""
    os.makedirs(out_dir, exist_ok=True)
    entries = []
    for m in dims:
        for k in dims:
            for n in dims:
                for relu in (False, True):
                    name = bucket_name(m, k, n, relu)
                    path = f"{name}.hlo.txt"
                    text = to_hlo_text(lower_chiplet_gemm(m, k, n, relu))
                    with open(os.path.join(out_dir, path), "w") as f:
                        f.write(text)
                    entries.append({
                        "name": name, "path": path,
                        "m": m, "k": k, "n": n,
                        "relu": relu, "dtype": "f32",
                    })
                    if verbose:
                        print(f"  wrote {path} ({len(text)} chars)")
    manifest = {
        "version": 1,
        "kernel": "matmul_os",
        "accum_dtype": "f32",
        "buckets": entries,
    }
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    if verbose:
        print(f"wrote manifest.json ({len(entries)} buckets)")
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--dims", type=int, nargs="*", default=list(BUCKET_DIMS),
                    help="bucket dims (powers of two)")
    args = ap.parse_args()
    emit_all(args.out_dir, dims=tuple(args.dims))


if __name__ == "__main__":
    main()
