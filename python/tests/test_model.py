"""L2 correctness: chunk function and chain semantics + lowering sanity."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.model import chiplet_gemm, gemm_chain, lower_chiplet_gemm
from compile.kernels.ref import ref_gemm, ref_gemm_chain

RNG = np.random.default_rng(1)


def _rand(shape):
    return jnp.asarray(RNG.standard_normal(shape).astype(np.float32))


def test_chiplet_gemm_matches_ref():
    x, w, b = _rand((32, 64)), _rand((64, 32)), _rand((32,))
    (out,) = chiplet_gemm(x, w, b, relu=True)
    np.testing.assert_allclose(out, ref_gemm(x, w, b, True),
                               rtol=1e-5, atol=1e-5)


def test_chiplet_gemm_returns_tuple():
    out = chiplet_gemm(_rand((16, 16)), _rand((16, 16)), _rand((16,)),
                       relu=False)
    assert isinstance(out, tuple) and len(out) == 1


@settings(max_examples=10, deadline=None)
@given(depth=st.integers(1, 4), relu=st.booleans())
def test_gemm_chain_matches_ref_chain(depth, relu):
    dims = [32] * (depth + 1)
    x = _rand((16, dims[0]))
    ws = [_rand((dims[i], dims[i + 1])) for i in range(depth)]
    bs = [_rand((dims[i + 1],)) for i in range(depth)]
    flat = tuple(v for pair in zip(ws, bs) for v in pair)
    relus = [relu] * depth
    (out,) = gemm_chain(x, flat, relus)
    want = ref_gemm_chain(x, ws, bs, relus)
    np.testing.assert_allclose(out, want, rtol=1e-4, atol=1e-4)


def test_lowering_produces_stablehlo():
    lowered = lower_chiplet_gemm(16, 16, 16, relu=True)
    ir = str(lowered.compiler_ir("stablehlo"))
    assert "stablehlo" in ir


def test_lowered_output_shape():
    lowered = lower_chiplet_gemm(32, 16, 64, relu=False)
    compiled = lowered.compile()
    x, w, b = _rand((32, 16)), _rand((16, 64)), _rand((64,))
    (out,) = compiled(x, w, b)
    assert out.shape == (32, 64)
    np.testing.assert_allclose(out, ref_gemm(x, w, b), rtol=1e-5, atol=1e-5)
