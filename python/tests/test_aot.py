"""AOT pipeline: HLO-text artifacts + manifest are well-formed.

Runs the real emitter on a tiny bucket set into a tmpdir; the full set is
produced by `make artifacts`.
"""

import json
import os

from compile.aot import bucket_name, emit_all, to_hlo_text
from compile.model import lower_chiplet_gemm


def test_hlo_text_is_parseable_entry(tmp_path):
    text = to_hlo_text(lower_chiplet_gemm(16, 16, 16, relu=False))
    # The Rust side parses this with HloModuleProto::from_text_file.
    assert "ENTRY" in text
    assert "f32[16,16]" in text
    # Tuple return convention (unwrapped by to_tuple1 on the Rust side).
    assert "(f32[16,16]" in text


def test_emit_all_writes_manifest_and_artifacts(tmp_path):
    manifest = emit_all(str(tmp_path), dims=(16,), verbose=False)
    assert len(manifest["buckets"]) == 2  # 1 shape x {id, relu}
    on_disk = json.loads((tmp_path / "manifest.json").read_text())
    assert on_disk == manifest
    for e in manifest["buckets"]:
        p = tmp_path / e["path"]
        assert p.exists() and p.stat().st_size > 0
        assert "ENTRY" in p.read_text()


def test_bucket_name_stable():
    assert bucket_name(16, 64, 256, True) == "gemm_m16_k64_n256_relu"
    assert bucket_name(16, 64, 256, False) == "gemm_m16_k64_n256_id"


def test_relu_variant_differs(tmp_path):
    t_id = to_hlo_text(lower_chiplet_gemm(16, 16, 16, relu=False))
    t_relu = to_hlo_text(lower_chiplet_gemm(16, 16, 16, relu=True))
    assert t_id != t_relu
    assert "maximum" in t_relu
