"""L1 correctness: Pallas output-stationary GEMM vs the pure-jnp oracle.

This is the core numeric signal of the compile path — every artifact the
Rust runtime executes lowers through `matmul_os`, so the kernel must match
`ref.py` across shapes, dtypes, block choices and epilogue configs.
Hypothesis sweeps the space; a few pinned cases document known-interesting
points (single tile, tall/skinny, K=1 block count, bf16 inputs).
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.matmul_os import matmul_os, _pick_block
from compile.kernels.ref import ref_gemm, ref_gemm_chain

RNG = np.random.default_rng(0)


def _rand(shape, dtype=np.float32):
    return jnp.asarray(RNG.standard_normal(shape).astype(dtype))


def _check(m, k, n, *, relu, bias, dtype=np.float32, rtol=1e-5, atol=1e-5,
           **blocks):
    x, w = _rand((m, k), dtype), _rand((k, n), dtype)
    b = _rand((n,), dtype) if bias else None
    got = matmul_os(x, w, b, relu=relu, **blocks)
    want = ref_gemm(x, w, b, relu=relu)
    np.testing.assert_allclose(got, want, rtol=rtol, atol=atol)
    assert got.dtype == jnp.float32


# --- pinned cases ---------------------------------------------------------

def test_single_tile():
    _check(16, 16, 16, relu=False, bias=False)


def test_single_tile_full_epilogue():
    _check(16, 16, 16, relu=True, bias=True)


def test_multi_tile_square():
    _check(128, 128, 128, relu=False, bias=True)


def test_tall_skinny():
    _check(256, 16, 32, relu=True, bias=False)


def test_wide_short():
    _check(16, 256, 256, relu=False, bias=False)


def test_explicit_small_blocks():
    # Force many grid steps in every axis to exercise accumulation.
    _check(64, 64, 64, relu=True, bias=True, bm=16, bn=16, bk=16)


def test_bf16_inputs_f32_accum():
    _check(64, 64, 64, relu=False, bias=True, dtype=jnp.bfloat16,
           rtol=2e-2, atol=2e-2)


def test_relu_clamps_negatives():
    x = -jnp.ones((16, 16), jnp.float32)
    w = jnp.ones((16, 16), jnp.float32)
    out = matmul_os(x, w, relu=True)
    assert np.all(np.asarray(out) == 0.0)


def test_contraction_mismatch_raises():
    with pytest.raises(AssertionError):
        matmul_os(jnp.zeros((16, 32)), jnp.zeros((16, 16)))


# --- hypothesis sweeps ----------------------------------------------------

pow2 = st.sampled_from([16, 32, 64, 128])


@settings(max_examples=25, deadline=None)
@given(m=pow2, k=pow2, n=pow2, relu=st.booleans(), bias=st.booleans())
def test_shape_sweep(m, k, n, relu, bias):
    _check(m, k, n, relu=relu, bias=bias)


@settings(max_examples=10, deadline=None)
@given(m=st.sampled_from([16, 64]), k=st.sampled_from([16, 64]),
       n=st.sampled_from([16, 64]),
       bm=st.sampled_from([8, 16]), bk=st.sampled_from([8, 16]),
       bn=st.sampled_from([8, 16]))
def test_block_sweep(m, k, n, bm, bk, bn):
    _check(m, k, n, relu=True, bias=True, bm=bm, bn=bn, bk=bk)


@settings(max_examples=20, deadline=None)
@given(dim=st.integers(8, 512), preferred=st.sampled_from([32, 128]))
def test_pick_block_divides(dim, preferred):
    b = _pick_block(dim, preferred)
    assert dim % b == 0
    assert b <= max(preferred, dim)


# --- chain oracle sanity --------------------------------------------------

def test_chain_matches_manual():
    x = _rand((32, 16))
    ws = [_rand((16, 64)), _rand((64, 16))]
    bs = [_rand((64,)), _rand((16,))]
    out = ref_gemm_chain(x, ws, bs, [True, False])
    want = ref_gemm(ref_gemm(x, ws[0], bs[0], True), ws[1], bs[1], False)
    np.testing.assert_allclose(out, want, rtol=1e-6)
