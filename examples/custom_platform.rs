//! Packaging as data: load platform descriptions from JSON, sweep one
//! workload across them (presets *and* layouts no `SystemType` can
//! express), and report which packaging wins — the platform-API
//! counterpart of `design_space_sweep`.
//!
//!     cargo run --release --example custom_platform

use std::path::Path;

use mcmcomm::engine::{schedulers, Engine, Scenario, Scheduler};
use mcmcomm::opt::ga::GaParams;
use mcmcomm::platform::Platform;
use mcmcomm::util::bench::Reporter;
use mcmcomm::util::error::Result;
use mcmcomm::workload::models::alexnet;

fn main() -> Result<()> {
    let wl = alexnet(1);

    // Every description under examples/platforms/, plus the built-in
    // headline preset for reference. A JSON file and a preset are the
    // same thing to the engine: a validated `Platform`.
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("../examples/platforms");
    let mut platforms = vec![Platform::headline()];
    let mut files: Vec<_> = std::fs::read_dir(&dir)?
        .filter_map(|e| e.ok())
        .map(|e| e.path())
        .filter(|p| p.extension().is_some_and(|x| x == "json"))
        .collect();
    files.sort();
    for f in &files {
        platforms.push(Platform::load(f)?);
    }

    let mut scenarios = Vec::new();
    for plat in &platforms {
        scenarios.push(
            Scenario::builder()
                .platform(plat.clone())
                .workload(wl.clone())
                .build()?,
        );
    }

    let ga = schedulers::Ga::new(
        GaParams { population: 24, generations: 20, ..Default::default() },
        42,
    );
    let scheds: Vec<&dyn Scheduler> = vec![&schedulers::Baseline, &ga];
    let rows = Engine::sweep(scenarios, &scheds)?;

    let mut rep = Reporter::new(
        &format!("Platform sweep: {} latency (ms) and GA speedup", wl.name),
        &["platform", "attachments", "LS (ms)", "GA (ms)", "speedup"],
    );
    for (plat, row) in platforms.iter().zip(&rows) {
        let ls = row.outcome("baseline").unwrap().plan.objective_value;
        let ga = row.outcome("ga").unwrap().plan.objective_value;
        rep.row(vec![
            row.system(),
            plat.globals().len().to_string(),
            format!("{:.3}", ls / 1e6),
            format!("{:.3}", ga / 1e6),
            format!("{:.2}x", ls / ga),
        ]);
    }
    rep.print();
    println!(
        "\nEvery row above ran through the same engine — the asymmetric \
         L-shape and the boundary-fed 2x8 are design points no \
         SystemType enum variant could express."
    );
    Ok(())
}
