//! Quickstart: optimize a workload partition for an MCM and read the
//! analytical cost report — the 60-second tour of the public API.
//!
//!     cargo run --release --example quickstart

use mcmcomm::config::{HwConfig, MemKind, SystemType};
use mcmcomm::cost::evaluator::{evaluate, Objective, OptFlags};
use mcmcomm::opt::{run_scheme, Scheme, SchedulerConfig};
use mcmcomm::topology::Topology;
use mcmcomm::workload::models::alexnet;

fn main() {
    // 1. Describe the hardware: Table-2 MCM, type-A packaging (corner
    //    memory, like SIMBA), HBM, 4x4 chiplets of 16x16 PEs.
    let hw = HwConfig::paper(SystemType::A, MemKind::Hbm, 4);
    let topo = Topology::from_hw(&hw);

    // 2. Pick a workload from the model zoo (GEMM-sequence IR).
    let wl = alexnet(1);
    println!(
        "workload: {} ({} GEMMs, {:.2} GMACs)",
        wl.name,
        wl.ops.len(),
        wl.total_macs() as f64 / 1e9
    );

    // 3. Baseline: uniform layer-sequential execution, no optimizations.
    let cfg = SchedulerConfig::default();
    let base = run_scheme(Scheme::Baseline, &hw, &topo, &wl, &cfg);
    println!("baseline latency : {:.3} ms", base.objective_value / 1e6);

    // 4. MCMComm-GA: non-uniform partitions + diagonal links +
    //    on-package redistribution + asynchronized execution.
    let ga = run_scheme(Scheme::Ga, &hw, &topo, &wl, &cfg);
    println!(
        "GA latency       : {:.3} ms  ({:.2}x speedup)",
        ga.objective_value / 1e6,
        base.objective_value / ga.objective_value
    );

    // 5. Inspect the full cost breakdown of the optimized schedule.
    let cost = evaluate(&hw, &topo, &wl, &ga.alloc, ga.flags);
    let redist = cost.per_op.iter().filter(|o| o.redistributed_in).count();
    println!(
        "energy {:.3} mJ | EDP {:.3e} pJ*ns | {} ops fed by on-package \
         redistribution",
        cost.energy_pj / 1e9,
        cost.edp(),
        redist
    );

    // 6. The same API optimizes for EDP instead.
    let cfg_edp =
        SchedulerConfig { objective: Objective::Edp, ..Default::default() };
    let edp = run_scheme(Scheme::Ga, &hw, &topo, &wl, &cfg_edp);
    let edp_base =
        evaluate(&hw, &topo, &wl, &base.alloc, OptFlags::NONE).edp();
    println!(
        "EDP objective    : {:.2}x improvement",
        edp_base / edp.objective_value
    );
}
