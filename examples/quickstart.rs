//! Quickstart: optimize a workload partition for an MCM and read the
//! analytical cost report — the 60-second tour of the public API.
//!
//! The API is three nouns and one verb: build a validated `Scenario`
//! (hardware + workload + flags + objective), hand it to the `Engine`,
//! schedule with any `Scheduler` from the registry to get a `Plan`,
//! and score the plan into a `Report`.
//!
//!     cargo run --release --example quickstart

use mcmcomm::config::{MemKind, SystemType};
use mcmcomm::cost::evaluator::Objective;
use mcmcomm::engine::{Engine, Scenario, SchedulerRegistry};
use mcmcomm::util::error::Result;
use mcmcomm::workload::models::alexnet;

fn main() -> Result<()> {
    // 1. Describe the scenario: Table-2 MCM, type-A packaging (corner
    //    memory, like SIMBA), HBM, 4x4 chiplets of 16x16 PEs, and a
    //    workload from the model zoo (GEMM-sequence IR). The builder
    //    validates everything up front.
    let scenario = Scenario::builder()
        .system(SystemType::A)
        .mem(MemKind::Hbm)
        .grid(4)
        .workload(alexnet(1))
        .build()?;
    println!(
        "workload: {} ({} GEMMs, {:.2} GMACs)",
        scenario.workload().name,
        scenario.workload().ops.len(),
        scenario.workload().total_macs() as f64 / 1e9
    );

    // 2. The engine drives schedulers over the scenario; the registry
    //    holds the five Table-3 schemes behind the `Scheduler` trait.
    let engine = Engine::new(scenario);
    let registry = SchedulerRegistry::standard(42);

    // 3. Baseline: uniform layer-sequential execution, no optimizations.
    let base = engine.schedule(&registry, "baseline")?;
    println!("baseline latency : {:.3} ms", base.objective_value() / 1e6);

    // 4. MCMComm-GA: non-uniform partitions + diagonal links +
    //    on-package redistribution + asynchronized execution.
    let ga = engine.schedule(&registry, "ga")?;
    println!(
        "GA latency       : {:.3} ms  ({:.2}x speedup)",
        ga.objective_value() / 1e6,
        base.objective_value() / ga.objective_value()
    );

    // 5. Inspect the full cost report of the optimized plan.
    let report = ga.report();
    println!(
        "energy {:.3} mJ | EDP {:.3e} pJ*ns | {} ops fed by on-package \
         redistribution",
        report.energy_pj() / 1e9,
        report.edp(),
        report.redistributed_ops()
    );

    // 6. The same API optimizes for EDP instead: objective is part of
    //    the scenario, not scattered through solver arguments.
    let edp_engine = Engine::new(
        Scenario::builder()
            .workload(alexnet(1))
            .objective(Objective::Edp)
            .build()?,
    );
    let edp = edp_engine.schedule(&registry, "ga")?;
    let edp_base = edp_engine.schedule(&registry, "baseline")?;
    println!(
        "EDP objective    : {:.2}x improvement",
        edp_base.objective_value() / edp.objective_value()
    );
    Ok(())
}
