//! Design-space exploration: sweep packaging type x memory kind x grid
//! size for one workload and report which co-design wins where — the
//! §3.3 "packaging needs tailored optimization" observation in practice.
//!
//!     cargo run --release --example design_space_sweep

use mcmcomm::config::{HwConfig, MemKind, SystemType};
use mcmcomm::opt::{ga::GaParams, run_scheme, Scheme, SchedulerConfig};
use mcmcomm::topology::Topology;
use mcmcomm::util::bench::Reporter;
use mcmcomm::workload::models::hydranet;

fn main() {
    let wl = hydranet(1);
    let cfg = SchedulerConfig {
        ga: GaParams { population: 24, generations: 25, ..Default::default() },
        ..Default::default()
    };
    let mut rep = Reporter::new(
        &format!("Design-space sweep: {} latency (ms) and GA speedup", wl.name),
        &["system", "mem", "grid", "LS (ms)", "GA (ms)", "speedup"],
    );
    let mut best: Option<(String, f64)> = None;
    for ty in SystemType::ALL {
        for mem in [MemKind::Hbm, MemKind::Dram] {
            for grid in [4usize, 8] {
                let hw = HwConfig::paper(ty, mem, grid);
                let topo = Topology::from_hw(&hw);
                let base =
                    run_scheme(Scheme::Baseline, &hw, &topo, &wl, &cfg);
                let ga = run_scheme(Scheme::Ga, &hw, &topo, &wl, &cfg);
                let name = format!("{}-{}-{}x{}", ty.short(), mem.name(),
                                   grid, grid);
                rep.row(vec![
                    ty.name().to_string(),
                    mem.name().to_string(),
                    format!("{grid}x{grid}"),
                    format!("{:.3}", base.objective_value / 1e6),
                    format!("{:.3}", ga.objective_value / 1e6),
                    format!(
                        "{:.2}x",
                        base.objective_value / ga.objective_value
                    ),
                ]);
                if best.as_ref().is_none_or(|(_, v)| ga.objective_value < *v)
                {
                    best = Some((name, ga.objective_value));
                }
            }
        }
    }
    rep.print();
    let (name, v) = best.unwrap();
    println!("\nbest configuration: {name} at {:.3} ms", v / 1e6);
}
