//! Design-space exploration with the batch API: sweep packaging type x
//! memory kind x grid size for one workload via `Engine::sweep` and
//! report which co-design wins where — the §3.3 "packaging needs
//! tailored optimization" observation in practice.
//!
//!     cargo run --release --example design_space_sweep

use mcmcomm::config::{MemKind, SystemType};
use mcmcomm::engine::{schedulers, Engine, Scenario, Scheduler};
use mcmcomm::opt::ga::GaParams;
use mcmcomm::util::bench::Reporter;
use mcmcomm::util::error::Result;
use mcmcomm::workload::models::hydranet;

fn main() -> Result<()> {
    let wl = hydranet(1);

    // One scenario per design point.
    let mut scenarios = Vec::new();
    for ty in SystemType::ALL {
        for mem in [MemKind::Hbm, MemKind::Dram] {
            for grid in [4usize, 8] {
                scenarios.push(
                    Scenario::builder()
                        .system(ty)
                        .mem(mem)
                        .grid(grid)
                        .workload(wl.clone())
                        .build()?,
                );
            }
        }
    }

    // Two schedulers as plain trait objects — no registry needed.
    let ga = schedulers::Ga::new(
        GaParams { population: 24, generations: 25, ..Default::default() },
        42,
    );
    let scheds: Vec<&dyn Scheduler> = vec![&schedulers::Baseline, &ga];

    // The batch API: every scheduler on every scenario.
    let rows = Engine::sweep(scenarios, &scheds)?;

    let mut rep = Reporter::new(
        &format!("Design-space sweep: {} latency (ms) and GA speedup", wl.name),
        &["system", "LS (ms)", "GA (ms)", "speedup"],
    );
    let mut best: Option<(String, f64)> = None;
    for row in &rows {
        let ls = row.outcome("baseline").unwrap().plan.objective_value;
        let ga = row.outcome("ga").unwrap().plan.objective_value;
        rep.row(vec![
            row.system(),
            format!("{:.3}", ls / 1e6),
            format!("{:.3}", ga / 1e6),
            format!("{:.2}x", ls / ga),
        ]);
        if best.as_ref().map_or(true, |(_, v)| ga < *v) {
            best = Some((row.system(), ga));
        }
    }
    rep.print();
    let (name, v) = best.unwrap();
    println!("\nbest configuration: {name} at {:.3} ms", v / 1e6);
    Ok(())
}
