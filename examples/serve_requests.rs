//! Serving demo: the Layer-3 request loop batching inference requests
//! onto the simulated MCM, with every batch actually executed through
//! the GEMM runtime (Figure 1's "real-time applications" use case).
//!
//! Run `make artifacts` first, then:
//!
//!     cargo run --release --example serve_requests

use std::time::Duration;

use mcmcomm::coordinator::Executor;
use mcmcomm::serving::server::{RunnerFactory, Server};
use mcmcomm::engine::{Engine, Scenario, SchedulerRegistry};
use mcmcomm::pipeline::pipeline_speedup;
use mcmcomm::runtime::{GemmRuntime, Manifest};
use mcmcomm::util::error::Result;
use mcmcomm::workload::models::{scaled_down, vit};

fn main() -> Result<()> {
    let wl = scaled_down(&vit(1), 16, 16);
    let registry = SchedulerRegistry::standard(42);
    let engine = Engine::new(Scenario::headline(wl));
    let plan = engine.schedule(&registry, "ga")?.into_plan();
    println!(
        "serving {} on {} with the GA schedule",
        engine.scenario().workload().name,
        engine.scenario().label()
    );

    let scenario = engine.scenario().clone();
    // The runtime may not be Send (PJRT clients hold Rc): the factory
    // builds it on the batcher thread.
    let factory: RunnerFactory = Box::new(move || {
        let runtime =
            GemmRuntime::new(&Manifest::default_dir()).expect("artifacts");
        Executor::from_plan(&scenario, &plan, &runtime)
            .run(0, false)
            .expect("warmup");
        let cost = scenario.report(&plan).breakdown;
        Box::new(move |bsz| {
            let exec = Executor::from_plan(&scenario, &plan, &runtime);
            exec.run(bsz as u64, false).expect("batch run");
            let batch_ns = cost.latency_ns * bsz as f64
                / pipeline_speedup(&cost, bsz.max(1));
            (batch_ns, batch_ns / bsz as f64)
        })
    });

    let server = Server::start_factory(8, Duration::from_millis(2), factory);
    let client = server.client();
    let n_req = 24;
    let t0 = std::time::Instant::now();
    let waiters: Vec<_> = (0..n_req)
        .map(|_| client.submit())
        .collect::<Result<_>>()?;
    let mut batch_sizes = Vec::new();
    let mut per_sample = Vec::new();
    for w in waiters {
        let r = w.recv()?.done().expect("best-effort requests never shed");
        batch_sizes.push(r.batch_size);
        per_sample.push(r.modeled_per_sample_ns);
    }
    let wall = t0.elapsed();
    drop(client);
    let stats = server.shutdown();

    println!(
        "served {} requests in {} batches (max batch {}) in {:.2?}",
        stats.served, stats.batches, stats.max_batch, wall
    );
    println!(
        "modeled per-sample latency: mean {:.3} ms (batching amortizes \
         the pipeline)",
        mcmcomm::util::math::mean(&per_sample) / 1e6
    );
    println!(
        "host throughput: {:.1} req/s",
        n_req as f64 / wall.as_secs_f64()
    );
    Ok(())
}
