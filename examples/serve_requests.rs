//! Serving demo: the Layer-3 request loop batching inference requests
//! onto the simulated MCM, with every batch actually executed through
//! PJRT (Figure 1's "real-time applications" use case).
//!
//! Run `make artifacts` first, then:
//!
//!     cargo run --release --example serve_requests

use std::time::Duration;

use mcmcomm::config::{HwConfig, MemKind, SystemType};
use mcmcomm::coordinator::server::RunnerFactory;
use mcmcomm::coordinator::{Executor, Server};
use mcmcomm::cost::evaluator::evaluate;
use mcmcomm::opt::{run_scheme, Scheme, SchedulerConfig};
use mcmcomm::pipeline::pipeline_speedup;
use mcmcomm::runtime::{GemmRuntime, Manifest};
use mcmcomm::topology::Topology;
use mcmcomm::workload::models::{scaled_down, vit};

fn main() -> anyhow::Result<()> {
    let hw = HwConfig::paper(SystemType::A, MemKind::Hbm, 4);
    let topo = Topology::from_hw(&hw);
    let wl = scaled_down(&vit(1), 16, 16);
    let cfg = SchedulerConfig::default();
    let out = run_scheme(Scheme::Ga, &hw, &topo, &wl, &cfg);
    println!(
        "serving {} on 4x4 type-A HBM with the GA schedule",
        wl.name
    );

    let alloc = out.alloc.clone();
    let flags = out.flags;
    let (hw2, topo2, wl2) = (hw.clone(), topo.clone(), wl.clone());
    // PJRT clients are not Send: the factory builds the runtime on the
    // batcher thread.
    let factory: RunnerFactory = Box::new(move || {
        let runtime =
            GemmRuntime::new(&Manifest::default_dir()).expect("artifacts");
        Executor::new(&hw2, &topo2, &wl2, &alloc, flags, &runtime)
            .run(0, false)
            .expect("warmup");
        Box::new(move |bsz| {
            let exec =
                Executor::new(&hw2, &topo2, &wl2, &alloc, flags, &runtime);
            exec.run(bsz as u64, false).expect("batch run");
            let cost = evaluate(&hw2, &topo2, &wl2, &alloc, flags);
            let batch_ns = cost.latency_ns * bsz as f64
                / pipeline_speedup(&cost, bsz.max(1));
            (batch_ns, batch_ns / bsz as f64)
        })
    });

    let server = Server::start_factory(8, Duration::from_millis(2), factory);
    let client = server.client();
    let n_req = 24;
    let t0 = std::time::Instant::now();
    let waiters: Vec<_> = (0..n_req).map(|_| client.submit()).collect();
    let mut batch_sizes = Vec::new();
    let mut per_sample = Vec::new();
    for w in waiters {
        let r = w.recv()?;
        batch_sizes.push(r.batch_size);
        per_sample.push(r.modeled_per_sample_ns);
    }
    let wall = t0.elapsed();
    drop(client);
    let stats = server.shutdown();

    println!(
        "served {} requests in {} batches (max batch {}) in {:.2?}",
        stats.served, stats.batches, stats.max_batch, wall
    );
    println!(
        "modeled per-sample latency: mean {:.3} ms (batching amortizes \
         the pipeline)",
        mcmcomm::util::math::mean(&per_sample) / 1e6
    );
    println!(
        "host throughput: {:.1} req/s",
        n_req as f64 / wall.as_secs_f64()
    );
    Ok(())
}
