//! End-to-end driver (DESIGN.md "E2E" row): proves the three layers
//! compose on a real small workload.
//!
//!   L1  Pallas output-stationary GEMM kernel (python/compile/kernels)
//!   L2  JAX chunk graph, AOT-lowered to HLO-text buckets (aot.py)
//!   L3  this Rust coordinator: an MIQP/GA-optimized `Plan` from the
//!       engine, then every chiplet chunk executed through the GEMM
//!       runtime; outputs verified against a CPU reference; the modeled
//!       MCM clock reports the paper metrics.
//!
//! Run `make artifacts` first, then:
//!
//!     cargo run --release --example alexnet_e2e

use mcmcomm::coordinator::Executor;
use mcmcomm::engine::{Engine, Scenario, Scheduler, SchedulerRegistry};
use mcmcomm::runtime::{GemmRuntime, Manifest};
use mcmcomm::util::error::Result;
use mcmcomm::workload::models::{alexnet, scaled_down};

fn main() -> Result<()> {
    // AlexNet at 1/16 scale: same 8-GEMM chained structure, chunk dims
    // within the AOT bucket set (<= 256) so the runtime executes
    // quickly on CPU.
    let wl = scaled_down(&alexnet(1), 16, 16);
    let engine = Engine::new(Scenario::headline(wl));
    let registry = SchedulerRegistry::standard(42);

    println!("== MCMComm end-to-end driver ==");
    println!(
        "workload {}: {} GEMMs, {:.1} MMACs",
        engine.scenario().workload().name,
        engine.scenario().workload().ops.len(),
        engine.scenario().workload().total_macs() as f64 / 1e6
    );

    let runtime = GemmRuntime::new(&Manifest::default_dir())?;
    println!(
        "runtime platform: {} ({} buckets in manifest)",
        runtime.platform(),
        runtime.manifest().buckets.len()
    );

    for key in ["baseline", "ga", "miqp"] {
        let planned = engine.schedule(&registry, key)?;
        let exec =
            Executor::from_plan(engine.scenario(), planned.plan(), &runtime);
        let report = exec.run(42, /* verify= */ true)?;
        let scheduler = registry.require(key)?;
        println!("\n--- {} ---", scheduler.name());
        println!(
            "  {} chunk executions, host wall {:.2?}, compiled \
             executables cached: {}",
            report.chunks_executed,
            report.host_wall,
            runtime.compiled_count()
        );
        println!(
            "  numerics: max |runtime - cpu_ref| = {:.2e}  {}",
            report.max_abs_err,
            if report.max_abs_err < 1e-3 { "OK" } else { "MISMATCH" }
        );
        println!(
            "  modeled MCM: latency {:.3} ms | energy {:.3} mJ | EDP {:.3e}",
            report.modeled.latency_ns / 1e6,
            report.modeled.energy_pj / 1e9,
            report.modeled.edp()
        );
        assert!(report.max_abs_err < 1e-3, "numeric mismatch");
    }
    println!("\nall layers compose: e2e OK");
    Ok(())
}
