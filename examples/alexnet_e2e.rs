//! End-to-end driver (DESIGN.md "E2E" row): proves the three layers
//! compose on a real small workload.
//!
//!   L1  Pallas output-stationary GEMM kernel (python/compile/kernels)
//!   L2  JAX chunk graph, AOT-lowered to HLO-text buckets (aot.py)
//!   L3  this Rust coordinator: MIQP/GA-optimized schedule, then every
//!       chiplet chunk executed through PJRT; outputs verified against a
//!       CPU reference; the modeled MCM clock reports the paper metrics.
//!
//! Run `make artifacts` first, then:
//!
//!     cargo run --release --example alexnet_e2e

use mcmcomm::config::{HwConfig, MemKind, SystemType};
use mcmcomm::coordinator::Executor;
use mcmcomm::opt::{run_scheme, Scheme, SchedulerConfig};
use mcmcomm::runtime::{GemmRuntime, Manifest};
use mcmcomm::topology::Topology;
use mcmcomm::workload::models::{alexnet, scaled_down};

fn main() -> anyhow::Result<()> {
    // AlexNet at 1/16 scale: same 8-GEMM chained structure, chunk dims
    // within the AOT bucket set (<= 256) so interpret-lowered kernels
    // execute quickly on the CPU PJRT client.
    let wl = scaled_down(&alexnet(1), 16, 16);
    let hw = HwConfig::paper(SystemType::A, MemKind::Hbm, 4);
    let topo = Topology::from_hw(&hw);

    println!("== MCMComm end-to-end driver ==");
    println!(
        "workload {}: {} GEMMs, {:.1} MMACs",
        wl.name,
        wl.ops.len(),
        wl.total_macs() as f64 / 1e6
    );

    let runtime = GemmRuntime::new(&Manifest::default_dir())?;
    println!(
        "PJRT platform: {} ({} buckets in manifest)",
        runtime.platform(),
        runtime.manifest().buckets.len()
    );

    let cfg = SchedulerConfig::default();
    for scheme in [Scheme::Baseline, Scheme::Ga, Scheme::Miqp] {
        let out = run_scheme(scheme, &hw, &topo, &wl, &cfg);
        let exec =
            Executor::new(&hw, &topo, &wl, &out.alloc, out.flags, &runtime);
        let report = exec.run(42, /* verify= */ true)?;
        println!("\n--- {} ---", scheme.name());
        println!(
            "  {} PJRT chunk executions, host wall {:.2?}, compiled \
             executables cached: {}",
            report.chunks_executed,
            report.host_wall,
            runtime.compiled_count()
        );
        println!(
            "  numerics: max |pjrt - cpu_ref| = {:.2e}  {}",
            report.max_abs_err,
            if report.max_abs_err < 1e-3 { "OK" } else { "MISMATCH" }
        );
        println!(
            "  modeled MCM: latency {:.3} ms | energy {:.3} mJ | EDP {:.3e}",
            report.modeled.latency_ns / 1e6,
            report.modeled.energy_pj / 1e9,
            report.modeled.edp()
        );
        assert!(report.max_abs_err < 1e-3, "numeric mismatch");
    }
    println!("\nall layers compose: e2e OK");
    Ok(())
}
