//! Batch pipelining in action (paper §5.4 / Figure 11): expand a batch
//! into the RCPSP task DAG, schedule it with the list scheduler and the
//! exact branch & bound, and inspect the overlap. Cost breakdowns come
//! from the engine's `Report` — no raw evaluator calls.
//!
//!     cargo run --release --example pipeline_batching

use mcmcomm::engine::Scenario;
use mcmcomm::pipeline::{
    batch_tasks, exact_schedule, list_schedule, sequential_makespan,
    validate_schedule,
};
use mcmcomm::util::bench::Reporter;
use mcmcomm::util::error::Result;
use mcmcomm::workload::models::{alexnet, scaled_down};
use mcmcomm::workload::Workload;

fn main() -> Result<()> {
    // Full AlexNet through the list scheduler at several batch sizes.
    let scenario = Scenario::headline(alexnet(1));
    let cost = scenario.baseline_report().breakdown;
    let mut rep = Reporter::new(
        "Pipelining: per-sample speedup (list scheduler)",
        &["batch", "sequential (ms)", "pipelined (ms)", "speedup"],
    );
    for batch in [1usize, 2, 4, 8, 16] {
        let tasks = batch_tasks(&cost, batch);
        let s = list_schedule(&tasks);
        validate_schedule(&tasks, &s).expect("valid schedule");
        let seq = sequential_makespan(&cost, batch);
        rep.row(vec![
            batch.to_string(),
            format!("{:.3}", seq / 1e6),
            format!("{:.3}", s.makespan / 1e6),
            format!("{:.2}x", seq / s.makespan),
        ]);
    }
    rep.print();

    // A small instance where the exact solver can prove optimality:
    // 2 samples of a 3-op mini-net = 18 tasks.
    let mini = scaled_down(&alexnet(1), 64, 16);
    let mini3 = Workload::new("mini3", mini.ops[..3].to_vec());
    let cost = Scenario::headline(mini3).baseline_report().breakdown;
    let tasks = batch_tasks(&cost, 2);
    let ls = list_schedule(&tasks);
    let ex = exact_schedule(&tasks, 24);
    println!(
        "\nexact vs list on {} tasks: list {:.1} us, exact {:.1} us \
         (gap {:.2}%)",
        tasks.len(),
        ls.makespan / 1e3,
        ex.makespan / 1e3,
        (ls.makespan / ex.makespan - 1.0) * 100.0
    );
    assert!(ex.makespan <= ls.makespan + 1e-9);
    Ok(())
}
