//! Multi-model scheduling: fuse two tenants (AlexNet + ViT) into one
//! schedulable scenario with `Workload::multi_model`, sweep it through
//! the engine in a single call, and read one cost row per model plus
//! the fused total from the report's provenance spans.
//!
//!     cargo run --release --example multi_model

use mcmcomm::cost::evaluator::Objective;
use mcmcomm::engine::{Engine, Scenario, SchedulerRegistry, Scheduler};
use mcmcomm::opt::ga::GaParams;
use mcmcomm::util::error::Result;
use mcmcomm::workload::models::{alexnet, vit, vit_residual};
use mcmcomm::workload::Workload;

fn main() -> Result<()> {
    // 1. Fuse two tenants into one workload. Ops and dataflow edges are
    //    concatenated (no cross-tenant edges), and each constituent
    //    becomes a ModelSpan the report can attribute cost to.
    let fused = Workload::multi_model(&[alexnet(1), vit(1)]);
    println!(
        "fused scenario '{}': {} ops, {} dataflow edges, {} models",
        fused.name,
        fused.ops.len(),
        fused.edge_count(),
        fused.model_spans().len()
    );

    // 2. One Engine::sweep call covers the fused scenario and a
    //    branching single-model DAG (ViT with residual edges) at once.
    let registry = SchedulerRegistry::with_params(
        GaParams { population: 24, generations: 20, ..Default::default() },
        std::time::Duration::from_secs(4),
        42,
    );
    let schedulers: Vec<&dyn Scheduler> =
        registry.select(&["baseline", "ga"])?;
    let scenarios = vec![
        Scenario::builder()
            .workload(fused)
            .objective(Objective::Latency)
            .build()?,
        Scenario::builder()
            .workload(vit_residual(1))
            .objective(Objective::Latency)
            .build()?,
    ];
    let rows = Engine::sweep(scenarios, &schedulers)?;

    // 3. Per-model attribution + fused totals, per scenario.
    for row in &rows {
        println!("\n== scenario {} ({}) ==", row.model(), row.system());
        for key in ["baseline", "ga"] {
            let report = row.report(key).expect("scheduled key");
            println!(
                "{key:>8}: fused latency {:.3} ms | energy {:.3} mJ",
                report.latency_ns() / 1e6,
                report.energy_pj() / 1e9
            );
            for t in report.model_totals() {
                println!(
                    "          - {:<12} {:.3} ms over {} ops",
                    t.model,
                    t.latency_ns / 1e6,
                    t.ops
                );
            }
        }
    }
    Ok(())
}
