//! Batch pipelining as resource-constrained project scheduling (paper
//! §5.4): overlap communication of one sample with computation of
//! another.
//!
//! Model (following the paper via Concerto [12]): every (op, sample)
//! expands into up-to-three tasks — input comm, compute, output comm —
//! with precedence within the sample chain; compute and communication
//! are two unit-capacity resources, so a comm task can run while a
//! compute task runs, but two comm tasks serialize.
//!
//! Solvers: a serial schedule-generation list scheduler with
//! critical-path priority (fast, any size) and an exact DFS
//! branch-and-bound (the paper's "ILP solver" role) for the small
//! instances the paper notes are tractable.

use crate::cost::evaluator::CostBreakdown;

/// The two §5.4 resources.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Resource {
    Compute,
    Comm,
}

/// One schedulable unit.
#[derive(Debug, Clone)]
pub struct Task {
    pub name: String,
    pub dur: f64,
    pub resource: Resource,
    /// Indices of tasks that must finish first.
    pub preds: Vec<usize>,
}

/// A start-time assignment.
#[derive(Debug, Clone)]
pub struct Schedule {
    pub start: Vec<f64>,
    pub makespan: f64,
}

/// Expand a per-sample cost breakdown into the batch task DAG.
/// `per_op[i]` supplies the three stage durations of op `i`.
pub fn batch_tasks(cost: &CostBreakdown, batch: usize) -> Vec<Task> {
    assert!(batch >= 1);
    let mut tasks = Vec::new();
    for s in 0..batch {
        let mut prev: Option<usize> = None;
        for (i, oc) in cost.per_op.iter().enumerate() {
            let push = |name: String, dur: f64, res: Resource,
                            preds: Vec<usize>, tasks: &mut Vec<Task>|
             -> Option<usize> {
                if dur <= 0.0 {
                    return preds.first().copied().or(None);
                }
                tasks.push(Task { name, dur, resource: res, preds });
                Some(tasks.len() - 1)
            };
            let p0: Vec<usize> = prev.into_iter().collect();
            let t_in = push(
                format!("s{s}.op{i}.in"),
                oc.in_ns,
                Resource::Comm,
                p0,
                &mut tasks,
            );
            let t_cp = push(
                format!("s{s}.op{i}.comp"),
                oc.comp_ns,
                Resource::Compute,
                t_in.into_iter().collect(),
                &mut tasks,
            );
            let t_out = push(
                format!("s{s}.op{i}.out"),
                oc.out_ns,
                Resource::Comm,
                t_cp.into_iter().collect(),
                &mut tasks,
            );
            prev = t_out.or(t_cp).or(t_in).or(prev);
        }
    }
    tasks
}

/// Longest path from each task to the sink (critical-path priority).
fn tails(tasks: &[Task]) -> Vec<f64> {
    // preds reference earlier indices only, so a reverse sweep works.
    let mut succs: Vec<Vec<usize>> = vec![Vec::new(); tasks.len()];
    for (i, t) in tasks.iter().enumerate() {
        for &p in &t.preds {
            succs[p].push(i);
        }
    }
    let mut tail = vec![0.0f64; tasks.len()];
    for i in (0..tasks.len()).rev() {
        let succ_max = succs[i]
            .iter()
            .map(|&j| tail[j])
            .fold(0.0, f64::max);
        tail[i] = tasks[i].dur + succ_max;
    }
    tail
}

/// Serial schedule-generation list scheduling with critical-path
/// priority; resources are unit-capacity, tasks are non-preemptive.
pub fn list_schedule(tasks: &[Task]) -> Schedule {
    let n = tasks.len();
    let prio = tails(tasks);
    let mut start = vec![f64::NAN; n];
    let mut finish = vec![f64::NAN; n];
    let mut scheduled = vec![false; n];
    // Resource availability as the finish time of the last task on it.
    let mut res_free = [0.0f64; 2];
    let res_idx = |r: Resource| match r {
        Resource::Compute => 0,
        Resource::Comm => 1,
    };
    // Busy intervals per resource, kept sorted, for gap-less insertion.
    let mut busy: [Vec<(f64, f64)>; 2] = [Vec::new(), Vec::new()];

    for _ in 0..n {
        // Eligible: all preds scheduled; pick max priority.
        let cand = (0..n)
            .filter(|&i| {
                !scheduled[i]
                    && tasks[i].preds.iter().all(|&p| scheduled[p])
            })
            .max_by(|&a, &b| prio[a].partial_cmp(&prio[b]).unwrap())
            .expect("cyclic task graph?");
        let ready = tasks[cand]
            .preds
            .iter()
            .map(|&p| finish[p])
            .fold(0.0, f64::max);
        let r = res_idx(tasks[cand].resource);
        // Earliest gap on the resource at/after `ready`.
        let dur = tasks[cand].dur;
        let mut t = ready;
        for &(bs, bf) in &busy[r] {
            if t + dur <= bs {
                break;
            }
            t = t.max(bf);
        }
        start[cand] = t;
        finish[cand] = t + dur;
        busy[r].push((t, t + dur));
        busy[r].sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        res_free[r] = res_free[r].max(t + dur);
        scheduled[cand] = true;
    }
    let makespan = finish.iter().copied().fold(0.0, f64::max);
    Schedule { start, makespan }
}

/// Exact DFS branch & bound (optimal for small instances). Falls back to
/// the list schedule when the task count exceeds `limit`.
pub fn exact_schedule(tasks: &[Task], limit: usize) -> Schedule {
    let seed = list_schedule(tasks);
    if tasks.len() > limit || tasks.is_empty() {
        return seed;
    }
    let tail = tails(tasks);
    let mut best = seed.clone();

    #[derive(Clone)]
    struct State {
        start: Vec<f64>,
        finish: Vec<f64>,
        done: Vec<bool>,
        res_free: [f64; 2],
        n_done: usize,
    }
    let res_idx = |r: Resource| match r {
        Resource::Compute => 0usize,
        Resource::Comm => 1,
    };

    fn dfs(
        tasks: &[Task],
        tail: &[f64],
        st: &mut State,
        best: &mut Schedule,
        res_idx: &dyn Fn(Resource) -> usize,
        nodes: &mut usize,
    ) {
        *nodes += 1;
        if *nodes > 2_000_000 {
            return;
        }
        if st.n_done == tasks.len() {
            let mk = st.finish.iter().copied().fold(0.0, f64::max);
            if mk < best.makespan {
                best.makespan = mk;
                best.start = st.start.clone();
            }
            return;
        }
        // Lower bound: for each unfinished task, earliest possible finish
        // through its tail.
        let cur_mk = st.finish.iter().copied().fold(0.0, f64::max);
        let mut lb = cur_mk;
        for i in 0..tasks.len() {
            if !st.done[i] {
                let ready = tasks[i]
                    .preds
                    .iter()
                    .map(|&p| if st.done[p] { st.finish[p] } else { f64::MAX })
                    .fold(0.0, f64::max);
                if ready < f64::MAX {
                    lb = lb.max(ready + tail[i]);
                }
            }
        }
        if lb >= best.makespan {
            return;
        }
        // Branch on each eligible task.
        for i in 0..tasks.len() {
            if st.done[i] || !tasks[i].preds.iter().all(|&p| st.done[p]) {
                continue;
            }
            let ready = tasks[i]
                .preds
                .iter()
                .map(|&p| st.finish[p])
                .fold(0.0, f64::max);
            let r = res_idx(tasks[i].resource);
            let t = ready.max(st.res_free[r]);
            let saved_free = st.res_free[r];
            st.start[i] = t;
            st.finish[i] = t + tasks[i].dur;
            st.res_free[r] = t + tasks[i].dur;
            st.done[i] = true;
            st.n_done += 1;
            dfs(tasks, tail, st, best, res_idx, nodes);
            st.done[i] = false;
            st.n_done -= 1;
            st.res_free[r] = saved_free;
            st.start[i] = f64::NAN;
            st.finish[i] = f64::NAN;
        }
    }

    let mut st = State {
        start: vec![f64::NAN; tasks.len()],
        finish: vec![f64::NAN; tasks.len()],
        done: vec![false; tasks.len()],
        res_free: [0.0; 2],
        n_done: 0,
    };
    let mut nodes = 0usize;
    dfs(tasks, &tail, &mut st, &mut best, &res_idx, &mut nodes);
    best
}

/// Naive (sequential LS) makespan: no cross-sample overlap at all.
pub fn sequential_makespan(cost: &CostBreakdown, batch: usize) -> f64 {
    cost.latency_ns * batch as f64
}

/// Per-sample pipelining speedup at a batch size (Figure 11's metric).
pub fn pipeline_speedup(cost: &CostBreakdown, batch: usize) -> f64 {
    let tasks = batch_tasks(cost, batch);
    let sched = list_schedule(&tasks);
    sequential_makespan(cost, batch) / sched.makespan
}

/// Validate that a schedule respects precedence and unit resources.
pub fn validate_schedule(tasks: &[Task], s: &Schedule) -> Result<(), String> {
    for (i, t) in tasks.iter().enumerate() {
        for &p in &t.preds {
            if s.start[i] + 1e-9 < s.start[p] + tasks[p].dur {
                return Err(format!(
                    "task {i} starts before pred {p} finishes"
                ));
            }
        }
    }
    // No overlap per resource.
    for res in [Resource::Compute, Resource::Comm] {
        let mut ivs: Vec<(f64, f64)> = tasks
            .iter()
            .enumerate()
            .filter(|(_, t)| t.resource == res)
            .map(|(i, t)| (s.start[i], s.start[i] + t.dur))
            .collect();
        ivs.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        for w in ivs.windows(2) {
            if w[1].0 + 1e-9 < w[0].1 {
                return Err(format!(
                    "resource {res:?} overlap: {:?} vs {:?}",
                    w[0], w[1]
                ));
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{MemKind, SystemType};
    use crate::cost::evaluator::{evaluate, OptFlags};
    use crate::partition::uniform_allocation;
    use crate::platform::Platform;
    use crate::workload::models::alexnet;

    fn alexnet_cost() -> CostBreakdown {
        let plat = Platform::preset(SystemType::A, MemKind::Hbm, 4);
        let wl = alexnet(1);
        let alloc = uniform_allocation(&plat, &wl);
        evaluate(&plat, &wl, &alloc, OptFlags::NONE)
    }

    #[test]
    fn batch_tasks_structure() {
        let cost = alexnet_cost();
        let t1 = batch_tasks(&cost, 1);
        let t4 = batch_tasks(&cost, 4);
        assert_eq!(t4.len(), 4 * t1.len());
        // Precedences all point backwards.
        for (i, t) in t4.iter().enumerate() {
            for &p in &t.preds {
                assert!(p < i);
            }
        }
    }

    #[test]
    fn list_schedule_is_valid_and_beats_sequential() {
        let cost = alexnet_cost();
        for batch in [1usize, 2, 4, 8] {
            let tasks = batch_tasks(&cost, batch);
            let s = list_schedule(&tasks);
            validate_schedule(&tasks, &s).unwrap();
            assert!(s.makespan <= sequential_makespan(&cost, batch) + 1e-6);
            if batch > 1 {
                // Overlap must produce a real win on AlexNet.
                assert!(
                    s.makespan < sequential_makespan(&cost, batch) * 0.95,
                    "batch {batch}: no overlap win"
                );
            }
        }
    }

    #[test]
    fn exact_not_worse_than_list_on_small_instance() {
        let tasks = vec![
            Task { name: "a".into(), dur: 4.0, resource: Resource::Comm, preds: vec![] },
            Task { name: "b".into(), dur: 3.0, resource: Resource::Compute, preds: vec![0] },
            Task { name: "c".into(), dur: 5.0, resource: Resource::Comm, preds: vec![] },
            Task { name: "d".into(), dur: 2.0, resource: Resource::Compute, preds: vec![2] },
            Task { name: "e".into(), dur: 1.0, resource: Resource::Comm, preds: vec![1, 3] },
        ];
        let ls = list_schedule(&tasks);
        let ex = exact_schedule(&tasks, 16);
        validate_schedule(&tasks, &ex).unwrap();
        assert!(ex.makespan <= ls.makespan + 1e-9);
        // Hand-checked optimum: comm a(0-4),c(4-9); comp b(4-7),d(9-11);
        // or c first: c(0-5),a(5-9),d(5-7),b(9-12),e(12-13) = 13.
        assert!(ex.makespan <= 13.0 + 1e-9);
    }

    #[test]
    fn speedup_stable_across_batches() {
        // Fig. 11: per-sample speedup roughly flat in batch size.
        let cost = alexnet_cost();
        let s2 = pipeline_speedup(&cost, 2);
        let s8 = pipeline_speedup(&cost, 8);
        assert!(s2 > 1.05, "s2={s2}");
        assert!(s8 > 1.05, "s8={s8}");
        assert!((s8 / s2 - 1.0).abs() < 0.35, "s2={s2} s8={s8}");
    }

    #[test]
    fn zero_duration_stages_are_skipped() {
        let mut cost = alexnet_cost();
        for oc in cost.per_op.iter_mut() {
            oc.out_ns = 0.0;
        }
        let tasks = batch_tasks(&cost, 2);
        assert!(tasks.iter().all(|t| t.dur > 0.0));
        let s = list_schedule(&tasks);
        validate_schedule(&tasks, &s).unwrap();
    }

    #[test]
    fn single_chain_has_no_speedup() {
        let cost = alexnet_cost();
        let s1 = pipeline_speedup(&cost, 1);
        assert!((s1 - 1.0).abs() < 1e-6, "s1={s1}");
    }
}
