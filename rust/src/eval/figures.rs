//! Figure/table regeneration (paper §7). Every public `figN` function
//! prints the paper-shaped rows and returns the raw numbers for tests
//! and benches. All scheduling goes through the engine API — no direct
//! evaluator calls.

use crate::config::{MemKind, SystemType};
use crate::cost::evaluator::{Objective, OptFlags};
use crate::engine::{schedulers, Engine, Scenario, Scheduler};
use crate::pipeline;
use crate::platform::Platform;
use crate::topology::Pos;
use crate::util::bench::Reporter;
use crate::util::math::geomean;
use crate::util::par::{auto_threads, par_map};
use crate::workload::models::evaluation_suite;
use crate::workload::Workload;

use super::{run_cell, scheduler_geomean, Cell, EvalConfig};

/// Figure 3 output: scenario name -> (makespan ns, per-link utilization
/// heat map rendered as ASCII).
pub struct Fig3Row {
    pub scenario: String,
    pub makespan_ns: f64,
}

/// Figure 3 — motivation study: 16 chiplets pull 1 GB each over a 4x4
/// mesh; DRAM vs HBM, peripheral vs central placement, 1x vs 2x NoP.
///
/// Since the validation PR this replay runs on the plan-level
/// discrete-event engine (`netsim::sim`): each pull lowers to one
/// dependency-free transfer task of the same event loop that executes
/// whole schedules, so the motivation study and the conformance oracle
/// share one simulator.
pub fn fig3(print_heatmaps: bool) -> Vec<Fig3Row> {
    // Paper constants: DRAM 60 GB/s, HBM 1024 GB/s (Fig. 3 caption),
    // NoP 60 / 120 GB/s, 1 GB per chiplet.
    let gb = 1e9f64;
    let scenarios: Vec<(String, f64, f64, Pos)> = vec![
        ("DRAM peripheral, NoP 60".into(), 60.0, 60.0, Pos::new(0, 0)),
        ("DRAM peripheral, NoP 120".into(), 120.0, 60.0, Pos::new(0, 0)),
        ("HBM peripheral, NoP 60".into(), 60.0, 1024.0, Pos::new(0, 0)),
        ("HBM peripheral, NoP 120".into(), 120.0, 1024.0, Pos::new(0, 0)),
        ("HBM central, NoP 60".into(), 60.0, 1024.0, Pos::new(1, 1)),
        ("HBM central, NoP 120".into(), 120.0, 1024.0, Pos::new(1, 1)),
    ];
    let mut rep = Reporter::new(
        "Figure 3(d): total network communication latency (4x4 mesh, 16 x 1 GB pulls)",
        &["scenario", "latency (ms)", "vs DRAM-60"],
    );
    let mut rows = Vec::new();
    let mut base = None;
    for (name, bw_nop, bw_mem, attach) in scenarios {
        let (graph, res) = crate::netsim::all_pull_from_memory(
            4, gb, bw_nop, bw_mem, attach, false,
        )
        .expect("figure-3 mesh routes are well-formed");
        if base.is_none() {
            base = Some(res.makespan_ns);
        }
        rep.row(vec![
            name.clone(),
            format!("{:.2}", res.makespan_ns / 1e6),
            format!("{:.2}x", base.unwrap() / res.makespan_ns),
        ]);
        if print_heatmaps {
            print_heatmap(&name, &graph, &res);
        }
        rows.push(Fig3Row { scenario: name, makespan_ns: res.makespan_ns });
    }
    rep.print();
    rows
}

fn print_heatmap(
    name: &str,
    graph: &crate::topology::links::LinkGraph,
    res: &crate::netsim::SimResult,
) {
    println!("\n-- Figure 3 heatmap: {name} (mean link utilization %) --");
    let util = res.utilization(graph);
    // Aggregate directed links per chiplet node (mean of incident).
    for r in 0..graph.xdim {
        let mut line = String::new();
        for c in 0..graph.ydim {
            let node = graph.chiplet_id(Pos::new(r, c));
            let (mut acc, mut cnt) = (0.0, 0);
            for (i, l) in graph.links.iter().enumerate() {
                if l.from == node || l.to == node {
                    acc += util[i];
                    cnt += 1;
                }
            }
            line.push_str(&format!("{:>6.1}", 100.0 * acc / cnt as f64));
        }
        println!("{line}");
    }
}

/// The standard scheduler set the figures compare (Table 3).
const FIG_KEYS: [&str; 4] = ["baseline", "simba", "ga", "miqp"];

/// Run one `run_cell` per (hardware, workload, objective) job in
/// parallel; cells come back in job order so tables keep the paper's
/// row layout. Per-cell solver seeds come from `cfg`, identical to a
/// sequential run (RNGs never cross cells).
fn run_cells_par(
    jobs: &[(Platform, Workload, Objective)],
    cfg: &EvalConfig,
) -> Vec<Cell> {
    par_map(auto_threads(), jobs, |_, (plat, wl, obj)| {
        run_cell(plat, wl, *obj, cfg, &FIG_KEYS)
    })
}

fn print_cells(title: &str, cells: &[Cell]) {
    let mut rep = Reporter::new(
        title,
        &["model", "system", "LS", "SIMBA-like", "GA", "MIQP"],
    );
    for c in cells {
        let get = |key: &str| {
            c.normalized
                .iter()
                .find(|(x, _)| x == key)
                .map(|(_, v)| format!("{v:.3}"))
                .unwrap_or_else(|| "-".into())
        };
        rep.row(vec![
            c.model.clone(),
            c.system.clone(),
            get("baseline"),
            get("simba"),
            get("ga"),
            get("miqp"),
        ]);
    }
    rep.print();
    println!(
        "geo-mean speedup vs LS:  SIMBA-like {:+.1}%  GA {:+.1}%  MIQP {:+.1}%",
        (1.0 / scheduler_geomean(cells, "simba") - 1.0) * 100.0,
        (1.0 / scheduler_geomean(cells, "ga") - 1.0) * 100.0,
        (1.0 / scheduler_geomean(cells, "miqp") - 1.0) * 100.0,
    );
}

/// Figure 8 — normalized latency, 4x4 HBM, packaging types A–D.
pub fn fig8(cfg: &EvalConfig) -> Vec<Cell> {
    let mut jobs = Vec::new();
    for ty in SystemType::ALL {
        let plat = Platform::preset(ty, MemKind::Hbm, 4);
        for wl in evaluation_suite(1) {
            jobs.push((plat.clone(), wl, Objective::Latency));
        }
    }
    let cells = run_cells_par(&jobs, cfg);
    print_cells("Figure 8: normalized latency, 4x4 HBM, types A-D", &cells);
    cells
}

/// Figure 9 — latency scaling on type A (4x4 / 8x8 / 16x16).
pub fn fig9(cfg: &EvalConfig, grids: &[usize]) -> Vec<Cell> {
    let mut jobs = Vec::new();
    for &g in grids {
        let plat = Platform::preset(SystemType::A, MemKind::Hbm, g);
        for wl in evaluation_suite(1) {
            jobs.push((plat.clone(), wl, Objective::Latency));
        }
    }
    let cells = run_cells_par(&jobs, cfg);
    print_cells("Figure 9: normalized latency scaling, type-A HBM", &cells);
    cells
}

/// Figure 10 — EDP scaling on type A.
pub fn fig10(cfg: &EvalConfig, grids: &[usize]) -> Vec<Cell> {
    let mut jobs = Vec::new();
    for &g in grids {
        let plat = Platform::preset(SystemType::A, MemKind::Hbm, g);
        for wl in evaluation_suite(1) {
            jobs.push((plat.clone(), wl, Objective::Edp));
        }
    }
    let cells = run_cells_par(&jobs, cfg);
    print_cells("Figure 10: normalized EDP scaling, type-A HBM", &cells);
    cells
}

/// Figure 11 — per-sample pipelining speedup vs batch size.
pub fn fig11(batches: &[usize]) -> Vec<(String, usize, f64)> {
    let mut rep = Reporter::new(
        "Figure 11: per-sample pipelining speedup vs LS",
        &["model", "batch", "speedup"],
    );
    let mut rows = Vec::new();
    for wl in evaluation_suite(1) {
        let scenario = Scenario::headline(wl);
        let cost = scenario.baseline_report().breakdown;
        for &b in batches {
            let s = pipeline::pipeline_speedup(&cost, b);
            rep.row(vec![
                scenario.workload().name.clone(),
                b.to_string(),
                format!("{s:.2}x"),
            ]);
            rows.push((scenario.workload().name.clone(), b, s));
        }
    }
    rep.print();
    rows
}

/// Figure 12 — low-bandwidth (DRAM) latency + EDP, 4x4 type A.
pub fn fig12(cfg: &EvalConfig) -> (Vec<Cell>, Vec<Cell>) {
    let plat = Platform::preset(SystemType::A, MemKind::Dram, 4);
    let mut jobs = Vec::new();
    for wl in evaluation_suite(1) {
        jobs.push((plat.clone(), wl.clone(), Objective::Latency));
        jobs.push((plat.clone(), wl, Objective::Edp));
    }
    let cells = run_cells_par(&jobs, cfg);
    let mut lat = Vec::new();
    let mut edp = Vec::new();
    for (i, c) in cells.into_iter().enumerate() {
        if i % 2 == 0 {
            lat.push(c);
        } else {
            edp.push(c);
        }
    }
    print_cells("Figure 12a: normalized latency, 4x4 type-A DRAM", &lat);
    print_cells("Figure 12b: normalized EDP, 4x4 type-A DRAM", &edp);
    (lat, edp)
}

/// Figure 13 — ablation: partitioning only, +diagonal links,
/// +pipelining; for latency and EDP. Returns (config name, objective,
/// normalized value).
pub fn fig13(cfg: &EvalConfig) -> Vec<(String, String, f64)> {
    let stages: [(&str, OptFlags, bool); 3] = [
        ("partition only",
         OptFlags { diagonal: false, redistribution: true, async_fusion: false },
         false),
        ("+ diagonal links",
         OptFlags { diagonal: true, redistribution: true, async_fusion: false },
         false),
        ("+ pipelining (batch 4)",
         OptFlags { diagonal: true, redistribution: true, async_fusion: true },
         true),
    ];
    let mut rep = Reporter::new(
        "Figure 13: ablation (geo-mean speedup vs LS across models)",
        &["configuration", "latency speedup", "EDP speedup"],
    );
    let mut out = Vec::new();
    let mut lat_cols: Vec<Vec<f64>> = vec![Vec::new(); stages.len()];
    let mut edp_cols: Vec<Vec<f64>> = vec![Vec::new(); stages.len()];
    let ga = schedulers::Ga::new(cfg.ga_params(), cfg.seed);
    // One parallel job per workload; each job runs its ablation stages
    // in order (the GA reseeds per schedule call, so results match a
    // sequential run).
    let wls = evaluation_suite(1);
    let per_wl: Vec<Vec<(f64, f64)>> =
        par_map(auto_threads(), &wls, |_, wl| {
            let base = Scenario::headline(wl.clone()).baseline_report();
            stages
                .iter()
                .map(|(_, flags, pipelined)| {
                    let scenario = Scenario::builder()
                        .workload(wl.clone())
                        .flags(*flags)
                        .objective(Objective::Latency)
                        .build()
                        .expect("valid ablation scenario");
                    let engine = Engine::new(scenario);
                    let c = engine
                        .schedule_with(&ga)
                        .expect("GA schedules every stage")
                        .report();
                    let (mut lat, mut edp) = (c.latency_ns(), c.edp());
                    if *pipelined {
                        let speed =
                            pipeline::pipeline_speedup(&c.breakdown, 4);
                        lat /= speed;
                        edp /= speed * speed; // energy unchanged
                    }
                    (base.latency_ns() / lat, base.edp() / edp)
                })
                .collect()
        });
    for stage_rows in per_wl {
        for (si, (l, e)) in stage_rows.into_iter().enumerate() {
            lat_cols[si].push(l);
            edp_cols[si].push(e);
        }
    }
    for (si, (name, _, _)) in stages.iter().enumerate() {
        let l = geomean(&lat_cols[si]);
        let e = geomean(&edp_cols[si]);
        rep.row(vec![
            name.to_string(),
            format!("{l:.2}x"),
            format!("{e:.2}x"),
        ]);
        out.push((name.to_string(), "latency".into(), l));
        out.push((name.to_string(), "edp".into(), e));
    }
    rep.print();
    out
}

/// §3.5 solver comparison: quality + solving time per scheduler on the
/// headline config.
pub fn solver_compare(cfg: &EvalConfig) -> Vec<(String, f64, f64)> {
    let registry = cfg.registry();
    let engine = Engine::new(Scenario::headline(
        crate::workload::models::alexnet(1),
    ));
    let mut rep = Reporter::new(
        "Solver comparison (AlexNet, 4x4 type-A HBM, latency)",
        &["scheme", "normalized latency", "solve time (s)"],
    );
    let mut out = Vec::new();
    let base = engine
        .schedule(&registry, "baseline")
        .expect("baseline always schedules")
        .objective_value();
    for key in ["greedy", "ga", "miqp"] {
        let scheduler = registry.require(key).expect("table-3 scheduler");
        let t0 = std::time::Instant::now();
        let planned =
            engine.schedule_with(scheduler).expect("scheduling failed");
        let dt = t0.elapsed().as_secs_f64();
        let norm = planned.objective_value() / base;
        rep.row(vec![
            scheduler.name().to_string(),
            format!("{norm:.3}"),
            format!("{dt:.2}"),
        ]);
        out.push((key.to_string(), norm, dt));
    }
    rep.print();
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig3_shapes_hold() {
        let rows = fig3(false);
        let by = |n: &str| {
            rows.iter()
                .find(|r| r.scenario.starts_with(n))
                .unwrap()
                .makespan_ns
        };
        // DRAM flat in NoP BW.
        let flat = by("DRAM peripheral, NoP 60") / by("DRAM peripheral, NoP 120");
        assert!((flat - 1.0).abs() < 0.05, "flat={flat}");
        // HBM scales with NoP BW.
        let hbm = by("HBM peripheral, NoP 60") / by("HBM peripheral, NoP 120");
        assert!(hbm > 1.6, "hbm={hbm}");
        // Central beats peripheral for HBM (paper: 1.53x).
        let central = by("HBM peripheral, NoP 60") / by("HBM central, NoP 60");
        assert!(central > 1.2, "central={central}");
    }

    #[test]
    fn fig11_speedups_positive_and_flat() {
        let rows = fig11(&[2, 8]);
        for (model, b, s) in &rows {
            assert!(*s >= 0.99, "{model} batch {b}: speedup {s}");
        }
    }
}
