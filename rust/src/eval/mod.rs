//! Evaluation harness (paper §7): one regeneration function per figure,
//! shared by the CLI (`mcmcomm figures`) and the `cargo bench` targets.
//!
//! "Quick" mode shrinks solver budgets so every figure regenerates in
//! seconds; "full" mode uses paper-scale budgets (GA ≈ 30 s class,
//! MIQP anytime limit). Normalized *shapes* — who wins, rough factors,
//! crossovers — are the reproduction target (DESIGN.md).

pub mod figures;
pub mod lp;

use std::time::Duration;

use crate::config::{HwConfig, MemKind, SystemType};
use crate::cost::evaluator::{evaluate, Objective, OptFlags};
use crate::opt::{ga::GaParams, run_scheme, Scheme, SchedulerConfig};
use crate::topology::Topology;
use crate::workload::Workload;

/// Harness-wide knobs.
#[derive(Debug, Clone)]
pub struct EvalConfig {
    pub quick: bool,
    pub seed: u64,
}

impl Default for EvalConfig {
    fn default() -> Self {
        EvalConfig { quick: true, seed: 42 }
    }
}

impl EvalConfig {
    pub fn scheduler(&self, objective: Objective) -> SchedulerConfig {
        if self.quick {
            SchedulerConfig {
                objective,
                flags: OptFlags::ALL,
                seed: self.seed,
                ga: GaParams {
                    population: 24,
                    generations: 20,
                    seed: self.seed,
                    ..Default::default()
                },
                miqp_budget: Duration::from_secs(4),
            }
        } else {
            SchedulerConfig {
                objective,
                flags: OptFlags::ALL,
                seed: self.seed,
                ga: GaParams {
                    population: 48,
                    generations: 120,
                    seed: self.seed,
                    budget: Some(Duration::from_secs(30)),
                    ..Default::default()
                },
                miqp_budget: Duration::from_secs(120),
            }
        }
    }
}

/// One (model, system) cell: objective value per scheme, normalized to
/// the LS baseline (baseline == 1.0; lower is better).
#[derive(Debug, Clone)]
pub struct Cell {
    pub model: String,
    pub system: String,
    pub normalized: Vec<(Scheme, f64)>,
}

/// Run the Table-3 scheme set on one configuration.
pub fn run_cell(
    hw: &HwConfig,
    wl: &Workload,
    objective: Objective,
    cfg: &EvalConfig,
    schemes: &[Scheme],
) -> Cell {
    let topo = Topology::from_hw(hw);
    let scfg = cfg.scheduler(objective);
    let base = run_scheme(Scheme::Baseline, hw, &topo, wl, &scfg);
    let mut normalized = vec![(Scheme::Baseline, 1.0)];
    for &s in schemes {
        if s == Scheme::Baseline {
            continue;
        }
        let out = run_scheme(s, hw, &topo, wl, &scfg);
        normalized.push((s, out.objective_value / base.objective_value));
    }
    Cell {
        model: wl.name.clone(),
        system: format!(
            "{}-{}-{}x{}",
            hw.ty.short(),
            hw.mem.name(),
            hw.xdim,
            hw.ydim
        ),
        normalized,
    }
}

/// Geo-mean of the normalized values of one scheme across cells.
pub fn scheme_geomean(cells: &[Cell], scheme: Scheme) -> f64 {
    let vals: Vec<f64> = cells
        .iter()
        .filter_map(|c| {
            c.normalized
                .iter()
                .find(|(s, _)| *s == scheme)
                .map(|(_, v)| *v)
        })
        .collect();
    crate::util::math::geomean(&vals)
}

/// Quick helper: the standard 4-model suite at batch 1.
pub fn suite() -> Vec<Workload> {
    crate::workload::models::evaluation_suite(1)
}

/// Convenience: evaluate one allocation-scheme on a fresh config.
pub fn baseline_latency(ty: SystemType, mem: MemKind, grid: usize,
                        wl: &Workload) -> f64 {
    let hw = HwConfig::paper(ty, mem, grid);
    let topo = Topology::from_hw(&hw);
    let alloc = crate::partition::uniform_allocation(&hw, wl);
    evaluate(&hw, &topo, wl, &alloc, OptFlags::NONE).latency_ns
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::models::alexnet;

    #[test]
    fn cell_normalizes_to_baseline() {
        let hw = HwConfig::paper(SystemType::A, MemKind::Hbm, 4);
        let wl = alexnet(1);
        let cfg = EvalConfig { quick: true, seed: 7 };
        let cell = run_cell(
            &hw,
            &wl,
            Objective::Latency,
            &cfg,
            &[Scheme::Baseline, Scheme::SimbaLike, Scheme::Ga],
        );
        assert_eq!(cell.normalized[0], (Scheme::Baseline, 1.0));
        // GA (with optimizations) must beat the baseline on type A HBM.
        let ga = cell
            .normalized
            .iter()
            .find(|(s, _)| *s == Scheme::Ga)
            .unwrap()
            .1;
        assert!(ga < 1.0, "GA normalized {ga} >= 1");
    }

    #[test]
    fn geomean_over_cells() {
        let cells = vec![
            Cell {
                model: "a".into(),
                system: "s".into(),
                normalized: vec![(Scheme::Ga, 0.5)],
            },
            Cell {
                model: "b".into(),
                system: "s".into(),
                normalized: vec![(Scheme::Ga, 2.0)],
            },
        ];
        assert!((scheme_geomean(&cells, Scheme::Ga) - 1.0).abs() < 1e-12);
    }
}
