//! Evaluation harness (paper §7): one regeneration function per figure,
//! shared by the CLI (`mcmcomm figures`) and the `cargo bench` targets —
//! all built on the engine's batch API ([`Engine::sweep`]).
//!
//! "Quick" mode shrinks solver budgets so every figure regenerates in
//! seconds; "full" mode uses paper-scale budgets (GA ≈ 30 s class,
//! MIQP anytime limit). Normalized *shapes* — who wins, rough factors,
//! crossovers — are the reproduction target (DESIGN.md).

pub mod figures;
pub mod lp;

use std::time::Duration;

use crate::config::{MemKind, SystemType};
use crate::cost::evaluator::{Objective, OptFlags};
use crate::engine::{Engine, Scenario, SchedulerRegistry};
use crate::opt::ga::GaParams;
use crate::platform::Platform;
use crate::workload::Workload;

/// Harness-wide knobs.
#[derive(Debug, Clone)]
pub struct EvalConfig {
    pub quick: bool,
    pub seed: u64,
}

impl Default for EvalConfig {
    fn default() -> Self {
        EvalConfig { quick: true, seed: 42 }
    }
}

impl EvalConfig {
    /// GA knobs for this mode (quick: seconds-class, full: paper-class).
    ///
    /// `threads: 1`: the figure harnesses already parallelize at the
    /// (hw, workload, objective) cell level, so the GA inside each cell
    /// runs sequentially — nesting auto-threaded GAs under the parallel
    /// cell map would oversubscribe the machine and multiply per-worker
    /// cache memory for no wall-clock gain.
    pub fn ga_params(&self) -> GaParams {
        if self.quick {
            GaParams {
                population: 24,
                generations: 20,
                seed: self.seed,
                threads: 1,
                ..Default::default()
            }
        } else {
            GaParams {
                population: 48,
                generations: 120,
                seed: self.seed,
                budget: Some(Duration::from_secs(30)),
                threads: 1,
                ..Default::default()
            }
        }
    }

    /// MIQP anytime budget for this mode.
    pub fn miqp_budget(&self) -> Duration {
        if self.quick {
            Duration::from_secs(4)
        } else {
            Duration::from_secs(120)
        }
    }

    /// The Table-3 scheduler set under this mode's solver budgets.
    pub fn registry(&self) -> SchedulerRegistry {
        SchedulerRegistry::with_params(
            self.ga_params(),
            self.miqp_budget(),
            self.seed,
        )
    }
}

/// One (model, system) cell: objective value per scheduler key,
/// normalized to the LS baseline (baseline == 1.0; lower is better).
#[derive(Debug, Clone)]
pub struct Cell {
    /// Workload name (`a+b` composite for fused multi-model scenarios).
    pub model: String,
    /// Constituent model names (provenance; one entry per tenant).
    pub models: Vec<String>,
    pub system: String,
    pub normalized: Vec<(String, f64)>,
}

/// Run a scheduler set on one configuration through [`Engine::sweep`].
/// The `"baseline"` scheduler is always run (it anchors normalization)
/// even when absent from `keys`.
pub fn run_cell(
    plat: &Platform,
    wl: &Workload,
    objective: Objective,
    cfg: &EvalConfig,
    keys: &[&str],
) -> Cell {
    let registry = cfg.registry();
    let mut all_keys = vec!["baseline"];
    all_keys.extend(keys.iter().filter(|&&k| k != "baseline"));
    let schedulers =
        registry.select(&all_keys).expect("known scheduler keys");
    let scenario = Scenario::builder()
        .platform(plat.clone())
        .workload(wl.clone())
        .flags(OptFlags::ALL)
        .objective(objective)
        .build()
        .expect("valid eval scenario");
    let rows = Engine::sweep(std::iter::once(scenario), &schedulers)
        .expect("sweep failed");
    let row = rows.into_iter().next().expect("one scenario, one row");
    let normalized =
        row.normalized_to("baseline").expect("baseline always present");
    Cell {
        model: row.model().to_string(),
        models: row.models(),
        system: row.system(),
        normalized,
    }
}

/// Geo-mean of the normalized values of one scheduler across cells.
pub fn scheduler_geomean(cells: &[Cell], key: &str) -> f64 {
    let vals: Vec<f64> = cells
        .iter()
        .filter_map(|c| {
            c.normalized
                .iter()
                .find(|(s, _)| s == key)
                .map(|(_, v)| *v)
        })
        .collect();
    crate::util::math::geomean(&vals)
}

/// Quick helper: the standard 4-model suite at batch 1.
pub fn suite() -> Vec<Workload> {
    crate::workload::models::evaluation_suite(1)
}

/// Convenience: uniform-LS latency on a fresh config.
pub fn baseline_latency(
    ty: SystemType,
    mem: MemKind,
    grid: usize,
    wl: &Workload,
) -> f64 {
    let scenario = Scenario::builder()
        .system(ty)
        .mem(mem)
        .grid(grid)
        .workload(wl.clone())
        .build()
        .expect("valid baseline config");
    scenario.baseline_report().latency_ns()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::models::alexnet;

    #[test]
    fn cell_normalizes_to_baseline() {
        let plat = Platform::preset(SystemType::A, MemKind::Hbm, 4);
        let wl = alexnet(1);
        let cfg = EvalConfig { quick: true, seed: 7 };
        let cell = run_cell(
            &plat,
            &wl,
            Objective::Latency,
            &cfg,
            &["baseline", "simba", "ga"],
        );
        assert_eq!(cell.normalized[0], ("baseline".to_string(), 1.0));
        // GA (with optimizations) must beat the baseline on type A HBM.
        let ga = cell
            .normalized
            .iter()
            .find(|(s, _)| s == "ga")
            .unwrap()
            .1;
        assert!(ga < 1.0, "GA normalized {ga} >= 1");
    }

    #[test]
    fn geomean_over_cells() {
        let cells = vec![
            Cell {
                model: "a".into(),
                models: vec!["a".into()],
                system: "s".into(),
                normalized: vec![("ga".into(), 0.5)],
            },
            Cell {
                model: "b".into(),
                models: vec!["b".into()],
                system: "s".into(),
                normalized: vec![("ga".into(), 2.0)],
            },
        ];
        assert!((scheduler_geomean(&cells, "ga") - 1.0).abs() < 1e-12);
    }

    #[test]
    fn quick_and_full_budgets_differ() {
        let quick = EvalConfig { quick: true, seed: 1 };
        let full = EvalConfig { quick: false, seed: 1 };
        assert!(quick.ga_params().generations < full.ga_params().generations);
        assert!(quick.miqp_budget() < full.miqp_budget());
        assert_eq!(quick.registry().len(), 6);
    }
}
