//! Layer-pipeline (LP) orthogonality (paper §2.2): "Given an LP scheme,
//! MCMComm can optimize the workload partitions of different layers …
//! suppose a 4x4 MCM system is divided equally among two layers. We can
//! model each 2x4 MCM system separately. The 2x4 system closer to the
//! main memory can be modeled using type A and the other … using type B
//! where the first system serves as the distributed interface."
//!
//! This module implements exactly that construction: split the op
//! sequence into two stages, model the near-memory stage on a type-A
//! half-grid and the far stage on a type-B half-grid (its "memory" is
//! the boundary row of the first stage), and report the pipelined
//! throughput (stage max) instead of the LS sum.

use crate::config::{HwConfig, SystemType};
use crate::cost::evaluator::{evaluate, CostBreakdown, OptFlags};
use crate::partition::uniform_allocation;
use crate::topology::Topology;
use crate::workload::Workload;

/// Result of a two-stage LP split.
#[derive(Debug, Clone)]
pub struct LpSplit {
    pub near: CostBreakdown,
    pub far: CostBreakdown,
    /// Steady-state per-sample latency: the slower stage paces the
    /// pipeline.
    pub pipelined_ns: f64,
    /// The plain LS latency on the full grid for comparison.
    pub ls_ns: f64,
}

/// Model `wl` split after `split_at` ops onto two half-grids of `hw`
/// (rows halved). Stages use the uniform allocation (callers can refine
/// each stage with any scheduler — the sub-grids are ordinary
/// `HwConfig`s).
pub fn lp_two_stage(hw: &HwConfig, wl: &Workload, split_at: usize,
                    flags: OptFlags) -> LpSplit {
    assert!(split_at > 0 && split_at < wl.ops.len(), "split inside the net");
    assert!(hw.xdim >= 2, "need at least two chiplet rows to split");

    // Near-memory half: type A (corner memory), X/2 rows.
    let mut near_hw = hw.clone();
    near_hw.xdim = hw.xdim / 2;
    near_hw.ty = SystemType::A;
    // Far half: type B — fed along its full edge by the near stage,
    // which acts as the distributed memory interface; the interface
    // bandwidth is the NoP boundary, not the off-chip link.
    let mut far_hw = hw.clone();
    far_hw.xdim = hw.xdim - near_hw.xdim;
    far_hw.ty = SystemType::B;
    far_hw.bw_mem = hw.bw_nop * far_hw.ydim as f64; // boundary row links

    // Split the dataflow graph, keeping only the intra-half edges:
    // cross-boundary consumers read from the stage boundary instead of
    // a dataflow edge, which `from_graph` encodes by re-deriving their
    // `chained` flags from the surviving edges.
    let near_pairs: Vec<(usize, usize)> = wl
        .edges
        .iter()
        .filter(|e| e.dst < split_at)
        .map(|e| (e.src, e.dst))
        .collect();
    let far_pairs: Vec<(usize, usize)> = wl
        .edges
        .iter()
        .filter(|e| e.src >= split_at)
        .map(|e| (e.src - split_at, e.dst - split_at))
        .collect();
    let near_wl = Workload::from_graph(
        &format!("{}-near", wl.name),
        wl.ops[..split_at].to_vec(),
        &near_pairs,
    );
    let far_wl = Workload::from_graph(
        &format!("{}-far", wl.name),
        wl.ops[split_at..].to_vec(),
        &far_pairs,
    );

    let near_topo = Topology::from_hw(&near_hw);
    let far_topo = Topology::from_hw(&far_hw);
    let near = evaluate(&near_hw, &near_topo, &near_wl,
                        &uniform_allocation(&near_hw, &near_wl), flags);
    let far = evaluate(&far_hw, &far_topo, &far_wl,
                       &uniform_allocation(&far_hw, &far_wl), flags);

    let topo = Topology::from_hw(hw);
    let ls = evaluate(hw, &topo, wl, &uniform_allocation(hw, wl), flags);

    LpSplit {
        pipelined_ns: near.latency_ns.max(far.latency_ns),
        near,
        far,
        ls_ns: ls.latency_ns,
    }
}

/// The split point minimizing the pipelined stage time (balanced
/// stages).
pub fn best_split(hw: &HwConfig, wl: &Workload, flags: OptFlags) -> usize {
    (1..wl.ops.len())
        .min_by(|&a, &b| {
            let ca = lp_two_stage(hw, wl, a, flags).pipelined_ns;
            let cb = lp_two_stage(hw, wl, b, flags).pipelined_ns;
            ca.total_cmp(&cb)
        })
        .unwrap_or(1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::MemKind;
    use crate::workload::models::alexnet;

    #[test]
    fn lp_split_stages_cover_all_ops() {
        let hw = HwConfig::paper(SystemType::A, MemKind::Hbm, 4);
        let wl = alexnet(1);
        let s = lp_two_stage(&hw, &wl, 4, OptFlags::NONE);
        assert_eq!(s.near.per_op.len() + s.far.per_op.len(), wl.ops.len());
        assert!(s.pipelined_ns >= s.near.latency_ns.max(s.far.latency_ns) - 1e-9);
    }

    #[test]
    fn balanced_split_improves_steady_state_throughput() {
        // Per-sample steady-state time under LP (stage max on half
        // grids) should beat LS on the full grid for a deep chain.
        let hw = HwConfig::paper(SystemType::A, MemKind::Hbm, 4);
        let wl = alexnet(1);
        let best = best_split(&hw, &wl, OptFlags::NONE);
        let s = lp_two_stage(&hw, &wl, best, OptFlags::NONE);
        assert!(
            s.pipelined_ns < s.ls_ns,
            "LP steady state {} !< LS {}",
            s.pipelined_ns,
            s.ls_ns
        );
    }

    #[test]
    fn far_stage_sees_distributed_interface() {
        let hw = HwConfig::paper(SystemType::A, MemKind::Hbm, 4);
        let wl = alexnet(1);
        let s = lp_two_stage(&hw, &wl, 4, OptFlags::NONE);
        // Far stage costs exist and are finite.
        assert!(s.far.latency_ns.is_finite() && s.far.latency_ns > 0.0);
    }

    #[test]
    #[should_panic(expected = "split inside")]
    fn degenerate_split_rejected() {
        let hw = HwConfig::paper(SystemType::A, MemKind::Hbm, 4);
        let wl = alexnet(1);
        let _ = lp_two_stage(&hw, &wl, 0, OptFlags::NONE);
    }
}
