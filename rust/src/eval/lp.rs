//! Layer-pipeline (LP) orthogonality (paper §2.2): "Given an LP scheme,
//! MCMComm can optimize the workload partitions of different layers …
//! suppose a 4x4 MCM system is divided equally among two layers. We can
//! model each 2x4 MCM system separately. The 2x4 system closer to the
//! main memory can be modeled using type A and the other … using type B
//! where the first system serves as the distributed interface."
//!
//! This module implements exactly that construction on the platform
//! API: split the op sequence into two stages, model the near-memory
//! stage on a corner-attachment half-grid and the far stage on an
//! edge-attachment half-grid whose "memory" is the boundary row of the
//! first stage (interface bandwidth = the NoP boundary links, not the
//! off-chip link), and report the pipelined throughput (stage max)
//! instead of the LS sum. The virtual stages are ordinary [`Platform`]s
//! built from the parent's spec — exactly the kind of derived packaging
//! the data-driven description exists for.

use crate::config::SystemType;
use crate::cost::evaluator::{evaluate, CostBreakdown, OptFlags};
use crate::partition::uniform_allocation;
use crate::platform::{preset_attachments, Platform};
use crate::workload::Workload;

/// Result of a two-stage LP split.
#[derive(Debug, Clone)]
pub struct LpSplit {
    pub near: CostBreakdown,
    pub far: CostBreakdown,
    /// Steady-state per-sample latency: the slower stage paces the
    /// pipeline.
    pub pipelined_ns: f64,
    /// The plain LS latency on the full grid for comparison.
    pub ls_ns: f64,
}

/// Derive the two virtual stage platforms of the §2.2 construction from
/// the parent platform: `(near, far)`.
fn stage_platforms(plat: &Platform) -> (Platform, Platform) {
    // Near-memory half: corner attachment (type-A pattern), X/2 rows.
    let mut near_spec = plat.spec().clone();
    near_spec.name = format!("{}-lp-near", plat.name);
    near_spec.xdim = plat.xdim / 2;
    near_spec.attachments = preset_attachments(
        SystemType::A,
        near_spec.xdim,
        near_spec.ydim,
        near_spec.bw_mem,
    );
    // Far half: edge attachments (type-B pattern) — fed along its full
    // edge by the near stage, which acts as the distributed memory
    // interface; the interface bandwidth is the NoP boundary, not the
    // off-chip link.
    let mut far_spec = plat.spec().clone();
    far_spec.name = format!("{}-lp-far", plat.name);
    far_spec.xdim = plat.xdim - near_spec.xdim;
    far_spec.bw_mem = plat.bw_nop * far_spec.ydim as f64; // boundary links
    far_spec.attachments = preset_attachments(
        SystemType::B,
        far_spec.xdim,
        far_spec.ydim,
        far_spec.bw_mem,
    );
    (
        Platform::new(near_spec).expect("near half-grid is valid"),
        Platform::new(far_spec).expect("far half-grid is valid"),
    )
}

/// Model `wl` split after `split_at` ops onto two half-grids of `plat`
/// (rows halved). Stages use the uniform allocation (callers can refine
/// each stage with any scheduler — the sub-grids are ordinary
/// [`Platform`]s).
pub fn lp_two_stage(plat: &Platform, wl: &Workload, split_at: usize,
                    flags: OptFlags) -> LpSplit {
    assert!(split_at > 0 && split_at < wl.ops.len(), "split inside the net");
    assert!(plat.xdim >= 2, "need at least two chiplet rows to split");

    let (near_plat, far_plat) = stage_platforms(plat);

    // Split the dataflow graph, keeping only the intra-half edges:
    // cross-boundary consumers read from the stage boundary instead of
    // a dataflow edge, which `from_graph` encodes by re-deriving their
    // `chained` flags from the surviving edges.
    let near_pairs: Vec<(usize, usize)> = wl
        .edges
        .iter()
        .filter(|e| e.dst < split_at)
        .map(|e| (e.src, e.dst))
        .collect();
    let far_pairs: Vec<(usize, usize)> = wl
        .edges
        .iter()
        .filter(|e| e.src >= split_at)
        .map(|e| (e.src - split_at, e.dst - split_at))
        .collect();
    let near_wl = Workload::from_graph(
        &format!("{}-near", wl.name),
        wl.ops[..split_at].to_vec(),
        &near_pairs,
    );
    let far_wl = Workload::from_graph(
        &format!("{}-far", wl.name),
        wl.ops[split_at..].to_vec(),
        &far_pairs,
    );

    let near = evaluate(&near_plat, &near_wl,
                        &uniform_allocation(&near_plat, &near_wl), flags);
    let far = evaluate(&far_plat, &far_wl,
                       &uniform_allocation(&far_plat, &far_wl), flags);

    let ls = evaluate(plat, wl, &uniform_allocation(plat, wl), flags);

    LpSplit {
        pipelined_ns: near.latency_ns.max(far.latency_ns),
        near,
        far,
        ls_ns: ls.latency_ns,
    }
}

/// The split point minimizing the pipelined stage time (balanced
/// stages).
pub fn best_split(plat: &Platform, wl: &Workload, flags: OptFlags) -> usize {
    (1..wl.ops.len())
        .min_by(|&a, &b| {
            let ca = lp_two_stage(plat, wl, a, flags).pipelined_ns;
            let cb = lp_two_stage(plat, wl, b, flags).pipelined_ns;
            ca.total_cmp(&cb)
        })
        .unwrap_or(1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::MemKind;
    use crate::workload::models::alexnet;

    fn plat() -> Platform {
        Platform::preset(SystemType::A, MemKind::Hbm, 4)
    }

    #[test]
    fn lp_split_stages_cover_all_ops() {
        let wl = alexnet(1);
        let s = lp_two_stage(&plat(), &wl, 4, OptFlags::NONE);
        assert_eq!(s.near.per_op.len() + s.far.per_op.len(), wl.ops.len());
        assert!(s.pipelined_ns >= s.near.latency_ns.max(s.far.latency_ns) - 1e-9);
    }

    #[test]
    fn balanced_split_improves_steady_state_throughput() {
        // Per-sample steady-state time under LP (stage max on half
        // grids) should beat LS on the full grid for a deep chain.
        let p = plat();
        let wl = alexnet(1);
        let best = best_split(&p, &wl, OptFlags::NONE);
        let s = lp_two_stage(&p, &wl, best, OptFlags::NONE);
        assert!(
            s.pipelined_ns < s.ls_ns,
            "LP steady state {} !< LS {}",
            s.pipelined_ns,
            s.ls_ns
        );
    }

    #[test]
    fn far_stage_sees_distributed_interface() {
        let p = plat();
        let (near, far) = stage_platforms(&p);
        // 2x4 halves; the far half's "memory" is the 4-link boundary.
        assert_eq!((near.xdim, far.xdim), (2, 2));
        assert_eq!(far.bw_mem, p.bw_nop * 4.0);
        assert_eq!(far.globals().len(), 2 * 2); // both edge columns
        let wl = alexnet(1);
        let s = lp_two_stage(&p, &wl, 4, OptFlags::NONE);
        // Far stage costs exist and are finite.
        assert!(s.far.latency_ns.is_finite() && s.far.latency_ns > 0.0);
    }

    #[test]
    #[should_panic(expected = "split inside")]
    fn degenerate_split_rejected() {
        let wl = alexnet(1);
        let _ = lp_two_stage(&plat(), &wl, 0, OptFlags::NONE);
    }
}
