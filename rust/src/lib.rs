//! # MCMComm — hardware-software co-optimization for end-to-end
//! communication in multi-chip modules (reproduction)
//!
//! This crate is the Layer-3 (Rust) implementation of the MCMComm paper:
//! an end-to-end, congestion-aware and packaging-adaptive analytical
//! framework for MCM accelerators, the diagonal-link / on-package
//! redistribution / pipelining co-optimizations, and the GA + MIQP
//! schedulers that solve the optimized framework — plus the PJRT runtime
//! that executes the scheduled GEMM chunks on real tensors using HLO
//! artifacts AOT-compiled from the JAX/Pallas layers (`python/compile`).
//!
//! Module map (see DESIGN.md for the full inventory):
//! * [`config`] — hardware configuration (paper §4.2.1, Table 2)
//! * [`topology`] — grid types A–D, local indexing, hop models (§4.1, §5.1)
//! * [`workload`] — GEMM-sequence IR + model zoo (§4.2.2, §7)
//! * [`partition`] — workload allocations Px/Py (§4.2.3)
//! * [`cost`] — latency / energy / EDP evaluator (§4.3–4.4, §5.3)
//! * [`redistribution`] — 3-step on-package redistribution (§5.2)
//! * [`netsim`] — link-level congestion simulator (Fig. 3 substrate)
//! * [`opt`] — GA, greedy and MIQP schedulers (§6)
//! * [`pipeline`] — RCPSP batch pipelining (§5.4)
//! * [`runtime`] — PJRT execution of AOT HLO artifacts
//! * [`coordinator`] — end-to-end orchestration + serving loop
//! * [`eval`] — figure/table regeneration harnesses (§7)
//! * [`util`] — offline substrates: RNG, JSON, CLI, bench, propcheck

pub mod config;
pub mod coordinator;
pub mod cost;
pub mod eval;
pub mod netsim;
pub mod opt;
pub mod partition;
pub mod pipeline;
pub mod redistribution;
pub mod runtime;
pub mod topology;
pub mod util;
pub mod workload;
