//! # MCMComm — hardware-software co-optimization for end-to-end
//! communication in multi-chip modules (reproduction)
//!
//! This crate is the Layer-3 (Rust) implementation of the MCMComm paper:
//! an end-to-end, congestion-aware and packaging-adaptive analytical
//! framework for MCM accelerators, the diagonal-link / on-package
//! redistribution / pipelining co-optimizations, and the GA + MIQP
//! schedulers that solve the optimized framework — plus the runtime
//! that executes the scheduled GEMM chunks on real tensors using HLO
//! artifacts AOT-compiled from the JAX/Pallas layers (`python/compile`).
//!
//! ## Front door
//!
//! The public API is three nouns and one verb (see DESIGN.md):
//! a [`Scenario`] (validated hardware + workload + flags + objective)
//! is solved by a [`Scheduler`] into a [`Plan`], which scores into a
//! [`Report`]:
//!
//! ```no_run
//! use mcmcomm::{Engine, Scenario, SchedulerRegistry};
//! use mcmcomm::workload::models::alexnet;
//!
//! let engine = Engine::new(Scenario::headline(alexnet(1)));
//! let registry = SchedulerRegistry::standard(42);
//! let report = engine
//!     .schedule_with(registry.require("ga")?)?
//!     .report();
//! println!("latency {:.3} ms, EDP {:.3e}", report.latency_ns() / 1e6,
//!          report.edp());
//! # Ok::<(), mcmcomm::engine::EngineError>(())
//! ```
//!
//! Module map (see DESIGN.md for the full inventory):
//! * [`engine`] — Scenario → Plan → Report API, `Scheduler` trait +
//!   registry, `Engine` orchestrator and batch sweeps
//! * [`platform`] — data-driven packaging descriptions: declarative
//!   `PlatformSpec` (grid, link classes, arbitrary memory-attachment
//!   sets, Table-2 constants), validated `Platform` with hop tables
//!   precomputed from link-graph routing, paper presets A–D, JSON
//!   load/save
//! * [`config`] — thin preset constructors (paper §4.2.1, Table 2)
//!   onto [`platform::Platform`]
//! * [`topology`] — grid positions, local-index types, explicit NoP
//!   link graph (§4.1, §5.1)
//! * [`workload`] — graph workload IR (ops + explicit dataflow edges,
//!   multi-model composition) + model zoo (§4.2.2, §7)
//! * [`partition`] — workload allocations Px/Py (§4.2.3)
//! * [`cost`] — latency / energy / EDP evaluator (§4.3–4.4, §5.3);
//!   production call sites consume it through [`Report`]
//! * [`redistribution`] — 3-step on-package redistribution (§5.2)
//! * [`netsim`] — link-level congestion simulator (Fig. 3 substrate)
//! * [`opt`] — GA, greedy and MIQP solver backends (§6) behind the
//!   [`Scheduler`] implementations in [`engine::schedulers`]
//! * [`pipeline`] — RCPSP batch pipelining (§5.4)
//! * [`steady`] — steady-state pipelined execution engine: stage plans
//!   over the chiplet grid, the multi-batch DES (period, throughput,
//!   energy-per-sample, bottleneck stage/link) and the throughput
//!   optimizer behind `Objective::Throughput` / `EdpPerSample`
//! * [`runtime`] — execution of AOT HLO artifacts (PJRT when the
//!   `pjrt-xla` feature is enabled, CPU interpreter otherwise)
//! * [`coordinator`] — end-to-end orchestration (plan builder +
//!   executor)
//! * [`serving`] — the serving subsystem: concurrent plan cache,
//!   SLO-aware admission, continuous batching, open-loop traces and
//!   the virtual-time DES-backed load harness + threaded server
//! * [`eval`] — figure/table regeneration harnesses (§7), built on
//!   [`Engine::sweep`]
//! * [`util`] — offline substrates: RNG, JSON, CLI, bench, propcheck,
//!   scoped-thread parallel maps, error handling

pub mod config;
pub mod coordinator;
pub mod cost;
pub mod engine;
pub mod eval;
pub mod netsim;
pub mod opt;
pub mod partition;
pub mod pipeline;
pub mod platform;
pub mod redistribution;
pub mod runtime;
pub mod serving;
pub mod steady;
pub mod topology;
pub mod util;
pub mod workload;

pub use engine::{
    Engine, Plan, Report, Scenario, Scheduler, SchedulerRegistry,
};
pub use platform::{Platform, PlatformSpec};
