//! Steady-state multi-batch DES: replicate one batch's lowered task
//! graph, chain batches through per-(op, chiplet) compute
//! serialization and a `depth`-bounded in-flight window, run the
//! active-set engine, and detect the steady-state period.
//!
//! # Period detection (DESIGN.md §Steady-state pipeline engine)
//!
//! Every batch executes an identical task graph, so once the pipeline
//! is warm (after at most `depth` batches fill the window) the
//! inter-batch completion deltas settle to a single value — the
//! **period**. The simulation injects a window of batches, measures the
//! completion time of each, and accepts steady state when the last
//! three deltas agree to a relative tolerance; if they do not, the
//! batch count is doubled (up to a cap) and the run repeats on the same
//! warm [`SimScratch`]. Throughput is `1 / period`; a depth-1 pipeline
//! is strictly serialized, so its period equals the single-batch
//! makespan (the conformance bridge pinned by `tests/steady.rs`).

use crate::cost::energy::comp_energy_pj;
use crate::cost::evaluator::OptFlags;
use crate::netsim::sim::{
    lower_plan, run_tasks_into, Checkpoint, LowerCtx, LoweredPlan,
    RunOutcome, SimEnergy, SimMode, SimScratch, Task, TaskMeta, Work,
};
use crate::partition::Allocation;
use crate::platform::Platform;
use crate::topology::links::RouteCache;
use crate::util::error::Result;
use crate::workload::Workload;
use crate::{ensure, err};

use super::plan::StagePlan;

/// Steady-simulation knobs.
#[derive(Debug, Clone, Copy)]
pub struct SteadyConfig {
    /// Explicit batch count (`simulate --batches N`). `None` lets the
    /// simulator pick `max(depth + 6, 8)` and escalate on
    /// non-convergence.
    pub batches: Option<usize>,
    /// Forwarded to the event engine (wormhole fill; 0 everywhere the
    /// analytical model is the reference).
    pub hop_latency_ns: f64,
    /// Relative agreement required of the trailing completion deltas.
    pub rtol: f64,
}

impl Default for SteadyConfig {
    fn default() -> Self {
        SteadyConfig { batches: None, hop_latency_ns: 0.0, rtol: 1e-6 }
    }
}

/// Batch-count ceiling for the auto-escalation loop.
const MAX_BATCHES: usize = 64;

/// Per-stage steady-state diagnostics.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StageStat {
    /// Half-open op range `[start, end)`.
    pub ops: (usize, usize),
    /// Half-open chiplet-row range `[start, end)`.
    pub rows: (usize, usize),
    /// Compute-busy fraction of the stage's chiplet region over one
    /// steady period (1.0 = the region computes wall to wall).
    pub occupancy: f64,
}

/// What the steady-state run produced: a period instead of a makespan.
#[derive(Debug, Clone)]
pub struct SteadyReport {
    /// Steady inter-batch completion delta (ns per sample).
    pub period_ns: f64,
    /// Completion time of the first batch (pipeline fill latency).
    pub first_batch_ns: f64,
    /// Batches actually simulated to reach steady state.
    pub batches: usize,
    /// In-flight window of the simulated plan.
    pub depth: usize,
    /// Per-stage occupancy, stage order.
    pub stages: Vec<StageStat>,
    /// Highest-occupancy stage (the pipeline's rate limiter).
    pub bottleneck_stage: usize,
    /// Busiest link over one period: `(from, to, utilization)`.
    pub bottleneck_link: Option<(usize, usize, f64)>,
    /// Energy charged to one sample (per-batch traffic is exactly
    /// total / batches — every batch moves identical bytes).
    pub energy_per_sample: SimEnergy,
}

impl SteadyReport {
    /// Sustained throughput in samples per second.
    pub fn throughput_per_s(&self) -> f64 {
        if self.period_ns > 0.0 { 1e9 / self.period_ns } else { 0.0 }
    }

    /// Deterministic text summary (the golden-snapshot payload):
    /// period, throughput, fill latency, energy split per sample,
    /// per-stage occupancy and the bottlenecks.
    pub fn summary(&self) -> String {
        let mut s = String::new();
        s.push_str(&format!("period_ns {:.9e}\n", self.period_ns));
        s.push_str(&format!(
            "throughput_per_s {:.9e}\n",
            self.throughput_per_s()
        ));
        s.push_str(&format!("first_batch_ns {:.9e}\n", self.first_batch_ns));
        s.push_str(&format!(
            "batches {} depth {}\n",
            self.batches, self.depth
        ));
        s.push_str(&format!(
            "energy_per_sample_pj total {:.9e} offchip {:.9e} nop {:.9e} \
             compute {:.9e}\n",
            self.energy_per_sample.total_pj(),
            self.energy_per_sample.offchip_pj,
            self.energy_per_sample.nop_pj,
            self.energy_per_sample.compute_pj
        ));
        for (i, st) in self.stages.iter().enumerate() {
            s.push_str(&format!(
                "stage {} ops {}..{} rows {}..{} occupancy {:.6}\n",
                i, st.ops.0, st.ops.1, st.rows.0, st.rows.1, st.occupancy
            ));
        }
        s.push_str(&format!("bottleneck_stage {}\n", self.bottleneck_stage));
        if let Some((from, to, util)) = self.bottleneck_link {
            s.push_str(&format!(
                "bottleneck_link {from} -> {to} util {util:.9}\n"
            ));
        }
        s
    }
}

/// Replicate the single-batch template `batches` times: deps shift by
/// the batch offset, computes chain to the previous batch's same
/// (op, chiplet) compute (the event engine treats computes as pure
/// durations, so cross-batch occupancy of a chiplet must be an explicit
/// edge), and each batch's root tasks wait for batch `b - depth` to
/// finish (the in-flight window).
fn replicate(
    template: &LoweredPlan,
    batches: usize,
    depth: usize,
) -> (Vec<Task>, Vec<TaskMeta>) {
    let t_n = template.tasks.len();
    let last_done = template
        .op_done_ids
        .last()
        .map(|v| v.as_slice())
        .unwrap_or(&[]);
    let is_compute: Vec<bool> = template
        .tasks
        .iter()
        .map(|t| matches!(t.work, Work::Compute { .. }))
        .collect();
    let mut tasks = Vec::with_capacity(t_n * batches);
    let mut meta = Vec::with_capacity(t_n * batches);
    for b in 0..batches {
        let off = b * t_n;
        for (t, task) in template.tasks.iter().enumerate() {
            let mut deps: Vec<usize> =
                task.deps.iter().map(|&d| d + off).collect();
            if b > 0 && is_compute[t] {
                deps.push(off - t_n + t);
            }
            if b >= depth && task.deps.is_empty() {
                let prev = (b - depth) * t_n;
                deps.extend(last_done.iter().map(|&d| d + prev));
            }
            tasks.push(Task { work: task.work.clone(), deps });
        }
        meta.extend_from_slice(&template.meta);
    }
    (tasks, meta)
}

/// Completion time of each batch: max finish over its task slice.
fn batch_completions(finish: &[f64], t_n: usize, batches: usize) -> Vec<f64> {
    (0..batches)
        .map(|b| {
            finish[b * t_n..(b + 1) * t_n]
                .iter()
                .fold(0.0f64, |a, &v| a.max(v))
        })
        .collect()
}

/// Steady-state test: the trailing three inter-batch deltas agree to
/// `rtol`. Returns the period (the last delta).
fn detect_period(completions: &[f64], depth: usize, rtol: f64) -> Option<f64> {
    let n = completions.len();
    // Need the window full (warmup) plus three deltas.
    if n < depth.max(1) + 3 || n < 4 {
        return None;
    }
    let deltas: Vec<f64> =
        (n - 3..n).map(|b| completions[b] - completions[b - 1]).collect();
    let dmax = deltas.iter().copied().fold(f64::NEG_INFINITY, f64::max);
    let dmin = deltas.iter().copied().fold(f64::INFINITY, f64::min);
    if !dmax.is_finite() || dmin < 0.0 {
        return None;
    }
    if dmax - dmin <= rtol * dmax.max(1e-9) {
        Some(deltas[2])
    } else {
        None
    }
}

/// Per-stage compute-busy time of one batch (from the template's
/// compute durations) and the derived occupancy table.
fn stage_stats(
    plat: &Platform,
    plan: &StagePlan,
    template: &LoweredPlan,
    period_ns: f64,
) -> Vec<StageStat> {
    (0..plan.stages())
        .map(|s| {
            let ops = plan.op_range(s);
            let rows = plan.row_range(s);
            let busy: f64 = ops
                .clone()
                .flat_map(|i| template.compute_ids[i].iter())
                .map(|&t| match template.tasks[t].work {
                    Work::Compute { dur_ns } => dur_ns,
                    _ => 0.0,
                })
                .sum();
            let chiplets = (rows.len() * plat.ydim) as f64;
            let occupancy = if period_ns > 0.0 {
                busy / (period_ns * chiplets)
            } else {
                0.0
            };
            StageStat {
                ops: (ops.start, ops.end),
                rows: (rows.start, rows.end),
                occupancy,
            }
        })
        .collect()
}

/// Simulate a stage plan to steady state. Lowers the plan's derived
/// allocation once in [`SimMode::Pipelined`], replicates per batch,
/// and escalates the batch window until the period detector converges
/// (unless `cfg.batches` pins the window). Errors on non-convergence
/// name the **starved** (lowest-occupancy) stage — the usual culprit
/// when a boundary strands a stage without work.
pub fn simulate_steady(
    plat: &Platform,
    wl: &Workload,
    plan: &StagePlan,
    flags: OptFlags,
    cfg: &SteadyConfig,
) -> Result<SteadyReport> {
    plan.validate(plat, wl)?;
    let alloc = plan.allocation(plat, wl)?;
    simulate_steady_alloc(plat, wl, plan, &alloc, flags, cfg)
}

/// [`simulate_steady`] on a caller-supplied allocation (must be the
/// plan's own lowering or a refinement with the same stage regions —
/// the occupancy attribution assumes ops live on their stage bands).
pub fn simulate_steady_alloc(
    plat: &Platform,
    wl: &Workload,
    plan: &StagePlan,
    alloc: &Allocation,
    flags: OptFlags,
    cfg: &SteadyConfig,
) -> Result<SteadyReport> {
    ensure!(!wl.ops.is_empty(), "cannot pipeline an empty workload");
    let depth = plan.depth;
    let graph = plat.link_graph_shared(flags.diagonal);
    let ctx = LowerCtx::new(plat, wl);
    let mut rc = RouteCache::new();
    let mut scratch = SimScratch::default();
    let template = lower_plan(
        plat,
        wl,
        alloc,
        flags,
        SimMode::Pipelined,
        &ctx,
        &graph,
        &mut rc,
        &mut scratch.lower,
    )?;
    let t_n = template.tasks.len();
    ensure!(t_n > 0, "plan lowered to an empty task graph");

    let fixed = cfg.batches.is_some();
    let mut batches = cfg
        .batches
        .unwrap_or_else(|| (depth + 6).max(8))
        .max(2);
    let mut run = RunOutcome::default();
    let mut checkpoints: Vec<Checkpoint> = Vec::new();
    loop {
        let (tasks, meta) = replicate(&template, batches, depth);
        run_tasks_into(
            &graph,
            &tasks,
            Some(&meta),
            cfg.hop_latency_ns,
            &[],
            None,
            &mut scratch,
            &mut run,
            &mut checkpoints,
            None,
        )?;
        let completions = batch_completions(&run.finish, t_n, batches);
        if let Some(period) = detect_period(&completions, depth, cfg.rtol) {
            let stages = stage_stats(plat, plan, &template, period);
            let bottleneck_stage = stages
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.occupancy.total_cmp(&b.1.occupancy))
                .map(|(i, _)| i)
                .unwrap_or(0);
            // Energy and link utilization per batch: every batch moves
            // identical traffic, so total / batches is exact.
            let n_chiplets = plat.num_chiplets();
            let inv_b = 1.0 / batches as f64;
            let mut energy = SimEnergy::default();
            let mut bottleneck_link: Option<(usize, usize, f64)> = None;
            for (l, link) in graph.links.iter().enumerate() {
                let bytes = run.link_bytes[l] * inv_b;
                let bits = bytes * 8.0;
                if link.from >= n_chiplets || link.to >= n_chiplets {
                    energy.offchip_pj += bits * plat.mem_pj_bit;
                } else {
                    energy.nop_pj += bits * plat.energy.nop_pj_bit_hop;
                }
                let util = if period > 0.0 && link.capacity > 0.0 {
                    bytes / (link.capacity * period)
                } else {
                    0.0
                };
                let better = match bottleneck_link {
                    Some((_, _, best)) => util > best,
                    None => util > 0.0,
                };
                if better {
                    bottleneck_link = Some((link.from, link.to, util));
                }
            }
            energy.compute_pj = wl
                .ops
                .iter()
                .zip(&alloc.parts)
                .map(|(op, part)| comp_energy_pj(plat, op, part))
                .sum();
            return Ok(SteadyReport {
                period_ns: period,
                first_batch_ns: completions[0],
                batches,
                depth,
                stages,
                bottleneck_stage,
                bottleneck_link,
                energy_per_sample: energy,
            });
        }
        if fixed || batches >= MAX_BATCHES {
            // Name the starved stage: the least-occupied region under
            // the best current period estimate.
            let est = completions
                .last()
                .zip(completions.get(completions.len().wrapping_sub(2)))
                .map(|(a, b)| a - b)
                .unwrap_or(0.0);
            let stages = stage_stats(plat, plan, &template, est.max(1e-9));
            let (starved, stat) = stages
                .iter()
                .enumerate()
                .min_by(|a, b| a.1.occupancy.total_cmp(&b.1.occupancy))
                .expect("validated plan has at least one stage");
            return Err(err!(
                "steady state did not converge after {batches} batches \
                 (depth {depth}): starved stage {starved} (ops \
                 {}..{}, rows {}..{}, occupancy {:.4}) never settles — \
                 raise --batches or rebalance the stage boundaries",
                stat.ops.0,
                stat.ops.1,
                stat.rows.0,
                stat.rows.1,
                stat.occupancy
            ));
        }
        batches = (batches * 2).min(MAX_BATCHES);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netsim::sim::{simulate_plan, SimConfig};
    use crate::workload::models::alexnet;

    #[test]
    fn depth1_period_equals_single_batch_makespan() {
        let plat = Platform::headline();
        let wl = alexnet(1);
        let plan = StagePlan::single_stage(&plat, &wl, 1);
        let steady = simulate_steady(
            &plat,
            &wl,
            &plan,
            OptFlags::ALL,
            &SteadyConfig::default(),
        )
        .unwrap();
        let alloc = plan.allocation(&plat, &wl).unwrap();
        let single = simulate_plan(
            &plat,
            &wl,
            &alloc,
            OptFlags::ALL,
            &SimConfig { mode: SimMode::Pipelined, hop_latency_ns: 0.0 },
        )
        .unwrap();
        let rel = (steady.period_ns - single.makespan_ns).abs()
            / single.makespan_ns;
        assert!(
            rel < 1e-6,
            "depth-1 period {} vs single-batch makespan {} (rel {rel})",
            steady.period_ns,
            single.makespan_ns
        );
        assert!(steady.first_batch_ns > 0.0);
        assert!(steady.throughput_per_s() > 0.0);
    }

    #[test]
    fn deeper_pipelines_do_not_slow_down() {
        let plat = Platform::headline();
        let wl = alexnet(1);
        let mut prev = f64::INFINITY;
        for depth in [1usize, 2, 4] {
            let plan = StagePlan::single_stage(&plat, &wl, depth);
            let r = simulate_steady(
                &plat,
                &wl,
                &plan,
                OptFlags::ALL,
                &SteadyConfig::default(),
            )
            .unwrap();
            assert!(
                r.period_ns <= prev * 1.02,
                "depth {depth} period {} regressed from {prev}",
                r.period_ns
            );
            prev = r.period_ns;
        }
    }

    #[test]
    fn summary_names_stages_and_bottlenecks() {
        let plat = Platform::headline();
        let wl = alexnet(1);
        let plan = StagePlan::balanced(&plat, &wl, 2, 2).unwrap();
        let r = simulate_steady(
            &plat,
            &wl,
            &plan,
            OptFlags::ALL,
            &SteadyConfig::default(),
        )
        .unwrap();
        let s = r.summary();
        assert!(s.contains("period_ns"), "{s}");
        assert!(s.contains("stage 0") && s.contains("stage 1"), "{s}");
        assert!(s.contains("bottleneck_stage"), "{s}");
        assert_eq!(r.stages.len(), 2);
        for st in &r.stages {
            assert!(
                st.occupancy >= 0.0 && st.occupancy <= 1.0 + 1e-6,
                "occupancy {}",
                st.occupancy
            );
        }
        assert!(r.energy_per_sample.total_pj() > 0.0);
    }

    #[test]
    fn fixed_tiny_window_errors_name_a_starved_stage() {
        let plat = Platform::headline();
        let wl = alexnet(1);
        let plan = StagePlan::single_stage(&plat, &wl, 2);
        // Two batches can never produce three agreeing deltas.
        let err = simulate_steady(
            &plat,
            &wl,
            &plan,
            OptFlags::ALL,
            &SteadyConfig { batches: Some(2), ..Default::default() },
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("starved stage"), "{err}");
        assert!(err.contains("--batches"), "{err}");
    }
}
