//! Throughput optimizer: greedy stage-balancing seeds + a seeded
//! mutation search over stage boundaries, row bands and depth, scored
//! by the steady-state DES.
//!
//! The search space is tiny compared to the per-op partition space the
//! GA explores — a stage plan is two compositions (ops, rows) and a
//! depth — but every evaluation is a multi-batch simulation, so the
//! optimizer is a (1+1)-style hill climber with stage-local mutations
//! (move one cut by one op, move one band boundary by one row, bump
//! depth, split/merge a stage) rather than a population GA. Seeds cover
//! every stage count the grid supports, at several depths, so the
//! climber starts from the best balanced layout instead of a random
//! one.

use crate::cost::evaluator::{Objective, OptFlags};
use crate::platform::Platform;
use crate::util::error::Result;
use crate::util::rng::Pcg;
use crate::workload::Workload;
use crate::{ensure, err};

use super::plan::StagePlan;
use super::sim::{simulate_steady, SteadyConfig, SteadyReport};

/// Search knobs.
#[derive(Debug, Clone, Copy)]
pub struct SteadyParams {
    /// Mutation steps after seeding.
    pub iters: usize,
    /// Deepest in-flight window the search may propose.
    pub max_depth: usize,
    /// Stage-count ceiling (0 = `min(xdim, n_ops)`).
    pub max_stages: usize,
    pub seed: u64,
    /// Forwarded to every steady simulation.
    pub sim: SteadyConfig,
}

impl Default for SteadyParams {
    fn default() -> Self {
        SteadyParams {
            iters: 24,
            max_depth: 4,
            max_stages: 0,
            seed: 0xace5,
            sim: SteadyConfig::default(),
        }
    }
}

/// Best plan the search found, with its steady report and score.
#[derive(Debug, Clone)]
pub struct SteadyOutcome {
    pub plan: StagePlan,
    pub report: SteadyReport,
    /// The minimized value: period (Throughput / Latency) or
    /// period × energy-per-sample (EdpPerSample / Edp).
    pub objective_value: f64,
}

/// Score a steady report under `obj` (lower is better).
pub fn steady_objective(report: &SteadyReport, obj: Objective) -> f64 {
    match obj {
        Objective::Latency | Objective::Throughput => report.period_ns,
        Objective::Edp | Objective::EdpPerSample => {
            report.period_ns * report.energy_per_sample.total_pj()
        }
    }
}

/// One stage-local mutation; returns `None` when the move is illegal
/// from the current plan (caller retries with a fresh roll).
fn mutate(
    plan: &StagePlan,
    rng: &mut Pcg,
    max_depth: usize,
    max_stages: usize,
) -> Option<StagePlan> {
    let mut p = plan.clone();
    let stages = p.stages();
    match rng.range_usize(0, 3) {
        // Move one op across a stage cut.
        0 => {
            if stages < 2 {
                return None;
            }
            let cut = rng.range_usize(0, stages - 2); // between cut..cut+1
            if rng.chance(0.5) {
                if p.ops_per_stage[cut] < 2 {
                    return None;
                }
                p.ops_per_stage[cut] -= 1;
                p.ops_per_stage[cut + 1] += 1;
            } else {
                if p.ops_per_stage[cut + 1] < 2 {
                    return None;
                }
                p.ops_per_stage[cut + 1] -= 1;
                p.ops_per_stage[cut] += 1;
            }
            Some(p)
        }
        // Move one row across a band boundary.
        1 => {
            if stages < 2 {
                return None;
            }
            let cut = rng.range_usize(0, stages - 2);
            if rng.chance(0.5) {
                if p.rows_per_stage[cut] < 2 {
                    return None;
                }
                p.rows_per_stage[cut] -= 1;
                p.rows_per_stage[cut + 1] += 1;
            } else {
                if p.rows_per_stage[cut + 1] < 2 {
                    return None;
                }
                p.rows_per_stage[cut + 1] -= 1;
                p.rows_per_stage[cut] += 1;
            }
            Some(p)
        }
        // Bump the in-flight window.
        2 => {
            let up = rng.chance(0.5);
            if up && p.depth < max_depth {
                p.depth += 1;
            } else if !up && p.depth > 1 {
                p.depth -= 1;
            } else {
                return None;
            }
            Some(p)
        }
        // Split the fattest stage / merge the thinnest neighbor pair.
        _ => {
            if rng.chance(0.5) && stages < max_stages {
                let (s, _) = p
                    .ops_per_stage
                    .iter()
                    .enumerate()
                    .max_by_key(|(_, &c)| c)?;
                if p.ops_per_stage[s] < 2 || p.rows_per_stage[s] < 2 {
                    return None;
                }
                let oc = p.ops_per_stage[s];
                let rc = p.rows_per_stage[s];
                p.ops_per_stage[s] = oc / 2;
                p.ops_per_stage.insert(s + 1, oc - oc / 2);
                p.rows_per_stage[s] = rc / 2;
                p.rows_per_stage.insert(s + 1, rc - rc / 2);
                Some(p)
            } else if stages >= 2 {
                let s = rng.range_usize(0, stages - 2);
                p.ops_per_stage[s] += p.ops_per_stage[s + 1];
                p.ops_per_stage.remove(s + 1);
                p.rows_per_stage[s] += p.rows_per_stage[s + 1];
                p.rows_per_stage.remove(s + 1);
                Some(p)
            } else {
                None
            }
        }
    }
}

/// Find a stage plan minimizing the steady objective. Deterministic for
/// a fixed `(params, platform, workload, flags, obj)` tuple: seeds are
/// enumerated in a fixed order and the climber's RNG is the seeded
/// [`Pcg`].
pub fn optimize(
    plat: &Platform,
    wl: &Workload,
    flags: OptFlags,
    obj: Objective,
    params: &SteadyParams,
) -> Result<SteadyOutcome> {
    ensure!(!wl.ops.is_empty(), "cannot pipeline an empty workload");
    let max_stages = if params.max_stages == 0 {
        plat.xdim.min(wl.ops.len())
    } else {
        params.max_stages.min(plat.xdim).min(wl.ops.len())
    };
    let max_depth = params.max_depth.max(1);
    let eval = |plan: &StagePlan| -> Result<(SteadyReport, f64)> {
        let report = simulate_steady(plat, wl, plan, flags, &params.sim)?;
        let v = steady_objective(&report, obj);
        Ok((report, v))
    };

    // ---- seeds: every supported stage count × a shallow and a deep
    // window. A seed that fails to reach steady state is skipped (the
    // climber never starts from a non-converging layout).
    let mut best: Option<(StagePlan, SteadyReport, f64)> = None;
    let mut depths = vec![1usize];
    if max_depth >= 2 {
        depths.push(2);
    }
    if max_depth > 2 {
        depths.push(max_depth);
    }
    for k in 1..=max_stages {
        for &d in &depths {
            let plan = if k == 1 {
                StagePlan::single_stage(plat, wl, d)
            } else {
                StagePlan::balanced(plat, wl, k, d)?
            };
            match eval(&plan) {
                Ok((report, v)) => {
                    if best.as_ref().is_none_or(|(_, _, bv)| v < *bv) {
                        best = Some((plan, report, v));
                    }
                }
                Err(_) => continue,
            }
        }
    }
    let (mut best_plan, mut best_report, mut best_v) = best.ok_or_else(|| {
        err!(
            "no stage-plan seed reached steady state on '{}' × {} — raise \
             the batch window",
            wl.name,
            plat.name
        )
    })?;

    // ---- (1+1) hill climb with stage-local mutations.
    let mut rng = Pcg::seeded(params.seed);
    let mut step = 0usize;
    let mut rolls = 0usize;
    while step < params.iters && rolls < params.iters * 8 {
        rolls += 1;
        let Some(cand) = mutate(&best_plan, &mut rng, max_depth, max_stages)
        else {
            continue;
        };
        if cand.validate(plat, wl).is_err() {
            continue;
        }
        step += 1;
        let Ok((report, v)) = eval(&cand) else {
            continue; // non-converging candidate: reject, keep climbing
        };
        if v < best_v {
            best_plan = cand;
            best_report = report;
            best_v = v;
        }
    }
    Ok(SteadyOutcome {
        plan: best_plan,
        report: best_report,
        objective_value: best_v,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::models::alexnet;

    fn tiny_params() -> SteadyParams {
        SteadyParams { iters: 6, max_depth: 2, ..Default::default() }
    }

    #[test]
    fn optimize_is_deterministic_and_legal() {
        let plat = Platform::headline();
        let wl = alexnet(1);
        let a = optimize(
            &plat,
            &wl,
            OptFlags::ALL,
            Objective::Throughput,
            &tiny_params(),
        )
        .unwrap();
        let b = optimize(
            &plat,
            &wl,
            OptFlags::ALL,
            Objective::Throughput,
            &tiny_params(),
        )
        .unwrap();
        assert_eq!(a.plan, b.plan);
        assert_eq!(a.objective_value.to_bits(), b.objective_value.to_bits());
        a.plan.validate(&plat, &wl).unwrap();
        assert!(a.objective_value > 0.0);
        assert_eq!(a.objective_value, a.report.period_ns);
    }

    #[test]
    fn optimized_beats_or_matches_serial_depth1() {
        let plat = Platform::headline();
        let wl = alexnet(1);
        let serial = simulate_steady(
            &plat,
            &wl,
            &StagePlan::single_stage(&plat, &wl, 1),
            OptFlags::ALL,
            &SteadyConfig::default(),
        )
        .unwrap();
        let opt = optimize(
            &plat,
            &wl,
            OptFlags::ALL,
            Objective::Throughput,
            &tiny_params(),
        )
        .unwrap();
        // The serial plan is in the seed set, so the optimum can only
        // be at least as good.
        assert!(
            opt.report.period_ns <= serial.period_ns * (1.0 + 1e-9),
            "optimizer ({}) worse than serial ({})",
            opt.report.period_ns,
            serial.period_ns
        );
    }

    #[test]
    fn edp_per_sample_objective_scores_energy() {
        let plat = Platform::headline();
        let wl = alexnet(1);
        let out = optimize(
            &plat,
            &wl,
            OptFlags::ALL,
            Objective::EdpPerSample,
            &tiny_params(),
        )
        .unwrap();
        let expect = out.report.period_ns
            * out.report.energy_per_sample.total_pj();
        assert_eq!(out.objective_value.to_bits(), expect.to_bits());
    }
}
