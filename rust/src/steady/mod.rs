//! Steady-state pipelined execution: multi-batch throughput as a
//! first-class objective (ROADMAP item 2, the Scope-style merged
//! pipeline).
//!
//! Everything else in the repo scores one batch's makespan. This
//! subsystem turns a workload × platform into a *sustained stream*:
//!
//! * [`plan`] — the pipelined plan form: a [`StagePlan`] assigns
//!   contiguous op ranges to contiguous chiplet-row bands and carries a
//!   double-buffering depth (how many batches may be in flight). A
//!   stage plan lowers onto the existing [`crate::partition::Allocation`]
//!   (band rows hold the op's partition, other rows idle), so every
//!   downstream consumer — evaluator, DES, validators — works
//!   unchanged.
//! * [`sim`] — the steady-state multi-batch DES: the single-batch plan
//!   is lowered once in [`crate::netsim::SimMode::Pipelined`], the task
//!   graph is replicated per batch with (a) per-(op, chiplet) compute
//!   serialization across batches and (b) an in-flight cap of `depth`
//!   batches, then run on the active-set engine with a reused
//!   `SimScratch`. Steady state is detected as identical inter-batch
//!   completion deltas; the report carries the period, throughput
//!   (samples/s), per-stage occupancy, bottleneck stage/link and
//!   energy-per-sample instead of a makespan.
//! * [`opt`] — the throughput optimizer: greedy stage-balancing seeds
//!   (cuts that equalize per-stage compute load, rows proportional to
//!   stage load) refined by a seeded mutation search over stage
//!   boundaries, row bands and depth, scored by the steady DES under
//!   [`crate::cost::evaluator::Objective::Throughput`] or
//!   [`crate::cost::evaluator::Objective::EdpPerSample`].
//!
//! A depth-1 pipeline is strictly serialized, so its period equals the
//! single-batch Pipelined-mode makespan — the bit-consistency bridge to
//! the conformance suite (pinned by `tests/steady.rs`).

pub mod opt;
pub mod plan;
pub mod sim;

pub use opt::{optimize, SteadyOutcome, SteadyParams};
pub use plan::StagePlan;
pub use sim::{simulate_steady, StageStat, SteadyConfig, SteadyReport};
