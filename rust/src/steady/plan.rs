//! The pipelined plan form: contiguous stage assignment over the graph
//! IR with per-stage chiplet-row bands and a double-buffering depth.
//!
//! # Stage legality (DESIGN.md §Steady-state pipeline engine)
//!
//! * Stages partition the op list into **contiguous, non-empty ranges**
//!   in graph order. Ops are topologically ordered by construction
//!   ([`crate::workload::Workload`] validation), so a contiguous cut
//!   never places a consumer before its producer.
//! * Stages own **contiguous, non-empty row bands** of the chiplet
//!   grid, in row order; the bands partition the `xdim` rows. Columns
//!   are never split: every stage spans the full `ydim`, so the §5.2
//!   redistribution and collection-column machinery apply unchanged
//!   inside a stage.
//! * `depth >= 1` batches may be in flight at once (double buffering
//!   generalized to a ring of `depth` buffers).
//!
//! A stage plan lowers to an ordinary [`Allocation`]: ops of stage `s`
//! put their `px` mass on the band rows (uniform split inside the
//! band), zero elsewhere; `py` is the uniform column split. The
//! [`crate::netsim::SimMode::Pipelined`] lowering gates load demand on
//! region membership, so idle rows neither pull weights nor compute.

use crate::partition::{uniform_split, Allocation, Partition};
use crate::platform::Platform;
use crate::util::error::Result;
use crate::workload::Workload;
use crate::{ensure, err};

/// A pipelined execution plan: which ops run where, and how many
/// batches may be in flight.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct StagePlan {
    /// Ops per stage, in graph order; sums to the op count, entries
    /// >= 1.
    pub ops_per_stage: Vec<usize>,
    /// Chiplet rows per stage, top band first; sums to `xdim`, entries
    /// >= 1.
    pub rows_per_stage: Vec<usize>,
    /// Max batches in flight (>= 1). Depth 1 degenerates to the
    /// single-batch layer-sequential run.
    pub depth: usize,
}

impl StagePlan {
    /// The trivial plan: one stage over the whole grid. With
    /// `depth == 1` this is exactly the single-batch conformance
    /// execution; with `depth > 1` successive batches overlap on the
    /// full grid.
    pub fn single_stage(plat: &Platform, wl: &Workload, depth: usize) -> StagePlan {
        StagePlan {
            ops_per_stage: vec![wl.ops.len()],
            rows_per_stage: vec![plat.xdim],
            depth,
        }
    }

    /// Greedy stage-balancing seed: cut the op list into `stages`
    /// ranges with near-equal cumulative compute volume (MACs), then
    /// hand out rows proportionally to each stage's share of the load
    /// (largest-remainder, every stage >= 1 row).
    pub fn balanced(
        plat: &Platform,
        wl: &Workload,
        stages: usize,
        depth: usize,
    ) -> Result<StagePlan> {
        let n_ops = wl.ops.len();
        ensure!(stages >= 1, "stage count must be >= 1");
        ensure!(
            stages <= n_ops && stages <= plat.xdim,
            "{stages} stages need {stages} ops and rows (have {n_ops} ops, \
             {} rows)",
            plat.xdim
        );
        let macs: Vec<f64> = wl
            .ops
            .iter()
            .map(|op| op.m as f64 * op.k.max(1) as f64 * op.n as f64)
            .collect();
        let total: f64 = macs.iter().sum();
        // Cut after the op whose cumulative load first reaches the
        // stage's fair share, always leaving enough ops for the
        // remaining stages.
        let mut ops_per_stage = Vec::with_capacity(stages);
        let mut i = 0usize;
        let mut acc = 0.0;
        for s in 0..stages {
            let remaining_stages = stages - s;
            let hi = n_ops - (remaining_stages - 1); // leave 1 op each
            let lo = i + 1;
            let target = total * (s + 1) as f64 / stages as f64;
            let mut j = i;
            while j < hi && (j < lo || acc < target) {
                acc += macs[j];
                j += 1;
            }
            ops_per_stage.push(j - i);
            i = j;
        }
        debug_assert_eq!(ops_per_stage.iter().sum::<usize>(), n_ops);
        // Rows proportional to stage load, >= 1 each.
        let mut loads = Vec::with_capacity(stages);
        let mut k = 0usize;
        for &c in &ops_per_stage {
            loads.push(macs[k..k + c].iter().sum::<f64>().max(1.0));
            k += c;
        }
        let spare = plat.xdim - stages;
        let extra = crate::partition::proportional_split(spare, &loads);
        let rows_per_stage: Vec<usize> =
            extra.into_iter().map(|e| e + 1).collect();
        let plan = StagePlan { ops_per_stage, rows_per_stage, depth };
        plan.validate(plat, wl)?;
        Ok(plan)
    }

    pub fn stages(&self) -> usize {
        self.ops_per_stage.len()
    }

    /// Check the legality rules (module docs) against a binding.
    pub fn validate(&self, plat: &Platform, wl: &Workload) -> Result<()> {
        ensure!(
            !self.ops_per_stage.is_empty()
                && self.ops_per_stage.len() == self.rows_per_stage.len(),
            "stage plan has {} op ranges but {} row bands",
            self.ops_per_stage.len(),
            self.rows_per_stage.len()
        );
        ensure!(self.depth >= 1, "pipeline depth must be >= 1");
        ensure!(
            self.ops_per_stage.iter().all(|&c| c >= 1),
            "every stage needs at least one op"
        );
        ensure!(
            self.rows_per_stage.iter().all(|&r| r >= 1),
            "every stage needs at least one chiplet row"
        );
        let ops: usize = self.ops_per_stage.iter().sum();
        ensure!(
            ops == wl.ops.len(),
            "stage op ranges cover {ops} ops, workload has {}",
            wl.ops.len()
        );
        let rows: usize = self.rows_per_stage.iter().sum();
        ensure!(
            rows == plat.xdim,
            "stage row bands cover {rows} rows, grid has {}",
            plat.xdim
        );
        Ok(())
    }

    /// Half-open op range of stage `s`.
    pub fn op_range(&self, s: usize) -> std::ops::Range<usize> {
        let start: usize = self.ops_per_stage[..s].iter().sum();
        start..start + self.ops_per_stage[s]
    }

    /// Half-open row range of stage `s`.
    pub fn row_range(&self, s: usize) -> std::ops::Range<usize> {
        let start: usize = self.rows_per_stage[..s].iter().sum();
        start..start + self.rows_per_stage[s]
    }

    /// Stage owning op `i`.
    pub fn stage_of_op(&self, i: usize) -> usize {
        let mut acc = 0usize;
        for (s, &c) in self.ops_per_stage.iter().enumerate() {
            acc += c;
            if i < acc {
                return s;
            }
        }
        self.ops_per_stage.len() - 1
    }

    /// Lower the stage plan onto an ordinary [`Allocation`]: each op's
    /// `px` mass sits uniformly on its stage's row band (zero outside),
    /// `py` is the uniform column split, collection columns default to
    /// the grid middle (the [`crate::partition::uniform_allocation`]
    /// convention).
    pub fn allocation(&self, plat: &Platform, wl: &Workload) -> Result<Allocation> {
        self.validate(plat, wl)?;
        let mut parts = Vec::with_capacity(wl.ops.len());
        for (s, _) in self.ops_per_stage.iter().enumerate() {
            let rows = self.row_range(s);
            for i in self.op_range(s) {
                let op = &wl.ops[i];
                ensure!(
                    op.m >= 1 && op.n >= 1,
                    "op '{}' has an empty output",
                    op.name
                );
                let band = uniform_split(op.m, rows.len());
                let mut px = vec![0usize; plat.xdim];
                px[rows.clone()].copy_from_slice(&band);
                parts.push(Partition {
                    px,
                    py: uniform_split(op.n, plat.ydim),
                });
            }
        }
        Ok(Allocation {
            parts,
            collect_cols: vec![plat.ydim / 2; wl.edge_count()],
        })
    }

    /// One-line human description, e.g. `3 stages [5|2, 2|1, 1|1] depth 2`
    /// (ops|rows per stage).
    pub fn describe(&self) -> String {
        let stages: Vec<String> = self
            .ops_per_stage
            .iter()
            .zip(&self.rows_per_stage)
            .map(|(o, r)| format!("{o}|{r}"))
            .collect();
        format!(
            "{} stage{} [{}] depth {}",
            self.stages(),
            if self.stages() == 1 { "" } else { "s" },
            stages.join(", "),
            self.depth
        )
    }
}

/// Parse a `--stages` CLI spec: either a stage count (`"3"`, balanced
/// seed) or explicit op cuts are not accepted — the optimizer owns
/// boundary placement. Returns the balanced plan.
pub fn stage_plan_from_count(
    plat: &Platform,
    wl: &Workload,
    stages: usize,
    depth: usize,
) -> Result<StagePlan> {
    if stages <= 1 {
        let p = StagePlan::single_stage(plat, wl, depth);
        p.validate(plat, wl)?;
        Ok(p)
    } else {
        StagePlan::balanced(plat, wl, stages, depth)
            .map_err(|e| err!("building a {stages}-stage plan: {e}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::models::alexnet;

    #[test]
    fn single_stage_is_legal_and_covers_everything() {
        let plat = Platform::headline();
        let wl = alexnet(1);
        let p = StagePlan::single_stage(&plat, &wl, 1);
        p.validate(&plat, &wl).unwrap();
        assert_eq!(p.op_range(0), 0..wl.ops.len());
        assert_eq!(p.row_range(0), 0..plat.xdim);
        let alloc = p.allocation(&plat, &wl).unwrap();
        alloc.validate(&wl, &plat).unwrap();
        // Full-grid single stage == the uniform allocation's partitions.
        let uni = crate::partition::uniform_allocation(&plat, &wl);
        assert_eq!(alloc.parts, uni.parts);
    }

    #[test]
    fn balanced_cuts_are_contiguous_and_banded() {
        let plat = Platform::headline();
        let wl = alexnet(1);
        for stages in 1..=4usize.min(wl.ops.len()) {
            let p = StagePlan::balanced(&plat, &wl, stages, 2).unwrap();
            assert_eq!(p.stages(), stages);
            p.validate(&plat, &wl).unwrap();
            let alloc = p.allocation(&plat, &wl).unwrap();
            alloc.validate(&wl, &plat).unwrap();
            // px mass sits exactly on the stage band.
            for i in 0..wl.ops.len() {
                let s = p.stage_of_op(i);
                let rows = p.row_range(s);
                for (x, &v) in alloc.parts[i].px.iter().enumerate() {
                    if !rows.contains(&x) {
                        assert_eq!(v, 0, "op {i} leaks outside its band");
                    }
                }
                let band: usize =
                    alloc.parts[i].px[rows.clone()].iter().sum();
                assert_eq!(band, wl.ops[i].m);
            }
        }
    }

    #[test]
    fn balanced_rejects_too_many_stages() {
        let plat = Platform::headline();
        let wl = alexnet(1);
        assert!(StagePlan::balanced(&plat, &wl, plat.xdim + 1, 1).is_err());
    }

    #[test]
    fn stage_of_op_matches_ranges() {
        let plat = Platform::headline();
        let wl = alexnet(1);
        let p = StagePlan::balanced(&plat, &wl, 3, 1).unwrap();
        for s in 0..p.stages() {
            for i in p.op_range(s) {
                assert_eq!(p.stage_of_op(i), s);
            }
        }
    }

    #[test]
    fn describe_is_stable() {
        let plat = Platform::headline();
        let wl = alexnet(1);
        let p = StagePlan::single_stage(&plat, &wl, 2);
        let d = p.describe();
        assert!(d.contains("1 stage") && d.contains("depth 2"), "{d}");
    }
}
