//! [`HopTables`]: the §4.3.3 / §5.1.1 hop models precomputed once per
//! platform from explicit [`LinkGraph`] routing.
//!
//! The legacy `Topology` computed hops with per-`SystemType` closed-form
//! match arms. Here the *minimal* hop counts (eq. 10 low-bandwidth
//! loading and the §4.4.3 energy model) are the measured length of the
//! deterministic route from each chiplet's serving attachment through
//! the actual link graph — so an arbitrary attachment layout gets
//! correct hops with no new formulas — while the congestion-folded
//! shared-data counts (eqs. 11–12) derive from the same generalized
//! local-index/region-extent geometry the closed forms used. On the
//! four paper presets every entry is equal to the legacy closed forms
//! (pinned exhaustively by `tests/platform.rs` over 2x2–6x6 grids,
//! diagonal on and off), which is what keeps preset reports
//! bit-identical.
//!
//! All lookups are O(1) reads on the cost-model hot path; the §Perf
//! cache-invalidation rules are unaffected because tables are immutable
//! per platform (see DESIGN.md §Platform model).

use crate::topology::links::LinkGraph;
use crate::topology::{neighbour_offsets, LocalIdx, Pos};

use super::PlatformSpec;

/// Precomputed hop counts, indexed `[diagonal as usize][row-major pos]`.
#[derive(Debug, Clone)]
pub struct HopTables {
    /// Minimal route length from the serving attachment (eq. 10 and the
    /// energy model's travelled-path length).
    min_hops: [Vec<u32>; 2],
    /// Eq. 11 row-wise-shared loading hops (waiting slots folded in).
    row_shared: [Vec<u32>; 2],
    /// Eq. 12 column-wise-shared loading hops.
    col_shared: [Vec<u32>; 2],
    /// Eq. 8 entrance-link counts, `[diagonal as usize]`.
    entrance: [usize; 2],
}

impl HopTables {
    /// Build the tables for `spec` from link-graph routing plus the
    /// precomputed per-position geometry (`nearest` / `locals` /
    /// `extents`, all row-major).
    pub(crate) fn build(
        spec: &PlatformSpec,
        globals: &[Pos],
        global_mask: &[bool],
        nearest: &[Pos],
        locals: &[LocalIdx],
        extents: &[(usize, usize)],
    ) -> Result<HopTables, String> {
        let n = spec.xdim * spec.ydim;
        debug_assert_eq!(nearest.len(), n);
        let mut min_hops = [vec![0u32; n], vec![0u32; n]];
        let mut row_shared = [vec![0u32; n], vec![0u32; n]];
        let mut col_shared = [vec![0u32; n], vec![0u32; n]];
        let mut entrance = [0usize; 2];

        for (di, diagonal) in [false, true].into_iter().enumerate() {
            // Chiplet mesh only: minimal hops count NoP traversals from
            // the serving attachment chiplet (the off-chip link is the
            // separate serialized stage of the model).
            let graph = LinkGraph::mesh(
                spec.xdim,
                spec.ydim,
                diagonal,
                spec.bw_nop,
            );
            for (i, &l) in locals.iter().enumerate() {
                let p = Pos::new(i / spec.ydim, i % spec.ydim);
                let src = graph.chiplet_id(nearest[i]);
                let dst = graph.chiplet_id(p);
                let route = graph.route(src, dst).map_err(|e| {
                    format!(
                        "platform '{}': hop-table routing failed: {e:#}",
                        spec.name
                    )
                })?;
                min_hops[di][i] = route.len() as u32;
                // The deterministic router walks a minimal path, so the
                // measured length equals the geometric distance.
                debug_assert_eq!(
                    route.len(),
                    if diagonal { l.x.max(l.y) } else { l.x + l.y }
                );
                // Eqs. 11–12: congestion on the first column/row is
                // resolved farthest-first, adding (X - x) waiting slots:
                // total = X + y. With diagonal links (§5.1.1) the
                // alternative route costs (X - x) + max(x, y); the two
                // strategies use disjoint links, so take the min.
                let (xr, yr) = extents[i];
                let row_base = (xr + l.y) as u32;
                row_shared[di][i] = if diagonal {
                    row_base.min((xr - l.x + l.x.max(l.y)) as u32)
                } else {
                    row_base
                };
                let col_base = (yr + l.x) as u32;
                col_shared[di][i] = if diagonal {
                    col_base.min((yr - l.y + l.x.max(l.y)) as u32)
                } else {
                    col_base
                };
            }
            // Eq. 8: NoP links entering attachment chiplets from
            // non-attachment neighbours. Zero when every chiplet is an
            // attachment (collection is a no-op, e.g. 3D stacking).
            let mut count = 0;
            for g in globals {
                for &(dr, dc) in neighbour_offsets(diagonal) {
                    let nr = g.row as isize + dr;
                    let nc = g.col as isize + dc;
                    if nr < 0
                        || nc < 0
                        || nr >= spec.xdim as isize
                        || nc >= spec.ydim as isize
                    {
                        continue;
                    }
                    if !global_mask[nr as usize * spec.ydim + nc as usize] {
                        count += 1;
                    }
                }
            }
            entrance[di] = count;
        }
        Ok(HopTables { min_hops, row_shared, col_shared, entrance })
    }

    #[inline]
    pub fn min_hops(&self, idx: usize, diagonal: bool) -> usize {
        self.min_hops[diagonal as usize][idx] as usize
    }

    #[inline]
    pub fn row_shared(&self, idx: usize, diagonal: bool) -> usize {
        self.row_shared[diagonal as usize][idx] as usize
    }

    #[inline]
    pub fn col_shared(&self, idx: usize, diagonal: bool) -> usize {
        self.col_shared[diagonal as usize][idx] as usize
    }

    #[inline]
    pub fn entrance_links(&self, diagonal: bool) -> usize {
        self.entrance[diagonal as usize]
    }
}

#[cfg(test)]
mod tests {
    use crate::config::MemKind;
    use crate::platform::Platform;
    use crate::topology::Pos;

    #[test]
    fn tables_match_route_lengths_on_presets() {
        use crate::config::SystemType;
        for ty in SystemType::ALL {
            let plat = Platform::preset(ty, MemKind::Hbm, 4);
            for diagonal in [false, true] {
                let graph = plat.link_graph(diagonal);
                for p in plat.positions() {
                    let src = graph.chiplet_id(plat.nearest_global(p));
                    let dst = graph.chiplet_id(p);
                    let len = graph.route(src, dst).unwrap().len();
                    assert_eq!(
                        plat.hops_low_bw(p, diagonal),
                        len,
                        "{ty:?} {p:?} diagonal={diagonal}"
                    );
                }
            }
        }
    }

    #[test]
    fn shared_hops_dominate_min_hops() {
        // Waiting slots only ever add hops.
        let plat = Platform::headline();
        for diagonal in [false, true] {
            for p in plat.positions() {
                assert!(
                    plat.hops_row_shared(p, diagonal)
                        >= plat.hops_low_bw(p, diagonal)
                );
                assert!(
                    plat.hops_col_shared(p, diagonal)
                        >= plat.hops_low_bw(p, diagonal)
                );
            }
        }
    }

    #[test]
    fn corner_chiplet_is_free_everywhere() {
        let plat = Platform::headline();
        let origin = Pos::new(0, 0);
        assert_eq!(plat.hops_low_bw(origin, false), 0);
        assert_eq!(plat.hops_energy(origin, true), 0);
    }
}
