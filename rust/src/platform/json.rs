//! JSON codec for platform descriptions (`util::json` substrate; serde
//! is unavailable offline).
//!
//! Schema (see `examples/platforms/`):
//!
//! ```json
//! {
//!   "name": "asym-l-shape",
//!   "grid": {"xdim": 4, "ydim": 4},
//!   "systolic": {"r": 16, "c": 16},
//!   "links": {"nop_gbps": 60.0, "diagonal_gbps": 60.0,
//!             "offchip_gbps": 1000.0},
//!   "freq_ghz": 1.0,
//!   "bytes_per_elem": 1.0,
//!   "energy": {"nop_pj_bit_hop": 1.285, "sram_pj_bit": 0.28,
//!              "mac_pj_cycle": 4.6, "mem_pj_bit": 4.11},
//!   "attachments": [{"row": 0, "col": 0, "bw_gbps": 1000.0}]
//! }
//! ```
//!
//! Optional fields and their defaults: `links.diagonal_gbps` (=
//! `links.nop_gbps`), attachment `bw_gbps` (= an even share of
//! `links.offchip_gbps` over the attachments, like the presets),
//! `freq_ghz` (1.0), `bytes_per_elem` (1.0). Numbers round-trip
//! bit-exactly (shortest-representation f64 encoding), so save → load
//! reproduces an identical platform (pinned by `tests/properties.rs`).

use std::path::Path;

use crate::config::EnergyParams;
use crate::util::error::{Context, Error, Result};
use crate::util::json::{obj, Json};

use super::{MemAttachment, Platform, PlatformSpec};

fn req<'a>(v: &'a Json, key: &str) -> Result<&'a Json> {
    v.get(key)
        .with_context(|| format!("platform json: missing field '{key}'"))
}

fn req_f64(v: &Json, key: &str) -> Result<f64> {
    req(v, key)?
        .as_f64()
        .with_context(|| format!("platform json: '{key}' must be a number"))
}

fn req_usize(v: &Json, key: &str) -> Result<usize> {
    let n = req_f64(v, key)?;
    if n < 0.0 || n.fract() != 0.0 {
        return Err(Error::msg(format!(
            "platform json: '{key}' must be a non-negative integer, got {n}"
        )));
    }
    Ok(n as usize)
}

fn opt_f64(v: &Json, key: &str, default: f64) -> Result<f64> {
    match v.get(key) {
        None => Ok(default),
        Some(j) => j.as_f64().with_context(|| {
            format!("platform json: '{key}' must be a number")
        }),
    }
}

impl PlatformSpec {
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("name", Json::Str(self.name.clone())),
            (
                "grid",
                obj(vec![
                    ("xdim", Json::Num(self.xdim as f64)),
                    ("ydim", Json::Num(self.ydim as f64)),
                ]),
            ),
            (
                "systolic",
                obj(vec![
                    ("r", Json::Num(self.r as f64)),
                    ("c", Json::Num(self.c as f64)),
                ]),
            ),
            (
                "links",
                obj(vec![
                    ("nop_gbps", Json::Num(self.bw_nop)),
                    ("diagonal_gbps", Json::Num(self.bw_diag)),
                    ("offchip_gbps", Json::Num(self.bw_mem)),
                ]),
            ),
            ("freq_ghz", Json::Num(self.freq_ghz)),
            ("bytes_per_elem", Json::Num(self.bytes_per_elem)),
            (
                "energy",
                obj(vec![
                    (
                        "nop_pj_bit_hop",
                        Json::Num(self.energy.nop_pj_bit_hop),
                    ),
                    ("sram_pj_bit", Json::Num(self.energy.sram_pj_bit)),
                    ("mac_pj_cycle", Json::Num(self.energy.mac_pj_cycle)),
                    ("mem_pj_bit", Json::Num(self.mem_pj_bit)),
                ]),
            ),
            (
                "attachments",
                Json::Arr(
                    self.attachments
                        .iter()
                        .map(|a| {
                            obj(vec![
                                ("row", Json::Num(a.pos.row as f64)),
                                ("col", Json::Num(a.pos.col as f64)),
                                ("bw_gbps", Json::Num(a.bw)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    pub fn from_json(v: &Json) -> Result<PlatformSpec> {
        let name = req(v, "name")?
            .as_str()
            .context("platform json: 'name' must be a string")?
            .to_string();
        let grid = req(v, "grid")?;
        let systolic = req(v, "systolic")?;
        let links = req(v, "links")?;
        let energy = req(v, "energy")?;
        let bw_nop = req_f64(links, "nop_gbps")?;
        let bw_mem = req_f64(links, "offchip_gbps")?;
        let bw_diag = opt_f64(links, "diagonal_gbps", bw_nop)?;
        let attachments_json = req(v, "attachments")?
            .as_arr()
            .context("platform json: 'attachments' must be an array")?;
        // Default per-attachment bandwidth: an even share of the
        // aggregate, matching the preset semantics (the link graph then
        // offers exactly what the analytical model serializes at).
        let bw_share = bw_mem / attachments_json.len().max(1) as f64;
        let mut attachments = Vec::with_capacity(attachments_json.len());
        for (i, a) in attachments_json.iter().enumerate() {
            let row = req_usize(a, "row")
                .with_context(|| format!("attachment {i}"))?;
            let col = req_usize(a, "col")
                .with_context(|| format!("attachment {i}"))?;
            let bw = opt_f64(a, "bw_gbps", bw_share)
                .with_context(|| format!("attachment {i}"))?;
            attachments.push(MemAttachment::new(row, col, bw));
        }
        Ok(PlatformSpec {
            name,
            xdim: req_usize(grid, "xdim")?,
            ydim: req_usize(grid, "ydim")?,
            r: req_usize(systolic, "r")?,
            c: req_usize(systolic, "c")?,
            bw_nop,
            bw_diag,
            bw_mem,
            freq_ghz: opt_f64(v, "freq_ghz", 1.0)?,
            bytes_per_elem: opt_f64(v, "bytes_per_elem", 1.0)?,
            mem_pj_bit: req_f64(energy, "mem_pj_bit")?,
            energy: EnergyParams {
                nop_pj_bit_hop: req_f64(energy, "nop_pj_bit_hop")?,
                sram_pj_bit: req_f64(energy, "sram_pj_bit")?,
                mac_pj_cycle: req_f64(energy, "mac_pj_cycle")?,
            },
            attachments,
        })
    }
}

impl Platform {
    /// Serialize the declarative description (not the precomputes —
    /// they are rebuilt on load).
    pub fn to_json(&self) -> Json {
        self.spec().to_json()
    }

    /// Parse + validate + precompute from a JSON value.
    pub fn from_json(v: &Json) -> Result<Platform> {
        Platform::new(PlatformSpec::from_json(v)?).map_err(Error::msg)
    }

    /// Load a platform description file (the `--platform file.json` CLI
    /// path).
    pub fn load(path: &Path) -> Result<Platform> {
        let src = std::fs::read_to_string(path)
            .with_context(|| format!("reading platform file {path:?}"))?;
        let v = Json::parse(&src)
            .with_context(|| format!("parsing platform file {path:?}"))?;
        Platform::from_json(&v)
            .with_context(|| format!("loading platform file {path:?}"))
    }

    /// Save the description as canonical JSON (sorted keys, compact).
    pub fn save(&self, path: &Path) -> Result<()> {
        std::fs::write(path, self.to_json().encode() + "\n")
            .with_context(|| format!("writing platform file {path:?}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::MemKind;

    #[test]
    fn roundtrip_preserves_spec_exactly() {
        let plat = Platform::type_d(MemKind::Dram, 6);
        let encoded = plat.to_json().encode();
        let back = Platform::from_json(&Json::parse(&encoded).unwrap())
            .unwrap();
        assert_eq!(plat.spec(), back.spec());
    }

    #[test]
    fn defaults_fill_in() {
        let src = r#"{
            "name": "mini",
            "grid": {"xdim": 2, "ydim": 2},
            "systolic": {"r": 8, "c": 8},
            "links": {"nop_gbps": 60.0, "offchip_gbps": 200.0},
            "energy": {"nop_pj_bit_hop": 1.0, "sram_pj_bit": 0.2,
                       "mac_pj_cycle": 4.0, "mem_pj_bit": 5.0},
            "attachments": [{"row": 0, "col": 1}]
        }"#;
        let p = Platform::from_json(&Json::parse(src).unwrap()).unwrap();
        assert_eq!(p.bw_diag, 60.0);
        assert_eq!(p.freq_ghz, 1.0);
        assert_eq!(p.bytes_per_elem, 1.0);
        assert_eq!(p.attachments[0].bw, 200.0);
    }

    #[test]
    fn missing_fields_are_structured_errors() {
        let src = r#"{"name": "x"}"#;
        let err = Platform::from_json(&Json::parse(src).unwrap())
            .unwrap_err();
        assert!(format!("{err:#}").contains("grid"), "{err:#}");
    }

    #[test]
    fn invalid_specs_fail_validation_on_load() {
        let mut spec = Platform::headline().spec().clone();
        spec.attachments.clear();
        let encoded = spec.to_json().encode();
        let err = Platform::from_json(&Json::parse(&encoded).unwrap())
            .unwrap_err();
        assert!(
            format!("{err:#}").contains("attachment"),
            "{err:#}"
        );
    }
}
