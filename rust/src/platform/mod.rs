//! The [`Platform`] API: packaging as *data*.
//!
//! The paper's first contribution is a packaging-adaptive analytical
//! framework, but the original reproduction hard-coded packaging as a
//! closed `SystemType {A, B, C, D}` enum with per-type closed-form hop
//! formulas. This module replaces that with one declarative, validated
//! description — [`PlatformSpec`] — covering grid dims, per-class link
//! bandwidths (orthogonal NoP, §5.1 diagonal, off-chip), an *arbitrary*
//! memory-attachment set (any set of [`Pos`] with per-attachment
//! bandwidth, generalizing corner / edges / stacked / quadrant
//! placements), systolic dims, frequency, and the Table-2 energy
//! coefficients.
//!
//! [`Platform::new`] validates the spec and precomputes everything the
//! cost-model hot paths query per chiplet:
//!
//! * nearest attachment, local `(x, y)` index, and serving-region
//!   extents (Figure 4 generalized to any attachment set);
//! * [`HopTables`] — the eq. 9–12 / §5.1.1 hop counts, derived once
//!   from [`LinkGraph`] routing over the explicit link graph instead of
//!   per-type match arms, so cost-model hot paths stay O(1) lookups and
//!   arbitrary layouts get correct hops for free;
//! * the eq. 8 entrance-link counts.
//!
//! The four paper packagings are named presets ([`Platform::type_a`] …
//! [`Platform::type_d`]) whose reports are bit-identical to the legacy
//! `SystemType` runs (pinned by `tests/platform.rs`).
//! [`crate::config::HwConfig`] and `SystemType` survive only as thin
//! constructors onto `Platform`. JSON descriptions load and save
//! through [`json`] (`mcmcomm optimize --platform file.json`; examples
//! under `examples/platforms/`).

pub mod hops;
pub mod json;

use std::ops::Deref;
use std::sync::{Arc, OnceLock};

use crate::config::{EnergyParams, HwConfig, MemKind, SystemType};
use crate::topology::links::LinkGraph;
use crate::topology::{grid_positions, manhattan, LocalIdx, Pos};

pub use hops::HopTables;

/// One off-chip memory attachment point: the chiplet it is wired to and
/// the bandwidth of that individual interface link (GB/s). The
/// *aggregate* serialized memory bandwidth of the package is
/// [`PlatformSpec::bw_mem`]; per-attachment bandwidths feed the link
/// graph capacities (netsim / congestion studies).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MemAttachment {
    pub pos: Pos,
    pub bw: f64,
}

impl MemAttachment {
    pub fn new(row: usize, col: usize, bw: f64) -> Self {
        MemAttachment { pos: Pos::new(row, col), bw }
    }
}

/// The declarative platform description. Every field is plain data; no
/// packaging enum — the attachment set *is* the packaging.
#[derive(Debug, Clone, PartialEq)]
pub struct PlatformSpec {
    /// Short label, e.g. `A-HBM-4x4` for presets (figure-table "system"
    /// column) or a free-form name for JSON platforms.
    pub name: String,
    /// Chiplet grid rows (X) and columns (Y).
    pub xdim: usize,
    pub ydim: usize,
    /// Systolic array rows (R) and columns (C) per chiplet.
    pub r: usize,
    pub c: usize,
    /// Orthogonal NoP link bandwidth, GB/s (Table 2: 60).
    pub bw_nop: f64,
    /// §5.1 diagonal link bandwidth, GB/s — the capacity of the
    /// diagonal link class in the explicit link graph
    /// ([`Platform::link_graph`], netsim). The closed-form analytical
    /// model (eqs. 9–12) folds diagonal shortcuts into *hop counts* and
    /// charges all NoP traffic at [`PlatformSpec::bw_nop`], so keep
    /// `bw_diag == bw_nop` (the preset value) when analytical and
    /// simulated numbers must agree.
    pub bw_diag: f64,
    /// Aggregate off-chip (memory interface) bandwidth, GB/s — the
    /// paper's `BW_mem` that serializes every off-chip transfer.
    pub bw_mem: f64,
    /// Chiplet clock in GHz; converts eq. 7 cycles to ns.
    pub freq_ghz: f64,
    /// Datapath element width in bytes (int8 inference default).
    pub bytes_per_elem: f64,
    /// Off-chip transfer energy, pJ per bit (Table 2 per memory kind).
    pub mem_pj_bit: f64,
    /// NoP / SRAM / MAC energy coefficients (Table 2).
    pub energy: EnergyParams,
    /// Memory attachment set — any non-empty set of in-bounds grid
    /// positions. The chiplets listed here are the "global chiplets" of
    /// the paper.
    pub attachments: Vec<MemAttachment>,
}

impl PlatformSpec {
    pub fn num_chiplets(&self) -> usize {
        self.xdim * self.ydim
    }

    /// Element count -> bytes.
    pub fn bytes(&self, elems: usize) -> f64 {
        elems as f64 * self.bytes_per_elem
    }

    /// Cycle count -> nanoseconds.
    pub fn cycles_to_ns(&self, cycles: f64) -> f64 {
        cycles / self.freq_ghz
    }

    /// Largest accepted chiplet count. The hop tables and link graph
    /// are O(grid²) in memory; this cap keeps a malformed JSON
    /// description a structured error instead of an allocation abort
    /// (paper-scale grids are <= 16x16).
    pub const MAX_CHIPLETS: usize = 64 * 64;

    /// Structural validation; [`Platform::new`] calls this before any
    /// precomputation.
    pub fn validate(&self) -> Result<(), String> {
        if self.xdim == 0 || self.ydim == 0 {
            return Err(format!(
                "platform '{}': grid dims must be positive",
                self.name
            ));
        }
        if self
            .xdim
            .checked_mul(self.ydim)
            .is_none_or(|n| n > Self::MAX_CHIPLETS)
        {
            return Err(format!(
                "platform '{}': grid {}x{} exceeds the {}-chiplet limit",
                self.name,
                self.xdim,
                self.ydim,
                Self::MAX_CHIPLETS
            ));
        }
        if self.r == 0 || self.c == 0 {
            return Err(format!(
                "platform '{}': systolic dims must be positive",
                self.name
            ));
        }
        let pos_finite = |v: f64| v > 0.0 && v.is_finite();
        if !(pos_finite(self.bw_nop)
            && pos_finite(self.bw_diag)
            && pos_finite(self.bw_mem)
            && pos_finite(self.freq_ghz)
            && pos_finite(self.bytes_per_elem))
        {
            return Err(format!(
                "platform '{}': bandwidths, frequency and element width \
                 must be positive and finite",
                self.name
            ));
        }
        let coeff_ok = |v: f64| v.is_finite() && v >= 0.0;
        if !(coeff_ok(self.mem_pj_bit)
            && coeff_ok(self.energy.nop_pj_bit_hop)
            && coeff_ok(self.energy.sram_pj_bit)
            && coeff_ok(self.energy.mac_pj_cycle))
        {
            return Err(format!(
                "platform '{}': energy coefficients must be finite and \
                 non-negative",
                self.name
            ));
        }
        if self.attachments.is_empty() {
            return Err(format!(
                "platform '{}': needs at least one memory attachment",
                self.name
            ));
        }
        for (i, a) in self.attachments.iter().enumerate() {
            if a.pos.row >= self.xdim || a.pos.col >= self.ydim {
                return Err(format!(
                    "platform '{}': attachment {i} at ({}, {}) outside \
                     the {}x{} grid",
                    self.name, a.pos.row, a.pos.col, self.xdim, self.ydim
                ));
            }
            if !pos_finite(a.bw) {
                return Err(format!(
                    "platform '{}': attachment {i} bandwidth must be \
                     positive and finite",
                    self.name
                ));
            }
        }
        for (i, a) in self.attachments.iter().enumerate() {
            for b in &self.attachments[i + 1..] {
                if a.pos == b.pos {
                    return Err(format!(
                        "platform '{}': duplicate attachment at ({}, {})",
                        self.name, a.pos.row, a.pos.col
                    ));
                }
            }
        }
        Ok(())
    }
}

/// A validated platform with every topology-derived quantity the cost
/// model's per-chiplet loops query precomputed at construction (GA
/// fitness is the hottest path in the repo, §Perf).
///
/// `Platform` derefs to its [`PlatformSpec`], so scalar fields read
/// exactly like the old `HwConfig` did (`plat.bw_nop`, `plat.xdim`,
/// `plat.bytes(..)`) — that, plus hop accessors replicating the old
/// `Topology` API bit-for-bit on presets, is what keeps preset reports
/// identical to the pre-platform code.
#[derive(Debug, Clone)]
pub struct Platform {
    spec: PlatformSpec,
    /// Attachment positions in declaration order (the paper's "global
    /// chiplets").
    globals: Vec<Pos>,
    /// Per position (row-major): is this an attachment chiplet?
    global_mask: Vec<bool>,
    /// Per position: nearest attachment chiplet (Manhattan metric, ties
    /// broken toward the smaller position for determinism).
    nearest: Vec<Pos>,
    /// Per position: local (x, y) index.
    locals: Vec<LocalIdx>,
    /// Per position: serving region extent (X, Y).
    extents: Vec<(usize, usize)>,
    hops: HopTables,
    /// Lazily-built shared link graphs, one per diagonal setting
    /// ([`Platform::link_graph_shared`]). A spec is immutable once the
    /// platform is constructed, so these can never go stale; cloning the
    /// platform clones the (cheap) `Arc` handles.
    graph_plain: OnceLock<Arc<LinkGraph>>,
    graph_diag: OnceLock<Arc<LinkGraph>>,
}

impl Deref for Platform {
    type Target = PlatformSpec;

    fn deref(&self) -> &PlatformSpec {
        &self.spec
    }
}

impl Platform {
    /// Validate `spec` and precompute nearest attachments, local
    /// indices, region extents and the routing-derived [`HopTables`].
    pub fn new(spec: PlatformSpec) -> Result<Platform, String> {
        spec.validate()?;
        let n = spec.num_chiplets();
        let globals: Vec<Pos> =
            spec.attachments.iter().map(|a| a.pos).collect();
        let mut global_mask = vec![false; n];
        for g in &globals {
            global_mask[g.row * spec.ydim + g.col] = true;
        }
        let mut nearest = Vec::with_capacity(n);
        let mut locals = Vec::with_capacity(n);
        for p in grid_positions(spec.xdim, spec.ydim) {
            let g = *globals
                .iter()
                .min_by_key(|g| (manhattan(p, **g), (g.row, g.col)))
                .expect("validated: at least one attachment");
            nearest.push(g);
            locals.push(LocalIdx {
                x: p.row.abs_diff(g.row),
                y: p.col.abs_diff(g.col),
            });
        }
        // Region extents per serving attachment, then scatter per
        // position.
        use std::collections::HashMap;
        let mut per_global: HashMap<Pos, (usize, usize)> = HashMap::new();
        for i in 0..n {
            let g = nearest[i];
            let l = locals[i];
            let e = per_global.entry(g).or_insert((0, 0));
            e.0 = e.0.max(l.x);
            e.1 = e.1.max(l.y);
        }
        let extents: Vec<(usize, usize)> = (0..n)
            .map(|i| {
                let (mx, my) = per_global[&nearest[i]];
                (mx + 1, my + 1)
            })
            .collect();
        let hops = HopTables::build(
            &spec,
            &globals,
            &global_mask,
            &nearest,
            &locals,
            &extents,
        )?;
        Ok(Platform {
            spec,
            globals,
            global_mask,
            nearest,
            locals,
            extents,
            hops,
            graph_plain: OnceLock::new(),
            graph_diag: OnceLock::new(),
        })
    }

    /// The declarative description this platform was built from.
    pub fn spec(&self) -> &PlatformSpec {
        &self.spec
    }

    /// The precomputed hop tables.
    pub fn hop_tables(&self) -> &HopTables {
        &self.hops
    }

    /// Stable content fingerprint of the packaging description (the
    /// serving layer's plan-cache key component). Hashes the canonical
    /// JSON encoding of the spec — sorted keys, every field that can
    /// change a cost-model answer — with FNV-1a, so two platforms
    /// fingerprint identically iff their descriptions are identical
    /// (the name included: presets are distinguishable even when their
    /// numbers coincide).
    pub fn fingerprint(&self) -> u64 {
        crate::util::hash::fnv1a_64(self.spec.to_json().encode().as_bytes())
    }

    // ---- presets (the four paper packagings + headline) ----------------

    /// Table-2 preset: 16x16 PE chiplets, 60 GB/s NoP, chosen square
    /// grid, packaging type and memory kind. Bit-identical reports to
    /// the legacy `HwConfig::paper` + `Topology` pair.
    pub fn preset(ty: SystemType, mem: MemKind, grid: usize) -> Platform {
        Self::preset_grid(ty, mem, grid, grid)
    }

    /// [`Platform::preset`] with a rectangular grid.
    pub fn preset_grid(
        ty: SystemType,
        mem: MemKind,
        xdim: usize,
        ydim: usize,
    ) -> Platform {
        Self::try_preset_grid(ty, mem, xdim, ydim)
            .expect("paper presets are always valid")
    }

    /// Fallible preset constructor (zero grids etc. report instead of
    /// panicking).
    pub fn try_preset_grid(
        ty: SystemType,
        mem: MemKind,
        xdim: usize,
        ydim: usize,
    ) -> Result<Platform, String> {
        let bw_mem = mem.bandwidth_gbps();
        Platform::new(PlatformSpec {
            name: format!("{}-{}-{}x{}", ty.short(), mem.name(), xdim, ydim),
            xdim,
            ydim,
            r: 16,
            c: 16,
            bw_nop: 60.0,
            bw_diag: 60.0,
            bw_mem,
            freq_ghz: 1.0,
            bytes_per_elem: 1.0,
            mem_pj_bit: mem.energy_pj_per_bit(),
            energy: EnergyParams::default(),
            attachments: preset_attachments(ty, xdim, ydim, bw_mem),
        })
    }

    /// 2.5D, memory at one corner (SIMBA, Manticore).
    pub fn type_a(mem: MemKind, grid: usize) -> Platform {
        Self::preset(SystemType::A, mem, grid)
    }

    /// 2.5D, memory along two opposite edges (MTIA).
    pub fn type_b(mem: MemKind, grid: usize) -> Platform {
        Self::preset(SystemType::B, mem, grid)
    }

    /// 3D, memory stacked on every chiplet.
    pub fn type_c(mem: MemKind, grid: usize) -> Platform {
        Self::preset(SystemType::C, mem, grid)
    }

    /// 2.5D + 3D mix, stacks over the quadrant centers (Chiplet-Gym).
    pub fn type_d(mem: MemKind, grid: usize) -> Platform {
        Self::preset(SystemType::D, mem, grid)
    }

    /// The paper's headline evaluation point: 4x4 type-A HBM.
    pub fn headline() -> Platform {
        Self::type_a(MemKind::Hbm, 4)
    }

    /// Expand a legacy [`HwConfig`] description (thin-constructor path).
    /// Panics on invalid configs; use [`Platform::try_from_hw`] (or
    /// [`HwConfig::platform`]) where the config is untrusted.
    pub fn from_hw(hw: &HwConfig) -> Platform {
        Self::try_from_hw(hw).expect("invalid HwConfig")
    }

    pub fn try_from_hw(hw: &HwConfig) -> Result<Platform, String> {
        hw.validate()?;
        Platform::new(PlatformSpec {
            name: format!(
                "{}-{}-{}x{}",
                hw.ty.short(),
                hw.mem.name(),
                hw.xdim,
                hw.ydim
            ),
            xdim: hw.xdim,
            ydim: hw.ydim,
            r: hw.r,
            c: hw.c,
            bw_nop: hw.bw_nop,
            bw_diag: hw.bw_nop,
            bw_mem: hw.bw_mem,
            freq_ghz: hw.freq_ghz,
            bytes_per_elem: hw.bytes_per_elem,
            mem_pj_bit: hw.mem.energy_pj_per_bit(),
            energy: hw.energy,
            attachments: preset_attachments(
                hw.ty, hw.xdim, hw.ydim, hw.bw_mem,
            ),
        })
    }

    // ---- topology queries (all O(1), precomputed) ----------------------

    #[inline]
    fn idx(&self, p: Pos) -> usize {
        p.row * self.spec.ydim + p.col
    }

    /// All grid positions, row-major.
    pub fn positions(&self) -> impl Iterator<Item = Pos> + '_ {
        grid_positions(self.spec.xdim, self.spec.ydim)
    }

    /// Attachment chiplets (wired to main memory) — the paper's "global
    /// chiplets" — in declaration order.
    pub fn globals(&self) -> &[Pos] {
        &self.globals
    }

    /// O(1): is this chiplet wired to memory?
    #[inline]
    pub fn is_global(&self, p: Pos) -> bool {
        self.global_mask[self.idx(p)]
    }

    /// The closest attachment chiplet (paper: "each chiplet will only
    /// communicate with the closest global chiplet"); Manhattan metric,
    /// ties broken toward the smaller position for determinism.
    #[inline]
    pub fn nearest_global(&self, p: Pos) -> Pos {
        self.nearest[self.idx(p)]
    }

    /// The paper's local index `(x, y)` for a chiplet.
    #[inline]
    pub fn local_index(&self, p: Pos) -> LocalIdx {
        self.locals[self.idx(p)]
    }

    /// Manhattan distance to the serving attachment (SIMBA's
    /// partitioning key; §3.1).
    pub fn distance_to_memory(&self, p: Pos) -> usize {
        let l = self.local_index(p);
        l.x + l.y
    }

    /// Extent (X, Y) of the serving region of `p`'s attachment: the
    /// dims that enter the waiting-hop terms of eqs. 11–12.
    #[inline]
    pub fn region_extent(&self, p: Pos) -> (usize, usize) {
        self.extents[self.idx(p)]
    }

    /// Number of NoP links that enter the attachment chiplet(s) from
    /// non-attachment neighbours — the "bandwidth to entrances"
    /// multiplier of eq. 8 (0 when every chiplet is an attachment:
    /// collection is a no-op). Diagonal links add the diagonal
    /// neighbours (§5.1).
    #[inline]
    pub fn entrance_links(&self, diagonal: bool) -> usize {
        self.hops.entrance_links(diagonal)
    }

    // ---- hop lookups (§4.3.3, §5.1.1) — O(1) table reads ---------------

    /// Eq. 10 — low off-chip BW: links drain faster than memory feeds
    /// them, no contention, minimal path. Precomputed from the actual
    /// [`LinkGraph`] route length.
    #[inline]
    pub fn hops_low_bw(&self, p: Pos, diagonal: bool) -> usize {
        self.hops.min_hops(self.idx(p), diagonal)
    }

    /// Eq. 11 — high BW, row-wise-shared data: waiting hops folded in;
    /// with diagonal links the alternative §5.1.1 route is taken when
    /// cheaper.
    #[inline]
    pub fn hops_row_shared(&self, p: Pos, diagonal: bool) -> usize {
        self.hops.row_shared(self.idx(p), diagonal)
    }

    /// Eq. 12 — high BW, column-wise-shared data: symmetric to eq. 11.
    #[inline]
    pub fn hops_col_shared(&self, p: Pos, diagonal: bool) -> usize {
        self.hops.col_shared(self.idx(p), diagonal)
    }

    /// Hop count used by the on-chip energy model (§4.4.3): actual path
    /// length travelled, i.e. the minimal route.
    #[inline]
    pub fn hops_energy(&self, p: Pos, diagonal: bool) -> usize {
        self.hops.min_hops(self.idx(p), diagonal)
    }

    /// Materialize the explicit link graph of this platform: the chiplet
    /// mesh (with diagonals when `diagonal`, at [`PlatformSpec::bw_diag`])
    /// plus one memory node per attachment at its own bandwidth. The
    /// netsim congestion studies run on this.
    pub fn link_graph(&self, diagonal: bool) -> LinkGraph {
        let mut g = LinkGraph::mesh_classes(
            self.spec.xdim,
            self.spec.ydim,
            self.spec.bw_nop,
            if diagonal { Some(self.spec.bw_diag) } else { None },
        );
        for a in &self.spec.attachments {
            g.attach_memory(a.pos, a.bw);
        }
        g
    }

    /// Shared, lazily-built form of [`Platform::link_graph`]: the graph
    /// is constructed at most once per diagonal setting for this
    /// platform's lifetime and handed out as an `Arc`. Plan-lowering
    /// hot paths (the DES, `netsim::IncrementalSim`) use this so a
    /// 20×20 mesh is not rebuilt per candidate; the spec is immutable,
    /// so the cached graph can never go stale (DESIGN.md §Optimizer
    /// scale-out). Immutability also lets the DES scratch state
    /// (`SimScratch`/`MaxMinScratch`) size its per-link buffers once
    /// per run and reuse them allocation-free across runs on the same
    /// graph (DESIGN.md §DES performance architecture).
    pub fn link_graph_shared(&self, diagonal: bool) -> Arc<LinkGraph> {
        let slot =
            if diagonal { &self.graph_diag } else { &self.graph_plain };
        slot.get_or_init(|| Arc::new(self.link_graph(diagonal))).clone()
    }
}

/// The attachment set of one paper packaging type (Figure 2 / §4.1) —
/// every preset and the [`HwConfig`] thin-constructor path share this
/// placement code, and the LP half-grid construction (`eval::lp`)
/// reuses it for its virtual stages.
///
/// `bw_total` is the platform's *aggregate* off-chip bandwidth
/// ([`PlatformSpec::bw_mem`]); it is split evenly over the placed
/// attachments so the explicit link graph (netsim) offers exactly the
/// aggregate the analytical model serializes at — the two models stay
/// consistent for every preset, whatever the attachment count.
pub fn preset_attachments(
    ty: SystemType,
    xdim: usize,
    ydim: usize,
    bw_total: f64,
) -> Vec<MemAttachment> {
    let positions: Vec<Pos> = match ty {
        // Corner memory: single entry point at (0, 0).
        SystemType::A => vec![Pos::new(0, 0)],
        // Edge memory: first and last column are attachments (each row
        // has an entrance on both sides). Degenerates to one column for
        // ydim == 1.
        SystemType::B => {
            let mut g: Vec<Pos> = (0..xdim).map(|r| Pos::new(r, 0)).collect();
            if ydim > 1 {
                g.extend((0..xdim).map(|r| Pos::new(r, ydim - 1)));
            }
            g
        }
        // 3D stacked: every chiplet has its own memory interface.
        SystemType::C => grid_positions(xdim, ydim).collect(),
        // Mixed 2.5D+3D: four stacks over the quadrant centers.
        SystemType::D => {
            let qr = [(xdim - 1) / 2, xdim / 2];
            let qc = [(ydim - 1) / 2, ydim / 2];
            let mut g = vec![
                Pos::new(qr[0], qc[0]),
                Pos::new(qr[0], qc[1]),
                Pos::new(qr[1], qc[0]),
                Pos::new(qr[1], qc[1]),
            ];
            g.sort();
            g.dedup();
            g
        }
    };
    let bw = bw_total / positions.len() as f64;
    positions
        .into_iter()
        .map(|pos| MemAttachment { pos, bw })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn type_a_single_corner_global() {
        let t = Platform::type_a(MemKind::Hbm, 4);
        assert_eq!(t.globals(), &[Pos::new(0, 0)]);
        assert_eq!(t.local_index(Pos::new(3, 2)), LocalIdx { x: 3, y: 2 });
        assert_eq!(t.region_extent(Pos::new(1, 1)), (4, 4));
        assert_eq!(t.name, "A-HBM-4x4");
        assert_eq!(t.bw_mem, 1000.0);
    }

    #[test]
    fn type_b_edge_globals() {
        let t = Platform::type_b(MemKind::Hbm, 4);
        assert_eq!(t.globals().len(), 8);
        // Interior chiplet is served by the nearest edge, same row.
        let l = t.local_index(Pos::new(2, 1));
        assert_eq!((l.x, l.y), (0, 1));
        // Region extent spans half the row.
        let (xr, yr) = t.region_extent(Pos::new(2, 1));
        assert_eq!(xr, 1);
        assert!(yr >= 2);
    }

    #[test]
    fn type_c_all_global_zero_distance() {
        let t = Platform::type_c(MemKind::Hbm, 4);
        assert_eq!(t.globals().len(), 16);
        for p in t.positions() {
            assert_eq!(t.distance_to_memory(p), 0);
            assert_eq!(t.hops_low_bw(p, false), 0);
        }
        assert_eq!(t.entrance_links(false), 0);
    }

    #[test]
    fn type_d_quadrant_centers_near_uniform() {
        let t = Platform::type_d(MemKind::Hbm, 4);
        assert_eq!(t.globals().len(), 4);
        let max_d = t
            .positions()
            .map(|p| t.distance_to_memory(p))
            .max()
            .unwrap();
        assert!(max_d <= 2, "type D should be near-uniform, max={max_d}");
    }

    #[test]
    fn eq8_entrance_links_type_a() {
        let t = Platform::type_a(MemKind::Hbm, 4);
        // Corner global: 2 mesh links; +1 diagonal = 3 (the paper's "50%
        // more bandwidth on the bottleneck").
        assert_eq!(t.entrance_links(false), 2);
        assert_eq!(t.entrance_links(true), 3);
    }

    #[test]
    fn eq10_low_bw_hops() {
        let t = Platform::type_a(MemKind::Hbm, 5);
        assert_eq!(t.hops_low_bw(Pos::new(3, 2), false), 5);
        assert_eq!(t.hops_low_bw(Pos::new(3, 2), true), 3);
        assert_eq!(t.hops_low_bw(Pos::new(0, 0), false), 0);
    }

    #[test]
    fn eq11_row_shared_hops_and_diagonal() {
        let t = Platform::type_a(MemKind::Hbm, 5);
        let p = Pos::new(3, 2);
        // eq. 11: X + y = 5 + 2 = 7.
        assert_eq!(t.hops_row_shared(p, false), 7);
        // §5.1.1: (X - x) + max(x, y) = 2 + 3 = 5; min(7, 5) = 5.
        assert_eq!(t.hops_row_shared(p, true), 5);
    }

    #[test]
    fn eq12_col_shared_symmetric() {
        let t = Platform::type_a(MemKind::Hbm, 5);
        let p = Pos::new(2, 3);
        assert_eq!(t.hops_col_shared(p, false), 5 + 2);
        assert_eq!(t.hops_col_shared(p, true), (5 - 3 + 3).min(7));
    }

    #[test]
    fn diagonal_never_worse() {
        for ty in SystemType::ALL {
            let t = Platform::preset(ty, MemKind::Hbm, 5);
            for p in t.positions() {
                assert!(
                    t.hops_row_shared(p, true) <= t.hops_row_shared(p, false)
                );
                assert!(
                    t.hops_col_shared(p, true) <= t.hops_col_shared(p, false)
                );
                assert!(t.hops_energy(p, true) <= t.hops_energy(p, false));
            }
        }
    }

    #[test]
    fn nearest_global_is_actually_nearest() {
        for ty in SystemType::ALL {
            let t = Platform::preset_grid(ty, MemKind::Hbm, 6, 5);
            for p in t.positions() {
                let g = t.nearest_global(p);
                let d = manhattan(p, g);
                for other in t.globals() {
                    assert!(d <= manhattan(p, *other));
                }
            }
        }
    }

    #[test]
    fn from_hw_matches_preset() {
        let hw = HwConfig::paper(SystemType::B, MemKind::Dram, 4);
        let a = Platform::from_hw(&hw);
        let b = Platform::type_b(MemKind::Dram, 4);
        assert_eq!(a.spec(), b.spec());
    }

    #[test]
    fn validate_rejects_malformed_specs() {
        let ok = Platform::headline().spec().clone();
        assert!(ok.validate().is_ok());
        let mut s = ok.clone();
        s.xdim = 0;
        assert!(s.validate().unwrap_err().contains("grid"));
        let mut s = ok.clone();
        s.bw_mem = f64::NEG_INFINITY;
        assert!(s.validate().is_err());
        let mut s = ok.clone();
        s.attachments.clear();
        assert!(s.validate().unwrap_err().contains("attachment"));
        let mut s = ok.clone();
        s.attachments = vec![MemAttachment::new(9, 9, 1000.0)];
        assert!(s.validate().unwrap_err().contains("outside"));
        let mut s = ok.clone();
        s.attachments =
            vec![MemAttachment::new(0, 0, 1.0), MemAttachment::new(0, 0, 2.0)];
        assert!(s.validate().unwrap_err().contains("duplicate"));
        let mut s = ok;
        s.energy.mac_pj_cycle = f64::NAN;
        assert!(s.validate().is_err());
    }

    #[test]
    fn asymmetric_attachments_are_first_class() {
        // An L-shaped attachment set no SystemType can express.
        let mut spec = Platform::headline().spec().clone();
        spec.name = "asym-L".into();
        spec.attachments = vec![
            MemAttachment::new(0, 0, 500.0),
            MemAttachment::new(0, 3, 250.0),
            MemAttachment::new(3, 0, 250.0),
        ];
        let p = Platform::new(spec).unwrap();
        assert_eq!(p.globals().len(), 3);
        // (3, 3) is served by one of the arm tips, 3 hops away.
        assert_eq!(p.distance_to_memory(Pos::new(3, 3)), 3);
        // Entrances: each of the three corner attachments has exactly
        // two in-grid orthogonal neighbours, none of them attachments.
        assert_eq!(p.entrance_links(false), 2 + 2 + 2);
        for pos in p.positions() {
            let g = p.nearest_global(pos);
            assert_eq!(
                p.hops_low_bw(pos, false),
                manhattan(pos, g),
                "{pos:?}"
            );
        }
    }

    #[test]
    fn link_graph_carries_attachment_bandwidths() {
        let plat = Platform::type_b(MemKind::Hbm, 3);
        let g = plat.link_graph(false);
        // 9 chiplets + 6 memory nodes (two edge columns x 3 rows).
        assert_eq!(g.nodes.len(), 9 + 6);
        let mem_links: Vec<f64> = g
            .links
            .iter()
            .filter(|l| l.from >= 9)
            .map(|l| l.capacity)
            .collect();
        assert_eq!(mem_links.len(), 6);
        // The aggregate bw_mem is split evenly over the attachments, so
        // netsim offers exactly what the analytical model serializes.
        assert!(mem_links.iter().all(|&c| c == 1000.0 / 6.0));
        let sum: f64 = mem_links.iter().sum();
        assert!((sum - plat.bw_mem).abs() < 1e-9);
    }

    #[test]
    fn shared_link_graph_is_built_once_and_matches() {
        let plat = Platform::type_b(MemKind::Hbm, 4);
        for diagonal in [false, true] {
            let a = plat.link_graph_shared(diagonal);
            let b = plat.link_graph_shared(diagonal);
            assert!(std::sync::Arc::ptr_eq(&a, &b), "built once");
            let fresh = plat.link_graph(diagonal);
            assert_eq!(a.nodes.len(), fresh.nodes.len());
            assert_eq!(a.links.len(), fresh.links.len());
            assert_eq!(a.diagonal, fresh.diagonal);
            for (x, y) in a.links.iter().zip(&fresh.links) {
                assert_eq!((x.from, x.to), (y.from, y.to));
                assert_eq!(x.capacity, y.capacity);
            }
        }
        // The two diagonal settings are distinct graphs.
        assert_ne!(
            plat.link_graph_shared(false).links.len(),
            plat.link_graph_shared(true).links.len()
        );
    }

    #[test]
    fn validate_caps_grid_size() {
        let mut s = Platform::headline().spec().clone();
        s.xdim = 1 << 20;
        s.ydim = 1 << 20;
        assert!(s.validate().unwrap_err().contains("limit"));
        let mut s = Platform::headline().spec().clone();
        s.xdim = PlatformSpec::MAX_CHIPLETS + 1;
        s.ydim = 1;
        assert!(s.validate().is_err());
    }
}
