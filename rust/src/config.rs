//! Hardware configuration: the `HW` tuple of paper §4.2.1 plus the
//! Table 2 constants.
//!
//! Units used throughout the cost model:
//!   * time   — nanoseconds (f64). The chiplet clock defaults to 1 GHz so
//!     1 compute cycle == 1 ns, matching the paper's cycle-accurate eqs.
//!   * data   — bytes (f64); `bytes_per_elem` converts GEMM elements
//!     (int8 edge-NPU datapath by default, per SIMBA/MTIA practice).
//!   * BW     — GB/s, which is numerically bytes/ns, so `bytes / bw`
//!     yields ns directly.
//!   * energy — picojoules (f64).

/// Packaging type (paper Figure 2 / §4.1): where main memory sits
/// relative to the chiplet grid.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum SystemType {
    /// 2.5D, memory at one corner (SIMBA, Manticore): a single global
    /// chiplet at grid position (0, 0).
    A,
    /// 2.5D, memory distributed along two opposite edges (MTIA): every
    /// chiplet in the first and last grid column is a global chiplet.
    B,
    /// 3D, memory stacked on top of logic: every chiplet is global.
    C,
    /// 2.5D + 3D mix (Chiplet-Gym): memory stacks over the quadrant
    /// centers — four interior global chiplets, near-uniform distance.
    D,
}

impl SystemType {
    pub const ALL: [SystemType; 4] =
        [SystemType::A, SystemType::B, SystemType::C, SystemType::D];

    pub fn name(self) -> &'static str {
        match self {
            SystemType::A => "type-A (corner, 2.5D)",
            SystemType::B => "type-B (edges, 2.5D)",
            SystemType::C => "type-C (stacked, 3D)",
            SystemType::D => "type-D (mixed, 2.5D+3D)",
        }
    }

    pub fn short(self) -> &'static str {
        match self {
            SystemType::A => "A",
            SystemType::B => "B",
            SystemType::C => "C",
            SystemType::D => "D",
        }
    }
}

/// Off-chip memory technology (Table 2 bandwidth/energy points).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum MemKind {
    /// 60 GB/s, 14.8 pJ/bit — the "low bandwidth" case (§4.3.3 case 1).
    Dram,
    /// 1000 GB/s, 4.11 pJ/bit — the "high bandwidth" case (case 2).
    Hbm,
}

impl MemKind {
    pub fn bandwidth_gbps(self) -> f64 {
        match self {
            MemKind::Dram => 60.0,
            MemKind::Hbm => 1000.0,
        }
    }

    pub fn energy_pj_per_bit(self) -> f64 {
        match self {
            MemKind::Dram => 14.8,
            MemKind::Hbm => 4.11,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            MemKind::Dram => "DRAM",
            MemKind::Hbm => "HBM",
        }
    }
}

/// Energy coefficients (Table 2).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct EnergyParams {
    /// NoP link energy, pJ per bit per hop.
    pub nop_pj_bit_hop: f64,
    /// SRAM read/write energy, pJ per bit.
    pub sram_pj_bit: f64,
    /// MAC energy, pJ per PE per cycle.
    pub mac_pj_cycle: f64,
}

impl Default for EnergyParams {
    fn default() -> Self {
        EnergyParams {
            nop_pj_bit_hop: 1.285,
            sram_pj_bit: 0.28,
            mac_pj_cycle: 4.6,
        }
    }
}

/// The paper's hardware tuple `HW = {BW_nop, BW_mem, X, Y, R, C, type}`
/// (§4.2.1) plus modeling constants.
///
/// Since the platform redesign this type survives only as a thin,
/// ergonomic *constructor* onto [`crate::platform::Platform`] — the
/// engine, cost stack, and optimizers all consume `Platform` (which
/// describes packaging as data: attachment sets + link classes +
/// precomputed hop tables) rather than matching on [`SystemType`].
#[derive(Debug, Clone)]
pub struct HwConfig {
    pub ty: SystemType,
    pub mem: MemKind,
    /// Chiplet grid rows (X) and columns (Y).
    pub xdim: usize,
    pub ydim: usize,
    /// Systolic array rows (R) and columns (C) per chiplet.
    pub r: usize,
    pub c: usize,
    /// NoP link bandwidth, GB/s (Table 2: 60).
    pub bw_nop: f64,
    /// Off-chip (global chiplet <-> memory) bandwidth, GB/s.
    pub bw_mem: f64,
    /// Chiplet clock in GHz; converts eq. 7 cycles to ns.
    pub freq_ghz: f64,
    /// Datapath element width in bytes (int8 inference default).
    pub bytes_per_elem: f64,
    pub energy: EnergyParams,
}

impl HwConfig {
    /// Table 2 system: 16x16 PE chiplets, 60 GB/s NoP, chosen grid,
    /// packaging type and memory kind.
    pub fn paper(ty: SystemType, mem: MemKind, grid: usize) -> Self {
        HwConfig {
            ty,
            mem,
            xdim: grid,
            ydim: grid,
            r: 16,
            c: 16,
            bw_nop: 60.0,
            bw_mem: mem.bandwidth_gbps(),
            freq_ghz: 1.0,
            bytes_per_elem: 1.0,
            energy: EnergyParams::default(),
        }
    }

    /// The paper's headline evaluation point: 4x4 type-A HBM.
    pub fn default_4x4_hbm() -> Self {
        Self::paper(SystemType::A, MemKind::Hbm, 4)
    }

    pub fn num_chiplets(&self) -> usize {
        self.xdim * self.ydim
    }

    /// Cycle count -> nanoseconds.
    pub fn cycles_to_ns(&self, cycles: f64) -> f64 {
        cycles / self.freq_ghz
    }

    /// Element count -> bytes.
    pub fn bytes(&self, elems: usize) -> f64 {
        elems as f64 * self.bytes_per_elem
    }

    /// Expand this description into a full [`crate::platform::Platform`]
    /// (validates, places the packaging-type attachment set, and builds
    /// the hop tables).
    pub fn platform(&self) -> Result<crate::platform::Platform, String> {
        crate::platform::Platform::try_from_hw(self)
    }

    pub fn validate(&self) -> Result<(), String> {
        if self.xdim == 0 || self.ydim == 0 {
            return Err("grid dims must be positive".into());
        }
        if self.r == 0 || self.c == 0 {
            return Err("systolic dims must be positive".into());
        }
        if self.ty == SystemType::D && (self.xdim < 2 || self.ydim < 2) {
            return Err("type D needs at least a 2x2 grid".into());
        }
        if !(self.bw_nop > 0.0 && self.bw_mem > 0.0 && self.freq_ghz > 0.0) {
            return Err("bandwidths and frequency must be positive".into());
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_constants() {
        let hw = HwConfig::paper(SystemType::A, MemKind::Hbm, 4);
        assert_eq!(hw.bw_mem, 1000.0);
        assert_eq!(hw.bw_nop, 60.0);
        assert_eq!((hw.r, hw.c), (16, 16));
        assert_eq!(hw.energy.nop_pj_bit_hop, 1.285);
        assert_eq!(hw.energy.sram_pj_bit, 0.28);
        assert_eq!(hw.energy.mac_pj_cycle, 4.6);
        assert_eq!(MemKind::Dram.bandwidth_gbps(), 60.0);
        assert_eq!(MemKind::Dram.energy_pj_per_bit(), 14.8);
        assert_eq!(MemKind::Hbm.energy_pj_per_bit(), 4.11);
    }

    #[test]
    fn unit_conversions() {
        let hw = HwConfig::default_4x4_hbm();
        assert_eq!(hw.cycles_to_ns(100.0), 100.0); // 1 GHz
        assert_eq!(hw.bytes(64), 64.0); // int8
    }

    #[test]
    fn validation_catches_bad_configs() {
        let mut hw = HwConfig::default_4x4_hbm();
        hw.xdim = 0;
        assert!(hw.validate().is_err());
        let mut hw = HwConfig::paper(SystemType::D, MemKind::Hbm, 4);
        hw.ydim = 1;
        assert!(hw.validate().is_err());
        assert!(HwConfig::default_4x4_hbm().validate().is_ok());
    }
}
