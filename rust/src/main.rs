//! `mcmcomm` — the Layer-3 leader binary.
//!
//! Subcommands:
//!   figures    regenerate the paper's figures (3, 8–13) and tables
//!   optimize   run one scheduler on one workload/config and report
//!   simulate   execute a plan on the discrete-event simulator and
//!              compare against the analytical model (conformance)
//!   validate   run the standalone plan certifier on a scheduled plan
//!              (capacity / ordering / unicast / partition / memory
//!              reachability checks, independent of the cost model)
//!   netsim     run the Figure-3 congestion study with custom knobs
//!   run-e2e    execute a workload with real numerics end to end
//!   serve      virtual-time serving study: open-loop load, continuous
//!              batching, plan cache, SLO shedding (--live: wall-clock
//!              threaded server demo)
//!   help       this text

use std::time::Duration;

use std::path::Path;

use mcmcomm::config::{MemKind, SystemType};
use mcmcomm::coordinator::Executor;
use mcmcomm::cost::evaluator::Objective;
use mcmcomm::engine::{Engine, Scenario, Scheduler, SchedulerRegistry};
use mcmcomm::ensure;
use mcmcomm::eval::{figures, EvalConfig};
use mcmcomm::opt::ga::{self, GaParams};
use mcmcomm::platform::Platform;
use mcmcomm::runtime::{GemmRuntime, Manifest};
use mcmcomm::topology::Pos;
use mcmcomm::util::cli::Args;
use mcmcomm::util::error::{Error, Result};
use mcmcomm::workload::models;
use mcmcomm::workload::Workload;

const HELP: &str = "\
mcmcomm — MCMComm reproduction (see README.md)

USAGE: mcmcomm <subcommand> [--options]

  figures   --fig <3|8|9|10|11|12|13|solver> | --all   [--full] [--seed N]
  optimize  --model <alexnet|vit|vit_residual|vision_mamba|hydranet|hydranet_branched|gpt2_small|gpt2_large|multi>
            [--scheme <baseline|simba|greedy|ga|miqp|ilp>]
            scheme ilp: task-grained linear scheduler — branch-and-bound
            over an all-linear surrogate with per-link capacity terms on
            the link graph; deterministic at any seed/thread count and
            never worse than miqp's decoded plan on the true objective
            [--type <A|B|C|D>] [--mem <hbm|dram>] [--grid N]
            [--objective <latency|edp|throughput|edp-per-sample>]
            [--platform FILE.json] [--list-platforms]
            [--batch N] [--seed N]
            [--islands K] [--migration-interval M] [--profile]
            island-model GA (scheme ga): K demes evolve in parallel and
            exchange elites on a ring every M generations; results are
            bit-identical at any thread count. --profile prints the
            per-phase wall-clock split (eval | breeding | migration |
            DES sim) of one GA run
            steady objectives (throughput, edp-per-sample) search stage
            plans with the pipelined multi-batch DES instead of a
            single-batch scheduler; extra knobs: [--batches N]
            [--depth D] [--stages K] [--iters N]; reports samples/s and
            energy-per-sample
  platforms --validate FILE.json | --validate-dir DIR | --list
  validate  [--model NAME] [--scheme NAME] [--type T] [--mem M] [--grid N]
            [--platform FILE.json] [--dir DIR] [--batch N] [--seed N]
            schedule a plan, then run the standalone certifier on it:
            routes are re-derived from the link graph and checked for
            capacity overflow, dependency inversion, multicast edges,
            off-grid partitions and unreachable memory — independent of
            the analytical cost model. --dir certifies one plan per
            platform JSON in DIR (CI smoke: validate --dir
            examples/platforms)
  simulate  --model NAME [--scheme NAME] [--type T] [--mem M] [--grid N]
            [--platform FILE.json] [--batch N] [--seed N] [--overlap]
            [--hop-latency NS] [--profile]
            [--pipelined [--stages K] [--depth D] [--batches N]]
            --profile prints the DES wall-clock split (lowering |
            event loop | rate recomputes | component rebuilds) of the
            simulated plan; --pipelined streams batches through a
            K-stage plan to steady state and reports the period,
            samples/s, energy-per-sample and the bottleneck stage/link
  netsim    [--grid N] [--bw-nop G] [--bw-mem G] [--central] [--diagonal] [--gb BYTES]
  run-e2e   [--model NAME] [--scheme NAME] [--scale S] [--artifacts DIR] [--seed N]
  serve     [--requests N] [--rate RPS] [--slack-ms MS] [--model NAME]
            [--scheme NAME] [--modules N] [--max-batch N] [--queue-cap N]
            [--seed N] [--trace FILE.json] [--save-trace FILE.json]
            [--json FILE] [--routing <lowest-index|least-work>]
            [--pipeline-depth D]
            virtual-time load study: seeded Poisson arrivals (or a replayed
            --trace) against N simulated MCM replicas; continuous batching,
            plan-cache reuse, SLO-aware shedding; reports p50/p99/p99.9,
            goodput, shed and cache-hit rates. --routing picks the idle
            replica (least-work = least cumulative assigned service);
            --pipeline-depth D serves each batch through a steady
            pipelined plan with D in flight
  serve --live  [--requests N] [--max-batch N] [--model NAME] [--artifacts DIR]
            wall-clock threaded batching server over the GEMM runtime
";

fn parse_model(name: &str, batch: usize) -> Result<Workload> {
    Ok(match name {
        "alexnet" => models::alexnet(batch),
        "vit" => models::vit(batch),
        "vit_residual" => models::vit_residual(batch),
        "vision_mamba" | "vim" => models::vision_mamba(batch),
        "hydranet" => models::hydranet(batch),
        "hydranet_branched" => models::hydranet_branched(batch),
        // Transformer-scale blocks (ISSUE 7): decode-shaped GPT-2.
        "gpt2" | "gpt2_small" => models::gpt2_small(batch),
        "gpt2_large" => models::gpt2_large(batch),
        // Two-tenant fused scenario (graph IR multi-model composition).
        "multi" => Workload::multi_model(&[
            models::alexnet(batch),
            models::vit(batch),
        ]),
        _ => return Err(Error::msg(format!("unknown model '{name}'"))),
    })
}

fn parse_type(name: &str) -> Result<SystemType> {
    Ok(match name.to_ascii_uppercase().as_str() {
        "A" => SystemType::A,
        "B" => SystemType::B,
        "C" => SystemType::C,
        "D" => SystemType::D,
        _ => return Err(Error::msg(format!("unknown system type '{name}'"))),
    })
}

fn parse_mem(name: &str) -> Result<MemKind> {
    Ok(match name.to_ascii_lowercase().as_str() {
        "hbm" => MemKind::Hbm,
        "dram" => MemKind::Dram,
        _ => return Err(Error::msg(format!("unknown memory kind '{name}'"))),
    })
}

fn cmd_figures(mut args: Args) -> Result<()> {
    let all = args.flag("all");
    let fig = args.get("fig");
    let cfg = EvalConfig {
        quick: !args.flag("full"),
        seed: args.get_usize("seed", 42).map_err(Error::msg)? as u64,
    };
    args.finish().map_err(Error::msg)?;
    let grids: &[usize] = if cfg.quick { &[4, 8] } else { &[4, 8, 16] };
    let run = |f: &str| -> Result<()> {
        match f {
            "3" => {
                figures::fig3(true);
            }
            "8" => {
                figures::fig8(&cfg);
            }
            "9" => {
                figures::fig9(&cfg, grids);
            }
            "10" => {
                figures::fig10(&cfg, grids);
            }
            "11" => {
                figures::fig11(&[2, 4, 8, 16]);
            }
            "12" => {
                figures::fig12(&cfg);
            }
            "13" => {
                figures::fig13(&cfg);
            }
            "solver" => {
                figures::solver_compare(&cfg);
            }
            _ => return Err(Error::msg(format!("unknown figure '{f}'"))),
        }
        Ok(())
    };
    if all {
        for f in ["3", "8", "9", "10", "11", "12", "13", "solver"] {
            run(f)?;
        }
    } else {
        run(&fig.ok_or_else(|| Error::msg("need --fig or --all"))?)?;
    }
    Ok(())
}

/// Print the built-in preset platforms (the `--list-platforms` flag).
fn list_platforms() {
    println!("built-in preset platforms (use --type/--mem/--grid):");
    for ty in SystemType::ALL {
        for mem in [MemKind::Hbm, MemKind::Dram] {
            let plat = Platform::preset(ty, mem, 4);
            println!(
                "  {:<14} {} — {} memory attachment(s)",
                plat.name,
                ty.name(),
                plat.globals().len()
            );
        }
    }
    println!(
        "custom platforms: --platform <file.json> (see examples/platforms/)"
    );
}

fn cmd_optimize(mut args: Args) -> Result<()> {
    let model = args.get_or("model", "alexnet");
    let scheme = args.get_or("scheme", "ga");
    let ty = parse_type(&args.get_or("type", "A"))?;
    let mem = parse_mem(&args.get_or("mem", "hbm"))?;
    let grid = args.get_usize("grid", 4).map_err(Error::msg)?;
    let batch = args.get_usize("batch", 1).map_err(Error::msg)?;
    let platform_file = args.get("platform");
    let list = args.flag("list-platforms");
    let objective = match args.get_or("objective", "latency").as_str() {
        "latency" => Objective::Latency,
        "edp" => Objective::Edp,
        "throughput" => Objective::Throughput,
        "edp-per-sample" | "edp_per_sample" => Objective::EdpPerSample,
        o => return Err(Error::msg(format!("unknown objective '{o}'"))),
    };
    let steady = matches!(
        objective,
        Objective::Throughput | Objective::EdpPerSample
    );
    let seed = args.get_usize("seed", 42).map_err(Error::msg)? as u64;
    let islands = args.get_usize("islands", 1).map_err(Error::msg)?;
    let migration_interval =
        args.get_usize("migration-interval", 4).map_err(Error::msg)?;
    let profile = args.flag("profile");
    // Steady-objective knobs (parsed unconditionally so `finish` stays
    // clean; only the steady path reads them).
    let batches = get_opt_usize(&mut args, "batches")?;
    let max_depth = args.get_usize("depth", 4).map_err(Error::msg)?;
    let max_stages = args.get_usize("stages", 0).map_err(Error::msg)?;
    let iters = args.get_usize("iters", 24).map_err(Error::msg)?;
    args.finish().map_err(Error::msg)?;
    if list {
        list_platforms();
        return Ok(());
    }
    ensure!(islands >= 1, "--islands must be >= 1");
    ensure!(migration_interval >= 1, "--migration-interval must be >= 1");
    if (islands > 1 || profile) && scheme != "ga" {
        return Err(Error::msg(
            "--islands/--migration-interval/--profile apply to --scheme ga",
        ));
    }

    let ga_params =
        GaParams { islands, migration_interval, ..GaParams::default() };
    let registry = SchedulerRegistry::with_params(
        ga_params.clone(),
        Duration::from_secs(20),
        seed,
    );
    let scheduler = registry.require(&scheme)?;
    // The headline 4x4 type-A HBM preset stays the default; a JSON
    // description overrides the preset knobs.
    let mut builder = Scenario::builder().system(ty).mem(mem).grid(grid);
    if let Some(path) = &platform_file {
        builder = builder.platform(Platform::load(Path::new(path))?);
    }
    let scenario = builder
        .workload(parse_model(&model, batch)?)
        .objective(objective)
        .build()?;
    let engine = Engine::new(scenario);

    if steady {
        let params = mcmcomm::steady::SteadyParams {
            iters,
            max_depth: max_depth.max(1),
            max_stages,
            seed,
            sim: mcmcomm::steady::SteadyConfig {
                batches,
                ..Default::default()
            },
        };
        return optimize_steady(engine.scenario(), objective, &params);
    }

    let plat = engine.scenario().platform();
    println!(
        "optimizing {} on platform {} ({}x{} grid, {} memory \
         attachment(s), objective: {objective:?}, scheme: {})",
        engine.scenario().workload().name,
        plat.name,
        plat.xdim,
        plat.ydim,
        plat.globals().len(),
        scheduler.name()
    );
    if profile {
        return profile_ga(engine.scenario(), &ga_params, seed);
    }
    let t0 = std::time::Instant::now();
    let base = engine.schedule(&registry, "baseline")?;
    let planned = engine.schedule_with(scheduler)?;
    let report = planned.report();
    println!("solve time         : {:.2}s", t0.elapsed().as_secs_f64());
    println!("baseline objective : {:.3e}", base.objective_value());
    println!("optimized objective: {:.3e}", planned.objective_value());
    println!(
        "speedup            : {:.2}x",
        base.objective_value() / planned.objective_value()
    );
    println!(
        "latency {:.3} ms | energy {:.3} mJ | EDP {:.3e} pJ*ns",
        report.latency_ns() / 1e6,
        report.energy_pj() / 1e9,
        report.edp()
    );
    let plan = planned.plan();
    let ops = &engine.scenario().workload().ops;
    for (i, p) in plan.alloc.parts.iter().enumerate().take(8) {
        println!("  op {i:>2} {:<12} px={:?} py={:?}", ops[i].name, p.px, p.py);
    }
    if plan.alloc.parts.len() > 8 {
        println!("  ... ({} ops total)", plan.alloc.parts.len());
    }
    Ok(())
}

/// `optimize --profile`: one GA run with the per-phase wall-clock split
/// (fitness eval | breeding | ring migration), then a timed DES
/// simulation of the winning plan.
fn profile_ga(
    scenario: &Scenario,
    ga_params: &GaParams,
    seed: u64,
) -> Result<()> {
    use mcmcomm::netsim::sim::SimConfig;

    let mut params = ga_params.clone();
    params.seed = seed;
    let t0 = std::time::Instant::now();
    let r = ga::optimize(
        scenario.platform(),
        scenario.workload(),
        scenario.flags(),
        scenario.objective(),
        &params,
    );
    let ga_wall = t0.elapsed();
    let plan = scenario.plan("ga", r.alloc, scenario.flags(), seed);
    let ts = std::time::Instant::now();
    let sim = scenario.simulate_with(&plan, &SimConfig::default())?;
    let sim_wall = ts.elapsed();

    let s = |ns: u64| ns as f64 / 1e9;
    println!(
        "ga profile ({} island(s), {} generation(s)):",
        params.islands.max(1),
        r.generations_run
    );
    println!("  eval      : {:>9.3}s (summed across workers)",
             s(r.profile.eval_ns));
    println!("  breeding  : {:>9.3}s", s(r.profile.breed_ns));
    println!("  migration : {:>9.3}s", s(r.profile.migration_ns));
    println!("  ga wall   : {:>9.3}s", ga_wall.as_secs_f64());
    println!("  sim       : {:>9.3}s (DES of the winning plan)",
             sim_wall.as_secs_f64());
    println!(
        "best objective {:.3e} | simulated makespan {:.4} ms",
        r.objective_value,
        sim.makespan_ns / 1e6
    );
    Ok(())
}

/// Parse an optional `--key N` integer (None when absent).
fn get_opt_usize(args: &mut Args, key: &str) -> Result<Option<usize>> {
    match args.get(key) {
        Some(s) => s
            .parse()
            .map(Some)
            .map_err(|_| {
                Error::msg(format!("--{key} expects an integer, got '{s}'"))
            }),
        None => Ok(None),
    }
}

/// Shared pretty-printer for a steady-state report (`optimize` with a
/// steady objective and `simulate --pipelined`).
fn print_steady_report(report: &mcmcomm::steady::SteadyReport) {
    println!(
        "steady period      : {:.4} ms  ({:.1} samples/s)",
        report.period_ns / 1e6,
        report.throughput_per_s()
    );
    println!(
        "first batch latency: {:.4} ms  ({} batches simulated, depth {})",
        report.first_batch_ns / 1e6,
        report.batches,
        report.depth
    );
    let e = &report.energy_per_sample;
    println!(
        "energy per sample  : {:.3} mJ  (offchip {:.3} | nop {:.3} | \
         compute {:.3})",
        e.total_pj() / 1e9,
        e.offchip_pj / 1e9,
        e.nop_pj / 1e9,
        e.compute_pj / 1e9
    );
    for (s, stat) in report.stages.iter().enumerate() {
        println!(
            "  stage {s}: ops {:>3}..{:<3} rows {}..{} occupancy {:.1}%{}",
            stat.ops.0,
            stat.ops.1,
            stat.rows.0,
            stat.rows.1,
            stat.occupancy * 100.0,
            if s == report.bottleneck_stage { "  <- bottleneck" } else { "" }
        );
    }
    if let Some((from, to, util)) = report.bottleneck_link {
        println!(
            "bottleneck link    : {from} -> {to} ({:.1}% utilized)",
            util * 100.0
        );
    }
}

/// `optimize --objective throughput|edp-per-sample`: stage-plan search
/// scored by the steady-state multi-batch DES.
fn optimize_steady(
    scenario: &Scenario,
    objective: Objective,
    params: &mcmcomm::steady::SteadyParams,
) -> Result<()> {
    use mcmcomm::steady::{optimize, simulate_steady, StagePlan};

    let plat = scenario.platform();
    let wl = scenario.workload();
    println!(
        "steady optimize: {} on platform {} ({}x{} grid, objective: \
         {objective:?})",
        wl.name, plat.name, plat.xdim, plat.ydim
    );
    let t0 = std::time::Instant::now();
    let out = optimize(plat, wl, scenario.flags(), objective, params)?;
    let solve = t0.elapsed();
    // Serial reference: single stage, one batch in flight — the
    // pipelined analogue of "best single-batch 1/makespan".
    let serial = simulate_steady(
        plat,
        wl,
        &StagePlan::single_stage(plat, wl, 1),
        scenario.flags(),
        &params.sim,
    )?;
    println!("solve time         : {:.2}s", solve.as_secs_f64());
    println!("best plan          : {}", out.plan.describe());
    print_steady_report(&out.report);
    println!(
        "vs serial depth-1  : {:.2}x throughput ({:.1} -> {:.1} samples/s)",
        serial.period_ns / out.report.period_ns,
        serial.throughput_per_s(),
        out.report.throughput_per_s()
    );
    Ok(())
}

fn cmd_platforms(mut args: Args) -> Result<()> {
    let file = args.get("validate");
    let dir = args.get("validate-dir");
    let list = args.flag("list");
    args.finish().map_err(Error::msg)?;
    if list || (file.is_none() && dir.is_none()) {
        list_platforms();
        return Ok(());
    }
    let mut files: Vec<std::path::PathBuf> = Vec::new();
    if let Some(f) = file {
        files.push(f.into());
    }
    if let Some(d) = dir {
        let mut entries: Vec<_> = std::fs::read_dir(&d)
            .map_err(|e| Error::msg(format!("reading {d}: {e}")))?
            .filter_map(|e| e.ok())
            .map(|e| e.path())
            .filter(|p| p.extension().is_some_and(|x| x == "json"))
            .collect();
        entries.sort();
        ensure!(!entries.is_empty(), "no *.json platform files in {d}");
        files.extend(entries);
    }
    for path in &files {
        let plat = Platform::load(path)?;
        println!(
            "OK  {:<40} {} ({}x{} grid, {} attachment(s))",
            path.display(),
            plat.name,
            plat.xdim,
            plat.ydim,
            plat.globals().len()
        );
    }
    println!("validated {} platform file(s)", files.len());
    Ok(())
}

/// `validate`: schedule a plan and run the standalone certifier
/// (`engine::certify`) on it — structural checks plus per-link capacity
/// bounds re-derived from the `LinkGraph`, independent of the
/// analytical cost model. With `--dir`, certifies one plan per platform
/// JSON in the directory (the CI smoke path).
fn cmd_validate(mut args: Args) -> Result<()> {
    let model = args.get_or("model", "alexnet");
    let scheme = args.get_or("scheme", "baseline");
    let ty = parse_type(&args.get_or("type", "A"))?;
    let mem = parse_mem(&args.get_or("mem", "hbm"))?;
    let grid = args.get_usize("grid", 4).map_err(Error::msg)?;
    let batch = args.get_usize("batch", 1).map_err(Error::msg)?;
    let seed = args.get_usize("seed", 42).map_err(Error::msg)? as u64;
    let platform_file = args.get("platform");
    let dir = args.get("dir");
    args.finish().map_err(Error::msg)?;

    // Tiny solver budgets: the point is certifying whatever plan comes
    // out, not plan quality — the smoke path must stay seconds-class.
    let registry = SchedulerRegistry::with_params(
        GaParams {
            population: 8,
            generations: 6,
            threads: 1,
            seed,
            ..Default::default()
        },
        Duration::from_secs(2),
        seed,
    );
    let scheduler = registry.require(&scheme)?;

    let mut plats: Vec<Platform> = Vec::new();
    if let Some(d) = &dir {
        let mut entries: Vec<_> = std::fs::read_dir(d)
            .map_err(|e| Error::msg(format!("reading {d}: {e}")))?
            .filter_map(|e| e.ok())
            .map(|e| e.path())
            .filter(|p| p.extension().is_some_and(|x| x == "json"))
            .collect();
        entries.sort();
        ensure!(!entries.is_empty(), "no *.json platform files in {d}");
        for path in &entries {
            plats.push(Platform::load(path)?);
        }
    } else if let Some(path) = &platform_file {
        plats.push(Platform::load(Path::new(path))?);
    } else {
        plats.push(Platform::preset(ty, mem, grid));
    }

    let wl = parse_model(&model, batch)?;
    let mut rejected = 0usize;
    let n_plats = plats.len();
    for plat in plats {
        let name = plat.name.clone();
        let scenario = Scenario::builder()
            .platform(plat)
            .workload(wl.clone())
            .build()?;
        let engine = Engine::new(scenario);
        let planned = engine.schedule_with(scheduler)?;
        let plan = planned.plan();
        match plan.validate(
            engine.scenario().platform(),
            engine.scenario().workload(),
        ) {
            Ok(cert) => println!(
                "OK   {:<24} '{}' plan: {} flows, {:.3e} byte-hops, \
                 fingerprint {:016x}",
                name, plan.scheduler, cert.flows, cert.total_bytes,
                cert.fingerprint
            ),
            Err(violations) => {
                rejected += 1;
                println!(
                    "FAIL {:<24} '{}' plan rejected ({} violation(s)):",
                    name,
                    plan.scheduler,
                    violations.len()
                );
                for v in &violations {
                    println!("  [{}] {v}", v.kind());
                }
            }
        }
    }
    ensure!(
        rejected == 0,
        "certifier rejected {rejected} of {n_plats} plan(s)"
    );
    println!("certified {n_plats} plan(s) for model '{model}'");
    Ok(())
}

/// `simulate`: schedule a workload, execute the plan on the plan-level
/// discrete-event simulator, and compare against the analytical model.
fn cmd_simulate(mut args: Args) -> Result<()> {
    use mcmcomm::netsim::sim::{SimConfig, SimMode};

    let model = args.get_or("model", "alexnet");
    let scheme = args.get_or("scheme", "ga");
    let ty = parse_type(&args.get_or("type", "A"))?;
    let mem = parse_mem(&args.get_or("mem", "hbm"))?;
    let grid = args.get_usize("grid", 4).map_err(Error::msg)?;
    let batch = args.get_usize("batch", 1).map_err(Error::msg)?;
    let platform_file = args.get("platform");
    let seed = args.get_usize("seed", 42).map_err(Error::msg)? as u64;
    let overlap = args.flag("overlap");
    let profile = args.flag("profile");
    let hop_latency =
        args.get_f64("hop-latency", 0.0).map_err(Error::msg)?;
    let pipelined = args.flag("pipelined");
    let stages = args.get_usize("stages", 1).map_err(Error::msg)?;
    let depth = args.get_usize("depth", 2).map_err(Error::msg)?;
    let batches = get_opt_usize(&mut args, "batches")?;
    args.finish().map_err(Error::msg)?;

    let mut builder = Scenario::builder().system(ty).mem(mem).grid(grid);
    if let Some(path) = &platform_file {
        builder = builder.platform(Platform::load(Path::new(path))?);
    }
    let scenario =
        builder.workload(parse_model(&model, batch)?).build()?;
    let engine = Engine::new(scenario);

    if pipelined {
        ensure!(
            !overlap && !profile,
            "--pipelined is incompatible with --overlap/--profile"
        );
        return simulate_pipelined(
            engine.scenario(),
            stages,
            depth,
            mcmcomm::steady::SteadyConfig {
                batches,
                hop_latency_ns: hop_latency,
                ..Default::default()
            },
        );
    }
    let registry = SchedulerRegistry::standard(seed);
    let planned = engine.schedule(&registry, &scheme)?;
    let report = planned.report();
    let plan = planned.plan();

    let cfg = SimConfig {
        mode: if overlap { SimMode::Overlap } else { SimMode::Conformance },
        hop_latency_ns: hop_latency,
    };
    if profile {
        return profile_sim(engine.scenario(), plan, &cfg);
    }
    let sim = engine.scenario().simulate_with(plan, &cfg)?;

    println!(
        "simulated {} on {} (scheme {}, mode {:?})",
        engine.scenario().workload().name,
        engine.scenario().label(),
        plan.scheduler,
        cfg.mode,
    );
    // LS stage terms: `in_ns` folds redistribution in, so subtract it
    // back out for a disjoint load | redist | comp | out split. Under
    // async fusion the stages overlap, so their sum exceeds the total.
    let b = &report.breakdown;
    let load_ns = b.in_total_ns() - b.redist_total_ns();
    let offchip_ns: f64 = b.per_op.iter().map(|o| o.in_offchip_ns).sum();
    println!(
        "analytical latency : {:.4} ms  (load {:.4} of which offchip \
         {:.4} | redist {:.4} | comp {:.4} | out {:.4}{})",
        report.latency_ns() / 1e6,
        load_ns / 1e6,
        offchip_ns / 1e6,
        b.redist_total_ns() / 1e6,
        b.comp_total_ns() / 1e6,
        b.out_total_ns() / 1e6,
        if plan.flags.async_fusion {
            "; fusion overlaps load+comp, stages sum above the total"
        } else {
            ""
        },
    );
    println!(
        "simulated makespan : {:.4} ms  ({} redistributed edge(s), \
         energy {:.3} mJ)",
        sim.makespan_ns / 1e6,
        sim.redistributed_edges(),
        sim.energy.total_pj() / 1e9,
    );
    println!("top links by mean utilization:");
    for (l, u) in sim.top_links(5) {
        let link = &sim.graph.links[l];
        println!(
            "  {:>3} -> {:<3} {:>6.1}%  ({:.0} GB/s)",
            link.from,
            link.to,
            u * 100.0,
            link.capacity
        );
    }
    if overlap || hop_latency != 0.0 {
        println!(
            "({} not comparable to the analytical LS model)",
            if overlap { "overlap mode:" } else { "nonzero hop latency:" }
        );
    } else {
        // Grade the run we already have (no second simulation): the
        // default config above IS conformance mode.
        let tol =
            mcmcomm::netsim::conformance::scheme_tolerance(&plan.scheduler);
        let ratio = sim.makespan_ns / report.latency_ns();
        let pass = tol.contains(ratio);
        println!(
            "conformance        : ratio {:.3} in band [{:.2}, {:.2}] -> {}",
            ratio,
            tol.lo,
            tol.hi,
            if pass { "ok" } else { "FAIL" }
        );
        ensure!(
            pass,
            "simulated/analytical ratio {ratio:.3} outside tolerance"
        );
    }
    Ok(())
}

/// `simulate --pipelined`: stream batches through a K-stage plan to
/// steady state and report throughput instead of makespan.
fn simulate_pipelined(
    scenario: &Scenario,
    stages: usize,
    depth: usize,
    cfg: mcmcomm::steady::SteadyConfig,
) -> Result<()> {
    use mcmcomm::steady::plan::stage_plan_from_count;
    use mcmcomm::steady::simulate_steady;

    let plat = scenario.platform();
    let wl = scenario.workload();
    let plan = stage_plan_from_count(plat, wl, stages, depth)?;
    println!(
        "pipelined simulation: {} on {} — plan {}",
        wl.name,
        scenario.label(),
        plan.describe()
    );
    let report = simulate_steady(plat, wl, &plan, scenario.flags(), &cfg)?;
    print_steady_report(&report);
    Ok(())
}

/// `simulate --profile`: one DES run with the per-phase wall-clock
/// split (lowering | event loop | incremental rate recomputes |
/// component rebuilds), mirroring `optimize --profile`.
fn profile_sim(
    scenario: &Scenario,
    plan: &mcmcomm::engine::Plan,
    cfg: &mcmcomm::netsim::sim::SimConfig,
) -> Result<()> {
    let (sim, p) = mcmcomm::netsim::simulate_plan_profiled(
        scenario.platform(),
        scenario.workload(),
        &plan.alloc,
        plan.flags,
        cfg,
    )?;
    let s = |ns: u64| ns as f64 / 1e9;
    println!(
        "sim profile ({} task(s), {} event(s), mode {:?}):",
        p.tasks, p.events, cfg.mode
    );
    println!("  lowering  : {:>9.3}s", s(p.lower_ns));
    println!(
        "  event loop: {:>9.3}s (wall, includes rate work)",
        s(p.event_loop_ns)
    );
    println!(
        "  rates     : {:>9.3}s ({} incremental recompute(s))",
        s(p.rate_recompute_ns),
        p.rate_recomputes
    );
    println!(
        "  components: {:>9.3}s ({} component(s) recomputed)",
        s(p.components_ns),
        p.components_recomputed
    );
    println!(
        "simulated makespan {:.4} ms ({} redistributed edge(s))",
        sim.makespan_ns / 1e6,
        sim.redistributed_edges(),
    );
    Ok(())
}

fn cmd_netsim(mut args: Args) -> Result<()> {
    let grid = args.get_usize("grid", 4).map_err(Error::msg)?;
    let bw_nop = args.get_f64("bw-nop", 60.0).map_err(Error::msg)?;
    let bw_mem = args.get_f64("bw-mem", 1024.0).map_err(Error::msg)?;
    let central = args.flag("central");
    let diagonal = args.flag("diagonal");
    let gb = args.get_f64("gb", 1e9).map_err(Error::msg)?;
    args.finish().map_err(Error::msg)?;
    let attach = if central {
        Pos::new((grid - 1) / 2, (grid - 1) / 2)
    } else {
        Pos::new(0, 0)
    };
    let (_, res) = mcmcomm::netsim::all_pull_from_memory(
        grid, gb, bw_nop, bw_mem, attach, diagonal,
    )?;
    println!(
        "grid {grid}x{grid}, NoP {bw_nop} GB/s, mem {bw_mem} GB/s, attach {:?}, diagonal {diagonal}",
        attach
    );
    println!("makespan: {:.3} ms", res.makespan_ns / 1e6);
    Ok(())
}

fn cmd_run_e2e(mut args: Args) -> Result<()> {
    let model = args.get_or("model", "alexnet");
    let scheme = args.get_or("scheme", "ga");
    let scale = args.get_usize("scale", 16).map_err(Error::msg)?;
    let artifacts = args.get_or(
        "artifacts",
        Manifest::default_dir().to_str().unwrap_or("artifacts"),
    );
    let seed = args.get_usize("seed", 42).map_err(Error::msg)? as u64;
    args.finish().map_err(Error::msg)?;

    let full = parse_model(&model, 1)?;
    let wl = models::scaled_down(&full, scale, 16);
    let registry = SchedulerRegistry::standard(seed);
    let engine = Engine::new(Scenario::headline(wl));
    let planned = engine.schedule(&registry, &scheme)?;

    let runtime = GemmRuntime::new(std::path::Path::new(&artifacts))?;
    println!("runtime platform: {}", runtime.platform());
    let exec = Executor::from_plan(engine.scenario(), planned.plan(),
                                   &runtime);
    let report = exec.run(seed, true)?;
    println!(
        "{}: {} chunks executed in {:.2?} host wall, max |err| vs CPU ref = {:.2e}",
        engine.scenario().workload().name,
        report.chunks_executed,
        report.host_wall,
        report.max_abs_err
    );
    println!(
        "modeled MCM latency {:.3} ms | energy {:.3} mJ | EDP {:.3e}",
        report.modeled.latency_ns / 1e6,
        report.modeled.energy_pj / 1e9,
        report.modeled.edp()
    );
    if let Some(sim_ns) = report.simulated_ns {
        println!(
            "simulated MCM latency {:.3} ms (DES cross-check, ratio {:.3})",
            sim_ns / 1e6,
            sim_ns / report.modeled.latency_ns
        );
    }
    ensure!(report.max_abs_err < 1e-3, "numeric mismatch!");
    println!("e2e OK");
    Ok(())
}

fn cmd_serve(mut args: Args) -> Result<()> {
    if args.flag("live") {
        return cmd_serve_live(args);
    }
    let n_req = args.get_usize("requests", 2000).map_err(Error::msg)?;
    let rate = args.get_f64("rate", 5000.0).map_err(Error::msg)?;
    let slack_ms = args.get_f64("slack-ms", 0.0).map_err(Error::msg)?;
    let model = args.get_or("model", "multi");
    let scheme = args.get_or("scheme", "greedy");
    let modules = args.get_usize("modules", 4).map_err(Error::msg)?;
    let max_batch = args.get_usize("max-batch", 8).map_err(Error::msg)?;
    let queue_cap = args.get_usize("queue-cap", 256).map_err(Error::msg)?;
    let seed = args.get_usize("seed", 42).map_err(Error::msg)? as u64;
    let trace_in = args.get("trace");
    let trace_out = args.get("save-trace");
    let json_out = args.get("json");
    let routing = match args.get_or("routing", "lowest-index").as_str() {
        "lowest-index" => mcmcomm::serving::RoutingPolicy::LowestIndex,
        "least-work" | "least-outstanding-work" => {
            mcmcomm::serving::RoutingPolicy::LeastOutstandingWork
        }
        o => {
            return Err(Error::msg(format!("unknown routing policy '{o}'")))
        }
    };
    let pipeline_depth = get_opt_usize(&mut args, "pipeline-depth")?;
    args.finish().map_err(Error::msg)?;
    ensure!(rate > 0.0, "--rate must be > 0");

    // One tenant per model span of the (possibly fused) workload; the
    // trace's tenant ids index those spans.
    let base = Scenario::headline(parse_model(&model, 1)?);
    let cfg = mcmcomm::serving::HarnessConfig {
        modules,
        max_batch,
        queue_cap,
        scheduler: scheme.clone(),
        seed,
        // miqp's anytime budget is nondeterministic: recomputation may
        // legitimately differ, so skip hit re-verification for it.
        verify_cache: scheme != "miqp",
        routing,
        pipeline_depth,
        ..mcmcomm::serving::HarnessConfig::default()
    };
    let harness = mcmcomm::serving::LoadHarness::multi_tenant(&base, cfg)?;
    let trace = match trace_in {
        Some(path) => mcmcomm::serving::Trace::load(Path::new(&path))?,
        None => mcmcomm::serving::Trace::poisson(
            n_req,
            1e9 / rate,
            harness.tenant_count(),
            (slack_ms > 0.0).then_some(slack_ms * 1e6),
            seed,
        ),
    };
    if let Some(path) = trace_out {
        trace.save(Path::new(&path))?;
        println!("trace saved to {path}");
    }
    println!(
        "serving {} ({} tenants) with '{scheme}' plans: {} requests \
         in virtual time",
        base.workload().name,
        harness.tenant_count(),
        trace.len(),
    );
    let report = harness.run(&trace)?;
    println!("{}", report.summary());
    if let Some(path) = json_out {
        std::fs::write(&path, report.to_json().encode())
            .map_err(|e| Error::msg(format!("writing {path}: {e}")))?;
        println!("report written to {path}");
    }
    Ok(())
}

/// The legacy wall-clock demo: a threaded batching server over the
/// GEMM runtime (`serve --live`).
fn cmd_serve_live(mut args: Args) -> Result<()> {
    let n_req = args.get_usize("requests", 32).map_err(Error::msg)?;
    let max_batch = args.get_usize("max-batch", 8).map_err(Error::msg)?;
    let model = args.get_or("model", "vit");
    let artifacts = args.get_or(
        "artifacts",
        Manifest::default_dir().to_str().unwrap_or("artifacts"),
    );
    args.finish().map_err(Error::msg)?;

    let full = parse_model(&model, 1)?;
    let wl = models::scaled_down(&full, 16, 16);
    let registry = SchedulerRegistry::standard(42);
    let engine = Engine::new(Scenario::headline(wl));
    let plan = engine.schedule(&registry, "ga")?.into_plan();
    let scenario = engine.scenario().clone();
    // The runtime may not be Send (PJRT clients hold Rc): build it
    // inside the batcher thread via the factory.
    let factory: mcmcomm::coordinator::server::RunnerFactory =
        Box::new(move || {
            let runtime = GemmRuntime::new(std::path::Path::new(&artifacts))
                .expect("loading artifacts");
            // Warm the compile cache so serving latencies are steady.
            Executor::from_plan(&scenario, &plan, &runtime)
                .run(0, false)
                .expect("warmup run");
            let cost = scenario.report(&plan).breakdown;
            Box::new(move |bsz| {
                let exec = Executor::from_plan(&scenario, &plan, &runtime);
                let _ = exec.run(bsz as u64, false);
                let batch_ns = cost.latency_ns * bsz as f64
                    / mcmcomm::pipeline::pipeline_speedup(&cost, bsz.max(1));
                (batch_ns, batch_ns / bsz as f64)
            })
        });
    let server = mcmcomm::coordinator::Server::start_factory(
        max_batch,
        Duration::from_millis(2),
        factory,
    );
    let client = server.client();
    let t0 = std::time::Instant::now();
    let waiters: Vec<_> = (0..n_req)
        .map(|_| client.submit())
        .collect::<Result<_>>()?;
    let mut per_sample = Vec::new();
    for w in waiters {
        let r = w.recv()?.done().expect("best-effort requests never shed");
        per_sample.push(r.modeled_per_sample_ns);
    }
    let wall = t0.elapsed();
    drop(client);
    let stats = server.shutdown();
    println!(
        "served {} requests in {} batches (max batch {}), host wall {:.2?}",
        stats.served, stats.batches, stats.max_batch, wall
    );
    println!(
        "modeled per-sample latency: mean {:.3} ms",
        mcmcomm::util::math::mean(&per_sample) / 1e6
    );
    println!(
        "host throughput: {:.1} req/s",
        n_req as f64 / wall.as_secs_f64()
    );
    Ok(())
}

fn main() {
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}\n{HELP}");
            std::process::exit(2);
        }
    };
    let sub = args.subcommand.clone().unwrap_or_else(|| "help".into());
    let result = match sub.as_str() {
        "figures" => cmd_figures(args),
        "optimize" => cmd_optimize(args),
        "platforms" => cmd_platforms(args),
        "validate" => cmd_validate(args),
        "simulate" => cmd_simulate(args),
        "netsim" => cmd_netsim(args),
        "run-e2e" => cmd_run_e2e(args),
        "serve" => cmd_serve(args),
        "help" | "--help" | "-h" => {
            println!("{HELP}");
            Ok(())
        }
        other => {
            eprintln!("unknown subcommand '{other}'\n{HELP}");
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}
