//! On-package redistribution (paper §5.2): the three-step heuristic that
//! replaces an output→memory→input round-trip between chained GEMMs with
//! purely on-package traffic.
//!
//! Step 1 — *row reduction*: chiplets of a grid row send their output
//! chunks toward a collection column `c*` chosen to balance the bytes
//! arriving from the left and from the right (the link adjacent to `c*`
//! on each side serializes that side's bytes).
//!
//! Step 2 — *row broadcast*: the assembled row block (Px[x] × N) is
//! broadcast back along the row; wormhole pipelining makes the wall time
//! one block transfer regardless of row length.
//!
//! Step 3 — *column redistribution*: rows migrate across grid-row
//! boundaries so the layout matches the next op's Px' partition; the
//! column link crossing boundary `b` carries the cumulative mismatch
//! between the two partitions.
//!
//! Vertical links "help little during row reduction" (§5.2), so steps are
//! strictly row-then-column; the three step latencies add.

use crate::platform::Platform;
use crate::partition::{Allocation, Partition};
use crate::workload::{EdgeId, GemmOp, Workload};

/// Latency + energy of one redistribution between `op` (producer, with
/// partition `part`) and the next op (consumer, with partition
/// `next_part`), collecting at column `c_star`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RedistCost {
    pub step1_ns: f64,
    pub step2_ns: f64,
    pub step3_ns: f64,
    pub energy_pj: f64,
}

impl RedistCost {
    pub fn total_ns(&self) -> f64 {
        self.step1_ns + self.step2_ns + self.step3_ns
    }
}

/// Cost of the 3-step redistribution (§5.2).
pub fn redistribute(
    plat: &Platform,
    op: &GemmOp,
    part: &Partition,
    next_part: &Partition,
    c_star: usize,
) -> RedistCost {
    assert!(c_star < part.py.len(), "collection column out of range");
    let bw = plat.bw_nop;
    let e_nop_bit = plat.energy.nop_pj_bit_hop;

    // ---- Step 1: row reduction toward c*.
    // Per row x: left side carries sum of chunks with y < c*, right side
    // with y > c*; the two directions proceed in parallel, rows proceed
    // in parallel, so the step time is the max serialized side.
    let mut step1_ns: f64 = 0.0;
    let mut energy_bits = 0.0;
    for &px in &part.px {
        let mut left = 0.0;
        let mut right = 0.0;
        for (y, &py) in part.py.iter().enumerate() {
            let chunk_bytes = plat.bytes(px * py);
            let hops = y.abs_diff(c_star) as f64;
            if y < c_star {
                left += chunk_bytes;
            } else if y > c_star {
                right += chunk_bytes;
            }
            energy_bits += chunk_bytes * 8.0 * hops;
        }
        step1_ns = step1_ns.max(left.max(right) / bw);
    }

    // ---- Step 2: broadcast the row block to the whole row (pipelined
    // wavefront: one block transfer of Px[x] * N bytes).
    let ydim = part.py.len();
    let mut step2_ns: f64 = 0.0;
    for &px in &part.px {
        let row_bytes = plat.bytes(px * op.n);
        step2_ns = step2_ns.max(row_bytes / bw);
        // Every one of the (ydim - 1) row links carries the full block.
        energy_bits += row_bytes * 8.0 * (ydim - 1) as f64;
    }

    // ---- Step 3: column redistribution to the next partition's Px'.
    // Per-boundary bytes come from the shared helper (the
    // discrete-event simulator lowers the same numbers to per-boundary
    // flows, so the two models cannot drift apart).
    let mut step3_worst_bytes: f64 = 0.0;
    for &bytes in &step3_boundary_bytes(plat, op, part, next_part) {
        step3_worst_bytes = step3_worst_bytes.max(bytes);
        energy_bits += bytes * 8.0;
    }
    let step3_ns = step3_worst_bytes / bw;

    RedistCost {
        step1_ns,
        step2_ns,
        step3_ns,
        energy_pj: energy_bits * e_nop_bit,
    }
}

/// Step-3 bytes crossing each grid-row boundary `b` (between rows `b`
/// and `b+1`): the cumulative mismatch between the producer's `Px` and
/// the consumer's `Px'`, mapped through the row-count rescale when
/// `M' != M`. The moved data is the producer's output rows, so the row
/// width is `N` (for im2col chains the consumer's `K'` may exceed `N`;
/// see [`crate::workload::Workload::edge_redistributable`]).
///
/// Single source of truth for the step-3 arithmetic: [`redistribute`]
/// maxes/sums these bytes into `step3_ns`/energy, and the plan-level
/// discrete-event simulator (`netsim::sim`) lowers the same values to
/// one flow per boundary — which is what keeps the simulated exchange
/// window equal to the closed form on a congestion-free package.
pub fn step3_boundary_bytes(
    plat: &Platform,
    op: &GemmOp,
    part: &Partition,
    next_part: &Partition,
) -> Vec<f64> {
    let next_m: usize = next_part.px.iter().sum();
    let xdim = part.px.len();
    let m: usize = part.px.iter().sum();
    let scale = m as f64 / next_m.max(1) as f64;
    let mut cum_a = 0.0f64;
    let mut cum_b = 0.0f64;
    let mut out = Vec::with_capacity(xdim.saturating_sub(1));
    for b in 0..xdim.saturating_sub(1) {
        cum_a += part.px[b] as f64;
        cum_b += next_part.px[b] as f64 * scale;
        let rows_moved = (cum_a - cum_b).abs();
        out.push(rows_moved * plat.bytes(op.n));
    }
    out
}

/// Per-edge convenience over [`redistribute`]: the 3-step cost of
/// moving the tensor on dataflow edge `e` of `wl` under `alloc`, using
/// the edge's own collection-column gene. Legality is the caller's
/// concern ([`Workload::edge_redistributable`]); the cost of an
/// illegal move is still well-defined (diagnostics, what-if tooling).
pub fn redistribute_edge(
    plat: &Platform,
    wl: &Workload,
    alloc: &Allocation,
    e: EdgeId,
) -> RedistCost {
    let edge = wl.edges[e];
    redistribute(
        plat,
        &wl.ops[edge.src],
        &alloc.parts[edge.src],
        &alloc.parts[edge.dst],
        alloc.collect_cols[e],
    )
}

/// The collection column minimizing step-1 latency (§5.2: "best balances
/// the left-coming and right-coming data size") — the default gene value
/// the GA starts from and the value MIQP fixes.
pub fn best_collect_col(plat: &Platform, op: &GemmOp, part: &Partition,
                        next_part: &Partition) -> usize {
    (0..part.py.len())
        .min_by(|&a, &b| {
            let ca = redistribute(plat, op, part, next_part, a).total_ns();
            let cb = redistribute(plat, op, part, next_part, b).total_ns();
            ca.total_cmp(&cb)
        })
        .unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{MemKind, SystemType};
    use crate::partition::{uniform, Partition};

    fn hw() -> Platform {
        Platform::preset(SystemType::A, MemKind::Hbm, 4)
    }

    fn op() -> GemmOp {
        GemmOp::dense("x", 512, 128, 512)
    }

    #[test]
    fn balanced_collection_beats_edge() {
        let h = hw();
        let o = op();
        let p = uniform(&h, &o);
        let mid = redistribute(&h, &o, &p, &p, 2).total_ns();
        let edge = redistribute(&h, &o, &p, &p, 0).total_ns();
        assert!(mid < edge, "mid={mid} edge={edge}");
        let best = best_collect_col(&h, &o, &p, &p);
        assert!(best == 1 || best == 2, "best={best}");
    }

    #[test]
    fn identical_partitions_need_no_step3() {
        let h = hw();
        let o = op();
        let p = uniform(&h, &o);
        let c = redistribute(&h, &o, &p, &p, 2);
        assert_eq!(c.step3_ns, 0.0);
        assert!(c.step1_ns > 0.0 && c.step2_ns > 0.0);
    }

    #[test]
    fn skewed_next_partition_pays_step3() {
        let h = hw();
        let o = op();
        let p = uniform(&h, &o);
        let skew = Partition { px: vec![512, 0, 0, 0], py: p.py.clone() };
        let c = redistribute(&h, &o, &p, &skew, 2);
        assert!(c.step3_ns > 0.0);
    }

    #[test]
    fn cheaper_than_memory_roundtrip_high_bw() {
        // The whole point of §5.2: beat offload+reload via memory.
        use crate::cost::latency::{load, offload};
        let h = hw();
        let o = op();
        let p = uniform(&h, &o);
        let redist = redistribute(&h, &o, &p, &p, 2).total_ns();
        let roundtrip = offload(&h, &o, false).wall_ns()
            + load(&h, &o, &p, false, true).wall_ns();
        assert!(
            redist < roundtrip,
            "redist={redist} roundtrip={roundtrip}"
        );
    }

    #[test]
    fn step3_helper_is_the_single_source_of_truth() {
        // `redistribute`'s step-3 time is exactly the worst boundary of
        // the shared helper — the invariant the simulator lowering
        // relies on (one flow per boundary, worst link dominates).
        let h = hw();
        let o = op();
        let p = uniform(&h, &o);
        let skew =
            Partition { px: vec![200, 120, 120, 72], py: p.py.clone() };
        let c = redistribute(&h, &o, &p, &skew, 2);
        let worst = step3_boundary_bytes(&h, &o, &p, &skew)
            .into_iter()
            .fold(0.0f64, f64::max);
        assert_eq!(c.step3_ns.to_bits(), (worst / h.bw_nop).to_bits());
        assert!(c.step3_ns > 0.0);
        // Identical partitions: every boundary is zero.
        assert!(step3_boundary_bytes(&h, &o, &p, &p)
            .into_iter()
            .all(|b| b == 0.0));
    }

    #[test]
    fn energy_positive_and_scales_with_size() {
        let h = hw();
        let small = GemmOp::dense("s", 64, 32, 64);
        let big = GemmOp::dense("b", 1024, 32, 1024);
        let ps = uniform(&h, &small);
        let pb = uniform(&h, &big);
        let es = redistribute(&h, &small, &ps, &ps, 2).energy_pj;
        let eb = redistribute(&h, &big, &pb, &pb, 2).energy_pj;
        assert!(es > 0.0 && eb > es * 50.0);
    }
}
