//! The [`Scheduler`] trait, the five Table-3 implementations, and the
//! task-grained ILP.
//!
//! Callers iterate `dyn Scheduler`s (usually from a
//! [`super::SchedulerRegistry`]) instead of matching a scheme enum; new
//! schedulers plug in by implementing the trait and registering.

use std::time::Duration;

use crate::cost::evaluator::OptFlags;
use crate::opt::ga::GaParams;
use crate::opt::{ga, greedy, ilp, miqp};
use crate::partition::{simba_allocation, uniform_allocation};

use super::plan::Plan;
use super::scenario::Scenario;
use super::EngineError;

/// A scheduling strategy: consumes a [`Scenario`], produces a [`Plan`].
///
/// Implementations own their tuning knobs (population sizes, solver
/// budgets, seeds); the scenario owns the problem (hardware, workload,
/// requested flags, objective).
///
/// `Sync` is a supertrait: [`crate::engine::Engine::sweep`] shares one
/// scheduler across worker threads, so implementations must keep any
/// mutable solver state local to `schedule` (all built-ins do — their
/// RNGs are constructed per call from the owned seed).
pub trait Scheduler: Sync {
    /// Human-readable name (figure tables), e.g. `"MCMComm-GA"`.
    fn name(&self) -> &str;

    /// Stable registry key, e.g. `"ga"`.
    fn key(&self) -> &str;

    /// Alternative lookup spellings accepted by the registry.
    fn aliases(&self) -> &[&str] {
        &[]
    }

    /// The flags this scheduler actually optimizes under. Schedulers
    /// that predate the MCMComm co-optimizations run unoptimized
    /// (Table 3 column "MCMComm Optimizations").
    fn effective_flags(&self, requested: OptFlags) -> OptFlags {
        let _ = requested;
        OptFlags::NONE
    }

    /// Solve the scenario.
    fn schedule(&self, scenario: &Scenario) -> Result<Plan, EngineError>;
}

/// Layer Sequential baseline: uniform partitioning, no optimizations.
#[derive(Debug, Clone, Copy, Default)]
pub struct Baseline;

impl Scheduler for Baseline {
    fn name(&self) -> &str {
        "LS (baseline)"
    }

    fn key(&self) -> &str {
        "baseline"
    }

    fn aliases(&self) -> &[&str] {
        &["ls"]
    }

    fn schedule(&self, scenario: &Scenario) -> Result<Plan, EngineError> {
        let alloc =
            uniform_allocation(scenario.platform(), scenario.workload());
        Ok(scenario.plan(self.key(), alloc, OptFlags::NONE, 0))
    }
}

/// SIMBA-like inverse-distance partitioning, no optimizations (§3.1).
#[derive(Debug, Clone, Copy, Default)]
pub struct SimbaLike;

impl Scheduler for SimbaLike {
    fn name(&self) -> &str {
        "SIMBA-like"
    }

    fn key(&self) -> &str {
        "simba"
    }

    fn schedule(&self, scenario: &Scenario) -> Result<Plan, EngineError> {
        let alloc = simba_allocation(
            scenario.platform(),
            scenario.workload(),
        );
        Ok(scenario.plan(self.key(), alloc, OptFlags::NONE, 0))
    }
}

/// Greedy layer-by-layer hill climbing (§3.5 strawman).
#[derive(Debug, Clone, Copy, Default)]
pub struct Greedy;

impl Scheduler for Greedy {
    fn name(&self) -> &str {
        "greedy"
    }

    fn key(&self) -> &str {
        "greedy"
    }

    fn schedule(&self, scenario: &Scenario) -> Result<Plan, EngineError> {
        let r = greedy::optimize(
            scenario.platform(),
            scenario.workload(),
            OptFlags::NONE,
            scenario.objective(),
        );
        Ok(scenario.plan_scored(
            self.key(),
            r.alloc,
            OptFlags::NONE,
            0,
            r.objective_value,
        ))
    }
}

/// MCMComm-GA (§6.2): genetic search over the §6.2 trust region, scored
/// by the true evaluator under the scenario's requested flags.
#[derive(Debug, Clone)]
pub struct Ga {
    pub params: GaParams,
    pub seed: u64,
}

impl Ga {
    pub fn new(params: GaParams, seed: u64) -> Self {
        Ga { params, seed }
    }

    pub fn seeded(seed: u64) -> Self {
        Ga { params: GaParams::default(), seed }
    }
}

impl Scheduler for Ga {
    fn name(&self) -> &str {
        "MCMComm-GA"
    }

    fn key(&self) -> &str {
        "ga"
    }

    fn effective_flags(&self, requested: OptFlags) -> OptFlags {
        requested
    }

    fn schedule(&self, scenario: &Scenario) -> Result<Plan, EngineError> {
        let flags = self.effective_flags(scenario.flags());
        let mut params = self.params.clone();
        params.seed = self.seed;
        let r = ga::optimize(
            scenario.platform(),
            scenario.workload(),
            flags,
            scenario.objective(),
            &params,
        );
        Ok(scenario.plan_scored(
            self.key(),
            r.alloc,
            flags,
            self.seed,
            r.objective_value,
        ))
    }
}

/// MCMComm-MIQP (§6.3): surrogate MIQP + branch & bound, re-scored on
/// the true evaluator (anytime semantics bounded by `budget`).
#[derive(Debug, Clone)]
pub struct Miqp {
    pub budget: Duration,
    pub seed: u64,
}

impl Miqp {
    pub fn new(budget: Duration, seed: u64) -> Self {
        Miqp { budget, seed }
    }

    pub fn seeded(seed: u64) -> Self {
        Miqp { budget: Duration::from_secs(20), seed }
    }
}

impl Scheduler for Miqp {
    fn name(&self) -> &str {
        "MCMComm-MIQP"
    }

    fn key(&self) -> &str {
        "miqp"
    }

    fn effective_flags(&self, requested: OptFlags) -> OptFlags {
        requested
    }

    fn schedule(&self, scenario: &Scenario) -> Result<Plan, EngineError> {
        let flags = self.effective_flags(scenario.flags());
        let r = miqp::optimize(
            scenario.platform(),
            scenario.workload(),
            flags,
            scenario.objective(),
            self.budget,
            self.seed,
        );
        Ok(scenario.plan_scored(
            self.key(),
            r.alloc,
            flags,
            self.seed,
            r.objective_value,
        ))
    }
}

/// MCMComm-ILP: task-grained linear surrogate over the link graph +
/// branch & bound over the LP relaxation ([`crate::opt::ilp`]),
/// re-scored on the true evaluator. Beats-or-ties MIQP by construction
/// (the MIQP decode is in its candidate set). The seed is provenance
/// only: the solver uses fixed internal seeds, so equal scenarios
/// produce equal plans across seeds and thread counts.
#[derive(Debug, Clone)]
pub struct Ilp {
    pub budget: Duration,
    pub seed: u64,
}

impl Ilp {
    pub fn new(budget: Duration, seed: u64) -> Self {
        Ilp { budget, seed }
    }

    pub fn seeded(seed: u64) -> Self {
        Ilp { budget: Duration::from_secs(20), seed }
    }
}

impl Scheduler for Ilp {
    fn name(&self) -> &str {
        "MCMComm-ILP"
    }

    fn key(&self) -> &str {
        "ilp"
    }

    fn effective_flags(&self, requested: OptFlags) -> OptFlags {
        requested
    }

    fn schedule(&self, scenario: &Scenario) -> Result<Plan, EngineError> {
        let flags = self.effective_flags(scenario.flags());
        let r = ilp::optimize(
            scenario.platform(),
            scenario.workload(),
            flags,
            scenario.objective(),
            self.budget,
            self.seed,
        );
        Ok(scenario.plan_scored(
            self.key(),
            r.alloc,
            flags,
            self.seed,
            r.objective_value,
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::models::alexnet;

    #[test]
    fn table3_flag_gating() {
        assert_eq!(Baseline.effective_flags(OptFlags::ALL), OptFlags::NONE);
        assert_eq!(SimbaLike.effective_flags(OptFlags::ALL), OptFlags::NONE);
        assert_eq!(Greedy.effective_flags(OptFlags::ALL), OptFlags::NONE);
        assert_eq!(
            Ga::seeded(1).effective_flags(OptFlags::ALL),
            OptFlags::ALL
        );
        assert_eq!(
            Miqp::seeded(1).effective_flags(OptFlags::ALL),
            OptFlags::ALL
        );
        assert_eq!(
            Ilp::seeded(1).effective_flags(OptFlags::ALL),
            OptFlags::ALL
        );
    }

    #[test]
    fn baseline_plan_is_uniform_and_scored() {
        let scenario = Scenario::headline(alexnet(1));
        let plan = Baseline.schedule(&scenario).unwrap();
        assert_eq!(plan.scheduler, "baseline");
        assert_eq!(plan.flags, OptFlags::NONE);
        assert!(plan.objective_value > 0.0);
        let uni = uniform_allocation(scenario.platform(), scenario.workload());
        assert_eq!(plan.alloc, uni);
    }
}
