//! [`Report`]: the scored outcome of a plan — full cost breakdown,
//! per-op diagnostics and derived metrics. This is the public face of
//! `cost::evaluator::evaluate`, which production call sites no longer
//! touch directly.

use crate::cost::evaluator::{
    evaluate, CostBreakdown, Objective, OpCost, OptFlags,
};
use crate::partition::Allocation;
use crate::platform::Platform;
use crate::workload::{ModelSpan, Workload};

/// Crate-internal bridge to the low-level evaluator; everything outside
/// the `cost` module goes through [`Report`] / [`super::Scenario`].
pub(crate) fn modeled_breakdown(
    plat: &Platform,
    wl: &Workload,
    alloc: &Allocation,
    flags: OptFlags,
) -> CostBreakdown {
    evaluate(plat, wl, alloc, flags)
}

/// Cost attributed to one constituent model of a (possibly fused)
/// workload — the per-model rows of a multi-model report.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelTotal {
    pub model: String,
    pub latency_ns: f64,
    pub energy_pj: f64,
    pub ops: usize,
}

impl ModelTotal {
    /// Energy-delay product of this model's share in pJ·ns.
    pub fn edp(&self) -> f64 {
        self.latency_ns * self.energy_pj
    }
}

/// End-to-end cost report for one (scenario, plan) pair.
#[derive(Debug, Clone)]
pub struct Report {
    /// Scheduler provenance (registry key, or `"manual"`).
    pub scheduler: String,
    /// Effective flags the allocation was scored under.
    pub flags: OptFlags,
    /// Objective the scenario optimizes.
    pub objective: Objective,
    /// Full eq.-3 cost decomposition.
    pub breakdown: CostBreakdown,
    /// Model provenance of the scored workload: one span per
    /// constituent model ([`crate::workload::Workload::model_spans`]),
    /// so multi-model sweeps report one total per tenant.
    pub models: Vec<ModelSpan>,
}

impl Report {
    pub fn latency_ns(&self) -> f64 {
        self.breakdown.latency_ns
    }

    pub fn energy_pj(&self) -> f64 {
        self.breakdown.energy_pj
    }

    /// Energy-delay product in pJ·ns.
    pub fn edp(&self) -> f64 {
        self.breakdown.edp()
    }

    /// The scenario objective evaluated on this breakdown — bit-identical
    /// to `evaluate(..).objective(..)` on the same allocation.
    pub fn objective_value(&self) -> f64 {
        self.breakdown.objective(self.objective)
    }

    /// Per-op cost decomposition (diagnostics, pipelining inputs).
    pub fn per_op(&self) -> &[OpCost] {
        &self.breakdown.per_op
    }

    /// Number of ops whose activations arrived by on-package
    /// redistribution (§5.2).
    pub fn redistributed_ops(&self) -> usize {
        self.breakdown
            .per_op
            .iter()
            .filter(|o| o.redistributed_in)
            .count()
    }

    /// Check this report's end-to-end latency against the plan-level
    /// discrete-event simulator: re-executes `plan` on `scenario`'s
    /// platform in conformance mode and grades the
    /// simulated-vs-analytical ratio against the scheduler's tolerance
    /// band (`netsim::conformance::scheme_tolerance`; DESIGN.md
    /// §Validation). `scenario` and `plan` must be the pair this report
    /// was derived from — enforced: the analytical side is re-derived
    /// from them, and the single-evaluator rule makes it bit-identical
    /// to this report, so any mismatch is a structured error rather
    /// than a silently mis-attributed verdict.
    pub fn validate_against_sim(
        &self,
        scenario: &crate::engine::Scenario,
        plan: &crate::engine::Plan,
    ) -> crate::util::error::Result<crate::netsim::conformance::Conformance>
    {
        let c = crate::netsim::conformance::check_plan(scenario, plan)?;
        if c.analytical_ns.to_bits() != self.latency_ns().to_bits() {
            return Err(crate::err!(
                "validate_against_sim: (scenario, plan) re-derives \
                 latency {} ns but this report holds {} ns — the pair \
                 does not correspond to this report",
                c.analytical_ns,
                self.latency_ns()
            ));
        }
        Ok(c)
    }

    /// Per-model cost attribution: one [`ModelTotal`] per constituent
    /// span (single-model workloads yield one row covering everything).
    /// The rows sum to the fused totals up to floating-point
    /// association (each row sums its own op range).
    pub fn model_totals(&self) -> Vec<ModelTotal> {
        self.models
            .iter()
            .map(|span| {
                let ops = &self.breakdown.per_op
                    [span.ops.start.min(self.breakdown.per_op.len())
                        ..span.ops.end.min(self.breakdown.per_op.len())];
                ModelTotal {
                    model: span.name.clone(),
                    latency_ns: ops.iter().map(|o| o.latency_ns).sum(),
                    energy_pj: ops.iter().map(|o| o.energy_pj).sum(),
                    ops: ops.len(),
                }
            })
            .collect()
    }
}
