//! [`Plan`]: a scheduling outcome — the allocation plus its provenance
//! (which scheduler, which effective flags, which seed) and the
//! true-evaluator score it was accepted at.

use crate::cost::evaluator::{Objective, OptFlags};
use crate::partition::Allocation;

/// The output of [`super::Scheduler::schedule`].
#[derive(Debug, Clone)]
pub struct Plan {
    /// Per-op partitions + collection columns.
    pub alloc: Allocation,
    /// Registry key of the scheduler that produced this plan.
    pub scheduler: String,
    /// The *effective* flags the plan was scored under (non-MCMComm
    /// schedulers force [`OptFlags::NONE`], Table 3).
    pub flags: OptFlags,
    /// RNG seed provenance (0 for deterministic schedulers).
    pub seed: u64,
    /// Objective the scheduler optimized.
    pub objective: Objective,
    /// True-evaluator score of `alloc` under `flags` and `objective`.
    pub objective_value: f64,
}
