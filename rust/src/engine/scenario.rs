//! [`Scenario`]: the validated problem statement of one engine run —
//! platform + workload + co-optimization flags + objective — replacing
//! the ad-hoc `(hw, topo, wl, flags, objective)` argument tuples the
//! seed crate passed around.
//!
//! The hardware half is a [`Platform`] (data-driven packaging: grid,
//! link classes, arbitrary memory-attachment sets, precomputed hop
//! tables). The legacy [`HwConfig`] / `SystemType` spellings remain as
//! thin constructors: [`ScenarioBuilder::system`] / `mem` / `grid`
//! compose a preset, [`ScenarioBuilder::hw`] expands a full config, and
//! [`ScenarioBuilder::platform`] takes any platform — including one
//! loaded from JSON (`--platform file.json`).

use crate::config::{HwConfig, MemKind, SystemType};
use crate::cost::evaluator::{Objective, OptFlags};
use crate::partition::Allocation;
use crate::platform::Platform;
use crate::workload::Workload;

use super::plan::Plan;
use super::report::{modeled_breakdown, Report};
use super::EngineError;

/// A complete, validated co-optimization scenario. Construct via
/// [`Scenario::builder`]; every accessor is cheap.
#[derive(Debug, Clone)]
pub struct Scenario {
    plat: Platform,
    wl: Workload,
    flags: OptFlags,
    objective: Objective,
}

impl Scenario {
    pub fn builder() -> ScenarioBuilder {
        ScenarioBuilder::default()
    }

    /// The paper's headline evaluation point: 4x4 type-A HBM, all §5
    /// co-optimizations requested, latency objective.
    pub fn headline(wl: Workload) -> Scenario {
        Scenario::builder()
            .workload(wl)
            .build()
            .expect("headline scenario is always valid")
    }

    /// The steady-state serving point: the headline platform with all
    /// §5 co-optimizations, but optimizing sustained samples/s through
    /// the pipelined engine ([`crate::steady`]) instead of single-batch
    /// makespan.
    pub fn throughput(wl: Workload) -> Scenario {
        Scenario::builder()
            .workload(wl)
            .objective(Objective::Throughput)
            .build()
            .expect("throughput scenario is always valid")
    }

    /// The hardware platform (packaging description + precomputed hop
    /// tables).
    pub fn platform(&self) -> &Platform {
        &self.plat
    }

    pub fn workload(&self) -> &Workload {
        &self.wl
    }

    /// The *requested* co-optimization flags; schedulers that predate
    /// the MCMComm optimizations (Table 3) ignore them.
    pub fn flags(&self) -> OptFlags {
        self.flags
    }

    pub fn objective(&self) -> Objective {
        self.objective
    }

    /// Short system label, e.g. `A-HBM-4x4` for presets or the
    /// platform's own name for custom descriptions (figure tables).
    pub fn label(&self) -> String {
        self.plat.name.clone()
    }

    /// Stable content fingerprint of the whole problem statement:
    /// platform description, workload graph, requested flags and
    /// objective. Two scenarios with equal fingerprints are solved to
    /// bit-identical plans by any deterministic scheduler, which is
    /// what lets the serving layer's plan cache
    /// ([`crate::serving::PlanCache`]) return cached plans without
    /// re-validating them.
    pub fn fingerprint(&self) -> u64 {
        let mut h = crate::util::hash::Fnv1a::new();
        h.write_u64(self.plat.fingerprint());
        h.write_u64(self.wl.fingerprint());
        h.write_bool(self.flags.diagonal);
        h.write_bool(self.flags.redistribution);
        h.write_bool(self.flags.async_fusion);
        h.write_u8(match self.objective {
            Objective::Latency => 0,
            Objective::Edp => 1,
            Objective::Throughput => 2,
            Objective::EdpPerSample => 3,
        });
        h.finish()
    }

    /// Execute a plan on the plan-level discrete-event simulator
    /// (conformance mode: layer-sequential barriers, zero hop latency —
    /// the configuration comparable to [`Scenario::report`]). See
    /// [`Scenario::simulate_with`] for other modes.
    pub fn simulate(
        &self,
        plan: &Plan,
    ) -> crate::util::error::Result<crate::netsim::sim::SimReport> {
        self.simulate_with(plan, &crate::netsim::sim::SimConfig::default())
    }

    /// [`Scenario::simulate`] with explicit simulation knobs (overlap
    /// mode, per-hop latency).
    pub fn simulate_with(
        &self,
        plan: &Plan,
        cfg: &crate::netsim::sim::SimConfig,
    ) -> crate::util::error::Result<crate::netsim::sim::SimReport> {
        crate::netsim::conformance::simulate_scenario_plan(self, plan, cfg)
    }

    /// Score a plan on the single-source-of-truth evaluator.
    pub fn report(&self, plan: &Plan) -> Report {
        Report {
            scheduler: plan.scheduler.clone(),
            flags: plan.flags,
            objective: self.objective,
            breakdown: modeled_breakdown(
                &self.plat, &self.wl, &plan.alloc, plan.flags,
            ),
            models: self.wl.model_spans(),
        }
    }

    /// Score an arbitrary allocation under explicit flags (figure
    /// harnesses, ablations, hand-written allocations).
    pub fn report_allocation(
        &self,
        alloc: &Allocation,
        flags: OptFlags,
    ) -> Report {
        Report {
            scheduler: "manual".to_string(),
            flags,
            objective: self.objective,
            breakdown: modeled_breakdown(&self.plat, &self.wl, alloc, flags),
            models: self.wl.model_spans(),
        }
    }

    /// The uniform layer-sequential reference point (no optimizations).
    pub fn baseline_report(&self) -> Report {
        let alloc = crate::partition::uniform_allocation(&self.plat, &self.wl);
        let mut r = self.report_allocation(&alloc, OptFlags::NONE);
        r.scheduler = "baseline".to_string();
        r
    }

    /// Assemble a [`Plan`], scoring `alloc` on the true evaluator —
    /// the constructor custom [`crate::engine::Scheduler`]
    /// implementations should use, so `Plan::objective_value` is always
    /// consistent with the allocation and flags it carries.
    pub fn plan(
        &self,
        scheduler: &str,
        alloc: Allocation,
        flags: OptFlags,
        seed: u64,
    ) -> Plan {
        let objective_value =
            modeled_breakdown(&self.plat, &self.wl, &alloc, flags)
                .objective(self.objective);
        Plan {
            scheduler: scheduler.to_string(),
            alloc,
            flags,
            seed,
            objective: self.objective,
            objective_value,
        }
    }

    /// Like [`Scenario::plan`] but trusting a solver-reported score
    /// (already produced by the true evaluator inside the solver).
    pub(crate) fn plan_scored(
        &self,
        scheduler: &str,
        alloc: Allocation,
        flags: OptFlags,
        seed: u64,
        objective_value: f64,
    ) -> Plan {
        Plan {
            scheduler: scheduler.to_string(),
            alloc,
            flags,
            seed,
            objective: self.objective,
            objective_value,
        }
    }
}

/// Builder for [`Scenario`]. Pick the hardware through exactly one of
/// three spellings, most to least specific:
/// [`ScenarioBuilder::platform`] (any [`Platform`], including JSON
/// files), [`ScenarioBuilder::hw`] (a full legacy [`HwConfig`]), or
/// [`ScenarioBuilder::system`] / [`ScenarioBuilder::mem`] /
/// [`ScenarioBuilder::grid`] (paper Table-2 preset defaults).
#[derive(Debug, Clone)]
pub struct ScenarioBuilder {
    plat: Option<Platform>,
    hw: Option<HwConfig>,
    ty: SystemType,
    mem: MemKind,
    grid: usize,
    wl: Option<Workload>,
    flags: OptFlags,
    objective: Objective,
}

impl Default for ScenarioBuilder {
    fn default() -> Self {
        ScenarioBuilder {
            plat: None,
            hw: None,
            ty: SystemType::A,
            mem: MemKind::Hbm,
            grid: 4,
            wl: None,
            flags: OptFlags::ALL,
            objective: Objective::Latency,
        }
    }
}

impl ScenarioBuilder {
    /// Use a fully custom platform (overrides `hw`/`system`/`mem`/
    /// `grid`). The platform is already validated by construction.
    pub fn platform(mut self, plat: Platform) -> Self {
        self.plat = Some(plat);
        self
    }

    /// Use a legacy hardware configuration (overrides
    /// `system`/`mem`/`grid`); expanded onto a [`Platform`] at build
    /// time.
    pub fn hw(mut self, hw: HwConfig) -> Self {
        self.hw = Some(hw);
        self
    }

    pub fn system(mut self, ty: SystemType) -> Self {
        self.ty = ty;
        self
    }

    pub fn mem(mut self, mem: MemKind) -> Self {
        self.mem = mem;
        self
    }

    pub fn grid(mut self, grid: usize) -> Self {
        self.grid = grid;
        self
    }

    pub fn workload(mut self, wl: Workload) -> Self {
        self.wl = Some(wl);
        self
    }

    pub fn flags(mut self, flags: OptFlags) -> Self {
        self.flags = flags;
        self
    }

    pub fn objective(mut self, objective: Objective) -> Self {
        self.objective = objective;
        self
    }

    /// Validate everything and assemble the scenario.
    pub fn build(self) -> Result<Scenario, EngineError> {
        let plat = match (self.plat, self.hw) {
            (Some(plat), _) => plat,
            (None, Some(hw)) => hw
                .platform()
                .map_err(EngineError::InvalidHardware)?,
            (None, None) => {
                HwConfig::paper(self.ty, self.mem, self.grid)
                    .platform()
                    .map_err(EngineError::InvalidHardware)?
            }
        };
        let wl = self.wl.ok_or(EngineError::MissingWorkload)?;
        wl.validate().map_err(EngineError::InvalidWorkload)?;
        Ok(Scenario {
            plat,
            wl,
            flags: self.flags,
            objective: self.objective,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::platform::MemAttachment;
    use crate::workload::models::alexnet;
    use crate::workload::{GemmOp, Workload};

    #[test]
    fn headline_defaults() {
        let s = Scenario::headline(alexnet(1));
        assert_eq!(s.platform().xdim, 4);
        assert_eq!(s.platform().globals().len(), 1);
        assert_eq!(s.flags(), OptFlags::ALL);
        assert_eq!(s.objective(), Objective::Latency);
        assert_eq!(s.label(), "A-HBM-4x4");
    }

    #[test]
    fn builder_rejects_zero_grid() {
        let err = Scenario::builder()
            .grid(0)
            .workload(alexnet(1))
            .build()
            .unwrap_err();
        assert!(matches!(err, EngineError::InvalidHardware(_)), "{err}");
    }

    #[test]
    fn builder_rejects_invalid_bandwidth() {
        let mut hw = HwConfig::default_4x4_hbm();
        hw.bw_nop = 0.0;
        let err = Scenario::builder()
            .hw(hw)
            .workload(alexnet(1))
            .build()
            .unwrap_err();
        assert!(matches!(err, EngineError::InvalidHardware(_)), "{err}");
    }

    #[test]
    fn builder_requires_workload() {
        let err = Scenario::builder().build().unwrap_err();
        assert!(matches!(err, EngineError::MissingWorkload));
    }

    #[test]
    fn builder_rejects_invalid_workload() {
        let wl = Workload {
            name: "bad".into(),
            ops: vec![GemmOp::dense("z", 0, 16, 16)],
            edges: vec![],
            models: vec![],
        };
        let err = Scenario::builder().workload(wl).build().unwrap_err();
        assert!(matches!(err, EngineError::InvalidWorkload(_)), "{err}");
    }

    #[test]
    fn builder_accepts_custom_platform() {
        let mut spec = Platform::headline().spec().clone();
        spec.name = "custom".into();
        spec.attachments = vec![
            MemAttachment::new(0, 0, 750.0),
            MemAttachment::new(2, 3, 250.0),
        ];
        let plat = Platform::new(spec).unwrap();
        let s = Scenario::builder()
            .platform(plat)
            .workload(alexnet(1))
            .build()
            .unwrap();
        assert_eq!(s.label(), "custom");
        assert_eq!(s.platform().globals().len(), 2);
        // The custom platform reports end to end.
        let r = s.baseline_report();
        assert!(r.latency_ns() > 0.0 && r.energy_pj() > 0.0);
    }

    #[test]
    fn platform_overrides_preset_knobs() {
        let s = Scenario::builder()
            .system(SystemType::D)
            .grid(8)
            .platform(Platform::headline())
            .workload(alexnet(1))
            .build()
            .unwrap();
        assert_eq!(s.label(), "A-HBM-4x4");
    }
}
