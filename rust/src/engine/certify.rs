//! Standalone plan certifier (ROADMAP item 5): a model-independent
//! feasibility checker for any `(Platform, Workload, Allocation,
//! OptFlags)` binding, in the spirit of SCAR's `validate_solution`.
//!
//! The certifier shares **no code with the analytical evaluator**
//! (`cost::evaluator` is never called): it re-derives every
//! communication route directly from the [`LinkGraph`] and re-counts
//! bytes from the workload dims, so a bug in the evaluator (or in a
//! scheduler that games it) cannot silently certify itself. The only
//! shared arithmetic is [`crate::redistribution::step3_boundary_bytes`],
//! which is the declared single source of truth for the step-3 exchange
//! in *both* the closed form and the DES lowering — reusing it here is
//! what lets the certificate's per-link bounds provably dominate the
//! simulator's per-link byte counters.
//!
//! # Checks (violation taxonomy)
//!
//! * **Structural / ordering** — allocation arity matches the op and
//!   edge counts ([`Violation::OrphanedOp`]); every dataflow edge runs
//!   forward in the stored topological order
//!   ([`Violation::DependencyInversion`]); no duplicated `(src, dst)`
//!   pair, i.e. no silent multicast of one producer tensor over two
//!   edges ([`Violation::MulticastEdge`]).
//! * **On-grid partitions** — per-op `px`/`py` arities equal the grid
//!   dims, sums equal `M`/`N`, and every collection column indexes a
//!   real grid column ([`Violation::OffGridPartition`]).
//! * **Memory reachability** — the graph carries at least one memory
//!   node, every platform attachment appears as a `Node::Memory` at the
//!   expected id with the expected attach position, and every
//!   memory↔chiplet route the plan needs actually exists
//!   ([`Violation::UnreachableMemory`]).
//! * **Capacity** — every link the plan puts bytes on has a finite,
//!   positive capacity, and the accumulated per-link byte bound is
//!   finite ([`Violation::CapacityOverflow`]).
//!
//! # The certificate
//!
//! On success the certifier returns a [`Certificate`] whose
//! `link_bound[l]` is a **conservative upper bound** on the bytes the
//! plan-level DES ([`crate::netsim::sim`]) can push over link `l` in
//! one batch, in any [`crate::netsim::SimMode`]. Conservatism comes
//! from charging *both* sides of every adaptive decision the DES may
//! take: a redistribution-legal edge contributes its full 3-step
//! on-package flows *and* the consumer's activation load, and every
//! producer is charged its store — so whichever branch the simulator's
//! `edge_decision` adopts, its bytes are below the bound. Unicast is
//! by construction: every byte is charged along its full single XY
//! route, never shared.

use std::fmt;

use crate::cost::evaluator::OptFlags;
use crate::partition::Allocation;
use crate::platform::Platform;
use crate::topology::links::{LinkGraph, Node};
use crate::topology::Pos;
use crate::workload::Workload;

use super::plan::Plan;

/// One structured infeasibility diagnostic, naming the op / edge / link
/// it implicates.
#[derive(Debug, Clone, PartialEq)]
pub enum Violation {
    /// A partition is off the chiplet grid: wrong `px`/`py` arity,
    /// row/column sums not equal to the op's `M`/`N`, or a collection
    /// column outside the grid.
    OffGridPartition { op: usize, detail: String },
    /// A dataflow edge runs backwards (or self-loops) against the
    /// stored topological order.
    DependencyInversion { edge: usize, src: usize, dst: usize },
    /// Two edges carry the same `(src, dst)` pair — the same producer
    /// tensor would be sent twice (multicast is not allowed).
    MulticastEdge { edge: usize, src: usize, dst: usize },
    /// An op (or edge endpoint) has no partition / no collection
    /// column covering it — the allocation arity does not match the
    /// workload graph.
    OrphanedOp { op: usize, detail: String },
    /// A link the plan needs is overloaded: zero / non-finite capacity
    /// under a positive byte bound, or a non-finite byte bound.
    CapacityOverflow { link: usize, bytes: f64, capacity: f64 },
    /// A memory attachment the plan loads from / stores to is missing
    /// from the link graph, or a required route does not exist.
    UnreachableMemory { detail: String },
}

impl Violation {
    /// Short kind tag (stable across detail-message wording), used by
    /// the corruption-driven property suite.
    pub fn kind(&self) -> &'static str {
        match self {
            Violation::OffGridPartition { .. } => "off-grid-partition",
            Violation::DependencyInversion { .. } => "dependency-inversion",
            Violation::MulticastEdge { .. } => "multicast-edge",
            Violation::OrphanedOp { .. } => "orphaned-op",
            Violation::CapacityOverflow { .. } => "capacity-overflow",
            Violation::UnreachableMemory { .. } => "unreachable-memory",
        }
    }
}

impl fmt::Display for Violation {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Violation::OffGridPartition { op, detail } => {
                write!(f, "off-grid partition for op {op}: {detail}")
            }
            Violation::DependencyInversion { edge, src, dst } => write!(
                f,
                "dependency inversion on edge {edge}: {src} -> {dst} \
                 violates topological order"
            ),
            Violation::MulticastEdge { edge, src, dst } => write!(
                f,
                "multicast: edge {edge} duplicates the ({src}, {dst}) \
                 dataflow pair"
            ),
            Violation::OrphanedOp { op, detail } => {
                write!(f, "orphaned op {op}: {detail}")
            }
            Violation::CapacityOverflow { link, bytes, capacity } => write!(
                f,
                "capacity overflow on link {link}: {bytes:.1} bytes \
                 bound over capacity {capacity} GB/s"
            ),
            Violation::UnreachableMemory { detail } => {
                write!(f, "unreachable memory: {detail}")
            }
        }
    }
}

/// Proof object of a successful certification: the conservative
/// per-link byte bounds plus summary counters. `link_bound[l]`
/// dominates the DES's `link_bytes[l]` for the same binding in every
/// simulation mode (the cross-check in `netsim::conformance` holds the
/// two against each other on every simulated plan).
#[derive(Debug, Clone)]
pub struct Certificate {
    /// Upper bound on bytes crossing each link of the plan's
    /// [`LinkGraph`] (same link ids as `Platform::link_graph_shared`
    /// for the plan's diagonal flag).
    pub link_bound: Vec<f64>,
    /// Number of point-to-point flows charged into the bounds.
    pub flows: usize,
    /// Sum of `link_bound` over all links (byte·hops of the plan).
    pub total_bytes: f64,
    /// Stable fingerprint over (platform, workload, bounds) — two
    /// identical bindings certify to the same fingerprint.
    pub fingerprint: u64,
}

impl Plan {
    /// Certify this plan against `plat` / `wl`: structural checks plus
    /// route/capacity accounting re-derived from the link graph. See
    /// the module docs for the violation taxonomy.
    pub fn validate(
        &self,
        plat: &Platform,
        wl: &Workload,
    ) -> Result<Certificate, Vec<Violation>> {
        certify_allocation(plat, wl, &self.alloc, self.flags)
    }
}

/// Certify an allocation under explicit flags, building the link graph
/// from the platform (the common entry point; [`Plan::validate`] and
/// the CLI `validate` subcommand delegate here).
pub fn certify_allocation(
    plat: &Platform,
    wl: &Workload,
    alloc: &Allocation,
    flags: OptFlags,
) -> Result<Certificate, Vec<Violation>> {
    let graph = plat.link_graph_shared(flags.diagonal);
    certify_on_graph(plat, wl, alloc, flags, &graph)
}

/// [`certify_allocation`] against a caller-provided graph. This is the
/// low-level surface the corruption suite drives: platform validation
/// refuses to *construct* degenerate packages, so capacity-overflow and
/// missing-memory corruption is injected by mutating a built
/// [`LinkGraph`] (its `links` / capacities are public) and certifying
/// against it directly.
pub fn certify_on_graph(
    plat: &Platform,
    wl: &Workload,
    alloc: &Allocation,
    flags: OptFlags,
    graph: &LinkGraph,
) -> Result<Certificate, Vec<Violation>> {
    let mut violations = Vec::new();
    let n_ops = wl.ops.len();
    let n_edges = wl.edges.len();

    // ---- structural: allocation arity covers the graph.
    if alloc.parts.len() != n_ops {
        violations.push(Violation::OrphanedOp {
            op: alloc.parts.len().min(n_ops),
            detail: format!(
                "{} partitions for {} ops",
                alloc.parts.len(),
                n_ops
            ),
        });
    }
    if alloc.collect_cols.len() != n_edges {
        violations.push(Violation::OrphanedOp {
            op: 0,
            detail: format!(
                "{} collection columns for {} edges",
                alloc.collect_cols.len(),
                n_edges
            ),
        });
    }

    // ---- ordering + unicast over the dataflow edges.
    for (e, edge) in wl.edges.iter().enumerate() {
        if edge.src >= n_ops || edge.dst >= n_ops {
            violations.push(Violation::OrphanedOp {
                op: edge.src.max(edge.dst),
                detail: format!(
                    "edge {e} ({} -> {}) references a nonexistent op \
                     (workload has {n_ops})",
                    edge.src, edge.dst
                ),
            });
            continue;
        }
        if edge.src >= edge.dst {
            violations.push(Violation::DependencyInversion {
                edge: e,
                src: edge.src,
                dst: edge.dst,
            });
        }
        for (e2, other) in wl.edges.iter().enumerate().skip(e + 1) {
            if (other.src, other.dst) == (edge.src, edge.dst) {
                violations.push(Violation::MulticastEdge {
                    edge: e2,
                    src: edge.src,
                    dst: edge.dst,
                });
            }
        }
    }

    // ---- on-grid partitions.
    let (xd, yd) = (plat.xdim, plat.ydim);
    for (i, part) in alloc.parts.iter().enumerate().take(n_ops) {
        if part.px.len() != xd || part.py.len() != yd {
            violations.push(Violation::OffGridPartition {
                op: i,
                detail: format!(
                    "partition arity {}x{} vs grid {xd}x{yd}",
                    part.px.len(),
                    part.py.len()
                ),
            });
            continue;
        }
        let op = &wl.ops[i];
        let sx: usize = part.px.iter().sum();
        let sy: usize = part.py.iter().sum();
        if sx != op.m {
            violations.push(Violation::OffGridPartition {
                op: i,
                detail: format!(
                    "sum(px)={sx} != M={} for '{}'",
                    op.m, op.name
                ),
            });
        }
        if sy != op.n {
            violations.push(Violation::OffGridPartition {
                op: i,
                detail: format!(
                    "sum(py)={sy} != N={} for '{}'",
                    op.n, op.name
                ),
            });
        }
    }
    for (e, &c) in alloc.collect_cols.iter().enumerate().take(n_edges) {
        if c >= yd {
            let op = wl.edges.get(e).map_or(0, |edge| edge.src);
            violations.push(Violation::OffGridPartition {
                op,
                detail: format!(
                    "collection column {c} of edge {e} outside the \
                     {yd}-column grid"
                ),
            });
        }
    }

    // ---- memory-attachment reachability.
    let n_chiplets = plat.num_chiplets();
    let atts = &plat.spec().attachments;
    if graph.xdim != xd || graph.ydim != yd {
        violations.push(Violation::UnreachableMemory {
            detail: format!(
                "link graph is {}x{}, platform is {xd}x{yd}",
                graph.xdim, graph.ydim
            ),
        });
    }
    if !graph.nodes.iter().any(|n| matches!(n, Node::Memory { .. })) {
        violations.push(Violation::UnreachableMemory {
            detail: "link graph has no memory node".to_string(),
        });
    } else {
        for (a, att) in atts.iter().enumerate() {
            match graph.nodes.get(n_chiplets + a) {
                Some(Node::Memory { attach }) if *attach == att.pos => {}
                other => violations.push(Violation::UnreachableMemory {
                    detail: format!(
                        "attachment {a} at ({}, {}) expected a memory \
                         node at graph id {}, found {other:?}",
                        att.pos.row,
                        att.pos.col,
                        n_chiplets + a
                    ),
                }),
            }
        }
    }

    // Structural violations make the flow derivation meaningless (and
    // often panicky) — report everything found so far.
    if !violations.is_empty() {
        return Err(violations);
    }

    // ---- flow derivation: conservative per-link byte bounds.
    let mut link_bound = vec![0.0f64; graph.links.len()];
    let mut flows = 0usize;
    let chiplet = |p: Pos| p.row * yd + p.col;
    let att_node = |a: usize| n_chiplets + a;
    let mut route_err: Vec<Violation> = Vec::new();
    let charge = |src: usize,
                      dst: usize,
                      bytes: f64,
                      what: &str,
                      bounds: &mut [f64],
                      flows: &mut usize,
                      errs: &mut Vec<Violation>| {
        if bytes <= 0.0 {
            return;
        }
        match graph.route(src, dst) {
            Ok(links) => {
                for l in links {
                    bounds[l] += bytes;
                }
                *flows += 1;
            }
            Err(e) => errs.push(Violation::UnreachableMemory {
                detail: format!("no route for {what}: {e:#}"),
            }),
        }
    };

    let (mut in_edge, mut out_edge) = (Vec::new(), Vec::new());
    wl.sole_edges_into(&mut in_edge, &mut out_edge);

    for (i, op) in wl.ops.iter().enumerate() {
        let part = &alloc.parts[i];

        // Off-chip load: weights always, activations conservatively
        // always (the DES drops them only when redistribution is
        // adopted). Charged in full on every attachment's memory link —
        // dominates the DES's demand-apportioned shares.
        let off_unique = plat.bytes(op.k * op.n) + plat.bytes(op.m * op.k);
        for (a, att) in atts.iter().enumerate() {
            charge(
                att_node(a),
                chiplet(att.pos),
                off_unique,
                &format!("load of op {i} '{}' from attachment {a}", op.name),
                &mut link_bound,
                &mut flows,
                &mut route_err,
            );
        }

        // On-package distribution: each chiplet pulls its operand slice
        // from its serving global attach point.
        for p in plat.positions() {
            let d = plat.bytes(op.k * part.py[p.col])
                + plat.bytes(part.px[p.row] * op.k);
            charge(
                chiplet(plat.nearest_global(p)),
                chiplet(p),
                d,
                &format!("distribution of op {i} '{}'", op.name),
                &mut link_bound,
                &mut flows,
                &mut route_err,
            );
        }

        // Writeback collection + off-chip store: conservatively always
        // charged (the DES skips the store only when the consumer's
        // redistribution is adopted).
        for p in plat.positions() {
            let b = plat.bytes(part.px[p.row] * part.py[p.col]);
            charge(
                chiplet(p),
                chiplet(plat.nearest_global(p)),
                b,
                &format!("writeback of op {i} '{}'", op.name),
                &mut link_bound,
                &mut flows,
                &mut route_err,
            );
        }
        let out_total = plat.bytes(op.m * op.n);
        for (a, att) in atts.iter().enumerate() {
            charge(
                chiplet(att.pos),
                att_node(a),
                out_total,
                &format!("store of op {i} '{}' to attachment {a}", op.name),
                &mut link_bound,
                &mut flows,
                &mut route_err,
            );
        }
    }

    // §5.2 redistribution: every *legal* edge is charged its full
    // 3-step flows, whether or not the simulator's adaptive decision
    // adopts it (the activation load above covers the other branch).
    if flags.redistribution {
        for (e, edge) in wl.edges.iter().enumerate() {
            if !wl.edge_redistributable_with(e, &in_edge, &out_edge) {
                continue;
            }
            let p_op = &wl.ops[edge.src];
            let p_part = &alloc.parts[edge.src];
            let part = &alloc.parts[edge.dst];
            let c_star = alloc.collect_cols[e];
            // Step 1: row reduction toward c*.
            for x in 0..xd {
                for y in 0..yd {
                    if y == c_star {
                        continue;
                    }
                    charge(
                        chiplet(Pos::new(x, y)),
                        chiplet(Pos::new(x, c_star)),
                        plat.bytes(p_part.px[x] * p_part.py[y]),
                        &format!("redistribution step 1 of edge {e}"),
                        &mut link_bound,
                        &mut flows,
                        &mut route_err,
                    );
                }
            }
            // Step 2: wormhole row broadcast (both directions).
            for x in 0..xd {
                let row_bytes = plat.bytes(p_part.px[x] * p_op.n);
                for far in [0, yd - 1] {
                    if far == c_star {
                        continue;
                    }
                    charge(
                        chiplet(Pos::new(x, c_star)),
                        chiplet(Pos::new(x, far)),
                        row_bytes,
                        &format!("redistribution step 2 of edge {e}"),
                        &mut link_bound,
                        &mut flows,
                        &mut route_err,
                    );
                }
            }
            // Step 3: boundary exchange (shared single source of truth
            // with both the closed form and the DES lowering).
            let bnd = crate::redistribution::step3_boundary_bytes(
                plat, p_op, p_part, part,
            );
            for (b, &bytes) in bnd.iter().enumerate() {
                charge(
                    chiplet(Pos::new(b, c_star)),
                    chiplet(Pos::new(b + 1, c_star)),
                    bytes,
                    &format!("redistribution step 3 of edge {e}"),
                    &mut link_bound,
                    &mut flows,
                    &mut route_err,
                );
            }
        }
    }
    violations.extend(route_err);

    // ---- capacity: every loaded link must be able to drain.
    for (l, link) in graph.links.iter().enumerate() {
        let b = link_bound[l];
        if b > 0.0 && (!link.capacity.is_finite() || link.capacity <= 0.0) {
            violations.push(Violation::CapacityOverflow {
                link: l,
                bytes: b,
                capacity: link.capacity,
            });
        }
        if !b.is_finite() {
            violations.push(Violation::CapacityOverflow {
                link: l,
                bytes: b,
                capacity: link.capacity,
            });
        }
    }

    if !violations.is_empty() {
        return Err(violations);
    }

    let total_bytes: f64 = link_bound.iter().sum();
    let mut h = crate::util::hash::Fnv1a::new();
    h.write_u64(plat.fingerprint());
    h.write_u64(wl.fingerprint());
    h.write_len(link_bound.len());
    for &b in &link_bound {
        h.write_u64(b.to_bits());
    }
    Ok(Certificate {
        link_bound,
        flows,
        total_bytes,
        fingerprint: h.finish(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::partition::uniform_allocation;
    use crate::workload::models::alexnet;

    #[test]
    fn uniform_alexnet_certifies() {
        let plat = Platform::headline();
        let wl = alexnet(1);
        let alloc = uniform_allocation(&plat, &wl);
        let cert = certify_allocation(&plat, &wl, &alloc, OptFlags::ALL)
            .expect("uniform allocation certifies");
        assert!(cert.total_bytes > 0.0 && cert.flows > 0);
        assert_eq!(
            cert.link_bound.len(),
            plat.link_graph_shared(true).links.len()
        );
        // Deterministic proof object.
        let again = certify_allocation(&plat, &wl, &alloc, OptFlags::ALL)
            .unwrap();
        assert_eq!(cert.fingerprint, again.fingerprint);
    }

    #[test]
    fn off_grid_sum_rejected() {
        let plat = Platform::headline();
        let wl = alexnet(1);
        let mut alloc = uniform_allocation(&plat, &wl);
        alloc.parts[2].px[0] += 1;
        let errs = certify_allocation(&plat, &wl, &alloc, OptFlags::ALL)
            .unwrap_err();
        assert!(errs.iter().any(|v| matches!(
            v,
            Violation::OffGridPartition { op: 2, .. }
        )));
    }

    #[test]
    fn arity_mismatch_is_orphaned_op() {
        let plat = Platform::headline();
        let wl = alexnet(1);
        let mut alloc = uniform_allocation(&plat, &wl);
        alloc.parts.pop();
        let errs = certify_allocation(&plat, &wl, &alloc, OptFlags::ALL)
            .unwrap_err();
        assert!(errs
            .iter()
            .any(|v| matches!(v, Violation::OrphanedOp { .. })));
    }

    #[test]
    fn corrupted_capacity_rejected() {
        let plat = Platform::headline();
        let wl = alexnet(1);
        let alloc = uniform_allocation(&plat, &wl);
        let mut graph = (*plat.link_graph_shared(true)).clone();
        // Saturate the first memory link (from the memory node).
        let mem = plat.num_chiplets();
        let l = graph
            .links
            .iter()
            .position(|lk| lk.from == mem)
            .expect("memory link");
        graph.links[l].capacity = 0.0;
        let errs =
            certify_on_graph(&plat, &wl, &alloc, OptFlags::ALL, &graph)
                .unwrap_err();
        assert!(errs.iter().any(
            |v| matches!(v, Violation::CapacityOverflow { link, .. } if *link == l)
        ));
    }

    #[test]
    fn violation_kinds_are_stable() {
        let v = Violation::CapacityOverflow {
            link: 3,
            bytes: 10.0,
            capacity: 0.0,
        };
        assert_eq!(v.kind(), "capacity-overflow");
        assert!(v.to_string().contains("link 3"));
    }
}
