//! The engine API: the crate's front door.
//!
//! Three nouns (paper framing: one analytical pipeline from packaging
//! config through scheduling to reports):
//!
//! * [`Scenario`] — validated problem statement: platform (data-driven
//!   packaging) + workload + requested co-optimization flags +
//!   objective.
//! * [`Plan`] — a scheduling outcome with provenance (scheduler key,
//!   effective flags, seed) and its true-evaluator score.
//! * [`Report`] — full cost breakdown + per-op diagnostics + EDP.
//!
//! One verb: [`Scheduler::schedule`], implemented by the five Table-3
//! schemes plus the task-grained ILP in [`schedulers`] and discovered
//! through [`SchedulerRegistry`]. Any plan from any scheduler can be
//! mechanically certified by [`Plan::validate`] (module [`certify`]).
//!
//! ```no_run
//! use mcmcomm::engine::{Engine, Scenario, SchedulerRegistry};
//! use mcmcomm::workload::models::alexnet;
//!
//! let engine = Engine::new(Scenario::headline(alexnet(1)));
//! let registry = SchedulerRegistry::standard(42);
//! let report = engine
//!     .schedule_with(registry.require("ga").unwrap())
//!     .unwrap()
//!     .report();
//! println!("latency {:.3} ms", report.latency_ns() / 1e6);
//! ```

pub mod certify;
mod plan;
mod registry;
mod report;
mod scenario;
pub mod scheduler;

pub use certify::{certify_allocation, certify_on_graph, Certificate,
                  Violation};
pub use plan::Plan;
pub use registry::SchedulerRegistry;
pub use report::{ModelTotal, Report};
pub use scenario::{Scenario, ScenarioBuilder};
pub use scheduler::Scheduler;

/// The Table-3 scheduler implementations plus the task-grained ILP.
pub mod schedulers {
    pub use super::scheduler::{Baseline, Ga, Greedy, Ilp, Miqp, SimbaLike};
}

pub(crate) use report::modeled_breakdown;

use std::fmt;

/// Engine-level failures: invalid scenarios, unknown schedulers,
/// schedulers returning malformed plans.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum EngineError {
    /// The scenario builder was not given a workload.
    MissingWorkload,
    /// Hardware validation failed (zero grid, non-positive bandwidth…).
    InvalidHardware(String),
    /// Workload validation failed (zero dims, bad chaining…).
    InvalidWorkload(String),
    /// Registry lookup failed.
    UnknownScheduler { name: String, known: String },
    /// A scheduler produced an allocation that does not validate.
    InvalidPlan { scheduler: String, reason: String },
}

impl fmt::Display for EngineError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            EngineError::MissingWorkload => {
                write!(f, "scenario has no workload")
            }
            EngineError::InvalidHardware(m) => {
                write!(f, "invalid hardware config: {m}")
            }
            EngineError::InvalidWorkload(m) => {
                write!(f, "invalid workload: {m}")
            }
            EngineError::UnknownScheduler { name, known } => {
                write!(f, "unknown scheduler '{name}' (known: {known})")
            }
            EngineError::InvalidPlan { scheduler, reason } => {
                write!(f, "scheduler '{scheduler}' produced an invalid \
                           plan: {reason}")
            }
        }
    }
}

impl std::error::Error for EngineError {}

/// The orchestrator: owns a [`Scenario`] and drives schedulers over it.
#[derive(Debug, Clone)]
pub struct Engine {
    scenario: Scenario,
}

impl Engine {
    pub fn new(scenario: Scenario) -> Engine {
        Engine { scenario }
    }

    pub fn scenario(&self) -> &Scenario {
        &self.scenario
    }

    /// Run one scheduler; the returned [`Planned`] borrows the scenario
    /// so `.report()` needs no extra arguments.
    pub fn schedule_with(
        &self,
        scheduler: &dyn Scheduler,
    ) -> Result<Planned<'_>, EngineError> {
        let plan = scheduler.schedule(&self.scenario)?;
        plan.alloc
            .validate(self.scenario.workload(), self.scenario.platform())
            .map_err(|reason| EngineError::InvalidPlan {
                scheduler: scheduler.key().to_string(),
                reason,
            })?;
        Ok(Planned { scenario: &self.scenario, plan })
    }

    /// Registry-keyed convenience for [`Engine::schedule_with`].
    pub fn schedule(
        &self,
        registry: &SchedulerRegistry,
        name: &str,
    ) -> Result<Planned<'_>, EngineError> {
        self.schedule_with(registry.require(name)?)
    }

    /// Batch API: run every scheduler on every scenario. One row per
    /// scenario, outcomes in scheduler order — the substrate of the
    /// figure harnesses and design-space sweeps. Outcomes carry plans
    /// (with their solver-accepted scores); full [`Report`]s are
    /// derived on demand via [`SweepRow::report`], not eagerly.
    ///
    /// Scenarios are scheduled in parallel across worker threads (auto
    /// thread count); rows come back in scenario order and every value
    /// is bit-identical to a sequential run for deterministic
    /// schedulers (see [`Engine::sweep_threaded`]).
    pub fn sweep(
        scenarios: impl IntoIterator<Item = Scenario>,
        schedulers: &[&dyn Scheduler],
    ) -> Result<Vec<SweepRow>, EngineError> {
        Self::sweep_threaded(scenarios, schedulers, 0)
    }

    /// [`Engine::sweep`] with an explicit worker count: `0` = auto
    /// (`MCMCOMM_THREADS` env or machine parallelism), `1` = fully
    /// sequential. Each scenario is one work item; schedulers run in
    /// registration order inside it, and no RNG state crosses threads
    /// (every scheduler reseeds from its owned seed per call), so
    /// thread count cannot change a deterministic scheduler's output
    /// bits — pinned by `tests/perf_equivalence.rs`.
    pub fn sweep_threaded(
        scenarios: impl IntoIterator<Item = Scenario>,
        schedulers: &[&dyn Scheduler],
        threads: usize,
    ) -> Result<Vec<SweepRow>, EngineError> {
        let scenarios: Vec<Scenario> = scenarios.into_iter().collect();
        let workers = crate::util::par::resolve_threads(threads);
        let rows = crate::util::par::par_map(
            workers,
            &scenarios,
            |_, scenario| -> Result<SweepRow, EngineError> {
                let engine = Engine::new(scenario.clone());
                let mut outcomes = Vec::with_capacity(schedulers.len());
                for &s in schedulers {
                    let planned = engine.schedule_with(s)?;
                    outcomes.push(SweepOutcome {
                        scheduler: s.key().to_string(),
                        plan: planned.into_plan(),
                    });
                }
                Ok(SweepRow { scenario: engine.into_scenario(), outcomes })
            },
        );
        rows.into_iter().collect()
    }

    /// Take the scenario back out of the engine.
    pub fn into_scenario(self) -> Scenario {
        self.scenario
    }
}

/// A plan still attached to its scenario: score it, inspect it, or take
/// the plan out.
#[derive(Debug, Clone)]
pub struct Planned<'a> {
    scenario: &'a Scenario,
    plan: Plan,
}

impl Planned<'_> {
    pub fn plan(&self) -> &Plan {
        &self.plan
    }

    pub fn into_plan(self) -> Plan {
        self.plan
    }

    /// The solver-accepted objective score.
    pub fn objective_value(&self) -> f64 {
        self.plan.objective_value
    }

    /// Full cost report (re-derived from the single-source-of-truth
    /// evaluator; bit-identical to the score the scheduler accepted).
    pub fn report(&self) -> Report {
        self.scenario.report(&self.plan)
    }
}

/// One (scenario × scheduler) result inside a sweep.
#[derive(Debug, Clone)]
pub struct SweepOutcome {
    pub scheduler: String,
    pub plan: Plan,
}

/// One scenario's sweep results.
#[derive(Debug, Clone)]
pub struct SweepRow {
    pub scenario: Scenario,
    pub outcomes: Vec<SweepOutcome>,
}

impl SweepRow {
    /// Workload name (figure-table "model" column). For fused
    /// multi-model scenarios this is the `a+b+…` composite name; see
    /// [`SweepRow::models`] for the constituents.
    pub fn model(&self) -> &str {
        &self.scenario.workload().name
    }

    /// Constituent model names (provenance): one entry per
    /// [`crate::workload::ModelSpan`] of the scheduled workload.
    pub fn models(&self) -> Vec<String> {
        self.scenario
            .workload()
            .model_spans()
            .into_iter()
            .map(|s| s.name)
            .collect()
    }

    /// System label (figure-table "system" column), e.g. `A-HBM-4x4`.
    pub fn system(&self) -> String {
        self.scenario.label()
    }

    pub fn outcome(&self, key: &str) -> Option<&SweepOutcome> {
        self.outcomes.iter().find(|o| o.scheduler == key)
    }

    /// Full cost report for one outcome, derived on demand.
    pub fn report(&self, key: &str) -> Option<Report> {
        self.outcome(key).map(|o| self.scenario.report(&o.plan))
    }

    /// Objective values normalized to `baseline_key` (baseline == 1.0,
    /// lower is better). `None` if the baseline is absent.
    pub fn normalized_to(
        &self,
        baseline_key: &str,
    ) -> Option<Vec<(String, f64)>> {
        let base = self.outcome(baseline_key)?.plan.objective_value;
        Some(
            self.outcomes
                .iter()
                .map(|o| {
                    (o.scheduler.clone(), o.plan.objective_value / base)
                })
                .collect(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cost::evaluator::Objective;
    use crate::workload::models::alexnet;

    #[test]
    fn schedule_then_report_round_trip() {
        let engine = Engine::new(Scenario::headline(alexnet(1)));
        let planned =
            engine.schedule_with(&schedulers::Baseline).unwrap();
        let report = planned.report();
        assert_eq!(report.scheduler, "baseline");
        // The report re-derives exactly the score the plan was accepted
        // at (same evaluator, same inputs — bit-identical).
        assert_eq!(report.objective_value(), planned.objective_value());
        assert!(report.latency_ns() > 0.0 && report.energy_pj() > 0.0);
        assert_eq!(
            report.objective_value(),
            report.breakdown.objective(Objective::Latency)
        );
    }

    #[test]
    fn registry_keyed_schedule() {
        let engine = Engine::new(Scenario::headline(alexnet(1)));
        let registry = SchedulerRegistry::standard(42);
        let planned = engine.schedule(&registry, "simba").unwrap();
        assert_eq!(planned.plan().scheduler, "simba");
        let err = engine.schedule(&registry, "bogus").unwrap_err();
        assert!(matches!(err, EngineError::UnknownScheduler { .. }));
    }

    #[test]
    fn sweep_rows_follow_scheduler_order() {
        let registry = SchedulerRegistry::standard(42);
        let scheds = registry.select(&["baseline", "simba"]).unwrap();
        let scenarios = vec![
            Scenario::headline(alexnet(1)),
            Scenario::headline(alexnet(2)),
        ];
        let rows = Engine::sweep(scenarios, &scheds).unwrap();
        assert_eq!(rows.len(), 2);
        for row in &rows {
            assert_eq!(row.outcomes.len(), 2);
            assert_eq!(row.outcomes[0].scheduler, "baseline");
            let norm = row.normalized_to("baseline").unwrap();
            assert_eq!(norm[0].1, 1.0);
        }
    }
}
