//! [`SchedulerRegistry`]: the set of available [`Scheduler`]s, looked up
//! by key or alias and iterated as trait objects.

use std::time::Duration;

use crate::opt::ga::GaParams;

use super::scheduler::{Baseline, Ga, Greedy, Ilp, Miqp, Scheduler,
                       SimbaLike};
use super::EngineError;

/// An ordered collection of schedulers (registration order is iteration
/// order, which sweeps and figure tables rely on).
pub struct SchedulerRegistry {
    entries: Vec<Box<dyn Scheduler>>,
}

impl SchedulerRegistry {
    pub fn empty() -> Self {
        SchedulerRegistry { entries: Vec::new() }
    }

    /// The five Table-3 schemes plus the task-grained ILP, with
    /// explicit solver knobs (the ILP shares the MIQP's anytime
    /// budget).
    pub fn with_params(
        ga: GaParams,
        miqp_budget: Duration,
        seed: u64,
    ) -> Self {
        let mut r = SchedulerRegistry::empty();
        r.register(Box::new(Baseline));
        r.register(Box::new(SimbaLike));
        r.register(Box::new(Greedy));
        r.register(Box::new(Ga::new(ga, seed)));
        r.register(Box::new(Miqp::new(miqp_budget, seed)));
        r.register(Box::new(Ilp::new(miqp_budget, seed)));
        r
    }

    /// Default solver knobs (GA defaults, MIQP 20 s anytime budget).
    /// The figure harness builds its quick/full-budget registries via
    /// `eval::EvalConfig::registry` — those constants live there, once.
    pub fn standard(seed: u64) -> Self {
        Self::with_params(GaParams::default(), Duration::from_secs(20), seed)
    }

    /// Add a scheduler; later registrations shadow earlier ones with the
    /// same key.
    pub fn register(&mut self, s: Box<dyn Scheduler>) -> &mut Self {
        self.entries.retain(|e| e.key() != s.key());
        self.entries.push(s);
        self
    }

    /// Look up by key, alias or display name (case-insensitive).
    pub fn get(&self, name: &str) -> Option<&dyn Scheduler> {
        let want = name.to_ascii_lowercase();
        self.entries
            .iter()
            .find(|s| {
                s.key().eq_ignore_ascii_case(&want)
                    || s.name().eq_ignore_ascii_case(&want)
                    || s.aliases()
                        .iter()
                        .any(|a| a.eq_ignore_ascii_case(&want))
            })
            .map(|b| b.as_ref())
    }

    /// Like [`SchedulerRegistry::get`] but with a descriptive error.
    pub fn require(&self, name: &str) -> Result<&dyn Scheduler, EngineError> {
        self.get(name).ok_or_else(|| {
            EngineError::UnknownScheduler {
                name: name.to_string(),
                known: self.keys().join(", "),
            }
        })
    }

    /// Resolve several keys at once (figure scheme sets).
    pub fn select(
        &self,
        names: &[&str],
    ) -> Result<Vec<&dyn Scheduler>, EngineError> {
        names.iter().map(|n| self.require(n)).collect()
    }

    pub fn iter(&self) -> impl Iterator<Item = &dyn Scheduler> {
        self.entries.iter().map(|b| b.as_ref())
    }

    pub fn keys(&self) -> Vec<&str> {
        self.entries.iter().map(|s| s.key()).collect()
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn standard_registry_has_all_five() {
        let r = SchedulerRegistry::standard(42);
        assert_eq!(
            r.keys(),
            vec!["baseline", "simba", "greedy", "ga", "miqp", "ilp"]
        );
        for key in ["baseline", "simba", "greedy", "ga", "miqp", "ilp"] {
            assert!(r.get(key).is_some(), "missing {key}");
        }
    }

    #[test]
    fn lookup_accepts_aliases_and_names() {
        let r = SchedulerRegistry::standard(42);
        assert_eq!(r.get("ls").unwrap().key(), "baseline");
        assert_eq!(r.get("MCMComm-GA").unwrap().key(), "ga");
        assert_eq!(r.get("BASELINE").unwrap().key(), "baseline");
        assert!(r.get("does-not-exist").is_none());
    }

    #[test]
    fn require_reports_known_keys() {
        let r = SchedulerRegistry::standard(42);
        let err = r.require("nope").unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("nope") && msg.contains("baseline"), "{msg}");
    }

    #[test]
    fn register_shadows_same_key() {
        use crate::engine::schedulers::Ga;
        let mut r = SchedulerRegistry::standard(1);
        r.register(Box::new(Ga::seeded(99)));
        assert_eq!(r.len(), 6);
    }
}
