//! SLO-aware admission control: decide at arrival time whether a
//! request can still meet its deadline, and shed it immediately if not.
//!
//! Shedding at admission (rather than timing out in the queue) is what
//! protects goodput under overload: a request that cannot meet its
//! deadline anyway would only add queueing delay to every request
//! behind it. The policy is deliberately estimate-based and cheap —
//! one comparison against `now + predicted wait + predicted service`;
//! the virtual-time harness ([`super::harness`]) and the real-time
//! server ([`super::server`]) both feed it their own notions of time
//! and predicted service.

/// Why a request was refused at admission.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShedReason {
    /// The wait queue is at capacity and no module is idle
    /// (backpressure; an idle module means the request starts
    /// immediately and never queues, so a full — or even zero-length —
    /// queue alone is not grounds to shed).
    QueueFull,
    /// The deadline had already passed at arrival.
    DeadlineExpired,
    /// Admission-time prediction says the deadline cannot be met, even
    /// with expedited (queue-jumping, solo-batch) service.
    DeadlinePredictedMiss,
}

impl ShedReason {
    pub fn label(&self) -> &'static str {
        match self {
            ShedReason::QueueFull => "queue_full",
            ShedReason::DeadlineExpired => "deadline_expired",
            ShedReason::DeadlinePredictedMiss => "deadline_predicted_miss",
        }
    }
}

/// Outcome of [`AdmissionPolicy::decide`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AdmissionDecision {
    /// Join the tail of the queue, batched normally.
    Admit,
    /// Jump the queue and run as a solo batch — the deadline is too
    /// tight to survive normal queueing but still feasible.
    Expedite,
    Shed(ShedReason),
}

/// Everything the policy reads, in one bag so callers can't misorder
/// nine positional floats. All times are in the caller's clock domain
/// (virtual ns in the harness, host ns in the server) — the policy
/// only ever compares them to each other.
#[derive(Debug, Clone, Copy)]
pub struct AdmissionInputs {
    pub now_ns: f64,
    /// Absolute deadline; `None` = best-effort (never deadline-shed).
    pub deadline_ns: Option<f64>,
    /// Requests currently waiting (not yet in service).
    pub queue_len: usize,
    /// Queue bound; `usize::MAX` = unbounded.
    pub queue_cap: usize,
    /// Whether some service module is idle right now.
    pub has_idle_capacity: bool,
    /// Predicted time until service would start for a tail-of-queue
    /// admit.
    pub est_wait_ns: f64,
    /// Predicted (batch-amortized) service time for this request.
    pub est_batch_service_ns: f64,
    /// Predicted solo-batch service time (the expedite path).
    pub est_solo_service_ns: f64,
}

/// The admission policy: bound the queue, never queue a dead request,
/// expedite salvageable tight deadlines (if enabled).
#[derive(Debug, Clone, Copy)]
pub struct AdmissionPolicy {
    /// Allow the queue-jumping solo path. Off = strict FIFO fairness.
    pub allow_expedite: bool,
}

impl Default for AdmissionPolicy {
    fn default() -> Self {
        AdmissionPolicy { allow_expedite: true }
    }
}

impl AdmissionPolicy {
    pub fn decide(&self, inp: &AdmissionInputs) -> AdmissionDecision {
        if let Some(d) = inp.deadline_ns {
            if d < inp.now_ns {
                return AdmissionDecision::Shed(ShedReason::DeadlineExpired);
            }
        }
        if !inp.has_idle_capacity && inp.queue_len >= inp.queue_cap {
            return AdmissionDecision::Shed(ShedReason::QueueFull);
        }
        let Some(d) = inp.deadline_ns else {
            return AdmissionDecision::Admit;
        };
        if inp.now_ns + inp.est_wait_ns + inp.est_batch_service_ns <= d {
            return AdmissionDecision::Admit;
        }
        if self.allow_expedite && inp.now_ns + inp.est_solo_service_ns <= d {
            return AdmissionDecision::Expedite;
        }
        AdmissionDecision::Shed(ShedReason::DeadlinePredictedMiss)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn base() -> AdmissionInputs {
        AdmissionInputs {
            now_ns: 1000.0,
            deadline_ns: None,
            queue_len: 0,
            queue_cap: 64,
            has_idle_capacity: true,
            est_wait_ns: 0.0,
            est_batch_service_ns: 100.0,
            est_solo_service_ns: 150.0,
        }
    }

    #[test]
    fn best_effort_always_admits_with_capacity() {
        let p = AdmissionPolicy::default();
        assert_eq!(p.decide(&base()), AdmissionDecision::Admit);
    }

    #[test]
    fn expired_deadline_sheds_even_when_idle() {
        let p = AdmissionPolicy::default();
        let inp = AdmissionInputs { deadline_ns: Some(999.0), ..base() };
        assert_eq!(
            p.decide(&inp),
            AdmissionDecision::Shed(ShedReason::DeadlineExpired)
        );
    }

    #[test]
    fn zero_capacity_queue_still_admits_onto_idle_module() {
        // The queue_cap = 0 edge: a request that would start immediately
        // never queues, so it must not be shed as QueueFull.
        let p = AdmissionPolicy::default();
        let inp = AdmissionInputs { queue_cap: 0, ..base() };
        assert_eq!(p.decide(&inp), AdmissionDecision::Admit);
        let inp = AdmissionInputs {
            queue_cap: 0,
            has_idle_capacity: false,
            ..base()
        };
        assert_eq!(
            p.decide(&inp),
            AdmissionDecision::Shed(ShedReason::QueueFull)
        );
    }

    #[test]
    fn full_queue_sheds_only_without_idle_capacity() {
        let p = AdmissionPolicy::default();
        let full = AdmissionInputs { queue_len: 64, ..base() };
        assert_eq!(p.decide(&full), AdmissionDecision::Admit);
        let full_busy = AdmissionInputs {
            has_idle_capacity: false,
            ..full
        };
        assert_eq!(
            p.decide(&full_busy),
            AdmissionDecision::Shed(ShedReason::QueueFull)
        );
    }

    #[test]
    fn tight_deadline_expedites_then_sheds() {
        let p = AdmissionPolicy::default();
        // Wait makes the batch path miss (1000+500+100 > 1200) but a
        // solo run fits (1000+150 <= 1200): expedite.
        let tight = AdmissionInputs {
            deadline_ns: Some(1200.0),
            est_wait_ns: 500.0,
            has_idle_capacity: false,
            queue_len: 3,
            ..base()
        };
        assert_eq!(p.decide(&tight), AdmissionDecision::Expedite);
        // Even solo misses: predicted-miss shed.
        let hopeless = AdmissionInputs {
            deadline_ns: Some(1100.0),
            ..tight
        };
        assert_eq!(
            p.decide(&hopeless),
            AdmissionDecision::Shed(ShedReason::DeadlinePredictedMiss)
        );
        // Expedite disabled: strict policy sheds the tight one too.
        let strict = AdmissionPolicy { allow_expedite: false };
        assert_eq!(
            strict.decide(&tight),
            AdmissionDecision::Shed(ShedReason::DeadlinePredictedMiss)
        );
    }
}
