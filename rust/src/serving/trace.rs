//! Open-loop request traces: seeded Poisson generation plus a
//! replayable JSON format.
//!
//! Open-loop means arrivals are fixed in advance and do *not* react to
//! server backpressure — the standard methodology for tail-latency
//! measurement (a closed loop self-throttles and hides queueing
//! collapse). Generation is pure PCG32 arithmetic from a seed, so a
//! trace is reproducible from `(seed, rate, n, tenants, slack)` alone;
//! the JSON form exists to pin a trace across machines or feed
//! externally captured arrival logs to the harness.

use std::collections::BTreeMap;

use crate::util::error::{Context, Result};
use crate::util::json::{obj, Json};
use crate::util::rng::Pcg;

/// One request: when it arrives, which tenant (model) it is for, and
/// its absolute deadline, if any.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceRequest {
    pub arrival_ns: f64,
    /// Index into the harness's tenant table.
    pub tenant: usize,
    /// Absolute virtual-time deadline; `None` = best-effort.
    pub deadline_ns: Option<f64>,
}

/// An arrival-ordered request stream.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct Trace {
    pub requests: Vec<TraceRequest>,
}

impl Trace {
    /// Seeded Poisson process: `n` requests, exponential inter-arrival
    /// gaps with mean `mean_gap_ns` (rate = 1/mean), tenants drawn
    /// uniformly from `0..tenants`, and (optionally) a per-request
    /// deadline of `arrival + slack_ns`. Deterministic in all inputs.
    pub fn poisson(
        n: usize,
        mean_gap_ns: f64,
        tenants: usize,
        slack_ns: Option<f64>,
        seed: u64,
    ) -> Trace {
        assert!(tenants >= 1, "need at least one tenant");
        assert!(mean_gap_ns > 0.0, "mean inter-arrival gap must be > 0");
        let mut rng = Pcg::seeded(seed);
        let mut t = 0.0f64;
        let mut requests = Vec::with_capacity(n);
        for _ in 0..n {
            // Inverse-CDF exponential; 1-u is in (0,1] so ln is finite.
            let u = rng.f64();
            t += -mean_gap_ns * (1.0 - u).ln();
            requests.push(TraceRequest {
                arrival_ns: t,
                tenant: rng.below(tenants as u64) as usize,
                deadline_ns: slack_ns.map(|s| t + s),
            });
        }
        Trace { requests }
    }

    pub fn len(&self) -> usize {
        self.requests.len()
    }

    pub fn is_empty(&self) -> bool {
        self.requests.is_empty()
    }

    /// Largest tenant index + 1 (0 for an empty trace) — the number of
    /// tenant models the harness must be configured with.
    pub fn tenant_count(&self) -> usize {
        self.requests
            .iter()
            .map(|r| r.tenant + 1)
            .max()
            .unwrap_or(0)
    }

    pub fn to_json(&self) -> Json {
        Json::Obj(BTreeMap::from([
            ("schema".to_string(), Json::Num(1.0)),
            (
                "requests".to_string(),
                Json::Arr(
                    self.requests
                        .iter()
                        .map(|r| {
                            obj(vec![
                                ("arrival_ns", Json::Num(r.arrival_ns)),
                                ("tenant", Json::Num(r.tenant as f64)),
                                (
                                    "deadline_ns",
                                    r.deadline_ns
                                        .map(Json::Num)
                                        .unwrap_or(Json::Null),
                                ),
                            ])
                        })
                        .collect(),
                ),
            ),
        ]))
    }

    pub fn from_json(j: &Json) -> Result<Trace> {
        let schema = j
            .get("schema")
            .and_then(Json::as_usize)
            .ok_or_else(|| crate::err!("trace missing schema field"))?;
        crate::ensure!(schema == 1, "unsupported trace schema {schema}");
        let reqs = j
            .get("requests")
            .and_then(Json::as_arr)
            .ok_or_else(|| crate::err!("trace missing requests array"))?;
        let mut requests = Vec::with_capacity(reqs.len());
        let mut last = f64::NEG_INFINITY;
        for (i, r) in reqs.iter().enumerate() {
            let arrival_ns = r
                .get("arrival_ns")
                .and_then(Json::as_f64)
                .ok_or_else(|| crate::err!("request {i}: bad arrival_ns"))?;
            let tenant = r
                .get("tenant")
                .and_then(Json::as_usize)
                .ok_or_else(|| crate::err!("request {i}: bad tenant"))?;
            let deadline_ns = match r.get("deadline_ns") {
                None | Some(Json::Null) => None,
                Some(v) => Some(v.as_f64().ok_or_else(|| {
                    crate::err!("request {i}: bad deadline_ns")
                })?),
            };
            crate::ensure!(
                arrival_ns.is_finite() && arrival_ns >= last,
                "request {i}: arrivals must be finite and non-decreasing"
            );
            last = arrival_ns;
            requests.push(TraceRequest { arrival_ns, tenant, deadline_ns });
        }
        Ok(Trace { requests })
    }

    pub fn save(&self, path: &std::path::Path) -> Result<()> {
        std::fs::write(path, self.to_json().encode())
            .with_context(|| format!("writing trace to {}", path.display()))
    }

    pub fn load(path: &std::path::Path) -> Result<Trace> {
        let src = std::fs::read_to_string(path)
            .with_context(|| format!("reading trace {}", path.display()))?;
        let j = Json::parse(&src)
            .with_context(|| format!("parsing trace {}", path.display()))?;
        Trace::from_json(&j)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poisson_is_seed_deterministic() {
        let a = Trace::poisson(500, 1000.0, 3, Some(5e4), 42);
        let b = Trace::poisson(500, 1000.0, 3, Some(5e4), 42);
        assert_eq!(a, b);
        let c = Trace::poisson(500, 1000.0, 3, Some(5e4), 43);
        assert_ne!(a, c);
    }

    #[test]
    fn poisson_statistics_are_sane() {
        let n = 20_000;
        let mean = 1000.0;
        let t = Trace::poisson(n, mean, 4, None, 7);
        assert_eq!(t.len(), n);
        assert_eq!(t.tenant_count(), 4);
        // Arrivals strictly ordered; empirical mean gap within 5%.
        let mut last = 0.0;
        for r in &t.requests {
            assert!(r.arrival_ns > last);
            last = r.arrival_ns;
        }
        let emp = last / n as f64;
        assert!(
            (emp - mean).abs() / mean < 0.05,
            "empirical mean gap {emp} vs {mean}"
        );
        // Every tenant appears (uniform over 4, 20k draws).
        for tn in 0..4 {
            assert!(t.requests.iter().any(|r| r.tenant == tn));
        }
    }

    #[test]
    fn json_round_trip_is_exact() {
        let t = Trace::poisson(64, 777.0, 2, Some(1.25e5), 11);
        let back = Trace::from_json(&t.to_json()).unwrap();
        // Bit-exact: Rust's f64 Display is shortest-round-trip and the
        // parser reads it back to the same bits.
        assert_eq!(t, back);
        // Mixed deadlines survive too.
        let mut t2 = t.clone();
        t2.requests[3].deadline_ns = None;
        let back2 = Trace::from_json(&t2.to_json()).unwrap();
        assert_eq!(t2, back2);
    }

    #[test]
    fn from_json_rejects_malformed() {
        let bad = Json::parse(r#"{"schema":2,"requests":[]}"#).unwrap();
        assert!(Trace::from_json(&bad).is_err());
        let unsorted = Json::parse(
            r#"{"schema":1,"requests":[
                {"arrival_ns":10,"tenant":0,"deadline_ns":null},
                {"arrival_ns":5,"tenant":0,"deadline_ns":null}]}"#,
        )
        .unwrap();
        assert!(Trace::from_json(&unsorted).is_err());
    }

    #[test]
    fn save_load_round_trip() {
        let t = Trace::poisson(32, 500.0, 2, None, 3);
        let dir = std::env::temp_dir();
        let path = dir.join("mcmcomm_trace_test.json");
        t.save(&path).unwrap();
        let back = Trace::load(&path).unwrap();
        let _ = std::fs::remove_file(&path);
        assert_eq!(t, back);
    }
}
