//! The virtual-time load harness: open-loop traces played against a
//! pool of simulated MCM replicas, with continuous batching, SLO-aware
//! admission, and plan-cache accounting — entirely in virtual time.
//!
//! Service times are DES-backed: each tenant's plan is optimized once
//! (through the [`PlanCache`]), executed once on the plan-level
//! discrete-event simulator ([`crate::netsim::sim`]) for its batch-1
//! makespan, and extended to batch sizes via the crate's pipelining
//! model ([`crate::pipeline::pipeline_speedup`]) — the same
//! `batch_ns = base · b / speedup(b)` law the `serve` CLI has always
//! reported. The queueing layer on top is
//! [`crate::netsim::vtime::ModulePool`].
//!
//! Continuous batching: a batch is formed the moment a module goes
//! idle, from the head-of-queue request plus up to `max_batch - 1`
//! same-tenant requests further back (others keep their order). There
//! is no artificial linger — under light load requests run solo with
//! minimal latency, under load batches grow naturally as the queue
//! fills, which is exactly the continuous-batching trade-off.
//!
//! Everything is deterministic: same trace + same config ⇒ a
//! bit-identical [`HarnessReport`] (pinned by `tests/serving_load.rs`).

use std::collections::VecDeque;
use std::sync::Arc;

use crate::engine::{Engine, Plan, Scenario, SchedulerRegistry};
use crate::netsim::vtime::ModulePool;
use crate::pipeline::pipeline_speedup;
use crate::util::error::Result;
use crate::util::json::{obj, Json};

use super::admission::{
    AdmissionDecision, AdmissionInputs, AdmissionPolicy, ShedReason,
};
use super::cache::{PlanCache, PlanCacheStats, PlanKey};
use super::metrics::LatencyStats;
use super::trace::Trace;

/// How the continuous batcher picks among idle modules at dispatch.
/// Both options are deterministic; the default is pinned by the
/// serving determinism tests.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum RoutingPolicy {
    /// Lowest-indexed idle module first (the historical behavior).
    #[default]
    LowestIndex,
    /// Idle module with the least cumulative assigned service time
    /// (ties break toward the lower index) — spreads work evenly
    /// across replicas instead of piling onto module 0.
    LeastOutstandingWork,
}

/// Harness knobs. `Default` is a sensible mid-size serving setup.
#[derive(Debug, Clone)]
pub struct HarnessConfig {
    /// Number of MCM replicas behind the router.
    pub modules: usize,
    /// Largest batch one module runs at once.
    pub max_batch: usize,
    /// Bound on requests waiting (not in service); `usize::MAX` =
    /// unbounded. 0 means requests only run if a module is idle.
    pub queue_cap: usize,
    /// Scheduler registry key used to plan every tenant.
    pub scheduler: String,
    /// Seed for the scheduler registry (stochastic schedulers).
    pub seed: u64,
    pub policy: AdmissionPolicy,
    /// Plan-cache capacity (ignored when a cache is shared in via
    /// [`LoadHarness::with_cache`]).
    pub cache_capacity: usize,
    /// Re-verify first cache hits against recomputation (must be off
    /// for nondeterministic schedulers such as `miqp`).
    pub verify_cache: bool,
    /// Idle-module selection policy at dispatch.
    pub routing: RoutingPolicy,
    /// `Some(d)`: each replica streams its batch through the tenant's
    /// plan as a steady pipeline with `d` batches in flight
    /// ([`crate::steady`]), so `batch_ns[b] = fill + (b-1) · period`
    /// instead of the single-batch `base · b / speedup(b)` law. `None`
    /// (default) keeps the historical service model.
    pub pipeline_depth: Option<usize>,
}

impl Default for HarnessConfig {
    fn default() -> Self {
        HarnessConfig {
            modules: 4,
            max_batch: 8,
            queue_cap: 256,
            scheduler: "greedy".to_string(),
            seed: 0,
            policy: AdmissionPolicy::default(),
            cache_capacity: 64,
            verify_cache: cfg!(debug_assertions),
            routing: RoutingPolicy::default(),
            pipeline_depth: None,
        }
    }
}

/// Resolved per-tenant service model: one cached plan, one DES run,
/// a batch-size → service-time table.
struct TenantModel {
    /// `batch_ns[b]` = modeled service time of a size-`b` batch;
    /// index 0 unused.
    batch_ns: Vec<f64>,
    /// Per-request amortized service at full batch (admission's
    /// optimistic estimate).
    amortized_ns: f64,
}

impl TenantModel {
    fn build(
        scen: &Scenario,
        plan: &Plan,
        max_batch: usize,
        pipeline_depth: Option<usize>,
    ) -> Result<TenantModel> {
        if let Some(depth) = pipeline_depth {
            return TenantModel::build_pipelined(scen, plan, max_batch, depth);
        }
        let sim = scen.simulate(plan)?;
        crate::ensure!(
            sim.makespan_ns.is_finite() && sim.makespan_ns > 0.0,
            "tenant '{}' simulated to a degenerate makespan {}",
            scen.workload().name,
            sim.makespan_ns
        );
        let breakdown = scen.report(plan).breakdown;
        let mut batch_ns = vec![0.0; max_batch + 1];
        for (b, slot) in batch_ns.iter_mut().enumerate().skip(1) {
            *slot =
                sim.makespan_ns * b as f64 / pipeline_speedup(&breakdown, b);
        }
        let amortized_ns = batch_ns[max_batch] / max_batch as f64;
        Ok(TenantModel { batch_ns, amortized_ns })
    }

    /// Steady-pipeline service model: the replica streams the batch's
    /// samples through the tenant's own (full-grid) plan with `depth`
    /// in flight, so a size-`b` batch costs the pipeline fill latency
    /// plus `b - 1` steady periods ([`crate::steady::sim`]).
    fn build_pipelined(
        scen: &Scenario,
        plan: &Plan,
        max_batch: usize,
        depth: usize,
    ) -> Result<TenantModel> {
        crate::ensure!(depth >= 1, "pipeline_depth must be >= 1");
        let plat = scen.platform();
        let wl = scen.workload();
        let stage_plan = crate::steady::StagePlan::single_stage(plat, wl, depth);
        let report = crate::steady::sim::simulate_steady_alloc(
            plat,
            wl,
            &stage_plan,
            &plan.alloc,
            plan.flags,
            &crate::steady::SteadyConfig::default(),
        )?;
        crate::ensure!(
            report.period_ns.is_finite() && report.period_ns > 0.0,
            "tenant '{}' pipelined to a degenerate period {}",
            wl.name,
            report.period_ns
        );
        let mut batch_ns = vec![0.0; max_batch + 1];
        for (b, slot) in batch_ns.iter_mut().enumerate().skip(1) {
            *slot =
                report.first_batch_ns + (b as f64 - 1.0) * report.period_ns;
        }
        let amortized_ns = batch_ns[max_batch] / max_batch as f64;
        Ok(TenantModel { batch_ns, amortized_ns })
    }
}

/// One admitted request waiting or in service.
struct Queued {
    tenant: usize,
    arrival_ns: f64,
    deadline_ns: Option<f64>,
    /// The service estimate charged to the backlog at admission
    /// (credited back at dispatch).
    est_ns: f64,
}

/// Mutable event-loop state, split out so the borrow checker sees it
/// disjoint from the tenant table.
struct RunState {
    pool: ModulePool,
    queue: VecDeque<Queued>,
    expedite: VecDeque<Queued>,
    inflight: Vec<Option<Vec<Queued>>>,
    /// Estimated service backlog of everything queued (ns).
    queued_work_ns: f64,
    now: f64,
    latencies: Vec<f64>,
    good: usize,
    batches: usize,
    batch_total: usize,
    shed_queue_full: usize,
    shed_deadline_expired: usize,
    shed_predicted_miss: usize,
}

impl RunState {
    fn new(modules: usize) -> RunState {
        RunState {
            pool: ModulePool::new(modules),
            queue: VecDeque::new(),
            expedite: VecDeque::new(),
            inflight: (0..modules).map(|_| None).collect(),
            queued_work_ns: 0.0,
            now: 0.0,
            latencies: Vec::new(),
            good: 0,
            batches: 0,
            batch_total: 0,
            shed_queue_full: 0,
            shed_deadline_expired: 0,
            shed_predicted_miss: 0,
        }
    }

    fn queue_len(&self) -> usize {
        self.queue.len() + self.expedite.len()
    }

    /// Fill idle modules at `now`: expedited requests first (solo
    /// batches), then head-of-queue continuous batches.
    fn dispatch(
        &mut self,
        now: f64,
        models: &[Option<TenantModel>],
        max_batch: usize,
        routing: RoutingPolicy,
    ) {
        let pick = |pool: &ModulePool| match routing {
            RoutingPolicy::LowestIndex => pool.idle_at(now),
            RoutingPolicy::LeastOutstandingWork => {
                pool.idle_least_assigned_at(now)
            }
        };
        while let Some(m) = pick(&self.pool) {
            let (batch, service) = if let Some(q) = self.expedite.pop_front()
            {
                let model =
                    models[q.tenant].as_ref().expect("resolved at admission");
                (vec![q], model.batch_ns[1])
            } else if let Some(head) = self.queue.pop_front() {
                let tenant = head.tenant;
                let model =
                    models[tenant].as_ref().expect("resolved at admission");
                let mut batch = vec![head];
                let mut rest = VecDeque::with_capacity(self.queue.len());
                for q in std::mem::take(&mut self.queue) {
                    if q.tenant == tenant && batch.len() < max_batch {
                        batch.push(q);
                    } else {
                        rest.push_back(q);
                    }
                }
                self.queue = rest;
                let service = model.batch_ns[batch.len()];
                (batch, service)
            } else {
                break;
            };
            for q in &batch {
                self.queued_work_ns -= q.est_ns;
            }
            self.queued_work_ns = self.queued_work_ns.max(0.0);
            self.pool.occupy(m, now, now + service);
            self.batches += 1;
            self.batch_total += batch.len();
            self.inflight[m] = Some(batch);
        }
    }

    fn complete(&mut self, m: usize, done_ns: f64) {
        let batch =
            self.inflight[m].take().expect("completion without a batch");
        for q in batch {
            self.latencies.push(done_ns - q.arrival_ns);
            if q.deadline_ns.is_none_or(|d| done_ns <= d) {
                self.good += 1;
            }
        }
    }

    /// Advance virtual time processing completions up to `until`
    /// (inclusive — a batch finishing exactly at an arrival's timestamp
    /// frees its module *before* the arrival is admitted).
    fn drain(
        &mut self,
        until: f64,
        models: &[Option<TenantModel>],
        max_batch: usize,
        routing: RoutingPolicy,
    ) {
        loop {
            self.dispatch(self.now, models, max_batch, routing);
            match self.pool.next_completion(self.now) {
                Some((m, done)) if done <= until => {
                    self.now = done;
                    self.complete(m, done);
                }
                _ => break,
            }
        }
    }

    fn record_shed(&mut self, reason: ShedReason) {
        match reason {
            ShedReason::QueueFull => self.shed_queue_full += 1,
            ShedReason::DeadlineExpired => self.shed_deadline_expired += 1,
            ShedReason::DeadlinePredictedMiss => self.shed_predicted_miss += 1,
        }
    }
}

/// End-of-run serving metrics. Deterministic: same harness + same
/// trace ⇒ bit-identical report (compare via [`HarnessReport::to_json`]).
#[derive(Debug, Clone, PartialEq)]
pub struct HarnessReport {
    pub submitted: usize,
    pub completed: usize,
    pub shed_queue_full: usize,
    pub shed_deadline_expired: usize,
    pub shed_predicted_miss: usize,
    /// Completions that met their deadline (best-effort always counts).
    pub good: usize,
    pub batches: usize,
    /// Virtual time from t=0 to the last completion (or last arrival
    /// if later).
    pub makespan_ns: f64,
    pub latency: LatencyStats,
    /// Plan-cache snapshot at the end of the run (cumulative if the
    /// cache is shared across runs).
    pub cache: PlanCacheStats,
}

impl HarnessReport {
    pub fn shed(&self) -> usize {
        self.shed_queue_full + self.shed_deadline_expired
            + self.shed_predicted_miss
    }

    /// Shed fraction of submitted requests, in [0, 1].
    pub fn shed_rate(&self) -> f64 {
        if self.submitted == 0 {
            0.0
        } else {
            self.shed() as f64 / self.submitted as f64
        }
    }

    /// Deadline-meeting completions per *virtual* second.
    pub fn goodput_rps(&self) -> f64 {
        if self.makespan_ns <= 0.0 {
            0.0
        } else {
            self.good as f64 / (self.makespan_ns / 1e9)
        }
    }

    pub fn mean_batch(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.completed as f64 / self.batches as f64
        }
    }

    pub fn to_json(&self) -> Json {
        obj(vec![
            ("schema", Json::Num(1.0)),
            ("submitted", Json::Num(self.submitted as f64)),
            ("completed", Json::Num(self.completed as f64)),
            ("shed_queue_full", Json::Num(self.shed_queue_full as f64)),
            (
                "shed_deadline_expired",
                Json::Num(self.shed_deadline_expired as f64),
            ),
            (
                "shed_predicted_miss",
                Json::Num(self.shed_predicted_miss as f64),
            ),
            ("shed_rate", Json::Num(self.shed_rate())),
            ("good", Json::Num(self.good as f64)),
            ("goodput_rps", Json::Num(self.goodput_rps())),
            ("batches", Json::Num(self.batches as f64)),
            ("mean_batch", Json::Num(self.mean_batch())),
            ("makespan_ns", Json::Num(self.makespan_ns)),
            ("latency", self.latency.to_json()),
            (
                "cache",
                obj(vec![
                    ("hits", Json::Num(self.cache.hits as f64)),
                    ("misses", Json::Num(self.cache.misses as f64)),
                    ("evictions", Json::Num(self.cache.evictions as f64)),
                    ("entries", Json::Num(self.cache.entries as f64)),
                    ("hit_rate", Json::Num(self.cache.hit_rate())),
                ]),
            ),
        ])
    }

    /// Human-readable multi-line summary (CLI + CI artifact).
    pub fn summary(&self) -> String {
        format!(
            "requests   {} submitted, {} completed, {} shed ({:.2}%)\n\
             sheds      queue_full {}  deadline_expired {}  \
             predicted_miss {}\n\
             latency    p50 {:.3} ms  p99 {:.3} ms  p99.9 {:.3} ms  \
             max {:.3} ms\n\
             goodput    {:.1} req/s (virtual), {} within deadline\n\
             batching   {} batches, mean size {:.2}\n\
             plan cache {} hits / {} misses ({:.2}% hit rate), \
             {} evictions",
            self.submitted,
            self.completed,
            self.shed(),
            100.0 * self.shed_rate(),
            self.shed_queue_full,
            self.shed_deadline_expired,
            self.shed_predicted_miss,
            self.latency.p50_ns / 1e6,
            self.latency.p99_ns / 1e6,
            self.latency.p999_ns / 1e6,
            self.latency.max_ns / 1e6,
            self.goodput_rps(),
            self.good,
            self.batches,
            self.mean_batch(),
            self.cache.hits,
            self.cache.misses,
            100.0 * self.cache.hit_rate(),
            self.cache.evictions,
        )
    }
}

/// The harness itself: a tenant table (one [`Scenario`] per tenant), a
/// scheduler, and a plan cache. Reusable across traces; the cache
/// persists between [`LoadHarness::run`] calls.
pub struct LoadHarness {
    tenants: Vec<Scenario>,
    cfg: HarnessConfig,
    registry: SchedulerRegistry,
    cache: Arc<PlanCache>,
}

impl LoadHarness {
    pub fn new(
        tenants: Vec<Scenario>,
        cfg: HarnessConfig,
    ) -> Result<LoadHarness> {
        crate::ensure!(!tenants.is_empty(), "harness needs >= 1 tenant");
        crate::ensure!(cfg.modules >= 1, "harness needs >= 1 module");
        crate::ensure!(cfg.max_batch >= 1, "max_batch must be >= 1");
        let registry = SchedulerRegistry::standard(cfg.seed);
        registry.require(&cfg.scheduler)?;
        let cache = Arc::new(
            PlanCache::new(cfg.cache_capacity.max(tenants.len()))
                .verify_hits(cfg.verify_cache),
        );
        Ok(LoadHarness { tenants, cfg, registry, cache })
    }

    /// One tenant per [`crate::workload::ModelSpan`] of a fused
    /// multi-model scenario: trace tenant `i` maps to the `i`-th span
    /// (via [`crate::workload::Workload::split_models`]), all sharing
    /// the scenario's platform, flags and objective.
    pub fn multi_tenant(
        base: &Scenario,
        cfg: HarnessConfig,
    ) -> Result<LoadHarness> {
        let tenants = base
            .workload()
            .split_models()
            .into_iter()
            .map(|wl| {
                Scenario::builder()
                    .platform(base.platform().clone())
                    .workload(wl)
                    .flags(base.flags())
                    .objective(base.objective())
                    .build()
            })
            .collect::<std::result::Result<Vec<_>, _>>()?;
        LoadHarness::new(tenants, cfg)
    }

    /// Share a plan cache (e.g. across harnesses or with a server).
    pub fn with_cache(mut self, cache: Arc<PlanCache>) -> LoadHarness {
        self.cache = cache;
        self
    }

    pub fn cache(&self) -> &PlanCache {
        &self.cache
    }

    pub fn tenant_count(&self) -> usize {
        self.tenants.len()
    }

    /// Play `trace` to completion in virtual time.
    pub fn run(&self, trace: &Trace) -> Result<HarnessReport> {
        crate::ensure!(
            trace.tenant_count() <= self.tenants.len(),
            "trace references tenant {} but only {} are configured",
            trace.tenant_count().saturating_sub(1),
            self.tenants.len()
        );
        let scheduler = self.registry.require(&self.cfg.scheduler)?;
        let keys: Vec<PlanKey> = self
            .tenants
            .iter()
            .map(|s| PlanKey::of(s, &self.cfg.scheduler))
            .collect();
        let mut models: Vec<Option<TenantModel>> =
            (0..self.tenants.len()).map(|_| None).collect();
        let mut st = RunState::new(self.cfg.modules);

        for req in &trace.requests {
            let t = req.arrival_ns;
            st.drain(t, &models, self.cfg.max_batch, self.cfg.routing);
            st.now = t;

            // Resolve the tenant's plan through the cache on *every*
            // request — that is the lookup stream the hit rate
            // measures; repeated tenants hit after their first miss.
            let tn = req.tenant;
            let scen = &self.tenants[tn];
            // `get_or_compute_in` additionally certifies first hits
            // against the tenant's platform/workload binding on the
            // verify_hits debug path.
            let (plan, _hit) =
                self.cache.get_or_compute_in(scen, &keys[tn], || {
                    Ok(Engine::new(scen.clone())
                        .schedule_with(scheduler)?
                        .into_plan())
                })?;
            if models[tn].is_none() {
                models[tn] = Some(TenantModel::build(
                    scen,
                    &plan,
                    self.cfg.max_batch,
                    self.cfg.pipeline_depth,
                )?);
            }
            let model = models[tn].as_ref().expect("just resolved");

            let decision = self.cfg.policy.decide(&AdmissionInputs {
                now_ns: t,
                deadline_ns: req.deadline_ns,
                queue_len: st.queue_len(),
                queue_cap: self.cfg.queue_cap,
                has_idle_capacity: st.pool.idle_count(t) > 0,
                est_wait_ns: (st.queued_work_ns + st.pool.remaining_ns(t))
                    / self.cfg.modules as f64,
                est_batch_service_ns: model.amortized_ns,
                est_solo_service_ns: model.batch_ns[1],
            });
            match decision {
                AdmissionDecision::Shed(reason) => st.record_shed(reason),
                AdmissionDecision::Admit => {
                    let est_ns = model.amortized_ns;
                    st.queued_work_ns += est_ns;
                    st.queue.push_back(Queued {
                        tenant: tn,
                        arrival_ns: t,
                        deadline_ns: req.deadline_ns,
                        est_ns,
                    });
                }
                AdmissionDecision::Expedite => {
                    let est_ns = model.batch_ns[1];
                    st.queued_work_ns += est_ns;
                    st.expedite.push_back(Queued {
                        tenant: tn,
                        arrival_ns: t,
                        deadline_ns: req.deadline_ns,
                        est_ns,
                    });
                }
            }
            st.dispatch(t, &models, self.cfg.max_batch, self.cfg.routing);
        }
        st.drain(
            f64::INFINITY,
            &models,
            self.cfg.max_batch,
            self.cfg.routing,
        );
        debug_assert_eq!(st.queue_len(), 0, "drain left requests queued");

        let completed = st.latencies.len();
        let shed = st.shed_queue_full
            + st.shed_deadline_expired
            + st.shed_predicted_miss;
        debug_assert_eq!(
            completed + shed,
            trace.len(),
            "request conservation violated"
        );
        let last_arrival =
            trace.requests.last().map_or(0.0, |r| r.arrival_ns);
        Ok(HarnessReport {
            submitted: trace.len(),
            completed,
            shed_queue_full: st.shed_queue_full,
            shed_deadline_expired: st.shed_deadline_expired,
            shed_predicted_miss: st.shed_predicted_miss,
            good: st.good,
            batches: st.batches,
            makespan_ns: st.now.max(last_arrival),
            latency: LatencyStats::from_samples(st.latencies),
            cache: self.cache.stats(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::models::{alexnet, scaled_down};
    use crate::workload::Workload;

    /// Two small tenants on the headline platform (mini dims keep
    /// debug-build scheduling and DES fast).
    fn tenants() -> Vec<Scenario> {
        let a = scaled_down(&alexnet(1), 16, 16);
        let mut b = scaled_down(&alexnet(2), 16, 16);
        b.name = "alexnet-b2-mini".to_string();
        vec![Scenario::headline(a), Scenario::headline(b)]
    }

    fn cfg() -> HarnessConfig {
        HarnessConfig {
            modules: 2,
            max_batch: 4,
            queue_cap: 32,
            ..HarnessConfig::default()
        }
    }

    #[test]
    fn smoke_run_conserves_requests_and_hits_cache() {
        let h = LoadHarness::new(tenants(), cfg()).unwrap();
        let trace = Trace::poisson(300, 50_000.0, 2, None, 9);
        let r = h.run(&trace).unwrap();
        assert_eq!(r.submitted, 300);
        assert_eq!(r.completed + r.shed(), 300);
        // Best-effort: nothing deadline-shed, only backpressure can
        // shed, and every completion counts as good.
        assert_eq!(r.shed_deadline_expired + r.shed_predicted_miss, 0);
        assert_eq!(r.good, r.completed);
        assert!(r.latency.p50_ns > 0.0);
        assert!(r.latency.p50_ns <= r.latency.p99_ns);
        assert!(r.makespan_ns > 0.0 && r.goodput_rps() > 0.0);
        // 2 tenants -> 2 misses; every other lookup hits.
        assert_eq!(r.cache.misses, 2);
        assert!(r.cache.hit_rate() > 0.9, "hit rate {}", r.cache.hit_rate());
    }

    #[test]
    fn zero_capacity_queue_only_serves_idle_modules() {
        let mut c = cfg();
        c.modules = 1;
        c.queue_cap = 0;
        let h = LoadHarness::new(tenants(), c).unwrap();
        // A dense burst: arrival gaps far below service time, so only
        // requests landing on the idle module run; the rest shed.
        let trace = Trace::poisson(100, 10.0, 2, None, 5);
        let r = h.run(&trace).unwrap();
        assert!(r.completed >= 1, "idle module must still serve");
        assert!(r.shed_queue_full > 0, "overload must shed");
        assert_eq!(r.completed + r.shed_queue_full, 100);
        // Nothing ever queued => every batch is size 1.
        assert_eq!(r.batches, r.completed);
    }

    #[test]
    fn burst_beyond_queue_bound_backpressures() {
        let mut c = cfg();
        c.modules = 1;
        c.max_batch = 1;
        c.queue_cap = 4;
        let h = LoadHarness::new(tenants(), c).unwrap();
        // 12 simultaneous arrivals (t=0 burst), single module, no
        // batching: 1 dispatches, 4 queue, 7 shed as QueueFull.
        let trace = Trace {
            requests: (0..12)
                .map(|_| super::super::trace::TraceRequest {
                    arrival_ns: 0.0,
                    tenant: 0,
                    deadline_ns: None,
                })
                .collect(),
        };
        let r = h.run(&trace).unwrap();
        assert_eq!(r.completed, 5);
        assert_eq!(r.shed_queue_full, 7);
    }

    #[test]
    fn deadlines_shed_and_goodput_counts_only_met() {
        let mut c = cfg();
        c.modules = 1;
        c.max_batch = 2;
        let h = LoadHarness::new(tenants(), c).unwrap();
        // Impossibly tight slack: everything deadline-sheds (either
        // expired or predicted-miss), nothing runs.
        let tight = Trace::poisson(50, 1000.0, 2, Some(1.0), 3);
        let r = h.run(&tight).unwrap();
        assert_eq!(r.completed, 0);
        assert_eq!(r.shed(), 50);
        assert_eq!(r.good, 0);
        assert_eq!(r.goodput_rps(), 0.0);
        // Generous slack: everything admitted and good.
        let loose = Trace::poisson(50, 1_000_000.0, 2, Some(1e12), 3);
        let r2 = h.run(&loose).unwrap();
        assert_eq!(r2.completed, 50);
        assert_eq!(r2.good, 50);
    }

    #[test]
    fn multi_tenant_maps_model_spans() {
        let fused = Workload::multi_model(&[
            scaled_down(&alexnet(1), 16, 16),
            scaled_down(&alexnet(2), 16, 16),
        ]);
        let base = Scenario::headline(fused);
        let h = LoadHarness::multi_tenant(&base, cfg()).unwrap();
        assert_eq!(h.tenant_count(), 2);
        let trace = Trace::poisson(60, 100_000.0, 2, None, 1);
        let r = h.run(&trace).unwrap();
        assert_eq!(r.completed + r.shed(), 60);
        assert_eq!(r.cache.misses, 2);
    }

    #[test]
    fn run_is_deterministic() {
        let trace = Trace::poisson(400, 20_000.0, 2, Some(5e8), 77);
        let r1 = LoadHarness::new(tenants(), cfg())
            .unwrap()
            .run(&trace)
            .unwrap();
        let r2 = LoadHarness::new(tenants(), cfg())
            .unwrap()
            .run(&trace)
            .unwrap();
        assert_eq!(r1, r2);
        assert_eq!(r1.to_json().encode(), r2.to_json().encode());
    }

    /// The default routing policy is part of the serving contract:
    /// lowest-index-first, bit-identical to the pre-policy harness.
    #[test]
    fn default_routing_is_lowest_index_and_unchanged() {
        assert_eq!(RoutingPolicy::default(), RoutingPolicy::LowestIndex);
        assert_eq!(
            HarnessConfig::default().routing,
            RoutingPolicy::LowestIndex
        );
        let trace = Trace::poisson(200, 30_000.0, 2, None, 11);
        let implicit =
            LoadHarness::new(tenants(), cfg()).unwrap().run(&trace).unwrap();
        let mut c = cfg();
        c.routing = RoutingPolicy::LowestIndex;
        let explicit =
            LoadHarness::new(tenants(), c).unwrap().run(&trace).unwrap();
        assert_eq!(implicit, explicit);
    }

    #[test]
    fn least_outstanding_work_routing_serves_everything() {
        let trace = Trace::poisson(200, 30_000.0, 2, None, 11);
        let mut c = cfg();
        c.routing = RoutingPolicy::LeastOutstandingWork;
        let r = LoadHarness::new(tenants(), c.clone())
            .unwrap()
            .run(&trace)
            .unwrap();
        assert_eq!(r.completed + r.shed(), 200);
        // Identical service times, different module choice: the two
        // policies agree on aggregate work, so both runs complete the
        // same requests under an uncontended queue.
        let base =
            LoadHarness::new(tenants(), cfg()).unwrap().run(&trace).unwrap();
        assert_eq!(r.submitted, base.submitted);
        // Determinism holds per policy.
        let r2 =
            LoadHarness::new(tenants(), c).unwrap().run(&trace).unwrap();
        assert_eq!(r, r2);
    }

    #[test]
    fn pipelined_service_model_scales_linearly_in_batch() {
        let mut c = cfg();
        c.pipeline_depth = Some(2);
        let h = LoadHarness::new(tenants(), c).unwrap();
        let trace = Trace::poisson(80, 50_000.0, 2, None, 4);
        let r = h.run(&trace).unwrap();
        assert_eq!(r.completed + r.shed(), 80);
        assert!(r.latency.p50_ns > 0.0);
        // The model itself: fill + (b-1)·period, so increments between
        // consecutive batch sizes are a constant period.
        let scen = &tenants()[0];
        let plan = Engine::new(scen.clone())
            .schedule(&SchedulerRegistry::standard(0), "greedy")
            .unwrap()
            .into_plan();
        let m = TenantModel::build(scen, &plan, 4, Some(2)).unwrap();
        let d1 = m.batch_ns[2] - m.batch_ns[1];
        let d2 = m.batch_ns[3] - m.batch_ns[2];
        let d3 = m.batch_ns[4] - m.batch_ns[3];
        assert!((d1 - d2).abs() <= 1e-6 * d1.abs());
        assert!((d2 - d3).abs() <= 1e-6 * d2.abs());
        // The steady model never beats one batch's own fill latency.
        let single = TenantModel::build(scen, &plan, 4, None).unwrap();
        assert!(m.batch_ns[1] > 0.0 && single.batch_ns[1] > 0.0);
    }

    #[test]
    fn rejects_bad_configs() {
        assert!(LoadHarness::new(vec![], cfg()).is_err());
        let mut c = cfg();
        c.scheduler = "bogus".to_string();
        assert!(LoadHarness::new(tenants(), c).is_err());
        let h = LoadHarness::new(tenants(), cfg()).unwrap();
        // Trace referencing a tenant beyond the table is rejected.
        let bad = Trace::poisson(10, 1000.0, 5, None, 2);
        assert!(h.run(&bad).is_err());
    }
}
