//! Serving metrics: tail-latency quantiles, goodput, shed accounting.
//!
//! Quantiles use the nearest-rank definition (`ceil(q·n)`-th smallest)
//! — exact on the recorded sample set, no interpolation — because the
//! whole latency vector is retained (virtual-time runs are cheap), not
//! sketched. Goodput counts only completions that met their deadline;
//! best-effort requests (no deadline) always count.

use crate::util::json::{obj, Json};

/// Nearest-rank quantile of an ascending-sorted slice. `q` in [0, 1];
/// returns 0.0 for an empty slice.
pub fn quantile(sorted_ns: &[f64], q: f64) -> f64 {
    if sorted_ns.is_empty() {
        return 0.0;
    }
    let n = sorted_ns.len();
    let rank = (q * n as f64).ceil() as usize;
    sorted_ns[rank.clamp(1, n) - 1]
}

/// Latency distribution summary (all values in ns).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct LatencyStats {
    pub count: usize,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p99_ns: f64,
    pub p999_ns: f64,
    pub max_ns: f64,
}

impl LatencyStats {
    /// Summarize a sample set (need not be sorted; consumed to sort
    /// in place).
    pub fn from_samples(mut samples_ns: Vec<f64>) -> LatencyStats {
        if samples_ns.is_empty() {
            return LatencyStats::default();
        }
        samples_ns
            .sort_by(|a, b| a.partial_cmp(b).expect("NaN latency sample"));
        let n = samples_ns.len();
        LatencyStats {
            count: n,
            mean_ns: samples_ns.iter().sum::<f64>() / n as f64,
            p50_ns: quantile(&samples_ns, 0.50),
            p99_ns: quantile(&samples_ns, 0.99),
            p999_ns: quantile(&samples_ns, 0.999),
            max_ns: samples_ns[n - 1],
        }
    }

    pub fn to_json(&self) -> Json {
        obj(vec![
            ("count", Json::Num(self.count as f64)),
            ("mean_ns", Json::Num(self.mean_ns)),
            ("p50_ns", Json::Num(self.p50_ns)),
            ("p99_ns", Json::Num(self.p99_ns)),
            ("p999_ns", Json::Num(self.p999_ns)),
            ("max_ns", Json::Num(self.max_ns)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nearest_rank_quantiles() {
        let v: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(quantile(&v, 0.50), 50.0);
        assert_eq!(quantile(&v, 0.99), 99.0);
        assert_eq!(quantile(&v, 0.999), 100.0);
        assert_eq!(quantile(&v, 0.0), 1.0); // clamped to first sample
        assert_eq!(quantile(&v, 1.0), 100.0);
        assert_eq!(quantile(&[], 0.5), 0.0);
        // Single sample: every quantile is it.
        assert_eq!(quantile(&[7.0], 0.001), 7.0);
        assert_eq!(quantile(&[7.0], 0.999), 7.0);
    }

    #[test]
    fn stats_from_unsorted_samples() {
        let s = LatencyStats::from_samples(vec![30.0, 10.0, 20.0, 40.0]);
        assert_eq!(s.count, 4);
        assert_eq!(s.mean_ns, 25.0);
        assert_eq!(s.p50_ns, 20.0);
        assert_eq!(s.max_ns, 40.0);
        assert_eq!(LatencyStats::from_samples(vec![]), LatencyStats::default());
    }

    #[test]
    fn tail_orders_correctly() {
        // Heavy tail: p999 >= p99 >= p50 always.
        let mut v: Vec<f64> = (0..5000).map(|i| (i % 97) as f64).collect();
        v.push(1e9);
        let s = LatencyStats::from_samples(v);
        assert!(s.p50_ns <= s.p99_ns);
        assert!(s.p99_ns <= s.p999_ns);
        assert!(s.p999_ns <= s.max_ns);
        assert_eq!(s.max_ns, 1e9);
    }
}
