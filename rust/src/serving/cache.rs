//! Concurrent plan cache: optimized plans keyed by the full problem
//! statement so repeated tenants skip the scheduler entirely.
//!
//! Key design: a [`PlanKey`] is (platform fingerprint, workload
//! fingerprint, scheduler registry key, opt-flag/objective bits) — the
//! complete input set of [`crate::engine::Scheduler::schedule`] for a
//! deterministic scheduler, so a cache hit is *guaranteed*
//! bit-identical to recomputation. That guarantee is actively checked:
//! with [`PlanCache::verify_hits`] enabled (the default under
//! `debug_assertions`), the first hit on every entry recomputes the
//! plan and asserts bit-identity (allocation, flags, seed, and the
//! exact `objective_value` bits). Disable it for nondeterministic
//! schedulers (`miqp` runs under a wall-clock anytime budget).
//!
//! Concurrency: the map is sharded (FNV of the key selects the shard),
//! each shard behind its own `RwLock`, so readers on different shards
//! never contend and hits take only a read lock. Eviction is FIFO per
//! shard; counters are relaxed atomics.

use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, RwLock};

use crate::cost::evaluator::{Objective, OptFlags};
use crate::engine::{Plan, Scenario};
use crate::util::error::Result;
use crate::util::hash::Fnv1a;

/// Complete identity of one scheduling problem: everything a
/// deterministic scheduler reads. Equal keys ⇒ bit-identical plans.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct PlanKey {
    /// [`crate::platform::Platform::fingerprint`] of the packaging
    /// description.
    pub platform_fp: u64,
    /// [`crate::workload::Workload::fingerprint`] of the op/edge graph.
    pub workload_fp: u64,
    /// Scheduler registry key (`"greedy"`, `"ga"`, …).
    pub scheduler: String,
    /// Requested [`OptFlags`] (bits 0–2) and [`Objective`] (bits 3–4).
    pub opt_bits: u8,
}

impl PlanKey {
    /// Key for scheduling `scenario` with the scheduler registered
    /// under `scheduler`.
    pub fn of(scenario: &Scenario, scheduler: &str) -> PlanKey {
        PlanKey {
            platform_fp: scenario.platform().fingerprint(),
            workload_fp: scenario.workload().fingerprint(),
            scheduler: scheduler.to_string(),
            opt_bits: pack_bits(scenario.flags(), scenario.objective()),
        }
    }

    /// Stable content hash (shard selector; also usable as a compact
    /// cross-process cache id).
    pub fn fingerprint(&self) -> u64 {
        let mut h = Fnv1a::new();
        h.write_u64(self.platform_fp);
        h.write_u64(self.workload_fp);
        h.write_str(&self.scheduler);
        h.write_u8(self.opt_bits);
        h.finish()
    }
}

fn pack_bits(flags: OptFlags, objective: Objective) -> u8 {
    (flags.diagonal as u8)
        | (flags.redistribution as u8) << 1
        | (flags.async_fusion as u8) << 2
        | match objective {
            Objective::Latency => 0,
            Objective::Edp => 1 << 3,
            Objective::Throughput => 2 << 3,
            Objective::EdpPerSample => 3 << 3,
        }
}

/// Monotonic cache counters (snapshot).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct PlanCacheStats {
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
    /// Hits that were re-verified against a fresh computation.
    pub verified: u64,
    /// Current number of cached plans.
    pub entries: usize,
}

impl PlanCacheStats {
    /// Hit fraction in [0, 1]; 0 when the cache was never queried.
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

struct Slot {
    plan: Arc<Plan>,
    /// Whether a hit has already re-verified this entry.
    verified: bool,
}

#[derive(Default)]
struct Shard {
    map: HashMap<PlanKey, Slot>,
    /// Insertion order for FIFO eviction.
    order: VecDeque<PlanKey>,
}

/// Sharded concurrent plan cache. See the module docs for the key and
/// verification contracts.
pub struct PlanCache {
    shards: Vec<RwLock<Shard>>,
    cap_per_shard: usize,
    verify_hits: bool,
    hits: AtomicU64,
    misses: AtomicU64,
    evictions: AtomicU64,
    verified: AtomicU64,
}

impl std::fmt::Debug for PlanCache {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("PlanCache")
            .field("shards", &self.shards.len())
            .field("cap_per_shard", &self.cap_per_shard)
            .field("stats", &self.stats())
            .finish()
    }
}

impl PlanCache {
    /// Cache holding at most `capacity` plans, spread over 8 shards
    /// (capacity is rounded up to a multiple of the shard count). Hit
    /// verification defaults to on under `debug_assertions`, off in
    /// release.
    pub fn new(capacity: usize) -> PlanCache {
        Self::with_shards(capacity, 8)
    }

    pub fn with_shards(capacity: usize, nshards: usize) -> PlanCache {
        let nshards = nshards.max(1);
        PlanCache {
            shards: (0..nshards)
                .map(|_| RwLock::new(Shard::default()))
                .collect(),
            cap_per_shard: capacity.div_ceil(nshards).max(1),
            verify_hits: cfg!(debug_assertions),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
            verified: AtomicU64::new(0),
        }
    }

    /// Toggle first-hit re-verification. Must be off for
    /// nondeterministic schedulers (e.g. `miqp`'s anytime budget),
    /// whose recomputation legitimately differs.
    pub fn verify_hits(mut self, on: bool) -> PlanCache {
        self.verify_hits = on;
        self
    }

    /// Fetch the plan for `key`, computing (and caching) it on a miss.
    /// Returns `(plan, hit)`. On a verified hit the cached plan has
    /// been asserted bit-identical to a fresh `compute()`.
    pub fn get_or_compute(
        &self,
        key: &PlanKey,
        compute: impl Fn() -> Result<Plan>,
    ) -> Result<(Arc<Plan>, bool)> {
        self.get_or_compute_inner(None, key, compute)
    }

    /// Like [`PlanCache::get_or_compute`], but first-hit verification
    /// additionally runs the standalone plan certifier
    /// ([`crate::engine::certify`]) against `scenario`: a verified hit
    /// must both be bit-identical to recomputation *and* certify on
    /// the scenario's platform/workload binding — a corrupted cache
    /// entry is caught before it is ever served.
    pub fn get_or_compute_in(
        &self,
        scenario: &Scenario,
        key: &PlanKey,
        compute: impl Fn() -> Result<Plan>,
    ) -> Result<(Arc<Plan>, bool)> {
        self.get_or_compute_inner(Some(scenario), key, compute)
    }

    fn get_or_compute_inner(
        &self,
        scenario: Option<&Scenario>,
        key: &PlanKey,
        compute: impl Fn() -> Result<Plan>,
    ) -> Result<(Arc<Plan>, bool)> {
        let shard =
            &self.shards[(key.fingerprint() % self.shards.len() as u64) as usize];

        let (cached, needs_verify) = {
            let g = shard.read().expect("plan cache poisoned");
            match g.map.get(key) {
                Some(slot) => {
                    (Some(slot.plan.clone()), self.verify_hits && !slot.verified)
                }
                None => (None, false),
            }
        };
        if let Some(plan) = cached {
            self.hits.fetch_add(1, Ordering::Relaxed);
            if needs_verify {
                let fresh = compute()?;
                assert!(
                    plans_identical(&plan, &fresh),
                    "plan cache hit diverged from recomputation for \
                     scheduler '{}' — is it deterministic?",
                    key.scheduler
                );
                if let Some(s) = scenario {
                    if let Err(violations) =
                        plan.validate(s.platform(), s.workload())
                    {
                        panic!(
                            "plan cache hit for scheduler '{}' failed \
                             certification: {}",
                            key.scheduler,
                            violations
                                .iter()
                                .map(|v| v.to_string())
                                .collect::<Vec<_>>()
                                .join("; ")
                        );
                    }
                }
                self.verified.fetch_add(1, Ordering::Relaxed);
                let mut g = shard.write().expect("plan cache poisoned");
                if let Some(slot) = g.map.get_mut(key) {
                    slot.verified = true;
                }
            }
            return Ok((plan, true));
        }

        // Miss: compute outside any lock (scheduling can be expensive),
        // then insert. A racing thread may have inserted meanwhile —
        // keep the first entry so later hits verify against one canon.
        let plan = Arc::new(compute()?);
        self.misses.fetch_add(1, Ordering::Relaxed);
        let mut g = shard.write().expect("plan cache poisoned");
        if let Some(slot) = g.map.get(key) {
            return Ok((slot.plan.clone(), false));
        }
        while g.map.len() >= self.cap_per_shard {
            let Some(old) = g.order.pop_front() else { break };
            g.map.remove(&old);
            self.evictions.fetch_add(1, Ordering::Relaxed);
        }
        g.map.insert(
            key.clone(),
            Slot { plan: plan.clone(), verified: false },
        );
        g.order.push_back(key.clone());
        Ok((plan, false))
    }

    pub fn stats(&self) -> PlanCacheStats {
        PlanCacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            evictions: self.evictions.load(Ordering::Relaxed),
            verified: self.verified.load(Ordering::Relaxed),
            entries: self
                .shards
                .iter()
                .map(|s| s.read().expect("plan cache poisoned").map.len())
                .sum(),
        }
    }
}

/// Bit-identity across every field that defines a plan, including the
/// exact bit pattern of the score (`to_bits`, not an epsilon).
pub fn plans_identical(a: &Plan, b: &Plan) -> bool {
    a.scheduler == b.scheduler
        && a.alloc == b.alloc
        && a.flags == b.flags
        && a.seed == b.seed
        && a.objective == b.objective
        && a.objective_value.to_bits() == b.objective_value.to_bits()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::{Engine, SchedulerRegistry};
    use crate::workload::models::alexnet;

    fn key_for(batch: usize) -> (Scenario, PlanKey) {
        let s = Scenario::headline(alexnet(batch));
        let k = PlanKey::of(&s, "greedy");
        (s, k)
    }

    fn compute(s: &Scenario) -> Result<Plan> {
        let engine = Engine::new(s.clone());
        let reg = SchedulerRegistry::standard(7);
        Ok(engine
            .schedule_with(reg.require("greedy").unwrap())?
            .into_plan())
    }

    #[test]
    fn hit_after_miss_and_bit_identity() {
        let cache = PlanCache::new(16).verify_hits(true);
        let (s, k) = key_for(1);
        let (p1, hit1) = cache.get_or_compute(&k, || compute(&s)).unwrap();
        assert!(!hit1);
        // The hit path re-verifies against a fresh computation (the
        // assert inside get_or_compute) and returns the same Arc.
        let (p2, hit2) = cache.get_or_compute(&k, || compute(&s)).unwrap();
        assert!(hit2);
        assert!(Arc::ptr_eq(&p1, &p2));
        assert!(plans_identical(&p1, &compute(&s).unwrap()));
        let st = cache.stats();
        assert_eq!((st.hits, st.misses, st.entries), (1, 1, 1));
        assert_eq!(st.verified, 1);
        assert!((st.hit_rate() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn certifying_hit_path_accepts_clean_plans() {
        let cache = PlanCache::new(16).verify_hits(true);
        let (s, k) = key_for(1);
        let (_, hit1) =
            cache.get_or_compute_in(&s, &k, || compute(&s)).unwrap();
        assert!(!hit1);
        // The first hit re-verifies bit-identity AND runs the plan
        // certifier against the scenario binding.
        let (p, hit2) =
            cache.get_or_compute_in(&s, &k, || compute(&s)).unwrap();
        assert!(hit2);
        assert_eq!(cache.stats().verified, 1);
        p.validate(s.platform(), s.workload())
            .expect("cached plan certifies");
    }

    #[test]
    fn distinct_problems_get_distinct_keys() {
        let (s1, k1) = key_for(1);
        let (_, k2) = key_for(4);
        assert_ne!(k1, k2);
        assert_ne!(k1.fingerprint(), k2.fingerprint());
        // Same scenario, different scheduler: different key too.
        assert_ne!(k1, PlanKey::of(&s1, "simba"));
        // Key is a pure function of content.
        assert_eq!(k1, PlanKey::of(&Scenario::headline(alexnet(1)), "greedy"));
    }

    #[test]
    fn fifo_eviction_respects_capacity() {
        let cache = PlanCache::with_shards(1, 1).verify_hits(false);
        let (s1, k1) = key_for(1);
        let (s2, k2) = key_for(2);
        cache.get_or_compute(&k1, || compute(&s1)).unwrap();
        cache.get_or_compute(&k2, || compute(&s2)).unwrap();
        let st = cache.stats();
        assert_eq!(st.entries, 1);
        assert_eq!(st.evictions, 1);
        // k1 was evicted: re-fetching is a miss.
        let (_, hit) = cache.get_or_compute(&k1, || compute(&s1)).unwrap();
        assert!(!hit);
    }

    #[test]
    fn concurrent_readers_share_one_entry() {
        let cache = Arc::new(PlanCache::new(16).verify_hits(false));
        let (s, k) = key_for(1);
        let canon = cache.get_or_compute(&k, || compute(&s)).unwrap().0;
        let mut handles = Vec::new();
        for _ in 0..4 {
            let cache = cache.clone();
            let (s, k) = (s.clone(), k.clone());
            handles.push(std::thread::spawn(move || {
                for _ in 0..8 {
                    let (p, hit) =
                        cache.get_or_compute(&k, || compute(&s)).unwrap();
                    assert!(hit);
                    assert_eq!(p.scheduler, "greedy");
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let st = cache.stats();
        assert_eq!(st.hits, 32);
        assert_eq!(st.misses, 1);
        let now = cache.get_or_compute(&k, || compute(&s)).unwrap().0;
        assert!(plans_identical(&canon, &now));
    }
}
