//! The serving subsystem: end-to-end request serving on top of the
//! engine and the DES oracle.
//!
//! The paper evaluates MCMComm on single-shot workloads; real
//! deployments see *streams* of requests with deadlines, where
//! communication-optimal plans only matter if (a) they can be reused
//! across requests without re-running the optimizer and (b) queueing
//! and batching on top of them still meet SLOs. This subsystem
//! supplies that layer:
//!
//! * [`cache`] — a sharded concurrent [`PlanCache`] keyed by the full
//!   problem fingerprint (platform, workload, scheduler, flags,
//!   objective); hits are bit-identical to recomputation, actively
//!   verified on first hit.
//! * [`admission`] — SLO-aware [`AdmissionPolicy`]: bounded queues,
//!   immediate shedding of infeasible deadlines, optional expedited
//!   solo batches for salvageable tight ones.
//! * [`trace`] — open-loop load: seeded Poisson generation and a
//!   replayable JSON trace format.
//! * [`metrics`] — tail quantiles (p50/p99/p99.9), goodput, shed and
//!   cache-hit accounting.
//! * [`harness`] — the virtual-time [`LoadHarness`]: continuous
//!   batching over a pool of simulated MCM replicas
//!   ([`crate::netsim::vtime`]), DES-backed service times,
//!   deterministic end to end. Module routing is pluggable
//!   ([`RoutingPolicy`]); with `pipeline_depth` set, each replica
//!   serves its batch through a steady pipelined plan
//!   ([`crate::steady`]) instead of the single-batch speedup law.
//! * [`server`] — the wall-clock threaded [`Server`] (the executable
//!   counterpart; PJRT-backed runners plug in here).

pub mod admission;
pub mod cache;
pub mod harness;
pub mod metrics;
pub mod server;
pub mod trace;

pub use admission::{
    AdmissionDecision, AdmissionInputs, AdmissionPolicy, ShedReason,
};
pub use cache::{plans_identical, PlanCache, PlanCacheStats, PlanKey};
pub use harness::{
    HarnessConfig, HarnessReport, LoadHarness, RoutingPolicy,
};
pub use metrics::{quantile, LatencyStats};
pub use server::{Client, Response, ServeReply, Server, ServerStats};
pub use trace::{Trace, TraceRequest};
