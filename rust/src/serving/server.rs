//! Threaded real-time serving loop: the wall-clock counterpart of the
//! virtual-time harness — the "real-time applications" framing of
//! Figure 1 (autonomous-system inference on an edge MCM).
//!
//! tokio is unavailable offline; std threads + mpsc channels implement
//! the same leader/worker shape: one batcher thread owns the (single)
//! simulated MCM, request producers are arbitrary threads. Requests
//! carry optional wall-clock deadlines; a request whose deadline has
//! already passed when its batch forms is shed (reply
//! [`ServeReply::Shed`]) instead of wasting MCM time.
//!
//! Relationship to [`super::harness`]: same concepts (batching,
//! deadlines, shedding, [`ShedReason`]) on the host clock instead of
//! the virtual one. Capacity planning and tail-latency studies belong
//! in the harness where time is free and runs are deterministic; this
//! server exists to *execute* — its runner callback is where PJRT-
//! backed execution plugs in (built on the batcher thread via
//! [`RunnerFactory`]; the PJRT client holds `Rc`s and must not cross
//! threads).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc;
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::util::error::Result;

use super::admission::ShedReason;

/// One inference request.
#[derive(Debug)]
pub struct Request {
    pub id: u64,
    pub submitted: Instant,
    /// Absolute wall-clock deadline, if any.
    pub deadline: Option<Instant>,
    reply: mpsc::Sender<ServeReply>,
}

/// Completion record returned to the client.
#[derive(Debug, Clone)]
pub struct Response {
    pub id: u64,
    /// Modeled MCM latency for the batch this request rode in (ns).
    pub modeled_batch_ns: f64,
    /// Modeled per-sample latency with pipelining (ns).
    pub modeled_per_sample_ns: f64,
    /// Host-side queueing + execution time.
    pub host_latency: Duration,
    pub batch_size: usize,
}

/// What a waiter receives: a completion or a shed notice.
#[derive(Debug, Clone)]
pub enum ServeReply {
    Done(Response),
    Shed { id: u64, reason: ShedReason },
}

impl ServeReply {
    pub fn id(&self) -> u64 {
        match self {
            ServeReply::Done(r) => r.id,
            ServeReply::Shed { id, .. } => *id,
        }
    }

    /// The completion, or `None` if the request was shed.
    pub fn done(self) -> Option<Response> {
        match self {
            ServeReply::Done(r) => Some(r),
            ServeReply::Shed { .. } => None,
        }
    }
}

/// Server statistics.
#[derive(Debug, Clone, Default)]
pub struct ServerStats {
    pub served: u64,
    pub shed: u64,
    pub batches: u64,
    pub max_batch: usize,
}

/// Batch executor callback: given a batch size, return (modeled batch
/// ns, modeled per-sample ns). Kept as a callback so the server logic
/// is testable without PJRT. The non-`Send` variant is produced
/// *inside* the batcher thread by a [`RunnerFactory`].
pub type BatchRunner = Box<dyn FnMut(usize) -> (f64, f64) + Send>;
pub type LocalBatchRunner = Box<dyn FnMut(usize) -> (f64, f64)>;
pub type RunnerFactory = Box<dyn FnOnce() -> LocalBatchRunner + Send>;

/// Intake protocol: requests, or the shutdown sentinel. An explicit
/// sentinel (rather than relying on every `Sender` clone being
/// dropped) lets [`Server::shutdown`] return even while `Client`
/// handles are still alive — channel FIFO guarantees everything
/// submitted before shutdown is still served first.
enum Msg {
    Req(Request),
    Stop,
}

/// Client handle. Cloneable; ids are process-unique.
#[derive(Clone)]
pub struct Client {
    tx: mpsc::Sender<Msg>,
    next_id: Arc<AtomicU64>,
}

impl Client {
    /// Submit a best-effort request; returns the receiver for its
    /// reply, or an error if the server has shut down.
    pub fn submit(&self) -> Result<mpsc::Receiver<ServeReply>> {
        self.submit_with_deadline(None)
    }

    /// Submit with a relative deadline: if the batch forms after
    /// `deadline` has elapsed, the request is shed rather than run.
    pub fn submit_with_deadline(
        &self,
        deadline: Option<Duration>,
    ) -> Result<mpsc::Receiver<ServeReply>> {
        let (rtx, rrx) = mpsc::channel();
        let id = self.next_id.fetch_add(1, Ordering::Relaxed) + 1;
        let now = Instant::now();
        self.tx
            .send(Msg::Req(Request {
                id,
                submitted: now,
                deadline: deadline.map(|d| now + d),
                reply: rtx,
            }))
            .map_err(|_| crate::err!("server stopped"))?;
        Ok(rrx)
    }
}

/// The batching server. Collects up to `max_batch` requests or waits
/// at most `max_wait` for stragglers, sheds dead-on-arrival requests,
/// then runs the batch.
pub struct Server {
    handle: Option<JoinHandle<ServerStats>>,
    tx: Option<mpsc::Sender<Msg>>,
    next_id: Arc<AtomicU64>,
}

impl Server {
    pub fn start(
        max_batch: usize,
        max_wait: Duration,
        mut runner: BatchRunner,
    ) -> Server {
        Self::start_factory(
            max_batch,
            max_wait,
            Box::new(move || {
                Box::new(move |bsz| runner(bsz)) as LocalBatchRunner
            }),
        )
    }

    /// Start with a factory that builds the runner *on the batcher
    /// thread* (required for PJRT-backed runners, which are not
    /// `Send`).
    pub fn start_factory(
        max_batch: usize,
        max_wait: Duration,
        factory: RunnerFactory,
    ) -> Server {
        assert!(max_batch >= 1);
        let (tx, rx) = mpsc::channel::<Msg>();
        let handle = std::thread::spawn(move || {
            let mut runner = factory();
            let mut stats = ServerStats::default();
            let mut stopping = false;
            while !stopping {
                // Block for the first request of a batch. Requests
                // buffered ahead of the Stop sentinel (or ahead of the
                // last sender dropping) are still served — shutdown
                // never drops in-flight work.
                let first = match rx.recv() {
                    Ok(Msg::Req(r)) => r,
                    Ok(Msg::Stop) | Err(_) => break,
                };
                let mut batch = vec![first];
                let linger_until = Instant::now() + max_wait;
                while batch.len() < max_batch {
                    let now = Instant::now();
                    if now >= linger_until {
                        break;
                    }
                    match rx.recv_timeout(linger_until - now) {
                        Ok(Msg::Req(r)) => batch.push(r),
                        Ok(Msg::Stop) => {
                            stopping = true;
                            break;
                        }
                        Err(mpsc::RecvTimeoutError::Timeout) => break,
                        Err(mpsc::RecvTimeoutError::Disconnected) => break,
                    }
                }
                // Shed requests already past their deadline; don't let
                // dead work occupy the MCM.
                let now = Instant::now();
                let mut live = Vec::with_capacity(batch.len());
                for req in batch {
                    if req.deadline.is_some_and(|d| now > d) {
                        stats.shed += 1;
                        let _ = req.reply.send(ServeReply::Shed {
                            id: req.id,
                            reason: ShedReason::DeadlineExpired,
                        });
                    } else {
                        live.push(req);
                    }
                }
                if live.is_empty() {
                    continue;
                }
                let bsz = live.len();
                let (batch_ns, per_sample_ns) = runner(bsz);
                stats.batches += 1;
                stats.served += bsz as u64;
                stats.max_batch = stats.max_batch.max(bsz);
                for req in live {
                    let _ = req.reply.send(ServeReply::Done(Response {
                        id: req.id,
                        modeled_batch_ns: batch_ns,
                        modeled_per_sample_ns: per_sample_ns,
                        host_latency: req.submitted.elapsed(),
                        batch_size: bsz,
                    }));
                }
            }
            stats
        });
        Server {
            handle: Some(handle),
            tx: Some(tx),
            next_id: Arc::new(AtomicU64::new(0)),
        }
    }

    pub fn client(&self) -> Client {
        Client {
            tx: self.tx.as_ref().expect("server running").clone(),
            next_id: self.next_id.clone(),
        }
    }

    /// Stop the batcher and join it. Requests already submitted are
    /// still served (or deadline-shed) before the stats come back;
    /// `Client` handles outliving the server get errors from `submit`.
    pub fn shutdown(mut self) -> ServerStats {
        if let Some(tx) = self.tx.take() {
            let _ = tx.send(Msg::Stop);
        }
        self.handle.take().unwrap().join().expect("batcher panicked")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fake_runner() -> BatchRunner {
        Box::new(|bsz| {
            let batch_ns = 100.0 + 10.0 * bsz as f64;
            (batch_ns, batch_ns / bsz as f64)
        })
    }

    #[test]
    fn serves_all_requests() {
        let server =
            Server::start(4, Duration::from_millis(5), fake_runner());
        let client = server.client();
        let waiters: Vec<_> =
            (0..10).map(|_| client.submit().unwrap()).collect();
        let mut ids = Vec::new();
        for w in waiters {
            let resp = w.recv().unwrap().done().expect("not shed");
            assert!(resp.batch_size >= 1 && resp.batch_size <= 4);
            ids.push(resp.id);
        }
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), 10);
        drop(client);
        let stats = server.shutdown();
        assert_eq!(stats.served, 10);
        assert_eq!(stats.shed, 0);
        assert!(stats.batches >= 3); // 10 requests, batch cap 4
    }

    #[test]
    fn batching_amortizes_per_sample_latency() {
        let server =
            Server::start(8, Duration::from_millis(30), fake_runner());
        let client = server.client();
        // Submit a burst so they batch together.
        let waiters: Vec<_> =
            (0..8).map(|_| client.submit().unwrap()).collect();
        let resps: Vec<Response> = waiters
            .into_iter()
            .map(|w| w.recv().unwrap().done().expect("not shed"))
            .collect();
        let batched = resps.iter().map(|r| r.batch_size).max().unwrap();
        assert!(batched >= 2, "burst should have batched, got {batched}");
        for r in &resps {
            if r.batch_size > 1 {
                assert!(r.modeled_per_sample_ns < r.modeled_batch_ns);
            }
        }
        drop(client);
        server.shutdown();
    }

    #[test]
    fn shutdown_drains_in_flight_requests() {
        // Satellite pin: requests buffered at shutdown are served, not
        // dropped — mpsc delivers buffered sends before reporting
        // disconnect.
        let server =
            Server::start(4, Duration::from_millis(1), fake_runner());
        let client = server.client();
        let waiters: Vec<_> =
            (0..20).map(|_| client.submit().unwrap()).collect();
        drop(client);
        let stats = server.shutdown();
        assert_eq!(stats.served + stats.shed, 20);
        assert_eq!(stats.shed, 0); // no deadlines -> nothing shed
        for w in waiters {
            // Every waiter got a reply before shutdown returned.
            let reply = w.try_recv().expect("reply missing after shutdown");
            assert!(reply.done().is_some());
        }
    }

    #[test]
    fn zero_wait_serves_solo_batches() {
        // max_wait = 0: no lingering — each request runs the moment the
        // batcher sees it (batch of whatever is already buffered, which
        // for sequential submit/recv pairs is always 1).
        let server = Server::start(8, Duration::ZERO, fake_runner());
        let client = server.client();
        for _ in 0..5 {
            let r = client
                .submit()
                .unwrap()
                .recv()
                .unwrap()
                .done()
                .expect("not shed");
            assert_eq!(r.batch_size, 1);
        }
        drop(client);
        let stats = server.shutdown();
        assert_eq!(stats.served, 5);
        assert_eq!(stats.batches, 5);
    }

    #[test]
    fn expired_deadline_is_shed() {
        let server =
            Server::start(4, Duration::from_millis(1), fake_runner());
        let client = server.client();
        // A zero deadline is already expired when the batch forms.
        let dead = client.submit_with_deadline(Some(Duration::ZERO)).unwrap();
        match dead.recv().unwrap() {
            ServeReply::Shed { reason, .. } => {
                assert_eq!(reason, ShedReason::DeadlineExpired)
            }
            ServeReply::Done(r) => panic!("dead request ran: {r:?}"),
        }
        // A generous deadline still completes.
        let live = client
            .submit_with_deadline(Some(Duration::from_secs(60)))
            .unwrap();
        assert!(live.recv().unwrap().done().is_some());
        drop(client);
        let stats = server.shutdown();
        assert_eq!(stats.shed, 1);
        assert_eq!(stats.served, 1);
    }

    #[test]
    fn submit_after_shutdown_errors() {
        let server =
            Server::start(2, Duration::from_millis(1), fake_runner());
        let client = server.client();
        client.submit().unwrap().recv().unwrap();
        let stats = server.shutdown();
        assert_eq!(stats.served, 1);
        // The old API panicked here; now it reports the error.
        assert!(client.submit().is_err());
    }
}
