//! GEMM runtime over the AOT bucket artifacts — the only place Rust
//! would touch XLA. Loads `artifacts/manifest.json`, resolves every
//! chunk to the smallest covering power-of-two bucket, pads, executes,
//! and slices the result back.
//!
//! Two backends share the same bucket/padding contract:
//!
//! * **default** — a pure-Rust CPU interpreter: the padded GEMM is
//!   computed by [`reference_gemm`]. Zero dependencies, bit-exact with
//!   the reference by construction, so the whole e2e path (executor,
//!   server, examples) runs on the offline image.
//! * **`pjrt-xla` feature** — compiles each bucket's HLO text once on
//!   the PJRT CPU client via the `xla` crate (vendor it yourself; the
//!   offline image has no crates.io) and executes chunks there.
//!
//! Python never runs here: this is the request path.

use std::path::Path;
use std::sync::Mutex;

use crate::ensure;
use crate::util::error::Result;

use super::artifacts::{pad_matrix, unpad_matrix, Bucket, Manifest};

#[cfg(feature = "pjrt-xla")]
use crate::util::error::Context;

/// Lazily-compiled bucket executables over one backend.
pub struct GemmRuntime {
    #[cfg(feature = "pjrt-xla")]
    client: xla::PjRtClient,
    #[cfg(feature = "pjrt-xla")]
    cache: Mutex<std::collections::HashMap<String, xla::PjRtLoadedExecutable>>,
    /// Interpreter backend: buckets "compiled" (touched) so far.
    #[cfg(not(feature = "pjrt-xla"))]
    cache: Mutex<std::collections::HashSet<String>>,
    manifest: Manifest,
    /// Executed-chunk counter (metrics).
    pub executions: std::sync::atomic::AtomicU64,
}

impl GemmRuntime {
    pub fn new(artifact_dir: &Path) -> Result<Self> {
        let manifest = Manifest::load(artifact_dir)?;
        Ok(GemmRuntime {
            #[cfg(feature = "pjrt-xla")]
            client: xla::PjRtClient::cpu()
                .context("creating PJRT CPU client")?,
            cache: Mutex::new(Default::default()),
            manifest,
            executions: std::sync::atomic::AtomicU64::new(0),
        })
    }

    pub fn platform(&self) -> String {
        #[cfg(feature = "pjrt-xla")]
        {
            self.client.platform_name()
        }
        #[cfg(not(feature = "pjrt-xla"))]
        {
            "cpu-interpreter".to_string()
        }
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Number of compiled executables currently cached.
    pub fn compiled_count(&self) -> usize {
        self.cache.lock().unwrap().len()
    }

    /// Execute `relu?(x @ w + bias)` for a row-major `m x k` activation
    /// chunk and `k x n` weight chunk via the smallest covering bucket.
    pub fn gemm(
        &self,
        x: &[f32],
        w: &[f32],
        bias: Option<&[f32]>,
        m: usize,
        k: usize,
        n: usize,
        relu: bool,
    ) -> Result<Vec<f32>> {
        ensure!(x.len() == m * k, "x: {} != {m}x{k}", x.len());
        ensure!(w.len() == k * n, "w: {} != {k}x{n}", w.len());
        if let Some(b) = bias {
            ensure!(b.len() == n, "bias: {} != {n}", b.len());
        }
        if m == 0 || n == 0 {
            return Ok(Vec::new());
        }
        let bucket = self.manifest.pick(m, k, n, relu)?;
        let xp = pad_matrix(x, m, k, bucket.m, bucket.k);
        let wp = pad_matrix(w, k, n, bucket.k, bucket.n);
        let mut bp = vec![0.0f32; bucket.n];
        if let Some(b) = bias {
            bp[..n].copy_from_slice(b);
        }
        let full = self.execute_bucket(bucket, &xp, &wp, &bp)?;
        self.executions
            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        Ok(unpad_matrix(&full, bucket.m, bucket.n, m, n))
    }

    /// Interpreter backend: the padded bucket GEMM is computed by the
    /// CPU reference. Padding with zeros is exact for GEMM, so this is
    /// bit-identical to slicing the true bucket result.
    #[cfg(not(feature = "pjrt-xla"))]
    fn execute_bucket(
        &self,
        bucket: &Bucket,
        xp: &[f32],
        wp: &[f32],
        bp: &[f32],
    ) -> Result<Vec<f32>> {
        // "Compile" = record the bucket on first use, mirroring the
        // one-executable-per-bucket cache of the XLA path.
        self.cache.lock().unwrap().insert(bucket.name.clone());
        Ok(reference_gemm(
            xp,
            wp,
            Some(bp),
            bucket.m,
            bucket.k,
            bucket.n,
            bucket.relu,
        ))
    }

    /// XLA backend: lazily compile the bucket's HLO text, then execute.
    /// `PjRtLoadedExecutable` is not `Clone`, so execution happens under
    /// the cache lock; executions are short and the CPU client
    /// serializes anyway.
    #[cfg(feature = "pjrt-xla")]
    fn execute_bucket(
        &self,
        bucket: &Bucket,
        xp: &[f32],
        wp: &[f32],
        bp: &[f32],
    ) -> Result<Vec<f32>> {
        let lx = xla::Literal::vec1(xp)
            .reshape(&[bucket.m as i64, bucket.k as i64])?;
        let lw = xla::Literal::vec1(wp)
            .reshape(&[bucket.k as i64, bucket.n as i64])?;
        let lb = xla::Literal::vec1(bp).reshape(&[bucket.n as i64])?;
        let mut cache = self.cache.lock().unwrap();
        if !cache.contains_key(&bucket.name) {
            let proto = xla::HloModuleProto::from_text_file(&bucket.path)
                .with_context(|| {
                    format!("parsing HLO text {}", bucket.path.display())
                })?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .with_context(|| format!("compiling bucket {}", bucket.name))?;
            cache.insert(bucket.name.clone(), exe);
        }
        let exe = cache.get(&bucket.name).unwrap();
        let result =
            exe.execute::<xla::Literal>(&[lx, lw, lb])?[0][0]
                .to_literal_sync()?;
        Ok(result.to_tuple1()?.to_vec::<f32>()?)
    }
}

/// Plain CPU reference GEMM used to verify the runtime path end to end
/// (and, in the interpreter backend, to execute it).
pub fn reference_gemm(
    x: &[f32],
    w: &[f32],
    bias: Option<&[f32]>,
    m: usize,
    k: usize,
    n: usize,
    relu: bool,
) -> Vec<f32> {
    let mut out = vec![0.0f32; m * n];
    for i in 0..m {
        for l in 0..k {
            let a = x[i * k + l];
            if a == 0.0 {
                continue;
            }
            let wrow = &w[l * n..(l + 1) * n];
            let orow = &mut out[i * n..(i + 1) * n];
            for j in 0..n {
                orow[j] += a * wrow[j];
            }
        }
    }
    if let Some(b) = bias {
        for i in 0..m {
            for j in 0..n {
                out[i * n + j] += b[j];
            }
        }
    }
    if relu {
        for v in out.iter_mut() {
            *v = v.max(0.0);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reference_gemm_known_values() {
        // [[1,2],[3,4]] @ [[1,0],[0,1]] = identity passthrough.
        let x = [1.0, 2.0, 3.0, 4.0];
        let w = [1.0, 0.0, 0.0, 1.0];
        assert_eq!(reference_gemm(&x, &w, None, 2, 2, 2, false), x);
        // With bias and relu.
        let out =
            reference_gemm(&x, &w, Some(&[-10.0, 0.0]), 2, 2, 2, true);
        assert_eq!(out, [0.0, 2.0, 0.0, 4.0]);
    }

    #[cfg(not(feature = "pjrt-xla"))]
    #[test]
    fn interpreter_backend_matches_reference_through_padding() {
        let dir =
            std::env::temp_dir().join("mcmcomm_pjrt_interp_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"version": 1, "buckets": [
                {"name": "b16", "path": "b16.hlo.txt", "m": 16, "k": 16,
                 "n": 16, "relu": false},
                {"name": "b16r", "path": "b16r.hlo.txt", "m": 16, "k": 16,
                 "n": 16, "relu": true}]}"#,
        )
        .unwrap();
        let rt = GemmRuntime::new(&dir).unwrap();
        assert_eq!(rt.platform(), "cpu-interpreter");
        let mut rng = crate::util::rng::Pcg::seeded(9);
        let (m, k, n) = (5, 11, 7); // ragged: forces padding
        let x: Vec<f32> =
            (0..m * k).map(|_| rng.normal() as f32).collect();
        let w: Vec<f32> =
            (0..k * n).map(|_| rng.normal() as f32).collect();
        let b: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
        for relu in [false, true] {
            let got = rt.gemm(&x, &w, Some(&b), m, k, n, relu).unwrap();
            let want =
                reference_gemm(&x, &w, Some(&b), m, k, n, relu);
            assert_eq!(got, want, "relu={relu}");
        }
        assert_eq!(rt.compiled_count(), 2);
        assert_eq!(
            rt.executions.load(std::sync::atomic::Ordering::Relaxed),
            2
        );
    }

    // XLA-backed tests live in rust/tests/e2e_runtime.rs (they need
    // `make artifacts` and the `pjrt-xla` feature).
}
