//! PJRT execution of AOT HLO artifacts — the only place Rust touches
//! XLA. Loads `artifacts/*.hlo.txt` (HLO **text**: the id-safe
//! interchange format, see python/compile/aot.py), compiles once per
//! bucket on the CPU PJRT client, and executes padded GEMM chunks.
//!
//! Python never runs here: this is the request path.

use std::collections::HashMap;
use std::path::Path;
use std::sync::Mutex;

use anyhow::{Context, Result};

use super::artifacts::{pad_matrix, unpad_matrix, Manifest};

/// Lazily-compiled bucket executables over one PJRT client.
pub struct GemmRuntime {
    client: xla::PjRtClient,
    manifest: Manifest,
    cache: Mutex<HashMap<String, xla::PjRtLoadedExecutable>>,
    /// Executed-chunk counter (metrics).
    pub executions: std::sync::atomic::AtomicU64,
}

impl GemmRuntime {
    pub fn new(artifact_dir: &Path) -> Result<Self> {
        let client =
            xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let manifest = Manifest::load(artifact_dir)?;
        Ok(GemmRuntime {
            client,
            manifest,
            cache: Mutex::new(HashMap::new()),
            executions: std::sync::atomic::AtomicU64::new(0),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Run `f` with the (lazily compiled) executable for a bucket.
    /// `PjRtLoadedExecutable` is not `Clone`, so callers execute under
    /// the cache lock; executions are short and the CPU client
    /// serializes anyway.
    fn with_executable<T>(
        &self,
        name: &str,
        path: &Path,
        f: impl FnOnce(&xla::PjRtLoadedExecutable) -> Result<T>,
    ) -> Result<T> {
        let mut cache = self.cache.lock().unwrap();
        if !cache.contains_key(name) {
            let proto =
                xla::HloModuleProto::from_text_file(path).with_context(
                    || format!("parsing HLO text {}", path.display()),
                )?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .with_context(|| format!("compiling bucket {name}"))?;
            cache.insert(name.to_string(), exe);
        }
        f(cache.get(name).unwrap())
    }

    /// Number of compiled executables currently cached.
    pub fn compiled_count(&self) -> usize {
        self.cache.lock().unwrap().len()
    }

    /// Execute `relu?(x @ w + bias)` for a row-major `m x k` activation
    /// chunk and `k x n` weight chunk via the smallest covering bucket.
    pub fn gemm(
        &self,
        x: &[f32],
        w: &[f32],
        bias: Option<&[f32]>,
        m: usize,
        k: usize,
        n: usize,
        relu: bool,
    ) -> Result<Vec<f32>> {
        anyhow::ensure!(x.len() == m * k, "x: {} != {m}x{k}", x.len());
        anyhow::ensure!(w.len() == k * n, "w: {} != {k}x{n}", w.len());
        if let Some(b) = bias {
            anyhow::ensure!(b.len() == n, "bias: {} != {n}", b.len());
        }
        if m == 0 || n == 0 {
            return Ok(Vec::new());
        }
        let bucket = self.manifest.pick(m, k, n, relu)?;
        let xp = pad_matrix(x, m, k, bucket.m, bucket.k);
        let wp = pad_matrix(w, k, n, bucket.k, bucket.n);
        let mut bp = vec![0.0f32; bucket.n];
        if let Some(b) = bias {
            bp[..n].copy_from_slice(b);
        }
        let lx = xla::Literal::vec1(&xp)
            .reshape(&[bucket.m as i64, bucket.k as i64])?;
        let lw = xla::Literal::vec1(&wp)
            .reshape(&[bucket.k as i64, bucket.n as i64])?;
        let lb = xla::Literal::vec1(&bp).reshape(&[bucket.n as i64])?;

        let full = self.with_executable(&bucket.name, &bucket.path, |exe| {
            let result = exe.execute::<xla::Literal>(&[lx, lw, lb])?[0][0]
                .to_literal_sync()?;
            Ok(result.to_tuple1()?.to_vec::<f32>()?)
        })?;
        self.executions
            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        Ok(unpad_matrix(&full, bucket.m, bucket.n, m, n))
    }
}

/// Plain CPU reference GEMM used to verify the PJRT path end to end.
pub fn reference_gemm(
    x: &[f32],
    w: &[f32],
    bias: Option<&[f32]>,
    m: usize,
    k: usize,
    n: usize,
    relu: bool,
) -> Vec<f32> {
    let mut out = vec![0.0f32; m * n];
    for i in 0..m {
        for l in 0..k {
            let a = x[i * k + l];
            if a == 0.0 {
                continue;
            }
            let wrow = &w[l * n..(l + 1) * n];
            let orow = &mut out[i * n..(i + 1) * n];
            for j in 0..n {
                orow[j] += a * wrow[j];
            }
        }
    }
    if let Some(b) = bias {
        for i in 0..m {
            for j in 0..n {
                out[i * n + j] += b[j];
            }
        }
    }
    if relu {
        for v in out.iter_mut() {
            *v = v.max(0.0);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reference_gemm_known_values() {
        // [[1,2],[3,4]] @ [[1,0],[0,1]] = identity passthrough.
        let x = [1.0, 2.0, 3.0, 4.0];
        let w = [1.0, 0.0, 0.0, 1.0];
        assert_eq!(reference_gemm(&x, &w, None, 2, 2, 2, false), x);
        // With bias and relu.
        let out =
            reference_gemm(&x, &w, Some(&[-10.0, 0.0]), 2, 2, 2, true);
        assert_eq!(out, [0.0, 2.0, 0.0, 4.0]);
    }

    // PJRT-backed tests live in rust/tests/e2e_runtime.rs (they need
    // `make artifacts` to have run).
}
