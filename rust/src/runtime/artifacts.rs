//! Artifact manifest: the contract between `python/compile/aot.py` and
//! the PJRT runtime.
//!
//! HLO is shape-static, so the AOT step emits one executable per
//! power-of-two (M, K, N, relu) *bucket*; the runtime pads a chiplet
//! chunk up to the smallest covering bucket and slices the result back.
//! Padding with zeros is exact for GEMM (+bias broadcast on padded
//! columns is sliced away; ReLU(0) = 0).

use std::path::{Path, PathBuf};

use crate::util::error::{Context, Error, Result};
use crate::util::json::Json;
use crate::{bail, err};

/// One AOT-compiled GEMM bucket.
#[derive(Debug, Clone, PartialEq)]
pub struct Bucket {
    pub name: String,
    pub path: PathBuf,
    pub m: usize,
    pub k: usize,
    pub n: usize,
    pub relu: bool,
}

/// Parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub buckets: Vec<Bucket>,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let raw = std::fs::read_to_string(dir.join("manifest.json"))
            .with_context(|| {
                format!(
                    "reading {}/manifest.json — run `make artifacts` first",
                    dir.display()
                )
            })?;
        let json = Json::parse(&raw).map_err(Error::msg)?;
        let version = json
            .get("version")
            .and_then(Json::as_usize)
            .ok_or_else(|| err!("manifest missing version"))?;
        if version != 1 {
            bail!("unsupported manifest version {version}");
        }
        let mut buckets = Vec::new();
        for b in json
            .get("buckets")
            .and_then(Json::as_arr)
            .ok_or_else(|| err!("manifest missing buckets"))?
        {
            let field = |k: &str| {
                b.get(k)
                    .and_then(Json::as_usize)
                    .ok_or_else(|| err!("bucket missing '{k}'"))
            };
            buckets.push(Bucket {
                name: b
                    .get("name")
                    .and_then(Json::as_str)
                    .ok_or_else(|| err!("bucket missing name"))?
                    .to_string(),
                path: dir.join(
                    b.get("path")
                        .and_then(Json::as_str)
                        .ok_or_else(|| err!("bucket missing path"))?,
                ),
                m: field("m")?,
                k: field("k")?,
                n: field("n")?,
                relu: b
                    .get("relu")
                    .and_then(Json::as_bool)
                    .ok_or_else(|| err!("bucket missing relu"))?,
            });
        }
        if buckets.is_empty() {
            bail!("manifest has no buckets");
        }
        Ok(Manifest { dir: dir.to_path_buf(), buckets })
    }

    /// The default artifact directory: `$MCMCOMM_ARTIFACTS` or
    /// `./artifacts`.
    pub fn default_dir() -> PathBuf {
        std::env::var_os("MCMCOMM_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|| PathBuf::from("artifacts"))
    }

    /// Smallest bucket covering (m, k, n) with the right epilogue.
    pub fn pick(&self, m: usize, k: usize, n: usize, relu: bool)
                -> Result<&Bucket> {
        self.buckets
            .iter()
            .filter(|b| {
                b.relu == relu && b.m >= m && b.k >= k && b.n >= n
            })
            .min_by_key(|b| b.m * b.k + b.k * b.n + b.m * b.n)
            .ok_or_else(|| {
                err!(
                    "no bucket covers m={m} k={k} n={n} relu={relu} \
                     (largest emitted dim: {}); re-run aot.py with bigger \
                     --dims or scale the workload down",
                    self.buckets.iter().map(|b| b.m.max(b.k).max(b.n))
                        .max().unwrap_or(0)
                )
            })
    }
}

/// Pad a row-major `rows x cols` matrix to `prows x pcols` with zeros.
pub fn pad_matrix(
    data: &[f32],
    rows: usize,
    cols: usize,
    prows: usize,
    pcols: usize,
) -> Vec<f32> {
    assert_eq!(data.len(), rows * cols);
    assert!(prows >= rows && pcols >= cols);
    let mut out = vec![0.0f32; prows * pcols];
    for r in 0..rows {
        out[r * pcols..r * pcols + cols]
            .copy_from_slice(&data[r * cols..(r + 1) * cols]);
    }
    out
}

/// Slice the top-left `rows x cols` of a row-major `prows x pcols`.
pub fn unpad_matrix(
    data: &[f32],
    prows: usize,
    pcols: usize,
    rows: usize,
    cols: usize,
) -> Vec<f32> {
    assert_eq!(data.len(), prows * pcols);
    assert!(prows >= rows && pcols >= cols);
    let mut out = Vec::with_capacity(rows * cols);
    for r in 0..rows {
        out.extend_from_slice(&data[r * pcols..r * pcols + cols]);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fake_manifest() -> Manifest {
        let mk = |m: usize, k: usize, n: usize, relu: bool| Bucket {
            name: format!("b{m}_{k}_{n}_{relu}"),
            path: PathBuf::from("x"),
            m,
            k,
            n,
            relu,
        };
        Manifest {
            dir: PathBuf::from("."),
            buckets: vec![
                mk(16, 16, 16, false),
                mk(64, 64, 64, false),
                mk(256, 256, 256, false),
                mk(16, 16, 16, true),
                mk(64, 256, 64, false),
            ],
        }
    }

    #[test]
    fn pick_smallest_covering() {
        let m = fake_manifest();
        assert_eq!(m.pick(10, 10, 10, false).unwrap().m, 16);
        assert_eq!(m.pick(17, 16, 16, false).unwrap().m, 64);
        // Rect bucket preferred over cube when cheaper.
        assert_eq!(m.pick(60, 200, 60, false).unwrap().name, "b64_256_64_false");
        assert!(m.pick(300, 16, 16, false).is_err());
        assert_eq!(m.pick(16, 16, 16, true).unwrap().relu, true);
    }

    #[test]
    fn pad_unpad_roundtrip() {
        let data: Vec<f32> = (0..6).map(|x| x as f32).collect(); // 2x3
        let padded = pad_matrix(&data, 2, 3, 4, 5);
        assert_eq!(padded.len(), 20);
        assert_eq!(padded[0..3], [0.0, 1.0, 2.0]);
        assert_eq!(padded[3..5], [0.0, 0.0]);
        assert_eq!(padded[5..8], [3.0, 4.0, 5.0]);
        let back = unpad_matrix(&padded, 4, 5, 2, 3);
        assert_eq!(back, data);
    }

    #[test]
    fn manifest_parses_real_format() {
        let dir = std::env::temp_dir().join("mcmcomm_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"version": 1, "kernel": "matmul_os", "accum_dtype": "f32",
                "buckets": [{"name": "g", "path": "g.hlo.txt", "m": 16,
                             "k": 16, "n": 16, "relu": false, "dtype": "f32"}]}"#,
        )
        .unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.buckets.len(), 1);
        assert_eq!(m.buckets[0].path, dir.join("g.hlo.txt"));
    }

    #[test]
    fn missing_manifest_is_helpful() {
        let err = Manifest::load(Path::new("/nonexistent-dir-xyz"))
            .unwrap_err()
            .to_string();
        assert!(err.contains("make artifacts"));
    }
}
