//! PJRT runtime: load and execute the AOT-compiled GEMM artifacts on the
//! Layer-3 request path (no Python anywhere here).

pub mod artifacts;
pub mod pjrt;

pub use artifacts::Manifest;
pub use pjrt::{reference_gemm, GemmRuntime};
