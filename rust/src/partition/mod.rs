//! Workload allocation (paper §4.2.3): per-op partitions `Px[X]`,
//! `Py[Y]` assigning output rows/columns to chiplet grid rows/columns,
//! the §6.2 search-space constraints, and the baseline partitioners
//! (uniform LS, SIMBA-like inverse-distance).

use crate::platform::Platform;
use crate::topology::Pos;
use crate::workload::{GemmOp, Workload};

/// Partition of one GEMM: `px[x]` output rows for chiplet grid row `x`,
/// `py[y]` output columns for grid column `y`.
/// Invariants: `px.len() == X`, `py.len() == Y`, `sum(px) == M`,
/// `sum(py) == N`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Partition {
    pub px: Vec<usize>,
    pub py: Vec<usize>,
}

impl Partition {
    pub fn validate(&self, op: &GemmOp) -> Result<(), String> {
        if self.px.iter().sum::<usize>() != op.m {
            return Err(format!(
                "sum(px)={} != M={} for '{}'",
                self.px.iter().sum::<usize>(),
                op.m,
                op.name
            ));
        }
        if self.py.iter().sum::<usize>() != op.n {
            return Err(format!(
                "sum(py)={} != N={} for '{}'",
                self.py.iter().sum::<usize>(),
                op.n,
                op.name
            ));
        }
        Ok(())
    }

    /// The chunk (rows, cols) computed by chiplet at grid (x, y).
    pub fn chunk(&self, x: usize, y: usize) -> (usize, usize) {
        (self.px[x], self.py[y])
    }
}

/// A full allocation: one partition per op (indexed by op id), plus one
/// collection column per **dataflow edge** used by on-package
/// redistribution (§5.2/§6.2 — "positions of the collection chiplet"
/// are GA genes). `collect_cols[e]` belongs to `wl.edges[e]`.
#[derive(Debug, Clone, PartialEq)]
pub struct Allocation {
    pub parts: Vec<Partition>,
    pub collect_cols: Vec<usize>,
}

impl Allocation {
    pub fn validate(
        &self,
        wl: &Workload,
        plat: &Platform,
    ) -> Result<(), String> {
        if self.parts.len() != wl.ops.len() {
            return Err("allocation arity != op count".into());
        }
        for (p, op) in self.parts.iter().zip(&wl.ops) {
            if p.px.len() != plat.xdim || p.py.len() != plat.ydim {
                return Err(format!("partition arity mismatch for '{}'", op.name));
            }
            p.validate(op)?;
        }
        if self.collect_cols.len() != wl.edge_count() {
            return Err(format!(
                "collect_cols arity {} != edge count {}",
                self.collect_cols.len(),
                wl.edge_count()
            ));
        }
        for &c in &self.collect_cols {
            if c >= plat.ydim {
                return Err(format!("collect col {c} out of range"));
            }
        }
        Ok(())
    }
}

/// Split `total` into `parts` integers proportional to `weights`,
/// preserving the exact sum (largest-remainder rounding). Zero weights
/// yield zero shares unless everything is zero.
pub fn proportional_split(total: usize, weights: &[f64]) -> Vec<usize> {
    assert!(!weights.is_empty());
    let wsum: f64 = weights.iter().sum();
    if wsum <= 0.0 {
        return uniform_split(total, weights.len());
    }
    let mut out = vec![0usize; weights.len()];
    let mut rema: Vec<(f64, usize)> = Vec::with_capacity(weights.len());
    let mut assigned = 0usize;
    for (i, w) in weights.iter().enumerate() {
        let exact = total as f64 * w / wsum;
        out[i] = exact.floor() as usize;
        assigned += out[i];
        rema.push((exact - exact.floor(), i));
    }
    rema.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap().then(a.1.cmp(&b.1)));
    for (_, i) in rema.into_iter().take(total - assigned) {
        out[i] += 1;
    }
    out
}

/// Even split (uniform LS baseline): remainder spread over the first
/// rows.
pub fn uniform_split(total: usize, parts: usize) -> Vec<usize> {
    assert!(parts > 0);
    let base = total / parts;
    let rem = total % parts;
    (0..parts).map(|i| base + usize::from(i < rem)).collect()
}

/// The paper's baseline: uniform partitioning in both dimensions.
pub fn uniform(plat: &Platform, op: &GemmOp) -> Partition {
    Partition {
        px: uniform_split(op.m, plat.xdim),
        py: uniform_split(op.n, plat.ydim),
    }
}

/// SIMBA-like heuristic (§3.1): share inversely proportional to the
/// chiplet's communication distance from off-chip memory, per grid row /
/// column (marginalized over the other dimension).
pub fn simba(plat: &Platform, op: &GemmOp) -> Partition {
    let inv = |d: usize| 1.0 / (d as f64 + 1.0);
    let row_w: Vec<f64> = (0..plat.xdim)
        .map(|x| {
            (0..plat.ydim)
                .map(|y| inv(plat.distance_to_memory(Pos::new(x, y))))
                .sum()
        })
        .collect();
    let col_w: Vec<f64> = (0..plat.ydim)
        .map(|y| {
            (0..plat.xdim)
                .map(|x| inv(plat.distance_to_memory(Pos::new(x, y))))
                .sum()
        })
        .collect();
    Partition {
        px: proportional_split(op.m, &row_w),
        py: proportional_split(op.n, &col_w),
    }
}

/// Whole-workload allocations for the two non-optimized schemes
/// (Table 3 rows "Layer Sequential" and "SIMBA-like").
pub fn uniform_allocation(plat: &Platform, wl: &Workload) -> Allocation {
    Allocation {
        parts: wl.ops.iter().map(|op| uniform(plat, op)).collect(),
        collect_cols: vec![plat.ydim / 2; wl.edge_count()],
    }
}

pub fn simba_allocation(plat: &Platform, wl: &Workload) -> Allocation {
    Allocation {
        parts: wl.ops.iter().map(|op| simba(plat, op)).collect(),
        collect_cols: vec![plat.ydim / 2; wl.edge_count()],
    }
}

/// §6.2 search-space bounds for one dimension: the uniform tile count
/// ±2 tiles, floored at one systolic tile (R): partitions below R
/// under-utilize the PE array.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Bounds {
    pub lo: usize,
    pub hi: usize,
    /// Mutation step (one systolic tile).
    pub step: usize,
}

impl Bounds {
    pub fn clamp(&self, v: usize) -> usize {
        v.clamp(self.lo, self.hi)
    }
}

/// Bounds for partitioning `total` over `parts` grid rows with tile
/// size `tile` (R for rows, C for columns).
pub fn dim_bounds(total: usize, parts: usize, tile: usize) -> Bounds {
    let uniform_tiles = (total as f64 / parts as f64 / tile as f64).ceil() as usize;
    let lo_tiles = uniform_tiles.saturating_sub(2).max(1);
    let hi_tiles = uniform_tiles + 2;
    // Small workloads (total < parts * tile) cannot give every grid row
    // a full tile: rows must be allowed to idle (lo = 0).
    let lo = if total >= parts * tile {
        (lo_tiles * tile).min(total)
    } else {
        0
    };
    let hi = (hi_tiles * tile).min(total);
    Bounds { lo, hi: hi.max(1), step: tile }
}

/// Project `vals` so that each lies in `bounds` and the sum equals
/// `total` (greedy water-filling; feasible whenever
/// `parts*lo <= total <= parts*hi` and best-effort otherwise).
pub fn project_to_sum(vals: &mut [usize], total: usize, bounds: Bounds) {
    for v in vals.iter_mut() {
        *v = bounds.clamp(*v);
    }
    let mut sum: usize = vals.iter().sum();
    // Add to the smallest / remove from the largest until the sum fits:
    // keeps the distribution shape while restoring feasibility.
    while sum < total {
        let deficit = total - sum;
        let i = (0..vals.len())
            .filter(|&i| vals[i] < bounds.hi)
            .min_by_key(|&i| vals[i]);
        match i {
            Some(i) => {
                let add = deficit.min(bounds.hi - vals[i]);
                vals[i] += add;
                sum += add;
            }
            None => {
                // Bounds infeasible: spill into the last entry.
                let last = vals.len() - 1;
                vals[last] += deficit;
                sum += deficit;
            }
        }
    }
    while sum > total {
        let excess = sum - total;
        let i = (0..vals.len())
            .filter(|&i| vals[i] > bounds.lo)
            .max_by_key(|&i| vals[i]);
        match i {
            Some(i) => {
                let sub = excess.min(vals[i] - bounds.lo);
                vals[i] -= sub;
                sum -= sub;
            }
            None => {
                let first = 0;
                let sub = excess.min(vals[first].saturating_sub(1));
                vals[first] -= sub;
                sum -= sub;
                if sub == 0 {
                    break;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{MemKind, SystemType};

    fn plat() -> Platform {
        Platform::preset(SystemType::A, MemKind::Hbm, 4)
    }

    #[test]
    fn uniform_split_sums_and_balance() {
        let s = uniform_split(10, 4);
        assert_eq!(s.iter().sum::<usize>(), 10);
        assert_eq!(s, vec![3, 3, 2, 2]);
    }

    #[test]
    fn proportional_split_preserves_sum() {
        let s = proportional_split(100, &[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.iter().sum::<usize>(), 100);
        assert!(s[3] > s[0]);
        // Degenerate weights fall back to uniform.
        let z = proportional_split(7, &[0.0, 0.0]);
        assert_eq!(z.iter().sum::<usize>(), 7);
    }

    #[test]
    fn uniform_partition_valid() {
        let op = GemmOp::dense("x", 1000, 64, 300);
        let p = uniform(&plat(), &op);
        assert!(p.validate(&op).is_ok());
        assert_eq!(p.px.len(), 4);
    }

    #[test]
    fn simba_prefers_near_chiplets_type_a() {
        let t = plat();
        let op = GemmOp::dense("x", 1000, 64, 1000);
        let p = simba(&t, &op);
        assert!(p.validate(&op).is_ok());
        // Row 0 (contains the global chiplet) gets the largest share.
        assert!(p.px[0] > p.px[3], "px={:?}", p.px);
        assert!(p.py[0] > p.py[3], "py={:?}", p.py);
    }

    #[test]
    fn simba_uniform_on_type_c() {
        let t = Platform::preset(SystemType::C, MemKind::Hbm, 4);
        let op = GemmOp::dense("x", 400, 64, 400);
        let p = simba(&t, &op);
        assert_eq!(p.px, uniform_split(400, 4));
    }

    #[test]
    fn bounds_match_paper_formula() {
        // M=1024 over 4 rows, R=16: uniform tiles = 16 -> [14, 18] tiles.
        let b = dim_bounds(1024, 4, 16);
        assert_eq!((b.lo, b.hi), (14 * 16, 18 * 16));
        // Tiny workload: rows may idle (lo = 0), hi capped at total.
        let b = dim_bounds(8, 4, 16);
        assert_eq!((b.lo, b.hi), (0, 8));
    }

    #[test]
    fn project_restores_sum_within_bounds() {
        let b = Bounds { lo: 16, hi: 128, step: 16 };
        let mut v = vec![200, 10, 50, 50];
        project_to_sum(&mut v, 240, b);
        assert_eq!(v.iter().sum::<usize>(), 240);
        assert!(v.iter().all(|&x| (16..=128).contains(&x)), "{v:?}");
    }

    #[test]
    fn project_handles_infeasible_bounds() {
        let b = Bounds { lo: 16, hi: 20, step: 16 };
        let mut v = vec![16, 16];
        project_to_sum(&mut v, 100, b); // 2*20 < 100: spills
        assert_eq!(v.iter().sum::<usize>(), 100);
    }

    #[test]
    fn allocation_validation() {
        let t = plat();
        let wl = Workload::new(
            "w",
            vec![GemmOp::dense("a", 100, 32, 64)],
        );
        let mut a = uniform_allocation(&t, &wl);
        assert!(a.validate(&wl, &t).is_ok());
        a.parts[0].px[0] += 1;
        assert!(a.validate(&wl, &t).is_err());
    }
}
