//! Genetic-algorithm scheduler (paper §6.2).
//!
//! Genome = a full [`Allocation`]: per-op partitions (Px, Py) plus one
//! collection-chiplet column per **dataflow edge** used by on-package
//! redistribution — the two gene sets the paper crosses over and
//! mutates. Partition genes are constrained to the §6.2 trust region
//! (uniform ± 2 systolic tiles, floored at one tile) and always sum to
//! the exact workload dims; redistribution genes are mutated over edge
//! neighborhoods (an op mutation perturbs only the collection columns
//! of edges incident to that op, mirroring the cache's edge-endpoint
//! invalidation). Fitness is the true analytical evaluator (eq. 6),
//! delta-scored through per-worker [`CachedEval`]s and evaluated in
//! parallel.
//!
//! Determinism (DESIGN.md §Performance architecture): every stochastic
//! decision — population seeding, tournament picks, crossover masks,
//! mutations — happens on the calling thread, in a fixed order, before
//! each generation's fitness fan-out. Fitness values are bit-identical
//! to the sequential full evaluator regardless of cache state or
//! thread count, so the same seed yields the same result at any
//! `threads` setting.

use std::time::{Duration, Instant};

use crate::cost::evaluator::{Objective, OptFlags};
use crate::cost::CachedEval;
use crate::partition::{
    dim_bounds, project_to_sum, simba_allocation, uniform_allocation,
    Allocation,
};
use crate::platform::Platform;
use crate::util::par::{par_for_each_state, par_map_state, resolve_threads};
use crate::util::rng::Pcg;
use crate::workload::Workload;

#[derive(Debug, Clone)]
pub struct GaParams {
    pub population: usize,
    pub generations: usize,
    pub elite: usize,
    pub tournament: usize,
    /// Per-op crossover probability.
    pub p_cross: f64,
    /// Per-genome mutation count (expected).
    pub mutations: usize,
    pub seed: u64,
    /// Optional wall-clock budget (paper: GA ≈ 30 s).
    pub budget: Option<Duration>,
    /// Fitness worker threads; `0` = auto (`MCMCOMM_THREADS` env or the
    /// machine's parallelism), `1` = fully sequential. Results are
    /// bit-identical across all settings.
    pub threads: usize,
    /// Island count (`<= 1` = the classic single-population GA). With
    /// K islands the population is split into K independent demes, each
    /// with its own seeded RNG stream and its worker's warm
    /// [`CachedEval`]; demes evolve in parallel and exchange elites on
    /// a ring every [`GaParams::migration_interval`] generations.
    /// Results are bit-identical across `threads` settings for any K
    /// (DESIGN.md §Optimizer scale-out).
    pub islands: usize,
    /// Generations between ring migrations in island mode.
    pub migration_interval: usize,
    /// Elites each island sends to its ring successor per migration.
    pub migrants: usize,
}

impl Default for GaParams {
    fn default() -> Self {
        GaParams {
            population: 48,
            generations: 80,
            elite: 2,
            tournament: 3,
            p_cross: 0.5,
            mutations: 4,
            seed: 0xc0ffee,
            budget: None,
            threads: 0,
            islands: 1,
            migration_interval: 4,
            migrants: 2,
        }
    }
}

/// Wall-clock split of one [`optimize`] run (`optimize --profile`).
/// Timings are informational only — they never feed back into any
/// decision, so determinism is unaffected.
#[derive(Debug, Clone, Copy, Default)]
pub struct GaProfile {
    /// Fitness evaluation (summed across islands/workers).
    pub eval_ns: u64,
    /// Selection, crossover and mutation.
    pub breed_ns: u64,
    /// Ring migration (island mode only).
    pub migration_ns: u64,
}

#[derive(Debug, Clone)]
pub struct GaResult {
    pub alloc: Allocation,
    pub objective_value: f64,
    pub generations_run: usize,
    /// Best objective per generation (convergence diagnostics). In
    /// island mode: the best across all islands at each generation.
    pub history: Vec<f64>,
    /// Per-phase wall-clock timings of this run.
    pub profile: GaProfile,
}

struct Ctx<'a> {
    plat: &'a Platform,
    wl: &'a Workload,
    /// Per op: ids of every incident dataflow edge (in + out) — the
    /// neighborhood a mutation of that op can perturb.
    incident: Vec<Vec<usize>>,
    /// Per op: ids of outgoing dataflow edges — the redistribution
    /// genes that travel with the op under crossover (the producer owns
    /// its edges' collection columns).
    out_edges: Vec<Vec<usize>>,
}

impl<'a> Ctx<'a> {
    fn new(plat: &'a Platform, wl: &'a Workload) -> Ctx<'a> {
        let n = wl.ops.len();
        let mut incident = vec![Vec::new(); n];
        let mut out_edges = vec![Vec::new(); n];
        for (e, edge) in wl.edges.iter().enumerate() {
            incident[edge.src].push(e);
            incident[edge.dst].push(e);
            out_edges[edge.src].push(e);
        }
        Ctx { plat, wl, incident, out_edges }
    }
}

fn mutate(ctx: &Ctx, rng: &mut Pcg, a: &mut Allocation, times: usize) {
    for _ in 0..times {
        let i = rng.range_usize(0, ctx.wl.ops.len() - 1);
        let op = &ctx.wl.ops[i];
        match rng.range_usize(0, 2) {
            0 => {
                // Move one tile of rows between two grid rows.
                let b = dim_bounds(op.m, ctx.plat.xdim, ctx.plat.r);
                let px = &mut a.parts[i].px;
                let from = rng.range_usize(0, px.len() - 1);
                let to = rng.range_usize(0, px.len() - 1);
                let step = b.step.min(px[from]);
                if from != to && px[from] - step >= b.lo && px[to] + step <= b.hi
                {
                    px[from] -= step;
                    px[to] += step;
                }
            }
            1 => {
                let b = dim_bounds(op.n, ctx.plat.ydim, ctx.plat.c);
                let py = &mut a.parts[i].py;
                let from = rng.range_usize(0, py.len() - 1);
                let to = rng.range_usize(0, py.len() - 1);
                let step = b.step.min(py[from]);
                if from != to && py[from] - step >= b.lo && py[to] + step <= b.hi
                {
                    py[from] -= step;
                    py[to] += step;
                }
            }
            _ => {
                // Collection-chiplet gene: re-pick the column of one
                // edge in this op's neighborhood (mutation locality —
                // only the edges whose cached decisions the op already
                // dirties). Ops with no incident edges no-op.
                let inc = &ctx.incident[i];
                if !inc.is_empty() {
                    let e = inc[rng.range_usize(0, inc.len() - 1)];
                    a.collect_cols[e] = rng.range_usize(0, ctx.plat.ydim - 1);
                }
            }
        }
    }
}

fn crossover(ctx: &Ctx, rng: &mut Pcg, a: &Allocation, b: &Allocation,
             p: f64) -> Allocation {
    let mut child = a.clone();
    for i in 0..ctx.wl.ops.len() {
        if rng.chance(p) {
            child.parts[i] = b.parts[i].clone();
            // The producer's redistribution genes travel with it.
            for &e in &ctx.out_edges[i] {
                child.collect_cols[e] = b.collect_cols[e];
            }
        }
    }
    child
}

fn random_individual(ctx: &Ctx, rng: &mut Pcg) -> Allocation {
    let mut a = uniform_allocation(ctx.plat, ctx.wl);
    for (i, op) in ctx.wl.ops.iter().enumerate() {
        let bx = dim_bounds(op.m, ctx.plat.xdim, ctx.plat.r);
        let by = dim_bounds(op.n, ctx.plat.ydim, ctx.plat.c);
        for v in a.parts[i].px.iter_mut() {
            let jitter = rng.range_i64(-2, 2) * bx.step as i64;
            *v = (*v as i64 + jitter).max(0) as usize;
        }
        project_to_sum(&mut a.parts[i].px, op.m, bx);
        for v in a.parts[i].py.iter_mut() {
            let jitter = rng.range_i64(-2, 2) * by.step as i64;
            *v = (*v as i64 + jitter).max(0) as usize;
        }
        project_to_sum(&mut a.parts[i].py, op.n, by);
    }
    for c in a.collect_cols.iter_mut() {
        *c = rng.range_usize(0, ctx.plat.ydim - 1);
    }
    a
}

/// Score a batch of genomes across the per-worker caches; results in
/// genome order, bit-identical to sequential full evaluation.
fn eval_batch(
    genomes: &[Allocation],
    caches: &mut [CachedEval<'_>],
    obj: Objective,
) -> Vec<f64> {
    par_map_state(genomes, caches, |cache, _i, g| cache.objective(g, obj))
}

/// Indices of the `k` best individuals, ascending by fitness. NaN-safe
/// (`f64::total_cmp`): a poisoned objective sorts last instead of
/// panicking mid-run.
fn elite_indices(pop: &[(Allocation, f64)], k: usize) -> Vec<usize> {
    let k = k.min(pop.len());
    let mut idx: Vec<usize> = (0..pop.len()).collect();
    if k > 0 && k < idx.len() {
        idx.select_nth_unstable_by(k - 1, |&a, &b| {
            pop[a].1.total_cmp(&pop[b].1)
        });
    }
    idx.truncate(k);
    idx.sort_unstable_by(|&a, &b| pop[a].1.total_cmp(&pop[b].1));
    idx
}

/// Run the GA. Dispatches on [`GaParams::islands`]: `<= 1` is the
/// classic single-population path (bit-identical to the pre-island
/// code), `> 1` the island model.
pub fn optimize(
    plat: &Platform,
    wl: &Workload,
    flags: OptFlags,
    obj: Objective,
    params: &GaParams,
) -> GaResult {
    if params.islands > 1 {
        return optimize_islands(plat, wl, flags, obj, params);
    }
    let ctx = Ctx::new(plat, wl);
    let mut rng = Pcg::seeded(params.seed);
    let t0 = Instant::now();
    let mut profile = GaProfile::default();

    let workers = resolve_threads(params.threads)
        .min(params.population.max(1));
    let mut caches: Vec<CachedEval<'_>> = (0..workers)
        .map(|_| CachedEval::new(plat, wl, flags))
        .collect();

    // Seed the population with the two reference schemes + random jitter
    // (genomes drawn on this thread, then scored as one batch).
    let mut genomes: Vec<Allocation> = Vec::with_capacity(params.population);
    genomes.push(uniform_allocation(plat, wl));
    genomes.push(simba_allocation(plat, wl));
    while genomes.len() < params.population {
        genomes.push(random_individual(&ctx, &mut rng));
    }
    let te = Instant::now();
    let fits = eval_batch(&genomes, &mut caches, obj);
    profile.eval_ns += te.elapsed().as_nanos() as u64;
    let mut pop: Vec<(Allocation, f64)> =
        genomes.into_iter().zip(fits).collect();

    let mut history = Vec::with_capacity(params.generations);
    let mut gens = 0;
    for _gen in 0..params.generations {
        if let Some(b) = params.budget {
            if t0.elapsed() > b {
                break;
            }
        }
        gens += 1;
        let elites = elite_indices(&pop, params.elite);
        let best = pop
            .iter()
            .map(|(_, f)| *f)
            .min_by(f64::total_cmp)
            .expect("non-empty population");
        history.push(best);

        // Breed every child on this thread (fixed RNG order), then score
        // the whole brood in parallel.
        let n_children = params.population.saturating_sub(elites.len());
        let mut children: Vec<Allocation> = Vec::with_capacity(n_children);
        let pick = |rng: &mut Pcg, pop: &[(Allocation, f64)]| {
            let mut best = rng.range_usize(0, pop.len() - 1);
            for _ in 1..params.tournament {
                let c = rng.range_usize(0, pop.len() - 1);
                if pop[c].1 < pop[best].1 {
                    best = c;
                }
            }
            best
        };
        let tb = Instant::now();
        for _ in 0..n_children {
            let pa = pick(&mut rng, pop.as_slice());
            let pb = pick(&mut rng, pop.as_slice());
            let mut child =
                crossover(&ctx, &mut rng, &pop[pa].0, &pop[pb].0,
                          params.p_cross);
            mutate(&ctx, &mut rng, &mut child, params.mutations);
            children.push(child);
        }
        profile.breed_ns += tb.elapsed().as_nanos() as u64;
        let te = Instant::now();
        let fits = eval_batch(&children, &mut caches, obj);
        profile.eval_ns += te.elapsed().as_nanos() as u64;

        // Next generation: elites move over (no clones), children follow.
        let mut next: Vec<(Allocation, f64)> =
            Vec::with_capacity(elites.len() + n_children);
        {
            let mut take = elites;
            take.sort_unstable_by(|a, b| b.cmp(a)); // descending index
            let mut moved: Vec<(Allocation, f64)> =
                take.into_iter().map(|i| pop.swap_remove(i)).collect();
            moved.sort_by(|a, b| a.1.total_cmp(&b.1));
            next.extend(moved);
        }
        next.extend(children.into_iter().zip(fits));
        pop = next;
    }

    let mut best_i = 0;
    for j in 1..pop.len() {
        if pop[j].1.total_cmp(&pop[best_i].1).is_lt() {
            best_i = j;
        }
    }
    let (best, best_f) = pop.swap_remove(best_i);
    GaResult {
        alloc: best,
        objective_value: best_f,
        generations_run: gens,
        history,
        profile,
    }
}

/// One deme of the island model: its own population, its own RNG
/// stream, and its accumulated phase timings.
struct Island {
    pop: Vec<(Allocation, f64)>,
    rng: Pcg,
    /// Best objective per generation evolved so far (local history; the
    /// global history is the elementwise min across islands).
    history: Vec<f64>,
    eval_ns: u64,
    breed_ns: u64,
}

/// Evolve one island for `gens` generations — the plain GA loop with
/// sequential fitness through this worker's cache. All stochastic
/// decisions use the island's own RNG in a fixed order, so the result
/// is a pure function of the island's state, never of which worker ran
/// it or what the cache held.
fn evolve_island(
    ctx: &Ctx,
    params: &GaParams,
    obj: Objective,
    cache: &mut CachedEval<'_>,
    isl: &mut Island,
    gens: usize,
) {
    for _ in 0..gens {
        let elites = elite_indices(&isl.pop, params.elite);
        let best = isl
            .pop
            .iter()
            .map(|(_, f)| *f)
            .min_by(f64::total_cmp)
            .expect("non-empty island");
        isl.history.push(best);

        let n_children = isl.pop.len().saturating_sub(elites.len());
        let tb = Instant::now();
        let mut children: Vec<Allocation> = Vec::with_capacity(n_children);
        for _ in 0..n_children {
            let pick = |rng: &mut Pcg, pop: &[(Allocation, f64)]| {
                let mut best = rng.range_usize(0, pop.len() - 1);
                for _ in 1..params.tournament {
                    let c = rng.range_usize(0, pop.len() - 1);
                    if pop[c].1 < pop[best].1 {
                        best = c;
                    }
                }
                best
            };
            let pa = pick(&mut isl.rng, isl.pop.as_slice());
            let pb = pick(&mut isl.rng, isl.pop.as_slice());
            let mut child = crossover(
                ctx,
                &mut isl.rng,
                &isl.pop[pa].0,
                &isl.pop[pb].0,
                params.p_cross,
            );
            mutate(ctx, &mut isl.rng, &mut child, params.mutations);
            children.push(child);
        }
        isl.breed_ns += tb.elapsed().as_nanos() as u64;

        let te = Instant::now();
        let fits: Vec<f64> = children
            .iter()
            .map(|g| cache.objective(g, obj))
            .collect();
        isl.eval_ns += te.elapsed().as_nanos() as u64;

        let mut next: Vec<(Allocation, f64)> =
            Vec::with_capacity(elites.len() + n_children);
        {
            let mut take = elites;
            take.sort_unstable_by(|a, b| b.cmp(a)); // descending index
            let mut moved: Vec<(Allocation, f64)> =
                take.into_iter().map(|i| isl.pop.swap_remove(i)).collect();
            moved.sort_by(|a, b| a.1.total_cmp(&b.1));
            next.extend(moved);
        }
        next.extend(children.into_iter().zip(fits));
        isl.pop = next;
    }
}

/// The island model (DESIGN.md §Optimizer scale-out): K demes evolve
/// independently in epochs of [`GaParams::migration_interval`]
/// generations — in parallel *across islands*, each pinned to one
/// worker's warm cache — then the top [`GaParams::migrants`] of every
/// island replace the worst of its ring successor, on the calling
/// thread, in island order. Fitness values travel with the migrants
/// (they are exact, so no re-evaluation), and every stochastic decision
/// is drawn from the owning island's seeded stream, so the result is
/// bit-identical at any thread count.
fn optimize_islands(
    plat: &Platform,
    wl: &Workload,
    flags: OptFlags,
    obj: Objective,
    params: &GaParams,
) -> GaResult {
    let ctx = Ctx::new(plat, wl);
    let t0 = Instant::now();
    let k = params.islands;
    let per = (params.population / k).max(params.elite + 1).max(2);
    let migrants = params.migrants.min(per.saturating_sub(1)).max(1);
    let interval = params.migration_interval.max(1);

    let workers = resolve_threads(params.threads).min(k);
    let mut caches: Vec<CachedEval<'_>> = (0..workers)
        .map(|_| CachedEval::new(plat, wl, flags))
        .collect();

    // Seed every island from its own PCG stream (stream = island index,
    // same base seed): island 0 carries the two reference schemes, the
    // rest are fully random — K independent starting points.
    let mut islands: Vec<Island> = (0..k)
        .map(|i| {
            let mut rng = Pcg::new(params.seed, i as u64);
            let mut genomes: Vec<Allocation> = Vec::with_capacity(per);
            if i == 0 {
                genomes.push(uniform_allocation(plat, wl));
                genomes.push(simba_allocation(plat, wl));
                genomes.truncate(per);
            }
            while genomes.len() < per {
                genomes.push(random_individual(&ctx, &mut rng));
            }
            Island {
                pop: genomes.into_iter().map(|g| (g, f64::NAN)).collect(),
                rng,
                history: Vec::with_capacity(params.generations),
                eval_ns: 0,
                breed_ns: 0,
            }
        })
        .collect();

    // Initial fitness, per island on its own worker.
    par_for_each_state(&mut islands, &mut caches, |cache, _i, isl| {
        let te = Instant::now();
        for (g, f) in isl.pop.iter_mut() {
            *f = cache.objective(g, obj);
        }
        isl.eval_ns += te.elapsed().as_nanos() as u64;
    });

    let mut gens = 0usize;
    let mut migration_ns = 0u64;
    while gens < params.generations {
        if let Some(b) = params.budget {
            if t0.elapsed() > b {
                break;
            }
        }
        let epoch = interval.min(params.generations - gens);
        par_for_each_state(&mut islands, &mut caches, |cache, _i, isl| {
            evolve_island(&ctx, params, obj, cache, isl, epoch);
        });
        gens += epoch;

        // Ring migration (skip after the final epoch — nothing would
        // re-evaluate the exchanged genomes).
        if gens < params.generations {
            let tm = Instant::now();
            let outbound: Vec<Vec<(Allocation, f64)>> = islands
                .iter()
                .map(|isl| {
                    elite_indices(&isl.pop, migrants)
                        .into_iter()
                        .map(|i| isl.pop[i].clone())
                        .collect()
                })
                .collect();
            for (i, pack) in outbound.into_iter().enumerate() {
                let dst = &mut islands[(i + 1) % k].pop;
                // Replace the worst `migrants` individuals (descending
                // fitness = ascending quality from the back).
                let mut worst: Vec<usize> = (0..dst.len()).collect();
                worst.sort_unstable_by(|&a, &b| {
                    dst[b].1.total_cmp(&dst[a].1).then(b.cmp(&a))
                });
                for (w, m) in worst.into_iter().zip(pack) {
                    dst[w] = m;
                }
            }
            migration_ns += tm.elapsed().as_nanos() as u64;
        }
    }

    // Global history: elementwise min across the islands' local
    // histories (all the same length — every island ran every epoch).
    let mut history = vec![f64::INFINITY; gens];
    for isl in &islands {
        for (h, &v) in history.iter_mut().zip(&isl.history) {
            if v.total_cmp(h).is_lt() {
                *h = v;
            }
        }
    }

    // Global best: islands in order, genomes in order, strict total_cmp
    // improvement — deterministic on the calling thread.
    let (mut bi, mut bj) = (0usize, 0usize);
    for (i, isl) in islands.iter().enumerate() {
        for (j, (_, f)) in isl.pop.iter().enumerate() {
            if f.total_cmp(&islands[bi].pop[bj].1).is_lt() {
                (bi, bj) = (i, j);
            }
        }
    }
    let profile = GaProfile {
        eval_ns: islands.iter().map(|i| i.eval_ns).sum(),
        breed_ns: islands.iter().map(|i| i.breed_ns).sum(),
        migration_ns,
    };
    let (best, best_f) = islands[bi].pop.swap_remove(bj);
    GaResult {
        alloc: best,
        objective_value: best_f,
        generations_run: gens,
        history,
        profile,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{MemKind, SystemType};
    use crate::cost::evaluator::evaluate;
    use crate::workload::models::alexnet;

    fn setup() -> (Platform, Workload) {
        (Platform::preset(SystemType::A, MemKind::Hbm, 4), alexnet(1))
    }

    fn small_params(seed: u64) -> GaParams {
        GaParams {
            population: 16,
            generations: 12,
            seed,
            ..Default::default()
        }
    }

    #[test]
    fn ga_never_worse_than_uniform() {
        let (plat, wl) = setup();
        let uni = uniform_allocation(&plat, &wl);
        let base = evaluate(&plat, &wl, &uni, OptFlags::ALL)
            .objective(Objective::Latency);
        let r = optimize(&plat, &wl, OptFlags::ALL, Objective::Latency,
                         &small_params(1));
        assert!(r.objective_value <= base * 1.0001);
        assert!(r.alloc.validate(&wl, &plat).is_ok());
    }

    #[test]
    fn ga_monotone_history() {
        let (plat, wl) = setup();
        let r = optimize(&plat, &wl, OptFlags::ALL, Objective::Latency,
                         &small_params(2));
        for w in r.history.windows(2) {
            assert!(w[1] <= w[0] * 1.0001, "elitism must be monotone");
        }
    }

    #[test]
    fn ga_deterministic_per_seed() {
        let (plat, wl) = setup();
        let a = optimize(&plat, &wl, OptFlags::ALL, Objective::Latency,
                         &small_params(7));
        let b = optimize(&plat, &wl, OptFlags::ALL, Objective::Latency,
                         &small_params(7));
        assert_eq!(a.objective_value, b.objective_value);
        assert_eq!(a.alloc, b.alloc);
    }

    #[test]
    fn ga_result_score_matches_full_evaluator() {
        // The reported objective must be the true evaluator's score of
        // the reported allocation, bit-for-bit (delta-scoring and
        // parallelism must not leak into results).
        let (plat, wl) = setup();
        let r = optimize(&plat, &wl, OptFlags::ALL, Objective::Latency,
                         &small_params(5));
        let full = evaluate(&plat, &wl, &r.alloc, OptFlags::ALL)
            .objective(Objective::Latency);
        assert_eq!(r.objective_value.to_bits(), full.to_bits());
    }

    #[test]
    fn elite_selection_tolerates_nan() {
        // A NaN objective must sort last, never panic (satellite:
        // total_cmp population ordering).
        let (plat, wl) = setup();
        let a = uniform_allocation(&plat, &wl);
        let pop = vec![
            (a.clone(), f64::NAN),
            (a.clone(), 2.0),
            (a.clone(), 1.0),
            (a, f64::NAN),
        ];
        let e = elite_indices(&pop, 2);
        assert_eq!(e, vec![2, 1]);
    }

    #[test]
    fn island_ga_bit_identical_across_thread_counts() {
        // The PR-2 guarantee extended to islands: fixed seed, any
        // worker count, same bits — for several island counts.
        let (plat, wl) = setup();
        for islands in [2, 3, 5] {
            let params = |threads: usize| GaParams {
                population: 18,
                generations: 9,
                islands,
                migration_interval: 3,
                seed: 0x15fa,
                threads,
                ..Default::default()
            };
            let seq = optimize(&plat, &wl, OptFlags::ALL,
                               Objective::Latency, &params(1));
            for threads in [2, 4] {
                let par = optimize(&plat, &wl, OptFlags::ALL,
                                   Objective::Latency, &params(threads));
                assert_eq!(
                    seq.objective_value.to_bits(),
                    par.objective_value.to_bits(),
                    "islands={islands} threads={threads}"
                );
                assert_eq!(seq.alloc, par.alloc);
                assert_eq!(seq.history.len(), par.history.len());
                for (a, b) in seq.history.iter().zip(&par.history) {
                    assert_eq!(a.to_bits(), b.to_bits());
                }
            }
        }
    }

    #[test]
    fn island_ga_never_worse_than_uniform_and_scores_exactly() {
        let (plat, wl) = setup();
        let uni = uniform_allocation(&plat, &wl);
        let base = evaluate(&plat, &wl, &uni, OptFlags::ALL)
            .objective(Objective::Latency);
        let r = optimize(
            &plat,
            &wl,
            OptFlags::ALL,
            Objective::Latency,
            &GaParams {
                population: 16,
                generations: 10,
                islands: 4,
                seed: 3,
                ..Default::default()
            },
        );
        // Island 0 seeds uniform, elitism keeps it: never worse.
        assert!(r.objective_value <= base * 1.0001);
        assert!(r.alloc.validate(&wl, &plat).is_ok());
        // The reported score is the true evaluator's, bit-for-bit.
        let full = evaluate(&plat, &wl, &r.alloc, OptFlags::ALL)
            .objective(Objective::Latency);
        assert_eq!(r.objective_value.to_bits(), full.to_bits());
        // Global history is monotone (elitism + min across islands).
        for w in r.history.windows(2) {
            assert!(w[1] <= w[0] * 1.0001);
        }
    }

    #[test]
    fn islands_one_is_the_plain_path() {
        // `islands: 1` must take the classic single-population path
        // bit-for-bit (it is the same code).
        let (plat, wl) = setup();
        let a = optimize(&plat, &wl, OptFlags::ALL, Objective::Latency,
                         &small_params(9));
        let b = optimize(
            &plat,
            &wl,
            OptFlags::ALL,
            Objective::Latency,
            &GaParams { islands: 1, ..small_params(9) },
        );
        assert_eq!(a.objective_value.to_bits(), b.objective_value.to_bits());
        assert_eq!(a.alloc, b.alloc);
    }

    #[test]
    fn budget_caps_generations() {
        let (plat, wl) = setup();
        let r = optimize(
            &plat,
            &wl,
            OptFlags::ALL,
            Objective::Latency,
            &GaParams {
                population: 16,
                generations: 10_000,
                budget: Some(Duration::from_millis(200)),
                ..Default::default()
            },
        );
        assert!(r.generations_run < 10_000);
    }
}
