//! Genetic-algorithm scheduler (paper §6.2).
//!
//! Genome = a full [`Allocation`]: per-op partitions (Px, Py) plus one
//! collection-chiplet column per **dataflow edge** used by on-package
//! redistribution — the two gene sets the paper crosses over and
//! mutates. Partition genes are constrained to the §6.2 trust region
//! (uniform ± 2 systolic tiles, floored at one tile) and always sum to
//! the exact workload dims; redistribution genes are mutated over edge
//! neighborhoods (an op mutation perturbs only the collection columns
//! of edges incident to that op, mirroring the cache's edge-endpoint
//! invalidation). Fitness is the true analytical evaluator (eq. 6),
//! delta-scored through per-worker [`CachedEval`]s and evaluated in
//! parallel.
//!
//! Determinism (DESIGN.md §Performance architecture): every stochastic
//! decision — population seeding, tournament picks, crossover masks,
//! mutations — happens on the calling thread, in a fixed order, before
//! each generation's fitness fan-out. Fitness values are bit-identical
//! to the sequential full evaluator regardless of cache state or
//! thread count, so the same seed yields the same result at any
//! `threads` setting.

use std::time::{Duration, Instant};

use crate::cost::evaluator::{Objective, OptFlags};
use crate::cost::CachedEval;
use crate::partition::{
    dim_bounds, project_to_sum, simba_allocation, uniform_allocation,
    Allocation,
};
use crate::platform::Platform;
use crate::util::par::{par_map_state, resolve_threads};
use crate::util::rng::Pcg;
use crate::workload::Workload;

#[derive(Debug, Clone)]
pub struct GaParams {
    pub population: usize,
    pub generations: usize,
    pub elite: usize,
    pub tournament: usize,
    /// Per-op crossover probability.
    pub p_cross: f64,
    /// Per-genome mutation count (expected).
    pub mutations: usize,
    pub seed: u64,
    /// Optional wall-clock budget (paper: GA ≈ 30 s).
    pub budget: Option<Duration>,
    /// Fitness worker threads; `0` = auto (`MCMCOMM_THREADS` env or the
    /// machine's parallelism), `1` = fully sequential. Results are
    /// bit-identical across all settings.
    pub threads: usize,
}

impl Default for GaParams {
    fn default() -> Self {
        GaParams {
            population: 48,
            generations: 80,
            elite: 2,
            tournament: 3,
            p_cross: 0.5,
            mutations: 4,
            seed: 0xc0ffee,
            budget: None,
            threads: 0,
        }
    }
}

#[derive(Debug, Clone)]
pub struct GaResult {
    pub alloc: Allocation,
    pub objective_value: f64,
    pub generations_run: usize,
    /// Best objective per generation (convergence diagnostics).
    pub history: Vec<f64>,
}

struct Ctx<'a> {
    plat: &'a Platform,
    wl: &'a Workload,
    /// Per op: ids of every incident dataflow edge (in + out) — the
    /// neighborhood a mutation of that op can perturb.
    incident: Vec<Vec<usize>>,
    /// Per op: ids of outgoing dataflow edges — the redistribution
    /// genes that travel with the op under crossover (the producer owns
    /// its edges' collection columns).
    out_edges: Vec<Vec<usize>>,
}

impl<'a> Ctx<'a> {
    fn new(plat: &'a Platform, wl: &'a Workload) -> Ctx<'a> {
        let n = wl.ops.len();
        let mut incident = vec![Vec::new(); n];
        let mut out_edges = vec![Vec::new(); n];
        for (e, edge) in wl.edges.iter().enumerate() {
            incident[edge.src].push(e);
            incident[edge.dst].push(e);
            out_edges[edge.src].push(e);
        }
        Ctx { plat, wl, incident, out_edges }
    }
}

fn mutate(ctx: &Ctx, rng: &mut Pcg, a: &mut Allocation, times: usize) {
    for _ in 0..times {
        let i = rng.range_usize(0, ctx.wl.ops.len() - 1);
        let op = &ctx.wl.ops[i];
        match rng.range_usize(0, 2) {
            0 => {
                // Move one tile of rows between two grid rows.
                let b = dim_bounds(op.m, ctx.plat.xdim, ctx.plat.r);
                let px = &mut a.parts[i].px;
                let from = rng.range_usize(0, px.len() - 1);
                let to = rng.range_usize(0, px.len() - 1);
                let step = b.step.min(px[from]);
                if from != to && px[from] - step >= b.lo && px[to] + step <= b.hi
                {
                    px[from] -= step;
                    px[to] += step;
                }
            }
            1 => {
                let b = dim_bounds(op.n, ctx.plat.ydim, ctx.plat.c);
                let py = &mut a.parts[i].py;
                let from = rng.range_usize(0, py.len() - 1);
                let to = rng.range_usize(0, py.len() - 1);
                let step = b.step.min(py[from]);
                if from != to && py[from] - step >= b.lo && py[to] + step <= b.hi
                {
                    py[from] -= step;
                    py[to] += step;
                }
            }
            _ => {
                // Collection-chiplet gene: re-pick the column of one
                // edge in this op's neighborhood (mutation locality —
                // only the edges whose cached decisions the op already
                // dirties). Ops with no incident edges no-op.
                let inc = &ctx.incident[i];
                if !inc.is_empty() {
                    let e = inc[rng.range_usize(0, inc.len() - 1)];
                    a.collect_cols[e] = rng.range_usize(0, ctx.plat.ydim - 1);
                }
            }
        }
    }
}

fn crossover(ctx: &Ctx, rng: &mut Pcg, a: &Allocation, b: &Allocation,
             p: f64) -> Allocation {
    let mut child = a.clone();
    for i in 0..ctx.wl.ops.len() {
        if rng.chance(p) {
            child.parts[i] = b.parts[i].clone();
            // The producer's redistribution genes travel with it.
            for &e in &ctx.out_edges[i] {
                child.collect_cols[e] = b.collect_cols[e];
            }
        }
    }
    child
}

fn random_individual(ctx: &Ctx, rng: &mut Pcg) -> Allocation {
    let mut a = uniform_allocation(ctx.plat, ctx.wl);
    for (i, op) in ctx.wl.ops.iter().enumerate() {
        let bx = dim_bounds(op.m, ctx.plat.xdim, ctx.plat.r);
        let by = dim_bounds(op.n, ctx.plat.ydim, ctx.plat.c);
        for v in a.parts[i].px.iter_mut() {
            let jitter = rng.range_i64(-2, 2) * bx.step as i64;
            *v = (*v as i64 + jitter).max(0) as usize;
        }
        project_to_sum(&mut a.parts[i].px, op.m, bx);
        for v in a.parts[i].py.iter_mut() {
            let jitter = rng.range_i64(-2, 2) * by.step as i64;
            *v = (*v as i64 + jitter).max(0) as usize;
        }
        project_to_sum(&mut a.parts[i].py, op.n, by);
    }
    for c in a.collect_cols.iter_mut() {
        *c = rng.range_usize(0, ctx.plat.ydim - 1);
    }
    a
}

/// Score a batch of genomes across the per-worker caches; results in
/// genome order, bit-identical to sequential full evaluation.
fn eval_batch(
    genomes: &[Allocation],
    caches: &mut [CachedEval<'_>],
    obj: Objective,
) -> Vec<f64> {
    par_map_state(genomes, caches, |cache, _i, g| cache.objective(g, obj))
}

/// Indices of the `k` best individuals, ascending by fitness. NaN-safe
/// (`f64::total_cmp`): a poisoned objective sorts last instead of
/// panicking mid-run.
fn elite_indices(pop: &[(Allocation, f64)], k: usize) -> Vec<usize> {
    let k = k.min(pop.len());
    let mut idx: Vec<usize> = (0..pop.len()).collect();
    if k > 0 && k < idx.len() {
        idx.select_nth_unstable_by(k - 1, |&a, &b| {
            pop[a].1.total_cmp(&pop[b].1)
        });
    }
    idx.truncate(k);
    idx.sort_unstable_by(|&a, &b| pop[a].1.total_cmp(&pop[b].1));
    idx
}

/// Run the GA.
pub fn optimize(
    plat: &Platform,
    wl: &Workload,
    flags: OptFlags,
    obj: Objective,
    params: &GaParams,
) -> GaResult {
    let ctx = Ctx::new(plat, wl);
    let mut rng = Pcg::seeded(params.seed);
    let t0 = Instant::now();

    let workers = resolve_threads(params.threads)
        .min(params.population.max(1));
    let mut caches: Vec<CachedEval<'_>> = (0..workers)
        .map(|_| CachedEval::new(plat, wl, flags))
        .collect();

    // Seed the population with the two reference schemes + random jitter
    // (genomes drawn on this thread, then scored as one batch).
    let mut genomes: Vec<Allocation> = Vec::with_capacity(params.population);
    genomes.push(uniform_allocation(plat, wl));
    genomes.push(simba_allocation(plat, wl));
    while genomes.len() < params.population {
        genomes.push(random_individual(&ctx, &mut rng));
    }
    let fits = eval_batch(&genomes, &mut caches, obj);
    let mut pop: Vec<(Allocation, f64)> =
        genomes.into_iter().zip(fits).collect();

    let mut history = Vec::with_capacity(params.generations);
    let mut gens = 0;
    for _gen in 0..params.generations {
        if let Some(b) = params.budget {
            if t0.elapsed() > b {
                break;
            }
        }
        gens += 1;
        let elites = elite_indices(&pop, params.elite);
        let best = pop
            .iter()
            .map(|(_, f)| *f)
            .min_by(f64::total_cmp)
            .expect("non-empty population");
        history.push(best);

        // Breed every child on this thread (fixed RNG order), then score
        // the whole brood in parallel.
        let n_children = params.population.saturating_sub(elites.len());
        let mut children: Vec<Allocation> = Vec::with_capacity(n_children);
        let pick = |rng: &mut Pcg, pop: &[(Allocation, f64)]| {
            let mut best = rng.range_usize(0, pop.len() - 1);
            for _ in 1..params.tournament {
                let c = rng.range_usize(0, pop.len() - 1);
                if pop[c].1 < pop[best].1 {
                    best = c;
                }
            }
            best
        };
        for _ in 0..n_children {
            let pa = pick(&mut rng, pop.as_slice());
            let pb = pick(&mut rng, pop.as_slice());
            let mut child =
                crossover(&ctx, &mut rng, &pop[pa].0, &pop[pb].0,
                          params.p_cross);
            mutate(&ctx, &mut rng, &mut child, params.mutations);
            children.push(child);
        }
        let fits = eval_batch(&children, &mut caches, obj);

        // Next generation: elites move over (no clones), children follow.
        let mut next: Vec<(Allocation, f64)> =
            Vec::with_capacity(elites.len() + n_children);
        {
            let mut take = elites;
            take.sort_unstable_by(|a, b| b.cmp(a)); // descending index
            let mut moved: Vec<(Allocation, f64)> =
                take.into_iter().map(|i| pop.swap_remove(i)).collect();
            moved.sort_by(|a, b| a.1.total_cmp(&b.1));
            next.extend(moved);
        }
        next.extend(children.into_iter().zip(fits));
        pop = next;
    }

    let mut best_i = 0;
    for j in 1..pop.len() {
        if pop[j].1.total_cmp(&pop[best_i].1).is_lt() {
            best_i = j;
        }
    }
    let (best, best_f) = pop.swap_remove(best_i);
    GaResult {
        alloc: best,
        objective_value: best_f,
        generations_run: gens,
        history,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{MemKind, SystemType};
    use crate::cost::evaluator::evaluate;
    use crate::workload::models::alexnet;

    fn setup() -> (Platform, Workload) {
        (Platform::preset(SystemType::A, MemKind::Hbm, 4), alexnet(1))
    }

    fn small_params(seed: u64) -> GaParams {
        GaParams {
            population: 16,
            generations: 12,
            seed,
            ..Default::default()
        }
    }

    #[test]
    fn ga_never_worse_than_uniform() {
        let (plat, wl) = setup();
        let uni = uniform_allocation(&plat, &wl);
        let base = evaluate(&plat, &wl, &uni, OptFlags::ALL)
            .objective(Objective::Latency);
        let r = optimize(&plat, &wl, OptFlags::ALL, Objective::Latency,
                         &small_params(1));
        assert!(r.objective_value <= base * 1.0001);
        assert!(r.alloc.validate(&wl, &plat).is_ok());
    }

    #[test]
    fn ga_monotone_history() {
        let (plat, wl) = setup();
        let r = optimize(&plat, &wl, OptFlags::ALL, Objective::Latency,
                         &small_params(2));
        for w in r.history.windows(2) {
            assert!(w[1] <= w[0] * 1.0001, "elitism must be monotone");
        }
    }

    #[test]
    fn ga_deterministic_per_seed() {
        let (plat, wl) = setup();
        let a = optimize(&plat, &wl, OptFlags::ALL, Objective::Latency,
                         &small_params(7));
        let b = optimize(&plat, &wl, OptFlags::ALL, Objective::Latency,
                         &small_params(7));
        assert_eq!(a.objective_value, b.objective_value);
        assert_eq!(a.alloc, b.alloc);
    }

    #[test]
    fn ga_result_score_matches_full_evaluator() {
        // The reported objective must be the true evaluator's score of
        // the reported allocation, bit-for-bit (delta-scoring and
        // parallelism must not leak into results).
        let (plat, wl) = setup();
        let r = optimize(&plat, &wl, OptFlags::ALL, Objective::Latency,
                         &small_params(5));
        let full = evaluate(&plat, &wl, &r.alloc, OptFlags::ALL)
            .objective(Objective::Latency);
        assert_eq!(r.objective_value.to_bits(), full.to_bits());
    }

    #[test]
    fn elite_selection_tolerates_nan() {
        // A NaN objective must sort last, never panic (satellite:
        // total_cmp population ordering).
        let (plat, wl) = setup();
        let a = uniform_allocation(&plat, &wl);
        let pop = vec![
            (a.clone(), f64::NAN),
            (a.clone(), 2.0),
            (a.clone(), 1.0),
            (a, f64::NAN),
        ];
        let e = elite_indices(&pop, 2);
        assert_eq!(e, vec![2, 1]);
    }

    #[test]
    fn budget_caps_generations() {
        let (plat, wl) = setup();
        let r = optimize(
            &plat,
            &wl,
            OptFlags::ALL,
            Objective::Latency,
            &GaParams {
                population: 16,
                generations: 10_000,
                budget: Some(Duration::from_millis(200)),
                ..Default::default()
            },
        );
        assert!(r.generations_run < 10_000);
    }
}
