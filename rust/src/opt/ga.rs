//! Genetic-algorithm scheduler (paper §6.2).
//!
//! Genome = a full [`Allocation`]: per-op partitions (Px, Py) plus the
//! collection-chiplet columns used by on-package redistribution — the
//! two gene sets the paper crosses over and mutates. Partition genes are
//! constrained to the §6.2 trust region (uniform ± 2 systolic tiles,
//! floored at one tile) and always sum to the exact workload dims.
//! Fitness is the true analytical evaluator (eq. 6).

use std::time::{Duration, Instant};

use crate::config::HwConfig;
use crate::cost::evaluator::{evaluate, Objective, OptFlags};
use crate::partition::{
    dim_bounds, project_to_sum, simba_allocation, uniform_allocation,
    Allocation,
};
use crate::topology::Topology;
use crate::util::rng::Pcg;
use crate::workload::Workload;

#[derive(Debug, Clone)]
pub struct GaParams {
    pub population: usize,
    pub generations: usize,
    pub elite: usize,
    pub tournament: usize,
    /// Per-op crossover probability.
    pub p_cross: f64,
    /// Per-genome mutation count (expected).
    pub mutations: usize,
    pub seed: u64,
    /// Optional wall-clock budget (paper: GA ≈ 30 s).
    pub budget: Option<Duration>,
}

impl Default for GaParams {
    fn default() -> Self {
        GaParams {
            population: 48,
            generations: 80,
            elite: 2,
            tournament: 3,
            p_cross: 0.5,
            mutations: 4,
            seed: 0xc0ffee,
            budget: None,
        }
    }
}

#[derive(Debug, Clone)]
pub struct GaResult {
    pub alloc: Allocation,
    pub objective_value: f64,
    pub generations_run: usize,
    /// Best objective per generation (convergence diagnostics).
    pub history: Vec<f64>,
}

struct Ctx<'a> {
    hw: &'a HwConfig,
    topo: &'a Topology,
    wl: &'a Workload,
    flags: OptFlags,
    obj: Objective,
}

impl Ctx<'_> {
    fn fitness(&self, a: &Allocation) -> f64 {
        evaluate(self.hw, self.topo, self.wl, a, self.flags).objective(self.obj)
    }
}

fn mutate(ctx: &Ctx, rng: &mut Pcg, a: &mut Allocation, times: usize) {
    for _ in 0..times {
        let i = rng.range_usize(0, ctx.wl.ops.len() - 1);
        let op = &ctx.wl.ops[i];
        match rng.range_usize(0, 2) {
            0 => {
                // Move one tile of rows between two grid rows.
                let b = dim_bounds(op.m, ctx.hw.xdim, ctx.hw.r);
                let px = &mut a.parts[i].px;
                let from = rng.range_usize(0, px.len() - 1);
                let to = rng.range_usize(0, px.len() - 1);
                let step = b.step.min(px[from]);
                if from != to && px[from] - step >= b.lo && px[to] + step <= b.hi
                {
                    px[from] -= step;
                    px[to] += step;
                }
            }
            1 => {
                let b = dim_bounds(op.n, ctx.hw.ydim, ctx.hw.c);
                let py = &mut a.parts[i].py;
                let from = rng.range_usize(0, py.len() - 1);
                let to = rng.range_usize(0, py.len() - 1);
                let step = b.step.min(py[from]);
                if from != to && py[from] - step >= b.lo && py[to] + step <= b.hi
                {
                    py[from] -= step;
                    py[to] += step;
                }
            }
            _ => {
                // Collection-chiplet gene.
                a.collect_cols[i] = rng.range_usize(0, ctx.hw.ydim - 1);
            }
        }
    }
}

fn crossover(ctx: &Ctx, rng: &mut Pcg, a: &Allocation, b: &Allocation,
             p: f64) -> Allocation {
    let mut child = a.clone();
    for i in 0..ctx.wl.ops.len() {
        if rng.chance(p) {
            child.parts[i] = b.parts[i].clone();
            child.collect_cols[i] = b.collect_cols[i];
        }
    }
    child
}

fn random_individual(ctx: &Ctx, rng: &mut Pcg) -> Allocation {
    let mut a = uniform_allocation(ctx.hw, ctx.wl);
    for (i, op) in ctx.wl.ops.iter().enumerate() {
        let bx = dim_bounds(op.m, ctx.hw.xdim, ctx.hw.r);
        let by = dim_bounds(op.n, ctx.hw.ydim, ctx.hw.c);
        for v in a.parts[i].px.iter_mut() {
            let jitter = rng.range_i64(-2, 2) * bx.step as i64;
            *v = (*v as i64 + jitter).max(0) as usize;
        }
        project_to_sum(&mut a.parts[i].px, op.m, bx);
        for v in a.parts[i].py.iter_mut() {
            let jitter = rng.range_i64(-2, 2) * by.step as i64;
            *v = (*v as i64 + jitter).max(0) as usize;
        }
        project_to_sum(&mut a.parts[i].py, op.n, by);
        a.collect_cols[i] = rng.range_usize(0, ctx.hw.ydim - 1);
    }
    a
}

/// Run the GA.
pub fn optimize(
    hw: &HwConfig,
    topo: &Topology,
    wl: &Workload,
    flags: OptFlags,
    obj: Objective,
    params: &GaParams,
) -> GaResult {
    let ctx = Ctx { hw, topo, wl, flags, obj };
    let mut rng = Pcg::seeded(params.seed);
    let t0 = Instant::now();

    // Seed the population with the two reference schemes + random jitter.
    let mut pop: Vec<(Allocation, f64)> = Vec::with_capacity(params.population);
    let uni = uniform_allocation(hw, wl);
    let fit = ctx.fitness(&uni);
    pop.push((uni, fit));
    let simba = simba_allocation(hw, topo, wl);
    let fit = ctx.fitness(&simba);
    pop.push((simba, fit));
    while pop.len() < params.population {
        let ind = random_individual(&ctx, &mut rng);
        let f = ctx.fitness(&ind);
        pop.push((ind, f));
    }

    let mut history = Vec::with_capacity(params.generations);
    let mut gens = 0;
    for _gen in 0..params.generations {
        if let Some(b) = params.budget {
            if t0.elapsed() > b {
                break;
            }
        }
        gens += 1;
        pop.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
        history.push(pop[0].1);
        let mut next: Vec<(Allocation, f64)> =
            pop.iter().take(params.elite).cloned().collect();
        while next.len() < params.population {
            let pick = |rng: &mut Pcg| {
                let mut best = rng.range_usize(0, pop.len() - 1);
                for _ in 1..params.tournament {
                    let c = rng.range_usize(0, pop.len() - 1);
                    if pop[c].1 < pop[best].1 {
                        best = c;
                    }
                }
                best
            };
            let pa = pick(&mut rng);
            let pb = pick(&mut rng);
            let mut child =
                crossover(&ctx, &mut rng, &pop[pa].0, &pop[pb].0, params.p_cross);
            mutate(&ctx, &mut rng, &mut child, params.mutations);
            let f = ctx.fitness(&child);
            next.push((child, f));
        }
        pop = next;
    }
    pop.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
    let (best, best_f) = pop.swap_remove(0);
    GaResult {
        alloc: best,
        objective_value: best_f,
        generations_run: gens,
        history,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{MemKind, SystemType};
    use crate::workload::models::alexnet;

    fn setup() -> (HwConfig, Topology, Workload) {
        let hw = HwConfig::paper(SystemType::A, MemKind::Hbm, 4);
        let topo = Topology::from_hw(&hw);
        (hw, topo, alexnet(1))
    }

    fn small_params(seed: u64) -> GaParams {
        GaParams {
            population: 16,
            generations: 12,
            seed,
            ..Default::default()
        }
    }

    #[test]
    fn ga_never_worse_than_uniform() {
        let (hw, topo, wl) = setup();
        let uni = uniform_allocation(&hw, &wl);
        let base = evaluate(&hw, &topo, &wl, &uni, OptFlags::ALL)
            .objective(Objective::Latency);
        let r = optimize(&hw, &topo, &wl, OptFlags::ALL, Objective::Latency,
                         &small_params(1));
        assert!(r.objective_value <= base * 1.0001);
        assert!(r.alloc.validate(&wl, &hw).is_ok());
    }

    #[test]
    fn ga_monotone_history() {
        let (hw, topo, wl) = setup();
        let r = optimize(&hw, &topo, &wl, OptFlags::ALL, Objective::Latency,
                         &small_params(2));
        for w in r.history.windows(2) {
            assert!(w[1] <= w[0] * 1.0001, "elitism must be monotone");
        }
    }

    #[test]
    fn ga_deterministic_per_seed() {
        let (hw, topo, wl) = setup();
        let a = optimize(&hw, &topo, &wl, OptFlags::ALL, Objective::Latency,
                         &small_params(7));
        let b = optimize(&hw, &topo, &wl, OptFlags::ALL, Objective::Latency,
                         &small_params(7));
        assert_eq!(a.objective_value, b.objective_value);
        assert_eq!(a.alloc, b.alloc);
    }

    #[test]
    fn budget_caps_generations() {
        let (hw, topo, wl) = setup();
        let r = optimize(
            &hw,
            &topo,
            &wl,
            OptFlags::ALL,
            Objective::Latency,
            &GaParams {
                population: 16,
                generations: 10_000,
                budget: Some(Duration::from_millis(200)),
                ..Default::default()
            },
        );
        assert!(r.generations_run < 10_000);
    }
}
