//! Schedulers (paper §6): the four Table-3 schemes behind one interface.

pub mod ga;
pub mod greedy;
pub mod miqp;

use std::time::Duration;

use crate::config::HwConfig;
use crate::cost::evaluator::{evaluate, Objective, OptFlags};
use crate::partition::{simba_allocation, uniform_allocation, Allocation};
use crate::topology::Topology;
use crate::workload::Workload;

/// Table 3 — the evaluated scheduling schemes.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Scheme {
    /// Layer Sequential, uniform partitioning, no optimizations.
    Baseline,
    /// SIMBA-like inverse-distance partitioning, no optimizations.
    SimbaLike,
    /// Greedy layer-by-layer hill climbing (§3.5 strawman).
    Greedy,
    /// MCMComm-GA (§6.2).
    Ga,
    /// MCMComm-MIQP (§6.3).
    Miqp,
}

impl Scheme {
    pub const ALL: [Scheme; 5] = [
        Scheme::Baseline,
        Scheme::SimbaLike,
        Scheme::Greedy,
        Scheme::Ga,
        Scheme::Miqp,
    ];

    pub fn name(self) -> &'static str {
        match self {
            Scheme::Baseline => "LS (baseline)",
            Scheme::SimbaLike => "SIMBA-like",
            Scheme::Greedy => "greedy",
            Scheme::Ga => "MCMComm-GA",
            Scheme::Miqp => "MCMComm-MIQP",
        }
    }

    /// MCMComm optimizations apply only to the MCMComm schedulers
    /// (Table 3 column "MCMComm Optimizations").
    pub fn flags(self, requested: OptFlags) -> OptFlags {
        match self {
            Scheme::Baseline | Scheme::SimbaLike | Scheme::Greedy => {
                OptFlags::NONE
            }
            Scheme::Ga | Scheme::Miqp => requested,
        }
    }
}

/// Configuration for a scheduling run.
#[derive(Debug, Clone)]
pub struct SchedulerConfig {
    pub objective: Objective,
    pub flags: OptFlags,
    pub seed: u64,
    pub ga: ga::GaParams,
    pub miqp_budget: Duration,
}

impl Default for SchedulerConfig {
    fn default() -> Self {
        SchedulerConfig {
            objective: Objective::Latency,
            flags: OptFlags::ALL,
            seed: 42,
            ga: ga::GaParams::default(),
            miqp_budget: Duration::from_secs(20),
        }
    }
}

/// A scheduling outcome: allocation + true-evaluator score.
#[derive(Debug, Clone)]
pub struct ScheduleOutcome {
    pub scheme: Scheme,
    pub alloc: Allocation,
    pub objective_value: f64,
    pub flags: OptFlags,
}

/// Run one scheme end to end.
pub fn run_scheme(
    scheme: Scheme,
    hw: &HwConfig,
    topo: &Topology,
    wl: &Workload,
    cfg: &SchedulerConfig,
) -> ScheduleOutcome {
    let flags = scheme.flags(cfg.flags);
    let (alloc, objective_value) = match scheme {
        Scheme::Baseline => {
            let a = uniform_allocation(hw, wl);
            let v = evaluate(hw, topo, wl, &a, flags).objective(cfg.objective);
            (a, v)
        }
        Scheme::SimbaLike => {
            let a = simba_allocation(hw, topo, wl);
            let v = evaluate(hw, topo, wl, &a, flags).objective(cfg.objective);
            (a, v)
        }
        Scheme::Greedy => {
            let r = greedy::optimize(hw, topo, wl, flags, cfg.objective);
            (r.alloc, r.objective_value)
        }
        Scheme::Ga => {
            let mut p = cfg.ga.clone();
            p.seed = cfg.seed;
            let r = ga::optimize(hw, topo, wl, flags, cfg.objective, &p);
            (r.alloc, r.objective_value)
        }
        Scheme::Miqp => {
            let r = miqp::optimize(
                hw,
                topo,
                wl,
                flags,
                cfg.objective,
                cfg.miqp_budget,
                cfg.seed,
            );
            (r.alloc, r.objective_value)
        }
    };
    ScheduleOutcome { scheme, alloc, objective_value, flags }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{MemKind, SystemType};
    use crate::workload::models::alexnet;

    #[test]
    fn non_mcmcomm_schemes_run_unoptimized() {
        assert_eq!(Scheme::Baseline.flags(OptFlags::ALL), OptFlags::NONE);
        assert_eq!(Scheme::SimbaLike.flags(OptFlags::ALL), OptFlags::NONE);
        assert_eq!(Scheme::Ga.flags(OptFlags::ALL), OptFlags::ALL);
    }

    #[test]
    fn all_schemes_produce_valid_allocations() {
        let hw = HwConfig::paper(SystemType::A, MemKind::Hbm, 4);
        let topo = Topology::from_hw(&hw);
        let wl = alexnet(1);
        let cfg = SchedulerConfig {
            ga: ga::GaParams {
                population: 12,
                generations: 6,
                ..Default::default()
            },
            miqp_budget: Duration::from_secs(3),
            ..Default::default()
        };
        for s in Scheme::ALL {
            let out = run_scheme(s, &hw, &topo, &wl, &cfg);
            assert!(out.alloc.validate(&wl, &hw).is_ok(), "{}", s.name());
            assert!(out.objective_value > 0.0);
        }
    }
}
