//! Scheduler solver backends (paper §6): the GA, greedy and MIQP
//! optimizers, plus legacy shims for the pre-engine scheme API.
//!
//! The front door is `engine`: the five Table-3 schemes are
//! [`crate::engine::schedulers`] implementing
//! [`crate::engine::Scheduler`], discovered through
//! [`crate::engine::SchedulerRegistry`]. The free functions in
//! [`ga`], [`greedy`] and [`miqp`] remain the low-level solver entry
//! points those implementations call.

pub mod ga;
pub mod greedy;
pub mod miqp;

use std::time::Duration;

use crate::config::HwConfig;
use crate::cost::evaluator::{Objective, OptFlags};
use crate::engine::{schedulers, Scenario, Scheduler};
use crate::partition::Allocation;
use crate::topology::Topology;
use crate::workload::Workload;

/// Table 3 — the evaluated scheduling schemes.
#[deprecated(
    since = "0.2.0",
    note = "iterate `dyn Scheduler`s from `engine::SchedulerRegistry` \
            instead of matching scheme enums"
)]
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Scheme {
    /// Layer Sequential, uniform partitioning, no optimizations.
    Baseline,
    /// SIMBA-like inverse-distance partitioning, no optimizations.
    SimbaLike,
    /// Greedy layer-by-layer hill climbing (§3.5 strawman).
    Greedy,
    /// MCMComm-GA (§6.2).
    Ga,
    /// MCMComm-MIQP (§6.3).
    Miqp,
}

#[allow(deprecated)]
impl Scheme {
    pub const ALL: [Scheme; 5] = [
        Scheme::Baseline,
        Scheme::SimbaLike,
        Scheme::Greedy,
        Scheme::Ga,
        Scheme::Miqp,
    ];

    pub fn name(self) -> &'static str {
        match self {
            Scheme::Baseline => "LS (baseline)",
            Scheme::SimbaLike => "SIMBA-like",
            Scheme::Greedy => "greedy",
            Scheme::Ga => "MCMComm-GA",
            Scheme::Miqp => "MCMComm-MIQP",
        }
    }

    /// Registry key of the equivalent [`crate::engine::Scheduler`].
    pub fn key(self) -> &'static str {
        match self {
            Scheme::Baseline => "baseline",
            Scheme::SimbaLike => "simba",
            Scheme::Greedy => "greedy",
            Scheme::Ga => "ga",
            Scheme::Miqp => "miqp",
        }
    }

    /// MCMComm optimizations apply only to the MCMComm schedulers
    /// (Table 3 column "MCMComm Optimizations").
    pub fn flags(self, requested: OptFlags) -> OptFlags {
        match self {
            Scheme::Baseline | Scheme::SimbaLike | Scheme::Greedy => {
                OptFlags::NONE
            }
            Scheme::Ga | Scheme::Miqp => requested,
        }
    }
}

/// Configuration for a legacy scheduling run.
#[deprecated(
    since = "0.2.0",
    note = "objective/flags live on `engine::Scenario`; solver knobs \
            live on the `engine::schedulers` structs"
)]
#[derive(Debug, Clone)]
pub struct SchedulerConfig {
    pub objective: Objective,
    pub flags: OptFlags,
    pub seed: u64,
    pub ga: ga::GaParams,
    pub miqp_budget: Duration,
}

#[allow(deprecated)]
impl Default for SchedulerConfig {
    fn default() -> Self {
        SchedulerConfig {
            objective: Objective::Latency,
            flags: OptFlags::ALL,
            seed: 42,
            ga: ga::GaParams::default(),
            miqp_budget: Duration::from_secs(20),
        }
    }
}

/// A legacy scheduling outcome: allocation + true-evaluator score.
#[deprecated(since = "0.2.0", note = "use `engine::Plan`")]
#[derive(Debug, Clone)]
#[allow(deprecated)]
pub struct ScheduleOutcome {
    pub scheme: Scheme,
    pub alloc: Allocation,
    pub objective_value: f64,
    pub flags: OptFlags,
}

/// Run one scheme end to end (legacy shim; thin delegation to the
/// engine schedulers, so results are identical by construction).
#[deprecated(
    since = "0.2.0",
    note = "use `Engine::new(scenario).schedule_with(&scheduler)`"
)]
#[allow(deprecated)]
pub fn run_scheme(
    scheme: Scheme,
    hw: &HwConfig,
    topo: &Topology,
    wl: &Workload,
    cfg: &SchedulerConfig,
) -> ScheduleOutcome {
    let scenario = Scenario::builder()
        .hw(hw.clone())
        .topology(topo.clone())
        .workload(wl.clone())
        .flags(cfg.flags)
        .objective(cfg.objective)
        .build()
        .expect("run_scheme: invalid hardware/workload");
    let plan = match scheme {
        Scheme::Baseline => schedulers::Baseline.schedule(&scenario),
        Scheme::SimbaLike => schedulers::SimbaLike.schedule(&scenario),
        Scheme::Greedy => schedulers::Greedy.schedule(&scenario),
        Scheme::Ga => schedulers::Ga::new(cfg.ga.clone(), cfg.seed)
            .schedule(&scenario),
        Scheme::Miqp => schedulers::Miqp::new(cfg.miqp_budget, cfg.seed)
            .schedule(&scenario),
    }
    .expect("run_scheme: scheduling failed");
    ScheduleOutcome {
        scheme,
        alloc: plan.alloc,
        objective_value: plan.objective_value,
        flags: plan.flags,
    }
}

#[cfg(test)]
#[allow(deprecated)]
mod tests {
    use super::*;
    use crate::config::{MemKind, SystemType};
    use crate::workload::models::alexnet;

    #[test]
    fn non_mcmcomm_schemes_run_unoptimized() {
        assert_eq!(Scheme::Baseline.flags(OptFlags::ALL), OptFlags::NONE);
        assert_eq!(Scheme::SimbaLike.flags(OptFlags::ALL), OptFlags::NONE);
        assert_eq!(Scheme::Ga.flags(OptFlags::ALL), OptFlags::ALL);
    }

    #[test]
    fn all_schemes_produce_valid_allocations() {
        let hw = HwConfig::paper(SystemType::A, MemKind::Hbm, 4);
        let topo = Topology::from_hw(&hw);
        let wl = alexnet(1);
        let cfg = SchedulerConfig {
            ga: ga::GaParams {
                population: 12,
                generations: 6,
                ..Default::default()
            },
            miqp_budget: Duration::from_secs(3),
            ..Default::default()
        };
        for s in Scheme::ALL {
            let out = run_scheme(s, &hw, &topo, &wl, &cfg);
            assert!(out.alloc.validate(&wl, &hw).is_ok(), "{}", s.name());
            assert!(out.objective_value > 0.0);
        }
    }

    #[test]
    fn scheme_keys_resolve_in_registry() {
        let registry = crate::engine::SchedulerRegistry::standard(42);
        for s in Scheme::ALL {
            let sched = registry.get(s.key()).expect(s.key());
            assert_eq!(sched.name(), s.name());
        }
    }
}
