//! Scheduler solver backends (paper §6): the GA, greedy, MIQP and
//! task-grained ILP optimizers.
//!
//! The front door is `engine`: the five Table-3 schemes are
//! [`crate::engine::schedulers`] implementing
//! [`crate::engine::Scheduler`], discovered through
//! [`crate::engine::SchedulerRegistry`]. The free functions in
//! [`ga`], [`greedy`] and [`miqp`] remain the low-level solver entry
//! points those implementations call. (The pre-engine `Scheme` /
//! `run_scheme` shims, deprecated since 0.2.0, are gone — iterate
//! `dyn Scheduler`s from the registry instead.)

pub mod ga;
pub mod greedy;
pub mod ilp;
pub mod miqp;

#[cfg(test)]
mod tests {
    use crate::engine::SchedulerRegistry;

    #[test]
    fn registry_serves_all_table3_keys() {
        let registry = SchedulerRegistry::standard(42);
        for key in ["baseline", "simba", "greedy", "ga", "miqp", "ilp"] {
            assert!(registry.get(key).is_some(), "missing scheduler {key}");
        }
    }
}
