//! MIQP formulation of the MCMComm scheduling problem (Algorithm 1):
//! build a [`Model`] whose variables are the per-op workload partitions
//! and whose objective mirrors the analytical evaluator as a sum of
//! max-of-quadratic terms, then decode a solver point back into an
//! [`Allocation`].
//!
//! Faithfulness notes:
//! * compute `ceil(Px/R)·ceil(Py/C)` relaxes to the bilinear
//!   `Px·Py/(R·C)` (the §6.3.1 constant-division transform);
//! * per-op sync `max(comm, comp)` terms are the paper's §6.3.2
//!   synchronization operators;
//! * the EDP objective (latency × energy, degree 4) is linearized around
//!   the uniform point: `EDP ≈ E₀·L + L₀·E` — this is why the paper
//!   observes MIQP-EDP solutions are "not fully optimized" (§7.2); the
//!   final allocation is always re-scored on the true evaluator;
//! * redistribution edges are fixed up front from the uniform allocation
//!   (the paper's "fixed communication strategy", §6.1), with the
//!   collection column at its §5.2 balanced optimum.

use crate::cost::evaluator::{evaluate, Objective, OptFlags};
use crate::partition::{dim_bounds, uniform_allocation, Allocation, Partition};
use crate::platform::Platform;
use crate::topology::Pos;
use crate::workload::Workload;

use super::expr::{MaxTerm, QuadExpr};
use super::model::Model;

/// Mapping between model variables and (op, dim, index).
pub struct VarLayout {
    /// var id of px[i][x] = base_px[i] + x
    base_px: Vec<usize>,
    /// var id of py[i][y] = base_py[i] + y
    base_py: Vec<usize>,
    xdim: usize,
    ydim: usize,
}

impl VarLayout {
    pub fn px(&self, op: usize, x: usize) -> usize {
        debug_assert!(x < self.xdim);
        self.base_px[op] + x
    }

    pub fn py(&self, op: usize, y: usize) -> usize {
        debug_assert!(y < self.ydim);
        self.base_py[op] + y
    }
}

/// The assembled formulation.
pub struct Formulation {
    pub model: Model,
    pub layout: VarLayout,
    /// Redistribution decided per dataflow edge (`wl.edges` order;
    /// fixed strategy).
    pub redist_edge: Vec<bool>,
    /// Collection column per dataflow edge.
    pub collect_cols: Vec<usize>,
}

/// Build the MIQP model for `wl` on `plat` with the §5 optimizations in
/// `flags`, optimizing `obj`.
pub fn build(
    plat: &Platform,
    wl: &Workload,
    flags: OptFlags,
    obj: Objective,
) -> Formulation {
    let n = wl.ops.len();
    let (xd, yd) = (plat.xdim, plat.ydim);
    let mut model = Model::default();
    let mut base_px = Vec::with_capacity(n);
    let mut base_py = Vec::with_capacity(n);

    // ---- variables + partition constraints (§4.2.3, Algorithm 1).
    for op in &wl.ops {
        let bx = dim_bounds(op.m, xd, plat.r);
        let by = dim_bounds(op.n, yd, plat.c);
        let b0 = model.dim();
        for x in 0..xd {
            model.add_var(
                format!("{}::px[{x}]", op.name),
                bx.lo.min(op.m) as f64,
                bx.hi as f64,
                bx.step as f64,
            );
        }
        base_px.push(b0);
        model.add_group((b0..b0 + xd).collect(), op.m as f64);
        let b1 = model.dim();
        for y in 0..yd {
            model.add_var(
                format!("{}::py[{y}]", op.name),
                by.lo.min(op.n) as f64,
                by.hi as f64,
                by.step as f64,
            );
        }
        base_py.push(b1);
        model.add_group((b1..b1 + yd).collect(), op.n as f64);
    }
    let layout = VarLayout { base_px, base_py, xdim: xd, ydim: yd };

    // ---- fixed communication strategy: decide redistribution per
    // dataflow edge and the collection columns from the uniform
    // allocation (§6.1). An op whose activations arrived by
    // redistribution names its (unique) incoming edge.
    let uni = uniform_allocation(plat, wl);
    let uni_cost = evaluate(plat, wl, &uni, flags);
    let ne = wl.edges.len();
    let (mut in_edge, mut out_edge) = (Vec::new(), Vec::new());
    wl.sole_edges_into(&mut in_edge, &mut out_edge);
    let mut redist_edge = vec![false; ne];
    for (i, oc) in uni_cost.per_op.iter().enumerate() {
        if oc.redistributed_in {
            let e = in_edge[i]
                .expect("redistributed op has a unique incoming edge");
            redist_edge[e] = true;
        }
    }
    let mut collect_cols = vec![yd / 2; ne];
    for (e, edge) in wl.edges.iter().enumerate() {
        if redist_edge[e] {
            collect_cols[e] = crate::redistribution::best_collect_col(
                plat,
                &wl.ops[edge.src],
                &uni.parts[edge.src],
                &uni.parts[edge.dst],
            );
        }
    }

    // EDP linearization anchors.
    let (e0, l0) = (uni_cost.energy_pj, uni_cost.latency_ns);
    // Weight of one latency-ns (resp. energy-pJ) unit in the objective.
    let (w_lat, w_en) = match obj {
        // The steady objectives surrogate onto their single-batch
        // proxies here (the MIQP has no pipeline model).
        Objective::Latency | Objective::Throughput => (1.0, 0.0),
        // d(EDP) = E0 * dL + L0 * dE; normalize by E0*L0 so the scale
        // stays comparable to the latency objective.
        Objective::Edp | Objective::EdpPerSample => (1.0, l0 / e0),
    };

    let bw = plat.bw_nop;
    let bpe = plat.bytes_per_elem;

    for (i, op) in wl.ops.iter().enumerate() {
        let in_e = in_edge[i].filter(|&e| redist_edge[e]);
        let acts_from_redist = in_e.is_some();
        let hi_bw = crate::cost::latency::high_bw(plat);
        let tile_cycles =
            (2 * plat.r + plat.c + crate::util::math::ceil_div(op.k, op.groups))
                .saturating_sub(2) as f64
                * op.groups as f64;
        let comp_coeff =
            plat.cycles_to_ns(tile_cycles) / (plat.r as f64 * plat.c as f64);

        // ---- in + comp stage: max over chiplets of (in(x,y) + comp(x,y)).
        let mut off_bytes = op.k as f64 * op.n as f64 * bpe;
        if !acts_from_redist {
            off_bytes += op.m as f64 * op.k as f64 * bpe;
        }
        let offchip_ns = off_bytes / plat.bw_mem;
        let mut cases = Vec::with_capacity(xd * yd);
        for p in plat.positions() {
            let Pos { row: x, col: y } = p;
            let (act_hops, w_hops) = if hi_bw {
                (
                    plat.hops_row_shared(p, flags.diagonal) as f64,
                    plat.hops_col_shared(p, flags.diagonal) as f64,
                )
            } else {
                let h = plat.hops_low_bw(p, flags.diagonal) as f64;
                (h, h)
            };
            let vpx = QuadExpr::var(layout.px(i, x));
            let vpy = QuadExpr::var(layout.py(i, y));
            // on-chip in-time: linear.
            let mut in_e = QuadExpr::constant(offchip_ns);
            if !acts_from_redist {
                in_e = in_e.add(
                    &vpx.clone().scale(op.k as f64 * bpe * act_hops / bw),
                );
            }
            in_e = in_e
                .add(&vpy.clone().scale(op.k as f64 * bpe * w_hops / bw));
            // comp: bilinear.
            let comp_e = vpx.mul(&vpy).scale(comp_coeff);
            let total = if flags.async_fusion {
                in_e.add(&comp_e)
            } else {
                // Conservative surrogate of max(in)+max(comp): the same
                // per-chiplet sum upper-bounds each term; keep the sum
                // (the solver re-scores on the true evaluator anyway).
                in_e.add(&comp_e)
            };
            cases.push(total.scale(w_lat));
        }
        model.add_term(MaxTerm::of(&format!("{}::in+comp", op.name), cases));

        // ---- redistribution stage for the incoming edge.
        if let Some(e) = in_e {
            let prev = wl.edges[e].src;
            let c_star = collect_cols[e];
            let prev_n = wl.ops[prev].n as f64;
            // Step 1: max over rows x of max(left, right) bytes / bw.
            let mut s1 = Vec::new();
            for x in 0..xd {
                let vpx = QuadExpr::var(layout.px(prev, x));
                let mut left = QuadExpr::zero();
                let mut right = QuadExpr::zero();
                for y in 0..yd {
                    let vpy = QuadExpr::var(layout.py(prev, y));
                    let chunk = vpx.mul(&vpy).scale(bpe / bw);
                    if y < c_star {
                        left = left.add(&chunk);
                    } else if y > c_star {
                        right = right.add(&chunk);
                    }
                }
                s1.push(left.scale(w_lat));
                s1.push(right.scale(w_lat));
            }
            model.add_term(MaxTerm::of(
                &format!("{}::redist.s1", op.name),
                s1,
            ));
            // Step 2: max over rows of px * N_prev / bw.
            let s2 = (0..xd)
                .map(|x| {
                    QuadExpr::var(layout.px(prev, x))
                        .scale(prev_n * bpe / bw)
                        .scale(w_lat)
                })
                .collect();
            model
                .add_term(MaxTerm::of(&format!("{}::redist.s2", op.name), s2));
            // Step 3: max over boundaries of |cum(px_prev) - scale *
            // cum(px_i)| * N_prev bytes / bw; abs via a two-case max.
            let scale =
                wl.ops[prev].m as f64 / wl.ops[i].m.max(1) as f64;
            let mut s3 = vec![QuadExpr::zero()];
            let mut cum = QuadExpr::zero();
            for b in 0..xd.saturating_sub(1) {
                cum = cum
                    .add(&QuadExpr::var(layout.px(prev, b)))
                    .sub(&QuadExpr::var(layout.px(i, b)).scale(scale));
                let e = cum.clone().scale(prev_n * bpe / bw);
                s3.push(e.clone().scale(w_lat));
                s3.push(e.scale(-w_lat));
            }
            model
                .add_term(MaxTerm::of(&format!("{}::redist.s3", op.name), s3));
        }

        // ---- output stage (constant in the partition).
        let skip_store = match out_edge[i] {
            Some(e) => redist_edge[e],
            None => false,
        };
        if !skip_store {
            let store =
                crate::cost::latency::offload(plat, op, flags.diagonal)
                    .wall_ns();
            model.add_quad(
                &format!("{}::store", op.name),
                QuadExpr::constant(store).scale(w_lat),
            );
        }

        // ---- energy (only weighted in for EDP).
        if w_en > 0.0 {
            let mut en = QuadExpr::zero();
            for p in plat.positions() {
                let Pos { row: x, col: y } = p;
                let vpx = QuadExpr::var(layout.px(i, x));
                let vpy = QuadExpr::var(layout.py(i, y));
                // SRAM: (px*K + K*py + px*py) bytes * 8 * c_sram.
                let sram = plat.energy.sram_pj_bit * 8.0 * bpe;
                en = en
                    .add(&vpx.clone().scale(op.k as f64 * sram))
                    .add(&vpy.clone().scale(op.k as f64 * sram))
                    .add(&vpx.mul(&vpy).scale(sram));
                // MAC: c_mac * cycles * R * C = c_mac * tile_cycles *
                // px*py/(R*C) * R*C.
                en = en.add(
                    &vpx.mul(&vpy).scale(
                        plat.energy.mac_pj_cycle * tile_cycles
                            / (plat.r as f64 * plat.c as f64),
                    ),
                );
                // NoP distribution energy (linear).
                let hops = plat.hops_energy(p, flags.diagonal) as f64;
                let e_hop = plat.energy.nop_pj_bit_hop * 8.0 * bpe * hops;
                if !acts_from_redist {
                    en = en.add(&vpx.clone().scale(op.k as f64 * e_hop));
                }
                en = en.add(&vpy.clone().scale(op.k as f64 * e_hop));
                // Collection energy for the store.
                if !skip_store {
                    en = en.add(&vpx.mul(&vpy).scale(e_hop));
                }
            }
            // Off-chip energy (constant given the fixed strategy).
            let mut off_b = op.k as f64 * op.n as f64 * bpe;
            if !acts_from_redist {
                off_b += op.m as f64 * op.k as f64 * bpe;
            }
            if !skip_store {
                off_b += op.m as f64 * op.n as f64 * bpe;
            }
            en = en.add(&QuadExpr::constant(
                plat.mem_pj_bit * off_b * 8.0,
            ));
            model.add_quad(
                &format!("{}::energy", op.name),
                en.scale(w_en),
            );
        }
    }

    Formulation { model, layout, redist_edge, collect_cols }
}

/// Decode a solver point into an [`Allocation`] (rounding to integers
/// and restoring exact sums).
pub fn decode(
    f: &Formulation,
    plat: &Platform,
    wl: &Workload,
    point: &[f64],
) -> Allocation {
    let mut parts = Vec::with_capacity(wl.ops.len());
    for (i, op) in wl.ops.iter().enumerate() {
        let mut px: Vec<usize> = (0..plat.xdim)
            .map(|x| point[f.layout.px(i, x)].round().max(0.0) as usize)
            .collect();
        let mut py: Vec<usize> = (0..plat.ydim)
            .map(|y| point[f.layout.py(i, y)].round().max(0.0) as usize)
            .collect();
        fix_sum(&mut px, op.m);
        fix_sum(&mut py, op.n);
        parts.push(Partition { px, py });
    }
    Allocation { parts, collect_cols: f.collect_cols.clone() }
}

/// Adjust `vals` minimally so they sum to `total`.
fn fix_sum(vals: &mut [usize], total: usize) {
    loop {
        let s: usize = vals.iter().sum();
        match s.cmp(&total) {
            std::cmp::Ordering::Equal => return,
            std::cmp::Ordering::Less => {
                let i = (0..vals.len()).min_by_key(|&i| vals[i]).unwrap();
                vals[i] += total - s;
            }
            std::cmp::Ordering::Greater => {
                let i = (0..vals.len()).max_by_key(|&i| vals[i]).unwrap();
                let cut = (s - total).min(vals[i]);
                vals[i] -= cut;
                if cut == 0 {
                    return;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{MemKind, SystemType};
    use crate::workload::models::alexnet;

    fn setup() -> (Platform, Workload) {
        (Platform::preset(SystemType::A, MemKind::Hbm, 4), alexnet(1))
    }

    #[test]
    fn model_dimensions() {
        let (plat, wl) = setup();
        let f = build(&plat, &wl, OptFlags::ALL, Objective::Latency);
        assert_eq!(f.model.dim(), wl.ops.len() * (plat.xdim + plat.ydim));
        assert_eq!(f.model.groups.len(), wl.ops.len() * 2);
        assert!(!f.model.terms.is_empty());
    }

    #[test]
    fn surrogate_tracks_evaluator_on_uniform_point() {
        // The surrogate at the uniform point should be within ~2x of the
        // true latency (it is a structured approximation, not exact).
        let (plat, wl) = setup();
        let f = build(&plat, &wl, OptFlags::ALL, Objective::Latency);
        let uni = uniform_allocation(&plat, &wl);
        let mut point = vec![0.0; f.model.dim()];
        for (i, p) in uni.parts.iter().enumerate() {
            for (x, &v) in p.px.iter().enumerate() {
                point[f.layout.px(i, x)] = v as f64;
            }
            for (y, &v) in p.py.iter().enumerate() {
                point[f.layout.py(i, y)] = v as f64;
            }
        }
        let surrogate = f.model.eval(&point);
        let truth = evaluate(&plat, &wl, &uni, OptFlags::ALL).latency_ns;
        let ratio = surrogate / truth;
        assert!(
            (0.5..2.0).contains(&ratio),
            "surrogate {surrogate} vs truth {truth} (ratio {ratio})"
        );
    }

    #[test]
    fn decode_produces_valid_allocation() {
        let (plat, wl) = setup();
        let f = build(&plat, &wl, OptFlags::ALL, Objective::Latency);
        // A garbage point still decodes to a valid allocation.
        let point: Vec<f64> =
            (0..f.model.dim()).map(|i| (i % 7) as f64 * 50.0).collect();
        let alloc = decode(&f, &plat, &wl, &point);
        assert!(alloc.validate(&wl, &plat).is_ok());
    }

    #[test]
    fn fix_sum_cases() {
        let mut v = vec![5, 5, 5];
        fix_sum(&mut v, 12);
        assert_eq!(v.iter().sum::<usize>(), 12);
        let mut v = vec![1, 1];
        fix_sum(&mut v, 10);
        assert_eq!(v.iter().sum::<usize>(), 10);
        let mut v = vec![0, 0];
        fix_sum(&mut v, 0);
        assert_eq!(v, vec![0, 0]);
    }

    #[test]
    fn edp_objective_adds_energy_terms() {
        let (plat, wl) = setup();
        let lat = build(&plat, &wl, OptFlags::ALL, Objective::Latency);
        let edp = build(&plat, &wl, OptFlags::ALL, Objective::Edp);
        assert!(edp.model.terms.len() > lat.model.terms.len());
    }
}
