//! Mixed-Integer Quadratic Programming scheduler (paper §6.3).
//!
//! `expr` — sparse quadratic forms + the §6.3.1 division transforms;
//! `model` — variables, partition constraints, max-of-quadratic terms;
//! `solve` — from-scratch relaxation + lattice branch & bound solver;
//! `objective` — MCMComm formulation builder + allocation decoding.

pub mod expr;
pub mod model;
pub mod objective;
pub mod solve;

use std::time::Duration;

use crate::config::HwConfig;
use crate::cost::evaluator::{evaluate, Objective, OptFlags};
use crate::partition::Allocation;
use crate::topology::Topology;
use crate::workload::Workload;

/// Result of an MIQP optimization run.
#[derive(Debug, Clone)]
pub struct MiqpResult {
    pub alloc: Allocation,
    /// True-evaluator objective of the returned allocation.
    pub objective_value: f64,
    /// Surrogate value at the solver's incumbent.
    pub surrogate_value: f64,
    pub nodes_explored: usize,
}

/// Optimize workload partitions with the MIQP scheduler.
pub fn optimize(
    hw: &HwConfig,
    topo: &Topology,
    wl: &Workload,
    flags: OptFlags,
    obj: Objective,
    budget: Duration,
    seed: u64,
) -> MiqpResult {
    let f = objective::build(hw, topo, wl, flags, obj);
    let params = solve::SolveParams { budget, seed, ..Default::default() };
    let sol = solve::solve(&f.model, &params);
    let alloc = objective::decode(&f, hw, wl, &sol.point);
    // Always re-score on the single source of truth.
    let cost = evaluate(hw, topo, wl, &alloc, flags);
    // Keep the better of {decoded, uniform} — the solver must never
    // return something worse than the baseline it started from.
    let uni = crate::partition::uniform_allocation(hw, wl);
    let uni_cost = evaluate(hw, topo, wl, &uni, flags);
    if uni_cost.objective(obj) < cost.objective(obj) {
        return MiqpResult {
            alloc: uni,
            objective_value: uni_cost.objective(obj),
            surrogate_value: sol.objective,
            nodes_explored: sol.nodes_explored,
        };
    }
    MiqpResult {
        alloc,
        objective_value: cost.objective(obj),
        surrogate_value: sol.objective,
        nodes_explored: sol.nodes_explored,
    }
}
