//! Mixed-Integer Quadratic Programming scheduler (paper §6.3).
//!
//! `expr` — sparse quadratic forms + the §6.3.1 division transforms;
//! `model` — variables, partition constraints, max-of-quadratic terms;
//! `solve` — from-scratch relaxation + lattice branch & bound solver;
//! `objective` — MCMComm formulation builder + allocation decoding.

pub mod expr;
pub mod model;
pub mod objective;
pub mod solve;

use std::time::Duration;

use crate::cost::evaluator::{evaluate, Objective, OptFlags};
use crate::partition::Allocation;
use crate::platform::Platform;
use crate::workload::Workload;

/// Result of an MIQP optimization run.
#[derive(Debug, Clone)]
pub struct MiqpResult {
    pub alloc: Allocation,
    /// True-evaluator objective of the returned allocation.
    pub objective_value: f64,
    /// Surrogate value at the solver's incumbent.
    pub surrogate_value: f64,
    pub nodes_explored: usize,
}

/// Optimize workload partitions with the MIQP scheduler.
pub fn optimize(
    plat: &Platform,
    wl: &Workload,
    flags: OptFlags,
    obj: Objective,
    budget: Duration,
    seed: u64,
) -> MiqpResult {
    let f = objective::build(plat, wl, flags, obj);
    let params = solve::SolveParams { budget, seed, ..Default::default() };
    let sol = solve::solve(&f.model, &params);
    let alloc = objective::decode(&f, plat, wl, &sol.point);
    // Always re-score on the single source of truth.
    let cost = evaluate(plat, wl, &alloc, flags);
    // Keep the better of {decoded, uniform} — the solver must never
    // return something worse than the baseline it started from.
    let uni = crate::partition::uniform_allocation(plat, wl);
    let uni_cost = evaluate(plat, wl, &uni, flags);
    if uni_cost.objective(obj) < cost.objective(obj) {
        return MiqpResult {
            alloc: uni,
            objective_value: uni_cost.objective(obj),
            surrogate_value: sol.objective,
            nodes_explored: sol.nodes_explored,
        };
    }
    MiqpResult {
        alloc,
        objective_value: cost.objective(obj),
        surrogate_value: sol.objective,
        nodes_explored: sol.nodes_explored,
    }
}
