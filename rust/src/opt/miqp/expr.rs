//! Quadratic expression algebra for the MIQP formulation (paper §6.3).
//!
//! Expressions are sparse quadratic forms `c + Σ aᵢ vᵢ + Σ bᵢⱼ vᵢ vⱼ`
//! over integer decision variables (tile counts). Products beyond degree
//! 2 panic — the formulation must stay quadratic, exactly the constraint
//! the paper's §6.3.1 transforms exist to preserve.

use std::collections::BTreeMap;

pub type VarId = usize;

/// Sparse quadratic expression.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct QuadExpr {
    pub constant: f64,
    /// Linear coefficients.
    pub lin: BTreeMap<VarId, f64>,
    /// Quadratic coefficients, keyed with i <= j.
    pub quad: BTreeMap<(VarId, VarId), f64>,
}

impl QuadExpr {
    pub fn zero() -> Self {
        Self::default()
    }

    pub fn constant(c: f64) -> Self {
        QuadExpr { constant: c, ..Default::default() }
    }

    pub fn var(v: VarId) -> Self {
        let mut lin = BTreeMap::new();
        lin.insert(v, 1.0);
        QuadExpr { constant: 0.0, lin, quad: BTreeMap::new() }
    }

    pub fn is_linear(&self) -> bool {
        self.quad.is_empty()
    }

    pub fn scale(mut self, s: f64) -> Self {
        self.constant *= s;
        for v in self.lin.values_mut() {
            *v *= s;
        }
        for v in self.quad.values_mut() {
            *v *= s;
        }
        self
    }

    pub fn add(mut self, other: &QuadExpr) -> Self {
        self.constant += other.constant;
        for (&k, &c) in &other.lin {
            *self.lin.entry(k).or_insert(0.0) += c;
        }
        for (&k, &c) in &other.quad {
            *self.quad.entry(k).or_insert(0.0) += c;
        }
        self
    }

    pub fn sub(self, other: &QuadExpr) -> Self {
        self.add(&other.clone().scale(-1.0))
    }

    /// Multiply two expressions; panics if the product exceeds degree 2.
    pub fn mul(&self, other: &QuadExpr) -> Self {
        assert!(
            self.is_linear() && other.is_linear()
                || self.quad.is_empty() && other.lin.is_empty()
                    && other.quad.is_empty()
                || other.quad.is_empty() && self.lin.is_empty()
                    && self.quad.is_empty(),
            "product would exceed degree 2 (MIQP requires quadratic forms; \
             apply the §6.3.1 division/approximation transforms first)"
        );
        let mut out = QuadExpr::constant(self.constant * other.constant);
        for (&i, &a) in &self.lin {
            *out.lin.entry(i).or_insert(0.0) += a * other.constant;
        }
        for (&j, &b) in &other.lin {
            *out.lin.entry(j).or_insert(0.0) += b * self.constant;
        }
        for (&i, &a) in &self.lin {
            for (&j, &b) in &other.lin {
                let key = if i <= j { (i, j) } else { (j, i) };
                *out.quad.entry(key).or_insert(0.0) += a * b;
            }
        }
        // constant * existing quad terms
        for (&k, &q) in &self.quad {
            *out.quad.entry(k).or_insert(0.0) += q * other.constant;
        }
        for (&k, &q) in &other.quad {
            *out.quad.entry(k).or_insert(0.0) += q * self.constant;
        }
        out
    }

    /// Evaluate at a point.
    pub fn eval(&self, v: &[f64]) -> f64 {
        let mut s = self.constant;
        for (&i, &a) in &self.lin {
            s += a * v[i];
        }
        for (&(i, j), &b) in &self.quad {
            s += b * v[i] * v[j];
        }
        s
    }

    /// Accumulate the gradient at `v` into `grad`.
    pub fn add_grad(&self, v: &[f64], scale: f64, grad: &mut [f64]) {
        for (&i, &a) in &self.lin {
            grad[i] += scale * a;
        }
        for (&(i, j), &b) in &self.quad {
            if i == j {
                grad[i] += scale * 2.0 * b * v[i];
            } else {
                grad[i] += scale * b * v[j];
                grad[j] += scale * b * v[i];
            }
        }
    }

    // ---- §6.3.1 transforms ---------------------------------------------

    /// Division by a *constant*: the paper multiplies all equations by the
    /// product of constant denominators, then rescales by a global factor
    /// to keep magnitudes inside integer range. Here: exact scale by
    /// `1/c` (we keep f64 coefficients, so the rescale is a no-op
    /// numerically; the transform is retained for fidelity + the scaling
    /// guard below).
    pub fn div_const(self, c: f64) -> Self {
        assert!(c != 0.0, "division by zero constant");
        self.scale(1.0 / c)
    }

    /// Division by a *variable expression* `c + x` (paper §6.3.1):
    ///   e / (c + x)  ≈  e * (c - x) / c²
    /// valid when `x` stays small relative to `c` ("hardware irregularity
    /// can only happen to a small degree").
    pub fn div_approx(&self, c: f64, x: &QuadExpr) -> Self {
        assert!(c != 0.0);
        let corr = QuadExpr::constant(c).sub(x);
        self.mul(&corr).scale(1.0 / (c * c))
    }
}

/// One additive objective term: the max over a set of quadratic
/// expressions (the paper's synchronization `max` operators between
/// computation and its input communication, §6.3.2). A single-element
/// max is a plain quadratic term.
#[derive(Debug, Clone)]
pub struct MaxTerm {
    pub label: String,
    pub cases: Vec<QuadExpr>,
}

impl MaxTerm {
    pub fn single(label: &str, e: QuadExpr) -> Self {
        MaxTerm { label: label.to_string(), cases: vec![e] }
    }

    pub fn of(label: &str, cases: Vec<QuadExpr>) -> Self {
        assert!(!cases.is_empty());
        MaxTerm { label: label.to_string(), cases }
    }

    pub fn eval(&self, v: &[f64]) -> f64 {
        self.cases
            .iter()
            .map(|e| e.eval(v))
            .fold(f64::NEG_INFINITY, f64::max)
    }

    /// Index of the active (max-achieving) case.
    pub fn argmax(&self, v: &[f64]) -> usize {
        let mut best = 0;
        let mut bv = f64::NEG_INFINITY;
        for (i, e) in self.cases.iter().enumerate() {
            let x = e.eval(v);
            if x > bv {
                bv = x;
                best = i;
            }
        }
        best
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn x() -> QuadExpr {
        QuadExpr::var(0)
    }

    fn y() -> QuadExpr {
        QuadExpr::var(1)
    }

    #[test]
    fn arithmetic_and_eval() {
        // (2x + 3)(y) + 1 = 2xy + 3y + 1
        let e = x().scale(2.0).add(&QuadExpr::constant(3.0)).mul(&y())
            .add(&QuadExpr::constant(1.0));
        let v = [2.0, 5.0];
        assert_eq!(e.eval(&v), 2.0 * 2.0 * 5.0 + 3.0 * 5.0 + 1.0);
    }

    #[test]
    fn gradient_matches_finite_difference() {
        let e = x().mul(&y()).add(&x().scale(3.0)).add(&x().mul(&x()));
        let v = [1.5, -2.0];
        let mut g = vec![0.0; 2];
        e.add_grad(&v, 1.0, &mut g);
        let h = 1e-6;
        for i in 0..2 {
            let mut vp = v;
            vp[i] += h;
            let fd = (e.eval(&vp) - e.eval(&v)) / h;
            assert!((g[i] - fd).abs() < 1e-4, "g[{i}]={} fd={fd}", g[i]);
        }
    }

    #[test]
    #[should_panic(expected = "degree 2")]
    fn cubic_products_rejected() {
        let q = x().mul(&y()); // degree 2
        let _ = q.mul(&x()); // degree 3 -> panic
    }

    #[test]
    fn div_approx_accuracy_near_center() {
        // e / (c + x) with e = 10, c = 8: at x=1, exact 10/9 = 1.111,
        // approx 10*(8-1)/64 = 1.094 — within a few percent.
        let e = QuadExpr::constant(10.0);
        let approx = e.div_approx(8.0, &x());
        let v = [1.0];
        let exact = 10.0 / 9.0;
        assert!((approx.eval(&v) - exact).abs() / exact < 0.05);
        // And at x = 0 it is exact.
        assert!((approx.eval(&[0.0]) - 10.0 / 8.0).abs() < 1e-12);
    }

    #[test]
    fn max_term_eval_and_argmax() {
        let m = MaxTerm::of("t", vec![x(), y().scale(2.0)]);
        assert_eq!(m.eval(&[5.0, 1.0]), 5.0);
        assert_eq!(m.argmax(&[5.0, 1.0]), 0);
        assert_eq!(m.eval(&[1.0, 3.0]), 6.0);
        assert_eq!(m.argmax(&[1.0, 3.0]), 1);
    }

    #[test]
    fn div_const_scales() {
        let e = x().scale(6.0).div_const(3.0);
        assert_eq!(e.eval(&[2.0]), 4.0);
    }
}
