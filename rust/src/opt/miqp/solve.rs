//! MIQP solver: multi-start projected subgradient relaxation + lattice
//! branch & bound with pairwise-exchange polish.
//!
//! No commercial solver exists offline, so this is a from-scratch solver
//! tailored to the structure of the MCMComm formulation (DESIGN.md
//! §Substitutions):
//!
//! 1. **Relaxation** — the continuous problem over the box ∩ simplex
//!    feasible set, solved by projected subgradient descent (the
//!    objective is a sum of maxes of bilinear quadratics: non-convex, so
//!    we multi-start from perturbed uniform points).
//! 2. **Integerization** — snap to the tile lattice per sum-group,
//!    preserving the exact group totals.
//! 3. **Branch & bound** — best-first search over per-variable lattice
//!    deviations around the relaxed optimum (the §6.2 ±2-tile trust
//!    region keeps this space small), pruned against the incumbent.
//! 4. **Polish** — pairwise tile exchanges inside each group to a local
//!    minimum.
//!
//! Anytime semantics, like the paper's 10-minute Gurobi limit: `budget`
//! caps wall time and the best incumbent so far is returned.

use std::collections::BinaryHeap;
use std::time::{Duration, Instant};

use crate::util::rng::Pcg;

use super::model::Model;

/// Solver output: the integer point and its surrogate objective value.
#[derive(Debug, Clone)]
pub struct Solution {
    pub point: Vec<f64>,
    pub objective: f64,
    pub relaxation_objective: f64,
    pub nodes_explored: usize,
}

#[derive(Debug, Clone)]
pub struct SolveParams {
    pub budget: Duration,
    pub starts: usize,
    pub pgd_iters: usize,
    pub seed: u64,
    /// Max branch-and-bound nodes (safety valve).
    pub max_nodes: usize,
}

impl Default for SolveParams {
    fn default() -> Self {
        SolveParams {
            budget: Duration::from_secs(30),
            starts: 6,
            pgd_iters: 300,
            seed: 0x5eed,
            max_nodes: 20_000,
        }
    }
}

/// Project `v` in place onto box ∩ {Σ group = total} per group
/// (clip-and-shift bisection on the dual variable λ).
pub fn project(model: &Model, v: &mut [f64]) {
    // Ungrouped vars: plain clamp.
    let mut in_group = vec![false; model.dim()];
    for g in &model.groups {
        for &i in &g.vars {
            in_group[i] = true;
        }
    }
    for (i, d) in model.vars.iter().enumerate() {
        if !in_group[i] {
            v[i] = v[i].clamp(d.lo, d.hi);
        }
    }
    for g in &model.groups {
        let lo_sum: f64 = g.vars.iter().map(|&i| model.vars[i].lo).sum();
        let hi_sum: f64 = g.vars.iter().map(|&i| model.vars[i].hi).sum();
        let total = g.total.clamp(lo_sum, hi_sum);
        // Bisection over λ: Σ clamp(v_i + λ, lo, hi) = total.
        let (mut a, mut b) = (-1e12, 1e12);
        for _ in 0..200 {
            let mid = 0.5 * (a + b);
            let s: f64 = g
                .vars
                .iter()
                .map(|&i| {
                    (v[i] + mid).clamp(model.vars[i].lo, model.vars[i].hi)
                })
                .sum();
            if s < total {
                a = mid;
            } else {
                b = mid;
            }
            if b - a < 1e-9 {
                break;
            }
        }
        let lam = 0.5 * (a + b);
        for &i in &g.vars {
            v[i] = (v[i] + lam).clamp(model.vars[i].lo, model.vars[i].hi);
        }
        // Kill residual rounding drift on an arbitrary interior var.
        let s: f64 = g.vars.iter().map(|&i| v[i]).sum();
        let drift = total - s;
        if drift.abs() > 1e-9 {
            for &i in &g.vars {
                let d = &model.vars[i];
                let newv = (v[i] + drift).clamp(d.lo, d.hi);
                if (newv - v[i]).abs() > 0.0 {
                    v[i] = newv;
                    break;
                }
            }
        }
    }
}

/// Projected subgradient descent from `start`; returns the best visited
/// feasible point.
fn pgd(model: &Model, start: &[f64], iters: usize) -> (Vec<f64>, f64) {
    let mut v = start.to_vec();
    project(model, &mut v);
    let mut best = v.clone();
    let mut best_f = model.eval(&v);
    // Step scale relative to variable ranges.
    let range: f64 = model
        .vars
        .iter()
        .map(|d| d.hi - d.lo)
        .fold(0.0, f64::max)
        .max(1.0);
    for k in 0..iters {
        let g = model.subgrad(&v);
        let gnorm = g.iter().map(|x| x * x).sum::<f64>().sqrt();
        if gnorm < 1e-12 {
            break;
        }
        let step = 0.3 * range / (1.0 + k as f64).sqrt() / gnorm;
        for i in 0..v.len() {
            v[i] -= step * g[i];
        }
        project(model, &mut v);
        let f = model.eval(&v);
        if f < best_f {
            best_f = f;
            best = v.clone();
        }
    }
    (best, best_f)
}

/// Snap a continuous point to the per-variable lattice (`lo + k*step`)
/// while restoring each group's exact total.
pub fn snap_to_lattice(model: &Model, v: &[f64]) -> Vec<f64> {
    let mut out = v.to_vec();
    for (i, d) in model.vars.iter().enumerate() {
        let k = ((v[i] - d.lo) / d.step).round();
        out[i] = (d.lo + k * d.step).clamp(d.lo, d.hi);
    }
    for g in &model.groups {
        loop {
            let s: f64 = g.vars.iter().map(|&i| out[i]).sum();
            let diff = g.total - s;
            if diff.abs() < 1e-9 {
                break;
            }
            // Move one lattice step (or the remainder) in the right
            // direction on the variable with the most room.
            let dir = diff.signum();
            let cand = g
                .vars
                .iter()
                .copied()
                .filter(|&i| {
                    let d = &model.vars[i];
                    if dir > 0.0 {
                        out[i] < d.hi - 1e-9
                    } else {
                        out[i] > d.lo + 1e-9
                    }
                })
                .max_by(|&a, &b| {
                    let room = |i: usize| {
                        let d = &model.vars[i];
                        if dir > 0.0 {
                            d.hi - out[i]
                        } else {
                            out[i] - d.lo
                        }
                    };
                    room(a).partial_cmp(&room(b)).unwrap()
                });
            match cand {
                Some(i) => {
                    let d = &model.vars[i];
                    let step = diff.abs().min(d.step) * dir;
                    out[i] = (out[i] + step).clamp(d.lo, d.hi);
                }
                None => break, // infeasible totals: leave best effort
            }
        }
    }
    out
}

/// Pairwise-exchange local search on the lattice (one tile from var a to
/// var b within the same group) until no improving move exists.
pub fn polish(model: &Model, point: &mut Vec<f64>, deadline: Instant) {
    let mut improved = true;
    while improved && Instant::now() < deadline {
        improved = false;
        let cur = model.eval(point);
        'outer: for g in &model.groups {
            for &a in &g.vars {
                for &b in &g.vars {
                    if a == b {
                        continue;
                    }
                    let step = model.vars[a].step.min(model.vars[b].step);
                    if point[a] - step < model.vars[a].lo - 1e-9
                        || point[b] + step > model.vars[b].hi + 1e-9
                    {
                        continue;
                    }
                    point[a] -= step;
                    point[b] += step;
                    if model.eval(point) + 1e-12 < cur {
                        improved = true;
                        break 'outer;
                    }
                    point[a] += step;
                    point[b] -= step;
                }
            }
        }
    }
}

#[derive(PartialEq)]
struct Node {
    priority: f64, // lower objective first
    point: Vec<f64>,
}

impl Eq for Node {}

impl Ord for Node {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // BinaryHeap is a max-heap: invert.
        other
            .priority
            .partial_cmp(&self.priority)
            .unwrap_or(std::cmp::Ordering::Equal)
    }
}

impl PartialOrd for Node {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

/// Solve the model: relax, integerize, branch & bound, polish.
pub fn solve(model: &Model, params: &SolveParams) -> Solution {
    let t0 = Instant::now();
    let deadline = t0 + params.budget;
    let mut rng = Pcg::seeded(params.seed);

    // ---- 1. multi-start relaxation
    let mid: Vec<f64> = model
        .vars
        .iter()
        .map(|d| 0.5 * (d.lo + d.hi))
        .collect();
    let mut relax_best: Option<(Vec<f64>, f64)> = None;
    for s in 0..params.starts.max(1) {
        let start: Vec<f64> = if s == 0 {
            mid.clone()
        } else {
            mid.iter()
                .enumerate()
                .map(|(i, &m)| {
                    let d = &model.vars[i];
                    m + rng.normal() * 0.25 * (d.hi - d.lo)
                })
                .collect()
        };
        let (p, f) = pgd(model, &start, params.pgd_iters);
        if relax_best.as_ref().is_none_or(|(_, bf)| f < *bf) {
            relax_best = Some((p, f));
        }
        if Instant::now() > deadline {
            break;
        }
    }
    let (relax_pt, relax_f) = relax_best.expect("at least one start");

    // ---- 2. integerize
    let mut incumbent = snap_to_lattice(model, &relax_pt);
    polish(model, &mut incumbent, deadline);
    let mut inc_f = model.eval(&incumbent);

    // ---- 3. best-first lattice search around the incumbent
    let mut heap = BinaryHeap::new();
    heap.push(Node { priority: inc_f, point: incumbent.clone() });
    let mut seen = std::collections::HashSet::new();
    let key = |p: &[f64]| -> Vec<i64> {
        p.iter().map(|&x| (x * 16.0).round() as i64).collect()
    };
    seen.insert(key(&incumbent));
    let mut nodes = 0usize;
    while let Some(Node { priority, point }) = heap.pop() {
        if priority > inc_f * 1.05 {
            break; // prune: frontier is already clearly worse
        }
        nodes += 1;
        if nodes > params.max_nodes || Instant::now() > deadline {
            break;
        }
        // Branch: each single-tile exchange inside each group.
        for g in &model.groups {
            for &a in &g.vars {
                for &b in &g.vars {
                    if a == b {
                        continue;
                    }
                    let step = model.vars[a].step.min(model.vars[b].step);
                    if point[a] - step < model.vars[a].lo - 1e-9
                        || point[b] + step > model.vars[b].hi + 1e-9
                    {
                        continue;
                    }
                    let mut child = point.clone();
                    child[a] -= step;
                    child[b] += step;
                    let k = key(&child);
                    if !seen.insert(k) {
                        continue;
                    }
                    let f = model.eval(&child);
                    if f < inc_f {
                        inc_f = f;
                        incumbent = child.clone();
                    }
                    if f < inc_f * 1.05 {
                        heap.push(Node { priority: f, point: child });
                    }
                }
            }
        }
    }

    // ---- 4. final polish
    polish(model, &mut incumbent, deadline);
    let objective = model.eval(&incumbent);
    debug_assert!(model.infeasibility(&incumbent) < 1e-6);
    Solution {
        point: incumbent,
        objective,
        relaxation_objective: relax_f,
        nodes_explored: nodes,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::opt::miqp::expr::{MaxTerm, QuadExpr};

    /// min (v0-7)^2 + (v1-1)^2 st v0+v1=8, 0<=v<=8, step 1.
    fn quadratic_model() -> Model {
        let mut m = Model::default();
        let a = m.add_var("a".into(), 0.0, 8.0, 1.0);
        let b = m.add_var("b".into(), 0.0, 8.0, 1.0);
        m.add_group(vec![a, b], 8.0);
        let da = QuadExpr::var(a).sub(&QuadExpr::constant(7.0));
        let db = QuadExpr::var(b).sub(&QuadExpr::constant(1.0));
        m.add_quad("qa", da.mul(&da.clone()));
        m.add_quad("qb", db.mul(&db.clone()));
        m
    }

    #[test]
    fn projection_enforces_group_and_box() {
        let m = quadratic_model();
        let mut v = vec![20.0, -5.0];
        project(&m, &mut v);
        assert!((v[0] + v[1] - 8.0).abs() < 1e-6);
        assert!(v.iter().all(|&x| (0.0..=8.0).contains(&x)));
    }

    #[test]
    fn solves_separable_quadratic_exactly() {
        let m = quadratic_model();
        let s = solve(&m, &SolveParams {
            budget: Duration::from_secs(2),
            ..Default::default()
        });
        assert_eq!(s.point, vec![7.0, 1.0]);
        assert!(s.objective < 1e-9);
    }

    #[test]
    fn handles_max_terms() {
        // min max(v0, v1) st v0+v1 = 10 -> optimum 5/5 (value 5).
        let mut m = Model::default();
        let a = m.add_var("a".into(), 0.0, 10.0, 1.0);
        let b = m.add_var("b".into(), 0.0, 10.0, 1.0);
        m.add_group(vec![a, b], 10.0);
        m.add_term(MaxTerm::of(
            "mx",
            vec![QuadExpr::var(a), QuadExpr::var(b)],
        ));
        let s = solve(&m, &SolveParams::default());
        assert!((s.objective - 5.0).abs() < 1e-9, "obj={}", s.objective);
    }

    #[test]
    fn bilinear_nonconvex_finds_good_point() {
        // min v0*v1 st v0+v1=10, 1<=v<=9: optimum at an endpoint (9).
        let mut m = Model::default();
        let a = m.add_var("a".into(), 1.0, 9.0, 1.0);
        let b = m.add_var("b".into(), 1.0, 9.0, 1.0);
        m.add_group(vec![a, b], 10.0);
        m.add_quad("bi", QuadExpr::var(a).mul(&QuadExpr::var(b)));
        let s = solve(&m, &SolveParams::default());
        assert!((s.objective - 9.0).abs() < 1e-9, "obj={}", s.objective);
    }

    #[test]
    fn snap_preserves_totals() {
        let m = quadratic_model();
        let snapped = snap_to_lattice(&m, &[3.4, 4.6]);
        assert!((snapped[0] + snapped[1] - 8.0).abs() < 1e-9);
        for (i, d) in m.vars.iter().enumerate() {
            let k = (snapped[i] - d.lo) / d.step;
            assert!((k - k.round()).abs() < 1e-9);
        }
    }

    #[test]
    fn respects_budget() {
        let m = quadratic_model();
        let t0 = Instant::now();
        let _ = solve(&m, &SolveParams {
            budget: Duration::from_millis(50),
            starts: 100,
            pgd_iters: 100_000,
            ..Default::default()
        });
        assert!(t0.elapsed() < Duration::from_secs(5));
    }
}
