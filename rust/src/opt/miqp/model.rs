//! MIQP model container: integer variables with box bounds, sum-equality
//! groups (workload-partition constraints `Σ Px = M`, `Σ Py = N` of
//! Algorithm 1), and an objective that is a sum of max-of-quadratic
//! terms (the §6.3.2 synchronization operators).

use super::expr::{MaxTerm, QuadExpr, VarId};

#[derive(Debug, Clone)]
pub struct VarDef {
    pub name: String,
    pub lo: f64,
    pub hi: f64,
    /// Integer lattice step (tile size: R for row vars, C for columns).
    pub step: f64,
}

/// `Σ vars = total` (exact workload coverage).
#[derive(Debug, Clone)]
pub struct SumGroup {
    pub vars: Vec<VarId>,
    pub total: f64,
}

#[derive(Debug, Clone, Default)]
pub struct Model {
    pub vars: Vec<VarDef>,
    pub groups: Vec<SumGroup>,
    pub terms: Vec<MaxTerm>,
}

impl Model {
    pub fn add_var(&mut self, name: String, lo: f64, hi: f64, step: f64) -> VarId {
        assert!(lo <= hi && step > 0.0, "bad bounds for {name}");
        self.vars.push(VarDef { name, lo, hi, step });
        self.vars.len() - 1
    }

    pub fn add_group(&mut self, vars: Vec<VarId>, total: f64) {
        let lo: f64 = vars.iter().map(|&v| self.vars[v].lo).sum();
        debug_assert!(
            lo <= total + 1e-9,
            "group infeasible: sum(lo) {lo} > total {total}"
        );
        self.groups.push(SumGroup { vars, total });
    }

    pub fn add_term(&mut self, t: MaxTerm) {
        self.terms.push(t);
    }

    pub fn add_quad(&mut self, label: &str, e: QuadExpr) {
        self.terms.push(MaxTerm::single(label, e));
    }

    pub fn dim(&self) -> usize {
        self.vars.len()
    }

    /// Objective value at a point.
    pub fn eval(&self, v: &[f64]) -> f64 {
        self.terms.iter().map(|t| t.eval(v)).sum()
    }

    /// Subgradient at `v` (gradient of each term's active case).
    pub fn subgrad(&self, v: &[f64]) -> Vec<f64> {
        let mut g = vec![0.0; self.dim()];
        for t in &self.terms {
            let k = t.argmax(v);
            t.cases[k].add_grad(v, 1.0, &mut g);
        }
        g
    }

    /// Max constraint violation of a point (box + group equalities).
    pub fn infeasibility(&self, v: &[f64]) -> f64 {
        let mut worst = 0.0f64;
        for (i, d) in self.vars.iter().enumerate() {
            worst = worst.max(d.lo - v[i]).max(v[i] - d.hi);
        }
        for gp in &self.groups {
            let s: f64 = gp.vars.iter().map(|&i| v[i]).sum();
            worst = worst.max((s - gp.total).abs());
        }
        worst
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eval_sums_terms() {
        let mut m = Model::default();
        let a = m.add_var("a".into(), 0.0, 10.0, 1.0);
        let b = m.add_var("b".into(), 0.0, 10.0, 1.0);
        m.add_quad("lin", QuadExpr::var(a).scale(2.0));
        m.add_term(MaxTerm::of(
            "mx",
            vec![QuadExpr::var(b), QuadExpr::constant(4.0)],
        ));
        assert_eq!(m.eval(&[3.0, 1.0]), 6.0 + 4.0);
        assert_eq!(m.eval(&[3.0, 9.0]), 6.0 + 9.0);
    }

    #[test]
    fn subgrad_uses_active_case() {
        let mut m = Model::default();
        let a = m.add_var("a".into(), 0.0, 10.0, 1.0);
        let b = m.add_var("b".into(), 0.0, 10.0, 1.0);
        m.add_term(MaxTerm::of(
            "mx",
            vec![QuadExpr::var(a).scale(3.0), QuadExpr::var(b).scale(5.0)],
        ));
        let g = m.subgrad(&[10.0, 0.1]); // a-case active
        assert_eq!(g, vec![3.0, 0.0]);
        let g = m.subgrad(&[0.1, 10.0]); // b-case active
        assert_eq!(g, vec![0.0, 5.0]);
    }

    #[test]
    fn infeasibility_measures_worst() {
        let mut m = Model::default();
        let a = m.add_var("a".into(), 0.0, 5.0, 1.0);
        let b = m.add_var("b".into(), 0.0, 5.0, 1.0);
        m.add_group(vec![a, b], 6.0);
        assert_eq!(m.infeasibility(&[3.0, 3.0]), 0.0);
        assert_eq!(m.infeasibility(&[7.0, 3.0]), 4.0); // box + group
        assert_eq!(m.infeasibility(&[2.0, 2.0]), 2.0); // group deficit
    }
}
