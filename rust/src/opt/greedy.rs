//! Greedy layer-by-layer heuristic (the paper's §3.5 strawman: "a simple
//! greedy-based heuristic performs even worse").
//!
//! Faithful to SIMBA-style optimization: each layer is tuned *in
//! isolation* — hill-climb tile moves that minimize that single op's
//! standalone cost (its own load + compute + store), ignoring the
//! cross-layer implications (redistribution layout mismatches, skipped
//! stores) that the end-to-end evaluator scores. That blindness is
//! exactly why it can lose to plain uniform LS end-to-end (§7.1).

use crate::cost::compute::comp_ns;
use crate::cost::evaluator::{evaluate, Objective, OptFlags};
use crate::cost::latency::{load, offload};
use crate::partition::{dim_bounds, uniform_allocation, Allocation};
use crate::platform::Platform;
use crate::workload::{GemmOp, Workload};

/// Standalone (single-layer) cost of one op under a candidate partition.
fn layer_cost(
    plat: &Platform,
    op: &GemmOp,
    part: &crate::partition::Partition,
) -> f64 {
    let in_ns = load(plat, op, part, false, true).wall_ns();
    let comp = (0..plat.xdim)
        .flat_map(|x| (0..plat.ydim).map(move |y| (x, y)))
        .map(|(x, y)| comp_ns(plat, op, part.px[x], part.py[y]))
        .fold(0.0, f64::max);
    let out_ns = offload(plat, op, false).wall_ns();
    in_ns + comp + out_ns
}

#[derive(Debug, Clone)]
pub struct GreedyResult {
    pub alloc: Allocation,
    pub objective_value: f64,
}

/// Layer-by-layer greedy optimization (near-instant, §3.5).
pub fn optimize(
    plat: &Platform,
    wl: &Workload,
    flags: OptFlags,
    obj: Objective,
) -> GreedyResult {
    let mut alloc = uniform_allocation(plat, wl);
    for (i, op) in wl.ops.iter().enumerate() {
        let bx = dim_bounds(op.m, plat.xdim, plat.r);
        let by = dim_bounds(op.n, plat.ydim, plat.c);
        let mut cur = layer_cost(plat, op, &alloc.parts[i]);
        let mut improved = true;
        while improved {
            improved = false;
            // Try every single-tile exchange in px then py.
            for dim in 0..2 {
                let (len, step, lo, hi) = if dim == 0 {
                    (plat.xdim, bx.step, bx.lo, bx.hi)
                } else {
                    (plat.ydim, by.step, by.lo, by.hi)
                };
                for from in 0..len {
                    for to in 0..len {
                        if from == to {
                            continue;
                        }
                        let vals = if dim == 0 {
                            &mut alloc.parts[i].px
                        } else {
                            &mut alloc.parts[i].py
                        };
                        let s = step.min(vals[from]);
                        if s == 0
                            || vals[from] - s < lo
                            || vals[to] + s > hi
                        {
                            continue;
                        }
                        vals[from] -= s;
                        vals[to] += s;
                        let c = layer_cost(plat, op, &alloc.parts[i]);
                        if c + 1e-9 < cur {
                            cur = c;
                            improved = true;
                        } else {
                            let vals = if dim == 0 {
                                &mut alloc.parts[i].px
                            } else {
                                &mut alloc.parts[i].py
                            };
                            vals[from] += s;
                            vals[to] -= s;
                        }
                    }
                }
            }
        }
    }
    let objective_value = evaluate(plat, wl, &alloc, flags).objective(obj);
    GreedyResult { alloc, objective_value }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{MemKind, SystemType};
    use crate::workload::models::alexnet;

    #[test]
    fn greedy_is_valid_and_fast() {
        let plat = Platform::preset(SystemType::A, MemKind::Hbm, 4);
        let wl = alexnet(1);
        let t0 = std::time::Instant::now();
        let r = optimize(&plat, &wl, OptFlags::ALL, Objective::Latency);
        assert!(r.alloc.validate(&wl, &plat).is_ok());
        assert!(r.objective_value > 0.0);
        assert!(t0.elapsed().as_secs() < 10, "greedy must be near-instant");
    }

    #[test]
    fn greedy_improves_layer_cost_vs_uniform() {
        let plat = Platform::preset(SystemType::A, MemKind::Hbm, 4);
        let wl = alexnet(1);
        let uni = uniform_allocation(&plat, &wl);
        let r = optimize(&plat, &wl, OptFlags::NONE, Objective::Latency);
        // Per its objective (standalone layer cost) greedy must not lose.
        for (i, op) in wl.ops.iter().enumerate() {
            let g = layer_cost(&plat, op, &r.alloc.parts[i]);
            let u = layer_cost(&plat, op, &uni.parts[i]);
            assert!(g <= u + 1e-6, "op {i}: greedy {g} > uniform {u}");
        }
    }
}
