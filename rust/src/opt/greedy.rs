//! Greedy layer-by-layer heuristic (the paper's §3.5 strawman: "a simple
//! greedy-based heuristic performs even worse").
//!
//! Faithful to SIMBA-style optimization: each layer is tuned *in
//! isolation* — hill-climb tile moves that minimize that single op's
//! standalone cost (its own load + compute + store), ignoring the
//! cross-layer implications (redistribution layout mismatches, skipped
//! stores) that the end-to-end evaluator scores. That blindness is
//! exactly why it can lose to plain uniform LS end-to-end (§7.1).

use crate::config::HwConfig;
use crate::cost::compute::comp_ns;
use crate::cost::evaluator::{evaluate, Objective, OptFlags};
use crate::cost::latency::{load, offload};
use crate::partition::{dim_bounds, uniform_allocation, Allocation};
use crate::topology::Topology;
use crate::workload::{GemmOp, Workload};

/// Standalone (single-layer) cost of one op under a candidate partition.
fn layer_cost(
    hw: &HwConfig,
    topo: &Topology,
    op: &GemmOp,
    part: &crate::partition::Partition,
) -> f64 {
    let in_ns = load(hw, topo, op, part, false, true).wall_ns();
    let comp = (0..hw.xdim)
        .flat_map(|x| (0..hw.ydim).map(move |y| (x, y)))
        .map(|(x, y)| comp_ns(hw, op, part.px[x], part.py[y]))
        .fold(0.0, f64::max);
    let out_ns = offload(hw, topo, op, false).wall_ns();
    in_ns + comp + out_ns
}

#[derive(Debug, Clone)]
pub struct GreedyResult {
    pub alloc: Allocation,
    pub objective_value: f64,
}

/// Layer-by-layer greedy optimization (near-instant, §3.5).
pub fn optimize(
    hw: &HwConfig,
    topo: &Topology,
    wl: &Workload,
    flags: OptFlags,
    obj: Objective,
) -> GreedyResult {
    let mut alloc = uniform_allocation(hw, wl);
    for (i, op) in wl.ops.iter().enumerate() {
        let bx = dim_bounds(op.m, hw.xdim, hw.r);
        let by = dim_bounds(op.n, hw.ydim, hw.c);
        let mut cur = layer_cost(hw, topo, op, &alloc.parts[i]);
        let mut improved = true;
        while improved {
            improved = false;
            // Try every single-tile exchange in px then py.
            for dim in 0..2 {
                let (len, step, lo, hi) = if dim == 0 {
                    (hw.xdim, bx.step, bx.lo, bx.hi)
                } else {
                    (hw.ydim, by.step, by.lo, by.hi)
                };
                for from in 0..len {
                    for to in 0..len {
                        if from == to {
                            continue;
                        }
                        let vals = if dim == 0 {
                            &mut alloc.parts[i].px
                        } else {
                            &mut alloc.parts[i].py
                        };
                        let s = step.min(vals[from]);
                        if s == 0
                            || vals[from] - s < lo
                            || vals[to] + s > hi
                        {
                            continue;
                        }
                        vals[from] -= s;
                        vals[to] += s;
                        let c = layer_cost(hw, topo, op, &alloc.parts[i]);
                        if c + 1e-9 < cur {
                            cur = c;
                            improved = true;
                        } else {
                            let vals = if dim == 0 {
                                &mut alloc.parts[i].px
                            } else {
                                &mut alloc.parts[i].py
                            };
                            vals[from] += s;
                            vals[to] -= s;
                        }
                    }
                }
            }
        }
    }
    let objective_value = evaluate(hw, topo, wl, &alloc, flags).objective(obj);
    GreedyResult { alloc, objective_value }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{MemKind, SystemType};
    use crate::workload::models::alexnet;

    #[test]
    fn greedy_is_valid_and_fast() {
        let hw = HwConfig::paper(SystemType::A, MemKind::Hbm, 4);
        let topo = Topology::from_hw(&hw);
        let wl = alexnet(1);
        let t0 = std::time::Instant::now();
        let r = optimize(&hw, &topo, &wl, OptFlags::ALL, Objective::Latency);
        assert!(r.alloc.validate(&wl, &hw).is_ok());
        assert!(r.objective_value > 0.0);
        assert!(t0.elapsed().as_secs() < 10, "greedy must be near-instant");
    }

    #[test]
    fn greedy_improves_layer_cost_vs_uniform() {
        let hw = HwConfig::paper(SystemType::A, MemKind::Hbm, 4);
        let topo = Topology::from_hw(&hw);
        let wl = alexnet(1);
        let uni = uniform_allocation(&hw, &wl);
        let r = optimize(&hw, &topo, &wl, OptFlags::NONE, Objective::Latency);
        // Per its objective (standalone layer cost) greedy must not lose.
        for (i, op) in wl.ops.iter().enumerate() {
            let g = layer_cost(&hw, &topo, op, &r.alloc.parts[i]);
            let u = layer_cost(&hw, &topo, op, &uni.parts[i]);
            assert!(g <= u + 1e-6, "op {i}: greedy {g} > uniform {u}");
        }
    }
}
