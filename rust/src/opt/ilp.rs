//! Task-grained ILP scheduler: assign sub-layer tasks (the per-row /
//! per-column tile shares of each op) to chiplets under
//! dependency-timing, per-link-capacity and explicit no-multicast
//! constraints on the [`LinkGraph`], as a **linear** surrogate solved by
//! the same zero-dependency branch-and-bound the MIQP uses — B&B over
//! the LP relaxation instead of the QP relaxation.
//!
//! # Formulation
//!
//! Variables are the MIQP layout exactly (`px[i][x]`, `py[i][y]` on the
//! tile lattice, per-op simplex groups), but every objective term is
//! linear:
//!
//! * **Dependency timing** — ops execute in the stored topological
//!   order (LS schedule), so the objective is the sum over ops of that
//!   op's stage terms; an edge's redistribution terms land on the
//!   consumer, after the producer's terms (the linear analog of the
//!   §6.3.2 synchronization operators).
//! * **Per-link capacity** — the distribution stage is scored as
//!   `max over links l of bytes(l) / capacity(l)` where `bytes(l)` sums
//!   the (linear) demand of every chiplet whose XY route from its
//!   serving attach point crosses `l` — re-derived from the
//!   [`LinkGraph`] routes, not from the evaluator's folded hop tables.
//! * **No multicast** — every byte is charged along its full single
//!   route in the link terms; nothing is shared between destinations
//!   (the same unicast discipline the certifier checks).
//! * Bilinear terms (compute `px·py`, step-1 chunks, writeback) are
//!   linearized around the uniform point: `px·ȳ + x̄·py − x̄·ȳ`.
//!   Step-2 and step-3 redistribution are exactly linear already.
//!
//! # Beats-or-ties guarantee
//!
//! The surrogate is a bound-guidance device, not the score: the final
//! allocation is the **best of {ILP decode, MIQP decode, uniform}**
//! under the true evaluator, each optionally polished by a
//! deterministic single-tile descent. Since the MIQP's own result is in
//! the candidate set, `ilp` never returns a worse true objective than
//! `miqp` on the same scenario — the agreement suite pins this on every
//! 2×2–3×3 grid.
//!
//! Determinism: the internal solver seeds are fixed constants (the
//! caller's seed is provenance only), the search is single-threaded,
//! and the polish uses fixed scan orders with no wall-clock checks, so
//! equal scenarios decode to bit-identical allocations across seeds and
//! thread counts once the solver exhausts its node budget (small
//! grids).

use std::time::Duration;

use crate::cost::evaluator::{evaluate, Objective, OptFlags};
use crate::partition::{dim_bounds, uniform_allocation, Allocation, Partition};
use crate::platform::Platform;
use crate::topology::Pos;
use crate::workload::Workload;

use super::miqp;
use super::miqp::expr::{MaxTerm, QuadExpr};
use super::miqp::model::Model;

/// Result of an ILP optimization run.
#[derive(Debug, Clone)]
pub struct IlpResult {
    pub alloc: Allocation,
    /// True-evaluator objective of the returned allocation.
    pub objective_value: f64,
    /// Linear-surrogate value at the solver's incumbent.
    pub surrogate_value: f64,
    pub nodes_explored: usize,
}

/// Fixed internal solver seed: the ILP ignores the caller's seed so
/// equal scenarios solve identically regardless of engine seeding.
const ILP_SOLVE_SEED: u64 = 0x11f;

/// Polish only below this variable count — the descent re-scores every
/// candidate move on the true evaluator, which is the right trade on
/// the small grids the ILP targets but not on transformer-scale sweeps.
const POLISH_VAR_LIMIT: usize = 256;

/// Optimize workload partitions with the task-grained ILP scheduler.
/// `seed` is recorded as provenance but does not influence the search
/// (see the module docs on determinism).
pub fn optimize(
    plat: &Platform,
    wl: &Workload,
    flags: OptFlags,
    obj: Objective,
    budget: Duration,
    seed: u64,
) -> IlpResult {
    let _ = seed;
    let (model, layout, collect_cols) = build_linear(plat, wl, flags, obj);
    let params = miqp::solve::SolveParams {
        budget,
        seed: ILP_SOLVE_SEED,
        ..Default::default()
    };
    let sol = miqp::solve::solve(&model, &params);
    let ilp_alloc = decode(&layout, &collect_cols, plat, wl, &sol.point);

    // Candidate set: {ILP decode, MIQP decode, uniform}. Including the
    // MIQP's own answer is what makes beats-or-ties unconditional.
    let mq = miqp::optimize(plat, wl, flags, obj, budget, ILP_SOLVE_SEED);
    let uni = uniform_allocation(plat, wl);
    let mut best: Option<(Allocation, f64)> = None;
    for cand in [ilp_alloc, mq.alloc, uni] {
        let polished = polish(plat, wl, flags, obj, cand);
        let score = evaluate(plat, wl, &polished, flags).objective(obj);
        let better = match &best {
            None => true,
            Some((_, b)) => score < *b,
        };
        if better {
            best = Some((polished, score));
        }
    }
    let (alloc, objective_value) = best.expect("nonempty candidate set");
    IlpResult {
        alloc,
        objective_value,
        surrogate_value: sol.objective,
        nodes_explored: sol.nodes_explored,
    }
}

/// Variable layout (same shape as the MIQP's, owned here so the linear
/// model is self-contained).
struct Layout {
    base_px: Vec<usize>,
    base_py: Vec<usize>,
    xdim: usize,
    ydim: usize,
}

impl Layout {
    fn px(&self, op: usize, x: usize) -> usize {
        debug_assert!(x < self.xdim);
        self.base_px[op] + x
    }

    fn py(&self, op: usize, y: usize) -> usize {
        debug_assert!(y < self.ydim);
        self.base_py[op] + y
    }
}

/// `px·py` linearized around the uniform anchor `(x̄, ȳ)`:
/// `px·ȳ + x̄·py − x̄·ȳ` (exact at the anchor, first-order elsewhere).
fn linearized_product(
    vpx: &QuadExpr,
    vpy: &QuadExpr,
    xbar: f64,
    ybar: f64,
) -> QuadExpr {
    vpx.clone()
        .scale(ybar)
        .add(&vpy.clone().scale(xbar))
        .add(&QuadExpr::constant(-xbar * ybar))
}

/// Build the linear surrogate model + layout + fixed collection columns.
fn build_linear(
    plat: &Platform,
    wl: &Workload,
    flags: OptFlags,
    obj: Objective,
) -> (Model, Layout, Vec<usize>) {
    let n = wl.ops.len();
    let (xd, yd) = (plat.xdim, plat.ydim);
    let mut model = Model::default();
    let mut base_px = Vec::with_capacity(n);
    let mut base_py = Vec::with_capacity(n);
    for op in &wl.ops {
        let bx = dim_bounds(op.m, xd, plat.r);
        let by = dim_bounds(op.n, yd, plat.c);
        let b0 = model.dim();
        for x in 0..xd {
            model.add_var(
                format!("{}::px[{x}]", op.name),
                bx.lo.min(op.m) as f64,
                bx.hi as f64,
                bx.step as f64,
            );
        }
        base_px.push(b0);
        model.add_group((b0..b0 + xd).collect(), op.m as f64);
        let b1 = model.dim();
        for y in 0..yd {
            model.add_var(
                format!("{}::py[{y}]", op.name),
                by.lo.min(op.n) as f64,
                by.hi as f64,
                by.step as f64,
            );
        }
        base_py.push(b1);
        model.add_group((b1..b1 + yd).collect(), op.n as f64);
    }
    let layout = Layout { base_px, base_py, xdim: xd, ydim: yd };

    // Fixed communication strategy from the uniform point (§6.1), same
    // derivation as the MIQP's.
    let uni = uniform_allocation(plat, wl);
    let uni_cost = evaluate(plat, wl, &uni, flags);
    let ne = wl.edges.len();
    let (mut in_edge, mut out_edge) = (Vec::new(), Vec::new());
    wl.sole_edges_into(&mut in_edge, &mut out_edge);
    let mut redist_edge = vec![false; ne];
    for (i, oc) in uni_cost.per_op.iter().enumerate() {
        if oc.redistributed_in {
            let e = in_edge[i]
                .expect("redistributed op has a unique incoming edge");
            redist_edge[e] = true;
        }
    }
    let mut collect_cols = vec![yd / 2; ne];
    for (e, edge) in wl.edges.iter().enumerate() {
        if redist_edge[e] {
            collect_cols[e] = crate::redistribution::best_collect_col(
                plat,
                &wl.ops[edge.src],
                &uni.parts[edge.src],
                &uni.parts[edge.dst],
            );
        }
    }

    let (e0, l0) = (uni_cost.energy_pj, uni_cost.latency_ns);
    let (w_lat, w_en) = match obj {
        Objective::Latency | Objective::Throughput => (1.0, 0.0),
        Objective::Edp | Objective::EdpPerSample => (1.0, l0 / e0),
    };

    let bw = plat.bw_nop;
    let bpe = plat.bytes_per_elem;
    let graph = plat.link_graph_shared(flags.diagonal);
    let n_links = graph.links.len();

    for (i, op) in wl.ops.iter().enumerate() {
        let acts_from_redist =
            in_edge[i].is_some_and(|e| redist_edge[e]);
        let xbar = op.m as f64 / xd as f64;
        let ybar = op.n as f64 / yd as f64;
        let tile_cycles = (2 * plat.r
            + plat.c
            + crate::util::math::ceil_div(op.k, op.groups))
        .saturating_sub(2) as f64
            * op.groups as f64;
        let comp_coeff =
            plat.cycles_to_ns(tile_cycles) / (plat.r as f64 * plat.c as f64);

        // ---- off-chip pull: constant under the fixed strategy.
        let mut off_bytes = op.k as f64 * op.n as f64 * bpe;
        if !acts_from_redist {
            off_bytes += op.m as f64 * op.k as f64 * bpe;
        }
        model.add_quad(
            &format!("{}::offchip", op.name),
            QuadExpr::constant(off_bytes / plat.bw_mem).scale(w_lat),
        );

        // ---- per-link capacity stage (dependency-timed: one stage per
        // op, summed): for every link, the linear distribution demand
        // of all chiplets whose route crosses it, over that link's own
        // capacity. Unicast: the full demand is charged on every link
        // of the route, never shared.
        let mut per_link: Vec<QuadExpr> =
            (0..n_links).map(|_| QuadExpr::zero()).collect();
        let mut loaded = vec![false; n_links];
        for p in plat.positions() {
            let src = graph.chiplet_id(plat.nearest_global(p));
            let dst = graph.chiplet_id(p);
            let Ok(route) = graph.route(src, dst) else { continue };
            if route.is_empty() {
                continue;
            }
            // demand(p) = K·py[col]·bpe (+ K·px[row]·bpe when the
            // activations load on-package).
            let mut d = QuadExpr::var(layout.py(i, p.col))
                .scale(op.k as f64 * bpe);
            if !acts_from_redist {
                d = d.add(
                    &QuadExpr::var(layout.px(i, p.row))
                        .scale(op.k as f64 * bpe),
                );
            }
            for l in route {
                per_link[l] = std::mem::take(&mut per_link[l]).add(&d);
                loaded[l] = true;
            }
        }
        let cases: Vec<QuadExpr> = per_link
            .into_iter()
            .enumerate()
            .filter(|(l, _)| loaded[*l] && graph.links[*l].capacity > 0.0)
            .map(|(l, e)| e.scale(w_lat / graph.links[l].capacity))
            .collect();
        if !cases.is_empty() {
            model.add_term(MaxTerm::of(
                &format!("{}::link-cap", op.name),
                cases,
            ));
        }

        // ---- compute stage: max over chiplets of the linearized
        // bilinear tile volume.
        let mut comp_cases = Vec::with_capacity(xd * yd);
        for p in plat.positions() {
            let Pos { row: x, col: y } = p;
            let vpx = QuadExpr::var(layout.px(i, x));
            let vpy = QuadExpr::var(layout.py(i, y));
            comp_cases.push(
                linearized_product(&vpx, &vpy, xbar, ybar)
                    .scale(comp_coeff * w_lat),
            );
        }
        model.add_term(MaxTerm::of(&format!("{}::comp", op.name), comp_cases));

        // ---- redistribution stage for the incoming edge (linear:
        // step 1 linearized, steps 2 and 3 exact).
        if let Some(e) = in_edge[i].filter(|&e| redist_edge[e]) {
            let prev = wl.edges[e].src;
            let c_star = collect_cols[e];
            let prev_op = &wl.ops[prev];
            let pxbar = prev_op.m as f64 / xd as f64;
            let pybar = prev_op.n as f64 / yd as f64;
            let mut s1 = Vec::new();
            for x in 0..xd {
                let vpx = QuadExpr::var(layout.px(prev, x));
                let mut left = QuadExpr::zero();
                let mut right = QuadExpr::zero();
                for y in 0..yd {
                    let vpy = QuadExpr::var(layout.py(prev, y));
                    let chunk =
                        linearized_product(&vpx, &vpy, pxbar, pybar)
                            .scale(bpe / bw);
                    if y < c_star {
                        left = left.add(&chunk);
                    } else if y > c_star {
                        right = right.add(&chunk);
                    }
                }
                s1.push(left.scale(w_lat));
                s1.push(right.scale(w_lat));
            }
            model.add_term(MaxTerm::of(&format!("{}::redist.s1", op.name), s1));
            let s2 = (0..xd)
                .map(|x| {
                    QuadExpr::var(layout.px(prev, x))
                        .scale(prev_op.n as f64 * bpe / bw)
                        .scale(w_lat)
                })
                .collect();
            model.add_term(MaxTerm::of(&format!("{}::redist.s2", op.name), s2));
            let scale = prev_op.m as f64 / wl.ops[i].m.max(1) as f64;
            let mut s3 = vec![QuadExpr::zero()];
            let mut cum = QuadExpr::zero();
            for b in 0..xd.saturating_sub(1) {
                cum = cum
                    .add(&QuadExpr::var(layout.px(prev, b)))
                    .sub(&QuadExpr::var(layout.px(i, b)).scale(scale));
                let ex = cum.clone().scale(prev_op.n as f64 * bpe / bw);
                s3.push(ex.clone().scale(w_lat));
                s3.push(ex.scale(-w_lat));
            }
            model.add_term(MaxTerm::of(&format!("{}::redist.s3", op.name), s3));
        }

        // ---- store (constant), skipped when the outgoing edge
        // redistributes.
        let skip_store =
            out_edge[i].is_some_and(|e| redist_edge[e]);
        if !skip_store {
            let store = crate::cost::latency::offload(plat, op, flags.diagonal)
                .wall_ns();
            model.add_quad(
                &format!("{}::store", op.name),
                QuadExpr::constant(store).scale(w_lat),
            );
        }

        // ---- energy (EDP objectives only): linearized around uniform.
        if w_en > 0.0 {
            let mut en = QuadExpr::zero();
            for p in plat.positions() {
                let Pos { row: x, col: y } = p;
                let vpx = QuadExpr::var(layout.px(i, x));
                let vpy = QuadExpr::var(layout.py(i, y));
                let lin = linearized_product(&vpx, &vpy, xbar, ybar);
                let sram = plat.energy.sram_pj_bit * 8.0 * bpe;
                en = en
                    .add(&vpx.clone().scale(op.k as f64 * sram))
                    .add(&vpy.clone().scale(op.k as f64 * sram))
                    .add(&lin.clone().scale(sram));
                en = en.add(&lin.clone().scale(
                    plat.energy.mac_pj_cycle * tile_cycles
                        / (plat.r as f64 * plat.c as f64),
                ));
                let hops = plat.hops_energy(p, flags.diagonal) as f64;
                let e_hop = plat.energy.nop_pj_bit_hop * 8.0 * bpe * hops;
                if !acts_from_redist {
                    en = en.add(&vpx.clone().scale(op.k as f64 * e_hop));
                }
                en = en.add(&vpy.clone().scale(op.k as f64 * e_hop));
                if !skip_store {
                    en = en.add(&lin.scale(e_hop));
                }
            }
            let mut off_b = op.k as f64 * op.n as f64 * bpe;
            if !acts_from_redist {
                off_b += op.m as f64 * op.k as f64 * bpe;
            }
            if !skip_store {
                off_b += op.m as f64 * op.n as f64 * bpe;
            }
            en = en.add(&QuadExpr::constant(plat.mem_pj_bit * off_b * 8.0));
            model.add_quad(&format!("{}::energy", op.name), en.scale(w_en));
        }
    }

    (model, layout, collect_cols)
}

/// Decode a solver point into an [`Allocation`] (round to the lattice,
/// restore exact sums).
fn decode(
    layout: &Layout,
    collect_cols: &[usize],
    plat: &Platform,
    wl: &Workload,
    point: &[f64],
) -> Allocation {
    let mut parts = Vec::with_capacity(wl.ops.len());
    for (i, op) in wl.ops.iter().enumerate() {
        let mut px: Vec<usize> = (0..plat.xdim)
            .map(|x| point[layout.px(i, x)].round().max(0.0) as usize)
            .collect();
        let mut py: Vec<usize> = (0..plat.ydim)
            .map(|y| point[layout.py(i, y)].round().max(0.0) as usize)
            .collect();
        fix_sum(&mut px, op.m);
        fix_sum(&mut py, op.n);
        parts.push(Partition { px, py });
    }
    Allocation { parts, collect_cols: collect_cols.to_vec() }
}

/// Adjust `vals` minimally so they sum to `total` (same policy as the
/// MIQP decoder).
fn fix_sum(vals: &mut [usize], total: usize) {
    loop {
        let s: usize = vals.iter().sum();
        match s.cmp(&total) {
            std::cmp::Ordering::Equal => return,
            std::cmp::Ordering::Less => {
                let i = (0..vals.len()).min_by_key(|&i| vals[i]).unwrap();
                vals[i] += total - s;
            }
            std::cmp::Ordering::Greater => {
                let i = (0..vals.len()).max_by_key(|&i| vals[i]).unwrap();
                let cut = (s - total).min(vals[i]);
                vals[i] -= cut;
                if cut == 0 {
                    return;
                }
            }
        }
    }
}

/// Deterministic true-evaluator descent: move one lattice step of mass
/// between two entries of one dim vector (first improvement, fixed scan
/// order), then sweep each collection column; bounded rounds, no
/// wall-clock checks. Downhill-only, so polishing can never lose the
/// beats-or-ties property. Skipped above [`POLISH_VAR_LIMIT`] variables.
fn polish(
    plat: &Platform,
    wl: &Workload,
    flags: OptFlags,
    obj: Objective,
    mut alloc: Allocation,
) -> Allocation {
    let (xd, yd) = (plat.xdim, plat.ydim);
    if wl.ops.len() * (xd + yd) > POLISH_VAR_LIMIT {
        return alloc;
    }
    let mut best = evaluate(plat, wl, &alloc, flags).objective(obj);
    for _round in 0..3 {
        let mut improved = false;
        for i in 0..wl.ops.len() {
            for dim in 0..2 {
                let (len, total, tile) = if dim == 0 {
                    (xd, wl.ops[i].m, plat.r)
                } else {
                    (yd, wl.ops[i].n, plat.c)
                };
                let bounds = dim_bounds(total, len, tile);
                let step = bounds.step.max(1);
                for a in 0..len {
                    for b in 0..len {
                        if a == b {
                            continue;
                        }
                        {
                            let v = if dim == 0 {
                                &mut alloc.parts[i].px
                            } else {
                                &mut alloc.parts[i].py
                            };
                            if v[a] < step || v[b] + step > bounds.hi {
                                continue;
                            }
                            v[a] -= step;
                            v[b] += step;
                        }
                        let score =
                            evaluate(plat, wl, &alloc, flags).objective(obj);
                        if score < best {
                            best = score;
                            improved = true;
                        } else {
                            let v = if dim == 0 {
                                &mut alloc.parts[i].px
                            } else {
                                &mut alloc.parts[i].py
                            };
                            v[a] += step;
                            v[b] -= step;
                        }
                    }
                }
            }
        }
        // Collection-column sweep.
        let n_cols = alloc.collect_cols.len();
        for e in 0..n_cols {
            let orig = alloc.collect_cols[e];
            let mut best_c = orig;
            for c in 0..yd {
                if c == orig {
                    continue;
                }
                alloc.collect_cols[e] = c;
                let score = evaluate(plat, wl, &alloc, flags).objective(obj);
                if score < best {
                    best = score;
                    best_c = c;
                    improved = true;
                }
            }
            alloc.collect_cols[e] = best_c;
        }
        if !improved {
            break;
        }
    }
    alloc
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::models::alexnet;
    use crate::workload::{GemmOp, Workload};

    fn small() -> (Platform, Workload) {
        use crate::config::{MemKind, SystemType};
        let plat = Platform::preset(SystemType::A, MemKind::Hbm, 2);
        let wl = Workload::new(
            "tiny",
            vec![
                GemmOp::dense("a", 64, 32, 64),
                GemmOp::dense("b", 64, 64, 64).chained(),
            ],
        );
        (plat, wl)
    }

    #[test]
    fn ilp_returns_valid_certified_allocation() {
        let (plat, wl) = small();
        let r = optimize(
            &plat,
            &wl,
            OptFlags::ALL,
            Objective::Latency,
            Duration::from_millis(200),
            7,
        );
        assert!(r.alloc.validate(&wl, &plat).is_ok());
        assert!(r.objective_value.is_finite() && r.objective_value > 0.0);
        crate::engine::certify_allocation(&plat, &wl, &r.alloc, OptFlags::ALL)
            .expect("ILP plan certifies");
    }

    #[test]
    fn ilp_never_worse_than_miqp_or_uniform() {
        let (plat, wl) = small();
        let budget = Duration::from_millis(200);
        let r = optimize(&plat, &wl, OptFlags::ALL, Objective::Latency,
                         budget, 1);
        let mq = miqp::optimize(&plat, &wl, OptFlags::ALL,
                                Objective::Latency, budget, ILP_SOLVE_SEED);
        let uni = uniform_allocation(&plat, &wl);
        let uni_v = evaluate(&plat, &wl, &uni, OptFlags::ALL)
            .objective(Objective::Latency);
        assert!(r.objective_value <= mq.objective_value + 1e-9);
        assert!(r.objective_value <= uni_v + 1e-9);
    }

    #[test]
    fn ilp_ignores_caller_seed() {
        let (plat, wl) = small();
        let budget = Duration::from_secs(2);
        let a = optimize(&plat, &wl, OptFlags::ALL, Objective::Latency,
                         budget, 1);
        let b = optimize(&plat, &wl, OptFlags::ALL, Objective::Latency,
                         budget, 99);
        assert_eq!(a.alloc.parts, b.alloc.parts);
        assert_eq!(a.alloc.collect_cols, b.alloc.collect_cols);
        assert_eq!(a.objective_value.to_bits(), b.objective_value.to_bits());
    }

    #[test]
    fn linear_model_has_no_quadratic_cross_terms() {
        // The surrogate must be an LP after relaxation: evaluating at
        // points along a line segment is affine per max-case, so the
        // model value at the midpoint never exceeds the endpoint mean
        // (convexity of max-of-affine).
        let plat = Platform::headline();
        let wl = alexnet(1);
        let (model, layout, _) =
            build_linear(&plat, &wl, OptFlags::ALL, Objective::Latency);
        let uni = uniform_allocation(&plat, &wl);
        let mut a = vec![0.0; model.dim()];
        for (i, p) in uni.parts.iter().enumerate() {
            for (x, &v) in p.px.iter().enumerate() {
                a[layout.px(i, x)] = v as f64;
            }
            for (y, &v) in p.py.iter().enumerate() {
                a[layout.py(i, y)] = v as f64;
            }
        }
        let b: Vec<f64> = a.iter().map(|v| v * 0.5).collect();
        let mid: Vec<f64> =
            a.iter().zip(&b).map(|(x, y)| 0.5 * (x + y)).collect();
        let fa = model.eval(&a);
        let fb = model.eval(&b);
        let fm = model.eval(&mid);
        assert!(
            fm <= 0.5 * (fa + fb) + 1e-6 * (fa + fb).abs(),
            "midpoint {fm} above chord {} — quadratic term leaked in",
            0.5 * (fa + fb)
        );
    }
}
