//! Reusable evaluator state (§Perf): zero-allocation scratch buffers
//! for [`super::evaluator::evaluate_into`] and the gene-keyed
//! [`CachedEval`] that delta-scores GA children.
//!
//! # Cache invariants (see DESIGN.md §Performance architecture)
//!
//! Every cached value is keyed by *all* the genes that feed its
//! computation, so reuse is bit-identical to recomputation by
//! construction:
//!
//! * **Op core** ([`OpTerms`]) — keyed by op index, the op's own
//!   partition `(Px, Py)`, and the two booleans derived from the
//!   incident edge decisions (`acts_from_redist`, `skip_store`).
//! * **Edge decision** (`Option<RedistCost>` per dataflow edge
//!   `src -> dst`) — keyed by edge id, both endpoint ops' partitions
//!   and the edge's collection column. Cache invalidation is therefore
//!   keyed by the edge **endpoints**: a mutation of op `i` dirties only
//!   the decisions of edges incident to `i`.
//! * **Activation-load share** (what redistribution saves the
//!   consumer) — keyed by consumer op id and consumer partition; a
//!   sub-term of the edge decision cached separately because crossover
//!   creates novel (producer, consumer) pairs whose consumer half was
//!   already scored.
//! * Gene-independent terms (store wall time, per-edge legality, the
//!   sole-edge maps) are precomputed once at construction. The
//!   [`Platform`] hop tables are immutable per platform, so no gene can
//!   invalidate them (a different platform means a different
//!   `CachedEval`).
//!
//! A GA child that mutated `k` ops therefore recomputes only those
//! ops' cores plus the adjacent edges; everything else is a map hit.
//! Debug builds re-run the full evaluator on every call and assert the
//! composed result is bit-identical.

use std::collections::hash_map::Entry;
use std::collections::HashMap;
use std::hash::{BuildHasherDefault, Hasher};

use crate::partition::{Allocation, Partition};
use crate::platform::Platform;
use crate::redistribution::{redistribute, RedistCost};
use crate::workload::Workload;

use super::evaluator::{
    act_load_extra_ns, compose_op, op_terms, CostBreakdown, Objective,
    OpTerms, OptFlags,
};
use super::latency::{offload_wall_ns, CommCost};

/// Per-call temporaries shared by the evaluator's input/compute stages.
#[derive(Debug, Clone, Default)]
pub struct TermBufs {
    pub(crate) in_cost: CommCost,
    pub(crate) comp_per: Vec<f64>,
}

/// Scratch buffers for [`super::evaluator::evaluate_into`]: reused
/// across calls so the evaluator allocates nothing once warmed up to
/// the workload size (op count and edge count).
#[derive(Debug, Clone, Default)]
pub struct EvalScratch {
    /// Per dataflow edge: did the adaptive strategy adopt
    /// redistribution?
    pub(crate) redist_edge: Vec<bool>,
    pub(crate) redist_cost: Vec<Option<RedistCost>>,
    /// Per op: the unique incoming / outgoing edge id (in-/out-degree
    /// exactly 1), from [`crate::workload::Workload::sole_edges_into`].
    pub(crate) in_edge: Vec<Option<usize>>,
    pub(crate) out_edge: Vec<Option<usize>>,
    pub(crate) bufs: TermBufs,
}

// ---- FNV-1a hashing -----------------------------------------------------
//
// The cache keys are short integer slices; SipHash (std's default,
// DoS-resistant) costs more than the map probe itself here. FNV-1a is
// the standard zero-dependency replacement for small fixed keys.

pub(crate) struct Fnv64 {
    state: u64,
}

impl Default for Fnv64 {
    fn default() -> Self {
        Fnv64 { state: 0xcbf29ce484222325 }
    }
}

impl Hasher for Fnv64 {
    #[inline]
    fn finish(&self) -> u64 {
        self.state
    }

    #[inline]
    fn write(&mut self, bytes: &[u8]) {
        let mut h = self.state;
        for &b in bytes {
            h ^= b as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
        self.state = h;
    }
}

type FnvMap<K, V> = HashMap<K, V, BuildHasherDefault<Fnv64>>;

/// One op's partition genes, owned (map key).
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
struct GeneKey {
    px: Box<[usize]>,
    py: Box<[usize]>,
}

impl GeneKey {
    // Known follow-up: this boxes two small slices per probe even on
    // hits (~tens of short-lived allocations per rescore). Exactness
    // requires owning the genes, so the fix is interning each op's
    // partition to a small integer id and keying edge/core maps on ids
    // — deferred until a measured baseline shows it matters.
    fn of(part: &Partition) -> GeneKey {
        GeneKey {
            px: Box::from(part.px.as_slice()),
            py: Box::from(part.py.as_slice()),
        }
    }
}

#[derive(Debug, Clone, PartialEq, Eq, Hash)]
struct CoreKey {
    genes: GeneKey,
    acts_from_redist: bool,
    skip_store: bool,
}

#[derive(Debug, Clone, PartialEq, Eq, Hash)]
struct EdgeKey {
    producer: GeneKey,
    consumer: GeneKey,
    collect_col: usize,
}

/// Cache telemetry (tests + the hotpath bench report these).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    pub entries: usize,
    /// Per-map clears triggered by the capacity bound. Eviction never
    /// changes answers: every cached value is a pure function of its
    /// key, so a post-eviction miss recomputes the identical bits.
    pub evictions: u64,
}

/// Global entry cap: beyond this the caches are dropped wholesale. Each
/// entry is ~100 bytes for paper-scale grids, so the cap bounds a
/// worker at tens of MB while never firing inside one GA generation.
const CACHE_CAP_ENTRIES: usize = 1 << 18;

/// A memoizing evaluator bound to one `(platform, wl, flags)` problem.
///
/// [`CachedEval::objective`] / [`CachedEval::breakdown`] score an
/// allocation exactly like [`super::evaluator::evaluate`] but reuse
/// per-op/per-edge terms across calls (see the module docs for the key
/// structure). The GA holds one per worker thread; values are
/// bit-identical to full evaluation regardless of cache state, which
/// is what keeps parallel and delta-scored runs equal to the
/// sequential full evaluator.
pub struct CachedEval<'a> {
    plat: &'a Platform,
    wl: &'a Workload,
    flags: OptFlags,
    /// Per dataflow edge: §5.2 legality (gene-independent).
    edge_legal: Vec<bool>,
    /// Per op: the unique incoming / outgoing edge id, if the degree is
    /// exactly 1 (gene-independent; drives the op flag derivation).
    in_edge: Vec<Option<usize>>,
    out_edge: Vec<Option<usize>>,
    /// `offload_wall_ns` per op (gene-independent).
    store_wall: Vec<f64>,
    core_cache: Vec<FnvMap<CoreKey, OpTerms>>,
    /// Indexed by edge id: decisions keyed by both endpoint partitions
    /// + the edge's collection column.
    edge_cache: Vec<FnvMap<EdgeKey, Option<RedistCost>>>,
    /// Indexed by consumer op id.
    act_cache: Vec<FnvMap<GeneKey, f64>>,
    bufs: TermBufs,
    redist_edge: Vec<bool>,
    redist_cost: Vec<Option<RedistCost>>,
    out: CostBreakdown,
    hits: u64,
    misses: u64,
    entries: usize,
    /// Per-map entry bound (see [`CachedEval::set_map_cap`]): any single
    /// key-indexed map growing past this is cleared, keeping worst-case
    /// memory proportional to workload size instead of GA run length
    /// even when one hot op sees an adversarial gene stream.
    map_cap: usize,
    evictions: u64,
}

/// Clear `map` when it outgrew `cap`, keeping the global entry count
/// and eviction telemetry in sync. Values are pure functions of their
/// keys, so dropping them trades recompute time for memory without
/// perturbing a single bit of any future score.
fn evict_if_over<K, V>(
    map: &mut FnvMap<K, V>,
    cap: usize,
    entries: &mut usize,
    evictions: &mut u64,
) {
    if map.len() > cap {
        *entries = entries.saturating_sub(map.len());
        map.clear();
        *evictions += 1;
    }
}

impl<'a> CachedEval<'a> {
    pub fn new(
        plat: &'a Platform,
        wl: &'a Workload,
        flags: OptFlags,
    ) -> CachedEval<'a> {
        let n = wl.ops.len();
        let ne = wl.edges.len();
        let (mut in_edge, mut out_edge) = (Vec::new(), Vec::new());
        wl.sole_edges_into(&mut in_edge, &mut out_edge);
        let edge_legal: Vec<bool> = (0..ne)
            .map(|e| wl.edge_redistributable_with(e, &in_edge, &out_edge))
            .collect();
        let store_wall: Vec<f64> = wl
            .ops
            .iter()
            .map(|op| offload_wall_ns(plat, op, flags.diagonal))
            .collect();
        CachedEval {
            plat,
            wl,
            flags,
            edge_legal,
            in_edge,
            out_edge,
            store_wall,
            core_cache: (0..n).map(|_| FnvMap::default()).collect(),
            edge_cache: (0..ne).map(|_| FnvMap::default()).collect(),
            act_cache: (0..n).map(|_| FnvMap::default()).collect(),
            bufs: TermBufs::default(),
            redist_edge: vec![false; ne],
            redist_cost: vec![None; ne],
            out: CostBreakdown::default(),
            hits: 0,
            misses: 0,
            entries: 0,
            // Split the global budget across the per-op / per-edge maps
            // so no single map can hog it (two per-op maps + one per
            // edge), with a floor that keeps tiny workloads useful.
            map_cap: (CACHE_CAP_ENTRIES / (2 * n + ne).max(1)).max(8),
            evictions: 0,
        }
    }

    pub fn flags(&self) -> OptFlags {
        self.flags
    }

    /// Override the per-map entry bound (tests and memory-pressure
    /// tuning). Shrinking it only causes extra recomputation — scores
    /// stay bit-identical at any cap.
    pub fn set_map_cap(&mut self, cap: usize) {
        self.map_cap = cap.max(1);
    }

    pub fn stats(&self) -> CacheStats {
        CacheStats {
            hits: self.hits,
            misses: self.misses,
            entries: self.entries,
            evictions: self.evictions,
        }
    }

    /// Drop every memoized term (keeps the problem binding).
    pub fn clear_cache(&mut self) {
        for m in &mut self.core_cache {
            m.clear();
        }
        for m in &mut self.edge_cache {
            m.clear();
        }
        for m in &mut self.act_cache {
            m.clear();
        }
        self.entries = 0;
    }

    /// Score `alloc` on the objective — bit-identical to
    /// `evaluate(..).objective(obj)`.
    pub fn objective(&mut self, alloc: &Allocation, obj: Objective) -> f64 {
        self.rescore(alloc);
        self.out.objective(obj)
    }

    /// Full cost breakdown — bit-identical to `evaluate(..)`. The
    /// returned reference is valid until the next scoring call.
    pub fn breakdown(&mut self, alloc: &Allocation) -> &CostBreakdown {
        self.rescore(alloc);
        &self.out
    }

    fn rescore(&mut self, alloc: &Allocation) {
        if self.entries > CACHE_CAP_ENTRIES {
            self.clear_cache();
        }
        let CachedEval {
            plat,
            wl,
            flags,
            edge_legal,
            in_edge,
            out_edge,
            store_wall,
            core_cache,
            edge_cache,
            act_cache,
            bufs,
            redist_edge,
            redist_cost,
            out,
            hits,
            misses,
            entries,
            map_cap,
            evictions,
        } = self;
        let (plat, wl, flags) = (*plat, *wl, *flags);
        let cap = *map_cap;
        let n = wl.ops.len();
        let ne = wl.edges.len();
        debug_assert_eq!(alloc.parts.len(), n);
        debug_assert_eq!(alloc.collect_cols.len(), ne);

        // ---- Phase 1: decisions per dataflow edge, in edge-id order
        // (sorted by (src, dst): the historical i -> i+1 sweep on
        // linear chains).
        redist_edge.clear();
        redist_edge.resize(ne, false);
        redist_cost.clear();
        redist_cost.resize(ne, None);
        if flags.redistribution {
            for (e, edge) in wl.edges.iter().enumerate() {
                if !edge_legal[e] {
                    continue;
                }
                let (src, dst) = (edge.src, edge.dst);
                let key = EdgeKey {
                    producer: GeneKey::of(&alloc.parts[src]),
                    consumer: GeneKey::of(&alloc.parts[dst]),
                    collect_col: alloc.collect_cols[e],
                };
                let decision = match edge_cache[e].entry(key) {
                    Entry::Occupied(occ) => {
                        *hits += 1;
                        *occ.get()
                    }
                    Entry::Vacant(v) => {
                        *misses += 1;
                        *entries += 1;
                        // Same terms, same order as
                        // `evaluator::edge_decision` (legality already
                        // checked; store wall precomputed; activation
                        // share sub-cached by consumer genes).
                        let r = redistribute(
                            plat,
                            &wl.ops[src],
                            &alloc.parts[src],
                            &alloc.parts[dst],
                            alloc.collect_cols[e],
                        );
                        let act_extra = match act_cache[dst]
                            .entry(GeneKey::of(&alloc.parts[dst]))
                        {
                            Entry::Occupied(occ) => *occ.get(),
                            Entry::Vacant(av) => {
                                *entries += 1;
                                *av.insert(act_load_extra_ns(
                                    plat,
                                    &wl.ops[dst],
                                    &alloc.parts[dst],
                                    flags.diagonal,
                                    bufs,
                                ))
                            }
                        };
                        let adopt =
                            r.total_ns() < store_wall[src] + act_extra;
                        *v.insert(if adopt { Some(r) } else { None })
                    }
                };
                if let Some(r) = decision {
                    redist_edge[e] = true;
                    redist_cost[e] = Some(r);
                }
                evict_if_over(&mut edge_cache[e], cap, entries, evictions);
                evict_if_over(&mut act_cache[dst], cap, entries, evictions);
            }
        }

        // ---- Phase 2: per-op cores, composed in index order exactly
        // like the full evaluator (same summation order => same bits).
        out.latency_ns = 0.0;
        out.energy_pj = 0.0;
        out.per_op.clear();
        out.per_op.reserve(n);
        for (i, op) in wl.ops.iter().enumerate() {
            let acts_from_redist = match in_edge[i] {
                Some(e) => redist_edge[e],
                None => false,
            };
            let skip_store = match out_edge[i] {
                Some(e) => redist_edge[e],
                None => false,
            };
            let key = CoreKey {
                genes: GeneKey::of(&alloc.parts[i]),
                acts_from_redist,
                skip_store,
            };
            let terms = match core_cache[i].entry(key) {
                Entry::Occupied(e) => {
                    *hits += 1;
                    *e.get()
                }
                Entry::Vacant(v) => {
                    *misses += 1;
                    *entries += 1;
                    *v.insert(op_terms(
                        plat,
                        op,
                        &alloc.parts[i],
                        flags,
                        acts_from_redist,
                        skip_store,
                        bufs,
                    ))
                }
            };
            evict_if_over(&mut core_cache[i], cap, entries, evictions);
            let incoming = if acts_from_redist {
                redist_cost[in_edge[i].expect("redistributed op has an edge")]
            } else {
                None
            };
            let oc = compose_op(
                &terms,
                incoming.as_ref(),
                skip_store,
                flags.async_fusion,
            );
            out.latency_ns += oc.latency_ns;
            out.energy_pj += oc.energy_pj;
            out.per_op.push(oc);
        }

        // Debug builds re-derive everything from scratch and insist the
        // delta-scored composition is bit-identical (ISSUE 2 invariant).
        #[cfg(debug_assertions)]
        {
            let full = super::evaluator::evaluate(plat, wl, alloc, flags);
            debug_assert_eq!(
                full.latency_ns.to_bits(),
                out.latency_ns.to_bits(),
                "CachedEval latency diverged from full evaluate"
            );
            debug_assert_eq!(
                full.energy_pj.to_bits(),
                out.energy_pj.to_bits(),
                "CachedEval energy diverged from full evaluate"
            );
            debug_assert_eq!(full.per_op.len(), out.per_op.len());
            for (a, b) in full.per_op.iter().zip(out.per_op.iter()) {
                debug_assert_eq!(a.latency_ns.to_bits(),
                                 b.latency_ns.to_bits());
                debug_assert_eq!(a.energy_pj.to_bits(),
                                 b.energy_pj.to_bits());
                debug_assert_eq!(a.in_ns.to_bits(), b.in_ns.to_bits());
                debug_assert_eq!(a.comp_ns.to_bits(), b.comp_ns.to_bits());
                debug_assert_eq!(a.out_ns.to_bits(), b.out_ns.to_bits());
                debug_assert_eq!(a.redistributed_in, b.redistributed_in);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{MemKind, SystemType};
    use crate::cost::evaluator::evaluate;
    use crate::partition::uniform_allocation;
    use crate::workload::models::{alexnet, vit};

    fn setup() -> Platform {
        Platform::preset(SystemType::A, MemKind::Hbm, 4)
    }

    #[test]
    fn cached_matches_full_and_hits_on_repeat() {
        let plat = setup();
        let wl = alexnet(1);
        let alloc = uniform_allocation(&plat, &wl);
        let mut cache = CachedEval::new(&plat, &wl, OptFlags::ALL);
        let full = evaluate(&plat, &wl, &alloc, OptFlags::ALL);
        let a = cache.objective(&alloc, Objective::Latency);
        assert_eq!(a.to_bits(),
                   full.objective(Objective::Latency).to_bits());
        let miss_after_first = cache.stats().misses;
        assert!(miss_after_first > 0);
        // Identical allocation again: all terms hit.
        let b = cache.objective(&alloc, Objective::Latency);
        assert_eq!(a.to_bits(), b.to_bits());
        assert_eq!(cache.stats().misses, miss_after_first);
        assert!(cache.stats().hits > 0);
    }

    #[test]
    fn single_gene_change_recomputes_neighbors_only() {
        let plat = setup();
        let wl = alexnet(1);
        let mut alloc = uniform_allocation(&plat, &wl);
        let mut cache = CachedEval::new(&plat, &wl, OptFlags::ALL);
        cache.objective(&alloc, Objective::Latency);
        let before = cache.stats().misses;
        // Move one tile of rows in op 3: dirties op 3's core and the
        // two adjacent edges (plus their neighbors' core-flag keys),
        // not the whole workload.
        alloc.parts[3].px[0] += 16;
        alloc.parts[3].px[1] -= 16;
        let v = cache.objective(&alloc, Objective::Edp);
        let full = evaluate(&plat, &wl, &alloc, OptFlags::ALL)
            .objective(Objective::Edp);
        assert_eq!(v.to_bits(), full.to_bits());
        let fresh = cache.stats().misses - before;
        assert!(fresh <= 8, "expected a local recompute, got {fresh} misses");
        assert!(fresh >= 1);
    }

    #[test]
    fn edp_objective_matches_on_vit() {
        let plat = setup();
        let wl = vit(1);
        let alloc = uniform_allocation(&plat, &wl);
        for flags in [OptFlags::NONE, OptFlags::ALL] {
            let mut cache = CachedEval::new(&plat, &wl, flags);
            let v = cache.objective(&alloc, Objective::Edp);
            let full =
                evaluate(&plat, &wl, &alloc, flags).objective(Objective::Edp);
            assert_eq!(v.to_bits(), full.to_bits());
        }
    }

    #[test]
    fn clear_cache_keeps_answers_stable() {
        let plat = setup();
        let wl = alexnet(1);
        let alloc = uniform_allocation(&plat, &wl);
        let mut cache = CachedEval::new(&plat, &wl, OptFlags::ALL);
        let a = cache.objective(&alloc, Objective::Latency);
        cache.clear_cache();
        assert_eq!(cache.stats().entries, 0);
        let b = cache.objective(&alloc, Objective::Latency);
        assert_eq!(a.to_bits(), b.to_bits());
    }

    #[test]
    fn eviction_is_bit_identical_to_full_eval() {
        let plat = setup();
        let wl = alexnet(1);
        let base = uniform_allocation(&plat, &wl);
        // Distinct gene patterns so every per-edge / per-op map sees
        // more keys than the (tiny) cap allows.
        let mut variants = Vec::new();
        for k in 0..3usize {
            let mut a = base.clone();
            a.parts[3].px[0] += 8 * k;
            a.parts[3].px[1] -= 8 * k;
            for (e, c) in a.collect_cols.iter_mut().enumerate() {
                *c = (e + k) % plat.spec().ydim;
            }
            variants.push(a);
        }
        let mut cache = CachedEval::new(&plat, &wl, OptFlags::ALL);
        cache.set_map_cap(1);
        for round in 0..3 {
            for a in &variants {
                let v = cache.objective(a, Objective::Edp);
                let full = evaluate(&plat, &wl, a, OptFlags::ALL)
                    .objective(Objective::Edp);
                assert_eq!(v.to_bits(), full.to_bits(), "round {round}");
            }
        }
        let st = cache.stats();
        assert!(st.evictions > 0, "cap=1 must evict across distinct keys");
        // Memory stays bounded by workload size, not scoring history.
        let maps = 2 * wl.ops.len() + wl.edges.len();
        assert!(st.entries <= 2 * maps, "entries {} maps {maps}", st.entries);
    }

    #[test]
    fn fnv_hashes_differ_on_small_keys() {
        use std::hash::Hash;
        let h = |k: &GeneKey| {
            let mut f = Fnv64::default();
            k.hash(&mut f);
            f.finish()
        };
        let a = GeneKey { px: Box::from([1usize, 2].as_slice()),
                          py: Box::from([3usize].as_slice()) };
        let b = GeneKey { px: Box::from([1usize, 3].as_slice()),
                          py: Box::from([3usize].as_slice()) };
        assert_ne!(h(&a), h(&b));
        assert_eq!(h(&a), h(&a.clone()));
    }
}
