//! Communication latency model (paper §4.3.2–4.3.3): data offloading,
//! data loading in the low-BW (DRAM) and high-BW (HBM) congestion
//! regimes, and the shared/non-shared hop models — congestion-aware and
//! packaging-adaptive through the [`Platform`] hop tables (precomputed
//! from link-graph routing, so arbitrary memory layouts are costed
//! identically to the paper presets).

use crate::partition::Partition;
use crate::platform::Platform;
use crate::topology::Pos;
use crate::workload::GemmOp;

/// Cost of one communication stage. The paper decomposes every off-chip
/// communication into two *sequential* steps (§4.3.2–4.3.3): the
/// serialized off-chip transfer through the memory interface, then the
/// on-chip distribution/collection whose per-chiplet times encode
/// congestion via the eq. 9–12 hop models.
#[derive(Debug, Clone, Default)]
pub struct CommCost {
    /// On-chip distribution/collection time per chiplet, row-major; empty
    /// means "no on-chip stage" (e.g. 3D-stacked collection).
    pub per_chiplet_ns: Vec<f64>,
    /// Serialized off-chip (memory-interface) time.
    pub offchip_ns: f64,
}

impl CommCost {
    /// Synchronous wall time of this stage: off-chip step + slowest
    /// chiplet's on-chip step.
    pub fn wall_ns(&self) -> f64 {
        self.offchip_ns + self.max_onchip_ns()
    }

    pub fn max_onchip_ns(&self) -> f64 {
        self.per_chiplet_ns.iter().copied().fold(0.0, f64::max)
    }

    /// Data-ready time for chiplet `idx` (asynchronized execution §5.3):
    /// the off-chip step followed by its own distribution.
    pub fn ready_ns(&self, idx: usize) -> f64 {
        let on = self.per_chiplet_ns.get(idx).copied().unwrap_or(0.0);
        self.offchip_ns + on
    }
}

/// Is this configuration in the high-bandwidth regime (§4.3.3 case 2)?
/// When the memory interface outruns a NoP link, congestion moves onto
/// the package network.
pub fn high_bw(plat: &Platform) -> bool {
    plat.bw_mem > plat.bw_nop
}

/// §4.3.2 — data offloading: collect outputs at the attachment
/// chiplet(s) (eq. 8: bottlenecked on the entrance links), then write to
/// memory.
pub fn offload(plat: &Platform, op: &GemmOp, diagonal: bool) -> CommCost {
    let out_bytes = plat.bytes(op.m * op.n);
    let entr = plat.entrance_links(diagonal);
    let collection_ns = if entr == 0 {
        0.0 // every chiplet is an attachment: outputs go straight up
    } else {
        out_bytes / (entr as f64 * plat.bw_nop)
    };
    CommCost {
        per_chiplet_ns: vec![collection_ns; plat.num_chiplets()],
        offchip_ns: out_bytes / plat.bw_mem,
    }
}

/// Wall time of [`offload`] without materializing the per-chiplet vector
/// (every chiplet's collection time is identical, so the max *is* the
/// collection time). Bit-identical to `offload(..).wall_ns()` — pinned
/// by a test below and relied on by the evaluator hot path (§Perf).
pub fn offload_wall_ns(plat: &Platform, op: &GemmOp, diagonal: bool) -> f64 {
    let out_bytes = plat.bytes(op.m * op.n);
    let entr = plat.entrance_links(diagonal);
    let collection_ns = if entr == 0 {
        0.0
    } else {
        out_bytes / (entr as f64 * plat.bw_nop)
    };
    out_bytes / plat.bw_mem + collection_ns
}

/// §4.3.3 — data loading: off-chip fetch + congestion-aware on-chip
/// distribution. `load_acts` is false when on-package redistribution
/// (§5.2) supplies the activations and only weights stream from memory.
pub fn load(
    plat: &Platform,
    op: &GemmOp,
    part: &Partition,
    diagonal: bool,
    load_acts: bool,
) -> CommCost {
    let mut out = CommCost::default();
    load_into(plat, op, part, diagonal, load_acts, &mut out);
    out
}

/// [`load`] writing into a caller-provided [`CommCost`], reusing its
/// per-chiplet buffer — the zero-allocation form the evaluator scratch
/// path uses (§Perf). Results are bit-identical to [`load`] (same code).
pub fn load_into(
    plat: &Platform,
    op: &GemmOp,
    part: &Partition,
    diagonal: bool,
    load_acts: bool,
    out: &mut CommCost,
) {
    let hi = high_bw(plat);
    let per_chiplet = &mut out.per_chiplet_ns;
    per_chiplet.clear();
    per_chiplet.reserve(plat.num_chiplets());
    for p in plat.positions() {
        let Pos { row: x, col: y } = p;
        // Activation chunk px[x] * K is row-wise shared (every chiplet in
        // grid row x needs it); weight chunk K * py[y] is column-shared.
        let act_bytes = if load_acts {
            plat.bytes(part.px[x] * op.k)
        } else {
            0.0
        };
        let w_bytes = plat.bytes(op.k * part.py[y]);
        let (act_hops, w_hops) = if hi {
            // §4.3.3 case 2: congestion on the package network; eqs.
            // 11–12 fold the farthest-first waiting slots into the hop
            // count.
            (
                plat.hops_row_shared(p, diagonal) as f64,
                plat.hops_col_shared(p, diagonal) as f64,
            )
        } else {
            // §4.3.3 case 1 (eq. 9–10): no contention, minimal-path
            // store-and-forward.
            let h = plat.hops_low_bw(p, diagonal) as f64;
            (h, h)
        };
        per_chiplet
            .push((act_bytes * act_hops + w_bytes * w_hops) / plat.bw_nop);
    }
    // Unique bytes through the memory interface.
    let mut off_bytes = plat.bytes(op.k * op.n); // weights (K x N)
    if load_acts {
        off_bytes += plat.bytes(op.m * op.k);
    }
    out.offchip_ns = off_bytes / plat.bw_mem;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{MemKind, SystemType};
    use crate::partition::uniform;

    fn setup(ty: SystemType, mem: MemKind) -> Platform {
        Platform::preset(ty, mem, 4)
    }

    #[test]
    fn eq8_offload_entrance_bottleneck() {
        let plat = setup(SystemType::A, MemKind::Hbm);
        let op = GemmOp::dense("x", 480, 64, 100);
        let c = offload(&plat, &op, false);
        // 48000 bytes over 2 entrance links x 60 GB/s.
        assert!((c.max_onchip_ns() - 48000.0 / 120.0).abs() < 1e-9);
        // HBM: off-chip much faster than collection -> collection wins.
        assert!(c.wall_ns() > c.offchip_ns);
        // Diagonal entrance (3 links) cuts collection by 1/3 (§5.1).
        let cd = offload(&plat, &op, true);
        assert!((cd.max_onchip_ns() * 1.5 - c.max_onchip_ns()).abs() < 1e-6);
    }

    #[test]
    fn type_c_offload_is_memory_only() {
        let plat = setup(SystemType::C, MemKind::Hbm);
        let op = GemmOp::dense("x", 480, 64, 100);
        let c = offload(&plat, &op, false);
        assert_eq!(c.max_onchip_ns(), 0.0);
        assert!(c.offchip_ns > 0.0);
    }

    #[test]
    fn dram_shifts_bottleneck_offchip() {
        // §3.2: with DRAM the off-chip share of the load dominates much
        // more than with HBM (where congestion moves onto the NoP).
        let op = GemmOp::dense("x", 1024, 512, 1024);
        let plat_d = setup(SystemType::A, MemKind::Dram);
        let plat_h = setup(SystemType::A, MemKind::Hbm);
        assert!(!high_bw(&plat_d) && high_bw(&plat_h));
        let part = uniform(&plat_d, &op);
        let d = load(&plat_d, &op, &part, false, true);
        let h = load(&plat_h, &op, &part, false, true);
        let off_share = |c: &CommCost| c.offchip_ns / c.wall_ns();
        assert!(off_share(&d) > 3.0 * off_share(&h),
                "DRAM off-share {} vs HBM {}", off_share(&d), off_share(&h));
        // And DRAM is slower end-to-end.
        assert!(d.wall_ns() > h.wall_ns());
    }

    #[test]
    fn hbm_load_is_noc_bound() {
        let plat = setup(SystemType::A, MemKind::Hbm);
        let op = GemmOp::dense("x", 1024, 512, 1024);
        let part = uniform(&plat, &op);
        let c = load(&plat, &op, &part, false, true);
        assert!(high_bw(&plat));
        assert!(c.max_onchip_ns() > c.offchip_ns);
    }

    #[test]
    fn diagonal_reduces_hbm_distribution() {
        let plat = setup(SystemType::A, MemKind::Hbm);
        let op = GemmOp::dense("x", 1024, 512, 1024);
        let part = uniform(&plat, &op);
        let base = load(&plat, &op, &part, false, true);
        let diag = load(&plat, &op, &part, true, true);
        assert!(diag.max_onchip_ns() < base.max_onchip_ns());
    }

    #[test]
    fn weights_only_load_drops_activation_traffic() {
        let plat = setup(SystemType::A, MemKind::Hbm);
        let op = GemmOp::dense("x", 1024, 512, 1024);
        let part = uniform(&plat, &op);
        let full = load(&plat, &op, &part, false, true);
        let wonly = load(&plat, &op, &part, false, false);
        assert!(wonly.offchip_ns < full.offchip_ns);
        assert!(wonly.max_onchip_ns() < full.max_onchip_ns());
    }

    #[test]
    fn offload_wall_matches_full_offload() {
        let op = GemmOp::dense("x", 480, 64, 100);
        for ty in SystemType::ALL {
            for diagonal in [false, true] {
                let plat = setup(ty, MemKind::Hbm);
                let full = offload(&plat, &op, diagonal).wall_ns();
                let fast = offload_wall_ns(&plat, &op, diagonal);
                assert_eq!(full.to_bits(), fast.to_bits(), "{ty:?}");
            }
        }
    }

    #[test]
    fn load_into_reuses_buffer_bit_identically() {
        let plat = setup(SystemType::A, MemKind::Hbm);
        let op = GemmOp::dense("x", 1024, 512, 1024);
        let part = uniform(&plat, &op);
        let fresh = load(&plat, &op, &part, true, true);
        let mut buf = CommCost {
            per_chiplet_ns: vec![99.0; 3], // stale garbage must be cleared
            offchip_ns: -1.0,
        };
        load_into(&plat, &op, &part, true, true, &mut buf);
        assert_eq!(fresh.offchip_ns.to_bits(), buf.offchip_ns.to_bits());
        assert_eq!(fresh.per_chiplet_ns.len(), buf.per_chiplet_ns.len());
        for (a, b) in fresh.per_chiplet_ns.iter().zip(&buf.per_chiplet_ns) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn asymmetric_platform_loads_cost_less_near_memory() {
        // A custom attachment set: the congestion-aware load must favor
        // chiplets close to their serving attachment.
        use crate::platform::MemAttachment;
        let mut spec = Platform::headline().spec().clone();
        spec.name = "asym".into();
        spec.attachments = vec![MemAttachment::new(0, 0, 500.0),
                                MemAttachment::new(3, 3, 500.0)];
        let plat = Platform::new(spec).unwrap();
        let op = GemmOp::dense("x", 1024, 512, 1024);
        let part = uniform(&plat, &op);
        let c = load(&plat, &op, &part, false, true);
        let near = c.per_chiplet_ns[0]; // (0, 0): an attachment
        let far = c.per_chiplet_ns[6]; // (1, 2): interior
        assert!(near < far, "near={near} far={far}");
    }

    #[test]
    fn ready_sums_sequential_steps() {
        let c = CommCost { per_chiplet_ns: vec![5.0, 50.0], offchip_ns: 10.0 };
        assert_eq!(c.ready_ns(0), 15.0);
        assert_eq!(c.ready_ns(1), 60.0);
        assert_eq!(c.wall_ns(), 60.0);
    }
}
