//! Compute cost (paper §4.3.1, eq. 7): output-stationary systolic-array
//! cycle model from SCALE-Sim.
//!
//!   comp_{x,y}(*_i) = (2R + C + K - 2) * (Px[x]/R) * (Py[y]/C)
//!
//! The (2R + C + K - 2) term is the cycle count to fill, stream K
//! partial sums through, and drain one R x C output tile; the two ratios
//! count output-tile iterations. We use ceiling division (a partial tile
//! still occupies the full array — exactly the under-utilization the
//! paper's min-partition constraint avoids).

use crate::platform::Platform;
use crate::util::math::ceil_div;
use crate::workload::GemmOp;

/// Cycles for one chiplet computing a (px x py) output chunk of `op`.
pub fn comp_cycles(plat: &Platform, op: &GemmOp, px: usize, py: usize) -> f64 {
    if px == 0 || py == 0 {
        return 0.0;
    }
    // Grouped GEMMs run `groups` sequential sub-GEMMs with contraction
    // K/groups; the fill/drain overhead is paid per group.
    let g = op.groups.max(1);
    let k_per = ceil_div(op.k, g);
    let tile_cycles = (2 * plat.r + plat.c + k_per).saturating_sub(2) as f64;
    let tiles = (ceil_div(px, plat.r) * ceil_div(py, plat.c)) as f64;
    g as f64 * tile_cycles * tiles
}

/// Nanoseconds for the same chunk.
pub fn comp_ns(plat: &Platform, op: &GemmOp, px: usize, py: usize) -> f64 {
    plat.cycles_to_ns(comp_cycles(plat, op, px, py))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{MemKind, SystemType};

    fn plat() -> Platform {
        Platform::preset(SystemType::A, MemKind::Hbm, 4) // R=C=16
    }

    #[test]
    fn eq7_single_tile() {
        // (2*16 + 16 + K - 2) * 1 * 1 with K = 64.
        let op = GemmOp::dense("x", 16, 64, 16);
        assert_eq!(comp_cycles(&plat(), &op, 16, 16), (32 + 16 + 64 - 2) as f64);
    }

    #[test]
    fn eq7_tile_scaling() {
        let op = GemmOp::dense("x", 64, 32, 64);
        let one = comp_cycles(&plat(), &op, 16, 16);
        assert_eq!(comp_cycles(&plat(), &op, 32, 32), 4.0 * one);
        assert_eq!(comp_cycles(&plat(), &op, 64, 16), 4.0 * one);
    }

    #[test]
    fn partial_tiles_round_up() {
        let op = GemmOp::dense("x", 40, 32, 40);
        // 17 rows -> 2 row tiles, same as 32 rows.
        assert_eq!(
            comp_cycles(&plat(), &op, 17, 16),
            comp_cycles(&plat(), &op, 32, 16)
        );
    }

    #[test]
    fn zero_chunk_is_free() {
        let op = GemmOp::dense("x", 16, 16, 16);
        assert_eq!(comp_cycles(&plat(), &op, 0, 16), 0.0);
    }

    #[test]
    fn grouped_pays_fill_drain_per_group() {
        let p = plat();
        let plain = GemmOp::dense("x", 16, 128, 16);
        let grouped = GemmOp::dense("x", 16, 128, 16).grouped(4);
        // Same MAC count, more fill/drain overhead.
        assert!(
            comp_cycles(&p, &grouped, 16, 16) > comp_cycles(&p, &plain, 16, 16)
        );
    }

    #[test]
    fn ns_uses_clock() {
        let p = plat();
        let op = GemmOp::dense("x", 16, 16, 16);
        let base = comp_ns(&p, &op, 16, 16);
        let mut spec = p.spec().clone();
        spec.freq_ghz = 2.0;
        let fast = Platform::new(spec).unwrap();
        assert!((comp_ns(&fast, &op, 16, 16) - base / 2.0).abs() < 1e-9);
    }
}
