//! Compute cost (paper §4.3.1, eq. 7): output-stationary systolic-array
//! cycle model from SCALE-Sim.
//!
//!   comp_{x,y}(*_i) = (2R + C + K - 2) * (Px[x]/R) * (Py[y]/C)
//!
//! The (2R + C + K - 2) term is the cycle count to fill, stream K
//! partial sums through, and drain one R x C output tile; the two ratios
//! count output-tile iterations. We use ceiling division (a partial tile
//! still occupies the full array — exactly the under-utilization the
//! paper's min-partition constraint avoids).

use crate::config::HwConfig;
use crate::util::math::ceil_div;
use crate::workload::GemmOp;

/// Cycles for one chiplet computing a (px x py) output chunk of `op`.
pub fn comp_cycles(hw: &HwConfig, op: &GemmOp, px: usize, py: usize) -> f64 {
    if px == 0 || py == 0 {
        return 0.0;
    }
    // Grouped GEMMs run `groups` sequential sub-GEMMs with contraction
    // K/groups; the fill/drain overhead is paid per group.
    let g = op.groups.max(1);
    let k_per = ceil_div(op.k, g);
    let tile_cycles = (2 * hw.r + hw.c + k_per).saturating_sub(2) as f64;
    let tiles = (ceil_div(px, hw.r) * ceil_div(py, hw.c)) as f64;
    g as f64 * tile_cycles * tiles
}

/// Nanoseconds for the same chunk.
pub fn comp_ns(hw: &HwConfig, op: &GemmOp, px: usize, py: usize) -> f64 {
    hw.cycles_to_ns(comp_cycles(hw, op, px, py))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{MemKind, SystemType};

    fn hw() -> HwConfig {
        HwConfig::paper(SystemType::A, MemKind::Hbm, 4) // R=C=16
    }

    #[test]
    fn eq7_single_tile() {
        // (2*16 + 16 + K - 2) * 1 * 1 with K = 64.
        let op = GemmOp::dense("x", 16, 64, 16);
        assert_eq!(comp_cycles(&hw(), &op, 16, 16), (32 + 16 + 64 - 2) as f64);
    }

    #[test]
    fn eq7_tile_scaling() {
        let op = GemmOp::dense("x", 64, 32, 64);
        let one = comp_cycles(&hw(), &op, 16, 16);
        assert_eq!(comp_cycles(&hw(), &op, 32, 32), 4.0 * one);
        assert_eq!(comp_cycles(&hw(), &op, 64, 16), 4.0 * one);
    }

    #[test]
    fn partial_tiles_round_up() {
        let op = GemmOp::dense("x", 40, 32, 40);
        // 17 rows -> 2 row tiles, same as 32 rows.
        assert_eq!(
            comp_cycles(&hw(), &op, 17, 16),
            comp_cycles(&hw(), &op, 32, 16)
        );
    }

    #[test]
    fn zero_chunk_is_free() {
        let op = GemmOp::dense("x", 16, 16, 16);
        assert_eq!(comp_cycles(&hw(), &op, 0, 16), 0.0);
    }

    #[test]
    fn grouped_pays_fill_drain_per_group() {
        let h = hw();
        let plain = GemmOp::dense("x", 16, 128, 16);
        let grouped = GemmOp::dense("x", 16, 128, 16).grouped(4);
        // Same MAC count, more fill/drain overhead.
        assert!(
            comp_cycles(&h, &grouped, 16, 16) > comp_cycles(&h, &plain, 16, 16)
        );
    }

    #[test]
    fn ns_uses_clock() {
        let mut h = hw();
        let op = GemmOp::dense("x", 16, 16, 16);
        let base = comp_ns(&h, &op, 16, 16);
        h.freq_ghz = 2.0;
        assert!((comp_ns(&h, &op, 16, 16) - base / 2.0).abs() < 1e-9);
    }
}
