//! The end-to-end analytical cost framework (paper §4): cycle-accurate
//! compute, congestion-aware communication latency, energy, and the
//! evaluator that composes them under the §5 co-optimizations.

pub mod compute;
pub mod energy;
pub mod evaluator;
pub mod latency;
pub mod scratch;

pub use evaluator::{
    evaluate, evaluate_into, CostBreakdown, Objective, OpCost, OptFlags,
};
pub use scratch::{CacheStats, CachedEval, EvalScratch};
