//! Energy model (paper §4.4): SRAM + MAC compute energy, off-chip
//! transfer energy, and hop-weighted NoP transfer energy. EDP is the
//! product of total energy and the end-to-end latency (§4.4 intro).

use crate::config::HwConfig;
use crate::partition::Partition;
use crate::topology::{Pos, Topology};
use crate::workload::GemmOp;
use super::compute::comp_cycles;

/// §4.4.1 — computation energy over all chiplets for one op:
/// `c_SRAM * bits(inp+filt+out) + c_MAC * cycles * R * C` summed
/// per chiplet (the paper's `(X*Y)` factor distributed over the actual
/// per-chiplet cycle counts, so non-uniform partitions are credited).
pub fn comp_energy_pj(hw: &HwConfig, op: &GemmOp, part: &Partition) -> f64 {
    let mut pj = 0.0;
    for &px in &part.px {
        for &py in &part.py {
            let (inp, filt, out) =
                (px * op.k, op.k * py, px * py);
            let bits = hw.bytes(inp + filt + out) * 8.0;
            pj += hw.energy.sram_pj_bit * bits;
            pj += hw.energy.mac_pj_cycle
                * comp_cycles(hw, op, px, py)
                * (hw.r * hw.c) as f64;
        }
    }
    pj
}

/// §4.4.2 — off-chip transfer energy: `c_offchip * sizeof(data)`.
pub fn offchip_energy_pj(hw: &HwConfig, bytes: f64) -> f64 {
    hw.mem.energy_pj_per_bit() * bytes * 8.0
}

/// §4.4.3 — on-chip (NoP) energy for distributing one op's inputs:
/// `c_NoP * sizeof(data) * hops` per chiplet chunk, hop counts from the
/// actual traversed path (diagonals shorten it).
pub fn load_energy_pj(
    hw: &HwConfig,
    topo: &Topology,
    op: &GemmOp,
    part: &Partition,
    diagonal: bool,
    load_acts: bool,
) -> f64 {
    let mut pj = 0.0;
    for p in topo.positions() {
        let Pos { row: x, col: y } = p;
        let hops = topo.hops_energy(p, diagonal) as f64;
        let mut bytes = hw.bytes(op.k * part.py[y]);
        if load_acts {
            bytes += hw.bytes(part.px[x] * op.k);
        }
        pj += hw.energy.nop_pj_bit_hop * bytes * 8.0 * hops;
    }
    pj
}

/// §4.4.3 applied to output collection (offload step 1): each chunk
/// travels from its chiplet to the serving global chiplet.
pub fn collect_energy_pj(
    hw: &HwConfig,
    topo: &Topology,
    _op: &GemmOp,
    part: &Partition,
    diagonal: bool,
) -> f64 {
    let mut pj = 0.0;
    for p in topo.positions() {
        let Pos { row: x, col: y } = p;
        let hops = topo.hops_energy(p, diagonal) as f64;
        let bytes = hw.bytes(part.px[x] * part.py[y]);
        pj += hw.energy.nop_pj_bit_hop * bytes * 8.0 * hops;
    }
    pj
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{MemKind, SystemType};
    use crate::partition::uniform;

    fn setup() -> (HwConfig, Topology) {
        let hw = HwConfig::paper(SystemType::A, MemKind::Hbm, 4);
        let topo = Topology::from_hw(&hw);
        (hw, topo)
    }

    #[test]
    fn comp_energy_components() {
        let (hw, _) = setup();
        let op = GemmOp::dense("x", 64, 64, 64);
        let part = uniform(&hw, &op);
        let pj = comp_energy_pj(&hw, &op, &part);
        assert!(pj > 0.0);
        // MAC term alone is a lower bound.
        let mac_only: f64 = part
            .px
            .iter()
            .flat_map(|&px| {
                part.py.iter().map(move |&py| (px, py))
            })
            .map(|(px, py)| {
                hw.energy.mac_pj_cycle * comp_cycles(&hw, &op, px, py) * 256.0
            })
            .sum();
        assert!(pj > mac_only);
    }

    #[test]
    fn dram_costs_more_than_hbm_per_byte() {
        let (mut hw, _) = setup();
        let hbm = offchip_energy_pj(&hw, 1000.0);
        hw.mem = MemKind::Dram;
        let dram = offchip_energy_pj(&hw, 1000.0);
        assert!(dram > hbm * 3.0);
    }

    #[test]
    fn diagonal_cuts_nop_energy() {
        let (hw, topo) = setup();
        let op = GemmOp::dense("x", 512, 128, 512);
        let part = uniform(&hw, &op);
        let base = load_energy_pj(&hw, &topo, &op, &part, false, true);
        let diag = load_energy_pj(&hw, &topo, &op, &part, true, true);
        assert!(diag < base);
    }

    #[test]
    fn collect_energy_zero_for_type_c() {
        let hw = HwConfig::paper(SystemType::C, MemKind::Hbm, 4);
        let topo = Topology::from_hw(&hw);
        let op = GemmOp::dense("x", 512, 128, 512);
        let part = uniform(&hw, &op);
        assert_eq!(collect_energy_pj(&hw, &topo, &op, &part, false), 0.0);
    }
}
