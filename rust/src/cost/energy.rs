//! Energy model (paper §4.4): SRAM + MAC compute energy, off-chip
//! transfer energy, and hop-weighted NoP transfer energy. EDP is the
//! product of total energy and the end-to-end latency (§4.4 intro).

use super::compute::comp_cycles;
use crate::partition::Partition;
use crate::platform::Platform;
use crate::topology::Pos;
use crate::workload::GemmOp;

/// §4.4.1 — computation energy over all chiplets for one op:
/// `c_SRAM * bits(inp+filt+out) + c_MAC * cycles * R * C` summed
/// per chiplet (the paper's `(X*Y)` factor distributed over the actual
/// per-chiplet cycle counts, so non-uniform partitions are credited).
pub fn comp_energy_pj(plat: &Platform, op: &GemmOp, part: &Partition) -> f64 {
    let mut pj = 0.0;
    for &px in &part.px {
        for &py in &part.py {
            let (inp, filt, out) =
                (px * op.k, op.k * py, px * py);
            let bits = plat.bytes(inp + filt + out) * 8.0;
            pj += plat.energy.sram_pj_bit * bits;
            pj += plat.energy.mac_pj_cycle
                * comp_cycles(plat, op, px, py)
                * (plat.r * plat.c) as f64;
        }
    }
    pj
}

/// §4.4.2 — off-chip transfer energy: `c_offchip * sizeof(data)`.
pub fn offchip_energy_pj(plat: &Platform, bytes: f64) -> f64 {
    plat.mem_pj_bit * bytes * 8.0
}

/// §4.4.3 — on-chip (NoP) energy for distributing one op's inputs:
/// `c_NoP * sizeof(data) * hops` per chiplet chunk, hop counts from the
/// actual traversed path (diagonals shorten it).
pub fn load_energy_pj(
    plat: &Platform,
    op: &GemmOp,
    part: &Partition,
    diagonal: bool,
    load_acts: bool,
) -> f64 {
    let mut pj = 0.0;
    for p in plat.positions() {
        let Pos { row: x, col: y } = p;
        let hops = plat.hops_energy(p, diagonal) as f64;
        let mut bytes = plat.bytes(op.k * part.py[y]);
        if load_acts {
            bytes += plat.bytes(part.px[x] * op.k);
        }
        pj += plat.energy.nop_pj_bit_hop * bytes * 8.0 * hops;
    }
    pj
}

/// §4.4.3 applied to output collection (offload step 1): each chunk
/// travels from its chiplet to the serving attachment chiplet.
pub fn collect_energy_pj(
    plat: &Platform,
    _op: &GemmOp,
    part: &Partition,
    diagonal: bool,
) -> f64 {
    let mut pj = 0.0;
    for p in plat.positions() {
        let Pos { row: x, col: y } = p;
        let hops = plat.hops_energy(p, diagonal) as f64;
        let bytes = plat.bytes(part.px[x] * part.py[y]);
        pj += plat.energy.nop_pj_bit_hop * bytes * 8.0 * hops;
    }
    pj
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{MemKind, SystemType};
    use crate::partition::uniform;

    fn setup() -> Platform {
        Platform::preset(SystemType::A, MemKind::Hbm, 4)
    }

    #[test]
    fn comp_energy_components() {
        let plat = setup();
        let op = GemmOp::dense("x", 64, 64, 64);
        let part = uniform(&plat, &op);
        let pj = comp_energy_pj(&plat, &op, &part);
        assert!(pj > 0.0);
        // MAC term alone is a lower bound.
        let mac_only: f64 = part
            .px
            .iter()
            .flat_map(|&px| {
                part.py.iter().map(move |&py| (px, py))
            })
            .map(|(px, py)| {
                plat.energy.mac_pj_cycle * comp_cycles(&plat, &op, px, py)
                    * 256.0
            })
            .sum();
        assert!(pj > mac_only);
    }

    #[test]
    fn dram_costs_more_than_hbm_per_byte() {
        let hbm = offchip_energy_pj(&setup(), 1000.0);
        let plat_d = Platform::preset(SystemType::A, MemKind::Dram, 4);
        let dram = offchip_energy_pj(&plat_d, 1000.0);
        assert!(dram > hbm * 3.0);
    }

    #[test]
    fn diagonal_cuts_nop_energy() {
        let plat = setup();
        let op = GemmOp::dense("x", 512, 128, 512);
        let part = uniform(&plat, &op);
        let base = load_energy_pj(&plat, &op, &part, false, true);
        let diag = load_energy_pj(&plat, &op, &part, true, true);
        assert!(diag < base);
    }

    #[test]
    fn collect_energy_zero_for_type_c() {
        let plat = Platform::preset(SystemType::C, MemKind::Hbm, 4);
        let op = GemmOp::dense("x", 512, 128, 512);
        let part = uniform(&plat, &op);
        assert_eq!(collect_energy_pj(&plat, &op, &part, false), 0.0);
    }
}
