//! End-to-end cost evaluator (paper §4.2.4, eqs. 3–6): composes the
//! per-op compute and communication costs under the LS scheduling space
//! with the co-optimizations of §5 toggled by [`OptFlags`]. This is the
//! single source of truth scored by the GA, re-scored after MIQP, driven
//! by the figure harnesses, and used by the coordinator's simulated
//! clock. Packaging enters exclusively through the [`Platform`] hop
//! tables, so arbitrary memory layouts cost identically to presets.

use crate::partition::{Allocation, Partition};
use crate::platform::Platform;
use crate::redistribution::{redistribute, RedistCost};
use crate::workload::{GemmOp, Workload};

use super::compute::comp_ns;
use super::energy::{
    collect_energy_pj, comp_energy_pj, load_energy_pj, offchip_energy_pj,
};
use super::latency::{load_into, offload_wall_ns};
use super::scratch::EvalScratch;

/// The §5 co-optimization toggles (ablated in Figure 13).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct OptFlags {
    /// §5.1 diagonal NoP links.
    pub diagonal: bool,
    /// §5.2 on-package redistribution between chained ops.
    pub redistribution: bool,
    /// §5.3 asynchronized (fused load+compute) execution.
    pub async_fusion: bool,
}

impl OptFlags {
    pub const NONE: OptFlags = OptFlags {
        diagonal: false,
        redistribution: false,
        async_fusion: false,
    };
    pub const ALL: OptFlags = OptFlags {
        diagonal: true,
        redistribution: true,
        async_fusion: true,
    };
}

/// Optimization objective (eq. 6 "various metrics").
///
/// The first two are the paper's single-batch objectives. The last two
/// belong to the steady-state pipelined engine ([`crate::steady`]):
/// their *true* scores come from the multi-batch DES (period /
/// period × energy-per-sample), but the analytical evaluator still
/// needs a value for them — it answers with the single-batch proxy
/// (latency / EDP), which is a monotone stand-in whenever the steady
/// optimizer falls back to analytical scoring (MIQP surrogate, plan
/// provenance).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Objective {
    Latency,
    Edp,
    /// Steady-state throughput: minimize the pipeline period (ns per
    /// sample). Analytical proxy: single-batch latency.
    Throughput,
    /// Steady-state energy-delay per sample: minimize
    /// `period × energy-per-sample`. Analytical proxy: single-batch
    /// EDP.
    EdpPerSample,
}

/// Per-op cost decomposition (diagnostics + pipeline task durations +
/// the per-phase terms the simulation comparator reads).
#[derive(Debug, Clone, Default)]
pub struct OpCost {
    pub in_ns: f64,
    pub comp_ns: f64,
    pub out_ns: f64,
    /// True if the activations arrived by on-package redistribution.
    pub redistributed_in: bool,
    pub energy_pj: f64,
    /// Total latency contribution of this op.
    pub latency_ns: f64,
    /// §5.2 incoming-redistribution share of `in_ns` (0.0 when the
    /// activations came from memory).
    pub redist_ns: f64,
    /// Serialized off-chip (memory-interface) share of the load stage —
    /// the §4.3.2/4.3.3 "step 1" term the `simulate` CLI's phase
    /// comparison aligns with the simulator's off-chip pull window.
    pub in_offchip_ns: f64,
}

/// End-to-end cost (eq. 3).
#[derive(Debug, Clone, Default)]
pub struct CostBreakdown {
    pub latency_ns: f64,
    pub energy_pj: f64,
    pub per_op: Vec<OpCost>,
}

impl CostBreakdown {
    /// Energy-delay product in pJ·ns.
    pub fn edp(&self) -> f64 {
        self.latency_ns * self.energy_pj
    }

    pub fn objective(&self, obj: Objective) -> f64 {
        match obj {
            Objective::Latency | Objective::Throughput => self.latency_ns,
            Objective::Edp | Objective::EdpPerSample => self.edp(),
        }
    }

    // ---- per-phase aggregates (the conformance comparator and the
    // `simulate` CLI align these with the simulator's stage windows).

    /// Total input-stage time across ops (loads + incoming
    /// redistribution).
    pub fn in_total_ns(&self) -> f64 {
        self.per_op.iter().map(|o| o.in_ns).sum()
    }

    /// Total §5.2 redistribution time across ops.
    pub fn redist_total_ns(&self) -> f64 {
        self.per_op.iter().map(|o| o.redist_ns).sum()
    }

    /// Total compute time across ops (slowest-chiplet terms).
    pub fn comp_total_ns(&self) -> f64 {
        self.per_op.iter().map(|o| o.comp_ns).sum()
    }

    /// Total writeback time across ops.
    pub fn out_total_ns(&self) -> f64 {
        self.per_op.iter().map(|o| o.out_ns).sum()
    }
}

/// Evaluate `alloc` for `wl` on `plat` under `flags` (eqs. 3–5).
///
/// LS scheduling: ops run in sequence. Per op the stages are
/// `in → comp → out`; §5.3 async fusion merges in+comp per chiplet when
/// the previous boundary allows it. Redistribution (when legal per
/// §5.2 and enabled) replaces the producer's store + consumer's
/// activation load whenever it is the cheaper strategy ("adaptive
/// communication strategy", §6.1).
pub fn evaluate(
    plat: &Platform,
    wl: &Workload,
    alloc: &Allocation,
    flags: OptFlags,
) -> CostBreakdown {
    let mut scratch = EvalScratch::default();
    let mut out = CostBreakdown::default();
    evaluate_into(plat, wl, alloc, flags, &mut scratch, &mut out);
    out
}

/// [`evaluate`] writing into caller-provided scratch buffers and output:
/// after the buffers warm up to the workload's size, the inner loops
/// allocate nothing (§Perf). Results are bit-identical to [`evaluate`]
/// (which is now a thin wrapper over this function).
pub fn evaluate_into(
    plat: &Platform,
    wl: &Workload,
    alloc: &Allocation,
    flags: OptFlags,
    scratch: &mut EvalScratch,
    out: &mut CostBreakdown,
) {
    debug_assert!(alloc.parts.len() == wl.ops.len());
    debug_assert!(alloc.collect_cols.len() == wl.edges.len());
    let n = wl.ops.len();
    let ne = wl.edges.len();
    out.latency_ns = 0.0;
    out.energy_pj = 0.0;
    out.per_op.clear();
    out.per_op.reserve(n);

    // Per-op sole-edge maps: the op flags (`acts_from_redist`,
    // `skip_store`) read the unique incoming/outgoing edge, which is
    // also what makes redistribution legal on it (§5.2).
    wl.sole_edges_into(&mut scratch.in_edge, &mut scratch.out_edge);

    // Decide redistribution per dataflow edge up front, in edge-id
    // order (sorted by (src, dst) — identical to the historical i ->
    // i+1 sweep on linear chains); cache the 3-step cost so the per-op
    // loop never recomputes it (§Perf).
    scratch.redist_edge.clear();
    scratch.redist_edge.resize(ne, false);
    scratch.redist_cost.clear();
    scratch.redist_cost.resize(ne, None);
    if flags.redistribution {
        for (e, edge) in wl.edges.iter().enumerate() {
            if !wl.edge_redistributable_with(e, &scratch.in_edge,
                                             &scratch.out_edge) {
                continue;
            }
            if let Some(r) = edge_decision(
                plat,
                &wl.ops[edge.src],
                &wl.ops[edge.dst],
                &alloc.parts[edge.src],
                &alloc.parts[edge.dst],
                alloc.collect_cols[e],
                flags.diagonal,
                &mut scratch.bufs,
            ) {
                scratch.redist_edge[e] = true;
                scratch.redist_cost[e] = Some(r);
            }
        }
    }

    for (i, op) in wl.ops.iter().enumerate() {
        let part = &alloc.parts[i];
        let in_e = scratch.in_edge[i];
        let acts_from_redist = match in_e {
            Some(e) => scratch.redist_edge[e],
            None => false,
        };
        let skip_store = match scratch.out_edge[i] {
            Some(e) => scratch.redist_edge[e],
            None => false,
        };
        let incoming = if acts_from_redist {
            scratch.redist_cost[in_e.expect("redistributed op has an edge")]
        } else {
            None
        };
        let terms = op_terms(
            plat, op, part, flags, acts_from_redist, skip_store,
            &mut scratch.bufs,
        );
        let oc =
            compose_op(&terms, incoming.as_ref(), skip_store, flags.async_fusion);
        out.latency_ns += oc.latency_ns;
        out.energy_pj += oc.energy_pj;
        out.per_op.push(oc);
    }
}

/// The gene-dependent per-op cost terms the cache stores: everything in
/// one op's cost except the incoming-redistribution contributions
/// (which are additive and attributed at composition time). Produced by
/// [`op_terms`], composed by [`compose_op`]; the association order of
/// every floating-point expression replicates the historical monolithic
/// `evaluate` loop exactly, which is what makes delta-scored results
/// bit-identical to full evaluation.
#[derive(Debug, Clone, Copy, PartialEq)]
pub(crate) struct OpTerms {
    /// Input-stage wall time (`load(..).wall_ns()`), activation traffic
    /// gated by `acts_from_redist`.
    pub in_wall_ns: f64,
    /// The serialized off-chip share of `in_wall_ns` (surfaced as
    /// [`OpCost::in_offchip_ns`] for the `simulate` CLI's phase
    /// comparison).
    pub in_offchip_ns: f64,
    /// §5.3 fused in+comp wall time; 0.0 when async fusion is off.
    pub fused_ns: f64,
    /// Slowest chiplet's compute time.
    pub comp_max_ns: f64,
    /// Output-stage wall time if a store happens (gene-independent).
    pub store_ns: f64,
    /// Energy except the incoming redistribution's `energy_pj`.
    pub energy_pj: f64,
}

/// Compute one op's [`OpTerms`] (shared by the scratch evaluator and the
/// cache's miss path). Uses `bufs.in_cost` / `bufs.comp_per` only.
#[allow(clippy::too_many_arguments)]
pub(crate) fn op_terms(
    plat: &Platform,
    op: &GemmOp,
    part: &Partition,
    flags: OptFlags,
    acts_from_redist: bool,
    skip_store: bool,
    bufs: &mut super::scratch::TermBufs,
) -> OpTerms {
    // ---- input stage
    load_into(plat, op, part, flags.diagonal, !acts_from_redist,
              &mut bufs.in_cost);

    // ---- compute stage (per chiplet, row-major)
    bufs.comp_per.clear();
    for x in 0..plat.xdim {
        for y in 0..plat.ydim {
            bufs.comp_per.push(comp_ns(plat, op, part.px[x], part.py[y]));
        }
    }
    let comp_max = bufs.comp_per.iter().copied().fold(0.0, f64::max);
    let fused = if flags.async_fusion {
        // §5.3: each chiplet starts as soon as its data is ready.
        bufs.comp_per
            .iter()
            .enumerate()
            .map(|(idx, &c)| bufs.in_cost.ready_ns(idx) + c)
            .fold(0.0, f64::max)
    } else {
        0.0
    };

    // ---- output stage (value unused when the store is skipped)
    let store_ns = offload_wall_ns(plat, op, flags.diagonal);

    // ---- energy
    let mut pj = comp_energy_pj(plat, op, part);
    // Off-chip: weights always; activations only when loaded.
    let mut off_bytes = plat.bytes(op.k * op.n);
    if !acts_from_redist {
        off_bytes += plat.bytes(op.m * op.k);
    }
    if !skip_store {
        off_bytes += plat.bytes(op.m * op.n);
        pj += collect_energy_pj(plat, op, part, flags.diagonal);
    }
    pj += offchip_energy_pj(plat, off_bytes);
    pj += load_energy_pj(plat, op, part, flags.diagonal,
                         !acts_from_redist);

    OpTerms {
        in_wall_ns: bufs.in_cost.wall_ns(),
        in_offchip_ns: bufs.in_cost.offchip_ns,
        fused_ns: fused,
        comp_max_ns: comp_max,
        store_ns,
        energy_pj: pj,
    }
}

/// Compose an op's [`OpTerms`] with its incoming redistribution (if any)
/// into the final [`OpCost`]. `incoming` is `Some` exactly when the
/// activations arrived by on-package redistribution.
pub(crate) fn compose_op(
    terms: &OpTerms,
    incoming: Option<&RedistCost>,
    skip_store: bool,
    async_fusion: bool,
) -> OpCost {
    let redist_ns = incoming.map_or(0.0, |r| r.total_ns());
    // Redistribution is a row/column-structured exchange that must
    // finish before compute (it rewrites the operand layout), so it
    // serializes with the fused part.
    let in_comp_ns = if async_fusion {
        redist_ns + terms.fused_ns
    } else {
        redist_ns + terms.in_wall_ns + terms.comp_max_ns
    };
    let out_ns = if skip_store { 0.0 } else { terms.store_ns };
    let mut pj = terms.energy_pj;
    if let Some(r) = incoming {
        pj += r.energy_pj;
    }
    let latency_ns = in_comp_ns + out_ns;
    OpCost {
        in_ns: terms.in_wall_ns + redist_ns,
        comp_ns: terms.comp_max_ns,
        out_ns,
        redistributed_in: incoming.is_some(),
        energy_pj: pj,
        latency_ns,
        redist_ns,
        in_offchip_ns: terms.in_offchip_ns,
    }
}

/// §6.1 "adaptive communication strategy" for one dataflow edge
/// `producer -> consumer`: the redistribution cost when it is cheaper
/// than the store + activation-reload memory round-trip, else `None`.
/// Legality (§5.2, [`Workload::edge_redistributable`]) is the caller's
/// responsibility. Shared by the scratch evaluator and the cache's
/// miss path.
#[allow(clippy::too_many_arguments)]
pub(crate) fn edge_decision(
    plat: &Platform,
    producer: &GemmOp,
    consumer: &GemmOp,
    producer_part: &Partition,
    consumer_part: &Partition,
    collect_col: usize,
    diagonal: bool,
    bufs: &mut super::scratch::TermBufs,
) -> Option<RedistCost> {
    let r = redistribute(plat, producer, producer_part, consumer_part,
                         collect_col);
    let store_wall = offload_wall_ns(plat, producer, diagonal);
    let act_load_extra =
        act_load_extra_ns(plat, consumer, consumer_part, diagonal, bufs);
    // Adopt redistribution when it beats the memory round-trip.
    if r.total_ns() < store_wall + act_load_extra {
        Some(r)
    } else {
        None
    }
}

/// The activation share of a consumer op's load wall time: full load
/// minus weights-only load. What a producer's redistribution saves the
/// consumer (§5.2).
pub(crate) fn act_load_extra_ns(
    plat: &Platform,
    consumer: &GemmOp,
    consumer_part: &Partition,
    diagonal: bool,
    bufs: &mut super::scratch::TermBufs,
) -> f64 {
    load_into(plat, consumer, consumer_part, diagonal, true,
              &mut bufs.in_cost);
    let full = bufs.in_cost.wall_ns();
    load_into(plat, consumer, consumer_part, diagonal, false,
              &mut bufs.in_cost);
    let wonly = bufs.in_cost.wall_ns();
    full - wonly
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{MemKind, SystemType};
    use crate::partition::uniform_allocation;
    use crate::workload::models::alexnet;
    use crate::workload::{GemmOp, Workload};

    fn setup(mem: MemKind) -> Platform {
        Platform::preset(SystemType::A, mem, 4)
    }

    #[test]
    fn cost_is_positive_and_additive() {
        let plat = setup(MemKind::Hbm);
        let wl = alexnet(1);
        let alloc = uniform_allocation(&plat, &wl);
        let c = evaluate(&plat, &wl, &alloc, OptFlags::NONE);
        assert!(c.latency_ns > 0.0 && c.energy_pj > 0.0);
        let sum: f64 = c.per_op.iter().map(|o| o.latency_ns).sum();
        assert!((sum - c.latency_ns).abs() < 1e-6);
        assert_eq!(c.per_op.len(), wl.ops.len());
    }

    #[test]
    fn optimizations_never_hurt_latency() {
        let plat = setup(MemKind::Hbm);
        for wl in crate::workload::models::evaluation_suite(1) {
            let alloc = uniform_allocation(&plat, &wl);
            let base = evaluate(&plat, &wl, &alloc, OptFlags::NONE);
            let opt = evaluate(&plat, &wl, &alloc, OptFlags::ALL);
            assert!(
                opt.latency_ns <= base.latency_ns * 1.0001,
                "{}: opt {} > base {}",
                wl.name,
                opt.latency_ns,
                base.latency_ns
            );
        }
    }

    #[test]
    fn redistribution_fires_on_alexnet_hbm() {
        let plat = setup(MemKind::Hbm);
        let wl = alexnet(1);
        let alloc = uniform_allocation(&plat, &wl);
        let c = evaluate(&plat, &wl, &alloc, OptFlags::ALL);
        let n_redist =
            c.per_op.iter().filter(|o| o.redistributed_in).count();
        assert!(n_redist >= 4, "only {n_redist} redistributed inputs");
    }

    #[test]
    fn edp_is_product() {
        let plat = setup(MemKind::Dram);
        let wl = alexnet(1);
        let alloc = uniform_allocation(&plat, &wl);
        let c = evaluate(&plat, &wl, &alloc, OptFlags::NONE);
        assert!((c.edp() - c.latency_ns * c.energy_pj).abs() < 1.0);
        assert_eq!(c.objective(Objective::Latency), c.latency_ns);
        assert_eq!(c.objective(Objective::Edp), c.edp());
    }

    #[test]
    fn dram_slower_than_hbm() {
        let wl = alexnet(1);
        let plat_h = setup(MemKind::Hbm);
        let plat_d = setup(MemKind::Dram);
        let a_h = uniform_allocation(&plat_h, &wl);
        let c_h = evaluate(&plat_h, &wl, &a_h, OptFlags::NONE);
        let c_d = evaluate(&plat_d, &wl, &a_h, OptFlags::NONE);
        assert!(c_d.latency_ns > c_h.latency_ns);
    }

    #[test]
    fn async_fusion_helps_skewed_partitions() {
        let plat = setup(MemKind::Hbm);
        let wl = Workload::new(
            "w",
            vec![GemmOp::dense("a", 4096, 512, 4096)],
        );
        let alloc = uniform_allocation(&plat, &wl);
        let sync = evaluate(&plat, &wl, &alloc,
                            OptFlags { async_fusion: false, ..OptFlags::NONE });
        let asyn = evaluate(&plat, &wl, &alloc,
                            OptFlags { async_fusion: true, ..OptFlags::NONE });
        assert!(asyn.latency_ns <= sync.latency_ns);
    }

    #[test]
    fn evaluate_into_reuses_scratch_bit_identically() {
        // One scratch + one output reused across workloads of different
        // sizes and flag sets must reproduce fresh `evaluate` exactly.
        let plat = setup(MemKind::Hbm);
        let mut scratch = EvalScratch::default();
        let mut out = CostBreakdown::default();
        for wl in crate::workload::models::evaluation_suite(1) {
            let alloc = uniform_allocation(&plat, &wl);
            for flags in [
                OptFlags::NONE,
                OptFlags::ALL,
                OptFlags { redistribution: true, ..OptFlags::NONE },
                OptFlags { async_fusion: true, ..OptFlags::NONE },
            ] {
                let fresh = evaluate(&plat, &wl, &alloc, flags);
                evaluate_into(&plat, &wl, &alloc, flags, &mut scratch,
                              &mut out);
                assert_eq!(fresh.latency_ns.to_bits(),
                           out.latency_ns.to_bits(), "{}", wl.name);
                assert_eq!(fresh.energy_pj.to_bits(),
                           out.energy_pj.to_bits(), "{}", wl.name);
                assert_eq!(fresh.per_op.len(), out.per_op.len());
                for (a, b) in fresh.per_op.iter().zip(&out.per_op) {
                    assert_eq!(a.latency_ns.to_bits(), b.latency_ns.to_bits());
                    assert_eq!(a.energy_pj.to_bits(), b.energy_pj.to_bits());
                    assert_eq!(a.in_ns.to_bits(), b.in_ns.to_bits());
                    assert_eq!(a.out_ns.to_bits(), b.out_ns.to_bits());
                    assert_eq!(a.redistributed_in, b.redistributed_in);
                }
            }
        }
    }

    #[test]
    fn type_c_cheapest_communication() {
        let wl = alexnet(1);
        let mut lats = Vec::new();
        for ty in SystemType::ALL {
            let plat = Platform::preset(ty, MemKind::Hbm, 4);
            let alloc = uniform_allocation(&plat, &wl);
            lats.push((
                ty,
                evaluate(&plat, &wl, &alloc, OptFlags::NONE).latency_ns,
            ));
        }
        let type_a = lats[0].1;
        let type_c = lats[2].1;
        assert!(type_c < type_a, "C={type_c} A={type_a}");
    }

    #[test]
    fn custom_platform_evaluates_end_to_end() {
        // A non-preset, asymmetric attachment layout runs through the
        // full evaluator with finite positive costs and benefits from
        // the co-optimizations like any preset.
        use crate::platform::MemAttachment;
        let mut spec = Platform::headline().spec().clone();
        spec.name = "asym".into();
        spec.attachments = vec![
            MemAttachment::new(0, 0, 600.0),
            MemAttachment::new(3, 3, 400.0),
        ];
        let plat = Platform::new(spec).unwrap();
        let wl = alexnet(1);
        let alloc = uniform_allocation(&plat, &wl);
        let base = evaluate(&plat, &wl, &alloc, OptFlags::NONE);
        let opt = evaluate(&plat, &wl, &alloc, OptFlags::ALL);
        assert!(base.latency_ns.is_finite() && base.latency_ns > 0.0);
        assert!(opt.latency_ns <= base.latency_ns * 1.0001);
    }
}
