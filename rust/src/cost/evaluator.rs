//! End-to-end cost evaluator (paper §4.2.4, eqs. 3–6): composes the
//! per-op compute and communication costs under the LS scheduling space
//! with the co-optimizations of §5 toggled by [`OptFlags`]. This is the
//! single source of truth scored by the GA, re-scored after MIQP, driven
//! by the figure harnesses, and used by the coordinator's simulated
//! clock.

use crate::config::HwConfig;
use crate::partition::Allocation;
use crate::redistribution::redistribute;
use crate::topology::Topology;
use crate::workload::Workload;

use super::compute::comp_ns;
use super::energy::{
    collect_energy_pj, comp_energy_pj, load_energy_pj, offchip_energy_pj,
};
use super::latency::{load, offload};

/// The §5 co-optimization toggles (ablated in Figure 13).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct OptFlags {
    /// §5.1 diagonal NoP links.
    pub diagonal: bool,
    /// §5.2 on-package redistribution between chained ops.
    pub redistribution: bool,
    /// §5.3 asynchronized (fused load+compute) execution.
    pub async_fusion: bool,
}

impl OptFlags {
    pub const NONE: OptFlags = OptFlags {
        diagonal: false,
        redistribution: false,
        async_fusion: false,
    };
    pub const ALL: OptFlags = OptFlags {
        diagonal: true,
        redistribution: true,
        async_fusion: true,
    };
}

/// Optimization objective (eq. 6 "various metrics").
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Objective {
    Latency,
    Edp,
}

/// Per-op cost decomposition (diagnostics + pipeline task durations).
#[derive(Debug, Clone, Default)]
pub struct OpCost {
    pub in_ns: f64,
    pub comp_ns: f64,
    pub out_ns: f64,
    /// True if the activations arrived by on-package redistribution.
    pub redistributed_in: bool,
    pub energy_pj: f64,
    /// Total latency contribution of this op.
    pub latency_ns: f64,
}

/// End-to-end cost (eq. 3).
#[derive(Debug, Clone, Default)]
pub struct CostBreakdown {
    pub latency_ns: f64,
    pub energy_pj: f64,
    pub per_op: Vec<OpCost>,
}

impl CostBreakdown {
    /// Energy-delay product in pJ·ns.
    pub fn edp(&self) -> f64 {
        self.latency_ns * self.energy_pj
    }

    pub fn objective(&self, obj: Objective) -> f64 {
        match obj {
            Objective::Latency => self.latency_ns,
            Objective::Edp => self.edp(),
        }
    }
}

/// Evaluate `alloc` for `wl` on `hw` under `flags` (eqs. 3–5).
///
/// LS scheduling: ops run in sequence. Per op the stages are
/// `in → comp → out`; §5.3 async fusion merges in+comp per chiplet when
/// the previous boundary allows it. Redistribution (when legal per
/// §5.2 and enabled) replaces the producer's store + consumer's
/// activation load whenever it is the cheaper strategy ("adaptive
/// communication strategy", §6.1).
pub fn evaluate(
    hw: &HwConfig,
    topo: &Topology,
    wl: &Workload,
    alloc: &Allocation,
    flags: OptFlags,
) -> CostBreakdown {
    debug_assert!(alloc.parts.len() == wl.ops.len());
    let n = wl.ops.len();
    let mut out = CostBreakdown::default();
    out.per_op.reserve(n);

    // Decide redistribution per edge (i -> i+1) up front; cache the
    // 3-step cost so the per-op loop never recomputes it (§Perf).
    let mut redist_edge = vec![false; n]; // edge i: ops[i] -> ops[i+1]
    let mut redist_cost = vec![None; n];
    if flags.redistribution {
        for i in 0..n.saturating_sub(1) {
            if !wl.ops[i].redistributable_to(&wl.ops[i + 1]) {
                continue;
            }
            let r = redistribute(
                hw,
                &wl.ops[i],
                &alloc.parts[i],
                &alloc.parts[i + 1],
                alloc.collect_cols[i],
            );
            let store = offload(hw, topo, &wl.ops[i], flags.diagonal);
            let act_load_extra = {
                let full = load(hw, topo, &wl.ops[i + 1],
                                &alloc.parts[i + 1], flags.diagonal, true);
                let wonly = load(hw, topo, &wl.ops[i + 1],
                                 &alloc.parts[i + 1], flags.diagonal, false);
                full.wall_ns() - wonly.wall_ns()
            };
            // Adopt redistribution when it beats the memory round-trip.
            if r.total_ns() < store.wall_ns() + act_load_extra {
                redist_edge[i] = true;
                redist_cost[i] = Some(r);
            }
        }
    }

    for (i, op) in wl.ops.iter().enumerate() {
        let part = &alloc.parts[i];
        let acts_from_redist = i > 0 && redist_edge[i - 1];

        // ---- input stage
        let in_cost = load(hw, topo, op, part, flags.diagonal, !acts_from_redist);
        let incoming = if acts_from_redist {
            redist_cost[i - 1]
        } else {
            None
        };
        let redist_ns = incoming.map_or(0.0, |r| r.total_ns());

        // ---- compute stage (per chiplet)
        let comp_per: Vec<f64> = (0..hw.xdim)
            .flat_map(|x| {
                (0..hw.ydim)
                    .map(move |y| (x, y))
            })
            .map(|(x, y)| comp_ns(hw, op, part.px[x], part.py[y]))
            .collect();
        let comp_max = comp_per.iter().copied().fold(0.0, f64::max);

        // in+comp wall time. Redistribution is a row/column-structured
        // exchange that must finish before compute (it rewrites the
        // operand layout), so it serializes with the fused part.
        let in_comp_ns = if flags.async_fusion {
            // §5.3: each chiplet starts as soon as its data is ready.
            let fused = comp_per
                .iter()
                .enumerate()
                .map(|(idx, &c)| in_cost.ready_ns(idx) + c)
                .fold(0.0, f64::max);
            redist_ns + fused
        } else {
            redist_ns + in_cost.wall_ns() + comp_max
        };

        // ---- output stage
        let skip_store = i + 1 < n && redist_edge[i];
        let out_ns = if skip_store {
            0.0
        } else {
            offload(hw, topo, op, flags.diagonal).wall_ns()
        };

        // ---- energy
        let mut pj = comp_energy_pj(hw, op, part);
        // Off-chip: weights always; activations only when loaded.
        let mut off_bytes = hw.bytes(op.k * op.n);
        if !acts_from_redist {
            off_bytes += hw.bytes(op.m * op.k);
        }
        if !skip_store {
            off_bytes += hw.bytes(op.m * op.n);
            pj += collect_energy_pj(hw, topo, op, part, flags.diagonal);
        }
        pj += offchip_energy_pj(hw, off_bytes);
        pj += load_energy_pj(hw, topo, op, part, flags.diagonal,
                             !acts_from_redist);
        if let Some(r) = incoming {
            pj += r.energy_pj;
        }

        let latency_ns = in_comp_ns + out_ns;
        out.latency_ns += latency_ns;
        out.energy_pj += pj;
        out.per_op.push(OpCost {
            in_ns: in_cost.wall_ns() + redist_ns,
            comp_ns: comp_max,
            out_ns,
            redistributed_in: acts_from_redist,
            energy_pj: pj,
            latency_ns,
        });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{MemKind, SystemType};
    use crate::partition::uniform_allocation;
    use crate::workload::models::alexnet;
    use crate::workload::{GemmOp, Workload};

    fn setup(mem: MemKind) -> (HwConfig, Topology) {
        let hw = HwConfig::paper(SystemType::A, mem, 4);
        let topo = Topology::from_hw(&hw);
        (hw, topo)
    }

    #[test]
    fn cost_is_positive_and_additive() {
        let (hw, topo) = setup(MemKind::Hbm);
        let wl = alexnet(1);
        let alloc = uniform_allocation(&hw, &wl);
        let c = evaluate(&hw, &topo, &wl, &alloc, OptFlags::NONE);
        assert!(c.latency_ns > 0.0 && c.energy_pj > 0.0);
        let sum: f64 = c.per_op.iter().map(|o| o.latency_ns).sum();
        assert!((sum - c.latency_ns).abs() < 1e-6);
        assert_eq!(c.per_op.len(), wl.ops.len());
    }

    #[test]
    fn optimizations_never_hurt_latency() {
        let (hw, topo) = setup(MemKind::Hbm);
        for wl in crate::workload::models::evaluation_suite(1) {
            let alloc = uniform_allocation(&hw, &wl);
            let base = evaluate(&hw, &topo, &wl, &alloc, OptFlags::NONE);
            let opt = evaluate(&hw, &topo, &wl, &alloc, OptFlags::ALL);
            assert!(
                opt.latency_ns <= base.latency_ns * 1.0001,
                "{}: opt {} > base {}",
                wl.name,
                opt.latency_ns,
                base.latency_ns
            );
        }
    }

    #[test]
    fn redistribution_fires_on_alexnet_hbm() {
        let (hw, topo) = setup(MemKind::Hbm);
        let wl = alexnet(1);
        let alloc = uniform_allocation(&hw, &wl);
        let c = evaluate(&hw, &topo, &wl, &alloc, OptFlags::ALL);
        let n_redist =
            c.per_op.iter().filter(|o| o.redistributed_in).count();
        assert!(n_redist >= 4, "only {n_redist} redistributed inputs");
    }

    #[test]
    fn edp_is_product() {
        let (hw, topo) = setup(MemKind::Dram);
        let wl = alexnet(1);
        let alloc = uniform_allocation(&hw, &wl);
        let c = evaluate(&hw, &topo, &wl, &alloc, OptFlags::NONE);
        assert!((c.edp() - c.latency_ns * c.energy_pj).abs() < 1.0);
        assert_eq!(c.objective(Objective::Latency), c.latency_ns);
        assert_eq!(c.objective(Objective::Edp), c.edp());
    }

    #[test]
    fn dram_slower_than_hbm() {
        let wl = alexnet(1);
        let (hw_h, topo_h) = setup(MemKind::Hbm);
        let (hw_d, topo_d) = setup(MemKind::Dram);
        let a_h = uniform_allocation(&hw_h, &wl);
        let c_h = evaluate(&hw_h, &topo_h, &wl, &a_h, OptFlags::NONE);
        let c_d = evaluate(&hw_d, &topo_d, &wl, &a_h, OptFlags::NONE);
        assert!(c_d.latency_ns > c_h.latency_ns);
    }

    #[test]
    fn async_fusion_helps_skewed_partitions() {
        let (hw, topo) = setup(MemKind::Hbm);
        let wl = Workload::new(
            "w",
            vec![GemmOp::dense("a", 4096, 512, 4096)],
        );
        let alloc = uniform_allocation(&hw, &wl);
        let sync = evaluate(&hw, &topo, &wl, &alloc,
                            OptFlags { async_fusion: false, ..OptFlags::NONE });
        let asyn = evaluate(&hw, &topo, &wl, &alloc,
                            OptFlags { async_fusion: true, ..OptFlags::NONE });
        assert!(asyn.latency_ns <= sync.latency_ns);
    }

    #[test]
    fn type_c_cheapest_communication() {
        let wl = alexnet(1);
        let mut lats = Vec::new();
        for ty in SystemType::ALL {
            let hw = HwConfig::paper(ty, MemKind::Hbm, 4);
            let topo = Topology::from_hw(&hw);
            let alloc = uniform_allocation(&hw, &wl);
            lats.push((
                ty,
                evaluate(&hw, &topo, &wl, &alloc, OptFlags::NONE).latency_ns,
            ));
        }
        let type_a = lats[0].1;
        let type_c = lats[2].1;
        assert!(type_c < type_a, "C={type_c} A={type_a}");
    }
}
