//! Zero-dependency scoped-thread parallelism (rayon is unavailable
//! offline).
//!
//! Two shapes cover every parallel site in the repo:
//!
//! * [`par_map`] — stateless indexed map with dynamic work stealing
//!   (atomic counter); used for sweep scenario batches and the figure
//!   harnesses, where item costs vary.
//! * [`par_map_state`] — contiguous-chunk map where each worker owns a
//!   mutable state (a [`crate::cost::CachedEval`] in the GA); states
//!   persist across calls so caches stay warm between generations.
//!
//! Determinism rules (DESIGN.md §Performance architecture): results are
//! always returned in item-index order, workers never share RNG state
//! (all stochastic decisions happen on the caller's thread before the
//! fan-out), and every closure must be a pure function of its `(index,
//! item, state)` arguments — under those rules thread count cannot
//! change a single output bit.

use std::sync::atomic::{AtomicUsize, Ordering};

/// Worker count from the environment: `MCMCOMM_THREADS` if set and
/// positive, else `std::thread::available_parallelism()`.
pub fn auto_threads() -> usize {
    if let Ok(v) = std::env::var("MCMCOMM_THREADS") {
        if let Ok(n) = v.trim().parse::<usize>() {
            if n >= 1 {
                return n;
            }
        }
    }
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

/// Resolve a user-facing thread knob: `0` means "auto".
pub fn resolve_threads(requested: usize) -> usize {
    if requested == 0 {
        auto_threads()
    } else {
        requested
    }
}

/// Parallel indexed map: `out[i] = f(i, &items[i])`, results in index
/// order. Work is stolen from a shared atomic counter, so uneven item
/// costs balance automatically. `threads <= 1` (or fewer than two
/// items) runs inline on the caller's thread.
pub fn par_map<T, R, F>(threads: usize, items: &[T], f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(usize, &T) -> R + Sync,
{
    let n = items.len();
    if threads <= 1 || n <= 1 {
        return items.iter().enumerate().map(|(i, t)| f(i, t)).collect();
    }
    let workers = threads.min(n);
    let counter = AtomicUsize::new(0);
    let fref = &f;
    let cref = &counter;
    let per_worker: Vec<Vec<(usize, R)>> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..workers)
            .map(|_| {
                s.spawn(move || {
                    let mut local = Vec::new();
                    loop {
                        let i = cref.fetch_add(1, Ordering::Relaxed);
                        if i >= n {
                            break;
                        }
                        local.push((i, fref(i, &items[i])));
                    }
                    local
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("par_map worker panicked"))
            .collect()
    });
    // Reassemble in index order regardless of which worker ran what.
    let mut slots: Vec<Option<R>> = (0..n).map(|_| None).collect();
    for chunk in per_worker {
        for (i, r) in chunk {
            slots[i] = Some(r);
        }
    }
    slots
        .into_iter()
        .map(|o| o.expect("par_map missed a slot"))
        .collect()
}

/// Parallel indexed map with one mutable state per worker: items are
/// split into `states.len()` contiguous chunks and worker `w` maps its
/// chunk through `&mut states[w]`. Results come back in item-index
/// order. States persist across calls (warm caches); with a single
/// state the map runs inline on the caller's thread.
///
/// Note the chunking is static: per-item costs should be roughly
/// uniform (true for GA fitness, where every child scores the same
/// workload).
pub fn par_map_state<T, R, S, F>(items: &[T], states: &mut [S], f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    S: Send,
    F: Fn(&mut S, usize, &T) -> R + Sync,
{
    assert!(!states.is_empty(), "par_map_state needs at least one state");
    let n = items.len();
    let workers = states.len().min(n.max(1));
    if workers <= 1 || n <= 1 {
        let s0 = &mut states[0];
        return items.iter().enumerate().map(|(i, t)| f(s0, i, t)).collect();
    }
    let chunk = n.div_ceil(workers);
    let fref = &f;
    let parts: Vec<Vec<R>> = std::thread::scope(|s| {
        let mut handles = Vec::with_capacity(workers);
        for (w, state) in states.iter_mut().take(workers).enumerate() {
            let start = (w * chunk).min(n);
            let end = (start + chunk).min(n);
            let slice = &items[start..end];
            handles.push(s.spawn(move || {
                slice
                    .iter()
                    .enumerate()
                    .map(|(j, t)| fref(state, start + j, t))
                    .collect::<Vec<R>>()
            }));
        }
        handles
            .into_iter()
            .map(|h| h.join().expect("par_map_state worker panicked"))
            .collect()
    });
    let mut out = Vec::with_capacity(n);
    for p in parts {
        out.extend(p);
    }
    out
}

/// In-place sibling of [`par_map_state`]: items are split into
/// `states.len()` contiguous chunks and worker `w` mutates its chunk
/// through `&mut states[w]` — `f(&mut states[w], index, &mut items[index])`.
/// The island GA evolves one island per item with this, so each island
/// keeps hitting the same worker's warm [`crate::cost::CachedEval`]
/// across epochs (the chunk layout is a pure function of `items.len()`
/// and `states.len()`). Same determinism contract as the other shapes:
/// the closure must not read anything that depends on scheduling order.
pub fn par_for_each_state<T, S, F>(items: &mut [T], states: &mut [S], f: F)
where
    T: Send,
    S: Send,
    F: Fn(&mut S, usize, &mut T) + Sync,
{
    assert!(!states.is_empty(), "par_for_each_state needs at least one state");
    let n = items.len();
    let workers = states.len().min(n.max(1));
    if workers <= 1 || n <= 1 {
        let s0 = &mut states[0];
        for (i, t) in items.iter_mut().enumerate() {
            f(s0, i, t);
        }
        return;
    }
    let chunk = n.div_ceil(workers);
    let fref = &f;
    std::thread::scope(|s| {
        let mut handles = Vec::with_capacity(workers);
        for ((w, state), slice) in states
            .iter_mut()
            .take(workers)
            .enumerate()
            .zip(items.chunks_mut(chunk))
        {
            let start = w * chunk;
            handles.push(s.spawn(move || {
                for (j, t) in slice.iter_mut().enumerate() {
                    fref(state, start + j, t);
                }
            }));
        }
        for h in handles {
            h.join().expect("par_for_each_state worker panicked");
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn par_map_preserves_index_order() {
        let items: Vec<usize> = (0..97).collect();
        for threads in [1, 2, 3, 8] {
            let out = par_map(threads, &items, |i, &x| {
                assert_eq!(i, x);
                x * 3
            });
            assert_eq!(out, (0..97).map(|x| x * 3).collect::<Vec<_>>());
        }
    }

    #[test]
    fn par_map_handles_tiny_inputs() {
        let empty: Vec<u32> = Vec::new();
        assert!(par_map(4, &empty, |_, &x| x).is_empty());
        assert_eq!(par_map(4, &[7u32], |_, &x| x + 1), vec![8]);
    }

    #[test]
    fn par_map_state_chunks_and_orders() {
        let items: Vec<u64> = (0..50).collect();
        let mut states = vec![0u64; 4];
        let out = par_map_state(&items, &mut states, |acc, _i, &x| {
            *acc += 1;
            x * 2
        });
        assert_eq!(out, (0..50).map(|x| x * 2).collect::<Vec<_>>());
        // Every item was processed exactly once across the workers.
        assert_eq!(states.iter().sum::<u64>(), 50);
    }

    #[test]
    fn par_map_state_serial_with_one_state() {
        let items = [1u32, 2, 3];
        let mut states = vec![Vec::new()];
        let out = par_map_state(&items, &mut states, |seen: &mut Vec<u32>, _i, &x| {
            seen.push(x);
            x
        });
        assert_eq!(out, vec![1, 2, 3]);
        assert_eq!(states[0], vec![1, 2, 3]);
    }

    #[test]
    fn parallel_equals_serial_bitwise() {
        // The determinism contract: same inputs, any thread count, same
        // bits. Uses an fp-heavy function where evaluation-order bugs
        // would show.
        let items: Vec<f64> = (0..64).map(|i| i as f64 * 0.37 + 1.0).collect();
        let f = |_: usize, &x: &f64| (x.ln() * 3.0_f64).sin() / (x + 0.5);
        let serial = par_map(1, &items, f);
        for threads in [2, 3, 5] {
            let par = par_map(threads, &items, f);
            for (a, b) in serial.iter().zip(&par) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
        }
    }

    #[test]
    fn par_for_each_state_mutates_every_item_once() {
        for workers in [1, 2, 3, 5] {
            let mut items: Vec<u64> = (0..37).collect();
            let mut states = vec![0u64; workers];
            par_for_each_state(&mut items, &mut states, |acc, i, x| {
                assert_eq!(*x, i as u64);
                *x += 100;
                *acc += 1;
            });
            assert_eq!(
                items,
                (0..37).map(|x| x + 100).collect::<Vec<u64>>()
            );
            assert_eq!(states.iter().sum::<u64>(), 37);
        }
    }

    #[test]
    fn par_for_each_state_chunk_layout_is_stable() {
        // Same (n, workers) -> same item-to-worker assignment on every
        // call (the island GA's warm-cache affinity relies on this).
        let assign = |n: usize, workers: usize| {
            let mut items = vec![usize::MAX; n];
            let mut states: Vec<usize> = (0..workers).collect();
            par_for_each_state(&mut items, &mut states, |w, _i, slot| {
                *slot = *w;
            });
            items
        };
        let a = assign(11, 3);
        let b = assign(11, 3);
        assert_eq!(a, b);
        // Contiguous chunks in worker order.
        assert!(a.windows(2).all(|w| w[0] <= w[1]));
    }

    #[test]
    fn resolve_zero_is_auto() {
        assert!(resolve_threads(0) >= 1);
        assert_eq!(resolve_threads(3), 3);
    }
}
