//! Tiny CLI-argument substrate (clap is unavailable offline).
//!
//! Grammar: `mcmcomm <subcommand> [--key value]... [--flag]...`.
//! Unknown keys are an error so typos fail loudly.

use std::collections::BTreeMap;

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    kv: BTreeMap<String, String>,
    flags: Vec<String>,
    known: Vec<String>,
}

impl Args {
    /// Parse raw argv (without the program name).
    pub fn parse(argv: &[String]) -> Result<Args, String> {
        let mut a = Args::default();
        let mut it = argv.iter().peekable();
        if let Some(first) = it.peek() {
            if !first.starts_with("--") {
                a.subcommand = Some(it.next().unwrap().clone());
            }
        }
        while let Some(tok) = it.next() {
            let key = tok
                .strip_prefix("--")
                .ok_or_else(|| format!("expected --option, got '{tok}'"))?;
            match it.peek() {
                Some(v) if !v.starts_with("--") => {
                    a.kv.insert(key.to_string(), it.next().unwrap().clone());
                }
                _ => a.flags.push(key.to_string()),
            }
        }
        Ok(a)
    }

    pub fn from_env() -> Result<Args, String> {
        let argv: Vec<String> = std::env::args().skip(1).collect();
        Self::parse(&argv)
    }

    fn mark(&mut self, key: &str) {
        if !self.known.iter().any(|k| k == key) {
            self.known.push(key.to_string());
        }
    }

    pub fn flag(&mut self, key: &str) -> bool {
        self.mark(key);
        self.flags.iter().any(|f| f == key)
    }

    pub fn get(&mut self, key: &str) -> Option<String> {
        self.mark(key);
        self.kv.get(key).cloned()
    }

    pub fn get_or(&mut self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or_else(|| default.to_string())
    }

    pub fn get_usize(&mut self, key: &str, default: usize) -> Result<usize, String> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| format!("--{key}: expected integer, got '{v}'")),
        }
    }

    pub fn get_f64(&mut self, key: &str, default: f64) -> Result<f64, String> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| format!("--{key}: expected number, got '{v}'")),
        }
    }

    /// Call after all `get`/`flag` lookups: rejects unrecognized options.
    pub fn finish(&self) -> Result<(), String> {
        for k in self.kv.keys().chain(self.flags.iter()) {
            if !self.known.iter().any(|known| known == k) {
                return Err(format!(
                    "unknown option --{k} (known: {})",
                    self.known.join(", ")
                ));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_subcommand_kv_flags() {
        let mut a = Args::parse(&argv("figures --fig 8 --all")).unwrap();
        assert_eq!(a.subcommand.as_deref(), Some("figures"));
        assert_eq!(a.get("fig"), Some("8".into()));
        assert!(a.flag("all"));
        a.finish().unwrap();
    }

    #[test]
    fn defaults_and_numbers() {
        let mut a = Args::parse(&argv("run --gens 40 --pm 0.25")).unwrap();
        assert_eq!(a.get_usize("gens", 10).unwrap(), 40);
        assert_eq!(a.get_f64("pm", 0.1).unwrap(), 0.25);
        assert_eq!(a.get_usize("pop", 64).unwrap(), 64);
    }

    #[test]
    fn unknown_option_rejected() {
        let mut a = Args::parse(&argv("run --oops 1")).unwrap();
        let _ = a.get("gens");
        assert!(a.finish().is_err());
    }

    #[test]
    fn bad_number_is_error() {
        let mut a = Args::parse(&argv("run --gens abc")).unwrap();
        assert!(a.get_usize("gens", 1).is_err());
    }

    #[test]
    fn bare_value_is_error() {
        assert!(Args::parse(&argv("run stray --x 1")).is_err());
    }
}
