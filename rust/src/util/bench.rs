//! Micro-benchmark substrate (criterion is unavailable offline).
//!
//! Used by every `rust/benches/*.rs` target (built with `harness = false`)
//! and by the §Perf pass. Methodology: warmup, then fixed-count timed
//! batches; reports min/median/mean and a robust throughput line. Figures
//! benches also use `Reporter` to print the paper-shaped tables.

use std::time::{Duration, Instant};

#[derive(Debug, Clone)]
pub struct BenchStats {
    pub name: String,
    pub iters: u64,
    pub min: Duration,
    pub median: Duration,
    pub mean: Duration,
}

impl BenchStats {
    pub fn report(&self) {
        println!(
            "bench {:<44} iters {:>6}  min {:>12}  median {:>12}  mean {:>12}",
            self.name,
            self.iters,
            fmt_dur(self.min),
            fmt_dur(self.median),
            fmt_dur(self.mean)
        );
    }
}

pub fn fmt_dur(d: Duration) -> String {
    let ns = d.as_nanos() as f64;
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Time `f` adaptively: target ~`budget` of total measurement after a
/// 10%-budget warmup. Returns per-iteration stats over >= 10 samples.
pub fn bench<F: FnMut()>(name: &str, budget: Duration, mut f: F) -> BenchStats {
    // Warmup + calibration: how many iters fit in budget/10?
    let cal_start = Instant::now();
    let mut cal_iters = 0u64;
    while cal_start.elapsed() < budget / 10 || cal_iters == 0 {
        f();
        cal_iters += 1;
        if cal_iters > 1_000_000 {
            break;
        }
    }
    let per_iter = cal_start.elapsed() / cal_iters.max(1) as u32;

    // Sample loop: >=10 samples, each of batch size that keeps sample
    // duration ~budget/20.
    let samples = 10usize;
    let batch = ((budget.as_nanos() / 20).max(1) as u64
        / per_iter.as_nanos().max(1) as u64)
        .clamp(1, 1_000_000);
    let mut times: Vec<Duration> = Vec::with_capacity(samples);
    for _ in 0..samples {
        let t0 = Instant::now();
        for _ in 0..batch {
            f();
        }
        times.push(t0.elapsed() / batch as u32);
    }
    times.sort();
    let min = times[0];
    let median = times[times.len() / 2];
    let mean = times.iter().sum::<Duration>() / times.len() as u32;
    let stats = BenchStats {
        name: name.to_string(),
        iters: batch * samples as u64,
        min,
        median,
        mean,
    };
    stats.report();
    stats
}

/// Prevent the optimizer from discarding a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Table printer for figure harnesses: aligned columns, normalized rows.
pub struct Reporter {
    pub title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Reporter {
    pub fn new(title: &str, header: &[&str]) -> Self {
        Reporter {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells);
    }

    pub fn print(&self) {
        let mut widths: Vec<usize> =
            self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        println!("\n== {} ==", self.title);
        let line = |cells: &[String]| {
            let mut s = String::new();
            for (i, c) in cells.iter().enumerate() {
                s.push_str(&format!("{:<w$}  ", c, w = widths[i]));
            }
            println!("{}", s.trim_end());
        };
        line(&self.header);
        println!("{}", "-".repeat(widths.iter().sum::<usize>() + 2 * widths.len()));
        for row in &self.rows {
            line(row);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let s = bench("noop-ish", Duration::from_millis(30), || {
            black_box((0..100).sum::<u64>());
        });
        assert!(s.iters > 0);
        assert!(s.min <= s.median && s.median <= s.mean * 3);
    }

    #[test]
    fn fmt_dur_units() {
        assert!(fmt_dur(Duration::from_nanos(5)).ends_with("ns"));
        assert!(fmt_dur(Duration::from_micros(5)).ends_with("µs"));
        assert!(fmt_dur(Duration::from_millis(5)).ends_with("ms"));
        assert!(fmt_dur(Duration::from_secs(5)).ends_with("s"));
    }

    #[test]
    #[should_panic(expected = "arity")]
    fn reporter_rejects_ragged_rows() {
        let mut r = Reporter::new("t", &["a", "b"]);
        r.row(vec!["1".into()]);
    }
}
