//! Property-testing substrate (proptest is unavailable offline).
//!
//! `forall(cases, seed, gen, prop)` draws `cases` random inputs from `gen`
//! and checks `prop`; on failure it retries with progressively "smaller"
//! regenerated cases (seed-sweep shrinking: cheap, deterministic, and good
//! enough for the integer/config domains in this repo) and panics with the
//! reproducing seed. Used by `rust/tests/properties.rs` for L3 invariants
//! (routing, partitioning, scheduling, cost monotonicity).

use crate::util::rng::Pcg;

/// Run `prop` on `cases` inputs drawn by `gen`. Panics with a reproducer
/// seed on the first failure.
pub fn forall<T, G, P>(cases: u64, seed: u64, mut gen: G, mut prop: P)
where
    T: std::fmt::Debug,
    G: FnMut(&mut Pcg) -> T,
    P: FnMut(&T) -> Result<(), String>,
{
    for case in 0..cases {
        let case_seed = seed ^ (case.wrapping_mul(0x9e3779b97f4a7c15));
        let mut rng = Pcg::seeded(case_seed);
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property failed (case {case}, reproduce with seed \
                 {case_seed:#x}):\n  input: {input:?}\n  reason: {msg}"
            );
        }
    }
}

/// Assert helper returning the Result shape `forall` expects.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)+) => {
        if !$cond {
            return Err(format!($($fmt)+));
        }
    };
}

/// Generator helpers for common domains.
pub mod gens {
    use crate::util::rng::Pcg;

    /// A vector of `len` integers in `[lo, hi]`.
    pub fn int_vec(rng: &mut Pcg, len: usize, lo: i64, hi: i64) -> Vec<i64> {
        (0..len).map(|_| rng.range_i64(lo, hi)).collect()
    }

    /// A composition of `total` into `parts` non-negative integers.
    pub fn composition(rng: &mut Pcg, total: usize, parts: usize) -> Vec<usize> {
        assert!(parts > 0);
        let mut cuts: Vec<usize> =
            (0..parts - 1).map(|_| rng.range_usize(0, total)).collect();
        cuts.sort_unstable();
        let mut out = Vec::with_capacity(parts);
        let mut prev = 0;
        for c in cuts {
            out.push(c - prev);
            prev = c;
        }
        out.push(total - prev);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_completes() {
        forall(200, 1, |r| r.range_i64(0, 100), |x| {
            prop_assert!(*x >= 0 && *x <= 100, "out of range: {x}");
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_reports_seed() {
        forall(200, 2, |r| r.range_i64(0, 100), |x| {
            prop_assert!(*x < 95, "too big: {x}");
            Ok(())
        });
    }

    #[test]
    fn composition_sums_to_total() {
        let mut rng = Pcg::seeded(3);
        for _ in 0..100 {
            let parts = rng.range_usize(1, 8);
            let total = rng.range_usize(0, 500);
            let c = gens::composition(&mut rng, total, parts);
            assert_eq!(c.len(), parts);
            assert_eq!(c.iter().sum::<usize>(), total);
        }
    }
}
