//! Stable content hashing (FNV-1a 64) for fingerprints.
//!
//! The serving layer keys its concurrent plan cache by *content*
//! fingerprints of the platform description and the workload graph, so
//! the hash must be deterministic across runs, processes and machines —
//! which rules out `std`'s randomly-seeded SipHash. FNV-1a over an
//! explicit byte stream is the zero-dependency standard here (the
//! cached evaluator already uses the same function for its in-process
//! gene keys, where stability across runs does not matter).
//!
//! Every multi-byte integer is folded in little-endian order, and
//! variable-length sequences must be preceded by their length (see
//! [`Fnv1a::write_len`]) so that `["ab","c"]` and `["a","bc"]` hash
//! differently.

/// Streaming FNV-1a 64-bit hasher.
#[derive(Debug, Clone)]
pub struct Fnv1a {
    state: u64,
}

impl Default for Fnv1a {
    fn default() -> Self {
        Fnv1a { state: 0xcbf29ce484222325 }
    }
}

impl Fnv1a {
    pub fn new() -> Fnv1a {
        Fnv1a::default()
    }

    pub fn write(&mut self, bytes: &[u8]) {
        let mut h = self.state;
        for &b in bytes {
            h ^= b as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
        self.state = h;
    }

    pub fn write_u8(&mut self, v: u8) {
        self.write(&[v]);
    }

    pub fn write_u64(&mut self, v: u64) {
        self.write(&v.to_le_bytes());
    }

    pub fn write_usize(&mut self, v: usize) {
        self.write_u64(v as u64);
    }

    pub fn write_bool(&mut self, v: bool) {
        self.write_u8(u8::from(v));
    }

    /// IEEE-754 bit pattern of an `f64` (bit-identical inputs hash
    /// identically; `-0.0` and `0.0` intentionally differ).
    pub fn write_f64(&mut self, v: f64) {
        self.write_u64(v.to_bits());
    }

    /// Length prefix for a variable-length sequence (call before
    /// folding the elements).
    pub fn write_len(&mut self, n: usize) {
        self.write_usize(n);
    }

    /// Length-prefixed string content.
    pub fn write_str(&mut self, s: &str) {
        self.write_len(s.len());
        self.write(s.as_bytes());
    }

    pub fn finish(&self) -> u64 {
        self.state
    }
}

/// One-shot convenience over a byte slice.
pub fn fnv1a_64(bytes: &[u8]) -> u64 {
    let mut h = Fnv1a::new();
    h.write(bytes);
    h.finish()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_vectors() {
        // FNV-1a 64 reference values.
        assert_eq!(fnv1a_64(b""), 0xcbf29ce484222325);
        assert_eq!(fnv1a_64(b"a"), 0xaf63dc4c8601ec8c);
        assert_eq!(fnv1a_64(b"foobar"), 0x85944171f73967e8);
    }

    #[test]
    fn streaming_equals_oneshot() {
        let mut h = Fnv1a::new();
        h.write(b"foo");
        h.write(b"bar");
        assert_eq!(h.finish(), fnv1a_64(b"foobar"));
    }

    #[test]
    fn length_prefix_disambiguates() {
        let mut a = Fnv1a::new();
        a.write_str("ab");
        a.write_str("c");
        let mut b = Fnv1a::new();
        b.write_str("a");
        b.write_str("bc");
        assert_ne!(a.finish(), b.finish());
    }

    #[test]
    fn integer_folding_is_order_sensitive() {
        let mut a = Fnv1a::new();
        a.write_u64(1);
        a.write_u64(2);
        let mut b = Fnv1a::new();
        b.write_u64(2);
        b.write_u64(1);
        assert_ne!(a.finish(), b.finish());
    }
}
