//! Minimal error substrate (anyhow is unavailable offline): a
//! context-chaining error type plus the `err!` / `bail!` / `ensure!`
//! macros and a `Context` extension trait.
//!
//! Semantics mirror the anyhow conventions the repo grew up with:
//! `Display` prints the outermost context message, the alternate form
//! (`{:#}`) prints the whole chain outermost-first, and `?` converts any
//! `std::error::Error` automatically. Like `anyhow::Error`, [`Error`]
//! deliberately does **not** implement `std::error::Error` — that is
//! what makes the blanket `From` impl coherent.

use std::fmt;

/// A chain of human-readable messages, outermost context first.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Build an error from a single message.
    pub fn msg(m: impl fmt::Display) -> Error {
        Error { chain: vec![m.to_string()] }
    }

    /// Prepend a higher-level context message.
    pub fn push_context(mut self, m: impl fmt::Display) -> Error {
        self.chain.insert(0, m.to_string());
        self
    }

    /// The messages, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.join(": "))
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Self {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// Crate-wide result alias.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to errors (`anyhow::Context` shape).
pub trait Context<T> {
    fn context(self, msg: impl fmt::Display) -> Result<T>;
    fn with_context<D: fmt::Display>(self, f: impl FnOnce() -> D)
        -> Result<T>;
}

impl<T, E> Context<T> for std::result::Result<T, E>
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn context(self, msg: impl fmt::Display) -> Result<T> {
        self.map_err(|e| Error::from(e).push_context(msg))
    }

    fn with_context<D: fmt::Display>(
        self,
        f: impl FnOnce() -> D,
    ) -> Result<T> {
        self.map_err(|e| Error::from(e).push_context(f()))
    }
}

impl<T> Context<T> for std::result::Result<T, Error> {
    fn context(self, msg: impl fmt::Display) -> Result<T> {
        self.map_err(|e| e.push_context(msg))
    }

    fn with_context<D: fmt::Display>(
        self,
        f: impl FnOnce() -> D,
    ) -> Result<T> {
        self.map_err(|e| e.push_context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context(self, msg: impl fmt::Display) -> Result<T> {
        self.ok_or_else(|| Error::msg(msg))
    }

    fn with_context<D: fmt::Display>(
        self,
        f: impl FnOnce() -> D,
    ) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! err {
    ($($arg:tt)*) => {
        $crate::util::error::Error::msg(format!($($arg)*))
    };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::err!($($arg)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn display_prints_outermost_context() {
        let e: Error =
            Err::<(), _>(io_err()).context("reading manifest").unwrap_err();
        assert_eq!(e.to_string(), "reading manifest");
        assert_eq!(format!("{e:#}"), "reading manifest: gone");
    }

    #[test]
    fn with_context_chains_on_crate_errors() {
        let base: Result<()> = Err(Error::msg("inner"));
        let e = base.with_context(|| format!("outer {}", 1)).unwrap_err();
        assert_eq!(e.to_string(), "outer 1");
        assert_eq!(e.chain().collect::<Vec<_>>(), vec!["outer 1", "inner"]);
    }

    #[test]
    fn macros_build_errors() {
        fn f(x: usize) -> Result<usize> {
            ensure!(x < 10, "x too big: {x}");
            if x == 3 {
                bail!("three is right out");
            }
            Ok(x)
        }
        assert_eq!(f(2).unwrap(), 2);
        assert_eq!(f(3).unwrap_err().to_string(), "three is right out");
        assert_eq!(f(12).unwrap_err().to_string(), "x too big: 12");
    }

    #[test]
    fn option_context() {
        let none: Option<usize> = None;
        assert_eq!(
            none.context("missing value").unwrap_err().to_string(),
            "missing value"
        );
    }
}
