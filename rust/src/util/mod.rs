//! Offline substrates: everything crates.io would normally provide.

pub mod bench;
pub mod cli;
pub mod error;
pub mod hash;
pub mod json;
pub mod math;
pub mod par;
pub mod propcheck;
pub mod rng;
