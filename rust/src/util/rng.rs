//! Deterministic PRNG substrate (PCG-XSH-RR 64/32 + helpers).
//!
//! crates.io `rand` is unavailable offline, and the GA / property-test /
//! netsim layers all need a seedable, fast, statistically solid generator,
//! so we implement PCG32 (O'Neill 2014) from scratch. All stochastic code
//! in this repo (GA, propcheck, workload generators, serve example) takes
//! an explicit `Pcg` so every experiment is reproducible from a seed.

/// PCG-XSH-RR 64/32: 64-bit LCG state, 32-bit xorshift-rotated output.
#[derive(Debug, Clone)]
pub struct Pcg {
    state: u64,
    inc: u64,
}

const PCG_MULT: u64 = 6364136223846793005;

impl Pcg {
    /// Seed with a stream id; distinct `(seed, stream)` pairs are
    /// independent sequences.
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg { state: 0, inc: (stream << 1) | 1 };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    /// Convenience single-arg constructor (stream 0xda3e39cb94b95bdb).
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0xda3e39cb94b95bdb)
    }

    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in `[0, bound)` without modulo bias (Lemire rejection).
    pub fn below(&mut self, bound: u64) -> u64 {
        assert!(bound > 0, "below(0)");
        // 64-bit variant of Lemire's nearly-divisionless method.
        loop {
            let x = self.next_u64();
            let m = (x as u128) * (bound as u128);
            let lo = m as u64;
            if lo >= bound || lo >= bound.wrapping_neg() % bound {
                return (m >> 64) as u64;
            }
        }
    }

    /// Uniform integer in the inclusive range `[lo, hi]`.
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo <= hi, "range_i64: {lo} > {hi}");
        let span = (hi - lo) as u64 + 1;
        lo + self.below(span) as i64
    }

    pub fn range_usize(&mut self, lo: usize, hi: usize) -> usize {
        self.range_i64(lo as i64, hi as i64) as usize
    }

    /// Uniform f64 in `[0, 1)` with 53 bits of precision.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// `true` with probability `p`.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller (one value per call; simple > fast).
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Fisher–Yates in-place shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Pick a uniformly random element reference.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        assert!(!xs.is_empty(), "choose from empty slice");
        &xs[self.below(xs.len() as u64) as usize]
    }

    /// Roulette-wheel index selection proportional to `weights`.
    pub fn weighted_index(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0, "weighted_index: non-positive total");
        let mut t = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            t -= w;
            if t <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Pcg::seeded(42);
        let mut b = Pcg::seeded(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Pcg::seeded(1);
        let mut b = Pcg::seeded(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn below_is_in_bounds_and_hits_all() {
        let mut rng = Pcg::seeded(7);
        let mut seen = [false; 5];
        for _ in 0..1000 {
            let v = rng.below(5) as usize;
            assert!(v < 5);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn f64_unit_interval_mean() {
        let mut rng = Pcg::seeded(9);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| rng.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn range_inclusive_endpoints() {
        let mut rng = Pcg::seeded(3);
        let (mut lo_hit, mut hi_hit) = (false, false);
        for _ in 0..1000 {
            match rng.range_i64(-2, 2) {
                -2 => lo_hit = true,
                2 => hi_hit = true,
                v => assert!((-2..=2).contains(&v)),
            }
        }
        assert!(lo_hit && hi_hit);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg::seeded(5);
        let mut v: Vec<u32> = (0..50).collect();
        rng.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg::seeded(11);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / n as f64;
        assert!(mean.abs() < 0.03, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn weighted_index_prefers_heavy() {
        let mut rng = Pcg::seeded(13);
        let w = [1.0, 0.0, 9.0];
        let mut counts = [0usize; 3];
        for _ in 0..2000 {
            counts[rng.weighted_index(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        assert!(counts[2] > counts[0] * 5);
    }
}
