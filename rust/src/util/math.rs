//! Small numeric helpers shared across the cost model and eval harness.

/// Ceiling division for positive integers.
#[inline]
pub fn ceil_div(a: usize, b: usize) -> usize {
    assert!(b > 0, "ceil_div by zero");
    a.div_ceil(b)
}

/// Geometric mean of strictly positive values (paper reports geo-means).
pub fn geomean(xs: &[f64]) -> f64 {
    assert!(!xs.is_empty(), "geomean of empty slice");
    let log_sum: f64 = xs
        .iter()
        .map(|&x| {
            assert!(x > 0.0, "geomean needs positive values, got {x}");
            x.ln()
        })
        .sum();
    (log_sum / xs.len() as f64).exp()
}

pub fn mean(xs: &[f64]) -> f64 {
    assert!(!xs.is_empty());
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Speedup of `base` over `opt` expressed as the paper does ("X% speedup"
/// = base/opt - 1).
pub fn speedup_pct(base: f64, opt: f64) -> f64 {
    (base / opt - 1.0) * 100.0
}

/// Round `v` up to the next power of two, with a floor.
pub fn next_pow2_at_least(v: usize, floor: usize) -> usize {
    v.max(floor).next_power_of_two()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ceil_div_cases() {
        assert_eq!(ceil_div(0, 4), 0);
        assert_eq!(ceil_div(1, 4), 1);
        assert_eq!(ceil_div(4, 4), 1);
        assert_eq!(ceil_div(5, 4), 2);
    }

    #[test]
    fn geomean_known() {
        assert!((geomean(&[1.0, 4.0]) - 2.0).abs() < 1e-12);
        assert!((geomean(&[2.0, 2.0, 2.0]) - 2.0).abs() < 1e-12);
    }

    #[test]
    #[should_panic]
    fn geomean_rejects_nonpositive() {
        geomean(&[1.0, 0.0]);
    }

    #[test]
    fn speedup_pct_known() {
        assert!((speedup_pct(2.0, 1.0) - 100.0).abs() < 1e-12);
        assert!((speedup_pct(1.45, 1.0) - 45.0).abs() < 1e-9);
    }

    #[test]
    fn pow2_rounding() {
        assert_eq!(next_pow2_at_least(1, 16), 16);
        assert_eq!(next_pow2_at_least(16, 16), 16);
        assert_eq!(next_pow2_at_least(17, 16), 32);
        assert_eq!(next_pow2_at_least(200, 16), 256);
    }
}
