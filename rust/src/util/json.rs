//! Minimal JSON substrate (parser + writer).
//!
//! serde is unavailable offline; the only JSON we need is the artifact
//! manifest written by `python/compile/aot.py` and the metric reports the
//! coordinator emits, so a small recursive-descent parser is the right
//! size. Supports the full JSON grammar except `\u` surrogate pairs
//! outside the BMP being validated pairwise (we decode them best-effort).

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value. Numbers are kept as f64 (ints in our manifests
/// are small), objects use BTreeMap for deterministic iteration.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(src: &str) -> Result<Json, JsonError> {
        let mut p = Parser { src: src.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.src.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Writer: compact canonical encoding (sorted keys via BTreeMap).
    pub fn encode(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, e) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    e.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Build an object from key/value pairs — ergonomic report construction.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

#[derive(Debug, Clone, PartialEq)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    src: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.src.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let c = self.peek();
        if c.is_some() {
            self.pos += 1;
        }
        c
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.bump() == Some(c) {
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.src[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a value")),
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x80 => out.push(c as char),
                Some(c) => {
                    // Re-decode multi-byte UTF-8 from the raw slice.
                    let start = self.pos - 1;
                    let width = match c {
                        0xc0..=0xdf => 2,
                        0xe0..=0xef => 3,
                        _ => 4,
                    };
                    let end = (start + width).min(self.src.len());
                    let s = std::str::from_utf8(&self.src[start..end])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    out.push_str(s);
                    self.pos = end;
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.bump().ok_or_else(|| self.err("eof in \\u"))?;
            v = v * 16
                + (c as char)
                    .to_digit(16)
                    .ok_or_else(|| self.err("bad hex digit"))?;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let s = std::str::from_utf8(&self.src[start..self.pos]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(v)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(m)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" -12.5e2 ").unwrap(), Json::Num(-1250.0));
        assert_eq!(
            Json::parse("\"a\\nb\"").unwrap(),
            Json::Str("a\nb".into())
        );
    }

    #[test]
    fn parses_manifest_shape() {
        let src = r#"{"version": 1, "buckets": [{"name": "g", "m": 16,
                      "relu": false}]}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.get("version").unwrap().as_usize(), Some(1));
        let b = &v.get("buckets").unwrap().as_arr().unwrap()[0];
        assert_eq!(b.get("name").unwrap().as_str(), Some("g"));
        assert_eq!(b.get("relu").unwrap().as_bool(), Some(false));
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"a":[1,2.5,"x",null,true],"b":{"c":-3}}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(Json::parse(&v.encode()).unwrap(), v);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn unicode_and_escapes() {
        let v = Json::parse(r#""é café ☕""#).unwrap();
        assert_eq!(v.as_str(), Some("é café ☕"));
    }

    #[test]
    fn encode_escapes_control() {
        let v = Json::Str("a\"b\\c\nd".into());
        assert_eq!(v.encode(), r#""a\"b\\c\nd""#);
    }
}
