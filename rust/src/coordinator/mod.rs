//! Layer-3 coordinator: turns an optimized schedule into execution —
//! the plan builder, the simulated-clock executor with real PJRT
//! numerics, and the threaded batching server.

pub mod executor;
pub mod plan;
pub mod server;

pub use executor::{Executor, RunReport};
pub use plan::{build_plan, Chunk, ExecutionPlan};
pub use server::{Client, Response, Server, ServerStats};
