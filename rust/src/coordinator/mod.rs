//! Layer-3 coordinator: turns an optimized schedule into execution —
//! the plan builder and the simulated-clock executor with real PJRT
//! numerics. The threaded batching server grew into the full serving
//! subsystem ([`crate::serving`]); the old paths re-export from there.

pub mod executor;
pub mod plan;

/// The serving loop moved to [`crate::serving::server`]; this alias
/// keeps `coordinator::server::*` paths working.
pub use crate::serving::server;
pub use crate::serving::server::{Client, Response, Server, ServerStats};

pub use executor::{Executor, RunReport};
pub use plan::{build_plan, Chunk, ExecutionPlan};
