//! Threaded serving loop: the Layer-3 event loop that batches inference
//! requests and dispatches them through the executor — the "real-time
//! applications" framing of Figure 1 (self-driving / autonomous-system
//! inference on an edge MCM).
//!
//! tokio is unavailable offline; std threads + mpsc channels implement
//! the same leader/worker shape: one batcher thread owns the (single)
//! simulated MCM, request producers are arbitrary threads.

use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// One inference request.
#[derive(Debug)]
pub struct Request {
    pub id: u64,
    pub submitted: Instant,
    reply: mpsc::Sender<Response>,
}

/// Completion record returned to the client.
#[derive(Debug, Clone)]
pub struct Response {
    pub id: u64,
    /// Modeled MCM latency for the batch this request rode in (ns).
    pub modeled_batch_ns: f64,
    /// Modeled per-sample latency with pipelining (ns).
    pub modeled_per_sample_ns: f64,
    /// Host-side queueing + execution time.
    pub host_latency: Duration,
    pub batch_size: usize,
}

/// Server statistics.
#[derive(Debug, Clone, Default)]
pub struct ServerStats {
    pub served: u64,
    pub batches: u64,
    pub max_batch: usize,
}

/// Batch executor callback: given a batch size, return (modeled batch
/// ns, modeled per-sample ns). Kept as a callback so the server logic is
/// testable without PJRT. The non-`Send` variant is produced *inside*
/// the batcher thread by a [`RunnerFactory`] — the PJRT client holds
/// `Rc`s and must never cross threads.
pub type BatchRunner = Box<dyn FnMut(usize) -> (f64, f64) + Send>;
pub type LocalBatchRunner = Box<dyn FnMut(usize) -> (f64, f64)>;
pub type RunnerFactory = Box<dyn FnOnce() -> LocalBatchRunner + Send>;

/// Client handle.
#[derive(Clone)]
pub struct Client {
    tx: mpsc::Sender<Request>,
    next_id: Arc<Mutex<u64>>,
}

impl Client {
    /// Submit a request; returns the receiver for its response.
    pub fn submit(&self) -> mpsc::Receiver<Response> {
        let (rtx, rrx) = mpsc::channel();
        let id = {
            let mut g = self.next_id.lock().unwrap();
            *g += 1;
            *g
        };
        self.tx
            .send(Request { id, submitted: Instant::now(), reply: rtx })
            .expect("server stopped");
        rrx
    }
}

/// The batching server. Collects up to `max_batch` requests or waits at
/// most `max_wait`, then runs the batch.
pub struct Server {
    handle: Option<JoinHandle<ServerStats>>,
    tx: Option<mpsc::Sender<Request>>,
    next_id: Arc<Mutex<u64>>,
}

impl Server {
    pub fn start(max_batch: usize, max_wait: Duration,
                 mut runner: BatchRunner) -> Server {
        Self::start_factory(
            max_batch,
            max_wait,
            Box::new(move || {
                Box::new(move |bsz| runner(bsz)) as LocalBatchRunner
            }),
        )
    }

    /// Start with a factory that builds the runner *on the batcher
    /// thread* (required for PJRT-backed runners, which are not `Send`).
    pub fn start_factory(max_batch: usize, max_wait: Duration,
                         factory: RunnerFactory) -> Server {
        assert!(max_batch >= 1);
        let (tx, rx) = mpsc::channel::<Request>();
        let handle = std::thread::spawn(move || {
            let mut runner = factory();
            let mut stats = ServerStats::default();
            loop {
                // Block for the first request of a batch.
                let first = match rx.recv() {
                    Ok(r) => r,
                    Err(_) => break, // all clients gone
                };
                let mut batch = vec![first];
                let deadline = Instant::now() + max_wait;
                while batch.len() < max_batch {
                    let now = Instant::now();
                    if now >= deadline {
                        break;
                    }
                    match rx.recv_timeout(deadline - now) {
                        Ok(r) => batch.push(r),
                        Err(mpsc::RecvTimeoutError::Timeout) => break,
                        Err(mpsc::RecvTimeoutError::Disconnected) => break,
                    }
                }
                let bsz = batch.len();
                let (batch_ns, per_sample_ns) = runner(bsz);
                stats.batches += 1;
                stats.served += bsz as u64;
                stats.max_batch = stats.max_batch.max(bsz);
                for req in batch {
                    let _ = req.reply.send(Response {
                        id: req.id,
                        modeled_batch_ns: batch_ns,
                        modeled_per_sample_ns: per_sample_ns,
                        host_latency: req.submitted.elapsed(),
                        batch_size: bsz,
                    });
                }
            }
            stats
        });
        Server {
            handle: Some(handle),
            tx: Some(tx),
            next_id: Arc::new(Mutex::new(0)),
        }
    }

    pub fn client(&self) -> Client {
        Client {
            tx: self.tx.as_ref().expect("server running").clone(),
            next_id: self.next_id.clone(),
        }
    }

    /// Drop the intake side and join the batcher.
    pub fn shutdown(mut self) -> ServerStats {
        drop(self.tx.take());
        self.handle.take().unwrap().join().expect("batcher panicked")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fake_runner() -> BatchRunner {
        Box::new(|bsz| {
            let batch_ns = 100.0 + 10.0 * bsz as f64;
            (batch_ns, batch_ns / bsz as f64)
        })
    }

    #[test]
    fn serves_all_requests() {
        let server = Server::start(4, Duration::from_millis(5), fake_runner());
        let client = server.client();
        let waiters: Vec<_> = (0..10).map(|_| client.submit()).collect();
        let mut ids = Vec::new();
        for w in waiters {
            let resp = w.recv().unwrap();
            assert!(resp.batch_size >= 1 && resp.batch_size <= 4);
            ids.push(resp.id);
        }
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), 10);
        drop(client);
        let stats = server.shutdown();
        assert_eq!(stats.served, 10);
        assert!(stats.batches >= 3); // 10 requests, batch cap 4
    }

    #[test]
    fn batching_amortizes_per_sample_latency() {
        let server = Server::start(8, Duration::from_millis(30), fake_runner());
        let client = server.client();
        // Submit a burst so they batch together.
        let waiters: Vec<_> = (0..8).map(|_| client.submit()).collect();
        let resps: Vec<_> =
            waiters.into_iter().map(|w| w.recv().unwrap()).collect();
        let batched = resps.iter().map(|r| r.batch_size).max().unwrap();
        assert!(batched >= 2, "burst should have batched, got {batched}");
        for r in &resps {
            if r.batch_size > 1 {
                assert!(r.modeled_per_sample_ns < r.modeled_batch_ns);
            }
        }
        drop(client);
        server.shutdown();
    }

    #[test]
    fn shutdown_returns_stats() {
        let server = Server::start(2, Duration::from_millis(1), fake_runner());
        let client = server.client();
        client.submit().recv().unwrap();
        drop(client);
        let stats = server.shutdown();
        assert_eq!(stats.served, 1);
    }
}
