//! Simulated-clock executor: runs a scheduled workload with **real
//! numerics** — every chiplet chunk is executed through the GEMM
//! runtime (PJRT or the interpreter backend) — while the analytical
//! evaluator advances the modeled MCM clock. Output correctness is
//! checked against a plain CPU reference, proving all three layers
//! compose.
//!
//! Front door: [`Executor::from_plan`] consumes an engine
//! ([`Scenario`], [`Plan`]) pair; [`Executor::new`] remains the
//! low-level borrowed-parts constructor.

use crate::cost::evaluator::{CostBreakdown, OptFlags};
use crate::engine::{Plan, Scenario};
use crate::partition::Allocation;
use crate::platform::Platform;
use crate::runtime::pjrt::{reference_gemm, GemmRuntime};
use crate::util::error::{Context, Result};
use crate::util::rng::Pcg;
use crate::workload::Workload;

use super::plan::{build_plan, ExecutionPlan};

/// Result of one end-to-end run.
#[derive(Debug)]
pub struct RunReport {
    /// Analytical (modeled MCM) cost of the run.
    pub modeled: CostBreakdown,
    /// Discrete-event makespan of the same plan
    /// (`netsim::sim::simulate_plan`, conformance mode) — the
    /// independent cross-check on `modeled.latency_ns`. Populated on
    /// verification runs (`run(.., true)`); `None` on fast-path runs or
    /// if the plan could not be lowered.
    pub simulated_ns: Option<f64>,
    /// Host wall time actually spent executing chunks.
    pub host_wall: std::time::Duration,
    /// Runtime chunk executions performed.
    pub chunks_executed: u64,
    /// Max |runtime - reference| over all op outputs.
    pub max_abs_err: f32,
    /// Final op output (row-major M x N).
    pub output: Vec<f32>,
}

/// Deterministic synthetic weights/inputs (the "tiny-corpus" driver).
pub fn random_matrix(rng: &mut Pcg, rows: usize, cols: usize) -> Vec<f32> {
    (0..rows * cols)
        .map(|_| (rng.normal() as f32) * 0.25)
        .collect()
}

/// Reshape `src` (rows0 x cols0, row-major) into rows1 x cols1 by
/// wrap-around replication — the deterministic stand-in for the im2col /
/// pooling data reshuffles between layers whose dims do not match
/// exactly (documented in DESIGN.md §Substitutions). Numerical
/// correctness per op is still exact: both backends see identical
/// operands.
pub fn reshape_wrap(
    src: &[f32],
    rows0: usize,
    cols0: usize,
    rows1: usize,
    cols1: usize,
) -> Vec<f32> {
    assert_eq!(src.len(), rows0 * cols0);
    if rows0 == rows1 && cols0 == cols1 {
        return src.to_vec();
    }
    let n = src.len().max(1);
    (0..rows1 * cols1).map(|i| src[i % n]).collect()
}

/// The executor: owns the runtime + plan for one (platform, workload,
/// allocation) triple.
pub struct Executor<'a> {
    pub plat: &'a Platform,
    pub wl: &'a Workload,
    pub alloc: &'a Allocation,
    pub flags: OptFlags,
    pub plan: ExecutionPlan,
    runtime: &'a GemmRuntime,
}

impl<'a> Executor<'a> {
    /// Low-level constructor from borrowed parts.
    pub fn new(
        plat: &'a Platform,
        wl: &'a Workload,
        alloc: &'a Allocation,
        flags: OptFlags,
        runtime: &'a GemmRuntime,
    ) -> Self {
        let plan = build_plan(plat, wl, alloc);
        Executor { plat, wl, alloc, flags, plan, runtime }
    }

    /// Engine front door: execute a scheduled [`Plan`] on its
    /// [`Scenario`].
    pub fn from_plan(
        scenario: &'a Scenario,
        plan: &'a Plan,
        runtime: &'a GemmRuntime,
    ) -> Self {
        Executor::new(
            scenario.platform(),
            scenario.workload(),
            &plan.alloc,
            plan.flags,
            runtime,
        )
    }

    /// Run the whole workload once on synthetic data seeded by `seed`.
    /// `verify` additionally recomputes every op on the CPU reference.
    pub fn run(&self, seed: u64, verify: bool) -> Result<RunReport> {
        let mut rng = Pcg::seeded(seed);
        let t0 = std::time::Instant::now();
        let chunks0 = self
            .runtime
            .executions
            .load(std::sync::atomic::Ordering::Relaxed);

        let mut max_err = 0.0f32;
        let first = &self.wl.ops[0];
        let mut acts = random_matrix(&mut rng, first.m, first.k);
        // Producer outputs, indexed by op id, so consumers follow the
        // dataflow edges (not positional adjacency).
        let mut outputs: Vec<Vec<f32>> = Vec::with_capacity(self.wl.ops.len());

        for (i, op) in self.wl.ops.iter().enumerate() {
            // Activations come from the op's dataflow producers: the
            // sole producer's output wrapped to this op's input shape;
            // fan-in (residual-style) edges sum their wrapped
            // producers; edge-less ops read fresh data (the modeled
            // memory round-trip).
            if i > 0 {
                let producers: Vec<usize> = self
                    .wl
                    .edges
                    .iter()
                    .filter(|e| e.dst == i)
                    .map(|e| e.src)
                    .collect();
                match producers.as_slice() {
                    [] => {
                        acts = random_matrix(&mut rng, op.m, op.k);
                    }
                    [p] => {
                        let src = &self.wl.ops[*p];
                        acts = reshape_wrap(&outputs[*p], src.m, src.n,
                                            op.m, op.k);
                    }
                    many => {
                        let mut sum = vec![0.0f32; op.m * op.k];
                        for &p in many {
                            let src = &self.wl.ops[p];
                            let w = reshape_wrap(&outputs[p], src.m, src.n,
                                                 op.m, op.k);
                            for (s, &v) in sum.iter_mut().zip(&w) {
                                *s += v;
                            }
                        }
                        acts = sum;
                    }
                }
            }
            let weights = random_matrix(&mut rng, op.k, op.n);
            let bias = random_matrix(&mut rng, 1, op.n);

            // Execute every non-empty chunk via the runtime and assemble.
            let mut out = vec![0.0f32; op.m * op.n];
            for c in &self.plan.per_op[i].chunks {
                if c.is_empty() {
                    continue;
                }
                // Slice operands for this chunk.
                let mut xc = Vec::with_capacity(c.rows() * op.k);
                for r in c.row0..c.row1 {
                    xc.extend_from_slice(&acts[r * op.k..(r + 1) * op.k]);
                }
                let mut wc = Vec::with_capacity(op.k * c.cols());
                for r in 0..op.k {
                    wc.extend_from_slice(
                        &weights[r * op.n + c.col0..r * op.n + c.col1],
                    );
                }
                let bc = &bias[c.col0..c.col1];
                let oc = self
                    .runtime
                    .gemm(&xc, &wc, Some(bc), c.rows(), op.k, c.cols(),
                          op.relu)
                    .with_context(|| {
                        format!("op {} chunk {:?}", op.name, c.chiplet)
                    })?;
                for (ri, r) in (c.row0..c.row1).enumerate() {
                    out[r * op.n + c.col0..r * op.n + c.col1]
                        .copy_from_slice(
                            &oc[ri * c.cols()..(ri + 1) * c.cols()],
                        );
                }
            }

            if verify {
                let want = reference_gemm(
                    &acts, &weights, Some(&bias), op.m, op.k, op.n, op.relu,
                );
                for (a, b) in out.iter().zip(&want) {
                    max_err = max_err.max((a - b).abs());
                }
            }

            outputs.push(out);
        }
        let output = outputs.pop().unwrap_or_default();

        let modeled = crate::engine::modeled_breakdown(
            self.plat, self.wl, self.alloc, self.flags,
        );
        // Verification also runs the standalone plan certifier: an
        // executor must never report numbers for a binding whose
        // routes/capacities don't certify on the link graph.
        if verify {
            if let Err(violations) = crate::engine::certify_allocation(
                self.plat, self.wl, self.alloc, self.flags,
            ) {
                crate::bail!(
                    "plan failed certification before execution: {}",
                    violations
                        .iter()
                        .map(|v| v.to_string())
                        .collect::<Vec<_>>()
                        .join("; ")
                );
            }
        }
        // The DES cross-check rides the verification path only (serve
        // batches call `run(.., false)` in a hot loop).
        let simulated_ns = if verify {
            crate::netsim::sim::simulate_plan(
                self.plat,
                self.wl,
                self.alloc,
                self.flags,
                &crate::netsim::sim::SimConfig::default(),
            )
            .ok()
            .map(|r| r.makespan_ns)
        } else {
            None
        };
        let chunks1 = self
            .runtime
            .executions
            .load(std::sync::atomic::Ordering::Relaxed);
        Ok(RunReport {
            modeled,
            simulated_ns,
            host_wall: t0.elapsed(),
            chunks_executed: chunks1 - chunks0,
            max_abs_err: max_err,
            output,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reshape_wrap_identity_and_wrap() {
        let src = vec![1.0, 2.0, 3.0, 4.0];
        assert_eq!(reshape_wrap(&src, 2, 2, 2, 2), src);
        let w = reshape_wrap(&src, 2, 2, 1, 6);
        assert_eq!(w, vec![1.0, 2.0, 3.0, 4.0, 1.0, 2.0]);
    }

    #[test]
    fn random_matrix_deterministic() {
        let mut a = Pcg::seeded(1);
        let mut b = Pcg::seeded(1);
        assert_eq!(random_matrix(&mut a, 3, 4), random_matrix(&mut b, 3, 4));
    }

    // Runtime-backed executor tests live in rust/tests/e2e_runtime.rs.
}
