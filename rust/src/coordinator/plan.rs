//! Execution plan: the bridge from an optimized [`Allocation`] to
//! concrete per-chiplet GEMM chunks the runtime executes.

use crate::partition::Allocation;
use crate::platform::Platform;
use crate::workload::Workload;

/// One chiplet's share of one op: a rectangle of the output matrix.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Chunk {
    pub chiplet: (usize, usize),
    /// Output row range [row0, row1).
    pub row0: usize,
    pub row1: usize,
    /// Output column range [col0, col1).
    pub col0: usize,
    pub col1: usize,
}

impl Chunk {
    pub fn rows(&self) -> usize {
        self.row1 - self.row0
    }

    pub fn cols(&self) -> usize {
        self.col1 - self.col0
    }

    pub fn is_empty(&self) -> bool {
        self.rows() == 0 || self.cols() == 0
    }
}

/// Per-op chunk grid.
#[derive(Debug, Clone)]
pub struct OpPlan {
    pub op_index: usize,
    pub chunks: Vec<Chunk>,
}

/// The full plan for a workload.
#[derive(Debug, Clone)]
pub struct ExecutionPlan {
    pub per_op: Vec<OpPlan>,
}

/// Turn partition prefix sums into chunk rectangles.
pub fn build_plan(plat: &Platform, wl: &Workload, alloc: &Allocation)
                  -> ExecutionPlan {
    debug_assert!(alloc.validate(wl, plat).is_ok());
    let mut per_op = Vec::with_capacity(wl.ops.len());
    for (i, _op) in wl.ops.iter().enumerate() {
        let part = &alloc.parts[i];
        let mut row_off = vec![0usize; plat.xdim + 1];
        for x in 0..plat.xdim {
            row_off[x + 1] = row_off[x] + part.px[x];
        }
        let mut col_off = vec![0usize; plat.ydim + 1];
        for y in 0..plat.ydim {
            col_off[y + 1] = col_off[y] + part.py[y];
        }
        let mut chunks = Vec::with_capacity(plat.num_chiplets());
        for x in 0..plat.xdim {
            for y in 0..plat.ydim {
                chunks.push(Chunk {
                    chiplet: (x, y),
                    row0: row_off[x],
                    row1: row_off[x + 1],
                    col0: col_off[y],
                    col1: col_off[y + 1],
                });
            }
        }
        per_op.push(OpPlan { op_index: i, chunks });
    }
    ExecutionPlan { per_op }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{MemKind, SystemType};
    use crate::partition::uniform_allocation;
    use crate::workload::models::alexnet;

    #[test]
    fn chunks_tile_the_output_exactly() {
        let plat = crate::platform::Platform::preset(
            SystemType::A, MemKind::Hbm, 4,
        );
        let wl = alexnet(1);
        let alloc = uniform_allocation(&plat, &wl);
        let plan = build_plan(&plat, &wl, &alloc);
        for (op, p) in wl.ops.iter().zip(&plan.per_op) {
            assert_eq!(p.chunks.len(), 16);
            // Row/col coverage without overlap.
            let covered: usize =
                p.chunks.iter().map(|c| c.rows() * c.cols()).sum();
            assert_eq!(covered, op.m * op.n, "op {}", op.name);
            let max_r = p.chunks.iter().map(|c| c.row1).max().unwrap();
            let max_c = p.chunks.iter().map(|c| c.col1).max().unwrap();
            assert_eq!((max_r, max_c), (op.m, op.n));
        }
    }

    #[test]
    fn skewed_partition_yields_empty_chunks() {
        let plat = crate::platform::Platform::preset(
            SystemType::A, MemKind::Hbm, 4,
        );
        let wl = crate::workload::Workload::new(
            "w",
            vec![crate::workload::GemmOp::dense("a", 10, 16, 10)],
        );
        let mut alloc = uniform_allocation(&plat, &wl);
        alloc.parts[0].px = vec![10, 0, 0, 0];
        let plan = build_plan(&plat, &wl, &alloc);
        let empties =
            plan.per_op[0].chunks.iter().filter(|c| c.is_empty()).count();
        assert_eq!(empties, 12); // 3 idle rows x 4 cols
    }
}
