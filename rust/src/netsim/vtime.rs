//! Virtual-time queueing substrate for the serving load harness.
//!
//! The serving layer ([`crate::serving`]) measures sustained-stream
//! behavior — queueing delay, SLO attainment, goodput — by advancing a
//! *virtual* clock in nanoseconds instead of sleeping through wall
//! time: a load test of a million requests costs only the event
//! bookkeeping. Per-batch **service times** come from the plan-level
//! discrete-event simulator ([`super::sim`], ultimately
//! `sim::run_tasks`), so the queueing model sits on top of the same
//! oracle the conformance suite validates; this module provides the
//! queueing half: a deterministic pool of parallel service modules (N
//! simulated MCMs behind one router) tracked in virtual time. The DES
//! active-set rework (DESIGN.md §DES performance architecture) is
//! bit-identical to the original loop, so service times — and thus
//! every virtual-time trace — are unchanged by it.
//!
//! Determinism rules: module selection is lowest-index-first by default
//! (the serving layer can opt into least-assigned-work routing via
//! [`ModulePool::idle_least_assigned_at`] — equally deterministic, ties
//! broken toward the lower index), time comparisons are exact `f64`
//! comparisons (all quantities derive from deterministic arithmetic on
//! trace and simulator outputs — no wall clock anywhere), so a run is
//! bit-reproducible from its inputs.

/// A pool of `n` identical service modules advancing in virtual time.
/// Each module serves one batch at a time; the pool answers "who is
/// idle at `now`", "when does the next busy module free up" and "how
/// much service backlog is in flight" — the three questions the
/// continuous batcher and the admission estimator ask.
#[derive(Debug, Clone)]
pub struct ModulePool {
    /// Virtual completion time per module; `<= now` means idle.
    busy_until: Vec<f64>,
    /// Cumulative service time ever assigned per module — the
    /// "outstanding work" ledger least-loaded routing balances on.
    assigned_ns: Vec<f64>,
}

impl ModulePool {
    /// `n` must be at least 1 (a pool with no modules can never serve).
    pub fn new(n: usize) -> ModulePool {
        assert!(n >= 1, "ModulePool needs at least one module");
        ModulePool { busy_until: vec![0.0; n], assigned_ns: vec![0.0; n] }
    }

    pub fn len(&self) -> usize {
        self.busy_until.len()
    }

    pub fn is_empty(&self) -> bool {
        false // by construction: n >= 1
    }

    /// Lowest-indexed module idle at `now`, if any.
    pub fn idle_at(&self, now_ns: f64) -> Option<usize> {
        self.busy_until.iter().position(|&t| t <= now_ns)
    }

    /// Number of modules idle at `now`.
    pub fn idle_count(&self, now_ns: f64) -> usize {
        self.busy_until.iter().filter(|&&t| t <= now_ns).count()
    }

    /// Idle module with the least cumulative assigned work (ties break
    /// toward the lower index) — the least-outstanding-work router.
    pub fn idle_least_assigned_at(&self, now_ns: f64) -> Option<usize> {
        let mut best: Option<(usize, f64)> = None;
        for (m, (&t, &w)) in
            self.busy_until.iter().zip(&self.assigned_ns).enumerate()
        {
            if t <= now_ns && best.is_none_or(|(_, bw)| w < bw) {
                best = Some((m, w));
            }
        }
        best.map(|(m, _)| m)
    }

    /// Cumulative service time ever assigned to module `m`.
    pub fn assigned_ns(&self, m: usize) -> f64 {
        self.assigned_ns[m]
    }

    /// Occupy module `m` until `until_ns`. Panics if the module is
    /// still busy at `now_ns` or the interval runs backwards — both
    /// are driver bugs, not load conditions.
    pub fn occupy(&mut self, m: usize, now_ns: f64, until_ns: f64) {
        assert!(
            self.busy_until[m] <= now_ns,
            "module {m} occupied at t={now_ns} while busy until {}",
            self.busy_until[m]
        );
        assert!(
            until_ns >= now_ns,
            "module {m} service interval runs backwards \
             ({now_ns} -> {until_ns})"
        );
        self.busy_until[m] = until_ns;
        self.assigned_ns[m] += until_ns - now_ns;
    }

    /// The next completion strictly after `now`: `(module, time)` of
    /// the busy module finishing earliest (lowest index on ties).
    /// `None` when every module is already idle.
    pub fn next_completion(&self, now_ns: f64) -> Option<(usize, f64)> {
        let mut best: Option<(usize, f64)> = None;
        for (m, &t) in self.busy_until.iter().enumerate() {
            if t > now_ns && best.is_none_or(|(_, bt)| t < bt) {
                best = Some((m, t));
            }
        }
        best
    }

    /// Total remaining in-flight service at `now` (summed over busy
    /// modules) — the admission estimator's view of work the pool has
    /// already committed to.
    pub fn remaining_ns(&self, now_ns: f64) -> f64 {
        self.busy_until
            .iter()
            .filter(|&&t| t > now_ns)
            .map(|&t| t - now_ns)
            .sum()
    }

    /// Virtual time the last module frees up (0.0 if nothing ever ran).
    pub fn last_completion_ns(&self) -> f64 {
        self.busy_until.iter().fold(0.0f64, |a, &b| a.max(b))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn idle_then_busy_then_idle() {
        let mut pool = ModulePool::new(2);
        assert_eq!(pool.idle_at(0.0), Some(0));
        assert_eq!(pool.idle_count(0.0), 2);
        assert_eq!(pool.next_completion(0.0), None);

        pool.occupy(0, 0.0, 100.0);
        assert_eq!(pool.idle_at(0.0), Some(1));
        pool.occupy(1, 0.0, 50.0);
        assert_eq!(pool.idle_at(0.0), None);
        assert_eq!(pool.next_completion(0.0), Some((1, 50.0)));
        assert_eq!(pool.remaining_ns(0.0), 150.0);

        // At t=50 module 1 frees; module 0 still busy.
        assert_eq!(pool.idle_at(50.0), Some(1));
        assert_eq!(pool.next_completion(50.0), Some((0, 100.0)));
        assert_eq!(pool.remaining_ns(50.0), 50.0);
        assert_eq!(pool.last_completion_ns(), 100.0);
    }

    #[test]
    fn ties_pick_lowest_index() {
        let mut pool = ModulePool::new(3);
        pool.occupy(0, 0.0, 70.0);
        pool.occupy(1, 0.0, 70.0);
        assert_eq!(pool.next_completion(0.0), Some((0, 70.0)));
        // Module 2 idle: reuse fills lowest index first.
        assert_eq!(pool.idle_at(0.0), Some(2));
    }

    #[test]
    fn least_assigned_routing_balances_work() {
        let mut pool = ModulePool::new(3);
        // All idle, nothing assigned yet: ties break to module 0.
        assert_eq!(pool.idle_least_assigned_at(0.0), Some(0));
        pool.occupy(0, 0.0, 100.0);
        pool.occupy(1, 0.0, 10.0);
        // At t=200 everything is idle again; module 2 never worked.
        assert_eq!(pool.idle_least_assigned_at(200.0), Some(2));
        pool.occupy(2, 200.0, 250.0);
        // Now module 1 (10 ns) trails modules 0 (100) and 2 (50).
        assert_eq!(pool.idle_least_assigned_at(300.0), Some(1));
        assert_eq!(pool.assigned_ns(0), 100.0);
        assert_eq!(pool.assigned_ns(1), 10.0);
        assert_eq!(pool.assigned_ns(2), 50.0);
        // Lowest-index routing is unaffected by the ledger.
        assert_eq!(pool.idle_at(300.0), Some(0));
    }

    #[test]
    #[should_panic(expected = "occupied")]
    fn double_occupy_panics() {
        let mut pool = ModulePool::new(1);
        pool.occupy(0, 0.0, 100.0);
        pool.occupy(0, 10.0, 200.0);
    }

    #[test]
    #[should_panic(expected = "at least one module")]
    fn zero_modules_rejected() {
        let _ = ModulePool::new(0);
    }
}
