//! Analytical-vs-simulated conformance: the comparison layer between
//! `cost::evaluate` (the paper's closed-form end-to-end framework) and
//! the plan-level discrete-event simulator ([`super::sim`]).
//!
//! A [`Conformance`] record compares one scheduled plan's analytical
//! latency against the simulated makespan of the *same* allocation
//! under the *same* effective flags, in the simulator's conformance
//! (layer-sequential) mode. The pass criterion is a per-scheme ratio
//! band ([`scheme_tolerance`]): `lo <= simulated / analytical <= hi`.
//!
//! # Why bands, and why these widths (see DESIGN.md §Validation)
//!
//! The two models share the compute model, the off-chip serialization
//! assumption and the §5.2 redistribution step times — those terms
//! agree exactly on a congestion-free package. They deliberately differ
//! on on-chip congestion: the analytical model folds waiting slots into
//! shared-hop counts (eqs. 11–12), while the simulator runs unicast
//! flows under max-min fair contention. That disagreement is the whole
//! point — the band is where the hop-folding approximation must live.
//! Schemes that run with the §5 co-optimizations enabled (greedy, GA,
//! MIQP) exercise redistribution and fusion on skewed partitions, so
//! their band is slightly wider than the unoptimized baselines.
//!
//! Any PR that *loosens* a band must say so in CHANGES.md (the
//! tolerance table is a ratchet; see DESIGN.md).

use std::path::Path;

use crate::bail;
use crate::engine::{certify_allocation, Plan, Scenario};
use crate::util::error::{Context, Result};
use crate::util::math::geomean;

use super::sim::{simulate_plan, SimConfig, SimReport};

/// Allowed `simulated / analytical` latency ratio band.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Tolerance {
    pub lo: f64,
    pub hi: f64,
}

impl Tolerance {
    pub fn contains(&self, ratio: f64) -> bool {
        ratio.is_finite() && ratio >= self.lo && ratio <= self.hi
    }
}

/// The documented per-scheme tolerance table (DESIGN.md §Validation).
/// Unknown scheduler keys get the widest (optimized-scheme) band.
///
/// These are the *first-calibration* bands: the eq. 11–12 hop folding
/// sits between what unicast fluid contention (the simulator's choice)
/// and a perfect multicast tree would produce, so per-stage ratios span
/// roughly 0.5–2.4x across the preset matrix before the exact terms
/// (compute, off-chip serialization, redistribution) dilute them. The
/// calibration table artifact records the measured ratios per run;
/// tightening the bands toward those is welcome, loosening them
/// requires a CHANGES.md entry.
pub fn scheme_tolerance(scheduler: &str) -> Tolerance {
    match scheduler {
        // No co-optimizations (Table 3 forces OptFlags::NONE): uniform
        // or near-uniform partitions, no redistribution, no fusion —
        // only the hop-folding vs unicast-contention gap remains.
        "baseline" | "simba" => Tolerance { lo: 0.40, hi: 2.8 },
        // Optimized schemes additionally exercise diagonal routing,
        // redistribution and async fusion on skewed partitions.
        _ => Tolerance { lo: 0.33, hi: 3.0 },
    }
}

/// One (scenario × plan) conformance measurement.
#[derive(Debug, Clone)]
pub struct Conformance {
    pub model: String,
    pub system: String,
    pub scheduler: String,
    /// `cost::evaluate` end-to-end latency of the plan.
    pub analytical_ns: f64,
    /// Discrete-event makespan of the same plan (conformance mode).
    pub simulated_ns: f64,
    /// `simulated_ns / analytical_ns`.
    pub ratio: f64,
    pub tolerance: Tolerance,
}

impl Conformance {
    pub fn pass(&self) -> bool {
        self.tolerance.contains(self.ratio)
    }

    /// One formatted table row (markdown).
    fn row(&self) -> String {
        format!(
            "| {} | {} | {} | {:.4} | {:.4} | {:.3} | [{:.2}, {:.2}] | {} |",
            self.model,
            self.system,
            self.scheduler,
            self.analytical_ns / 1e6,
            self.simulated_ns / 1e6,
            self.ratio,
            self.tolerance.lo,
            self.tolerance.hi,
            if self.pass() { "ok" } else { "FAIL" }
        )
    }
}

/// Simulate a scheduled plan in conformance mode (the
/// [`Scenario::simulate`] backend).
///
/// Every simulated plan is also run through the standalone certifier
/// ([`certify_allocation`]), and the DES's per-link byte counters are
/// held against the certificate's conservative bounds: the certifier
/// charges both sides of every adaptive decision, so
/// `link_bytes[l] <= link_bound[l]` must hold on every link in every
/// [`super::SimMode`] — a violation means the certifier's accounting
/// and the lowering have drifted apart.
pub fn simulate_scenario_plan(
    scenario: &Scenario,
    plan: &Plan,
    cfg: &SimConfig,
) -> Result<SimReport> {
    let sim = simulate_plan(
        scenario.platform(),
        scenario.workload(),
        &plan.alloc,
        plan.flags,
        cfg,
    )
    .with_context(|| {
        format!(
            "simulating plan of scheduler '{}' on {}",
            plan.scheduler,
            scenario.label()
        )
    })?;
    let cert = match certify_allocation(
        scenario.platform(),
        scenario.workload(),
        &plan.alloc,
        plan.flags,
    ) {
        Ok(c) => c,
        Err(violations) => {
            let list: Vec<String> =
                violations.iter().map(|v| v.to_string()).collect();
            bail!(
                "plan of scheduler '{}' on {} failed certification: {}",
                plan.scheduler,
                scenario.label(),
                list.join("; ")
            );
        }
    };
    if cert.link_bound.len() != sim.link_bytes.len() {
        bail!(
            "certificate covers {} links but the simulation graph has {}",
            cert.link_bound.len(),
            sim.link_bytes.len()
        );
    }
    for (l, (&bytes, &bound)) in
        sim.link_bytes.iter().zip(&cert.link_bound).enumerate()
    {
        if bytes > bound * 1.000_001 + 1.0 {
            let link = &sim.graph.links[l];
            bail!(
                "DES pushed {bytes:.1} bytes over link {l} \
                 ({} -> {}) but the certificate bounds it at {bound:.1} \
                 (scheduler '{}' on {})",
                link.from,
                link.to,
                plan.scheduler,
                scenario.label()
            );
        }
    }
    Ok(sim)
}

/// Run the simulator against the plan's analytical score and grade the
/// ratio against the scheduler's tolerance band.
pub fn check_plan(scenario: &Scenario, plan: &Plan) -> Result<Conformance> {
    check_plan_perturbed(scenario, plan, 1.0)
}

/// [`check_plan`] with the analytical latency multiplied by `scale`
/// before grading — the suite's "does the oracle have teeth" hook: a
/// large injected perturbation of the cost model must push every
/// scenario outside its band.
pub fn check_plan_perturbed(
    scenario: &Scenario,
    plan: &Plan,
    scale: f64,
) -> Result<Conformance> {
    let analytical_ns =
        scenario.report(plan).latency_ns() * scale;
    let sim = simulate_scenario_plan(scenario, plan, &SimConfig::default())?;
    let ratio = if analytical_ns > 0.0 {
        sim.makespan_ns / analytical_ns
    } else {
        f64::INFINITY
    };
    Ok(Conformance {
        model: scenario.workload().name.clone(),
        system: scenario.label(),
        scheduler: plan.scheduler.clone(),
        analytical_ns,
        simulated_ns: sim.makespan_ns,
        ratio,
        tolerance: scheme_tolerance(&plan.scheduler),
    })
}

/// Render the calibration table artifact (markdown): one row per
/// measurement plus a per-scheme ratio summary.
pub fn calibration_table(rows: &[Conformance]) -> String {
    let mut s = String::new();
    s.push_str(
        "# Conformance calibration: analytical vs simulated latency\n\n\
         Generated by the conformance suite (`cargo test --release -q \
         conformance`).\nRatio = simulated / analytical; the band is the \
         per-scheme tolerance\n(DESIGN.md §Validation). Loosening a band \
         must be called out in CHANGES.md.\n\n",
    );
    s.push_str(
        "| model | system | scheduler | analytical (ms) | simulated (ms) \
         | ratio | band | verdict |\n\
         |---|---|---|---|---|---|---|---|\n",
    );
    for r in rows {
        s.push_str(&r.row());
        s.push('\n');
    }
    // Per-scheme summary.
    let mut keys: Vec<&str> =
        rows.iter().map(|r| r.scheduler.as_str()).collect();
    keys.sort_unstable();
    keys.dedup();
    s.push_str("\n## Per-scheme ratio summary\n\n");
    s.push_str(
        "| scheduler | cells | min | geomean | max | band |\n\
         |---|---|---|---|---|---|\n",
    );
    for key in keys {
        let ratios: Vec<f64> = rows
            .iter()
            .filter(|r| r.scheduler == key)
            .map(|r| r.ratio)
            .collect();
        let min = ratios.iter().copied().fold(f64::INFINITY, f64::min);
        let max = ratios.iter().copied().fold(0.0f64, f64::max);
        let tol = scheme_tolerance(key);
        s.push_str(&format!(
            "| {} | {} | {:.3} | {:.3} | {:.3} | [{:.2}, {:.2}] |\n",
            key,
            ratios.len(),
            min,
            geomean(&ratios),
            max,
            tol.lo,
            tol.hi
        ));
    }
    s
}

/// Write the calibration table to `path` (CI uploads it as a workflow
/// artifact).
pub fn write_calibration(rows: &[Conformance], path: &Path) -> Result<()> {
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)
                .with_context(|| format!("creating {}", dir.display()))?;
        }
    }
    std::fs::write(path, calibration_table(rows))
        .with_context(|| format!("writing {}", path.display()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::{schedulers, Engine};
    use crate::workload::models::alexnet;

    #[test]
    fn tolerance_table_shape() {
        let base = scheme_tolerance("baseline");
        let ga = scheme_tolerance("ga");
        assert!(base.lo > 0.0 && base.lo < 1.0 && base.hi > 1.0);
        assert!(ga.lo <= base.lo && ga.hi >= base.hi);
        // Unknown schedulers get the widest band.
        let unk = scheme_tolerance("custom-solver");
        assert_eq!(unk.lo, ga.lo);
        assert_eq!(unk.hi, ga.hi);
        assert!(base.contains(1.0));
        assert!(!base.contains(f64::NAN));
        assert!(!base.contains(100.0));
    }

    #[test]
    fn headline_baseline_plan_conforms() {
        let engine = Engine::new(Scenario::headline(alexnet(1)));
        let plan =
            engine.schedule_with(&schedulers::Baseline).unwrap().into_plan();
        let c = check_plan(engine.scenario(), &plan).unwrap();
        assert!(
            c.pass(),
            "baseline AlexNet ratio {} outside [{}, {}]",
            c.ratio,
            c.tolerance.lo,
            c.tolerance.hi
        );
        assert!(c.analytical_ns > 0.0 && c.simulated_ns > 0.0);
    }

    #[test]
    fn perturbation_breaks_the_band() {
        let engine = Engine::new(Scenario::headline(alexnet(1)));
        let plan =
            engine.schedule_with(&schedulers::Baseline).unwrap().into_plan();
        let hi = check_plan_perturbed(engine.scenario(), &plan, 100.0)
            .unwrap();
        assert!(!hi.pass(), "100x inflation passed: ratio {}", hi.ratio);
        let lo = check_plan_perturbed(engine.scenario(), &plan, 0.01)
            .unwrap();
        assert!(!lo.pass(), "100x deflation passed: ratio {}", lo.ratio);
    }

    #[test]
    fn calibration_table_formats() {
        let rows = vec![Conformance {
            model: "alexnet".into(),
            system: "A-HBM-4x4".into(),
            scheduler: "ga".into(),
            analytical_ns: 2e6,
            simulated_ns: 2.4e6,
            ratio: 1.2,
            tolerance: scheme_tolerance("ga"),
        }];
        let t = calibration_table(&rows);
        assert!(t.contains("| alexnet | A-HBM-4x4 | ga |"), "{t}");
        assert!(t.contains("Per-scheme ratio summary"));
        assert!(t.contains("| ga | 1 |"));
    }
}
