//! The pre-PR-8 DES event loop, frozen verbatim.
//!
//! [`run_tasks_legacy`] is a byte-for-byte copy of the original
//! `run_tasks_resumable` inner loop: five O(n) scans per event and a
//! from-scratch, allocating global [`maxmin_rates`] call on every
//! completion. It is kept for two reasons only:
//!
//! * **Oracle** — the active-set engine in [`super::sim`] must produce
//!   bit-identical `start`/`finish`/`link_bytes`; unit and property
//!   tests diff the two loops on lowered plans and random task graphs.
//! * **Bench emulation** — `benches/sim_conformance.rs` measures
//!   `des_event_loop_speedup` as legacy-time / new-time on the same
//!   lowered task graph (the CI ratchet blocks below 3x on the
//!   gpt2_large x 20x20 line).
//!
//! Do not "fix" or optimize this file: its value is that it does not
//! change.

use super::maxmin::maxmin_rates;
use super::sim::{Checkpoint, RunOutcome, Task, Work};
use crate::err;
use crate::topology::links::{LinkGraph, LinkId};
use crate::util::error::Result;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum State {
    Pending,
    Latency,
    Active,
    Done,
}

/// The original full-scan event loop (see the module docs). Semantics,
/// iteration order and floating-point arithmetic are exactly the
/// pre-PR-8 `run_tasks_resumable`.
pub(crate) fn run_tasks_legacy(
    graph: &LinkGraph,
    tasks: &[Task],
    hop_latency_ns: f64,
    boundaries: &[usize],
    resume: Option<(&Checkpoint, &RunOutcome)>,
) -> Result<(RunOutcome, Vec<Checkpoint>)> {
    let n = tasks.len();
    let mut unmet: Vec<usize> = tasks.iter().map(|t| t.deps.len()).collect();
    let mut dependents: Vec<Vec<usize>> = vec![Vec::new(); n];
    for (i, t) in tasks.iter().enumerate() {
        for &d in &t.deps {
            if d >= n {
                return Err(err!(
                    "task {i} depends on nonexistent task {d} (graph has \
                     {n} tasks)"
                ));
            }
            dependents[d].push(i);
        }
    }
    let routes: Vec<&[LinkId]> = tasks
        .iter()
        .map(|t| match &t.work {
            Work::Transfer { route, .. } => &route[..],
            Work::Compute { .. } => &[],
        })
        .collect();

    let mut state = vec![State::Pending; n];
    let mut remaining = vec![0.0f64; n];
    let mut lat_left = vec![0.0f64; n];
    let mut start = vec![0.0f64; n];
    let mut finish = vec![0.0f64; n];
    let mut link_bytes = vec![0.0f64; graph.links.len()];
    let mut done = 0usize;
    let mut now = 0.0f64;
    let mut checkpoints: Vec<Checkpoint> = Vec::new();
    let mut next_ckpt = 0usize;

    let base = match resume {
        Some((ck, prev)) => {
            if ck.boundary > n
                || prev.start.len() < ck.boundary
                || prev.finish.len() < ck.boundary
                || ck.link_bytes.len() != link_bytes.len()
            {
                return Err(err!(
                    "resume checkpoint (boundary {}) does not fit the \
                     task graph ({} tasks, {} links)",
                    ck.boundary,
                    n,
                    link_bytes.len()
                ));
            }
            for i in 0..ck.boundary {
                state[i] = State::Done;
                start[i] = prev.start[i];
                finish[i] = prev.finish[i];
            }
            done = ck.boundary;
            now = ck.now;
            link_bytes.copy_from_slice(&ck.link_bytes);
            for i in ck.boundary..n {
                unmet[i] = tasks[i]
                    .deps
                    .iter()
                    .filter(|&&d| d >= ck.boundary)
                    .count();
            }
            ck.boundary
        }
        None => 0,
    };
    while next_ckpt < boundaries.len() && boundaries[next_ckpt] <= base {
        next_ckpt += 1;
    }

    let mut ready: Vec<usize> =
        (base..n).filter(|&i| unmet[i] == 0).collect();
    let mut completions: Vec<usize> = Vec::new();
    let mut draining = vec![false; n];

    loop {
        while let Some(i) = ready.pop() {
            start[i] = now;
            let instant = match &tasks[i].work {
                Work::Compute { dur_ns } => *dur_ns <= 0.0,
                Work::Transfer { route, bytes } => {
                    route.is_empty() || *bytes <= 0.0
                }
            };
            if instant {
                state[i] = State::Done;
                finish[i] = now;
                done += 1;
                for &d in &dependents[i] {
                    unmet[d] -= 1;
                    if unmet[d] == 0 {
                        ready.push(d);
                    }
                }
            } else {
                match &tasks[i].work {
                    Work::Compute { dur_ns } => {
                        remaining[i] = *dur_ns;
                        state[i] = State::Active;
                    }
                    Work::Transfer { route, bytes } => {
                        remaining[i] = *bytes;
                        lat_left[i] = (route.len() - 1) as f64
                            * hop_latency_ns;
                        state[i] = if lat_left[i] > 0.0 {
                            State::Latency
                        } else {
                            State::Active
                        };
                    }
                }
            }
        }
        if done == n {
            break;
        }
        if !state
            .iter()
            .any(|s| matches!(s, State::Active | State::Latency))
        {
            return Err(err!(
                "simulation stalled with {} tasks blocked on unmet \
                 dependencies (cycle in the lowered task graph)",
                n - done
            ));
        }

        for i in 0..n {
            draining[i] = state[i] == State::Active
                && matches!(tasks[i].work, Work::Transfer { .. });
        }
        let rate = maxmin_rates(graph, &routes, &draining);

        let mut dt = f64::INFINITY;
        for i in 0..n {
            match state[i] {
                State::Latency => dt = dt.min(lat_left[i]),
                State::Active => match tasks[i].work {
                    Work::Compute { .. } => dt = dt.min(remaining[i]),
                    Work::Transfer { .. } => {
                        if rate[i] > 0.0 {
                            dt = dt.min(remaining[i] / rate[i]);
                        }
                    }
                },
                _ => {}
            }
        }
        if !dt.is_finite() {
            return Err(err!(
                "simulation deadlock: active transfer with zero rate \
                 (zero-capacity link on a route?)"
            ));
        }
        now += dt;
        for i in 0..n {
            match state[i] {
                State::Latency => {
                    lat_left[i] -= dt;
                    if lat_left[i] <= 1e-12 {
                        lat_left[i] = 0.0;
                        state[i] = State::Active;
                    }
                }
                State::Active => match &tasks[i].work {
                    Work::Compute { dur_ns } => {
                        remaining[i] -= dt;
                        if remaining[i] <= 1e-9 * dur_ns.max(1.0) {
                            completions.push(i);
                        }
                    }
                    Work::Transfer { route, bytes } => {
                        if rate[i] > 0.0 {
                            let moved = rate[i] * dt;
                            remaining[i] -= moved;
                            for &l in route.iter() {
                                link_bytes[l] += moved;
                            }
                            if remaining[i] <= 1e-9 * bytes.max(1.0) {
                                completions.push(i);
                            }
                        }
                    }
                },
                _ => {}
            }
        }
        for &i in &completions {
            state[i] = State::Done;
            remaining[i] = 0.0;
            finish[i] = now;
            done += 1;
            for &d in &dependents[i] {
                unmet[d] -= 1;
                if unmet[d] == 0 {
                    ready.push(d);
                }
            }
        }
        completions.clear();
        while next_ckpt < boundaries.len() && done > boundaries[next_ckpt] {
            next_ckpt += 1;
        }
        if next_ckpt < boundaries.len() && done == boundaries[next_ckpt] {
            let b = boundaries[next_ckpt];
            debug_assert!(
                state[..b].iter().all(|s| *s == State::Done)
                    && state[b..].iter().all(|s| *s == State::Pending),
                "checkpoint boundary {b} is not a quiescent cut"
            );
            checkpoints.push(Checkpoint {
                boundary: b,
                now,
                link_bytes: link_bytes.clone(),
            });
            next_ckpt += 1;
        }
    }
    Ok((RunOutcome { start, finish, link_bytes, makespan_ns: now }, checkpoints))
}
