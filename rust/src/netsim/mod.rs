//! Link-level network congestion simulator — the ASTRA-sim substitute
//! behind the paper's Figure 3 motivation study (DESIGN.md
//! §Substitutions).
//!
//! Model: fluid flows over the directed [`LinkGraph`]. At every event the
//! simulator computes the **max-min fair** rate allocation (progressive
//! filling: repeatedly freeze the most-contended link's flows at its fair
//! share), advances time to the next flow completion, and repeats.
//! Outputs per-link carried bytes (the Fig. 3(a–c) utilization heatmaps)
//! and flow/total completion times (Fig. 3(d)).

use crate::platform::Platform;
use crate::topology::links::{LinkGraph, LinkId, NodeId};
use crate::util::error::Result;

/// One transfer: `bytes` from `src` to `dst` along the graph's
/// deterministic route.
#[derive(Debug, Clone)]
pub struct Flow {
    pub src: NodeId,
    pub dst: NodeId,
    pub bytes: f64,
}

/// Simulation output.
#[derive(Debug, Clone)]
pub struct SimResult {
    /// Completion time of each flow (ns), same order as the input.
    pub flow_finish_ns: Vec<f64>,
    /// Total bytes carried per link (heatmap source).
    pub link_bytes: Vec<f64>,
    /// Time the last flow finished.
    pub makespan_ns: f64,
}

impl SimResult {
    /// Peak link utilization: carried bytes / (capacity * makespan),
    /// per link — 1.0 means a link was saturated for the whole run.
    pub fn utilization(&self, graph: &LinkGraph) -> Vec<f64> {
        self.link_bytes
            .iter()
            .zip(&graph.links)
            .map(|(b, l)| {
                if self.makespan_ns > 0.0 {
                    b / (l.capacity * self.makespan_ns)
                } else {
                    0.0
                }
            })
            .collect()
    }
}

/// Max-min fair rates for the active flows (progressive filling).
/// `routes[i]` lists the links flow `i` traverses.
fn maxmin_rates(
    graph: &LinkGraph,
    routes: &[Vec<LinkId>],
    active: &[bool],
) -> Vec<f64> {
    let nf = routes.len();
    let mut rate = vec![0.0f64; nf];
    let mut frozen: Vec<bool> =
        active.iter().map(|a| !a).collect();
    let mut cap: Vec<f64> = graph.links.iter().map(|l| l.capacity).collect();

    loop {
        // Count unfrozen flows per link.
        let mut nflows = vec![0usize; graph.links.len()];
        for (i, r) in routes.iter().enumerate() {
            if frozen[i] {
                continue;
            }
            for &l in r {
                nflows[l] += 1;
            }
        }
        // Bottleneck link: minimal fair share.
        let mut best: Option<(f64, LinkId)> = None;
        for (l, &n) in nflows.iter().enumerate() {
            if n == 0 {
                continue;
            }
            let share = cap[l] / n as f64;
            if best.is_none_or(|(s, _)| share < s) {
                best = Some((share, l));
            }
        }
        let Some((share, bott)) = best else { break };
        // Freeze every unfrozen flow crossing the bottleneck.
        for (i, r) in routes.iter().enumerate() {
            if frozen[i] || !r.contains(&bott) {
                continue;
            }
            rate[i] = share;
            frozen[i] = true;
            for &l in r {
                cap[l] = (cap[l] - share).max(0.0);
            }
        }
    }
    rate
}

/// Run all flows to completion; returns per-flow finish times and
/// per-link carried bytes. Errors if a flow's route cannot be
/// materialized (malformed graph / node ids).
pub fn simulate(graph: &LinkGraph, flows: &[Flow]) -> Result<SimResult> {
    let routes: Vec<Vec<LinkId>> = flows
        .iter()
        .map(|f| graph.route(f.src, f.dst))
        .collect::<Result<_>>()?;
    let mut remaining: Vec<f64> = flows.iter().map(|f| f.bytes).collect();
    let mut active: Vec<bool> = remaining.iter().map(|&b| b > 0.0).collect();
    let mut finish = vec![0.0f64; flows.len()];
    let mut link_bytes = vec![0.0f64; graph.links.len()];
    let mut now = 0.0f64;

    // Zero-byte or self-routed flows are done immediately.
    for (i, r) in routes.iter().enumerate() {
        if r.is_empty() {
            active[i] = false;
        }
    }

    while active.iter().any(|&a| a) {
        let rate = maxmin_rates(graph, &routes, &active);
        // Next completion.
        let mut dt = f64::INFINITY;
        for i in 0..flows.len() {
            if active[i] && rate[i] > 0.0 {
                dt = dt.min(remaining[i] / rate[i]);
            }
        }
        assert!(
            dt.is_finite(),
            "deadlock: active flows with zero rate (disconnected route?)"
        );
        now += dt;
        for i in 0..flows.len() {
            if !active[i] || rate[i] <= 0.0 {
                continue;
            }
            let moved = rate[i] * dt;
            remaining[i] -= moved;
            for &l in &routes[i] {
                link_bytes[l] += moved;
            }
            if remaining[i] <= 1e-9 * flows[i].bytes.max(1.0) {
                remaining[i] = 0.0;
                active[i] = false;
                finish[i] = now;
            }
        }
    }
    Ok(SimResult { flow_finish_ns: finish, link_bytes, makespan_ns: now })
}

/// The Figure 3 scenario: every chiplet of an `n x n` mesh pulls `bytes`
/// from a memory node attached at `attach`; returns the graph and result.
pub fn all_pull_from_memory(
    n: usize,
    bytes: f64,
    bw_nop: f64,
    bw_mem: f64,
    attach: crate::topology::Pos,
    diagonal: bool,
) -> Result<(LinkGraph, SimResult)> {
    let mut g = LinkGraph::mesh(n, n, diagonal, bw_nop);
    let mem = g.attach_memory(attach, bw_mem);
    let flows: Vec<Flow> = (0..n)
        .flat_map(|r| (0..n).map(move |c| (r, c)))
        .map(|(r, c)| Flow {
            src: mem,
            dst: g.chiplet_id(crate::topology::Pos::new(r, c)),
            bytes,
        })
        .collect();
    let res = simulate(&g, &flows)?;
    Ok((g, res))
}

/// The same all-pull study on an arbitrary [`Platform`]: every chiplet
/// pulls `bytes` from the memory stack of its *nearest* attachment
/// (mirroring the analytical model's serving-attachment assumption),
/// over the platform's own link graph — per-class NoP/diagonal
/// bandwidths and per-attachment off-chip bandwidths included.
pub fn platform_pull_from_memory(
    plat: &Platform,
    bytes: f64,
    diagonal: bool,
) -> Result<(LinkGraph, SimResult)> {
    let g = plat.link_graph(diagonal);
    // Memory nodes were appended after the chiplets, in attachment
    // declaration order.
    let n_chiplets = plat.num_chiplets();
    let mem_of = |pos: crate::topology::Pos| -> NodeId {
        let i = plat
            .spec()
            .attachments
            .iter()
            .position(|a| a.pos == pos)
            .expect("nearest_global returns an attachment position");
        n_chiplets + i
    };
    let flows: Vec<Flow> = plat
        .positions()
        .map(|p| Flow {
            src: mem_of(plat.nearest_global(p)),
            dst: g.chiplet_id(p),
            bytes,
        })
        .collect();
    let res = simulate(&g, &flows)?;
    Ok((g, res))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::Pos;

    #[test]
    fn single_flow_full_bandwidth() {
        let g = LinkGraph::mesh(2, 2, false, 60.0);
        let f = [Flow { src: 0, dst: 1, bytes: 600.0 }];
        let r = simulate(&g, &f).unwrap();
        assert!((r.makespan_ns - 10.0).abs() < 1e-6);
        assert_eq!(r.flow_finish_ns[0], r.makespan_ns);
    }

    #[test]
    fn two_flows_share_a_link_fairly() {
        // Both flows cross link 0->1; each should get half.
        let g = LinkGraph::mesh(1, 3, false, 60.0);
        let f = [
            Flow { src: 0, dst: 1, bytes: 600.0 },
            Flow { src: 0, dst: 2, bytes: 600.0 },
        ];
        let r = simulate(&g, &f).unwrap();
        // Flow 0 shares 0->1 (30 each) until flow... both finish their
        // 600 B: flow0 at t=20 (after sharing), flow1 continues at full
        // rate on the second hop.
        assert!(r.flow_finish_ns[0] <= r.flow_finish_ns[1] + 1e-9);
        assert!((r.flow_finish_ns[0] - 20.0).abs() < 1e-6);
    }

    #[test]
    fn dram_bottleneck_flat_in_nop_bw() {
        // Fig 3(d), DRAM: doubling NoP bandwidth yields no benefit.
        let b = 1e6;
        let (_, slow) =
            all_pull_from_memory(4, b, 60.0, 60.0, Pos::new(0, 0), false)
                .unwrap();
        let (_, fast) =
            all_pull_from_memory(4, b, 120.0, 60.0, Pos::new(0, 0), false)
                .unwrap();
        let ratio = slow.makespan_ns / fast.makespan_ns;
        assert!((ratio - 1.0).abs() < 0.05, "ratio={ratio}");
        // Memory link carries everything: 16 * b bytes.
        let total: f64 = 16.0 * b;
        assert!((slow.makespan_ns - total / 60.0).abs() / slow.makespan_ns < 0.05);
    }

    #[test]
    fn hbm_scales_with_nop_bw() {
        // Fig 3(d), HBM: performance scales ~linearly with NoP bandwidth.
        let b = 1e6;
        let (_, slow) =
            all_pull_from_memory(4, b, 60.0, 1024.0, Pos::new(0, 0), false)
                .unwrap();
        let (_, fast) =
            all_pull_from_memory(4, b, 120.0, 1024.0, Pos::new(0, 0), false)
                .unwrap();
        let ratio = slow.makespan_ns / fast.makespan_ns;
        assert!(ratio > 1.7, "ratio={ratio}");
    }

    #[test]
    fn central_hbm_beats_peripheral() {
        // Fig 3(c)-(d): central placement mitigates NoP congestion
        // (paper: 1.53x).
        let b = 1e6;
        let (_, peri) =
            all_pull_from_memory(4, b, 60.0, 1024.0, Pos::new(0, 0), false)
                .unwrap();
        let (_, cent) =
            all_pull_from_memory(4, b, 60.0, 1024.0, Pos::new(1, 1), false)
                .unwrap();
        let speedup = peri.makespan_ns / cent.makespan_ns;
        assert!(speedup > 1.3 && speedup < 2.2, "speedup={speedup}");
    }

    #[test]
    fn conservation_of_bytes() {
        let b = 1e5;
        let (g, r) =
            all_pull_from_memory(3, b, 60.0, 200.0, Pos::new(0, 0), false)
                .unwrap();
        // The memory attachment link must carry exactly 9 * b minus the
        // attach chiplet's own flow (which crosses it too: src==mem).
        let mem_out: f64 = g
            .links
            .iter()
            .enumerate()
            .filter(|(_, l)| l.from == g.nodes.len() - 1)
            .map(|(i, _)| r.link_bytes[i])
            .sum();
        assert!((mem_out - 9.0 * b).abs() < 1.0);
    }

    #[test]
    fn utilization_bounded_by_one() {
        let (g, r) =
            all_pull_from_memory(4, 1e5, 60.0, 1024.0, Pos::new(0, 0), false)
                .unwrap();
        for u in r.utilization(&g) {
            assert!((0.0..=1.0 + 1e-9).contains(&u));
        }
    }

    #[test]
    fn platform_pull_favors_distributed_attachments() {
        // Same aggregate demand: the edge-attachment preset drains the
        // package much faster than the single-corner one (16 stacks of
        // entrances vs 2 links), the §3.3 motivation for
        // packaging-adaptive optimization.
        use crate::config::{MemKind, SystemType};
        let b = 1e6;
        let (_, corner) = platform_pull_from_memory(
            &Platform::preset(SystemType::A, MemKind::Hbm, 4), b, false,
        )
        .unwrap();
        let (_, edges) = platform_pull_from_memory(
            &Platform::preset(SystemType::B, MemKind::Hbm, 4), b, false,
        )
        .unwrap();
        assert!(
            edges.makespan_ns < corner.makespan_ns / 2.0,
            "edges {} vs corner {}",
            edges.makespan_ns,
            corner.makespan_ns
        );
    }

    #[test]
    fn diagonal_links_relieve_corner_congestion() {
        let b = 1e6;
        let (_, base) =
            all_pull_from_memory(4, b, 60.0, 1024.0, Pos::new(0, 0), false)
                .unwrap();
        let (_, diag) =
            all_pull_from_memory(4, b, 60.0, 1024.0, Pos::new(0, 0), true)
                .unwrap();
        assert!(diag.makespan_ns < base.makespan_ns);
    }
}
