//! Link-level network simulation: the ASTRA-sim substitute behind the
//! paper's Figure 3 motivation study (DESIGN.md §Substitutions) and,
//! since the validation PR, the **plan-level discrete-event simulator**
//! ([`sim`]) plus the analytical-vs-simulated conformance suite
//! ([`conformance`], `tests/conformance.rs`).
//!
//! Model: fluid flows over the directed [`LinkGraph`]. At every event
//! the simulator computes the **max-min fair** rate allocation
//! (progressive filling: repeatedly freeze the most-contended link's
//! flows at its fair share), advances time to the next completion, and
//! repeats. The historical flow-replay API ([`simulate`],
//! [`all_pull_from_memory`]) is now a thin lowering onto the same
//! event engine that executes whole schedules ([`sim::simulate_plan`]):
//! each flow becomes one dependency-free transfer task.

pub mod conformance;
pub mod incremental;
pub(crate) mod legacy;
pub mod maxmin;
pub mod sim;
pub mod vtime;

pub use conformance::{check_plan, scheme_tolerance, Conformance};
pub use incremental::{IncSimStats, IncrementalSim};
pub use maxmin::{maxmin_rates, MaxMinScratch};
pub use sim::{
    simulate_plan, simulate_plan_profiled, SimConfig, SimMode, SimProfile,
    SimReport,
};
#[doc(hidden)]
pub use sim::SimBench;
pub use vtime::ModulePool;

use crate::platform::Platform;
use crate::topology::links::{LinkGraph, NodeId};
use crate::util::error::Result;

/// One transfer: `bytes` from `src` to `dst` along the graph's
/// deterministic route.
#[derive(Debug, Clone)]
pub struct Flow {
    pub src: NodeId,
    pub dst: NodeId,
    pub bytes: f64,
}

/// Simulation output.
#[derive(Debug, Clone)]
pub struct SimResult {
    /// Completion time of each flow (ns), same order as the input.
    pub flow_finish_ns: Vec<f64>,
    /// Total bytes carried per link (heatmap source).
    pub link_bytes: Vec<f64>,
    /// Time the last flow finished.
    pub makespan_ns: f64,
}

impl SimResult {
    /// Peak link utilization: carried bytes / (capacity * makespan),
    /// per link — 1.0 means a link was saturated for the whole run.
    pub fn utilization(&self, graph: &LinkGraph) -> Vec<f64> {
        self.link_bytes
            .iter()
            .zip(&graph.links)
            .map(|(b, l)| {
                if self.makespan_ns > 0.0 {
                    b / (l.capacity * self.makespan_ns)
                } else {
                    0.0
                }
            })
            .collect()
    }
}

/// Run all flows to completion; returns per-flow finish times and
/// per-link carried bytes. Degenerate flows — zero bytes, or
/// `src == dst` (an empty route) — complete at t = 0 and never enter
/// the rate allocation, so they can neither produce NaN rates nor
/// stretch the makespan. Errors if a flow's route cannot be
/// materialized (malformed graph / node ids).
pub fn simulate(graph: &LinkGraph, flows: &[Flow]) -> Result<SimResult> {
    simulate_with_latency(graph, flows, 0.0)
}

/// [`simulate`] with a per-hop pipeline-fill latency: a flow routed
/// over `h` links pays a serial `(h - 1) * hop_latency_ns` head-flit
/// latency before its bytes start draining (wormhole fill; the default
/// everywhere else in the repo is 0, matching the analytical model,
/// which has no per-hop constant). Pinned by a property test: a lone
/// congestion-free flow finishes at exactly
/// `bytes / bandwidth + (hops - 1) * hop_latency_ns`.
pub fn simulate_with_latency(
    graph: &LinkGraph,
    flows: &[Flow],
    hop_latency_ns: f64,
) -> Result<SimResult> {
    let tasks: Vec<sim::Task> = flows
        .iter()
        .map(|f| -> Result<sim::Task> {
            Ok(sim::Task::transfer(
                graph.route(f.src, f.dst)?,
                f.bytes,
            ))
        })
        .collect::<Result<_>>()?;
    let run = sim::run_tasks(graph, &tasks, hop_latency_ns)?;
    Ok(SimResult {
        flow_finish_ns: run.finish,
        link_bytes: run.link_bytes,
        makespan_ns: run.makespan_ns,
    })
}

/// [`simulate`] on the frozen pre-PR-8 event loop ([`legacy`]) — the
/// bit-identity oracle for the active-set engine. Test-only surface;
/// not a stable API.
#[doc(hidden)]
pub fn simulate_legacy(graph: &LinkGraph, flows: &[Flow]) -> Result<SimResult> {
    let tasks: Vec<sim::Task> = flows
        .iter()
        .map(|f| -> Result<sim::Task> {
            Ok(sim::Task::transfer(
                graph.route(f.src, f.dst)?,
                f.bytes,
            ))
        })
        .collect::<Result<_>>()?;
    let (run, _) = legacy::run_tasks_legacy(graph, &tasks, 0.0, &[], None)?;
    Ok(SimResult {
        flow_finish_ns: run.finish,
        link_bytes: run.link_bytes,
        makespan_ns: run.makespan_ns,
    })
}

/// The Figure 3 scenario: every chiplet of an `n x n` mesh pulls `bytes`
/// from a memory node attached at `attach`; returns the graph and result.
pub fn all_pull_from_memory(
    n: usize,
    bytes: f64,
    bw_nop: f64,
    bw_mem: f64,
    attach: crate::topology::Pos,
    diagonal: bool,
) -> Result<(LinkGraph, SimResult)> {
    let mut g = LinkGraph::mesh(n, n, diagonal, bw_nop);
    let mem = g.attach_memory(attach, bw_mem);
    let flows: Vec<Flow> = (0..n)
        .flat_map(|r| (0..n).map(move |c| (r, c)))
        .map(|(r, c)| Flow {
            src: mem,
            dst: g.chiplet_id(crate::topology::Pos::new(r, c)),
            bytes,
        })
        .collect();
    let res = simulate(&g, &flows)?;
    Ok((g, res))
}

/// The same all-pull study on an arbitrary [`Platform`]: every chiplet
/// pulls `bytes` from the memory stack of its *nearest* attachment
/// (mirroring the analytical model's serving-attachment assumption),
/// over the platform's own link graph — per-class NoP/diagonal
/// bandwidths and per-attachment off-chip bandwidths included.
pub fn platform_pull_from_memory(
    plat: &Platform,
    bytes: f64,
    diagonal: bool,
) -> Result<(LinkGraph, SimResult)> {
    let g = plat.link_graph(diagonal);
    // Memory nodes were appended after the chiplets, in attachment
    // declaration order.
    let n_chiplets = plat.num_chiplets();
    let mem_of = |pos: crate::topology::Pos| -> NodeId {
        let i = plat
            .spec()
            .attachments
            .iter()
            .position(|a| a.pos == pos)
            .expect("nearest_global returns an attachment position");
        n_chiplets + i
    };
    let flows: Vec<Flow> = plat
        .positions()
        .map(|p| Flow {
            src: mem_of(plat.nearest_global(p)),
            dst: g.chiplet_id(p),
            bytes,
        })
        .collect();
    let res = simulate(&g, &flows)?;
    Ok((g, res))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::links::LinkId;
    use crate::topology::Pos;

    #[test]
    fn single_flow_full_bandwidth() {
        let g = LinkGraph::mesh(2, 2, false, 60.0);
        let f = [Flow { src: 0, dst: 1, bytes: 600.0 }];
        let r = simulate(&g, &f).unwrap();
        assert!((r.makespan_ns - 10.0).abs() < 1e-6);
        assert_eq!(r.flow_finish_ns[0], r.makespan_ns);
    }

    #[test]
    fn two_flows_share_a_link_fairly() {
        // Both flows cross link 0->1; each should get half.
        let g = LinkGraph::mesh(1, 3, false, 60.0);
        let f = [
            Flow { src: 0, dst: 1, bytes: 600.0 },
            Flow { src: 0, dst: 2, bytes: 600.0 },
        ];
        let r = simulate(&g, &f).unwrap();
        // Flow 0 shares 0->1 (30 each) until flow... both finish their
        // 600 B: flow0 at t=20 (after sharing), flow1 continues at full
        // rate on the second hop.
        assert!(r.flow_finish_ns[0] <= r.flow_finish_ns[1] + 1e-9);
        assert!((r.flow_finish_ns[0] - 20.0).abs() < 1e-6);
    }

    #[test]
    fn degenerate_flows_complete_at_time_zero() {
        // Satellite pin: zero-byte flows and self-routed (src == dst)
        // flows finish at exactly t = 0, contribute no link bytes, and
        // never poison the rate allocation (no NaN, no deadlock) even
        // when mixed with real traffic.
        let mut g = LinkGraph::mesh(2, 2, false, 60.0);
        let mem = g.attach_memory(Pos::new(0, 0), 100.0);
        let f = [
            Flow { src: 0, dst: 0, bytes: 500.0 }, // self-routed
            Flow { src: 1, dst: 1, bytes: 0.0 },   // both degenerate
            Flow { src: 0, dst: 3, bytes: 0.0 },   // zero bytes, real route
            Flow { src: mem, dst: 3, bytes: 600.0 }, // real traffic
        ];
        let r = simulate(&g, &f).unwrap();
        for i in 0..3 {
            assert_eq!(r.flow_finish_ns[i], 0.0, "flow {i}");
        }
        assert!(r.flow_finish_ns[3] > 0.0);
        assert!(r.makespan_ns.is_finite() && r.makespan_ns > 0.0);
        for b in &r.link_bytes {
            assert!(b.is_finite() && *b >= 0.0);
        }
        // Only the real flow moved bytes: 600 over its 3-link route.
        let total: f64 = r.link_bytes.iter().sum();
        assert!((total - 3.0 * 600.0).abs() < 1.0, "total={total}");

        // All-degenerate set: empty simulation, makespan 0.
        let r0 = simulate(&g, &f[..3]).unwrap();
        assert_eq!(r0.makespan_ns, 0.0);
        assert!(r0.link_bytes.iter().all(|&b| b == 0.0));
    }

    #[test]
    fn maxmin_respects_capacity_and_saturates_a_bottleneck() {
        // Satellite invariants: per-link rate sums never exceed
        // capacity, and at least one link is exactly saturated whenever
        // any flow is active (the progressive-filling bottleneck).
        let mut g = LinkGraph::mesh(3, 3, false, 60.0);
        let mem = g.attach_memory(Pos::new(0, 0), 150.0);
        let routes_owned: Vec<Vec<LinkId>> = (0..9)
            .map(|c| g.route(mem, c).unwrap())
            .collect();
        let routes: Vec<&[LinkId]> =
            routes_owned.iter().map(|r| r.as_slice()).collect();
        let active = vec![true; routes.len()];
        let rates = maxmin_rates(&g, &routes, &active);
        let mut per_link = vec![0.0f64; g.links.len()];
        for (i, r) in routes.iter().enumerate() {
            assert!(rates[i].is_finite() && rates[i] >= 0.0);
            // The self-routed pull (mem -> chiplet 0 is 1 hop; chiplet 0
            // itself has a route) — every non-empty route gets rate > 0.
            if !r.is_empty() {
                assert!(rates[i] > 0.0, "flow {i} starved");
            }
            for &l in r.iter() {
                per_link[l] += rates[i];
            }
        }
        let mut saturated = 0;
        for (l, link) in g.links.iter().enumerate() {
            assert!(
                per_link[l] <= link.capacity + 1e-9,
                "link {l} oversubscribed: {} > {}",
                per_link[l],
                link.capacity
            );
            if (per_link[l] - link.capacity).abs() < 1e-9 {
                saturated += 1;
            }
        }
        assert!(saturated >= 1, "no bottleneck link saturated");
    }

    #[test]
    fn maxmin_rates_are_permutation_invariant() {
        // Satellite invariant: the allocation depends on the flow *set*,
        // not the order flows are listed in. The set is chosen so rates
        // genuinely differ across flows (shared chain vs lone reverse
        // flow): [30, 30, 30, 60] on a 1x4 chain at 60 GB/s.
        let g = LinkGraph::mesh(1, 4, false, 60.0);
        let routes_owned: Vec<Vec<LinkId>> = vec![
            g.route(0, 3).unwrap(), // crosses every forward link
            g.route(0, 1).unwrap(), // shares 0->1 with the long flow
            g.route(2, 3).unwrap(), // shares 2->3 with the long flow
            g.route(3, 0).unwrap(), // reverse direction: uncontended
        ];
        let routes: Vec<&[LinkId]> =
            routes_owned.iter().map(|r| r.as_slice()).collect();
        let active = vec![true; routes.len()];
        let base = maxmin_rates(&g, &routes, &active);
        assert!((base[0] - 30.0).abs() < 1e-9, "{base:?}");
        assert!((base[3] - 60.0).abs() < 1e-9, "{base:?}");
        // Every permutation of the flow list yields the same per-flow
        // rates.
        let perms: [[usize; 4]; 4] = [
            [3, 2, 1, 0],
            [1, 3, 0, 2],
            [2, 0, 3, 1],
            [3, 0, 1, 2],
        ];
        for perm in &perms {
            let proutes: Vec<&[LinkId]> =
                perm.iter().map(|&i| routes[i]).collect();
            let prates = maxmin_rates(&g, &proutes, &active);
            for (slot, &orig) in perm.iter().enumerate() {
                assert!(
                    (prates[slot] - base[orig]).abs() < 1e-9,
                    "rate of flow {orig} changed under permutation: \
                     {} vs {}",
                    prates[slot],
                    base[orig]
                );
            }
        }
    }

    #[test]
    fn maxmin_inactive_and_empty_routes_get_zero() {
        let g = LinkGraph::mesh(1, 3, false, 60.0);
        let r01 = g.route(0, 1).unwrap();
        let empty: Vec<LinkId> = Vec::new();
        let routes: Vec<&[LinkId]> =
            vec![r01.as_slice(), empty.as_slice(), r01.as_slice()];
        let rates =
            maxmin_rates(&g, &routes, &[true, true, false]);
        assert!(rates[0] > 0.0);
        assert_eq!(rates[1], 0.0);
        assert_eq!(rates[2], 0.0);
        // The lone active flow gets the full link.
        assert!((rates[0] - 60.0).abs() < 1e-9);
    }

    #[test]
    fn dram_bottleneck_flat_in_nop_bw() {
        // Fig 3(d), DRAM: doubling NoP bandwidth yields no benefit.
        let b = 1e6;
        let (_, slow) =
            all_pull_from_memory(4, b, 60.0, 60.0, Pos::new(0, 0), false)
                .unwrap();
        let (_, fast) =
            all_pull_from_memory(4, b, 120.0, 60.0, Pos::new(0, 0), false)
                .unwrap();
        let ratio = slow.makespan_ns / fast.makespan_ns;
        assert!((ratio - 1.0).abs() < 0.05, "ratio={ratio}");
        // Memory link carries everything: 16 * b bytes.
        let total: f64 = 16.0 * b;
        assert!((slow.makespan_ns - total / 60.0).abs() / slow.makespan_ns < 0.05);
    }

    #[test]
    fn hbm_scales_with_nop_bw() {
        // Fig 3(d), HBM: performance scales ~linearly with NoP bandwidth.
        let b = 1e6;
        let (_, slow) =
            all_pull_from_memory(4, b, 60.0, 1024.0, Pos::new(0, 0), false)
                .unwrap();
        let (_, fast) =
            all_pull_from_memory(4, b, 120.0, 1024.0, Pos::new(0, 0), false)
                .unwrap();
        let ratio = slow.makespan_ns / fast.makespan_ns;
        assert!(ratio > 1.7, "ratio={ratio}");
    }

    #[test]
    fn central_hbm_beats_peripheral() {
        // Fig 3(c)-(d): central placement mitigates NoP congestion
        // (paper: 1.53x).
        let b = 1e6;
        let (_, peri) =
            all_pull_from_memory(4, b, 60.0, 1024.0, Pos::new(0, 0), false)
                .unwrap();
        let (_, cent) =
            all_pull_from_memory(4, b, 60.0, 1024.0, Pos::new(1, 1), false)
                .unwrap();
        let speedup = peri.makespan_ns / cent.makespan_ns;
        assert!(speedup > 1.3 && speedup < 2.2, "speedup={speedup}");
    }

    #[test]
    fn conservation_of_bytes() {
        let b = 1e5;
        let (g, r) =
            all_pull_from_memory(3, b, 60.0, 200.0, Pos::new(0, 0), false)
                .unwrap();
        // The memory attachment link must carry exactly 9 * b minus the
        // attach chiplet's own flow (which crosses it too: src==mem).
        let mem_out: f64 = g
            .links
            .iter()
            .enumerate()
            .filter(|(_, l)| l.from == g.nodes.len() - 1)
            .map(|(i, _)| r.link_bytes[i])
            .sum();
        assert!((mem_out - 9.0 * b).abs() < 1.0);
    }

    #[test]
    fn utilization_bounded_by_one() {
        let (g, r) =
            all_pull_from_memory(4, 1e5, 60.0, 1024.0, Pos::new(0, 0), false)
                .unwrap();
        for u in r.utilization(&g) {
            assert!((0.0..=1.0 + 1e-9).contains(&u));
        }
    }

    #[test]
    fn hop_latency_adds_serial_fill_time() {
        // 1x4 chain, one flow over 3 hops: bytes/bw + 2 * hop_latency.
        let g = LinkGraph::mesh(1, 4, false, 60.0);
        let f = [Flow { src: 0, dst: 3, bytes: 600.0 }];
        let base = simulate_with_latency(&g, &f, 0.0).unwrap();
        assert!((base.makespan_ns - 10.0).abs() < 1e-9);
        let lat = simulate_with_latency(&g, &f, 5.0).unwrap();
        assert!(
            (lat.makespan_ns - (10.0 + 2.0 * 5.0)).abs() < 1e-9,
            "makespan={}",
            lat.makespan_ns
        );
    }

    #[test]
    fn platform_pull_favors_distributed_attachments() {
        // Same aggregate demand: the edge-attachment preset drains the
        // package much faster than the single-corner one (16 stacks of
        // entrances vs 2 links), the §3.3 motivation for
        // packaging-adaptive optimization.
        use crate::config::{MemKind, SystemType};
        let b = 1e6;
        let (_, corner) = platform_pull_from_memory(
            &Platform::preset(SystemType::A, MemKind::Hbm, 4), b, false,
        )
        .unwrap();
        let (_, edges) = platform_pull_from_memory(
            &Platform::preset(SystemType::B, MemKind::Hbm, 4), b, false,
        )
        .unwrap();
        assert!(
            edges.makespan_ns < corner.makespan_ns / 2.0,
            "edges {} vs corner {}",
            edges.makespan_ns,
            corner.makespan_ns
        );
    }

    #[test]
    fn diagonal_links_relieve_corner_congestion() {
        let b = 1e6;
        let (_, base) =
            all_pull_from_memory(4, b, 60.0, 1024.0, Pos::new(0, 0), false)
                .unwrap();
        let (_, diag) =
            all_pull_from_memory(4, b, 60.0, 1024.0, Pos::new(0, 0), true)
                .unwrap();
        assert!(diag.makespan_ns < base.makespan_ns);
    }
}
