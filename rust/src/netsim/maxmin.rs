//! Max-min fair rate allocation: the global progressive-filling
//! reference ([`maxmin_rates`]) and its component-wise incremental
//! twin ([`MaxMinScratch`]), the PR-8 fast path behind the DES event
//! loop.
//!
//! # Why components
//!
//! Progressive filling is a fixpoint over *link* state: freeze the
//! most-contended link's flows at its fair share `cap/n`, subtract,
//! repeat. Two flows influence each other's rates only if they are
//! connected through a chain of shared links — i.e. they sit in the
//! same connected component of the flow/link sharing graph. Links are
//! never shared across components (sharing *is* the component
//! relation), so the global algorithm's `cap` and `nflows` updates
//! decompose exactly: running progressive filling per component, links
//! scanned in ascending id and flows frozen in ascending id, performs
//! the *same* floating-point operations on the *same* values as the
//! global pass, merely reordering independent components. The rates
//! are therefore **bit-identical**, not merely close — the event loop
//! debug-asserts this against [`maxmin_rates`] on every event.
//!
//! # Why incremental
//!
//! Between two DES events the draining set changes only by the flows
//! that completed or started. A component whose flow set is unchanged
//! keeps its rates (same flows, same links, same arithmetic). The
//! invalidation rule is link-based: an event marks the route links of
//! every started/finished flow *dirty*; a component must be recomputed
//! iff it touches a dirty link. This is sound because any surviving
//! component whose rates could have changed must previously have
//! competed with an added/removed flow through some shared link — and
//! a component that shares *no* link with the changed flows was
//! already a maximal component before the event, with an unchanged
//! flow set (see DESIGN.md §DES performance architecture).
//!
//! All working state (union-find parents, per-link caps/counts/stamps,
//! member lists) lives in the reusable [`MaxMinScratch`]; steady-state
//! recomputation allocates nothing once buffers are warm.

use crate::topology::links::{LinkGraph, LinkId};

/// Max-min fair rates for the active flows (progressive filling).
/// `routes[i]` lists the links flow `i` traverses; `active[i]` gates
/// whether flow `i` competes for capacity. Inactive (and zero-route)
/// flows get rate 0. Public so invariant tests and external tooling can
/// probe the allocation directly. This is the allocating *reference*
/// implementation; the event loop runs the bit-identical component-wise
/// [`MaxMinScratch`] and debug-asserts against this one.
pub fn maxmin_rates(
    graph: &LinkGraph,
    routes: &[&[LinkId]],
    active: &[bool],
) -> Vec<f64> {
    let nf = routes.len();
    let mut rate = vec![0.0f64; nf];
    let mut frozen: Vec<bool> = active
        .iter()
        .zip(routes)
        .map(|(a, r)| !a || r.is_empty())
        .collect();
    let mut cap: Vec<f64> = graph.links.iter().map(|l| l.capacity).collect();

    loop {
        // Count unfrozen flows per link.
        let mut nflows = vec![0usize; graph.links.len()];
        for (i, r) in routes.iter().enumerate() {
            if frozen[i] {
                continue;
            }
            for &l in r.iter() {
                nflows[l] += 1;
            }
        }
        // Bottleneck link: minimal fair share.
        let mut best: Option<(f64, LinkId)> = None;
        for (l, &n) in nflows.iter().enumerate() {
            if n == 0 {
                continue;
            }
            let share = cap[l] / n as f64;
            if best.is_none_or(|(s, _)| share < s) {
                best = Some((share, l));
            }
        }
        let Some((share, bott)) = best else { break };
        // Freeze every unfrozen flow crossing the bottleneck.
        for (i, r) in routes.iter().enumerate() {
            if frozen[i] || !r.contains(&bott) {
                continue;
            }
            rate[i] = share;
            frozen[i] = true;
            for &l in r.iter() {
                cap[l] = (cap[l] - share).max(0.0);
            }
        }
    }
    rate
}

/// Telemetry of one [`MaxMinScratch::recompute`] call (profile
/// counters for `simulate --profile` and the bench).
#[derive(Debug, Clone, Copy, Default)]
pub struct CompStats {
    /// Connected components found in the active set this event.
    pub components: u64,
    /// Components whose rates were actually recomputed (dirty).
    pub recomputed: u64,
    /// Wall time of the union-find rebuild, ns (0 unless timed).
    pub rebuild_ns: u64,
}

/// Reusable state for component-wise incremental max-min (see the
/// module docs). One instance serves one event loop; buffers grow to
/// the task-graph/link-graph sizes once and are reused allocation-free
/// afterwards. Stamps are `u64` epochs, so buffers never need clearing
/// between events or runs.
#[derive(Debug, Clone, Default)]
pub struct MaxMinScratch {
    // ---- per-link (sized to graph.links.len()).
    cap: Vec<f64>,
    nflows: Vec<usize>,
    /// Link is rate-dirty when `dirty[l] == dirty_stamp`.
    dirty: Vec<u64>,
    /// Link already claimed this rebuild when `owner[l] == build_stamp`.
    owner: Vec<u64>,
    /// Flow that claimed the link (valid under `owner` stamp).
    owner_flow: Vec<usize>,
    /// Link already collected into `comp_links` this group.
    seen: Vec<u64>,
    // ---- per-flow (sized to the task count).
    parent: Vec<usize>,
    frozen: Vec<bool>,
    /// Root is dirty this rebuild when `rstamp[root] == build_stamp`.
    rstamp: Vec<u64>,
    // ---- transient lists (reused).
    members: Vec<(usize, usize)>,
    comp_links: Vec<LinkId>,
    // ---- epochs.
    dirty_stamp: u64,
    build_stamp: u64,
    seen_stamp: u64,
    any_dirty: bool,
}

fn find(parent: &mut [usize], mut x: usize) -> usize {
    while parent[x] != x {
        parent[x] = parent[parent[x]]; // path halving
        x = parent[x];
    }
    x
}

impl MaxMinScratch {
    pub fn new() -> MaxMinScratch {
        MaxMinScratch { dirty_stamp: 1, build_stamp: 1, seen_stamp: 1, ..MaxMinScratch::default() }
    }

    /// Grow buffers to `n_links` links and `n_flows` flows (no-op once
    /// warm) and reset the event-level dirty flag. Call once per run.
    pub(crate) fn begin_run(&mut self, n_links: usize, n_flows: usize) {
        if self.dirty_stamp == 0 {
            // Default-constructed instance: stamp 0 would alias the
            // zero-filled stamp buffers.
            self.dirty_stamp = 1;
            self.build_stamp = 1;
            self.seen_stamp = 1;
        }
        if self.cap.len() < n_links {
            self.cap.resize(n_links, 0.0);
            self.nflows.resize(n_links, 0);
            self.dirty.resize(n_links, 0);
            self.owner.resize(n_links, 0);
            self.owner_flow.resize(n_links, 0);
            self.seen.resize(n_links, 0);
        }
        if self.parent.len() < n_flows {
            self.parent.resize(n_flows, 0);
            self.frozen.resize(n_flows, false);
            self.rstamp.resize(n_flows, 0);
        }
        self.any_dirty = false;
    }

    /// Mark every link of `route` rate-dirty: a draining flow started
    /// or stopped crossing them, so every component touching one of
    /// these links must recompute at the next [`Self::recompute`].
    #[inline]
    pub(crate) fn mark_route_dirty(&mut self, route: &[LinkId]) {
        for &l in route {
            self.dirty[l] = self.dirty_stamp;
        }
        self.any_dirty = true;
    }

    /// Recompute fair-share rates for every dirty component of the
    /// active flow set. `active` lists draining flow ids in ascending
    /// order (all with non-empty routes); `route_of` resolves a flow's
    /// links; `rate` is the full-length rate table — entries of clean
    /// components are left untouched (they are still bit-exact), dirty
    /// components are overwritten. Consumes the dirty marks.
    ///
    /// Bit-identity with the global [`maxmin_rates`] over the same
    /// active set is asserted by the event loop in debug builds.
    pub(crate) fn recompute<'a>(
        &mut self,
        graph: &LinkGraph,
        active: &[usize],
        route_of: impl Fn(usize) -> &'a [LinkId],
        rate: &mut [f64],
        timed: bool,
    ) -> CompStats {
        let mut stats = CompStats::default();
        if !self.any_dirty || active.is_empty() {
            // No flow started or finished since the last allocation:
            // every component is unchanged, rates are already exact.
            return stats;
        }
        let t0 = if timed { Some(std::time::Instant::now()) } else { None };

        // ---- union-find rebuild over the active set, keyed by link
        // ownership: flows sharing any link land in one component. The
        // root of a component is its minimum flow id (deterministic).
        self.build_stamp += 1;
        let bs = self.build_stamp;
        for &f in active {
            self.parent[f] = f;
        }
        for &f in active {
            for &l in route_of(f) {
                if self.owner[l] == bs {
                    let a = find(&mut self.parent, f);
                    let b = find(&mut self.parent, self.owner_flow[l]);
                    if a < b {
                        self.parent[b] = a;
                    } else if b < a {
                        self.parent[a] = b;
                    }
                } else {
                    self.owner[l] = bs;
                    self.owner_flow[l] = f;
                }
            }
        }
        // ---- dirty roots: a component recomputes iff it touches a
        // dirty link (the invalidation rule; see module docs).
        let ds = self.dirty_stamp;
        for &f in active {
            if route_of(f).iter().any(|&l| self.dirty[l] == ds) {
                let r = find(&mut self.parent, f);
                self.rstamp[r] = bs;
            }
        }
        // ---- collect dirty members, grouped by root, flows ascending
        // within each group (unique (root, flow) keys, so the unstable
        // sort is deterministic).
        self.members.clear();
        let mut n_components = 0u64;
        for &f in active {
            let r = find(&mut self.parent, f);
            if r == f {
                n_components += 1;
            }
            if self.rstamp[r] == bs {
                self.members.push((r, f));
            }
        }
        self.members.sort_unstable();
        if let Some(t0) = t0 {
            stats.rebuild_ns = t0.elapsed().as_nanos() as u64;
        }
        stats.components = n_components;

        // ---- per-component progressive filling, replaying the global
        // algorithm's arithmetic restricted to the component: links
        // scanned ascending (same tie-break), flows frozen ascending,
        // caps decremented per frozen flow exactly as the global pass
        // does.
        let mut g = 0usize;
        while g < self.members.len() {
            let root = self.members[g].0;
            let mut end = g + 1;
            while end < self.members.len() && self.members[end].0 == root {
                end += 1;
            }
            stats.recomputed += 1;

            self.seen_stamp += 1;
            let ss = self.seen_stamp;
            self.comp_links.clear();
            for k in g..end {
                let f = self.members[k].1;
                self.frozen[f] = false;
                for &l in route_of(f) {
                    if self.seen[l] != ss {
                        self.seen[l] = ss;
                        self.comp_links.push(l);
                    }
                }
            }
            self.comp_links.sort_unstable();
            for &l in &self.comp_links {
                self.cap[l] = graph.links[l].capacity;
            }
            loop {
                for &l in &self.comp_links {
                    self.nflows[l] = 0;
                }
                for k in g..end {
                    let f = self.members[k].1;
                    if self.frozen[f] {
                        continue;
                    }
                    for &l in route_of(f) {
                        self.nflows[l] += 1;
                    }
                }
                let mut best: Option<(f64, LinkId)> = None;
                for &l in &self.comp_links {
                    let n = self.nflows[l];
                    if n == 0 {
                        continue;
                    }
                    let share = self.cap[l] / n as f64;
                    if best.is_none_or(|(s, _)| share < s) {
                        best = Some((share, l));
                    }
                }
                let Some((share, bott)) = best else { break };
                for k in g..end {
                    let f = self.members[k].1;
                    if self.frozen[f] {
                        continue;
                    }
                    let r = route_of(f);
                    if !r.contains(&bott) {
                        continue;
                    }
                    rate[f] = share;
                    self.frozen[f] = true;
                    for &l in r {
                        self.cap[l] = (self.cap[l] - share).max(0.0);
                    }
                }
            }
            g = end;
        }

        // Consume the dirty marks: bumping the stamp invalidates every
        // mark without touching the buffer.
        self.dirty_stamp += 1;
        self.any_dirty = false;
        stats
    }

    /// From-scratch component-wise allocation over an explicit flow
    /// list — same contract as [`maxmin_rates`] (inactive and
    /// empty-route flows get rate 0), same bits, different algorithm.
    /// Public so the property suite can pin the component
    /// decomposition against the global reference directly.
    pub fn rates(
        &mut self,
        graph: &LinkGraph,
        routes: &[&[LinkId]],
        active: &[bool],
    ) -> Vec<f64> {
        let nf = routes.len();
        let mut rate = vec![0.0f64; nf];
        self.begin_run(graph.links.len(), nf);
        let mut ids: Vec<usize> = Vec::with_capacity(nf);
        for (i, r) in routes.iter().enumerate() {
            if active[i] && !r.is_empty() {
                ids.push(i);
            }
        }
        for &i in &ids {
            self.mark_route_dirty(routes[i]);
        }
        self.recompute(graph, &ids, |i| routes[i], &mut rate, false);
        rate
    }

    /// Capacity fingerprint (perf-pin test: capacities must stop
    /// changing once the scratch is warm).
    pub fn capacities(&self) -> Vec<usize> {
        vec![
            self.cap.capacity(),
            self.nflows.capacity(),
            self.dirty.capacity(),
            self.owner.capacity(),
            self.owner_flow.capacity(),
            self.seen.capacity(),
            self.parent.capacity(),
            self.frozen.capacity(),
            self.rstamp.capacity(),
            self.members.capacity(),
            self.comp_links.capacity(),
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::Pos;

    fn owned(routes: &[Vec<LinkId>]) -> Vec<&[LinkId]> {
        routes.iter().map(|r| r.as_slice()).collect()
    }

    #[test]
    fn componentwise_matches_global_on_disjoint_components() {
        // Two independent chains: forward flows on a 1x4 chain plus an
        // uncontended reverse flow — three components in total.
        let g = LinkGraph::mesh(1, 4, false, 60.0);
        let routes_owned = vec![
            g.route(0, 3).unwrap(),
            g.route(0, 1).unwrap(),
            g.route(2, 3).unwrap(),
            g.route(3, 0).unwrap(),
        ];
        let routes = owned(&routes_owned);
        let active = vec![true; routes.len()];
        let global = maxmin_rates(&g, &routes, &active);
        let mut sc = MaxMinScratch::new();
        let comp = sc.rates(&g, &routes, &active);
        for i in 0..routes.len() {
            assert_eq!(
                global[i].to_bits(),
                comp[i].to_bits(),
                "flow {i}: {} vs {}",
                global[i],
                comp[i]
            );
        }
    }

    #[test]
    fn componentwise_matches_global_with_saturated_bottleneck() {
        // All-pull through one memory attachment: the attachment link
        // saturates and every flow lands in one big component.
        let mut g = LinkGraph::mesh(3, 3, false, 60.0);
        let mem = g.attach_memory(Pos::new(0, 0), 150.0);
        let routes_owned: Vec<Vec<LinkId>> =
            (0..9).map(|c| g.route(mem, c).unwrap()).collect();
        let routes = owned(&routes_owned);
        let active = vec![true; routes.len()];
        let global = maxmin_rates(&g, &routes, &active);
        let mut sc = MaxMinScratch::new();
        let comp = sc.rates(&g, &routes, &active);
        for i in 0..routes.len() {
            assert_eq!(global[i].to_bits(), comp[i].to_bits(), "flow {i}");
        }
    }

    #[test]
    fn componentwise_handles_inactive_and_empty_routes() {
        let g = LinkGraph::mesh(1, 3, false, 60.0);
        let r01 = g.route(0, 1).unwrap();
        let empty: Vec<LinkId> = Vec::new();
        let routes: Vec<&[LinkId]> =
            vec![r01.as_slice(), empty.as_slice(), r01.as_slice()];
        let active = [true, true, false];
        let global = maxmin_rates(&g, &routes, &active);
        let mut sc = MaxMinScratch::new();
        let comp = sc.rates(&g, &routes, &active);
        assert_eq!(global, comp);
        assert_eq!(comp[1], 0.0);
        assert_eq!(comp[2], 0.0);
    }

    #[test]
    fn scratch_reuse_is_bit_stable_across_calls() {
        // Same query through a reused scratch (stale stamps, warm
        // buffers) must reproduce the first answer bit for bit.
        let mut g = LinkGraph::mesh(2, 2, false, 60.0);
        let mem = g.attach_memory(Pos::new(0, 0), 100.0);
        let routes_owned: Vec<Vec<LinkId>> =
            (0..4).map(|c| g.route(mem, c).unwrap()).collect();
        let routes = owned(&routes_owned);
        let active = vec![true; routes.len()];
        let mut sc = MaxMinScratch::new();
        let first = sc.rates(&g, &routes, &active);
        for _ in 0..5 {
            let again = sc.rates(&g, &routes, &active);
            for i in 0..routes.len() {
                assert_eq!(first[i].to_bits(), again[i].to_bits());
            }
        }
        let caps = sc.capacities();
        let _ = sc.rates(&g, &routes, &active);
        assert_eq!(caps, sc.capacities(), "warm scratch must not regrow");
    }
}
