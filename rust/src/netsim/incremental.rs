//! Incremental DES re-simulation: delta-eval sibling of
//! [`super::sim::simulate_plan`] for optimizer loops that perturb a few
//! genes between simulations.
//!
//! A GA mutation changes one or two ops' partitions (or one edge's
//! collection column); re-simulating the whole plan re-lowers and
//! re-runs every op even though the event history is bit-identical up
//! to the first affected op. [`IncrementalSim`] exploits the
//! Conformance lowering's layer-sequential barrier, which makes each op
//! boundary a quiescent cut of the event loop:
//!
//! 1. **Diff** the new allocation against the cached one: an op is
//!    *affected* if its partition changed, if an incident
//!    redistribution decision flipped, or if it consumes a still-adopted
//!    exchange whose producer genes / collection column changed. The
//!    dirty frontier is the minimum affected op.
//! 2. **Re-lower the suffix**: the cached task prefix below the
//!    frontier is kept (routes are shared `Arc` slices, so this is a
//!    cheap structural clone); ops at or after the frontier are lowered
//!    again via the same [`super::sim::lower_op`] the full path uses.
//! 3. **Resume the event loop** from the latest [`Checkpoint`] at or
//!    before the frontier (sparse snapshots of `(clock, link_bytes)` at
//!    op boundaries), copying the cached outcome's start/finish for the
//!    unchanged prefix.
//!
//! Resuming is exact: the event loop iterates tasks in index order for
//! every per-step decision, so the suffix replays the same
//! floating-point arithmetic a from-scratch run would. Debug builds
//! re-simulate from scratch on every incremental call and assert the
//! lowered tasks, makespan, per-task finish times and per-link byte
//! counters are bit-identical.

use std::sync::Arc;

use crate::cost::evaluator::OptFlags;
use crate::err;
use crate::partition::Allocation;
use crate::platform::Platform;
use crate::topology::links::{LinkGraph, RouteCache};
use crate::util::error::Result;
use crate::workload::Workload;

use super::sim::{
    edge_redist_decision, lower_op, lower_plan, run_tasks_into, Checkpoint,
    LowerCtx, LoweredPlan, RunOutcome, SimConfig, SimMode, SimScratch,
};

/// Telemetry for the incremental path (tests + the hotpath bench).
#[derive(Debug, Clone, Copy, Default)]
pub struct IncSimStats {
    /// From-scratch simulations (first call, or no usable checkpoint).
    pub full_runs: u64,
    /// Calls that reused a prefix of the cached run.
    pub incremental_runs: u64,
    /// Calls whose allocation produced an identical plan (no re-run).
    pub noop_runs: u64,
    /// Ops whose lowered tasks were reused across incremental calls.
    pub ops_reused: u64,
    /// Ops re-lowered across incremental calls.
    pub ops_relowered: u64,
    /// Tasks skipped by checkpoint resume across incremental calls.
    pub tasks_resumed: u64,
}

struct CachedRun {
    alloc: Allocation,
    lowered: LoweredPlan,
    outcome: RunOutcome,
    checkpoints: Vec<Checkpoint>,
}

/// A re-simulation session bound to one `(platform, workload, flags)`
/// problem. Call [`IncrementalSim::simulate`] with successive
/// allocations; each call returns the same makespan
/// [`super::sim::simulate_plan`] would (bit-identical, asserted in
/// debug builds) while re-running only the affected suffix.
pub struct IncrementalSim {
    plat: Platform,
    wl: Workload,
    flags: OptFlags,
    hop_latency_ns: f64,
    graph: Arc<LinkGraph>,
    ctx: LowerCtx,
    routes: RouteCache,
    /// Event-loop + lowering scratch, warm across calls (PR 8: the
    /// steady state allocates nothing).
    scratch: SimScratch,
    /// Recycled outcome buffers from the run before last.
    spare: RunOutcome,
    cached: Option<CachedRun>,
    stats: IncSimStats,
}

impl IncrementalSim {
    /// Requires [`SimMode::Conformance`]: the layer-sequential barrier
    /// is what makes op boundaries quiescent cuts the resume can
    /// restart from. Overlap-mode plans have no such cuts.
    pub fn new(
        plat: &Platform,
        wl: &Workload,
        flags: OptFlags,
        cfg: &SimConfig,
    ) -> Result<IncrementalSim> {
        if cfg.mode != SimMode::Conformance {
            return Err(err!(
                "incremental re-simulation requires SimMode::Conformance \
                 (op boundaries are only quiescent under the \
                 layer-sequential barrier)"
            ));
        }
        Ok(IncrementalSim {
            plat: plat.clone(),
            wl: wl.clone(),
            flags,
            hop_latency_ns: cfg.hop_latency_ns,
            graph: plat.link_graph_shared(flags.diagonal),
            ctx: LowerCtx::new(plat, wl),
            routes: RouteCache::new(),
            scratch: SimScratch::default(),
            spare: RunOutcome::default(),
            cached: None,
            stats: IncSimStats::default(),
        })
    }

    pub fn stats(&self) -> IncSimStats {
        self.stats
    }

    /// `(hits, misses)` of the persistent route memo.
    pub fn route_cache_stats(&self) -> (usize, usize) {
        self.routes.stats()
    }

    /// Sparse checkpoint schedule: roughly 64 op boundaries, at least
    /// every op for small workloads.
    fn boundaries(op_task_start: &[usize]) -> Vec<usize> {
        let n_ops = op_task_start.len() - 1;
        let step = (n_ops / 64).max(1);
        (1..n_ops).step_by(step).map(|i| op_task_start[i]).collect()
    }

    /// Simulated end-to-end makespan of `alloc` — bit-identical to
    /// `simulate_plan(..).makespan_ns`.
    pub fn simulate(&mut self, alloc: &Allocation) -> Result<f64> {
        if alloc.parts.len() != self.wl.ops.len()
            || alloc.collect_cols.len() != self.wl.edges.len()
        {
            return Err(err!(
                "allocation arity mismatch: {} partitions / {} collect \
                 cols for {} ops / {} edges",
                alloc.parts.len(),
                alloc.collect_cols.len(),
                self.wl.ops.len(),
                self.wl.edges.len()
            ));
        }
        match self.cached.take() {
            None => self.full_run(alloc),
            Some(prev) => self.delta_run(alloc, prev),
        }
    }

    fn full_run(&mut self, alloc: &Allocation) -> Result<f64> {
        self.stats.full_runs += 1;
        let lowered = lower_plan(
            &self.plat,
            &self.wl,
            alloc,
            self.flags,
            SimMode::Conformance,
            &self.ctx,
            &self.graph,
            &mut self.routes,
            &mut self.scratch.lower,
        )?;
        let bounds = Self::boundaries(&lowered.op_task_start);
        let mut outcome = std::mem::take(&mut self.spare);
        let mut checkpoints = Vec::new();
        run_tasks_into(
            &self.graph,
            &lowered.tasks,
            Some(&lowered.meta),
            self.hop_latency_ns,
            &bounds,
            None,
            &mut self.scratch,
            &mut outcome,
            &mut checkpoints,
            None,
        )?;
        let makespan = outcome.makespan_ns;
        self.cached = Some(CachedRun {
            alloc: alloc.clone(),
            lowered,
            outcome,
            checkpoints,
        });
        Ok(makespan)
    }

    fn delta_run(
        &mut self,
        alloc: &Allocation,
        prev: CachedRun,
    ) -> Result<f64> {
        let n_ops = self.wl.ops.len();

        // ---- diff: which ops lower differently under the new genes?
        let part_changed: Vec<bool> = (0..n_ops)
            .map(|i| {
                let (a, b) = (&alloc.parts[i], &prev.alloc.parts[i]);
                a.px != b.px || a.py != b.py
            })
            .collect();
        let mut affected = part_changed.clone();
        let mut redist_edge = prev.lowered.redist_edge.clone();
        for (e, edge) in self.wl.edges.iter().enumerate() {
            let touched = part_changed[edge.src]
                || part_changed[edge.dst]
                || alloc.collect_cols[e] != prev.alloc.collect_cols[e];
            if !touched {
                continue;
            }
            let adopt = edge_redist_decision(
                &self.plat,
                &self.wl,
                alloc,
                self.flags,
                &self.ctx,
                e,
                &mut self.scratch.lower.bufs,
            );
            if adopt != redist_edge[e] {
                // A decision flip swaps the producer's writeback for an
                // exchange and rewrites the consumer's input stage.
                affected[edge.src] = true;
                affected[edge.dst] = true;
            } else if adopt {
                // Still redistributing, but the producer genes / the
                // collection column shape the consumer's exchange flows.
                affected[edge.dst] = true;
            }
            redist_edge[e] = adopt;
        }
        let frontier = match affected.iter().position(|&a| a) {
            Some(f) => f,
            None => {
                // Plan-identical allocation: nothing to re-run.
                self.stats.noop_runs += 1;
                let makespan = prev.outcome.makespan_ns;
                self.cached = Some(prev);
                return Ok(makespan);
            }
        };
        self.stats.incremental_runs += 1;
        self.stats.ops_reused += frontier as u64;
        self.stats.ops_relowered += (n_ops - frontier) as u64;

        // ---- re-lower the suffix onto the unchanged prefix.
        let mut lowered = prev.lowered.clone();
        lowered.truncate_to_op(frontier);
        lowered.redist_edge = redist_edge;
        for i in frontier..n_ops {
            lower_op(
                &self.plat,
                &self.wl,
                alloc,
                self.flags,
                SimMode::Conformance,
                &self.ctx,
                &self.graph,
                &mut self.routes,
                &mut self.scratch.lower,
                i,
                &mut lowered,
            )?;
        }

        // ---- resume from the latest checkpoint at or before the
        // frontier (the prefix below it is bit-identical by
        // construction).
        let cut = lowered.op_task_start[frontier];
        let resume =
            prev.checkpoints.iter().rev().find(|c| c.boundary <= cut);
        self.stats.tasks_resumed += resume.map_or(0, |c| c.boundary as u64);
        let bounds = Self::boundaries(&lowered.op_task_start);
        let mut outcome = std::mem::take(&mut self.spare);
        let mut fresh_ckpts = Vec::new();
        run_tasks_into(
            &self.graph,
            &lowered.tasks,
            Some(&lowered.meta),
            self.hop_latency_ns,
            &bounds,
            resume.map(|c| (c, &prev.outcome)),
            &mut self.scratch,
            &mut outcome,
            &mut fresh_ckpts,
            None,
        )?;
        let mut checkpoints: Vec<Checkpoint> = match resume {
            Some(c) => prev
                .checkpoints
                .iter()
                .filter(|k| k.boundary <= c.boundary)
                .cloned()
                .collect(),
            None => Vec::new(),
        };
        checkpoints.append(&mut fresh_ckpts);

        // Debug builds re-lower and re-run from scratch and insist the
        // incremental path is bit-identical (the ISSUE-7 invariant,
        // mirroring CachedEval's delta-vs-full assert).
        #[cfg(debug_assertions)]
        {
            use super::sim::Work;
            let mut dbg_ls = super::sim::LowerScratch::default();
            let full = lower_plan(
                &self.plat,
                &self.wl,
                alloc,
                self.flags,
                SimMode::Conformance,
                &self.ctx,
                &self.graph,
                &mut self.routes,
                &mut dbg_ls,
            )?;
            assert_eq!(
                full.tasks.len(),
                lowered.tasks.len(),
                "incremental lowering diverged in task count"
            );
            assert_eq!(full.op_task_start, lowered.op_task_start);
            assert_eq!(full.redist_edge, lowered.redist_edge);
            for (t, (a, b)) in
                full.tasks.iter().zip(&lowered.tasks).enumerate()
            {
                assert_eq!(a.deps, b.deps, "task {t} deps diverged");
                match (&a.work, &b.work) {
                    (
                        Work::Compute { dur_ns: x },
                        Work::Compute { dur_ns: y },
                    ) => assert_eq!(x.to_bits(), y.to_bits()),
                    (
                        Work::Transfer { route: ra, bytes: ba },
                        Work::Transfer { route: rb, bytes: bb },
                    ) => {
                        assert_eq!(ba.to_bits(), bb.to_bits());
                        assert_eq!(&ra[..], &rb[..]);
                    }
                    _ => panic!("task {t} work kind diverged"),
                }
            }
            let (fo, _) = super::sim::run_tasks_resumable(
                &self.graph,
                &full.tasks,
                self.hop_latency_ns,
                &[],
                None,
            )?;
            assert_eq!(
                fo.makespan_ns.to_bits(),
                outcome.makespan_ns.to_bits(),
                "incremental makespan diverged from full re-simulation"
            );
            for (t, (a, b)) in
                fo.finish.iter().zip(&outcome.finish).enumerate()
            {
                assert_eq!(
                    a.to_bits(),
                    b.to_bits(),
                    "incremental finish time diverged at task {t}"
                );
            }
            for (l, (a, b)) in
                fo.link_bytes.iter().zip(&outcome.link_bytes).enumerate()
            {
                assert_eq!(
                    a.to_bits(),
                    b.to_bits(),
                    "incremental link bytes diverged at link {l}"
                );
            }
        }

        let makespan = outcome.makespan_ns;
        self.cached = Some(CachedRun {
            alloc: alloc.clone(),
            lowered,
            outcome,
            checkpoints,
        });
        // Recycle the superseded outcome's buffers for the next run.
        self.spare = prev.outcome;
        Ok(makespan)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netsim::sim::simulate_plan;
    use crate::partition::{uniform_allocation, Partition};
    use crate::workload::models::{alexnet, gpt2, Gpt2Config};

    /// Move one row unit from the fullest to the emptiest X stripe —
    /// always a valid perturbation (sum preserved, no underflow).
    fn nudge(p: &mut Partition) {
        let (mut hi, mut lo) = (0usize, 0usize);
        for (j, &v) in p.px.iter().enumerate() {
            if v > p.px[hi] {
                hi = j;
            }
            if v < p.px[lo] {
                lo = j;
            }
        }
        if hi == lo {
            lo = (hi + 1) % p.px.len();
        }
        p.px[hi] -= 1;
        p.px[lo] += 1;
    }

    #[test]
    fn matches_full_simulation_across_perturbations() {
        let plat = Platform::headline();
        let wl = alexnet(1);
        let flags = OptFlags::ALL;
        let cfg = SimConfig::default();
        let mut inc = IncrementalSim::new(&plat, &wl, flags, &cfg).unwrap();
        let mut alloc = uniform_allocation(&plat, &wl);

        let full = simulate_plan(&plat, &wl, &alloc, flags, &cfg).unwrap();
        let first = inc.simulate(&alloc).unwrap();
        assert_eq!(first.to_bits(), full.makespan_ns.to_bits());

        // Late-op perturbation: most of the plan is reused.
        let late = wl.ops.len() - 1;
        nudge(&mut alloc.parts[late]);
        let full2 = simulate_plan(&plat, &wl, &alloc, flags, &cfg).unwrap();
        let second = inc.simulate(&alloc).unwrap();
        assert_eq!(second.to_bits(), full2.makespan_ns.to_bits());

        // Mid-op perturbation on top of the previous state.
        nudge(&mut alloc.parts[wl.ops.len() / 2]);
        let full3 = simulate_plan(&plat, &wl, &alloc, flags, &cfg).unwrap();
        let third = inc.simulate(&alloc).unwrap();
        assert_eq!(third.to_bits(), full3.makespan_ns.to_bits());

        let st = inc.stats();
        assert_eq!(st.full_runs, 1);
        assert_eq!(st.incremental_runs, 2);
        assert!(st.ops_reused > 0, "late perturbation must reuse a prefix");
        let (hits, misses) = inc.route_cache_stats();
        assert!(hits > misses, "route memo should dominate after warmup");
    }

    #[test]
    fn collect_col_change_is_tracked() {
        let plat = Platform::headline();
        let wl = alexnet(1);
        let flags = OptFlags::ALL;
        let cfg = SimConfig::default();
        let mut inc = IncrementalSim::new(&plat, &wl, flags, &cfg).unwrap();
        let mut alloc = uniform_allocation(&plat, &wl);
        inc.simulate(&alloc).unwrap();
        // Sweep one edge's collection column through every value; the
        // adaptive decision may flip either way and the incremental
        // result must track the full simulation bit for bit.
        let e = *wl.redistributable_edges().last().unwrap();
        for c in 0..plat.spec().ydim {
            alloc.collect_cols[e] = c;
            let full =
                simulate_plan(&plat, &wl, &alloc, flags, &cfg).unwrap();
            let got = inc.simulate(&alloc).unwrap();
            assert_eq!(got.to_bits(), full.makespan_ns.to_bits(), "col {c}");
        }
    }

    #[test]
    fn identical_allocation_is_a_noop() {
        let plat = Platform::headline();
        let wl = alexnet(1);
        let cfg = SimConfig::default();
        let mut inc =
            IncrementalSim::new(&plat, &wl, OptFlags::ALL, &cfg).unwrap();
        let alloc = uniform_allocation(&plat, &wl);
        let a = inc.simulate(&alloc).unwrap();
        let b = inc.simulate(&alloc).unwrap();
        assert_eq!(a.to_bits(), b.to_bits());
        assert_eq!(inc.stats().noop_runs, 1);
        assert_eq!(inc.stats().incremental_runs, 0);
    }

    #[test]
    fn gpt2_block_perturbation_matches_full() {
        // A transformer-shaped workload: attention sync ops + the
        // redistributable MLP seam.
        let cfg_model = Gpt2Config {
            layers: 2,
            heads: 2,
            d_model: 64,
            d_ff: 128,
            seq: 8,
            kv_len: 8,
            vocab: 96,
        };
        let wl = gpt2(&cfg_model, 1);
        let plat = Platform::headline();
        let flags = OptFlags::ALL;
        let cfg = SimConfig::default();
        let mut inc = IncrementalSim::new(&plat, &wl, flags, &cfg).unwrap();
        let mut alloc = uniform_allocation(&plat, &wl);
        inc.simulate(&alloc).unwrap();
        // Perturb an op ~90% of the way in (the bench's access
        // pattern): deep prefix reuse.
        let deep = wl.ops.len() * 9 / 10;
        nudge(&mut alloc.parts[deep]);
        let full = simulate_plan(&plat, &wl, &alloc, flags, &cfg).unwrap();
        let got = inc.simulate(&alloc).unwrap();
        assert_eq!(got.to_bits(), full.makespan_ns.to_bits());
        assert!(inc.stats().ops_reused as usize >= wl.ops.len() / 2);
    }

    #[test]
    fn overlap_mode_is_rejected() {
        let plat = Platform::headline();
        let wl = alexnet(1);
        let cfg =
            SimConfig { mode: SimMode::Overlap, hop_latency_ns: 0.0 };
        let err = IncrementalSim::new(&plat, &wl, OptFlags::ALL, &cfg)
            .unwrap_err();
        assert!(err.to_string().contains("Conformance"), "{err}");
    }
}
