//! Plan-level discrete-event simulation (the validation oracle).
//!
//! [`simulate_plan`] takes the exact inputs the analytical evaluator
//! scores — a [`Platform`], a [`Workload`], an [`Allocation`] and the
//! effective [`OptFlags`] — lowers them to a dependency graph of
//! per-chiplet compute events and max-min-fair fluid transfers over the
//! platform's explicit [`LinkGraph`], and advances one event loop that
//! overlaps compute with communication under per-link contention.
//!
//! # Lowering (conformance mode)
//!
//! Communication honors the paper's phase decomposition so the
//! simulator independently *re-derives* what `cost::evaluate` computes
//! in closed form, replacing the hop-count congestion folding of
//! eqs. 9–12 with actual per-link max-min contention:
//!
//! * **Off-chip pull** (§4.3.2 step 1): the op's *unique* off-chip
//!   bytes (weights `K×N`, plus activations `M×K` unless they arrive by
//!   redistribution), apportioned over the memory attachments by the
//!   demand of the chiplets each attachment serves, each share flowing
//!   over that attachment's own memory link. For every preset this
//!   serializes at the aggregate `bw_mem`, exactly the analytical
//!   assumption.
//! * **On-chip distribution** (step 2): one unicast flow per chiplet
//!   from its serving attachment carrying its partition chunk. Where
//!   the analytical model folds waiting slots into shared-hop counts,
//!   the simulator lets the flows contend on real links.
//! * **Redistribution** (§5.2): the three steps as real flows — row
//!   reduction toward the collection column, a per-direction pipelined
//!   broadcast wavefront (modeled as one flow to the farthest endpoint
//!   per side, matching the wormhole "one block transfer" wall time),
//!   and per-boundary cross-row moves. On a congestion-free package the
//!   fluid step times equal the analytical `RedistCost` terms exactly.
//! * **Writeback**: per-chiplet collection flows into the serving
//!   attachment, then demand-apportioned off-chip store flows.
//! * **Compute** (§4.3.1): one fixed-duration event per chiplet from
//!   the same `comp_ns` the evaluator uses. With §5.3 async fusion a
//!   chiplet's compute starts as soon as *its own* distribution flow
//!   lands; otherwise computes wait for the whole distribution stage.
//!
//! Conformance mode keeps the analytical model's layer-sequential
//! barrier between ops; [`SimMode::Overlap`] drops it and wires
//! dataflow dependencies instead: an op's load stage waits only for
//! its producers' writebacks (ops with no dataflow producers load at
//! t=0), so independent branches and far-apart layers overlap under
//! real link contention. This exposes cross-layer pipelining headroom
//! the LS formulation leaves on the table — conservatively, since the
//! weight share of a load rides the same gated stage as the
//! activations rather than prefetching.
//!
//! The redistribution decisions are taken by the *same* adaptive
//! strategy code as the evaluator ([`edge_decision`]), so the simulator
//! executes exactly the plan the cost model priced.

use std::sync::Arc;

use crate::cost::compute::comp_ns;
use crate::cost::energy::comp_energy_pj;
use crate::cost::evaluator::edge_decision;
use crate::cost::scratch::TermBufs;
use crate::partition::Allocation;
use crate::platform::Platform;
use crate::topology::links::{LinkGraph, LinkId, NodeId, RouteCache};
use crate::topology::Pos;
use crate::util::error::{Error, Result};
use crate::workload::{EdgeId, Workload};
use crate::{ensure, err};

use super::maxmin::MaxMinScratch;
use crate::cost::evaluator::OptFlags;

/// What the event loop schedules: a fixed-duration compute event or a
/// fluid byte transfer along a fixed route. Routes are shared `Arc`
/// slices so cloning a lowered plan (incremental re-simulation) and
/// memoized routing ([`RouteCache`]) never copy path data.
#[derive(Debug, Clone)]
pub(crate) enum Work {
    Compute { dur_ns: f64 },
    Transfer { route: Arc<[LinkId]>, bytes: f64 },
}

/// One node of the lowered dependency graph.
#[derive(Debug, Clone)]
pub(crate) struct Task {
    pub(crate) work: Work,
    /// Task ids that must complete before this one starts.
    pub(crate) deps: Vec<usize>,
}

impl Task {
    pub(crate) fn transfer(
        route: impl Into<Arc<[LinkId]>>,
        bytes: f64,
    ) -> Task {
        Task {
            work: Work::Transfer { route: route.into(), bytes },
            deps: Vec::new(),
        }
    }
}

/// Raw event-loop output: per-task start/finish plus per-link bytes.
#[derive(Debug, Clone, Default)]
pub(crate) struct RunOutcome {
    pub(crate) start: Vec<f64>,
    pub(crate) finish: Vec<f64>,
    pub(crate) link_bytes: Vec<f64>,
    pub(crate) makespan_ns: f64,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum State {
    Pending,
    /// Transfer paying its serial head-flit (pipeline-fill) latency.
    Latency,
    /// Draining bytes (transfer) or burning cycles (compute).
    Active,
    Done,
}

/// A clean cut of the event loop: every task with id below `boundary`
/// is done, none at or above it has started, and the clock plus the
/// per-link byte counters are snapshotted. Only the Conformance
/// lowering produces such moments (the layer-sequential barrier makes
/// each op boundary a quiescent point); recording is best-effort — a
/// boundary crossed inside an instant-completion cascade is skipped
/// and a resume simply falls back to an earlier checkpoint.
///
/// `link_bytes` must be snapshotted rather than recomputed: completed
/// transfers leave a sub-tolerance residual undelivered (the `1e-9`
/// completion rule), so the counters are not a function of which tasks
/// finished.
#[derive(Debug, Clone)]
pub(crate) struct Checkpoint {
    pub(crate) boundary: usize,
    pub(crate) now: f64,
    pub(crate) link_bytes: Vec<f64>,
}

/// Profile of one simulated run (`simulate --profile`): where the
/// wall-clock went (lowering vs event loop vs rate recomputation vs
/// component rebuild) and how much work the incremental rate engine
/// actually did. Mirrors the `GaProfile` shape on the optimizer side.
#[derive(Debug, Clone, Copy, Default)]
pub struct SimProfile {
    /// Plan -> task-graph lowering, ns.
    pub lower_ns: u64,
    /// Whole event loop, ns (includes the rate-recompute time).
    pub event_loop_ns: u64,
    /// Component-wise max-min recomputation, ns (subset of the event
    /// loop).
    pub rate_recompute_ns: u64,
    /// Of which: union-find component rebuild, ns.
    pub components_ns: u64,
    /// Discrete events processed.
    pub events: u64,
    /// Events that recomputed at least one component (the rest reused
    /// every rate unchanged).
    pub rate_recomputes: u64,
    /// Components recomputed across the run.
    pub components_recomputed: u64,
    /// Tasks in the lowered graph.
    pub tasks: u64,
}

/// Reusable lowering buffers: the per-op demand apportioning vectors
/// and the evaluator scratch the redistribution decisions run on.
/// Hoisted out of `lower_op` so incremental re-lowering and repeated
/// simulation allocate nothing per op once warm.
#[derive(Debug, Clone, Default)]
pub(crate) struct LowerScratch {
    demand: Vec<f64>,
    att_demand: Vec<f64>,
    att_out: Vec<f64>,
    pub(crate) bufs: TermBufs,
}

/// Reusable event-loop state (PR 8): every per-task array, the CSR
/// dependents adjacency, the active/latency index sets and the
/// component-wise max-min scratch. One instance serves any number of
/// [`run_tasks_into`] calls; buffers grow to the largest graph seen
/// and are then reused allocation-free (pinned by
/// `tests/sim_scratch_alloc.rs`).
#[derive(Debug, Clone, Default)]
pub(crate) struct SimScratch {
    unmet: Vec<usize>,
    state: Vec<State>,
    remaining: Vec<f64>,
    lat_left: Vec<f64>,
    rate: Vec<f64>,
    /// CSR dependents: tasks depending on `d` are
    /// `dep_list[dep_head[d]..dep_head[d + 1]]`, ascending.
    dep_head: Vec<usize>,
    dep_list: Vec<usize>,
    dep_cursor: Vec<usize>,
    ready: Vec<usize>,
    completions: Vec<usize>,
    /// Draining transfers, ascending task id (the byte-accounting
    /// iteration order — the floating-point contract with the legacy
    /// loop).
    act_transfers: Vec<usize>,
    act_computes: Vec<usize>,
    lat_transfers: Vec<usize>,
    promoted: Vec<usize>,
    pub(crate) maxmin: MaxMinScratch,
    pub(crate) lower: LowerScratch,
}

impl SimScratch {
    /// Capacity fingerprint of every reusable buffer (perf-pin test:
    /// capacities must stop changing once the scratch is warm).
    pub(crate) fn capacities(&self) -> Vec<usize> {
        let mut caps = vec![
            self.unmet.capacity(),
            self.state.capacity(),
            self.remaining.capacity(),
            self.lat_left.capacity(),
            self.rate.capacity(),
            self.dep_head.capacity(),
            self.dep_list.capacity(),
            self.dep_cursor.capacity(),
            self.ready.capacity(),
            self.completions.capacity(),
            self.act_transfers.capacity(),
            self.act_computes.capacity(),
            self.lat_transfers.capacity(),
            self.promoted.capacity(),
        ];
        caps.extend(self.maxmin.capacities());
        caps
    }
}

#[inline]
fn task_route(t: &Task) -> &[LinkId] {
    match &t.work {
        Work::Transfer { route, .. } => &route[..],
        Work::Compute { .. } => &[],
    }
}

fn meta_tag(meta: Option<&[TaskMeta]>, i: usize) -> String {
    match meta.map(|ms| &ms[i]) {
        Some(m) => match m.edge {
            Some(e) => format!(" (op {}, {:?}, edge {e})", m.op, m.phase),
            None => format!(" (op {}, {:?})", m.op, m.phase),
        },
        None => String::new(),
    }
}

/// Format up to eight offenders; diagnosable stalls at transformer
/// scale need op/phase/edge attribution, not just a count.
fn blocked_detail(
    ids: impl Iterator<Item = usize>,
    meta: Option<&[TaskMeta]>,
    per_id: impl Fn(usize) -> String,
) -> String {
    let mut detail = String::new();
    for (k, i) in ids.enumerate() {
        if k == 8 {
            detail.push_str(", ...");
            break;
        }
        if k > 0 {
            detail.push_str(", ");
        }
        detail.push_str(&format!("task {i}{}{}", meta_tag(meta, i), per_id(i)));
    }
    detail
}

#[cold]
fn stall_error(
    meta: Option<&[TaskMeta]>,
    unmet: &[usize],
    state: &[State],
    done: usize,
) -> Error {
    let n = state.len();
    let ids = (0..n).filter(|&i| state[i] == State::Pending);
    let detail =
        blocked_detail(ids, meta, |i| format!(" waiting on {} deps", unmet[i]));
    err!(
        "simulation stalled with {} tasks blocked on unmet dependencies \
         (cycle in the lowered task graph): {detail}",
        n - done
    )
}

#[cold]
fn deadlock_error(
    meta: Option<&[TaskMeta]>,
    act_transfers: &[usize],
    rate: &[f64],
) -> Error {
    let ids = act_transfers.iter().copied().filter(|&i| rate[i] <= 0.0);
    let detail = blocked_detail(ids, meta, |_| String::new());
    err!(
        "simulation deadlock: active transfer with zero rate \
         (zero-capacity link on a route?): {detail}"
    )
}

/// Advance the task graph to completion. Degenerate tasks (zero bytes,
/// empty route, zero duration) complete the instant their dependencies
/// do. Transfers pay `(hops - 1) * hop_latency_ns` serially before
/// draining at the max-min fair rate. Errors on dependency cycles and
/// on zero-rate deadlocks (zero-capacity links) instead of panicking.
pub(crate) fn run_tasks(
    graph: &LinkGraph,
    tasks: &[Task],
    hop_latency_ns: f64,
) -> Result<RunOutcome> {
    run_tasks_resumable(graph, tasks, hop_latency_ns, &[], None)
        .map(|(out, _)| out)
}

/// [`run_tasks`] with checkpoint recording and prefix resume, on a
/// fresh scratch. Allocating convenience wrapper over
/// [`run_tasks_into`] — hot callers (the incremental simulator, the
/// benches) thread their own [`SimScratch`] instead.
pub(crate) fn run_tasks_resumable(
    graph: &LinkGraph,
    tasks: &[Task],
    hop_latency_ns: f64,
    boundaries: &[usize],
    resume: Option<(&Checkpoint, &RunOutcome)>,
) -> Result<(RunOutcome, Vec<Checkpoint>)> {
    let mut scratch = SimScratch::default();
    let mut out = RunOutcome::default();
    let mut checkpoints = Vec::new();
    run_tasks_into(
        graph,
        tasks,
        None,
        hop_latency_ns,
        boundaries,
        resume,
        &mut scratch,
        &mut out,
        &mut checkpoints,
        None,
    )?;
    Ok((out, checkpoints))
}

/// The active-set DES event loop (PR 8) — bit-identical to the frozen
/// [`super::legacy::run_tasks_legacy`], asymptotically faster.
///
/// `boundaries` (strictly increasing task indices) mark the moments to
/// snapshot into `checkpoints`. `resume` restarts from a prior run's
/// [`Checkpoint`], copying the cached outcome's start/finish times for
/// the task prefix — valid only when `tasks[..boundary]` is
/// bit-identical to the run that produced the checkpoint. `meta`, when
/// present, enriches stall/deadlock errors with op/phase/edge ids.
///
/// # Bit-identity contract
///
/// The legacy loop scans all `n` tasks per event; this loop tracks
/// three index sets (draining transfers, running computes, transfers
/// paying fill latency) and touches only those, so steady-state cost is
/// O(active) per event. The floating-point stream is unchanged because
/// every arithmetic site preserves the legacy iteration order:
///
/// * `act_transfers` is kept sorted ascending, so per-link
///   `link_bytes` accumulation visits transfers in the same order as
///   the legacy `0..n` scan;
/// * completions are sorted ascending before processing, matching the
///   legacy completion order;
/// * `dt` is a fold of `f64::min` (order-independent) and per-task
///   decrements are independent, so set iteration order is immaterial
///   there;
/// * rates come from the component-wise incremental engine
///   ([`MaxMinScratch`]), bit-identical to the global
///   [`super::maxmin::maxmin_rates`] by the component decomposition
///   argument (asserted per event in debug builds).
///
/// Resuming replays the same arithmetic the full run would, so the
/// result is bit-identical (asserted in debug builds by
/// [`super::incremental::IncrementalSim`]).
#[allow(clippy::too_many_arguments)]
pub(crate) fn run_tasks_into(
    graph: &LinkGraph,
    tasks: &[Task],
    meta: Option<&[TaskMeta]>,
    hop_latency_ns: f64,
    boundaries: &[usize],
    resume: Option<(&Checkpoint, &RunOutcome)>,
    scratch: &mut SimScratch,
    out: &mut RunOutcome,
    checkpoints: &mut Vec<Checkpoint>,
    mut profile: Option<&mut SimProfile>,
) -> Result<()> {
    let n = tasks.len();
    let timed = profile.is_some();
    let SimScratch {
        unmet,
        state,
        remaining,
        lat_left,
        rate,
        dep_head,
        dep_list,
        dep_cursor,
        ready,
        completions,
        act_transfers,
        act_computes,
        lat_transfers,
        promoted,
        maxmin,
        ..
    } = scratch;
    let RunOutcome { start, finish, link_bytes, makespan_ns } = out;

    // ---- O(n + deps) per-run init, all on reused buffers.
    unmet.clear();
    unmet.extend(tasks.iter().map(|t| t.deps.len()));
    dep_head.clear();
    dep_head.resize(n + 1, 0);
    for (i, t) in tasks.iter().enumerate() {
        for &d in &t.deps {
            if d >= n {
                return Err(err!(
                    "task {i} depends on nonexistent task {d} (graph has \
                     {n} tasks)"
                ));
            }
            dep_head[d + 1] += 1;
        }
    }
    for d in 0..n {
        dep_head[d + 1] += dep_head[d];
    }
    dep_list.clear();
    dep_list.resize(dep_head[n], 0);
    dep_cursor.clear();
    dep_cursor.extend_from_slice(&dep_head[..n]);
    for (i, t) in tasks.iter().enumerate() {
        for &d in &t.deps {
            dep_list[dep_cursor[d]] = i;
            dep_cursor[d] += 1;
        }
    }
    state.clear();
    state.resize(n, State::Pending);
    remaining.clear();
    remaining.resize(n, 0.0);
    lat_left.clear();
    lat_left.resize(n, 0.0);
    rate.clear();
    rate.resize(n, 0.0);
    start.clear();
    start.resize(n, 0.0);
    finish.clear();
    finish.resize(n, 0.0);
    link_bytes.clear();
    link_bytes.resize(graph.links.len(), 0.0);
    checkpoints.clear();
    maxmin.begin_run(graph.links.len(), n);

    let mut done = 0usize;
    let mut now = 0.0f64;
    let mut next_ckpt = 0usize;

    let base = match resume {
        Some((ck, prev)) => {
            if ck.boundary > n
                || prev.start.len() < ck.boundary
                || prev.finish.len() < ck.boundary
                || ck.link_bytes.len() != link_bytes.len()
            {
                return Err(err!(
                    "resume checkpoint (boundary {}) does not fit the \
                     task graph ({} tasks, {} links)",
                    ck.boundary,
                    n,
                    link_bytes.len()
                ));
            }
            for i in 0..ck.boundary {
                state[i] = State::Done;
                start[i] = prev.start[i];
                finish[i] = prev.finish[i];
            }
            done = ck.boundary;
            now = ck.now;
            link_bytes.copy_from_slice(&ck.link_bytes);
            // Dependencies inside the resumed prefix are already met.
            for i in ck.boundary..n {
                unmet[i] = tasks[i]
                    .deps
                    .iter()
                    .filter(|&&d| d >= ck.boundary)
                    .count();
            }
            ck.boundary
        }
        None => 0,
    };
    while next_ckpt < boundaries.len() && boundaries[next_ckpt] <= base {
        next_ckpt += 1;
    }

    ready.clear();
    ready.extend((base..n).filter(|&i| unmet[i] == 0));
    completions.clear();
    act_transfers.clear();
    act_computes.clear();
    lat_transfers.clear();
    promoted.clear();

    let mut events = 0u64;
    let mut rate_ns = 0u64;
    let mut rebuild_ns = 0u64;
    let mut recomputes = 0u64;
    let mut comps_recomputed = 0u64;

    loop {
        // Activate ready tasks; degenerate ones complete instantly and
        // may cascade further activations at the same timestamp.
        // Transfers entering the draining set dirty their routes.
        let act_before = act_transfers.len();
        while let Some(i) = ready.pop() {
            start[i] = now;
            let instant = match &tasks[i].work {
                Work::Compute { dur_ns } => *dur_ns <= 0.0,
                Work::Transfer { route, bytes } => {
                    route.is_empty() || *bytes <= 0.0
                }
            };
            if instant {
                state[i] = State::Done;
                finish[i] = now;
                done += 1;
                for k in dep_head[i]..dep_head[i + 1] {
                    let d = dep_list[k];
                    unmet[d] -= 1;
                    if unmet[d] == 0 {
                        ready.push(d);
                    }
                }
            } else {
                match &tasks[i].work {
                    Work::Compute { dur_ns } => {
                        remaining[i] = *dur_ns;
                        state[i] = State::Active;
                        act_computes.push(i);
                    }
                    Work::Transfer { route, bytes } => {
                        remaining[i] = *bytes;
                        lat_left[i] =
                            (route.len() - 1) as f64 * hop_latency_ns;
                        if lat_left[i] > 0.0 {
                            state[i] = State::Latency;
                            lat_transfers.push(i);
                        } else {
                            state[i] = State::Active;
                            act_transfers.push(i);
                            maxmin.mark_route_dirty(route);
                        }
                    }
                }
            }
        }
        if done == n {
            break;
        }
        if act_transfers.is_empty()
            && act_computes.is_empty()
            && lat_transfers.is_empty()
        {
            return Err(stall_error(meta, unmet, state, done));
        }
        // Restore ascending order after new arrivals (the link-byte
        // accumulation order contract).
        if act_transfers.len() > act_before {
            act_transfers.sort_unstable();
        }
        events += 1;

        // Max-min fair rates over the transfers currently draining:
        // only components touching a dirty link recompute; a
        // transfer-free event skips the call outright.
        let t_rate = if timed { Some(std::time::Instant::now()) } else { None };
        let cs = maxmin.recompute(
            graph,
            act_transfers,
            |i| task_route(&tasks[i]),
            rate,
            timed,
        );
        if let Some(t0) = t_rate {
            rate_ns += t0.elapsed().as_nanos() as u64;
        }
        rebuild_ns += cs.rebuild_ns;
        if cs.recomputed > 0 {
            recomputes += 1;
            comps_recomputed += cs.recomputed;
        }
        #[cfg(debug_assertions)]
        {
            // The PR-8 correctness anchor: the incremental
            // component-wise rates must be bit-identical to the global
            // progressive-filling reference, every event.
            let routes_dbg: Vec<&[LinkId]> =
                tasks.iter().map(task_route).collect();
            let mut draining_dbg = vec![false; n];
            for &i in act_transfers.iter() {
                draining_dbg[i] = true;
            }
            let global =
                super::maxmin::maxmin_rates(graph, &routes_dbg, &draining_dbg);
            for &i in act_transfers.iter() {
                debug_assert!(
                    rate[i].to_bits() == global[i].to_bits(),
                    "component-wise max-min diverged from global for task \
                     {i}: {} vs {}",
                    rate[i],
                    global[i]
                );
            }
        }

        // Next event: a compute finishing, a fill latency elapsing, or
        // a transfer draining its last byte.
        let mut dt = f64::INFINITY;
        for &i in lat_transfers.iter() {
            dt = dt.min(lat_left[i]);
        }
        for &i in act_computes.iter() {
            dt = dt.min(remaining[i]);
        }
        for &i in act_transfers.iter() {
            if rate[i] > 0.0 {
                dt = dt.min(remaining[i] / rate[i]);
            }
        }
        if !dt.is_finite() {
            return Err(deadlock_error(meta, act_transfers, rate));
        }
        now += dt;

        // Advance each class. Latency promotions collect aside and
        // join the draining set after the byte accounting (they moved
        // no bytes this event).
        for &i in lat_transfers.iter() {
            lat_left[i] -= dt;
            if lat_left[i] <= 1e-12 {
                lat_left[i] = 0.0;
                state[i] = State::Active;
                promoted.push(i);
            }
        }
        let mut comp_done = false;
        for &i in act_computes.iter() {
            if let Work::Compute { dur_ns } = &tasks[i].work {
                remaining[i] -= dt;
                if remaining[i] <= 1e-9 * dur_ns.max(1.0) {
                    completions.push(i);
                    comp_done = true;
                }
            }
        }
        let mut xfer_done = false;
        for &i in act_transfers.iter() {
            if let Work::Transfer { route, bytes } = &tasks[i].work {
                if rate[i] > 0.0 {
                    let moved = rate[i] * dt;
                    remaining[i] -= moved;
                    for &l in route.iter() {
                        link_bytes[l] += moved;
                    }
                    if remaining[i] <= 1e-9 * bytes.max(1.0) {
                        completions.push(i);
                        xfer_done = true;
                    }
                }
            }
        }
        if !completions.is_empty() {
            // Legacy processed completions in ascending task id.
            completions.sort_unstable();
            for &i in completions.iter() {
                state[i] = State::Done;
                remaining[i] = 0.0;
                finish[i] = now;
                done += 1;
                for k in dep_head[i]..dep_head[i + 1] {
                    let d = dep_list[k];
                    unmet[d] -= 1;
                    if unmet[d] == 0 {
                        ready.push(d);
                    }
                }
                if let Work::Transfer { route, .. } = &tasks[i].work {
                    maxmin.mark_route_dirty(route);
                }
            }
            completions.clear();
            if comp_done {
                act_computes.retain(|&i| state[i] != State::Done);
            }
            if xfer_done {
                act_transfers.retain(|&i| state[i] != State::Done);
            }
        }
        if !promoted.is_empty() {
            lat_transfers.retain(|&i| state[i] == State::Latency);
            for &i in promoted.iter() {
                act_transfers.push(i);
                maxmin.mark_route_dirty(task_route(&tasks[i]));
            }
            promoted.clear();
            act_transfers.sort_unstable();
        }
        // Snapshot right after completions: the newly readied tasks
        // have not been activated yet, so a boundary hit here is a
        // quiescent cut. Boundaries crossed mid-cascade are skipped.
        while next_ckpt < boundaries.len() && done > boundaries[next_ckpt] {
            next_ckpt += 1;
        }
        if next_ckpt < boundaries.len() && done == boundaries[next_ckpt] {
            let b = boundaries[next_ckpt];
            debug_assert!(
                state[..b].iter().all(|s| *s == State::Done)
                    && state[b..].iter().all(|s| *s == State::Pending),
                "checkpoint boundary {b} is not a quiescent cut"
            );
            checkpoints.push(Checkpoint {
                boundary: b,
                now,
                link_bytes: link_bytes.clone(),
            });
            next_ckpt += 1;
        }
    }
    *makespan_ns = now;
    if let Some(p) = profile.as_deref_mut() {
        p.events += events;
        p.rate_recompute_ns += rate_ns;
        p.components_ns += rebuild_ns;
        p.rate_recomputes += recomputes;
        p.components_recomputed += comps_recomputed;
        p.tasks += n as u64;
    }
    Ok(())
}

// ---------------------------------------------------------------------
// Plan lowering
// ---------------------------------------------------------------------

/// Inter-op dependency policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum SimMode {
    /// Layer-sequential barrier between ops — the overlap assumption
    /// the analytical model makes, and what the conformance suite pins
    /// against.
    #[default]
    Conformance,
    /// Dataflow dependencies only: an op's load stage waits for its
    /// producers' writebacks (its compute, for redistributed edges);
    /// ops with no dataflow producers load at t=0. Weights ride the
    /// same gated load stage as the activations (no separate
    /// prefetch), so the exposed cross-layer pipelining headroom is a
    /// conservative bound. Not comparable to `cost::evaluate`.
    Overlap,
    /// The steady-state pipeline lowering ([`crate::steady`]): keeps
    /// the Conformance layer-sequential barrier *within* a batch (so a
    /// depth-1 pipeline degenerates to the single-batch conformance
    /// run), but gates load demand on stage-region membership — a
    /// chiplet whose partition share of an op is empty
    /// (`px[x] * py[y] == 0`) places zero load demand instead of the
    /// analytical model's per-row weight replication. On allocations
    /// where every chiplet holds work (e.g. the uniform allocation on
    /// ops with `m >= xdim`, `n >= ydim`) this lowering is
    /// bit-identical to Conformance; on stage-band allocations it stops
    /// idle stages from pulling weights they never consume.
    Pipelined,
}

/// Simulation knobs.
#[derive(Debug, Clone, Copy, Default)]
pub struct SimConfig {
    pub mode: SimMode,
    /// Serial head-flit latency per traversed hop beyond the first
    /// (wormhole fill). The analytical model has no per-hop constant,
    /// so conformance runs keep the 0.0 default.
    pub hop_latency_ns: f64,
}

/// Which stage of an op's lifecycle a task belongs to (timeline
/// attribution).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SimPhase {
    LoadOffchip,
    LoadOnchip,
    Redistribute,
    Compute,
    StoreOnchip,
    StoreOffchip,
}

#[derive(Debug, Clone, Copy)]
pub(crate) struct TaskMeta {
    op: usize,
    phase: SimPhase,
    edge: Option<EdgeId>,
}

/// A half-open simulated time window.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct Span {
    pub start_ns: f64,
    pub end_ns: f64,
}

impl Span {
    pub fn duration_ns(&self) -> f64 {
        self.end_ns - self.start_ns
    }
}

fn widen(slot: &mut Option<Span>, start: f64, end: f64) {
    match slot {
        Some(s) => {
            s.start_ns = s.start_ns.min(start);
            s.end_ns = s.end_ns.max(end);
        }
        None => *slot = Some(Span { start_ns: start, end_ns: end }),
    }
}

/// Per-op timeline: when its input stage (redistribution + loads), its
/// compute stage, and its writeback ran.
#[derive(Debug, Clone)]
pub struct OpSpan {
    pub op: usize,
    pub input: Span,
    pub compute: Span,
    /// `None` when the writeback was skipped (redistributed out-edge).
    pub output: Option<Span>,
}

impl OpSpan {
    /// The op's whole simulated window.
    pub fn total(&self) -> Span {
        Span {
            start_ns: self.input.start_ns.min(self.compute.start_ns),
            end_ns: self
                .output
                .map_or(self.compute.end_ns, |o| o.end_ns)
                .max(self.compute.end_ns),
        }
    }
}

/// Simulated energy, from the Table-2 constants applied to simulated
/// traffic: every byte crossing a NoP link is charged per link
/// traversal (the §4.4.3 per-hop coefficient), every byte through a
/// memory link at the off-chip energy, and compute/SRAM energy via the
/// same §4.4.1 model the evaluator uses.
#[derive(Debug, Clone, Copy, Default)]
pub struct SimEnergy {
    pub offchip_pj: f64,
    pub nop_pj: f64,
    pub compute_pj: f64,
}

impl SimEnergy {
    pub fn total_pj(&self) -> f64 {
        self.offchip_pj + self.nop_pj + self.compute_pj
    }
}

/// Everything the discrete-event run produced.
#[derive(Debug, Clone)]
pub struct SimReport {
    /// End-to-end simulated latency.
    pub makespan_ns: f64,
    /// Per-op stage windows, op-indexed.
    pub op_spans: Vec<OpSpan>,
    /// Per dataflow edge: the redistribution window, when the adaptive
    /// strategy adopted it (mirrors `OpCost::redistributed_in`).
    pub edge_spans: Vec<Option<Span>>,
    /// Total bytes carried per link of [`SimReport::graph`].
    pub link_bytes: Vec<f64>,
    /// The link graph the run executed on (chiplet mesh + memory nodes).
    pub graph: LinkGraph,
    pub energy: SimEnergy,
}

impl SimReport {
    /// Mean utilization per link over the whole run.
    pub fn utilization(&self) -> Vec<f64> {
        self.link_bytes
            .iter()
            .zip(&self.graph.links)
            .map(|(b, l)| {
                if self.makespan_ns > 0.0 {
                    b / (l.capacity * self.makespan_ns)
                } else {
                    0.0
                }
            })
            .collect()
    }

    /// The `k` busiest links, by mean utilization, descending (ties
    /// broken by link id for determinism).
    pub fn top_links(&self, k: usize) -> Vec<(LinkId, f64)> {
        let mut pairs: Vec<(LinkId, f64)> =
            self.utilization().into_iter().enumerate().collect();
        pairs.sort_by(|a, b| b.1.total_cmp(&a.1).then(a.0.cmp(&b.0)));
        pairs.truncate(k);
        pairs
    }

    /// Number of dataflow edges executed as on-package redistribution.
    pub fn redistributed_edges(&self) -> usize {
        self.edge_spans.iter().flatten().count()
    }

    /// Deterministic text summary (the golden-snapshot payload):
    /// makespan, energy split, redistributed-edge count and the top-5
    /// link utilizations.
    pub fn summary(&self) -> String {
        let mut s = String::new();
        s.push_str(&format!("makespan_ns {:.9e}\n", self.makespan_ns));
        s.push_str(&format!(
            "energy_pj total {:.9e} offchip {:.9e} nop {:.9e} compute \
             {:.9e}\n",
            self.energy.total_pj(),
            self.energy.offchip_pj,
            self.energy.nop_pj,
            self.energy.compute_pj
        ));
        s.push_str(&format!(
            "redistributed_edges {}\n",
            self.redistributed_edges()
        ));
        for (l, u) in self.top_links(5) {
            let link = &self.graph.links[l];
            s.push_str(&format!(
                "link {} -> {} util {:.9}\n",
                link.from, link.to, u
            ));
        }
        s
    }
}

fn push(
    tasks: &mut Vec<Task>,
    meta: &mut Vec<TaskMeta>,
    work: Work,
    deps: Vec<usize>,
    m: TaskMeta,
) -> usize {
    let id = tasks.len();
    tasks.push(Task { work, deps });
    meta.push(m);
    id
}

/// Gene-independent lowering context: the sole-edge maps (drive the
/// redistribution flag derivation) and the serving attachment per
/// chiplet. Built once per `(platform, workload)` binding and reused
/// across incremental re-lowerings.
pub(crate) struct LowerCtx {
    pub(crate) in_edge: Vec<Option<usize>>,
    pub(crate) out_edge: Vec<Option<usize>>,
    /// Serving attachment index per chiplet (row-major, matching
    /// chiplet node ids); memory nodes follow the chiplets in
    /// attachment declaration order.
    serving: Vec<usize>,
}

impl LowerCtx {
    pub(crate) fn new(plat: &Platform, wl: &Workload) -> LowerCtx {
        let (mut in_edge, mut out_edge) = (Vec::new(), Vec::new());
        wl.sole_edges_into(&mut in_edge, &mut out_edge);
        let atts = &plat.spec().attachments;
        let serving = plat
            .positions()
            .map(|p| {
                let g = plat.nearest_global(p);
                atts.iter()
                    .position(|a| a.pos == g)
                    .expect("nearest_global returns an attachment position")
            })
            .collect();
        LowerCtx { in_edge, out_edge, serving }
    }
}

/// The §6.1 adaptive decision for one dataflow edge, exactly as the
/// evaluator takes it (legality gate + adaptive strategy). Exposed per
/// edge so the incremental simulator can re-decide just the edges whose
/// genes changed.
pub(crate) fn edge_redist_decision(
    plat: &Platform,
    wl: &Workload,
    alloc: &Allocation,
    flags: OptFlags,
    ctx: &LowerCtx,
    e: usize,
    bufs: &mut TermBufs,
) -> bool {
    if !flags.redistribution
        || !wl.edge_redistributable_with(e, &ctx.in_edge, &ctx.out_edge)
    {
        return false;
    }
    let edge = wl.edges[e];
    edge_decision(
        plat,
        &wl.ops[edge.src],
        &wl.ops[edge.dst],
        &alloc.parts[edge.src],
        &alloc.parts[edge.dst],
        alloc.collect_cols[e],
        flags.diagonal,
        bufs,
    )
    .is_some()
}

/// One plan lowered to the event graph, with enough per-op structure to
/// re-lower a suffix in place (incremental re-simulation).
#[derive(Debug, Clone)]
pub(crate) struct LoweredPlan {
    pub(crate) tasks: Vec<Task>,
    pub(crate) meta: Vec<TaskMeta>,
    /// `tasks[op_task_start[i]..op_task_start[i + 1]]` belong to op `i`
    /// (length `n_ops + 1`).
    pub(crate) op_task_start: Vec<usize>,
    pub(crate) compute_ids: Vec<Vec<usize>>,
    pub(crate) op_done_ids: Vec<Vec<usize>>,
    pub(crate) redist_edge: Vec<bool>,
}

impl LoweredPlan {
    fn empty(wl: &Workload, redist_edge: Vec<bool>) -> LoweredPlan {
        LoweredPlan {
            tasks: Vec::new(),
            meta: Vec::new(),
            op_task_start: vec![0],
            compute_ids: Vec::with_capacity(wl.ops.len()),
            op_done_ids: Vec::with_capacity(wl.ops.len()),
            redist_edge,
        }
    }

    /// Drop every op at or after `frontier`, keeping the (unchanged)
    /// prefix; the incremental simulator then re-lowers the suffix.
    pub(crate) fn truncate_to_op(&mut self, frontier: usize) {
        let cut = self.op_task_start[frontier];
        self.tasks.truncate(cut);
        self.meta.truncate(cut);
        self.op_task_start.truncate(frontier + 1);
        self.compute_ids.truncate(frontier);
        self.op_done_ids.truncate(frontier);
    }
}

/// Lower every op of a plan (see the module docs for the lowering).
/// `ls` supplies the reusable per-op apportioning buffers and the
/// evaluator scratch the redistribution decisions run on.
#[allow(clippy::too_many_arguments)]
pub(crate) fn lower_plan(
    plat: &Platform,
    wl: &Workload,
    alloc: &Allocation,
    flags: OptFlags,
    mode: SimMode,
    ctx: &LowerCtx,
    graph: &LinkGraph,
    routes: &mut RouteCache,
    ls: &mut LowerScratch,
) -> Result<LoweredPlan> {
    let redist_edge: Vec<bool> = (0..wl.edges.len())
        .map(|e| {
            edge_redist_decision(plat, wl, alloc, flags, ctx, e, &mut ls.bufs)
        })
        .collect();
    let mut lp = LoweredPlan::empty(wl, redist_edge);
    for i in 0..wl.ops.len() {
        lower_op(
            plat, wl, alloc, flags, mode, ctx, graph, routes, ls, i, &mut lp,
        )?;
    }
    Ok(lp)
}

/// Append op `i`'s tasks to `lp` (redistribution, load, compute,
/// writeback — the module-docs lowering). Requires ops `0..i` already
/// lowered; `lp.redist_edge` must hold the adopted decisions for every
/// edge incident to ops `<= i`.
#[allow(clippy::too_many_arguments)]
pub(crate) fn lower_op(
    plat: &Platform,
    wl: &Workload,
    alloc: &Allocation,
    flags: OptFlags,
    mode: SimMode,
    ctx: &LowerCtx,
    graph: &LinkGraph,
    rc: &mut RouteCache,
    ls: &mut LowerScratch,
    i: usize,
    lp: &mut LoweredPlan,
) -> Result<()> {
    let LowerScratch { demand, att_demand, att_out, .. } = ls;
    let n_chiplets = plat.num_chiplets();
    let atts = &plat.spec().attachments;
    let att_node = |a: usize| -> NodeId { n_chiplets + a };
    {
        let op = &wl.ops[i];
        let part = &alloc.parts[i];
        let acts_from_redist =
            ctx.in_edge[i].is_some_and(|e| lp.redist_edge[e]);
        let skip_store =
            ctx.out_edge[i].is_some_and(|e| lp.redist_edge[e]);
        let load_acts = !acts_from_redist;
        let barrier: Vec<usize> = match mode {
            SimMode::Conformance | SimMode::Pipelined => {
                if i == 0 {
                    Vec::new()
                } else {
                    lp.op_done_ids[i - 1].clone()
                }
            }
            SimMode::Overlap => Vec::new(),
        };

        // ---- incoming redistribution: §5.2 steps 1-3 as real flows.
        let mut redist_last: Vec<usize> = Vec::new();
        if acts_from_redist {
            let e = ctx.in_edge[i].expect("redistributed op has an edge");
            let edge = wl.edges[e];
            let p_op = &wl.ops[edge.src];
            let p_part = &alloc.parts[edge.src];
            let c_star = alloc.collect_cols[e];
            let mut deps0: Vec<usize> = barrier.clone();
            deps0.extend(lp.compute_ids[edge.src].iter().copied());
            let rmeta =
                TaskMeta { op: i, phase: SimPhase::Redistribute, edge: Some(e) };

            // Step 1: row reduction toward the collection column.
            let mut step1: Vec<usize> = Vec::new();
            for x in 0..plat.xdim {
                for y in 0..plat.ydim {
                    if y == c_star {
                        continue;
                    }
                    let bytes = plat.bytes(p_part.px[x] * p_part.py[y]);
                    if bytes <= 0.0 {
                        continue;
                    }
                    let route = rc.route(
                        graph,
                        graph.chiplet_id(Pos::new(x, y)),
                        graph.chiplet_id(Pos::new(x, c_star)),
                    )?;
                    step1.push(push(
                        &mut lp.tasks,
                        &mut lp.meta,
                        Work::Transfer { route, bytes },
                        deps0.clone(),
                        rmeta,
                    ));
                }
            }
            // Step 2: wormhole row broadcast — one wavefront per
            // direction, one block transfer of Px[x] x N bytes.
            let s2_deps =
                if step1.is_empty() { deps0.clone() } else { step1.clone() };
            let mut step2: Vec<usize> = Vec::new();
            for x in 0..plat.xdim {
                let row_bytes = plat.bytes(p_part.px[x] * p_op.n);
                if row_bytes <= 0.0 {
                    continue;
                }
                let src = graph.chiplet_id(Pos::new(x, c_star));
                for far in [0usize, plat.ydim - 1] {
                    if far == c_star {
                        continue;
                    }
                    let route = rc
                        .route(graph, src, graph.chiplet_id(Pos::new(x, far)))?;
                    step2.push(push(
                        &mut lp.tasks,
                        &mut lp.meta,
                        Work::Transfer { route, bytes: row_bytes },
                        s2_deps.clone(),
                        rmeta,
                    ));
                }
            }
            // Step 3: per-boundary cross-row moves, bytes from the
            // shared `redistribution::step3_boundary_bytes` helper (one
            // source of truth with the closed form). Direction does not
            // affect fluid timing — each boundary's duplex vertical
            // link pair is dedicated — so flows go row b -> b+1.
            let s3_deps =
                if step2.is_empty() { s2_deps } else { step2.clone() };
            let boundary_bytes = crate::redistribution::step3_boundary_bytes(
                plat, p_op, p_part, part,
            );
            let mut step3: Vec<usize> = Vec::new();
            for (b, &bytes) in boundary_bytes.iter().enumerate() {
                if bytes <= 0.0 {
                    continue;
                }
                let route = rc.route(
                    graph,
                    graph.chiplet_id(Pos::new(b, c_star)),
                    graph.chiplet_id(Pos::new(b + 1, c_star)),
                )?;
                step3.push(push(
                    &mut lp.tasks,
                    &mut lp.meta,
                    Work::Transfer { route, bytes },
                    s3_deps.clone(),
                    rmeta,
                ));
            }
            redist_last = if step3.is_empty() { s3_deps } else { step3 };
        }

        // ---- load: demand-apportioned off-chip pull, then unicast
        // on-chip distribution.
        let load_deps: Vec<usize> = if acts_from_redist {
            redist_last
        } else {
            match mode {
                SimMode::Conformance | SimMode::Pipelined => barrier.clone(),
                SimMode::Overlap => {
                    // Activations come out of memory: wait for every
                    // producer's writeback (its compute, if the
                    // producer skipped its store).
                    let mut d = Vec::new();
                    for edge in wl.edges.iter().filter(|e| e.dst == i) {
                        d.extend(lp.op_done_ids[edge.src].iter().copied());
                    }
                    d
                }
            }
        };
        let mut off_unique = plat.bytes(op.k * op.n);
        if load_acts {
            off_unique += plat.bytes(op.m * op.k);
        }
        demand.clear();
        demand.resize(n_chiplets, 0.0);
        for (idx, p) in plat.positions().enumerate() {
            let Pos { row: x, col: y } = p;
            let mut d = plat.bytes(op.k * part.py[y]);
            if load_acts {
                d += plat.bytes(part.px[x] * op.k);
            }
            // Pipelined region gating: a chiplet with no share of this
            // op computes nothing, so it loads nothing — otherwise a
            // stage-band allocation would broadcast every stage's
            // weights to every row (the analytical per-row replication
            // the Conformance mode deliberately preserves).
            if mode == SimMode::Pipelined && part.px[x] * part.py[y] == 0 {
                d = 0.0;
            }
            demand[idx] = d;
        }
        let total_demand: f64 = demand.iter().sum();
        att_demand.clear();
        att_demand.resize(atts.len(), 0.0);
        for idx in 0..n_chiplets {
            att_demand[ctx.serving[idx]] += demand[idx];
        }
        let mut off_tasks: Vec<usize> = Vec::new();
        for (a, att) in atts.iter().enumerate() {
            let share = if total_demand > 0.0 {
                att_demand[a] / total_demand
            } else {
                0.0
            };
            let bytes = off_unique * share;
            if bytes <= 0.0 {
                continue;
            }
            let route =
                rc.route(graph, att_node(a), graph.chiplet_id(att.pos))?;
            off_tasks.push(push(
                &mut lp.tasks,
                &mut lp.meta,
                Work::Transfer { route, bytes },
                load_deps.clone(),
                TaskMeta { op: i, phase: SimPhase::LoadOffchip, edge: None },
            ));
        }
        let dist_deps =
            if off_tasks.is_empty() { load_deps } else { off_tasks };
        let mut dist_tasks: Vec<usize> = Vec::with_capacity(n_chiplets);
        for (idx, p) in plat.positions().enumerate() {
            let route = rc.route(
                graph,
                graph.chiplet_id(plat.nearest_global(p)),
                graph.chiplet_id(p),
            )?;
            dist_tasks.push(push(
                &mut lp.tasks,
                &mut lp.meta,
                Work::Transfer { route, bytes: demand[idx] },
                dist_deps.clone(),
                TaskMeta { op: i, phase: SimPhase::LoadOnchip, edge: None },
            ));
        }

        // ---- compute.
        let mut comp_tasks: Vec<usize> = Vec::with_capacity(n_chiplets);
        for (idx, p) in plat.positions().enumerate() {
            let Pos { row: x, col: y } = p;
            let dur = comp_ns(plat, op, part.px[x], part.py[y]);
            let deps = if flags.async_fusion {
                vec![dist_tasks[idx]]
            } else {
                dist_tasks.clone()
            };
            comp_tasks.push(push(
                &mut lp.tasks,
                &mut lp.meta,
                Work::Compute { dur_ns: dur },
                deps,
                TaskMeta { op: i, phase: SimPhase::Compute, edge: None },
            ));
        }

        // ---- writeback (skipped when a redistributed out-edge
        // replaces the store).
        let op_done: Vec<usize> = if skip_store {
            comp_tasks.clone()
        } else {
            let out_total = plat.bytes(op.m * op.n);
            att_out.clear();
            att_out.resize(atts.len(), 0.0);
            let mut collect_tasks: Vec<usize> =
                Vec::with_capacity(n_chiplets);
            for (idx, p) in plat.positions().enumerate() {
                let Pos { row: x, col: y } = p;
                let bytes = plat.bytes(part.px[x] * part.py[y]);
                att_out[ctx.serving[idx]] += bytes;
                let route = rc.route(
                    graph,
                    graph.chiplet_id(p),
                    graph.chiplet_id(plat.nearest_global(p)),
                )?;
                collect_tasks.push(push(
                    &mut lp.tasks,
                    &mut lp.meta,
                    Work::Transfer { route, bytes },
                    comp_tasks.clone(),
                    TaskMeta {
                        op: i,
                        phase: SimPhase::StoreOnchip,
                        edge: None,
                    },
                ));
            }
            let total_out: f64 = att_out.iter().sum();
            let mut store_off: Vec<usize> = Vec::new();
            for (a, att) in atts.iter().enumerate() {
                let share =
                    if total_out > 0.0 { att_out[a] / total_out } else { 0.0 };
                let bytes = out_total * share;
                if bytes <= 0.0 {
                    continue;
                }
                let route =
                    rc.route(graph, graph.chiplet_id(att.pos), att_node(a))?;
                store_off.push(push(
                    &mut lp.tasks,
                    &mut lp.meta,
                    Work::Transfer { route, bytes },
                    collect_tasks.clone(),
                    TaskMeta {
                        op: i,
                        phase: SimPhase::StoreOffchip,
                        edge: None,
                    },
                ));
            }
            if store_off.is_empty() { collect_tasks } else { store_off }
        };
        lp.op_done_ids.push(op_done);
        lp.compute_ids.push(comp_tasks);
    }
    lp.op_task_start.push(lp.tasks.len());
    Ok(())
}

/// Lower a plan to the event graph and run it to completion (see the
/// module docs for the lowering). `flags` must be the *effective* flags
/// the plan was scored under (`Plan::flags`), so the simulator adopts
/// exactly the redistribution decisions the evaluator priced.
pub fn simulate_plan(
    plat: &Platform,
    wl: &Workload,
    alloc: &Allocation,
    flags: OptFlags,
    cfg: &SimConfig,
) -> Result<SimReport> {
    simulate_plan_inner(plat, wl, alloc, flags, cfg, None)
}

/// [`simulate_plan`] with a wall-clock/work profile of the run
/// (`simulate --profile`): lowering vs event loop vs rate recompute vs
/// component rebuild, plus event/recompute counters. The report is
/// bit-identical to the unprofiled run.
pub fn simulate_plan_profiled(
    plat: &Platform,
    wl: &Workload,
    alloc: &Allocation,
    flags: OptFlags,
    cfg: &SimConfig,
) -> Result<(SimReport, SimProfile)> {
    let mut profile = SimProfile::default();
    let report =
        simulate_plan_inner(plat, wl, alloc, flags, cfg, Some(&mut profile))?;
    Ok((report, profile))
}

fn simulate_plan_inner(
    plat: &Platform,
    wl: &Workload,
    alloc: &Allocation,
    flags: OptFlags,
    cfg: &SimConfig,
    mut profile: Option<&mut SimProfile>,
) -> Result<SimReport> {
    if alloc.parts.len() != wl.ops.len()
        || alloc.collect_cols.len() != wl.edges.len()
    {
        return Err(err!(
            "allocation arity mismatch: {} partitions / {} collect cols \
             for {} ops / {} edges",
            alloc.parts.len(),
            alloc.collect_cols.len(),
            wl.ops.len(),
            wl.edges.len()
        ));
    }
    let graph = plat.link_graph_shared(flags.diagonal);
    let ctx = LowerCtx::new(plat, wl);
    let mut rc = RouteCache::new();
    let mut scratch = SimScratch::default();
    let t_lower = std::time::Instant::now();
    let lp = lower_plan(
        plat,
        wl,
        alloc,
        flags,
        cfg.mode,
        &ctx,
        &graph,
        &mut rc,
        &mut scratch.lower,
    )?;
    let lower_ns = t_lower.elapsed().as_nanos() as u64;
    let mut run = RunOutcome::default();
    let mut checkpoints = Vec::new();
    let t_loop = std::time::Instant::now();
    run_tasks_into(
        &graph,
        &lp.tasks,
        Some(&lp.meta),
        cfg.hop_latency_ns,
        &[],
        None,
        &mut scratch,
        &mut run,
        &mut checkpoints,
        profile.as_deref_mut(),
    )?;
    if let Some(p) = profile.as_deref_mut() {
        p.lower_ns += lower_ns;
        p.event_loop_ns += t_loop.elapsed().as_nanos() as u64;
    }
    Ok(assemble_report(plat, wl, alloc, &graph, &lp, &run))
}

/// Pre-lowered task graph plus warm engine state, for the DES benches
/// (`benches/sim_conformance.rs`) and the scratch-reuse perf-pin test.
/// Hidden from docs: not a stable API.
#[doc(hidden)]
pub struct SimBench {
    graph: Arc<LinkGraph>,
    tasks: Vec<Task>,
    meta: Vec<TaskMeta>,
    scratch: SimScratch,
    out: RunOutcome,
    checkpoints: Vec<Checkpoint>,
}

impl SimBench {
    /// Lower `(platform, workload, allocation)` in Conformance mode,
    /// optionally truncating to the first `prefix_ops` ops (the
    /// layer-sequential lowering makes dependencies prefix-closed, so
    /// a truncated graph is a valid run).
    pub fn lower(
        plat: &Platform,
        wl: &Workload,
        alloc: &Allocation,
        flags: OptFlags,
        prefix_ops: Option<usize>,
    ) -> Result<SimBench> {
        let graph = plat.link_graph_shared(flags.diagonal);
        let ctx = LowerCtx::new(plat, wl);
        let mut rc = RouteCache::new();
        let mut scratch = SimScratch::default();
        let mut lp = lower_plan(
            plat,
            wl,
            alloc,
            flags,
            SimMode::Conformance,
            &ctx,
            &graph,
            &mut rc,
            &mut scratch.lower,
        )?;
        if let Some(k) = prefix_ops {
            if k < wl.ops.len() {
                lp.truncate_to_op(k);
            }
        }
        Ok(SimBench {
            graph,
            tasks: lp.tasks,
            meta: lp.meta,
            scratch,
            out: RunOutcome::default(),
            checkpoints: Vec::new(),
        })
    }

    pub fn task_count(&self) -> usize {
        self.tasks.len()
    }

    /// One full run on the active-set engine, reusing the warm
    /// scratch. Returns the makespan.
    pub fn run_new(&mut self) -> Result<f64> {
        run_tasks_into(
            &self.graph,
            &self.tasks,
            Some(&self.meta),
            0.0,
            &[],
            None,
            &mut self.scratch,
            &mut self.out,
            &mut self.checkpoints,
            None,
        )?;
        Ok(self.out.makespan_ns)
    }

    /// One run on the frozen pre-PR-8 loop ([`super::legacy`]).
    pub fn run_legacy(&self) -> Result<f64> {
        super::legacy::run_tasks_legacy(&self.graph, &self.tasks, 0.0, &[], None)
            .map(|(o, _)| o.makespan_ns)
    }

    /// Run both engines and require bit-identical outcomes
    /// (start/finish per task, bytes per link, makespan).
    pub fn assert_parity(&mut self) -> Result<()> {
        self.run_new()?;
        let (old, _) = super::legacy::run_tasks_legacy(
            &self.graph,
            &self.tasks,
            0.0,
            &[],
            None,
        )?;
        ensure!(
            self.out.makespan_ns.to_bits() == old.makespan_ns.to_bits(),
            "engine parity: makespan {} vs legacy {}",
            self.out.makespan_ns,
            old.makespan_ns
        );
        for i in 0..self.tasks.len() {
            ensure!(
                self.out.start[i].to_bits() == old.start[i].to_bits()
                    && self.out.finish[i].to_bits() == old.finish[i].to_bits(),
                "engine parity: task {i} window ({}, {}) vs legacy ({}, {})",
                self.out.start[i],
                self.out.finish[i],
                old.start[i],
                old.finish[i]
            );
        }
        for (l, b) in old.link_bytes.iter().enumerate() {
            ensure!(
                self.out.link_bytes[l].to_bits() == b.to_bits(),
                "engine parity: link {l} bytes {} vs legacy {b}",
                self.out.link_bytes[l]
            );
        }
        Ok(())
    }

    /// Capacity fingerprint of every reusable buffer (perf-pin test).
    pub fn scratch_capacities(&self) -> Vec<usize> {
        self.scratch.capacities()
    }
}

/// Fold a raw event-loop outcome into the public [`SimReport`] (stage
/// spans, per-edge exchange windows, Table-2 energy from the simulated
/// traffic).
pub(crate) fn assemble_report(
    plat: &Platform,
    wl: &Workload,
    alloc: &Allocation,
    graph: &LinkGraph,
    lp: &LoweredPlan,
    run: &RunOutcome,
) -> SimReport {
    let n_ops = wl.ops.len();
    let ne = wl.edges.len();
    let n_chiplets = plat.num_chiplets();

    // ---- spans, per op and per redistributed edge.
    let mut input: Vec<Option<Span>> = vec![None; n_ops];
    let mut compute: Vec<Option<Span>> = vec![None; n_ops];
    let mut output: Vec<Option<Span>> = vec![None; n_ops];
    let mut edge_spans: Vec<Option<Span>> = vec![None; ne];
    for (t, m) in lp.meta.iter().enumerate() {
        let (s, f) = (run.start[t], run.finish[t]);
        match m.phase {
            SimPhase::LoadOffchip
            | SimPhase::LoadOnchip
            | SimPhase::Redistribute => widen(&mut input[m.op], s, f),
            SimPhase::Compute => widen(&mut compute[m.op], s, f),
            SimPhase::StoreOnchip | SimPhase::StoreOffchip => {
                widen(&mut output[m.op], s, f)
            }
        }
        if let Some(e) = m.edge {
            widen(&mut edge_spans[e], s, f);
        }
    }
    let op_spans: Vec<OpSpan> = (0..n_ops)
        .map(|i| OpSpan {
            op: i,
            input: input[i].unwrap_or_default(),
            compute: compute[i].unwrap_or_default(),
            output: output[i],
        })
        .collect();

    // ---- energy from simulated traffic + the shared compute model.
    let mut energy = SimEnergy::default();
    for (l, link) in graph.links.iter().enumerate() {
        let bits = run.link_bytes[l] * 8.0;
        if link.from >= n_chiplets || link.to >= n_chiplets {
            energy.offchip_pj += bits * plat.mem_pj_bit;
        } else {
            energy.nop_pj += bits * plat.energy.nop_pj_bit_hop;
        }
    }
    energy.compute_pj = wl
        .ops
        .iter()
        .zip(&alloc.parts)
        .map(|(op, part)| comp_energy_pj(plat, op, part))
        .sum();

    SimReport {
        makespan_ns: run.makespan_ns,
        op_spans,
        edge_spans,
        link_bytes: run.link_bytes.clone(),
        graph: graph.clone(),
        energy,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{MemKind, SystemType};
    use crate::cost::evaluator::evaluate;
    use crate::partition::uniform_allocation;
    use crate::workload::models::{alexnet, evaluation_suite};
    use crate::workload::{GemmOp, Workload};

    fn sim(
        plat: &Platform,
        wl: &Workload,
        flags: OptFlags,
        mode: SimMode,
    ) -> SimReport {
        let alloc = uniform_allocation(plat, wl);
        simulate_plan(
            plat,
            wl,
            &alloc,
            flags,
            &SimConfig { mode, hop_latency_ns: 0.0 },
        )
        .expect("plan simulates")
    }

    #[test]
    fn type_c_single_op_matches_analytical_exactly() {
        // 3D-stacked: no on-chip stages in either model, so simulated
        // and analytical decompositions coincide term by term.
        let plat = Platform::preset(SystemType::C, MemKind::Hbm, 4);
        let wl =
            Workload::new("w", vec![GemmOp::dense("a", 512, 256, 512)]);
        let alloc = uniform_allocation(&plat, &wl);
        let analytical =
            evaluate(&plat, &wl, &alloc, OptFlags::NONE).latency_ns;
        let r = sim(&plat, &wl, OptFlags::NONE, SimMode::Conformance);
        let rel = (r.makespan_ns - analytical).abs() / analytical;
        assert!(
            rel < 1e-6,
            "sim {} vs analytical {analytical} (rel {rel})",
            r.makespan_ns
        );
    }

    #[test]
    fn redistribution_window_matches_analytical_steps() {
        // On a congestion-free package the fluid step times equal the
        // closed-form RedistCost terms, so the simulated exchange
        // window must equal step1+step2+step3.
        let plat = Platform::preset(SystemType::A, MemKind::Hbm, 4);
        let wl = Workload::new(
            "w",
            vec![
                GemmOp::dense("a", 512, 128, 512),
                GemmOp::dense("b", 512, 512, 256).chained(),
            ],
        );
        let alloc = uniform_allocation(&plat, &wl);
        let flags = OptFlags {
            redistribution: true,
            diagonal: false,
            async_fusion: false,
        };
        let analytical = evaluate(&plat, &wl, &alloc, flags);
        assert!(
            analytical.per_op[1].redistributed_in,
            "test premise: redistribution adopted"
        );
        let expected = crate::redistribution::redistribute_edge(
            &plat, &wl, &alloc, 0,
        )
        .total_ns();
        let cfg = SimConfig::default();
        let r = simulate_plan(&plat, &wl, &alloc, flags, &cfg).unwrap();
        let span = r.edge_spans[0].expect("edge 0 redistributed in sim");
        let rel = (span.duration_ns() - expected).abs() / expected;
        assert!(
            rel < 1e-6,
            "sim window {} vs analytical {expected} (rel {rel})",
            span.duration_ns()
        );
        assert_eq!(r.redistributed_edges(), 1);

        // Skewed consumer partition: step 3 is nonzero (cross-row
        // moves) and the fluid window must still equal all three
        // closed-form steps.
        let mut alloc2 = alloc.clone();
        alloc2.parts[1] = crate::partition::Partition {
            px: vec![200, 120, 120, 72],
            py: vec![64; 4],
        };
        let analytical2 = evaluate(&plat, &wl, &alloc2, flags);
        assert!(
            analytical2.per_op[1].redistributed_in,
            "test premise: still adopted under the skewed consumer"
        );
        let r2c = crate::redistribution::redistribute_edge(
            &plat, &wl, &alloc2, 0,
        );
        assert!(r2c.step3_ns > 0.0, "skew must exercise step 3");
        let r2 = simulate_plan(&plat, &wl, &alloc2, flags, &cfg).unwrap();
        let span2 = r2.edge_spans[0].expect("still redistributed");
        let rel2 = (span2.duration_ns() - r2c.total_ns()).abs()
            / r2c.total_ns();
        assert!(
            rel2 < 1e-6,
            "skewed sim window {} vs analytical {} (rel {rel2})",
            span2.duration_ns(),
            r2c.total_ns()
        );
    }

    #[test]
    fn sim_redistribution_decisions_match_evaluator() {
        // The simulator reuses the evaluator's adaptive strategy, so
        // per-edge adoption must agree exactly on every zoo model.
        let plat = Platform::headline();
        for wl in evaluation_suite(1) {
            let alloc = uniform_allocation(&plat, &wl);
            let analytical = evaluate(&plat, &wl, &alloc, OptFlags::ALL);
            let n_model = analytical
                .per_op
                .iter()
                .filter(|o| o.redistributed_in)
                .count();
            let r = sim(&plat, &wl, OptFlags::ALL, SimMode::Conformance);
            assert_eq!(
                r.redistributed_edges(),
                n_model,
                "{}: sim and evaluator disagree on redistribution",
                wl.name
            );
        }
    }

    #[test]
    fn async_fusion_never_slower_in_sim() {
        let plat = Platform::headline();
        let wl =
            Workload::new("w", vec![GemmOp::dense("a", 4096, 512, 4096)]);
        let sync = sim(
            &plat,
            &wl,
            OptFlags { async_fusion: false, ..OptFlags::NONE },
            SimMode::Conformance,
        );
        let fused = sim(
            &plat,
            &wl,
            OptFlags { async_fusion: true, ..OptFlags::NONE },
            SimMode::Conformance,
        );
        assert!(
            fused.makespan_ns <= sync.makespan_ns + 1e-9,
            "fused {} > sync {}",
            fused.makespan_ns,
            sync.makespan_ns
        );
    }

    #[test]
    fn zoo_simulates_finite_on_presets() {
        for ty in SystemType::ALL {
            let plat = Platform::preset(ty, MemKind::Hbm, 4);
            for wl in evaluation_suite(1) {
                let r =
                    sim(&plat, &wl, OptFlags::ALL, SimMode::Conformance);
                assert!(
                    r.makespan_ns.is_finite() && r.makespan_ns > 0.0,
                    "{}/{:?}",
                    wl.name,
                    ty
                );
                assert!(r.energy.total_pj() > 0.0);
                for u in r.utilization() {
                    assert!((0.0..=1.0 + 1e-9).contains(&u));
                }
                assert_eq!(r.op_spans.len(), wl.ops.len());
                // Stage windows are ordered per op.
                for s in &r.op_spans {
                    assert!(
                        s.compute.end_ns >= s.input.start_ns - 1e-9
                    );
                    if let Some(out) = s.output {
                        assert!(out.end_ns >= s.compute.start_ns - 1e-9);
                    }
                }
            }
        }
    }

    #[test]
    fn overlap_mode_is_sane_and_no_slower_than_ls_within_margin() {
        let plat = Platform::headline();
        let wl = alexnet(1);
        let conf = sim(&plat, &wl, OptFlags::ALL, SimMode::Conformance);
        let over = sim(&plat, &wl, OptFlags::ALL, SimMode::Overlap);
        assert!(over.makespan_ns.is_finite() && over.makespan_ns > 0.0);
        // Fewer dependencies, same work: fluid-schedule anomalies are
        // possible in principle but must stay small.
        assert!(
            over.makespan_ns <= conf.makespan_ns * 1.5,
            "overlap {} vs conformance {}",
            over.makespan_ns,
            conf.makespan_ns
        );
    }

    #[test]
    fn hop_latency_config_slows_conformance_run() {
        let plat = Platform::headline();
        let wl = alexnet(1);
        let alloc = uniform_allocation(&plat, &wl);
        let base = simulate_plan(
            &plat,
            &wl,
            &alloc,
            OptFlags::NONE,
            &SimConfig::default(),
        )
        .unwrap();
        let lat = simulate_plan(
            &plat,
            &wl,
            &alloc,
            OptFlags::NONE,
            &SimConfig { mode: SimMode::Conformance, hop_latency_ns: 50.0 },
        )
        .unwrap();
        assert!(lat.makespan_ns > base.makespan_ns);
    }

    #[test]
    fn arity_mismatch_is_a_structured_error() {
        let plat = Platform::headline();
        let wl = alexnet(1);
        let mut alloc = uniform_allocation(&plat, &wl);
        alloc.parts.pop();
        let err = simulate_plan(
            &plat,
            &wl,
            &alloc,
            OptFlags::NONE,
            &SimConfig::default(),
        )
        .unwrap_err();
        assert!(err.to_string().contains("arity"), "{err}");
    }

    /// Lower a plan, run it on both engines (with checkpoints) and
    /// require bit-identical outcomes end to end.
    fn parity_case(
        plat: &Platform,
        wl: &Workload,
        alloc: &crate::partition::Allocation,
        flags: OptFlags,
        hop: f64,
    ) {
        let graph = plat.link_graph_shared(flags.diagonal);
        let ctx = LowerCtx::new(plat, wl);
        let mut rc = RouteCache::new();
        let mut scratch = SimScratch::default();
        let lp = lower_plan(
            plat,
            wl,
            alloc,
            flags,
            SimMode::Conformance,
            &ctx,
            &graph,
            &mut rc,
            &mut scratch.lower,
        )
        .expect("plan lowers");
        let bounds: Vec<usize> =
            lp.op_task_start[1..lp.op_task_start.len() - 1].to_vec();
        let mut out = RunOutcome::default();
        let mut cks = Vec::new();
        run_tasks_into(
            &graph,
            &lp.tasks,
            Some(&lp.meta),
            hop,
            &bounds,
            None,
            &mut scratch,
            &mut out,
            &mut cks,
            None,
        )
        .expect("new engine runs");
        let (old, old_cks) = crate::netsim::legacy::run_tasks_legacy(
            &graph, &lp.tasks, hop, &bounds, None,
        )
        .expect("legacy engine runs");
        assert_eq!(
            out.makespan_ns.to_bits(),
            old.makespan_ns.to_bits(),
            "{}: makespan {} vs legacy {}",
            wl.name,
            out.makespan_ns,
            old.makespan_ns
        );
        for i in 0..lp.tasks.len() {
            assert_eq!(out.start[i].to_bits(), old.start[i].to_bits());
            assert_eq!(out.finish[i].to_bits(), old.finish[i].to_bits());
        }
        for l in 0..old.link_bytes.len() {
            assert_eq!(
                out.link_bytes[l].to_bits(),
                old.link_bytes[l].to_bits(),
                "link {l}"
            );
        }
        assert_eq!(cks.len(), old_cks.len(), "checkpoint schedules differ");
        for (a, b) in cks.iter().zip(&old_cks) {
            assert_eq!(a.boundary, b.boundary);
            assert_eq!(a.now.to_bits(), b.now.to_bits());
            for (x, y) in a.link_bytes.iter().zip(&b.link_bytes) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
        }
    }

    #[test]
    fn active_set_engine_matches_legacy_bit_for_bit() {
        // The PR-8 acceptance anchor, on lowered plans that exercise
        // every task class: contended loads, redistribution steps
        // (incl. nonzero step 3 under a skewed consumer), async
        // fusion, writebacks, and nonzero fill latency.
        let headline = Platform::headline();
        let wl = alexnet(1);
        let alloc = uniform_allocation(&headline, &wl);
        parity_case(&headline, &wl, &alloc, OptFlags::ALL, 0.0);
        parity_case(&headline, &wl, &alloc, OptFlags::NONE, 50.0);

        let plat_c = Platform::preset(SystemType::C, MemKind::Hbm, 4);
        let wl1 =
            Workload::new("w", vec![GemmOp::dense("a", 512, 256, 512)]);
        let alloc1 = uniform_allocation(&plat_c, &wl1);
        parity_case(&plat_c, &wl1, &alloc1, OptFlags::NONE, 0.0);

        let plat_a = Platform::preset(SystemType::A, MemKind::Hbm, 4);
        let wl2 = Workload::new(
            "w2",
            vec![
                GemmOp::dense("a", 512, 128, 512),
                GemmOp::dense("b", 512, 512, 256).chained(),
            ],
        );
        let mut alloc2 = uniform_allocation(&plat_a, &wl2);
        alloc2.parts[1] = crate::partition::Partition {
            px: vec![200, 120, 120, 72],
            py: vec![64; 4],
        };
        let flags = OptFlags {
            redistribution: true,
            diagonal: false,
            async_fusion: false,
        };
        parity_case(&plat_a, &wl2, &alloc2, flags, 0.0);
    }

    #[test]
    fn stall_error_names_blocked_tasks() {
        let graph = LinkGraph::mesh(1, 2, false, 60.0);
        let tasks = [
            Task { work: Work::Compute { dur_ns: 5.0 }, deps: vec![1] },
            Task { work: Work::Compute { dur_ns: 5.0 }, deps: vec![0] },
        ];
        let err = run_tasks(&graph, &tasks, 0.0).unwrap_err().to_string();
        assert!(err.contains("cycle in the lowered task graph"), "{err}");
        assert!(err.contains("task 0") && err.contains("task 1"), "{err}");
        assert!(err.contains("waiting on 1 deps"), "{err}");
    }

    #[test]
    fn stall_error_includes_op_phase_and_edge_with_meta() {
        let graph = LinkGraph::mesh(1, 2, false, 60.0);
        let tasks = [
            Task { work: Work::Compute { dur_ns: 1.0 }, deps: vec![1] },
            Task { work: Work::Compute { dur_ns: 1.0 }, deps: vec![0] },
        ];
        let meta = [
            TaskMeta { op: 3, phase: SimPhase::Redistribute, edge: Some(7) },
            TaskMeta { op: 4, phase: SimPhase::Compute, edge: None },
        ];
        let mut scratch = SimScratch::default();
        let mut out = RunOutcome::default();
        let mut cks = Vec::new();
        let err = run_tasks_into(
            &graph,
            &tasks,
            Some(&meta),
            0.0,
            &[],
            None,
            &mut scratch,
            &mut out,
            &mut cks,
            None,
        )
        .unwrap_err()
        .to_string();
        assert!(
            err.contains("op 3")
                && err.contains("Redistribute")
                && err.contains("edge 7"),
            "{err}"
        );
        assert!(err.contains("op 4") && err.contains("Compute"), "{err}");
    }

    #[test]
    fn profiled_simulation_is_bit_identical_and_counts_work() {
        let plat = Platform::headline();
        let wl = alexnet(1);
        let alloc = uniform_allocation(&plat, &wl);
        let cfg = SimConfig::default();
        let base =
            simulate_plan(&plat, &wl, &alloc, OptFlags::ALL, &cfg).unwrap();
        let (report, p) =
            simulate_plan_profiled(&plat, &wl, &alloc, OptFlags::ALL, &cfg)
                .unwrap();
        assert_eq!(base.makespan_ns.to_bits(), report.makespan_ns.to_bits());
        assert!(p.events > 0 && p.tasks > 0);
        assert!(p.rate_recomputes > 0 && p.components_recomputed > 0);
        assert!(p.event_loop_ns >= p.rate_recompute_ns);
        assert!(p.rate_recompute_ns >= p.components_ns);
        // Each counted recompute touched at least one component, and
        // no event recomputes more than once.
        assert!(p.components_recomputed >= p.rate_recomputes);
        assert!(p.rate_recomputes <= p.events);
    }

    #[test]
    fn sim_scratch_capacities_stabilize_across_runs() {
        let plat = Platform::headline();
        let wl = alexnet(1);
        let alloc = uniform_allocation(&plat, &wl);
        let mut bench =
            SimBench::lower(&plat, &wl, &alloc, OptFlags::ALL, None)
                .expect("plan lowers");
        let first = bench.run_new().unwrap();
        let caps = bench.scratch_capacities();
        for _ in 0..3 {
            let again = bench.run_new().unwrap();
            assert_eq!(first.to_bits(), again.to_bits());
        }
        assert_eq!(
            caps,
            bench.scratch_capacities(),
            "warm scratch must not regrow"
        );
        bench.assert_parity().expect("engines agree");
    }
}
