//! Explicit NoP link graph: nodes, directed links, and XY(+diagonal)
//! routing. This is the substrate under `netsim` (the ASTRA-sim
//! substitute used for Figure 3), the per-link congestion ablations,
//! and the [`crate::platform::HopTables`] precomputation.
//!
//! Link lookup is a flat per-node adjacency index (a node has at most
//! 9 neighbours: 4 mesh + 4 diagonal + 1 memory), not a hash map; a
//! malformed graph makes [`LinkGraph::route`] return a structured
//! [`crate::util::error::Error`] instead of panicking.

use std::collections::HashMap;
use std::sync::Arc;

use super::Pos;
use crate::err;
use crate::util::error::Result;

/// Node in the package network: a chiplet or an off-package memory stack.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Node {
    Chiplet(Pos),
    Memory { attach: Pos },
}

pub type NodeId = usize;
pub type LinkId = usize;

/// A directed link with a fixed capacity (GB/s == bytes/ns).
#[derive(Debug, Clone, Copy)]
pub struct Link {
    pub from: NodeId,
    pub to: NodeId,
    pub capacity: f64,
}

/// Directed link graph over a 2D mesh of chiplets, with optional diagonal
/// links and any number of memory attachments.
#[derive(Debug, Clone)]
pub struct LinkGraph {
    pub xdim: usize,
    pub ydim: usize,
    pub diagonal: bool,
    pub nodes: Vec<Node>,
    pub links: Vec<Link>,
    /// Per-node outgoing adjacency `(to, link id)` — the flat index that
    /// replaced the `HashMap<(from, to), LinkId>` lookup (degree <= 9,
    /// so a linear probe beats hashing).
    adj: Vec<Vec<(NodeId, LinkId)>>,
}

impl LinkGraph {
    /// Build the chiplet mesh (all chiplet nodes + bidirectional NoP
    /// links, plus diagonals when enabled). Orthogonal and diagonal
    /// links share one capacity; see [`LinkGraph::mesh_classes`] for
    /// per-class bandwidths.
    pub fn mesh(xdim: usize, ydim: usize, diagonal: bool, bw_nop: f64) -> Self {
        Self::mesh_classes(xdim, ydim, bw_nop, if diagonal { Some(bw_nop) } else { None })
    }

    /// [`LinkGraph::mesh`] with per-class link bandwidths: orthogonal
    /// NoP links at `bw_nop`, diagonal links (§5.1) at `bw_diag` when
    /// present.
    pub fn mesh_classes(
        xdim: usize,
        ydim: usize,
        bw_nop: f64,
        bw_diag: Option<f64>,
    ) -> Self {
        let mut g = LinkGraph {
            xdim,
            ydim,
            diagonal: bw_diag.is_some(),
            nodes: Vec::new(),
            links: Vec::new(),
            adj: Vec::new(),
        };
        for r in 0..xdim {
            for c in 0..ydim {
                g.nodes.push(Node::Chiplet(Pos::new(r, c)));
                g.adj.push(Vec::new());
            }
        }
        let mut offsets: Vec<(isize, isize, f64)> =
            vec![(0, 1, bw_nop), (1, 0, bw_nop)];
        if let Some(bd) = bw_diag {
            offsets.extend([(1, 1, bd), (1, -1, bd)]);
        }
        for r in 0..xdim {
            for c in 0..ydim {
                for &(dr, dc, bw) in &offsets {
                    let (nr, nc) = (r as isize + dr, c as isize + dc);
                    if nr < 0
                        || nc < 0
                        || nr >= xdim as isize
                        || nc >= ydim as isize
                    {
                        continue;
                    }
                    let a = g.chiplet_id(Pos::new(r, c));
                    let b = g.chiplet_id(Pos::new(nr as usize, nc as usize));
                    g.add_duplex(a, b, bw);
                }
            }
        }
        g
    }

    /// Attach a memory node to `pos` with off-chip bandwidth `bw_mem`.
    pub fn attach_memory(&mut self, pos: Pos, bw_mem: f64) -> NodeId {
        let id = self.nodes.len();
        self.nodes.push(Node::Memory { attach: pos });
        self.adj.push(Vec::new());
        let c = self.chiplet_id(pos);
        self.add_duplex(id, c, bw_mem);
        id
    }

    fn add_duplex(&mut self, a: NodeId, b: NodeId, cap: f64) {
        for (f, t) in [(a, b), (b, a)] {
            let id = self.links.len();
            self.links.push(Link { from: f, to: t, capacity: cap });
            self.adj[f].push((t, id));
        }
    }

    pub fn chiplet_id(&self, p: Pos) -> NodeId {
        debug_assert!(p.row < self.xdim && p.col < self.ydim);
        p.row * self.ydim + p.col
    }

    /// The link `from -> to`, if it exists (linear probe over the flat
    /// adjacency row).
    pub fn link_between(&self, from: NodeId, to: NodeId) -> Option<LinkId> {
        self.adj
            .get(from)?
            .iter()
            .find(|&&(t, _)| t == to)
            .map(|&(_, id)| id)
    }

    /// Deterministic routing from `src` to `dst`:
    ///   * memory endpoints hop through their attachment chiplet;
    ///   * diagonal steps first while both coordinates differ (when the
    ///     mesh has diagonals), then dimension-order X-then-Y.
    /// Returns the traversed link ids in order, or a structured error on
    /// malformed graphs (out-of-range node ids, missing links) instead
    /// of panicking.
    pub fn route(&self, src: NodeId, dst: NodeId) -> Result<Vec<LinkId>> {
        if src >= self.nodes.len() || dst >= self.nodes.len() {
            return Err(err!(
                "route {src} -> {dst}: node id out of range (graph has {} \
                 nodes)",
                self.nodes.len()
            ));
        }
        if src == dst {
            return Ok(Vec::new());
        }
        let step_to = |cur: NodeId, next: NodeId| -> Result<LinkId> {
            self.link_between(cur, next).ok_or_else(|| {
                err!("route {src} -> {dst}: no link {cur} -> {next} \
                      (malformed graph)")
            })
        };
        let mut path = Vec::new();
        let mut cur = src;
        // Leave a memory node through its attachment.
        if let Node::Memory { attach } = self.nodes[cur] {
            let next = self.chiplet_id(attach);
            path.push(step_to(cur, next)?);
            cur = next;
            if cur == dst {
                return Ok(path);
            }
        }
        let target_pos = match self.nodes[dst] {
            Node::Chiplet(p) => p,
            Node::Memory { attach } => attach,
        };
        loop {
            let cur_pos = match self.nodes[cur] {
                Node::Chiplet(p) => p,
                Node::Memory { .. } => {
                    return Err(err!(
                        "route {src} -> {dst}: walked onto memory node \
                         {cur} mid-route (malformed graph)"
                    ))
                }
            };
            if cur_pos == target_pos {
                break;
            }
            let dr = (target_pos.row as isize - cur_pos.row as isize).signum();
            let dc = (target_pos.col as isize - cur_pos.col as isize).signum();
            let step = if self.diagonal && dr != 0 && dc != 0 {
                (dr, dc)
            } else if dr != 0 {
                (dr, 0)
            } else {
                (0, dc)
            };
            let next_pos = Pos::new(
                (cur_pos.row as isize + step.0) as usize,
                (cur_pos.col as isize + step.1) as usize,
            );
            let next = self.chiplet_id(next_pos);
            path.push(step_to(cur, next)?);
            cur = next;
        }
        // Enter a memory destination through its attachment link.
        if cur != dst {
            path.push(step_to(cur, dst)?);
        }
        Ok(path)
    }
}

/// Memoized [`LinkGraph::route`] lookups: routes are returned as cheap
/// [`Arc<[LinkId]>`] handles, computed once per `(src, dst)` pair. On a
/// 20×20 mesh a single plan lowering asks for the same few hundred
/// routes tens of thousands of times — this turns every repeat into one
/// hash probe plus an `Arc` clone.
///
/// **Invalidation**: a cache is only meaningful against the *one* graph
/// it was filled from. Routes depend on the node set, the diagonal
/// flag, and link existence; none of those can change on a built
/// [`LinkGraph`], so entries never go stale — but a different graph
/// (another platform, the other diagonal setting) needs a fresh cache.
/// Callers that outlive a graph (e.g. `netsim::IncrementalSim`) must
/// drop the cache together with it (DESIGN.md §Optimizer scale-out).
#[derive(Debug, Clone, Default)]
pub struct RouteCache {
    routes: HashMap<(NodeId, NodeId), Arc<[LinkId]>>,
    hits: usize,
    misses: usize,
}

impl RouteCache {
    pub fn new() -> Self {
        Self::default()
    }

    /// The route `src -> dst` on `g`, memoized.
    pub fn route(
        &mut self,
        g: &LinkGraph,
        src: NodeId,
        dst: NodeId,
    ) -> Result<Arc<[LinkId]>> {
        if let Some(r) = self.routes.get(&(src, dst)) {
            self.hits += 1;
            return Ok(r.clone());
        }
        self.misses += 1;
        let r: Arc<[LinkId]> = g.route(src, dst)?.into();
        self.routes.insert((src, dst), r.clone());
        Ok(r)
    }

    /// Distinct `(src, dst)` pairs cached so far.
    pub fn len(&self) -> usize {
        self.routes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.routes.is_empty()
    }

    /// `(hits, misses)` since construction.
    pub fn stats(&self) -> (usize, usize) {
        (self.hits, self.misses)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mesh_link_count() {
        // 4x4 mesh: 2 * (3*4 + 4*3) = 48 directed links.
        let g = LinkGraph::mesh(4, 4, false, 60.0);
        assert_eq!(g.nodes.len(), 16);
        assert_eq!(g.links.len(), 48);
        // Diagonals: 2 * 2 * 3 * 3 = 36 more.
        let gd = LinkGraph::mesh(4, 4, true, 60.0);
        assert_eq!(gd.links.len(), 48 + 36);
    }

    #[test]
    fn per_class_diagonal_bandwidth() {
        let g = LinkGraph::mesh_classes(3, 3, 60.0, Some(30.0));
        assert!(g.diagonal);
        let a = g.chiplet_id(Pos::new(0, 0));
        let b = g.chiplet_id(Pos::new(1, 1));
        let diag = g.link_between(a, b).expect("diagonal link exists");
        assert_eq!(g.links[diag].capacity, 30.0);
        let c = g.chiplet_id(Pos::new(0, 1));
        let orth = g.link_between(a, c).expect("mesh link exists");
        assert_eq!(g.links[orth].capacity, 60.0);
    }

    #[test]
    fn route_is_connected_and_minimal() {
        let g = LinkGraph::mesh(4, 4, false, 60.0);
        let src = g.chiplet_id(Pos::new(0, 0));
        let dst = g.chiplet_id(Pos::new(3, 2));
        let path = g.route(src, dst).unwrap();
        assert_eq!(path.len(), 5); // Manhattan distance
        // Links chain: from[i+1] == to[i].
        for w in path.windows(2) {
            assert_eq!(g.links[w[0]].to, g.links[w[1]].from);
        }
        assert_eq!(g.links[path[0]].from, src);
        assert_eq!(g.links[*path.last().unwrap()].to, dst);
    }

    #[test]
    fn diagonal_route_is_chebyshev() {
        let g = LinkGraph::mesh(5, 5, true, 60.0);
        let src = g.chiplet_id(Pos::new(0, 0));
        let dst = g.chiplet_id(Pos::new(3, 2));
        assert_eq!(g.route(src, dst).unwrap().len(), 3); // max(3, 2)
    }

    #[test]
    fn memory_routing_through_attachment() {
        let mut g = LinkGraph::mesh(4, 4, false, 60.0);
        let mem = g.attach_memory(Pos::new(0, 0), 1000.0);
        let dst = g.chiplet_id(Pos::new(2, 2));
        let path = g.route(mem, dst).unwrap();
        assert_eq!(path.len(), 1 + 4);
        assert_eq!(g.links[path[0]].capacity, 1000.0);
        // And the reverse direction enters memory last.
        let back = g.route(dst, mem).unwrap();
        assert_eq!(back.len(), 5);
        assert_eq!(g.links[*back.last().unwrap()].to, mem);
    }

    #[test]
    fn self_route_is_empty() {
        let g = LinkGraph::mesh(3, 3, false, 60.0);
        assert!(g.route(4, 4).unwrap().is_empty());
    }

    #[test]
    fn link_between_matches_adjacency() {
        let g = LinkGraph::mesh(3, 3, true, 60.0);
        let a = g.chiplet_id(Pos::new(1, 1));
        // All 8 neighbours reachable, self not.
        assert!(g.link_between(a, a).is_none());
        for (dr, dc) in [(0isize, 1isize), (1, 0), (1, 1), (1, -1)] {
            let b = g.chiplet_id(Pos::new(
                (1 + dr) as usize,
                (1 + dc) as usize,
            ));
            let fwd = g.link_between(a, b).expect("forward link");
            let bwd = g.link_between(b, a).expect("reverse link");
            assert_eq!(g.links[fwd].from, a);
            assert_eq!(g.links[bwd].to, a);
        }
    }

    #[test]
    fn route_cache_memoizes_and_matches_uncached() {
        let mut g = LinkGraph::mesh(4, 4, true, 60.0);
        let mem = g.attach_memory(Pos::new(0, 0), 1000.0);
        let mut cache = RouteCache::new();
        for dst in 0..g.nodes.len() {
            let cached = cache.route(&g, mem, dst).unwrap();
            assert_eq!(&cached[..], g.route(mem, dst).unwrap().as_slice());
            // Second lookup is a hit returning the same allocation.
            let again = cache.route(&g, mem, dst).unwrap();
            assert!(Arc::ptr_eq(&cached, &again));
        }
        let (hits, misses) = cache.stats();
        assert_eq!(misses, g.nodes.len());
        assert_eq!(hits, g.nodes.len());
        assert_eq!(cache.len(), g.nodes.len());
        // Errors are not cached as routes.
        assert!(cache.route(&g, 0, 999).is_err());
    }

    #[test]
    fn malformed_graphs_error_instead_of_panicking() {
        let g = LinkGraph::mesh(3, 3, false, 60.0);
        // Out-of-range node ids.
        let err = g.route(0, 999).unwrap_err();
        assert!(err.to_string().contains("out of range"), "{err}");
        // A disconnected graph (nodes without links).
        let broken = LinkGraph {
            xdim: 1,
            ydim: 2,
            diagonal: false,
            nodes: vec![
                Node::Chiplet(Pos::new(0, 0)),
                Node::Chiplet(Pos::new(0, 1)),
            ],
            links: Vec::new(),
            adj: vec![Vec::new(), Vec::new()],
        };
        let err = broken.route(0, 1).unwrap_err();
        assert!(err.to_string().contains("no link"), "{err}");
    }
}
