//! Explicit NoP link graph: nodes, directed links, and XY(+diagonal)
//! routing. This is the substrate under `netsim` (the ASTRA-sim
//! substitute used for Figure 3) and the per-link congestion ablations.

use super::Pos;

/// Node in the package network: a chiplet or an off-package memory stack.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Node {
    Chiplet(Pos),
    Memory { attach: Pos },
}

pub type NodeId = usize;
pub type LinkId = usize;

/// A directed link with a fixed capacity (GB/s == bytes/ns).
#[derive(Debug, Clone, Copy)]
pub struct Link {
    pub from: NodeId,
    pub to: NodeId,
    pub capacity: f64,
}

/// Directed link graph over a 2D mesh of chiplets, with optional diagonal
/// links and any number of memory attachments.
#[derive(Debug, Clone)]
pub struct LinkGraph {
    pub xdim: usize,
    pub ydim: usize,
    pub diagonal: bool,
    pub nodes: Vec<Node>,
    pub links: Vec<Link>,
    /// link index by (from, to)
    by_ends: std::collections::HashMap<(NodeId, NodeId), LinkId>,
}

impl LinkGraph {
    /// Build the chiplet mesh (all chiplet nodes + bidirectional NoP
    /// links, plus diagonals when enabled).
    pub fn mesh(xdim: usize, ydim: usize, diagonal: bool, bw_nop: f64) -> Self {
        let mut g = LinkGraph {
            xdim,
            ydim,
            diagonal,
            nodes: Vec::new(),
            links: Vec::new(),
            by_ends: Default::default(),
        };
        for r in 0..xdim {
            for c in 0..ydim {
                g.nodes.push(Node::Chiplet(Pos::new(r, c)));
            }
        }
        let mut offsets: Vec<(isize, isize)> = vec![(0, 1), (1, 0)];
        if diagonal {
            offsets.extend([(1, 1), (1, -1)]);
        }
        for r in 0..xdim {
            for c in 0..ydim {
                for &(dr, dc) in &offsets {
                    let (nr, nc) = (r as isize + dr, c as isize + dc);
                    if nr < 0
                        || nc < 0
                        || nr >= xdim as isize
                        || nc >= ydim as isize
                    {
                        continue;
                    }
                    let a = g.chiplet_id(Pos::new(r, c));
                    let b = g.chiplet_id(Pos::new(nr as usize, nc as usize));
                    g.add_duplex(a, b, bw_nop);
                }
            }
        }
        g
    }

    /// Attach a memory node to `pos` with off-chip bandwidth `bw_mem`.
    pub fn attach_memory(&mut self, pos: Pos, bw_mem: f64) -> NodeId {
        let id = self.nodes.len();
        self.nodes.push(Node::Memory { attach: pos });
        let c = self.chiplet_id(pos);
        self.add_duplex(id, c, bw_mem);
        id
    }

    fn add_duplex(&mut self, a: NodeId, b: NodeId, cap: f64) {
        for (f, t) in [(a, b), (b, a)] {
            let id = self.links.len();
            self.links.push(Link { from: f, to: t, capacity: cap });
            self.by_ends.insert((f, t), id);
        }
    }

    pub fn chiplet_id(&self, p: Pos) -> NodeId {
        debug_assert!(p.row < self.xdim && p.col < self.ydim);
        p.row * self.ydim + p.col
    }

    pub fn link_between(&self, from: NodeId, to: NodeId) -> Option<LinkId> {
        self.by_ends.get(&(from, to)).copied()
    }

    /// Deterministic routing from `src` to `dst`:
    ///   * memory endpoints hop through their attachment chiplet;
    ///   * diagonal steps first while both coordinates differ (when the
    ///     mesh has diagonals), then dimension-order X-then-Y.
    /// Returns the traversed link ids in order.
    pub fn route(&self, src: NodeId, dst: NodeId) -> Vec<LinkId> {
        if src == dst {
            return Vec::new();
        }
        let mut path = Vec::new();
        let mut cur = src;
        // Leave a memory node through its attachment.
        if let Node::Memory { attach } = self.nodes[cur] {
            let next = self.chiplet_id(attach);
            path.push(self.by_ends[&(cur, next)]);
            cur = next;
            if cur == dst {
                return path;
            }
        }
        let target_pos = match self.nodes[dst] {
            Node::Chiplet(p) => p,
            Node::Memory { attach } => attach,
        };
        loop {
            let cur_pos = match self.nodes[cur] {
                Node::Chiplet(p) => p,
                Node::Memory { .. } => unreachable!("mid-route memory node"),
            };
            if cur_pos == target_pos {
                break;
            }
            let dr = (target_pos.row as isize - cur_pos.row as isize).signum();
            let dc = (target_pos.col as isize - cur_pos.col as isize).signum();
            let step = if self.diagonal && dr != 0 && dc != 0 {
                (dr, dc)
            } else if dr != 0 {
                (dr, 0)
            } else {
                (0, dc)
            };
            let next_pos = Pos::new(
                (cur_pos.row as isize + step.0) as usize,
                (cur_pos.col as isize + step.1) as usize,
            );
            let next = self.chiplet_id(next_pos);
            path.push(
                self.by_ends[&(cur, next)],
            );
            cur = next;
        }
        // Enter a memory destination through its attachment link.
        if cur != dst {
            path.push(self.by_ends[&(cur, dst)]);
        }
        path
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mesh_link_count() {
        // 4x4 mesh: 2 * (3*4 + 4*3) = 48 directed links.
        let g = LinkGraph::mesh(4, 4, false, 60.0);
        assert_eq!(g.nodes.len(), 16);
        assert_eq!(g.links.len(), 48);
        // Diagonals: 2 * 2 * 3 * 3 = 36 more.
        let gd = LinkGraph::mesh(4, 4, true, 60.0);
        assert_eq!(gd.links.len(), 48 + 36);
    }

    #[test]
    fn route_is_connected_and_minimal() {
        let g = LinkGraph::mesh(4, 4, false, 60.0);
        let src = g.chiplet_id(Pos::new(0, 0));
        let dst = g.chiplet_id(Pos::new(3, 2));
        let path = g.route(src, dst);
        assert_eq!(path.len(), 5); // Manhattan distance
        // Links chain: from[i+1] == to[i].
        for w in path.windows(2) {
            assert_eq!(g.links[w[0]].to, g.links[w[1]].from);
        }
        assert_eq!(g.links[path[0]].from, src);
        assert_eq!(g.links[*path.last().unwrap()].to, dst);
    }

    #[test]
    fn diagonal_route_is_chebyshev() {
        let g = LinkGraph::mesh(5, 5, true, 60.0);
        let src = g.chiplet_id(Pos::new(0, 0));
        let dst = g.chiplet_id(Pos::new(3, 2));
        assert_eq!(g.route(src, dst).len(), 3); // max(3, 2)
    }

    #[test]
    fn memory_routing_through_attachment() {
        let mut g = LinkGraph::mesh(4, 4, false, 60.0);
        let mem = g.attach_memory(Pos::new(0, 0), 1000.0);
        let dst = g.chiplet_id(Pos::new(2, 2));
        let path = g.route(mem, dst);
        assert_eq!(path.len(), 1 + 4);
        assert_eq!(g.links[path[0]].capacity, 1000.0);
        // And the reverse direction enters memory last.
        let back = g.route(dst, mem);
        assert_eq!(back.len(), 5);
        assert_eq!(g.links[*back.last().unwrap()].to, mem);
    }

    #[test]
    fn self_route_is_empty() {
        let g = LinkGraph::mesh(3, 3, false, 60.0);
        assert!(g.route(4, 4).is_empty());
    }
}
