//! Grid geometry substrate: absolute positions, the paper's local
//! (x, y) indexing types (§4.2.1, Figure 4), and the explicit NoP link
//! graph ([`links`]).
//!
//! The packaging-specific parts that used to live here — global-chiplet
//! placement per `SystemType` and the closed-form hop models of §4.3.3
//! and §5.1.1 — are now data, not code: a [`crate::platform::Platform`]
//! carries an arbitrary memory-attachment set and precomputes its
//! [`crate::platform::HopTables`] from [`links::LinkGraph`] routing, so
//! the same cost equations adapt to 2.5D corner memory, edge memory,
//! 3D-stacked memory, the mixed case, and any layout a platform
//! description file can express.

pub mod links;

/// Absolute grid position (row, col), row 0 at the memory-facing edge.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct Pos {
    pub row: usize,
    pub col: usize,
}

impl Pos {
    pub fn new(row: usize, col: usize) -> Self {
        Pos { row, col }
    }
}

/// Local index relative to the assigned global chiplet: the paper's
/// `(x, y)` (Figure 4). `x` = row distance, `y` = column distance.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LocalIdx {
    pub x: usize,
    pub y: usize,
}

/// All grid positions, row-major.
pub(crate) fn grid_positions(
    xdim: usize,
    ydim: usize,
) -> impl Iterator<Item = Pos> {
    (0..xdim).flat_map(move |r| (0..ydim).map(move |c| Pos::new(r, c)))
}

pub(crate) fn manhattan(a: Pos, b: Pos) -> usize {
    a.row.abs_diff(b.row) + a.col.abs_diff(b.col)
}

/// Mesh neighbour offsets; the first four are the orthogonal links, the
/// tail adds the §5.1 diagonals.
const NEIGHBOUR_OFFSETS: [(isize, isize); 8] = [
    (-1, 0),
    (1, 0),
    (0, -1),
    (0, 1),
    (-1, -1),
    (-1, 1),
    (1, -1),
    (1, 1),
];

/// Const slice of neighbour offsets — no `Vec` allocation per call (it
/// sits inside the entrance-link counting loops).
pub(crate) fn neighbour_offsets(diagonal: bool) -> &'static [(isize, isize)] {
    if diagonal {
        &NEIGHBOUR_OFFSETS
    } else {
        &NEIGHBOUR_OFFSETS[..4]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grid_positions_row_major() {
        let ps: Vec<Pos> = grid_positions(2, 3).collect();
        assert_eq!(ps.len(), 6);
        assert_eq!(ps[0], Pos::new(0, 0));
        assert_eq!(ps[1], Pos::new(0, 1));
        assert_eq!(ps[3], Pos::new(1, 0));
    }

    #[test]
    fn manhattan_distance() {
        assert_eq!(manhattan(Pos::new(0, 0), Pos::new(3, 2)), 5);
        assert_eq!(manhattan(Pos::new(2, 2), Pos::new(2, 2)), 0);
    }

    #[test]
    fn neighbour_offsets_lengths() {
        assert_eq!(neighbour_offsets(false).len(), 4);
        assert_eq!(neighbour_offsets(true).len(), 8);
    }
}
