//! Chiplet-grid topology: per-type global-chiplet placement, the paper's
//! local (x, y) indexing (§4.2.1, Figure 4), and the hop models of
//! §4.3.3 and §5.1.1 (diagonal links).
//!
//! The paper encodes *all* topological information needed by the cost
//! model in a local index per chiplet: `(x, y)` = rows/columns away from
//! the nearest **global chiplet** (the chiplet wired to main memory).
//! Each packaging type places global chiplets differently, so the same
//! cost equations adapt to 2.5D corner memory (A), edge memory (B),
//! 3D-stacked memory (C) and the mixed case (D) just by re-indexing.

use crate::config::{HwConfig, SystemType};

pub mod links;

/// Absolute grid position (row, col), row 0 at the memory-facing edge.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct Pos {
    pub row: usize,
    pub col: usize,
}

impl Pos {
    pub fn new(row: usize, col: usize) -> Self {
        Pos { row, col }
    }
}

/// Local index relative to the assigned global chiplet: the paper's
/// `(x, y)` (Figure 4). `x` = row distance, `y` = column distance.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LocalIdx {
    pub x: usize,
    pub y: usize,
}

/// The topology of one MCM: grid dims + packaging type.
///
/// Local indices, serving globals and region extents are precomputed at
/// construction: the cost evaluator queries them inside per-chiplet
/// loops (GA fitness is the hottest path in the repo, §Perf).
#[derive(Debug, Clone)]
pub struct Topology {
    pub xdim: usize,
    pub ydim: usize,
    pub ty: SystemType,
    globals: Vec<Pos>,
    /// Per position (row-major): is this a global chiplet? O(1)
    /// membership for the `entrance_links`/evaluator loops instead of
    /// scanning `globals`.
    global_mask: Vec<bool>,
    /// Per position (row-major): nearest global chiplet.
    nearest: Vec<Pos>,
    /// Per position: local (x, y) index.
    locals: Vec<LocalIdx>,
    /// Per position: serving region extent (X, Y).
    extents: Vec<(usize, usize)>,
}

impl Topology {
    pub fn new(ty: SystemType, xdim: usize, ydim: usize) -> Self {
        assert!(xdim > 0 && ydim > 0);
        let globals = match ty {
            // Corner memory: single entry point at (0, 0).
            SystemType::A => vec![Pos::new(0, 0)],
            // Edge memory: first and last column are global (each row has
            // an entrance on both sides). Degenerates to one column for
            // ydim == 1.
            SystemType::B => {
                let mut g: Vec<Pos> =
                    (0..xdim).map(|r| Pos::new(r, 0)).collect();
                if ydim > 1 {
                    g.extend((0..xdim).map(|r| Pos::new(r, ydim - 1)));
                }
                g
            }
            // 3D stacked: every chiplet has its own memory interface.
            SystemType::C => (0..xdim)
                .flat_map(|r| (0..ydim).map(move |c| Pos::new(r, c)))
                .collect(),
            // Mixed 2.5D+3D: four stacks over the quadrant centers.
            SystemType::D => {
                let qr = [(xdim - 1) / 2, xdim / 2];
                let qc = [(ydim - 1) / 2, ydim / 2];
                let mut g = vec![
                    Pos::new(qr[0], qc[0]),
                    Pos::new(qr[0], qc[1]),
                    Pos::new(qr[1], qc[0]),
                    Pos::new(qr[1], qc[1]),
                ];
                g.dedup();
                g.sort();
                g.dedup();
                g
            }
        };
        let mut global_mask = vec![false; xdim * ydim];
        for g in &globals {
            global_mask[g.row * ydim + g.col] = true;
        }
        let mut t = Topology {
            xdim,
            ydim,
            ty,
            globals,
            global_mask,
            nearest: Vec::new(),
            locals: Vec::new(),
            extents: Vec::new(),
        };
        // Precompute nearest globals + local indices.
        for p in grid_positions(xdim, ydim) {
            let g = *t
                .globals
                .iter()
                .min_by_key(|g| (manhattan(p, **g), (g.row, g.col)))
                .expect("topology has at least one global chiplet");
            t.nearest.push(g);
            t.locals.push(LocalIdx {
                x: p.row.abs_diff(g.row),
                y: p.col.abs_diff(g.col),
            });
        }
        // Region extents per serving global, then scatter per position.
        use std::collections::HashMap;
        let mut per_global: HashMap<Pos, (usize, usize)> = HashMap::new();
        for (i, p) in grid_positions(xdim, ydim).enumerate() {
            let _ = p;
            let g = t.nearest[i];
            let l = t.locals[i];
            let e = per_global.entry(g).or_insert((0, 0));
            e.0 = e.0.max(l.x);
            e.1 = e.1.max(l.y);
        }
        for i in 0..xdim * ydim {
            let (mx, my) = per_global[&t.nearest[i]];
            t.extents.push((mx + 1, my + 1));
        }
        t
    }

    #[inline]
    fn idx(&self, p: Pos) -> usize {
        p.row * self.ydim + p.col
    }

    pub fn from_hw(hw: &HwConfig) -> Self {
        Self::new(hw.ty, hw.xdim, hw.ydim)
    }

    pub fn num_chiplets(&self) -> usize {
        self.xdim * self.ydim
    }

    /// All grid positions, row-major.
    pub fn positions(&self) -> impl Iterator<Item = Pos> + '_ {
        grid_positions(self.xdim, self.ydim)
    }

    /// Global chiplets (wired to main memory).
    pub fn globals(&self) -> &[Pos] {
        &self.globals
    }

    /// O(1): precomputed per-position bitmap (the linear scan over
    /// `globals` used to sit inside `entrance_links` loops).
    #[inline]
    pub fn is_global(&self, p: Pos) -> bool {
        self.global_mask[self.idx(p)]
    }

    /// The closest global chiplet (paper: "each chiplet will only
    /// communicate with the closest global chiplet"); Manhattan metric,
    /// ties broken toward the smaller position for determinism.
    #[inline]
    pub fn nearest_global(&self, p: Pos) -> Pos {
        self.nearest[self.idx(p)]
    }

    /// The paper's local index `(x, y)` for a chiplet.
    #[inline]
    pub fn local_index(&self, p: Pos) -> LocalIdx {
        self.locals[self.idx(p)]
    }

    /// Manhattan distance to the serving global chiplet (SIMBA's
    /// partitioning key; §3.1).
    pub fn distance_to_memory(&self, p: Pos) -> usize {
        let l = self.local_index(p);
        l.x + l.y
    }

    /// Extent (X, Y) of the serving region of `p`'s global chiplet: the
    /// dims that enter the waiting-hop terms of eqs. 11–12. For type A
    /// this is the whole grid; for B it is the half-grid served by one
    /// edge; for C it is a single chiplet.
    #[inline]
    pub fn region_extent(&self, p: Pos) -> (usize, usize) {
        self.extents[self.idx(p)]
    }

    /// Number of NoP links that enter the global chiplet(s) from
    /// non-global neighbours — the "bandwidth to entrances" multiplier of
    /// eq. 8. Diagonal links add the diagonal neighbours (§5.1: +50% for
    /// the type-A corner).
    pub fn entrance_links(&self, diagonal: bool) -> usize {
        if self.ty == SystemType::C {
            // Every chiplet is global: collection is a no-op.
            return 0;
        }
        let mut count = 0;
        for g in &self.globals {
            for &(dr, dc) in neighbour_offsets(diagonal) {
                let nr = g.row as isize + dr;
                let nc = g.col as isize + dc;
                if nr < 0
                    || nc < 0
                    || nr >= self.xdim as isize
                    || nc >= self.ydim as isize
                {
                    continue;
                }
                let n = Pos::new(nr as usize, nc as usize);
                if !self.is_global(n) {
                    count += 1;
                }
            }
        }
        count
    }

    // ---- hop models (§4.3.3, §5.1.1) -----------------------------------

    /// Eq. 10 — low off-chip BW: links drain faster than memory feeds
    /// them, no contention, minimal path (Chebyshev when diagonal links
    /// provide shortcuts).
    pub fn hops_low_bw(&self, p: Pos, diagonal: bool) -> usize {
        let l = self.local_index(p);
        if diagonal {
            l.x.max(l.y)
        } else {
            l.x + l.y
        }
    }

    /// Eq. 11 — high BW, row-wise-shared data: congestion on the first
    /// column resolved farthest-row-first, so waiting hops (X - x) are
    /// added: total = X + y. With diagonal links (§5.1.1) the alternative
    /// route costs (X - x) + max(x, y); the two strategies use disjoint
    /// links, so take the min.
    pub fn hops_row_shared(&self, p: Pos, diagonal: bool) -> usize {
        let l = self.local_index(p);
        let (xr, _) = self.region_extent(p);
        let base = xr + l.y;
        if diagonal {
            base.min(xr - l.x + l.x.max(l.y))
        } else {
            base
        }
    }

    /// Eq. 12 — high BW, column-wise-shared data: symmetric to eq. 11.
    pub fn hops_col_shared(&self, p: Pos, diagonal: bool) -> usize {
        let l = self.local_index(p);
        let (_, yr) = self.region_extent(p);
        let base = yr + l.x;
        if diagonal {
            base.min(yr - l.y + l.x.max(l.y))
        } else {
            base
        }
    }

    /// Hop count used by the on-chip energy model (§4.4.3): actual path
    /// length travelled, i.e. the minimal route (diagonal links shorten
    /// it to the Chebyshev distance).
    pub fn hops_energy(&self, p: Pos, diagonal: bool) -> usize {
        let l = self.local_index(p);
        if diagonal {
            l.x.max(l.y)
        } else {
            l.x + l.y
        }
    }
}

fn grid_positions(xdim: usize, ydim: usize) -> impl Iterator<Item = Pos> {
    (0..xdim).flat_map(move |r| (0..ydim).map(move |c| Pos::new(r, c)))
}

pub(crate) fn manhattan(a: Pos, b: Pos) -> usize {
    a.row.abs_diff(b.row) + a.col.abs_diff(b.col)
}

/// Mesh neighbour offsets; the first four are the orthogonal links, the
/// tail adds the §5.1 diagonals.
const NEIGHBOUR_OFFSETS: [(isize, isize); 8] = [
    (-1, 0),
    (1, 0),
    (0, -1),
    (0, 1),
    (-1, -1),
    (-1, 1),
    (1, -1),
    (1, 1),
];

/// Const slice of neighbour offsets — no `Vec` allocation per call (it
/// sits inside `entrance_links` loops).
fn neighbour_offsets(diagonal: bool) -> &'static [(isize, isize)] {
    if diagonal {
        &NEIGHBOUR_OFFSETS
    } else {
        &NEIGHBOUR_OFFSETS[..4]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn type_a_single_corner_global() {
        let t = Topology::new(SystemType::A, 4, 4);
        assert_eq!(t.globals(), &[Pos::new(0, 0)]);
        assert_eq!(t.local_index(Pos::new(3, 2)), LocalIdx { x: 3, y: 2 });
        assert_eq!(t.region_extent(Pos::new(1, 1)), (4, 4));
    }

    #[test]
    fn type_b_edge_globals() {
        let t = Topology::new(SystemType::B, 4, 4);
        assert_eq!(t.globals().len(), 8);
        // Interior chiplet is served by the nearest edge, same row.
        let l = t.local_index(Pos::new(2, 1));
        assert_eq!((l.x, l.y), (0, 1));
        // Region extent spans half the row.
        let (xr, yr) = t.region_extent(Pos::new(2, 1));
        assert_eq!(xr, 1);
        assert!(yr >= 2);
    }

    #[test]
    fn type_c_all_global_zero_distance() {
        let t = Topology::new(SystemType::C, 4, 4);
        assert_eq!(t.globals().len(), 16);
        for p in t.positions() {
            assert_eq!(t.distance_to_memory(p), 0);
            assert_eq!(t.hops_low_bw(p, false), 0);
        }
        assert_eq!(t.entrance_links(false), 0);
    }

    #[test]
    fn type_d_quadrant_centers_near_uniform() {
        let t = Topology::new(SystemType::D, 4, 4);
        assert_eq!(t.globals().len(), 4);
        let max_d = t
            .positions()
            .map(|p| t.distance_to_memory(p))
            .max()
            .unwrap();
        assert!(max_d <= 2, "type D should be near-uniform, max={max_d}");
    }

    #[test]
    fn eq8_entrance_links_type_a() {
        let t = Topology::new(SystemType::A, 4, 4);
        // Corner global: 2 mesh links; +1 diagonal = 3 (the paper's "50%
        // more bandwidth on the bottleneck").
        assert_eq!(t.entrance_links(false), 2);
        assert_eq!(t.entrance_links(true), 3);
    }

    #[test]
    fn eq10_low_bw_hops() {
        let t = Topology::new(SystemType::A, 5, 5);
        assert_eq!(t.hops_low_bw(Pos::new(3, 2), false), 5);
        assert_eq!(t.hops_low_bw(Pos::new(3, 2), true), 3);
        assert_eq!(t.hops_low_bw(Pos::new(0, 0), false), 0);
    }

    #[test]
    fn eq11_row_shared_hops_and_diagonal() {
        let t = Topology::new(SystemType::A, 5, 5);
        let p = Pos::new(3, 2);
        // eq. 11: X + y = 5 + 2 = 7.
        assert_eq!(t.hops_row_shared(p, false), 7);
        // §5.1.1: (X - x) + max(x, y) = 2 + 3 = 5; min(7, 5) = 5.
        assert_eq!(t.hops_row_shared(p, true), 5);
    }

    #[test]
    fn eq12_col_shared_symmetric() {
        let t = Topology::new(SystemType::A, 5, 5);
        let p = Pos::new(2, 3);
        assert_eq!(t.hops_col_shared(p, false), 5 + 2);
        assert_eq!(t.hops_col_shared(p, true), (5 - 3 + 3).min(7));
    }

    #[test]
    fn diagonal_never_worse() {
        for ty in SystemType::ALL {
            let t = Topology::new(ty, 5, 5);
            for p in t.positions() {
                assert!(t.hops_row_shared(p, true) <= t.hops_row_shared(p, false));
                assert!(t.hops_col_shared(p, true) <= t.hops_col_shared(p, false));
                assert!(t.hops_energy(p, true) <= t.hops_energy(p, false));
            }
        }
    }

    #[test]
    fn nearest_global_is_actually_nearest() {
        for ty in SystemType::ALL {
            let t = Topology::new(ty, 6, 5);
            for p in t.positions() {
                let g = t.nearest_global(p);
                let d = manhattan(p, g);
                for other in t.globals() {
                    assert!(d <= manhattan(p, *other));
                }
            }
        }
    }
}
