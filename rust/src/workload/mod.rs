//! Workload IR: the machine-learning task of paper §4.2.2 as a small
//! dataflow graph — GEMM operators plus explicit producer→consumer
//! [`Edge`]s — together with the model zoo used in the evaluation
//! (AlexNet, ViT, Vision Mamba, HydraNet).
//!
//! # Graph semantics
//!
//! `ops` is stored in a validated topological order of the DAG; every
//! edge runs forward (`src < dst`). An edge `(p, c)` states that op
//! `c`'s input activations are op `p`'s output — the relationship the
//! legacy IR encoded as a `chained` flag on the *consumer* with an
//! implicit `i → i+1` adjacency. `chained` is now **derived** from the
//! edges (an op is chained iff it has an incoming dataflow edge), so
//! branching structures (residual fan-out, multi-head models,
//! multi-tenant scenarios) are first-class.
//!
//! On-package redistribution (§5.2) stays per-edge: an edge is
//! redistributable only when the producer's store can actually be
//! skipped (sole consumer) and the consumer's activations are exactly
//! this producer's output (sole producer) — see
//! [`Workload::edge_redistributable`]. For linear chains this reduces
//! exactly to the historical `chained && groups == 1 && !sync` rule,
//! which is what keeps the edge-indexed evaluator bit-identical to the
//! pre-IR one on every existing model.

pub mod models;

use std::ops::Range;

/// Edge index into [`Workload::edges`].
pub type EdgeId = usize;

/// One GEMM operator: `OP_i = {M, K, N, sync, shared_row, shared_col}`
/// (eq. 2) plus execution attributes the co-optimizations need.
#[derive(Debug, Clone)]
pub struct GemmOp {
    pub name: String,
    /// Output rows (input dimension M).
    pub m: usize,
    /// Contraction (hidden) dimension.
    pub k: usize,
    /// Output columns.
    pub n: usize,
    /// Output must be synchronized across chiplets before the next op
    /// (softmax / layer-norm style reductions).
    pub sync: bool,
    /// Chiplets of the same grid row produce the same output rows.
    pub shared_row: bool,
    /// Chiplets of the same grid column produce the same output columns.
    pub shared_col: bool,
    /// Fused ReLU epilogue (computed in the chiplet SIMD unit).
    pub relu: bool,
    /// Input activations arrive over a dataflow edge rather than a
    /// memory round-trip. Derived from [`Workload::edges`] by the graph
    /// constructors; the builder flag remains the declaration syntax for
    /// linear chains ([`Workload::new`] turns it into edges).
    pub chained: bool,
    /// Grouped GEMM factor (attention heads). Redistribution only applies
    /// to plain GEMMs (`groups == 1`); grouped ops keep complex head-wise
    /// data mappings (§7.1).
    pub groups: usize,
}

impl GemmOp {
    /// Plain dense layer.
    pub fn dense(name: &str, m: usize, k: usize, n: usize) -> Self {
        GemmOp {
            name: name.to_string(),
            m,
            k,
            n,
            sync: false,
            shared_row: true,
            shared_col: true,
            relu: false,
            chained: false,
            groups: 1,
        }
    }

    pub fn relu(mut self) -> Self {
        self.relu = true;
        self
    }

    pub fn chained(mut self) -> Self {
        self.chained = true;
        self
    }

    pub fn sync(mut self) -> Self {
        self.sync = true;
        self
    }

    pub fn grouped(mut self, groups: usize) -> Self {
        assert!(groups >= 1);
        self.groups = groups;
        self
    }

    /// MACs for this op (per sample).
    pub fn macs(&self) -> usize {
        self.m * self.k * self.n
    }

    /// Element counts (input, weight, output).
    pub fn elems(&self) -> (usize, usize, usize) {
        (self.m * self.k, self.k * self.n, self.m * self.n)
    }
}

/// Explicit dataflow edge: `ops[src]`'s output tensor feeds `ops[dst]`'s
/// input activations. `rows × cols` is the tensor shape on the wire —
/// validated to equal the producer's output `M × N`, so consumers of
/// the IR (cost probes, exporters) can read the moved-tensor shape off
/// the edge without chasing the producer op.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Edge {
    pub src: usize,
    pub dst: usize,
    pub rows: usize,
    pub cols: usize,
}

/// Model provenance inside a (possibly fused) workload: the contiguous
/// op range contributed by one model. Multi-model scenarios built via
/// [`Workload::concat`] / [`Workload::multi_model`] carry one span per
/// constituent so reports can attribute cost per model.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ModelSpan {
    pub name: String,
    pub ops: Range<usize>,
}

/// A workload: named op set in a validated topological order plus the
/// explicit dataflow edges of the model DAG (§4.2.2).
#[derive(Debug, Clone)]
pub struct Workload {
    pub name: String,
    pub ops: Vec<GemmOp>,
    /// Dataflow edges, sorted by `(src, dst)`. For workloads built with
    /// [`Workload::new`] these are derived from the ops' `chained`
    /// flags (edge `i-1 → i` iff `ops[i].chained`).
    pub edges: Vec<Edge>,
    /// Per-model op spans. Empty means "one implicit span covering all
    /// ops" (the common single-model case); use
    /// [`Workload::model_spans`] to read either form uniformly.
    pub models: Vec<ModelSpan>,
}

impl Workload {
    /// Legacy linear constructor: a topologically-ordered GEMM sequence
    /// whose dataflow is declared via the ops' `chained` flags. Derives
    /// one edge `i-1 → i` per chained op.
    pub fn new(name: &str, ops: Vec<GemmOp>) -> Self {
        let edges = (1..ops.len())
            .filter(|&i| ops[i].chained)
            .map(|i| Edge {
                src: i - 1,
                dst: i,
                rows: ops[i - 1].m,
                cols: ops[i - 1].n,
            })
            .collect();
        let w = Workload {
            name: name.to_string(),
            ops,
            edges,
            models: Vec::new(),
        };
        w.validate().expect("invalid workload");
        w
    }

    /// Graph constructor: ops in topological order plus explicit
    /// dataflow edges as `(src, dst)` index pairs. The ops' `chained`
    /// flags are **derived** (an op is chained iff it has an incoming
    /// edge); edge tensor shapes come from the producer dims.
    pub fn from_graph(
        name: &str,
        mut ops: Vec<GemmOp>,
        edge_pairs: &[(usize, usize)],
    ) -> Self {
        let mut edges: Vec<Edge> = edge_pairs
            .iter()
            .map(|&(src, dst)| Edge {
                src,
                dst,
                rows: ops.get(src).map_or(0, |o| o.m),
                cols: ops.get(src).map_or(0, |o| o.n),
            })
            .collect();
        edges.sort_by_key(|e| (e.src, e.dst));
        for op in ops.iter_mut() {
            op.chained = false;
        }
        for e in &edges {
            if let Some(op) = ops.get_mut(e.dst) {
                op.chained = true;
            }
        }
        let w = Workload {
            name: name.to_string(),
            ops,
            edges,
            models: Vec::new(),
        };
        w.validate().expect("invalid graph workload");
        w
    }

    /// Fuse several workloads into one schedulable scenario: ops and
    /// edges are concatenated with shifted indices (no cross-model
    /// edges — independent tenants), and each constituent becomes one
    /// [`ModelSpan`] so reports can attribute cost per model.
    pub fn concat(name: &str, parts: &[Workload]) -> Self {
        assert!(!parts.is_empty(), "concat of zero workloads");
        let mut ops = Vec::new();
        let mut edges = Vec::new();
        let mut models = Vec::new();
        for part in parts {
            let off = ops.len();
            models.extend(part.model_spans().into_iter().map(|s| ModelSpan {
                name: s.name,
                ops: s.ops.start + off..s.ops.end + off,
            }));
            ops.extend(part.ops.iter().cloned());
            edges.extend(part.edges.iter().map(|e| Edge {
                src: e.src + off,
                dst: e.dst + off,
                rows: e.rows,
                cols: e.cols,
            }));
        }
        // Disambiguate duplicate tenant names (`m#0`, `m#1`, …) so
        // per-model report rows stay attributable.
        {
            use std::collections::HashMap;
            let mut counts: HashMap<String, usize> = HashMap::new();
            for span in &models {
                *counts.entry(span.name.clone()).or_insert(0) += 1;
            }
            let mut seen: HashMap<String, usize> = HashMap::new();
            for span in models.iter_mut() {
                if counts[&span.name] > 1 {
                    let k = seen.entry(span.name.clone()).or_insert(0);
                    span.name = format!("{}#{k}", span.name);
                    *k += 1;
                }
            }
        }
        let w = Workload { name: name.to_string(), ops, edges, models };
        w.validate().expect("invalid fused workload");
        w
    }

    /// Multi-tenant scenario: fuse the given models under an
    /// auto-generated `a+b+…` name (one `Engine::sweep` cell schedules
    /// them all together; the report carries one span per model).
    pub fn multi_model(parts: &[Workload]) -> Self {
        let name = parts
            .iter()
            .map(|w| w.name.as_str())
            .collect::<Vec<_>>()
            .join("+");
        Workload::concat(&name, parts)
    }

    /// The per-model op spans: the stored provenance, or one implicit
    /// span covering the whole workload.
    pub fn model_spans(&self) -> Vec<ModelSpan> {
        if self.models.is_empty() {
            vec![ModelSpan { name: self.name.clone(), ops: 0..self.ops.len() }]
        } else {
            self.models.clone()
        }
    }

    pub fn validate(&self) -> Result<(), String> {
        if self.ops.is_empty() {
            return Err(format!("workload '{}' has no ops", self.name));
        }
        let n = self.ops.len();
        for (i, op) in self.ops.iter().enumerate() {
            if op.m == 0 || op.k == 0 || op.n == 0 {
                return Err(format!("op {i} '{}' has a zero dim", op.name));
            }
            if op.groups == 0 {
                return Err(format!(
                    "op {i} '{}': groups must be >= 1",
                    op.name
                ));
            }
            if op.groups > 1 && op.k % op.groups != 0 {
                return Err(format!(
                    "op {i} '{}': K={} not divisible by groups={}",
                    op.name, op.k, op.groups
                ));
            }
        }
        // Edges: forward in the stored topological order, in range,
        // no self-loops or duplicates, sorted by (src, dst).
        for (e, edge) in self.edges.iter().enumerate() {
            if edge.src >= n || edge.dst >= n {
                return Err(format!(
                    "edge {e} ({} -> {}) out of range (n={n})",
                    edge.src, edge.dst
                ));
            }
            if edge.src >= edge.dst {
                return Err(format!(
                    "edge {e} ({} -> {}) violates the stored topological \
                     order (src must precede dst)",
                    edge.src, edge.dst
                ));
            }
            let src_op = &self.ops[edge.src];
            if edge.rows != src_op.m || edge.cols != src_op.n {
                return Err(format!(
                    "edge {e} ({} -> {}) carries tensor shape {}x{} but \
                     its producer '{}' outputs {}x{}",
                    edge.src,
                    edge.dst,
                    edge.rows,
                    edge.cols,
                    src_op.name,
                    src_op.m,
                    src_op.n
                ));
            }
            if e > 0 {
                let prev = &self.edges[e - 1];
                if (prev.src, prev.dst) == (edge.src, edge.dst) {
                    return Err(format!(
                        "duplicate edge {} -> {}",
                        edge.src, edge.dst
                    ));
                }
                if (prev.src, prev.dst) > (edge.src, edge.dst) {
                    return Err(format!(
                        "edges not sorted by (src, dst) at index {e}"
                    ));
                }
            }
        }
        // Chained-derivation consistency: an op is chained iff it has an
        // incoming dataflow edge. (Catches struct-literal construction
        // that sets `chained` without declaring an edge — e.g. a chained
        // first op, which can have no producer.)
        for (i, op) in self.ops.iter().enumerate() {
            let has_in = self.edges.iter().any(|e| e.dst == i);
            if op.chained != has_in {
                return Err(format!(
                    "op {i} '{}': chained={} but {} incoming dataflow edge \
                     (chained is derived from edges)",
                    op.name,
                    op.chained,
                    if has_in { "has an" } else { "has no" }
                ));
            }
        }
        // Model spans (when present): contiguous ascending cover of ops.
        if !self.models.is_empty() {
            let mut at = 0usize;
            for (s, span) in self.models.iter().enumerate() {
                if span.ops.start != at || span.ops.end < span.ops.start {
                    return Err(format!(
                        "model span {s} '{}' does not tile the op range \
                         (starts at {}, expected {at})",
                        span.name, span.ops.start
                    ));
                }
                at = span.ops.end;
            }
            if at != n {
                return Err(format!(
                    "model spans cover {at} ops, workload has {n}"
                ));
            }
        }
        Ok(())
    }

    pub fn total_macs(&self) -> usize {
        self.ops.iter().map(|o| o.macs()).sum()
    }

    /// Number of dataflow edges (the arity of the per-edge gene vectors:
    /// `Allocation::collect_cols`, GA redistribution genes, MIQP edge
    /// decisions).
    pub fn edge_count(&self) -> usize {
        self.edges.len()
    }

    /// Stable content fingerprint of the *schedulable* graph (the
    /// serving layer's plan-cache key component). Hashes every field a
    /// scheduler or the evaluator can observe — op dims and attributes,
    /// edge endpoints and tensor shapes — but **not** `name` or the
    /// `models` provenance spans, so a renamed-but-identical workload
    /// (the same tenant resubmitting its model) shares the cache entry.
    /// Plans for colliding workloads are interchangeable by
    /// construction: nothing in scheduling reads the excluded fields.
    pub fn fingerprint(&self) -> u64 {
        let mut h = crate::util::hash::Fnv1a::new();
        h.write_len(self.ops.len());
        for op in &self.ops {
            h.write_usize(op.m);
            h.write_usize(op.k);
            h.write_usize(op.n);
            h.write_usize(op.groups);
            h.write_bool(op.sync);
            h.write_bool(op.shared_row);
            h.write_bool(op.shared_col);
            h.write_bool(op.relu);
            h.write_bool(op.chained);
        }
        h.write_len(self.edges.len());
        for e in &self.edges {
            h.write_usize(e.src);
            h.write_usize(e.dst);
            h.write_usize(e.rows);
            h.write_usize(e.cols);
        }
        h.finish()
    }

    /// Inverse of [`Workload::multi_model`] for the serving layer: one
    /// standalone workload per [`ModelSpan`], keeping only intra-span
    /// edges (fused multi-tenant workloads have none crossing spans)
    /// and re-deriving each op's `chained` flag from the kept edges so
    /// every part validates on its own.
    pub fn split_models(&self) -> Vec<Workload> {
        self.model_spans()
            .into_iter()
            .map(|span| {
                let off = span.ops.start;
                let mut ops: Vec<GemmOp> =
                    self.ops[span.ops.clone()].to_vec();
                let edges: Vec<Edge> = self
                    .edges
                    .iter()
                    .filter(|e| {
                        span.ops.contains(&e.src) && span.ops.contains(&e.dst)
                    })
                    .map(|e| Edge {
                        src: e.src - off,
                        dst: e.dst - off,
                        rows: e.rows,
                        cols: e.cols,
                    })
                    .collect();
                for (i, op) in ops.iter_mut().enumerate() {
                    op.chained = edges.iter().any(|e| e.dst == i);
                }
                Workload {
                    name: span.name.clone(),
                    ops,
                    edges,
                    models: Vec::new(),
                }
            })
            .collect()
    }

    /// In-degree of op `i` (number of dataflow producers).
    pub fn in_degree(&self, i: usize) -> usize {
        self.edges.iter().filter(|e| e.dst == i).count()
    }

    /// Out-degree of op `i` (number of dataflow consumers).
    pub fn out_degree(&self, i: usize) -> usize {
        self.edges.iter().filter(|e| e.src == i).count()
    }

    /// The unique incoming edge of op `i`, if its in-degree is exactly 1.
    pub fn sole_in_edge(&self, i: usize) -> Option<EdgeId> {
        let mut found = None;
        for (e, edge) in self.edges.iter().enumerate() {
            if edge.dst == i {
                if found.is_some() {
                    return None;
                }
                found = Some(e);
            }
        }
        found
    }

    /// The unique outgoing edge of op `i`, if its out-degree is exactly 1.
    pub fn sole_out_edge(&self, i: usize) -> Option<EdgeId> {
        let mut found = None;
        for (e, edge) in self.edges.iter().enumerate() {
            if edge.src == i {
                if found.is_some() {
                    return None;
                }
                found = Some(e);
            }
        }
        found
    }

    /// Fill `in_edge[c]` / `out_edge[p]` with each op's unique
    /// incoming / outgoing edge id (`None` when the degree is 0 or > 1).
    /// One O(|edges|) pass per side; buffers are reused allocation-free
    /// once warmed to the op count (the evaluator hot path).
    pub fn sole_edges_into(
        &self,
        in_edge: &mut Vec<Option<EdgeId>>,
        out_edge: &mut Vec<Option<EdgeId>>,
    ) {
        let n = self.ops.len();
        in_edge.clear();
        in_edge.resize(n, None);
        out_edge.clear();
        out_edge.resize(n, None);
        // Sentinel: usize::MAX marks "more than one edge seen".
        const MANY: EdgeId = usize::MAX;
        for (e, edge) in self.edges.iter().enumerate() {
            in_edge[edge.dst] = match in_edge[edge.dst] {
                None => Some(e),
                Some(_) => Some(MANY),
            };
            out_edge[edge.src] = match out_edge[edge.src] {
                None => Some(e),
                Some(_) => Some(MANY),
            };
        }
        for v in in_edge.iter_mut().chain(out_edge.iter_mut()) {
            if *v == Some(MANY) {
                *v = None;
            }
        }
    }

    /// §5.2 legality for one edge `p → c`: redistribution replaces the
    /// producer's store *and* the consumer's activation load, so it
    /// needs `c` to be `p`'s sole consumer (the store can be skipped)
    /// and `p` to be `c`'s sole producer (the layout transform serves
    /// the whole input), plain GEMMs on both ends, and no forced
    /// synchronization on the producer. On linear chains this is the
    /// historical `chained && groups == 1 && !sync` rule exactly.
    pub fn edge_redistributable(&self, e: EdgeId) -> bool {
        let (mut in_edge, mut out_edge) = (Vec::new(), Vec::new());
        self.sole_edges_into(&mut in_edge, &mut out_edge);
        self.edge_redistributable_with(e, &in_edge, &out_edge)
    }

    /// The single source of truth for [`Workload::edge_redistributable`]
    /// given precomputed sole-edge maps — the O(1)-per-edge form the
    /// evaluator hot path and `CachedEval` construction use so the
    /// legality clauses exist exactly once.
    pub fn edge_redistributable_with(
        &self,
        e: EdgeId,
        in_edge: &[Option<EdgeId>],
        out_edge: &[Option<EdgeId>],
    ) -> bool {
        let Edge { src, dst, .. } = self.edges[e];
        out_edge[src] == Some(e)
            && in_edge[dst] == Some(e)
            && self.ops[src].groups == 1
            && self.ops[dst].groups == 1
            && !self.ops[src].sync
    }

    /// Ids of every redistribution-legal edge (§5.2).
    pub fn redistributable_edges(&self) -> Vec<EdgeId> {
        (0..self.edges.len())
            .filter(|&e| self.edge_redistributable(e))
            .collect()
    }

    /// Indices `i` such that the adjacent edge `ops[i] -> ops[i+1]`
    /// exists and is redistributable (the legacy linear view; on
    /// linear-chain workloads this covers every legal edge).
    pub fn redistributable_pairs(&self) -> Vec<usize> {
        self.redistributable_edges()
            .into_iter()
            .filter(|&e| self.edges[e].dst == self.edges[e].src + 1)
            .map(|e| self.edges[e].src)
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_flags() {
        let op = GemmOp::dense("l", 8, 16, 32).relu().sync().grouped(4);
        assert!(op.relu && op.sync);
        assert_eq!(op.groups, 4);
        assert_eq!(op.macs(), 8 * 16 * 32);
        assert_eq!(op.elems(), (128, 512, 256));
    }

    #[test]
    fn chained_chain_accepted_and_edges_derived() {
        let a = GemmOp::dense("a", 8, 16, 32);
        let ok = GemmOp::dense("b", 8, 32, 64).chained();
        let w = Workload::new("w", vec![a, ok]);
        assert!(w.validate().is_ok());
        assert_eq!(w.edges.len(), 1);
        assert_eq!((w.edges[0].src, w.edges[0].dst), (0, 1));
        assert_eq!((w.edges[0].rows, w.edges[0].cols), (8, 32));
    }

    #[test]
    fn first_op_cannot_chain() {
        let w = Workload {
            name: "w".into(),
            ops: vec![GemmOp::dense("a", 8, 16, 32).chained()],
            edges: vec![],
            models: vec![],
        };
        assert!(w.validate().is_err());
    }

    #[test]
    fn redistributable_pairs_respect_groups_and_sync() {
        let ops = vec![
            GemmOp::dense("a", 8, 16, 32),
            GemmOp::dense("b", 8, 32, 32).chained(),
            GemmOp::dense("c", 8, 32, 16).chained().grouped(4).sync(),
            GemmOp::dense("d", 8, 16, 16).chained(),
        ];
        let w = Workload::new("w", ops);
        // a->b ok; b->c blocked (c grouped); c->d blocked (c sync+grouped).
        assert_eq!(w.redistributable_pairs(), vec![0]);
        assert_eq!(w.redistributable_edges(), vec![0]);
    }

    #[test]
    fn zero_dim_rejected() {
        let w = Workload {
            name: "w".into(),
            ops: vec![GemmOp::dense("a", 0, 16, 32)],
            edges: vec![],
            models: vec![],
        };
        assert!(w.validate().is_err());
    }

    #[test]
    fn groups_validation_is_exact() {
        let mut op = GemmOp::dense("a", 8, 48, 32);
        op.groups = 0;
        let w = Workload {
            name: "w".into(),
            ops: vec![op],
            edges: vec![],
            models: vec![],
        };
        assert!(w.validate().unwrap_err().contains("groups must be >= 1"));
        let bad = Workload {
            name: "w".into(),
            ops: vec![GemmOp::dense("a", 8, 48, 32).grouped(5)],
            edges: vec![],
            models: vec![],
        };
        assert!(bad.validate().unwrap_err().contains("not divisible"));
        // groups == 1 never requires divisibility; groups dividing K is
        // fine.
        assert!(Workload::new(
            "ok",
            vec![GemmOp::dense("a", 8, 48, 32).grouped(4)]
        )
        .validate()
        .is_ok());
    }

    #[test]
    fn from_graph_derives_chained_and_sorts_edges() {
        let ops = vec![
            GemmOp::dense("a", 8, 16, 32),
            GemmOp::dense("b", 8, 32, 32),
            GemmOp::dense("c", 8, 32, 16),
        ];
        // Declared out of order; fan-out a -> {b, c}.
        let w = Workload::from_graph("w", ops, &[(0, 2), (0, 1)]);
        assert_eq!(
            w.edges.iter().map(|e| (e.src, e.dst)).collect::<Vec<_>>(),
            vec![(0, 1), (0, 2)]
        );
        assert!(!w.ops[0].chained && w.ops[1].chained && w.ops[2].chained);
        // Fan-out producer: neither edge is redistributable (the store
        // cannot be skipped while another consumer still reads it).
        assert!(w.redistributable_edges().is_empty());
    }

    #[test]
    fn graph_rejects_backward_and_duplicate_edges() {
        let ops = || {
            vec![
                GemmOp::dense("a", 8, 16, 32),
                GemmOp::dense("b", 8, 32, 32),
            ]
        };
        let backward = Workload {
            name: "w".into(),
            ops: {
                let mut o = ops();
                o[0].chained = true;
                o
            },
            edges: vec![Edge { src: 1, dst: 0, rows: 8, cols: 32 }],
            models: vec![],
        };
        assert!(backward.validate().is_err());
        let dup = Workload {
            name: "w".into(),
            ops: {
                let mut o = ops();
                o[1].chained = true;
                o
            },
            edges: vec![
                Edge { src: 0, dst: 1, rows: 8, cols: 32 },
                Edge { src: 0, dst: 1, rows: 8, cols: 32 },
            ],
            models: vec![],
        };
        assert!(dup.validate().is_err());
    }

    #[test]
    fn degrees_and_sole_edges() {
        let ops = vec![
            GemmOp::dense("a", 8, 16, 32),
            GemmOp::dense("b", 8, 32, 32),
            GemmOp::dense("c", 8, 64, 16),
            GemmOp::dense("d", 8, 16, 16),
        ];
        // a -> b, a -> c, b -> d, c -> d (diamond).
        let w = Workload::from_graph("w", ops, &[(0, 1), (0, 2), (1, 3), (2, 3)]);
        assert_eq!((w.in_degree(0), w.out_degree(0)), (0, 2));
        assert_eq!((w.in_degree(3), w.out_degree(3)), (2, 0));
        assert_eq!(w.sole_in_edge(1), Some(0));
        assert_eq!(w.sole_out_edge(1), Some(2));
        assert_eq!(w.sole_in_edge(3), None);
        assert_eq!(w.sole_out_edge(0), None);
        let (mut ie, mut oe) = (Vec::new(), Vec::new());
        w.sole_edges_into(&mut ie, &mut oe);
        assert_eq!(ie, vec![None, Some(0), Some(1), None]);
        assert_eq!(oe, vec![None, Some(2), Some(3), None]);
    }

    #[test]
    fn concat_offsets_ops_edges_and_spans() {
        let a = Workload::new(
            "a",
            vec![
                GemmOp::dense("a0", 8, 16, 32),
                GemmOp::dense("a1", 8, 32, 16).chained(),
            ],
        );
        let b = Workload::new(
            "b",
            vec![
                GemmOp::dense("b0", 4, 8, 8),
                GemmOp::dense("b1", 4, 8, 8).chained(),
            ],
        );
        let fused = Workload::multi_model(&[a, b]);
        assert_eq!(fused.name, "a+b");
        assert_eq!(fused.ops.len(), 4);
        assert_eq!(
            fused.edges.iter().map(|e| (e.src, e.dst)).collect::<Vec<_>>(),
            vec![(0, 1), (2, 3)]
        );
        let spans = fused.model_spans();
        assert_eq!(spans.len(), 2);
        assert_eq!((spans[0].name.as_str(), spans[0].ops.clone()), ("a", 0..2));
        assert_eq!((spans[1].name.as_str(), spans[1].ops.clone()), ("b", 2..4));
        // No cross-model redistribution can exist (no cross-model edges).
        for e in fused.redistributable_edges() {
            let edge = fused.edges[e];
            let same = spans.iter().any(|s| {
                s.ops.contains(&edge.src) && s.ops.contains(&edge.dst)
            });
            assert!(same);
        }
    }

    #[test]
    fn concat_disambiguates_duplicate_tenant_names() {
        let a = Workload::new("m", vec![GemmOp::dense("x", 8, 16, 32)]);
        let fused = Workload::multi_model(&[a.clone(), a]);
        assert_eq!(fused.name, "m+m");
        let names: Vec<String> =
            fused.model_spans().into_iter().map(|s| s.name).collect();
        assert_eq!(names, vec!["m#0".to_string(), "m#1".to_string()]);
    }

    #[test]
    fn model_spans_implicit_for_single_model() {
        let w = Workload::new("w", vec![GemmOp::dense("a", 8, 16, 32)]);
        let spans = w.model_spans();
        assert_eq!(spans.len(), 1);
        assert_eq!(spans[0].ops, 0..1);
        assert_eq!(spans[0].name, "w");
    }
}
