//! Workload IR: the machine-learning task of paper §4.2.2 — a
//! topologically-ordered sequence of GEMM operators with synchronization
//! and sharing attributes, plus the model zoo used in the evaluation
//! (AlexNet, ViT, Vision Mamba, HydraNet).

pub mod models;

/// One GEMM operator: `OP_i = {M, K, N, sync, shared_row, shared_col}`
/// (eq. 2) plus execution attributes the co-optimizations need.
#[derive(Debug, Clone)]
pub struct GemmOp {
    pub name: String,
    /// Output rows (input dimension M).
    pub m: usize,
    /// Contraction (hidden) dimension.
    pub k: usize,
    /// Output columns.
    pub n: usize,
    /// Output must be synchronized across chiplets before the next op
    /// (softmax / layer-norm style reductions).
    pub sync: bool,
    /// Chiplets of the same grid row produce the same output rows.
    pub shared_row: bool,
    /// Chiplets of the same grid column produce the same output columns.
    pub shared_col: bool,
    /// Fused ReLU epilogue (computed in the chiplet SIMD unit).
    pub relu: bool,
    /// Input activations are the previous op's output (enables §5.2
    /// on-package redistribution instead of a memory round-trip).
    pub chained: bool,
    /// Grouped GEMM factor (attention heads). Redistribution only applies
    /// to plain GEMMs (`groups == 1`); grouped ops keep complex head-wise
    /// data mappings (§7.1).
    pub groups: usize,
}

impl GemmOp {
    /// Plain dense layer.
    pub fn dense(name: &str, m: usize, k: usize, n: usize) -> Self {
        GemmOp {
            name: name.to_string(),
            m,
            k,
            n,
            sync: false,
            shared_row: true,
            shared_col: true,
            relu: false,
            chained: false,
            groups: 1,
        }
    }

    pub fn relu(mut self) -> Self {
        self.relu = true;
        self
    }

    pub fn chained(mut self) -> Self {
        self.chained = true;
        self
    }

    pub fn sync(mut self) -> Self {
        self.sync = true;
        self
    }

    pub fn grouped(mut self, groups: usize) -> Self {
        assert!(groups >= 1);
        self.groups = groups;
        self
    }

    /// MACs for this op (per sample).
    pub fn macs(&self) -> usize {
        self.m * self.k * self.n
    }

    /// Element counts (input, weight, output).
    pub fn elems(&self) -> (usize, usize, usize) {
        (self.m * self.k, self.k * self.n, self.m * self.n)
    }

    /// Redistribution between this op and the next is legal only for
    /// chained plain GEMMs (the next op consumes exactly this output).
    pub fn redistributable_to(&self, next: &GemmOp) -> bool {
        next.chained && self.groups == 1 && next.groups == 1 && !self.sync
    }
}

/// A workload: named, ordered GEMM sequence (one topological order of the
/// model DAG, §4.2.2).
#[derive(Debug, Clone)]
pub struct Workload {
    pub name: String,
    pub ops: Vec<GemmOp>,
}

impl Workload {
    pub fn new(name: &str, ops: Vec<GemmOp>) -> Self {
        let w = Workload { name: name.to_string(), ops };
        w.validate().expect("invalid workload");
        w
    }

    pub fn validate(&self) -> Result<(), String> {
        if self.ops.is_empty() {
            return Err(format!("workload '{}' has no ops", self.name));
        }
        for (i, op) in self.ops.iter().enumerate() {
            if op.m == 0 || op.k == 0 || op.n == 0 {
                return Err(format!("op {i} '{}' has a zero dim", op.name));
            }
            if op.groups == 0 || op.k % op.groups != 0 {
                // groups partition the contraction/head dim layout; we
                // only require divisibility of K for grouped ops.
                if op.groups != 1 {
                    return Err(format!(
                        "op {i} '{}': K={} not divisible by groups={}",
                        op.name, op.k, op.groups
                    ));
                }
            }
            if i == 0 && op.chained {
                return Err("first op cannot be chained".into());
            }
        }
        Ok(())
    }

    pub fn total_macs(&self) -> usize {
        self.ops.iter().map(|o| o.macs()).sum()
    }

    /// Indices `i` such that ops[i] -> ops[i+1] is redistributable.
    pub fn redistributable_pairs(&self) -> Vec<usize> {
        (0..self.ops.len().saturating_sub(1))
            .filter(|&i| self.ops[i].redistributable_to(&self.ops[i + 1]))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_flags() {
        let op = GemmOp::dense("l", 8, 16, 32).relu().sync().grouped(4);
        assert!(op.relu && op.sync);
        assert_eq!(op.groups, 4);
        assert_eq!(op.macs(), 8 * 16 * 32);
        assert_eq!(op.elems(), (128, 512, 256));
    }

    #[test]
    fn chained_chain_accepted() {
        let a = GemmOp::dense("a", 8, 16, 32);
        let ok = GemmOp::dense("b", 8, 32, 64).chained();
        assert!(Workload::new("w", vec![a, ok]).validate().is_ok());
    }

    #[test]
    fn first_op_cannot_chain() {
        let w = Workload {
            name: "w".into(),
            ops: vec![GemmOp::dense("a", 8, 16, 32).chained()],
        };
        assert!(w.validate().is_err());
    }

    #[test]
    fn redistributable_pairs_respect_groups_and_sync() {
        let ops = vec![
            GemmOp::dense("a", 8, 16, 32),
            GemmOp::dense("b", 8, 32, 32).chained(),
            GemmOp::dense("c", 8, 32, 16).chained().grouped(4).sync(),
            GemmOp::dense("d", 8, 16, 16).chained(),
        ];
        let w = Workload::new("w", ops);
        // a->b ok; b->c blocked (c grouped); c->d blocked (c sync+grouped).
        assert_eq!(w.redistributable_pairs(), vec![0]);
    }

    #[test]
    fn zero_dim_rejected() {
        let w = Workload {
            name: "w".into(),
            ops: vec![GemmOp::dense("a", 0, 16, 32)],
        };
        assert!(w.validate().is_err());
    }
}
