//! ViT-Base/16 at 224x224 as a GEMM sequence.
//!
//! Attention is expressed as grouped GEMMs over the 12 heads: the paper
//! notes grouped operators keep complex head-wise mappings, so
//! redistribution applies only to the (plain) MLP projections (§7.1).
//! Softmax / layer-norm boundaries are `sync` ops.
//!
//! Two IR views of the same op list:
//! * [`vit`] — the paper's linear-chain view (the evaluation workload;
//!   pinned bit-identical across the graph-IR refactor);
//! * [`vit_residual`] — the dataflow-graph view with the real residual
//!   edges around attention (`block input → proj`), giving `proj` a
//!   fan-in of 2. The residual consumer re-reads fused activations, so
//!   those edges are never redistribution-legal — exactly the
//!   branching structure the edge-indexed stack must schedule.

use crate::workload::{GemmOp, Workload};

const SEQ: usize = 197; // 196 patches + CLS
const D: usize = 768;
const HEADS: usize = 12;
const HEAD_D: usize = D / HEADS;
const MLP: usize = 3072;
const BLOCKS: usize = 12;

fn vit_ops(batch: usize) -> Vec<GemmOp> {
    assert!(batch >= 1);
    let s = batch * SEQ;
    let mut ops = Vec::new();
    // Patch embedding: 16x16x3 patches -> D.
    ops.push(GemmOp::dense("patch_embed", s, 16 * 16 * 3, D));
    for blk in 0..BLOCKS {
        let p = |stage: &str| format!("blk{blk}.{stage}");
        // LN precedes qkv -> sync on the producer side is modeled by the
        // qkv op being non-chained (activations re-read post-norm).
        ops.push(GemmOp::dense(&p("qkv"), s, D, 3 * D).sync());
        // scores = Q K^T per head: M = seq, K = head_d, N = seq.
        ops.push(
            GemmOp::dense(&p("scores"), s, HEAD_D * HEADS, SEQ)
                .grouped(HEADS)
                .sync(), // softmax afterwards
        );
        // context = softmax(scores) V per head.
        ops.push(
            GemmOp::dense(&p("attn_v"), s, SEQ * HEADS, HEAD_D)
                .grouped(HEADS),
        );
        ops.push(GemmOp::dense(&p("proj"), s, D, D).chained());
        // MLP (LN boundary -> sync on fc1).
        ops.push(GemmOp::dense(&p("fc1"), s, D, MLP).relu().sync());
        ops.push(GemmOp::dense(&p("fc2"), s, MLP, D).chained());
    }
    ops.push(GemmOp::dense("head", batch, D, 1000));
    ops
}

/// The linear-chain view (one topological order, dataflow declared via
/// `chained`; §4.2.2) — the paper's evaluation workload.
pub fn vit(batch: usize) -> Workload {
    Workload::new("vit", vit_ops(batch))
}

/// Op index of block `blk`'s `stage`-th op (0 = qkv … 5 = fc2).
fn blk_op(blk: usize, stage: usize) -> usize {
    1 + 6 * blk + stage
}

/// The dataflow-graph view with real residual edges: per block, the
/// chain edges `attn_v → proj` and `fc1 → fc2` plus the attention
/// residual `block input → proj` (block input = previous block's fc2,
/// or the patch embedding for block 0). `proj`'s fan-in of 2 makes its
/// incoming edges redistribution-illegal on top of ViT's grouped/sync
/// restrictions — a genuinely branching DAG the edge-indexed stack
/// must schedule end to end.
pub fn vit_residual(batch: usize) -> Workload {
    let ops = vit_ops(batch);
    let mut edges: Vec<(usize, usize)> = Vec::new();
    for blk in 0..BLOCKS {
        let block_in = if blk == 0 { 0 } else { blk_op(blk - 1, 5) };
        edges.push((blk_op(blk, 2), blk_op(blk, 3))); // attn_v -> proj
        edges.push((block_in, blk_op(blk, 3))); // residual -> proj
        edges.push((blk_op(blk, 4), blk_op(blk, 5))); // fc1 -> fc2
    }
    Workload::from_graph("vit-residual", ops, &edges)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn op_count_and_dims() {
        let w = vit(1);
        assert_eq!(w.ops.len(), 2 + 6 * BLOCKS);
        let qkv = &w.ops[1];
        assert_eq!((qkv.m, qkv.k, qkv.n), (197, 768, 2304));
        let scores = &w.ops[2];
        assert_eq!(scores.groups, HEADS);
    }

    #[test]
    fn total_macs_close_to_published() {
        // ViT-B/16 is published at 17.6 "GFLOPs" (MAC = 1 FLOP
        // convention, ~= params 86M x seq 197); we model matmuls only.
        let macs = vit(1).total_macs() as f64;
        assert!(macs > 14e9 && macs < 21e9, "macs={macs}");
    }

    #[test]
    fn redistribution_only_in_mlp_and_proj() {
        let w = vit(1);
        for i in w.redistributable_pairs() {
            let nxt = &w.ops[i + 1].name;
            assert!(
                nxt.contains("proj") || nxt.contains("fc2"),
                "unexpected redistributable edge into {nxt}"
            );
        }
    }

    #[test]
    fn residual_variant_branches_without_legal_redistribution() {
        let w = vit_residual(1);
        assert!(w.validate().is_ok());
        assert_eq!(w.edges.len(), 3 * BLOCKS);
        // Every proj has fan-in 2 (attn_v + residual).
        for blk in 0..BLOCKS {
            assert_eq!(w.in_degree(blk_op(blk, 3)), 2, "blk {blk} proj");
        }
        // ViT's grouped attention (attn_v), LN sync (fc1) and the
        // residual fan-in (proj) leave no §5.2-legal edge — same as the
        // linear view, whose redistributable pairs are also empty.
        assert!(w.redistributable_edges().is_empty());
        assert!(vit(1).redistributable_pairs().is_empty());
        // Same compute, different dataflow.
        assert_eq!(w.total_macs(), vit(1).total_macs());
    }
}
